//! UlyssesSPDataLoaderAdapter (paper §4.2) + pre-shifted labels (§4.3)
//! + synthetic long-sequence sources.
//!
//! The adapter wraps any batch source and (a) pre-shifts labels on the
//! FULL sequence, then (b) shards ids/labels/positions along the sequence
//! dimension — the SP-over-DP protocol: one source batch is consumed
//! collaboratively by all SP ranks.

use crate::util::rng::Rng;

pub const IGNORE_INDEX: i32 = -100;

/// One rank's view of a training sample.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedBatch {
    pub ids: Vec<i32>,
    /// Global positions (replaces the paper's O(S^2) 4-D mask, §3.4).
    pub positions: Vec<i32>,
    /// Pre-shifted labels (§4.3): shifted on the full sequence BEFORE
    /// sharding, so no token is dropped at shard boundaries.
    pub labels: Vec<i32>,
}

/// Paper §4.3: shift-left on the full sequence, pad with IGNORE_INDEX.
///
/// WHOLE-SEQUENCE-ONLY. This shift assumes `ids` is ONE document. On a
/// packed sequence (several documents back to back) it leaks exactly one
/// cross-document target per boundary: the last token of each document
/// gets the NEXT document's first token as its label — a silent §7.2-class
/// correctness bug. Packed inputs must use
/// `crate::packing::shift_labels_packed`, which masks every boundary with
/// `IGNORE_INDEX` instead (see `naive_shift_leaks_across_packed_boundaries`
/// below for the executable counterexample).
pub fn shift_labels(ids: &[i32]) -> Vec<i32> {
    let mut out = Vec::with_capacity(ids.len());
    out.extend_from_slice(&ids[1..]);
    out.push(IGNORE_INDEX);
    out
}

/// The WRONG way (what HF does without the ALST patch): shifting each
/// shard independently. Kept as an executable counterexample; tests assert
/// it drops one in-shard boundary token per shard.
pub fn naive_shard_then_shift(ids: &[i32], sp: usize) -> Vec<Vec<i32>> {
    split(ids, sp).into_iter().map(|s| shift_labels(&s)).collect()
}

fn split(xs: &[i32], sp: usize) -> Vec<Vec<i32>> {
    assert_eq!(xs.len() % sp, 0, "sequence not divisible by sp");
    let ssh = xs.len() / sp;
    (0..sp).map(|r| xs[r * ssh..(r + 1) * ssh].to_vec()).collect()
}

/// Shard one full sequence for `sp` ranks.
pub fn shard_sequence(ids: &[i32], sp: usize) -> Vec<ShardedBatch> {
    let labels = shift_labels(ids);
    let ssh = ids.len() / sp;
    let id_sh = split(ids, sp);
    let lab_sh = split(&labels, sp);
    (0..sp)
        .map(|r| ShardedBatch {
            ids: id_sh[r].clone(),
            positions: ((r * ssh) as i32..((r + 1) * ssh) as i32).collect(),
            labels: lab_sh[r].clone(),
        })
        .collect()
}

/// A source of full-length sequences.
pub trait BatchSource {
    fn next_sequence(&mut self) -> Vec<i32>;
    fn seq_len(&self) -> usize;
}

impl BatchSource for Box<dyn BatchSource> {
    fn next_sequence(&mut self) -> Vec<i32> {
        (**self).next_sequence()
    }

    fn seq_len(&self) -> usize {
        (**self).seq_len()
    }
}

/// Learnable synthetic corpus: an order-1 Markov chain with high-probability
/// deterministic transitions (next = a*cur+c mod V with prob 1-eps). A
/// model that trains correctly drives loss well below ln(V); a broken
/// pipeline stays at chance — this is the e2e driver's signal.
pub struct MarkovSource {
    pub vocab: usize,
    pub seq: usize,
    pub noise: f64,
    rng: Rng,
}

impl MarkovSource {
    pub fn new(vocab: usize, seq: usize, noise: f64, seed: u64) -> MarkovSource {
        MarkovSource { vocab, seq, noise, rng: Rng::new(seed) }
    }

    fn next_token(&mut self, cur: i32) -> i32 {
        if self.rng.uniform() < self.noise {
            self.rng.below(self.vocab) as i32
        } else {
            ((cur as u64 * 31 + 17) % self.vocab as u64) as i32
        }
    }
}

impl BatchSource for MarkovSource {
    fn next_sequence(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.seq);
        let mut cur = self.rng.below(self.vocab) as i32;
        for _ in 0..self.seq {
            out.push(cur);
            cur = self.next_token(cur);
        }
        out
    }

    fn seq_len(&self) -> usize {
        self.seq
    }
}

/// Uniform-random tokens (memory/perf benches where learnability is moot).
pub struct UniformSource {
    pub vocab: usize,
    pub seq: usize,
    rng: Rng,
}

impl UniformSource {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> UniformSource {
        UniformSource { vocab, seq, rng: Rng::new(seed) }
    }
}

impl BatchSource for UniformSource {
    fn next_sequence(&mut self) -> Vec<i32> {
        (0..self.seq).map(|_| self.rng.below(self.vocab) as i32).collect()
    }

    fn seq_len(&self) -> usize {
        self.seq
    }
}

/// Byte-level corpus source: tokenizes a text file as raw bytes (vocab
/// 256) and yields random windows — the "tiny-corpus" path for e2e runs
/// on real data without an external tokenizer.
pub struct CorpusSource {
    bytes: Vec<u8>,
    pub seq: usize,
    rng: Rng,
}

impl CorpusSource {
    pub fn from_file(path: &std::path::Path, seq: usize, seed: u64) -> anyhow::Result<Self> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(
            bytes.len() > seq,
            "corpus {} has {} bytes, need > {seq}",
            path.display(),
            bytes.len()
        );
        Ok(CorpusSource { bytes, seq, rng: Rng::new(seed) })
    }

    pub fn from_bytes(bytes: Vec<u8>, seq: usize, seed: u64) -> Self {
        assert!(bytes.len() > seq);
        CorpusSource { bytes, seq, rng: Rng::new(seed) }
    }

    /// Byte-level vocab for model configs trained on this source.
    pub const VOCAB: usize = 256;
}

impl BatchSource for CorpusSource {
    fn next_sequence(&mut self) -> Vec<i32> {
        let start = self.rng.below(self.bytes.len() - self.seq);
        self.bytes[start..start + self.seq]
            .iter()
            .map(|&b| b as i32)
            .collect()
    }

    fn seq_len(&self) -> usize {
        self.seq
    }
}

/// The adapter: wraps a source, yields per-rank shard sets.
pub struct UlyssesDataLoader<S: BatchSource> {
    pub source: S,
    pub sp: usize,
}

impl<S: BatchSource> UlyssesDataLoader<S> {
    pub fn new(source: S, sp: usize) -> Self {
        assert_eq!(source.seq_len() % sp, 0, "seq must divide by sp");
        UlyssesDataLoader { source, sp }
    }

    /// Next global batch as (full_sequence, per-rank shards).
    pub fn next(&mut self) -> (Vec<i32>, Vec<ShardedBatch>) {
        let ids = self.source.next_sequence();
        let shards = shard_sequence(&ids, self.sp);
        (ids, shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shift_example() {
        // §4.3: [1..8] -> [2 3 4 5 6 7 8 -100]; sp=2 shards keep token 5.
        let ids: Vec<i32> = (1..=8).collect();
        let sh = shard_sequence(&ids, 2);
        assert_eq!(sh[0].labels, vec![2, 3, 4, 5]);
        assert_eq!(sh[1].labels, vec![6, 7, 8, IGNORE_INDEX]);
        // the naive way drops token 5:
        let naive = naive_shard_then_shift(&ids, 2);
        assert!(!naive.concat().contains(&5));
    }

    #[test]
    fn positions_are_global() {
        let ids: Vec<i32> = (0..12).collect();
        let sh = shard_sequence(&ids, 3);
        assert_eq!(sh[1].positions, vec![4, 5, 6, 7]);
        assert_eq!(sh[2].positions, vec![8, 9, 10, 11]);
    }

    #[test]
    fn every_label_appears_exactly_once() {
        let ids: Vec<i32> = (100..164).collect();
        let sh = shard_sequence(&ids, 4);
        let all: Vec<i32> = sh.iter().flat_map(|s| s.labels.clone()).collect();
        let expect: Vec<i32> = (101..164).chain([IGNORE_INDEX]).collect();
        assert_eq!(all, expect);
    }

    #[test]
    fn naive_shift_leaks_across_packed_boundaries() {
        // The shift_labels hazard (companion to `naive_shard_then_shift`):
        // applied to a PACKED sequence it emits exactly one cross-document
        // target per boundary; the segment-aware shift differs from it at
        // exactly those positions and nowhere else.
        use crate::packing::shift_labels_packed;
        let lens = [3usize, 2, 4, 1];
        let mut ids = Vec::new();
        let mut cu = vec![0i32];
        for (d, &n) in lens.iter().enumerate() {
            ids.extend((0..n as i32).map(|t| 100 * (d as i32 + 1) + t));
            cu.push(ids.len() as i32);
        }
        let naive = shift_labels(&ids);
        let packed = shift_labels_packed(&ids, &cu);
        let boundaries: Vec<usize> =
            cu[1..cu.len() - 1].iter().map(|&c| c as usize - 1).collect();
        for i in 0..ids.len() {
            if boundaries.contains(&i) {
                // the leak: naive targets the NEXT document's first token
                assert_eq!(naive[i], ids[i + 1], "expected leak at {i}");
                assert_ne!(naive[i] / 100, ids[i] / 100, "leak crosses docs");
                assert_eq!(packed[i], IGNORE_INDEX, "packed must mask {i}");
            } else {
                assert_eq!(naive[i], packed[i], "only boundaries differ ({i})");
            }
        }
        // exactly one leaked target per internal boundary
        let leaks = ids
            .iter()
            .enumerate()
            .take(ids.len() - 1)
            .filter(|&(i, _)| naive[i] != packed[i])
            .count();
        assert_eq!(leaks, lens.len() - 1);
    }

    #[test]
    fn markov_source_is_learnable_structure() {
        let mut src = MarkovSource::new(64, 256, 0.05, 1);
        let seq = src.next_sequence();
        // most transitions follow the deterministic rule
        let follows = seq
            .windows(2)
            .filter(|w| w[1] as u64 == (w[0] as u64 * 31 + 17) % 64)
            .count();
        assert!(follows > 200, "only {follows}/255 deterministic");
    }

    #[test]
    fn markov_deterministic_by_seed() {
        let a = MarkovSource::new(64, 32, 0.1, 7).next_sequence();
        let b = MarkovSource::new(64, 32, 0.1, 7).next_sequence();
        assert_eq!(a, b);
    }

    #[test]
    fn corpus_source_windows_are_in_vocab_range() {
        let text: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        let mut src = CorpusSource::from_bytes(text, 128, 5);
        for _ in 0..10 {
            let seq = src.next_sequence();
            assert_eq!(seq.len(), 128);
            assert!(seq.iter().all(|&t| (0..256).contains(&t)));
        }
        // deterministic by seed
        let a = CorpusSource::from_bytes(vec![7; 300], 64, 9).next_sequence();
        let b = CorpusSource::from_bytes(vec![7; 300], 64, 9).next_sequence();
        assert_eq!(a, b);
    }

    #[test]
    fn corpus_source_rejects_short_files() {
        let err = CorpusSource::from_file(
            std::path::Path::new("/nonexistent-corpus"), 64, 0);
        assert!(err.is_err());
    }

    #[test]
    fn loader_shards_cover_sequence() {
        let mut dl = UlyssesDataLoader::new(UniformSource::new(100, 64, 3), 4);
        let (full, shards) = dl.next();
        let recat: Vec<i32> = shards.iter().flat_map(|s| s.ids.clone()).collect();
        assert_eq!(full, recat);
    }
}
