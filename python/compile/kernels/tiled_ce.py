"""Fused tiled cross-entropy (paper §3.1, the Liger-style logits+loss fusion).

The naive loss head materializes logits `[S, V]` — 7.65 GiB for Llama-8B at
16K tokens (paper's worked example). This kernel never does: a 2-D Pallas
grid walks (sequence tiles × vocab tiles) and keeps only a `[TS, TV]` score
tile plus three `[TS]` accumulators (running max `m`, running sum-exp `l`,
target logit `t`) in VMEM. The per-token loss is `(m + log l) - t`.

Backward is a `custom_vjp` with the same tiling schedule written in jnp
(`lax.scan` over sequence tiles; each step materializes only one
`[TS, V]` probability block) — this mirrors the paper's TiledCompute
autograd function, which re-runs each tile's forward during backward.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's Triton
(Liger) kernel streams logits chunks through SRAM; here the BlockSpec
index maps express the same HBM↔VMEM schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

IGNORE_INDEX = ref.IGNORE_INDEX
NEG_INF = -1e30


def _ce_kernel(h_ref, w_ref, lab_ref, m_ref, l_ref, t_ref, *, tile_v: int):
    """One (seq-tile i, vocab-tile j) grid step of the online-LSE reduction."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    scores = h_ref[...] @ w_ref[...]                        # [TS, TV] in VMEM
    labels = lab_ref[...]                                   # [TS] global ids

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, scores.max(axis=-1))
    # Rescale the old sum-exp to the new max, add this tile's contribution.
    l_ref[...] = l_ref[...] * jnp.exp(m_old - m_new) + jnp.exp(
        scores - m_new[:, None]
    ).sum(axis=-1)
    m_ref[...] = m_new

    # Pick out the target logit if it falls inside this vocab tile.
    local = labels - j * tile_v
    in_tile = (local >= 0) & (local < tile_v)
    safe = jnp.clip(local, 0, tile_v - 1)
    picked = jnp.take_along_axis(scores, safe[:, None], axis=-1)[:, 0]
    t_ref[...] = t_ref[...] + jnp.where(in_tile, picked, 0.0)


def ce_forward_parts(hidden, unembed, labels, *, tile_s: int = 128,
                     tile_v: int = 512, interpret: bool = True):
    """Run the Pallas grid; return (m, l, t) accumulators, shape [S] each."""
    s, h = hidden.shape
    v = unembed.shape[1]
    assert s % tile_s == 0 and v % tile_v == 0, (s, tile_s, v, tile_v)
    grid = (s // tile_s, v // tile_v)
    kernel = functools.partial(_ce_kernel, tile_v=tile_v)
    m, l, t = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_s, h), lambda i, j: (i, 0)),
            pl.BlockSpec((h, tile_v), lambda i, j: (0, j)),
            pl.BlockSpec((tile_s,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_s,), lambda i, j: (i,)),
            pl.BlockSpec((tile_s,), lambda i, j: (i,)),
            pl.BlockSpec((tile_s,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=interpret,
    )(hidden, unembed, labels)
    return m, l, t


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def ce_tiled(hidden, unembed, labels, tile_s: int = 128, tile_v: int = 512):
    """Fused tiled CE. Returns (loss_sum, count) like ref.ce_naive."""
    return _ce_fwd(hidden, unembed, labels, tile_s, tile_v)[0]


def _ce_fwd(hidden, unembed, labels, tile_s, tile_v):
    m, l, t = ce_forward_parts(hidden, unembed, labels,
                               tile_s=tile_s, tile_v=tile_v)
    mask = labels != IGNORE_INDEX
    per_tok = jnp.where(mask, (m + jnp.log(l)) - t, 0.0)
    out = (per_tok.sum(), mask.sum().astype(jnp.float32))
    return out, (hidden, unembed, labels)


def _ce_bwd(tile_s, tile_v, res, cts):
    """Tiled backward: per seq tile, d_logits = (softmax - onehot) masked."""
    hidden, unembed, labels = res
    g_sum, _ = cts                        # count is non-differentiable
    s, h = hidden.shape
    v = unembed.shape[1]
    n = s // tile_s

    def body(d_w, idx):
        hs = jax.lax.dynamic_slice_in_dim(hidden, idx * tile_s, tile_s, 0)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * tile_s, tile_s, 0)
        logits = hs @ unembed                                  # [TS, V] only
        probs = jax.nn.softmax(logits, axis=-1)
        mask = ls != IGNORE_INDEX
        onehot = jax.nn.one_hot(jnp.where(mask, ls, 0), v, dtype=probs.dtype)
        d_logits = (probs - onehot) * mask[:, None].astype(probs.dtype) * g_sum
        d_hs = d_logits @ unembed.T
        return d_w + hs.T @ d_logits, d_hs

    d_w0 = jnp.zeros_like(unembed)
    d_w, d_h_tiles = jax.lax.scan(body, d_w0, jnp.arange(n))
    d_hidden = d_h_tiles.reshape(s, h)
    return d_hidden, d_w, None


ce_tiled.defvjp(_ce_fwd, _ce_bwd)
