//! The fault-site sweep: the recovery contract must hold no matter WHERE
//! a fault lands, not just at hand-picked spots.
//!
//! For each plan (Ulysses, Ring), world (sp 2 and 4), and rank-execution
//! mode (threaded, serial), an unfaulted 2-step chaos-harness run counts
//! its collective ops; then one faulted run per op index injects a fault
//! at exactly that op — alternating a lost rank (must restore from
//! snapshot and replay) with a transient (must be absorbed in place by
//! retry/backoff) — and every run must end with parameters bit-identical
//! to the unfaulted reference, balanced host/device ledgers, and (sampled)
//! a steady-state arena. Companion sweeps cover the per-rank stage-exec
//! gates and the checksummed offload copy streams (corrupt payloads
//! included).

use alst::collectives::faults::{FaultKind, FaultPlan, FaultSite};
use alst::config::PlanKind;
use alst::coordinator::recover::{
    run_resilient, ChaosConfig, ChaosHarness, Recoverable, ResilienceOptions,
};

fn snap(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("alst-chaos-sweep");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.alst"))
}

fn cfg(
    plan: PlanKind,
    sp: usize,
    threaded: bool,
    fault: Option<FaultPlan>,
) -> ChaosConfig {
    ChaosConfig {
        sp,
        seq: 16,
        n_layers: 2,
        plan,
        threaded,
        trace: false,
        fault_plan: fault,
    }
}

/// Unfaulted 2-step run: final params + the sweep bound (successful
/// collective ops across both steps).
fn reference(plan: PlanKind, sp: usize, threaded: bool) -> (Vec<f32>, u64) {
    let mut h = ChaosHarness::new(cfg(plan, sp, threaded, None)).unwrap();
    let opts = ResilienceOptions {
        snapshot_every: 1,
        ..ResilienceOptions::new(snap(&format!("ref-{plan:?}-{sp}-{threaded}")))
    };
    run_resilient(&mut h, 2, &opts).unwrap();
    (h.params_flat(), h.collective_ops())
}

/// One faulted run at one (site, rank, op) point; asserts the full
/// recovery contract against `want`.
fn check_point(
    plan: PlanKind,
    sp: usize,
    threaded: bool,
    fault: FaultPlan,
    want: &[f32],
    steady_check: bool,
) {
    let tag = format!(
        "{plan:?}-{sp}-{threaded}-{:?}-{:?}-r{}-op{}",
        fault.site, fault.kind, fault.rank, fault.at_op
    );
    let kind = fault.kind;
    let mut h = ChaosHarness::new(cfg(plan, sp, threaded, Some(fault))).unwrap();
    let opts = ResilienceOptions {
        snapshot_every: 1,
        ..ResilienceOptions::new(snap(&tag))
    };
    let report = run_resilient(&mut h, 2, &opts)
        .unwrap_or_else(|e| panic!("{tag}: supervisor failed: {e:#}"));
    assert_eq!(report.fault.injected, 1, "{tag}: fault never fired");
    match kind {
        FaultKind::LostRank => {
            assert_eq!(report.recoveries, 1, "{tag}: lost rank must restore once");
        }
        FaultKind::Transient | FaultKind::CorruptPayload => {
            assert_eq!(report.recoveries, 0, "{tag}: retryable fault must not restore");
            assert!(report.fault.retries >= 1, "{tag}: retryable fault never retried");
        }
    }
    assert_eq!(h.params_flat(), want, "{tag}: diverged from unfaulted reference");
    assert_eq!(h.host_bytes(), 0, "{tag}: leaked host bytes");
    assert_eq!(h.device_bytes(), 0, "{tag}: leaked device bytes");
    if steady_check {
        // two further unfaulted steps take/recycle in balance: the pool
        // footprint stops changing once recovery settled
        h.step_once().unwrap();
        let one = (h.arena().pooled(), h.arena().pooled_bytes());
        h.step_once().unwrap();
        let two = (h.arena().pooled(), h.arena().pooled_bytes());
        assert_eq!(one, two, "{tag}: arena not steady after recovery");
    }
}

fn sweep_collectives(plan: PlanKind) {
    for sp in [2usize, 4] {
        for threaded in [true, false] {
            let (want, total_ops) = reference(plan, sp, threaded);
            assert!(
                total_ops >= 10,
                "{plan:?} sp={sp}: suspicious sweep bound {total_ops}"
            );
            for op in 0..total_ops {
                let kind = if op % 2 == 0 {
                    FaultKind::LostRank
                } else {
                    FaultKind::Transient
                };
                let fault = FaultPlan {
                    site: FaultSite::Collective,
                    kind,
                    rank: 0,
                    at_op: op,
                    seed: op ^ 0xa5,
                };
                check_point(plan, sp, threaded, fault, &want, op % 7 == 0);
            }
        }
    }
}

#[test]
fn every_collective_op_recovers_under_ulysses() {
    sweep_collectives(PlanKind::Ulysses);
}

#[test]
fn every_collective_op_recovers_under_ring() {
    sweep_collectives(PlanKind::Ring);
}

/// Per-rank stage gates: every (rank, gate index) of the 2-step run, both
/// thread modes, lost ranks alternating with transients.
#[test]
fn every_stage_gate_recovers() {
    let (plan, sp, n_layers) = (PlanKind::Ulysses, 4usize, 2u64);
    for threaded in [true, false] {
        let (want, _) = reference(plan, sp, threaded);
        for rank in 0..sp {
            for op in 0..2 * n_layers {
                let kind = if (op + rank as u64) % 2 == 0 {
                    FaultKind::LostRank
                } else {
                    FaultKind::Transient
                };
                let fault = FaultPlan {
                    site: FaultSite::StageExec,
                    kind,
                    rank,
                    at_op: op,
                    seed: 31 + op,
                };
                check_point(plan, sp, threaded, fault, &want, op == 0);
            }
        }
    }
}

/// Offload copy streams: every copy op of one rank's 2-step run — D2H
/// stores and H2D fetches interleave, so the sweep hits both directions.
/// Corrupt payloads are caught by the per-transfer checksums and retried
/// from the intact source; lost ranks latch the engine and recover
/// through abort + restore.
#[test]
fn every_offload_copy_op_recovers() {
    let (plan, sp, n_layers) = (PlanKind::Ulysses, 2usize, 2u64);
    let threaded = true;
    let (want, _) = reference(plan, sp, threaded);
    // per step per rank: n_layers d2h stores + n_layers h2d fetches
    for op in 0..2 * (2 * n_layers) {
        let kind = match op % 3 {
            0 => FaultKind::LostRank,
            1 => FaultKind::CorruptPayload,
            _ => FaultKind::Transient,
        };
        let fault = FaultPlan {
            site: FaultSite::OffloadCopy,
            kind,
            rank: 1,
            at_op: op,
            seed: 77 + op,
        };
        check_point(plan, sp, threaded, fault, &want, op % 3 == 0);
    }
}
