"""Tiled-execution stage equivalence (paper §3.1 executed).

The rust `tiling::exec` driver assumes three properties of the tile
stages, asserted here against the monolithic stages they replace:

  1. Summing `loss_fwd_tile`'s per-row losses over a sweep of row tiles
     reproduces `loss_fwd`'s (loss_sum, count).
  2. Accumulating `loss_bwd_tile` partials over the sweep reproduces
     `loss_bwd`'s weight gradients, and the d_h tiles concatenate to the
     full d_h (rows are independent).
  3. Padding rows (zero hidden state + IGNORE_INDEX label — how the
     driver masks a ragged tail tile) contribute exactly 0 loss and 0
     gradient.

Plus the per-document property the single-pass sweep relies on: bucketing
per-row losses by segment id equals the old masked-label re-execution.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

CFG = M.CONFIGS["tiny"]
CFG_REF = dataclasses.replace(CFG, name="tiny-ref", kernels="ref")
IGNORE = M.IGNORE_INDEX


def loss_head_inputs(seed: int, ssh: int, cfg):
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    lnf = jnp.ones((cfg.hidden,)) + 0.01 * jax.random.normal(k[0], (cfg.hidden,))
    unembed = jax.random.normal(k[1], (cfg.hidden, cfg.vocab)) * 0.05
    h = jax.random.normal(k[2], (ssh, cfg.hidden))
    labels = jax.random.randint(k[3], (ssh,), 0, cfg.vocab, dtype=jnp.int32)
    labels = labels.at[ssh - 1].set(IGNORE)  # shard tail is always masked
    labels = labels.at[5].set(IGNORE)
    return lnf, unembed, h, labels


@pytest.mark.parametrize("cfg", [CFG, CFG_REF], ids=["pallas", "ref"])
def test_tile_sweep_matches_monolithic_loss(cfg):
    ssh, t = 64, 32
    lnf, unembed, h, labels = loss_head_inputs(0, ssh, cfg)
    want_sum, want_count = M.loss_fwd(cfg, lnf, unembed, h, labels)
    per_rows = []
    for lo in range(0, ssh, t):
        (rows,) = M.loss_fwd_tile(cfg, lnf, unembed, h[lo:lo + t],
                                  labels[lo:lo + t])
        per_rows.append(rows)
    per = jnp.concatenate(per_rows)
    np.testing.assert_allclose(per.sum(), want_sum, rtol=1e-5)
    assert int((labels != IGNORE).sum()) == int(want_count)
    # ignored rows emit exactly 0 per-row loss
    assert per[5] == 0.0 and per[ssh - 1] == 0.0


@pytest.mark.parametrize("cfg", [CFG, CFG_REF], ids=["pallas", "ref"])
def test_tile_sweep_matches_monolithic_backward(cfg):
    ssh, t = 64, 32
    lnf, unembed, h, labels = loss_head_inputs(1, ssh, cfg)
    ct = jnp.float32(1.0 / 62.0)
    want_lnf, want_unembed, want_dh = M.loss_bwd(cfg, lnf, unembed, h,
                                                 labels, ct)
    acc_lnf = jnp.zeros_like(want_lnf)
    acc_unembed = jnp.zeros_like(want_unembed)
    dh_tiles = []
    for lo in range(0, ssh, t):
        d_lnf, d_unembed, d_h = M.loss_bwd(cfg, lnf, unembed, h[lo:lo + t],
                                           labels[lo:lo + t], ct)
        acc_lnf += d_lnf
        acc_unembed += d_unembed
        dh_tiles.append(d_h)
    np.testing.assert_allclose(acc_lnf, want_lnf, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(acc_unembed, want_unembed, rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(jnp.concatenate(dh_tiles), want_dh,
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("cfg", [CFG, CFG_REF], ids=["pallas", "ref"])
def test_padding_rows_are_free(cfg):
    """Zero hidden rows + IGNORE labels = the driver's ragged-tail mask.

    t = 64 with a 32-row live half so the live-only comparison tile is
    still a multiple of the pallas CE kernel's tile_s.
    """
    t = 64
    lnf, unembed, h, labels = loss_head_inputs(2, t, cfg)
    h = h.at[t // 2:].set(0.0)
    labels = labels.at[t // 2:].set(IGNORE)
    (per,) = M.loss_fwd_tile(cfg, lnf, unembed, h, labels)
    assert bool((per[t // 2:] == 0.0).all())
    d_lnf, d_unembed, d_h = M.loss_bwd(cfg, lnf, unembed, h, labels,
                                       jnp.float32(0.125))
    assert bool((d_h[t // 2:] == 0.0).all())
    # and the live half still produces the same grads as a live-only tile
    d_lnf2, d_unembed2, d_h2 = M.loss_bwd(
        cfg, lnf, unembed, h[: t // 2], labels[: t // 2], jnp.float32(0.125)
    )
    np.testing.assert_allclose(d_unembed, d_unembed2, rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(d_h[: t // 2], d_h2, rtol=1e-6, atol=1e-8)


def test_per_row_bucketing_equals_masked_label_rerun():
    """Per-document losses from ONE tiled sweep == the old n_docs
    re-execution with masked labels (the path the trainer replaces)."""
    cfg = CFG_REF
    ssh = 64
    lnf, unembed, h, _ = loss_head_inputs(3, ssh, cfg)
    # three "documents" over the shard rows
    bounds = [0, 20, 45, 64]
    rng = np.random.default_rng(7)
    labels = rng.integers(0, cfg.vocab, ssh).astype(np.int32)
    for b in bounds[1:]:
        labels[b - 1] = IGNORE  # no cross-document target
    labels = jnp.asarray(labels)

    (per,) = M.loss_fwd_tile(cfg, lnf, unembed, h, labels)
    for d in range(3):
        lo, hi = bounds[d], bounds[d + 1]
        masked = jnp.full((ssh,), IGNORE, jnp.int32)
        masked = masked.at[lo:hi].set(labels[lo:hi])
        old_sum, old_count = M.loss_fwd(cfg, lnf, unembed, h, masked)
        np.testing.assert_allclose(per[lo:hi].sum(), old_sum, rtol=1e-5)
        assert int(old_count) == int((labels[lo:hi] != IGNORE).sum())


def test_tile_row_helpers_align_and_reject_degenerate_chunks():
    """Tile rows must satisfy the kernels' `s % tile_s == 0` asserts on
    BOTH kernel paths, and a chunk budget below one fp32 vocab row is a
    config error (mirrors rust's plan_logits_checked)."""
    with pytest.raises(ValueError, match="vocab row"):
        aot.loss_tile_rows(CFG, 64, 100)
    for cfg in (CFG, CFG_REF):
        # ssh=96 -> raw mlp rows 48, not a multiple of tile_s=32: aligned
        assert aot.mlp_tile_rows(cfg, 96) == 32
        # 100 KB chunk -> raw 48 loss rows: aligned down to 32
        assert aot.loss_tile_rows(cfg, 96, 100_000) == 32
        # rows below tile_s pass through (stage-side clamp handles them)
        assert aot.loss_tile_rows(cfg, 96, 16 * 1024) == 8
        # boundary: exactly one vocab row of budget is accepted
        assert aot.loss_tile_rows(cfg, 96, 4 * cfg.vocab) == 1


def test_mlp_tile_sweep_matches_post_attn():
    cfg = CFG
    ssh, t = 64, 32
    k = jax.random.split(jax.random.PRNGKey(4), 7)
    hq = cfg.n_q_heads * cfg.head_dim
    wo = jax.random.normal(k[0], (hq, cfg.hidden)) * 0.05
    ln2 = jnp.ones((cfg.hidden,))
    wg = jax.random.normal(k[1], (cfg.hidden, cfg.ffn)) * 0.05
    wu = jax.random.normal(k[2], (cfg.hidden, cfg.ffn)) * 0.05
    wd = jax.random.normal(k[3], (cfg.ffn, cfg.hidden)) * 0.05
    h_in = jax.random.normal(k[4], (ssh, cfg.hidden))
    attn = jax.random.normal(k[5], (ssh, cfg.n_q_heads, cfg.head_dim))
    d_out = jax.random.normal(k[6], (ssh, cfg.hidden))

    (want,) = M.post_attn_fwd(cfg, wo, ln2, wg, wu, wd, h_in, attn)
    tiles = [
        M.post_attn_fwd(cfg, wo, ln2, wg, wu, wd, h_in[lo:lo + t],
                        attn[lo:lo + t])[0]
        for lo in range(0, ssh, t)
    ]
    np.testing.assert_allclose(jnp.concatenate(tiles), want, rtol=1e-5,
                               atol=1e-6)

    want_bwd = M.post_attn_bwd(cfg, wo, ln2, wg, wu, wd, h_in, attn, d_out)
    acc = [jnp.zeros_like(g) for g in want_bwd[:5]]
    dh_tiles, dattn_tiles = [], []
    for lo in range(0, ssh, t):
        out = M.post_attn_bwd(cfg, wo, ln2, wg, wu, wd, h_in[lo:lo + t],
                              attn[lo:lo + t], d_out[lo:lo + t])
        for i in range(5):
            acc[i] += out[i]
        dh_tiles.append(out[5])
        dattn_tiles.append(out[6])
    for got, want_g in zip(acc, want_bwd[:5]):
        np.testing.assert_allclose(got, want_g, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(jnp.concatenate(dh_tiles), want_bwd[5],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(jnp.concatenate(dattn_tiles), want_bwd[6],
                               rtol=1e-5, atol=1e-6)
