//! Hot-path bench: the Ulysses all-to-all relayout (L3's per-layer cost).
//! Reports throughput at several (sp, seq, heads) points including the
//! paper's head-sharding regimes (MHA split, GQA split, kv replication).

use alst::collectives::Group;
use alst::coordinator::ulysses::{a2a_head_to_seq, a2a_seq_to_head};
use alst::runtime::HostTensor;
use alst::util::bench::quick;
use alst::util::rng::Rng;

fn shards(rng: &mut Rng, sp: usize, ssh: usize, heads: usize, d: usize) -> Vec<HostTensor> {
    (0..sp)
        .map(|_| HostTensor::f32(vec![ssh, heads, d], rng.normal_vec(ssh * heads * d, 1.0)))
        .collect()
}

fn main() {
    println!("bench_ulysses: all-to-all relayout throughput\n");
    let mut rng = Rng::new(0);
    for (sp, seq, heads, d, label) in [
        (2usize, 4096usize, 8usize, 64usize, "sp=2 mha-split"),
        (4, 4096, 8, 64, "sp=4 gqa-split"),
        (8, 4096, 4, 64, "sp=8 kv-replicated"),
        (8, 16384, 32, 128, "sp=8 llama-shaped"),
    ] {
        let ssh = seq / sp;
        let input = shards(&mut rng, sp, ssh, heads, d);
        let bytes = (sp * ssh * heads * d * 4) as f64;
        let g = Group::new(sp);

        let r = quick(&format!("a2a seq->head {label}"), || {
            let out = a2a_seq_to_head(&g, &input);
            std::hint::black_box(&out);
        });
        println!(
            "    -> {:.2} GiB/s",
            bytes / r.median.as_secs_f64() / (1u64 << 30) as f64
        );

        let full = a2a_seq_to_head(&g, &input);
        let r = quick(&format!("a2a head->seq {label}"), || {
            let out = a2a_head_to_seq(&g, &full, heads, false);
            std::hint::black_box(&out);
        });
        println!(
            "    -> {:.2} GiB/s",
            bytes / r.median.as_secs_f64() / (1u64 << 30) as f64
        );
    }
}
