//! ZeRO Stage-3 flat parameter/gradient sharding (paper §5.2 baseline;
//! the superlinear seqlen scaling of §5.3.4 comes from this partitioning).
//!
//! All parameters live in ONE flat f32 vector laid out per the manifest's
//! `param_layout`; each rank owns a padded `1/world` shard. Layer groups
//! are all-gathered just-in-time before a stage runs and dropped after —
//! that is what frees per-GPU memory as the cluster grows. Gradients
//! reduce-scatter back into the owner's shard.

use anyhow::Result;

use crate::collectives::Group;
use crate::runtime::manifest::{ParamEntry, ParamLayout};
use crate::runtime::tensor::HostTensor;
use crate::util::rng::Rng;

/// A flat vector sharded across `world` ranks (padded equal shards).
#[derive(Debug, Clone)]
pub struct ShardedStore {
    pub total: usize,
    pub shard_len: usize,
    pub shards: Vec<Vec<f32>>,
}

impl ShardedStore {
    pub fn zeros(total: usize, world: usize) -> ShardedStore {
        let shard_len = total.div_ceil(world);
        ShardedStore { total, shard_len, shards: vec![vec![0.0; shard_len]; world] }
    }

    pub fn from_flat(flat: &[f32], world: usize) -> ShardedStore {
        let mut s = Self::zeros(flat.len(), world);
        for (r, shard) in s.shards.iter_mut().enumerate() {
            let start = r * s.shard_len;
            if start >= flat.len() {
                break;
            }
            let end = (start + s.shard_len).min(flat.len());
            shard[..end - start].copy_from_slice(&flat[start..end]);
        }
        s
    }

    pub fn world(&self) -> usize {
        self.shards.len()
    }

    /// Reassemble the full vector (tests / small exports only).
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.total);
        for shard in &self.shards {
            let take = (self.total - out.len()).min(shard.len());
            out.extend_from_slice(&shard[..take]);
            if out.len() == self.total {
                break;
            }
        }
        out
    }

    /// All-gather an arbitrary flat range (just-in-time param gather).
    /// Wire accounting: the gathered bytes, once per participating rank
    /// pair direction (ledgered as logical size, NCCL algbw convention).
    /// The wire can fault: the ledger entry runs the fault gate, and a
    /// failed gather drops its local copy before propagating.
    pub fn gather_range(&self, group: &Group, range: std::ops::Range<usize>) -> Result<Vec<f32>> {
        assert!(range.end <= self.total);
        // account as an all-gather of the range; gate faults before the
        // copy so a failed gather leaves nothing behind
        group.account_gather(range.len() as u64 * 4)?;
        let mut out = vec![0f32; range.len()];
        for (i, idx) in range.clone().enumerate() {
            let (r, off) = (idx / self.shard_len, idx % self.shard_len);
            out[i] = self.shards[r][off];
        }
        Ok(out)
    }

    /// Reduce-scatter `world` per-rank contributions covering `range`
    /// into the owning shards: `shard[owner] += sum_r contribs[r]`.
    /// The wire fault gate runs *before* the accumulation, so a lost rank
    /// leaves the owning shards untouched (the step aborts cleanly).
    pub fn reduce_into_range(
        &mut self,
        group: &Group,
        range: std::ops::Range<usize>,
        contribs: &[&[f32]],
    ) -> Result<()> {
        assert_eq!(contribs.len(), self.world());
        assert!(contribs.iter().all(|c| c.len() == range.len()));
        group.account_reduce_scatter(range.len() as u64 * 4)?;
        for (i, idx) in range.clone().enumerate() {
            let (r, off) = (idx / self.shard_len, idx % self.shard_len);
            let mut acc = 0f32;
            for c in contribs {
                acc += c[i];
            }
            self.shards[r][off] += acc;
        }
        Ok(())
    }

    pub fn zero_fill(&mut self) {
        for s in &mut self.shards {
            s.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// Device bytes a single rank holds for this store (ZeRO-3 benefit).
    pub fn shard_bytes(&self) -> u64 {
        (self.shard_len * 4) as u64
    }
}

/// Initialize the flat parameter vector per the manifest init recipes
/// (std-0.02 normals, ones for norms, zeros for `wd` — mirrors
/// `model.init_params`).
pub fn init_flat_params(layout: &ParamLayout, seed: u64, std: f32) -> Vec<f32> {
    let mut flat = vec![0f32; layout.total_numel()];
    let mut rng = Rng::new(seed);
    let mut fill = |entry: &ParamEntry, base: usize, rng: &mut Rng| {
        let dst = &mut flat[base..base + entry.numel()];
        match entry.init.as_str() {
            "ones" => dst.iter_mut().for_each(|x| *x = 1.0),
            "zeros" => dst.iter_mut().for_each(|x| *x = 0.0),
            _ => dst.iter_mut().for_each(|x| *x = rng.normal() as f32 * std),
        }
    };
    for e in &layout.embed {
        fill(e, e.offset, &mut rng);
    }
    for l in 0..layout.n_layers {
        for e in &layout.layer {
            let base = layout.embed_numel + l * layout.layer_numel + e.offset;
            fill(e, base, &mut rng);
        }
    }
    for e in &layout.final_ {
        let base = layout.embed_numel + layout.n_layers * layout.layer_numel + e.offset;
        fill(e, base, &mut rng);
    }
    flat
}

/// View a gathered flat group as named tensors (zero-copy would need
/// lifetimes through the engine; we copy — this is the gather cost anyway).
pub fn slice_group(gathered: &[f32], entries: &[ParamEntry]) -> Vec<HostTensor> {
    entries
        .iter()
        .map(|e| {
            HostTensor::f32(
                e.shape.clone(),
                gathered[e.offset..e.offset + e.numel()].to_vec(),
            )
        })
        .collect()
}

/// Gradient accumulation buffer for one flat group (per rank, before the
/// reduce-scatter). Named access mirrors `slice_group` order.
pub struct GroupGrads {
    pub entries: Vec<ParamEntry>,
    pub flat: Vec<f32>,
}

impl GroupGrads {
    pub fn zeros(entries: &[ParamEntry]) -> GroupGrads {
        let total: usize = entries.iter().map(|e| e.numel()).sum();
        GroupGrads { entries: entries.to_vec(), flat: vec![0.0; total] }
    }

    pub fn accumulate(&mut self, name: &str, grad: &HostTensor) -> Result<()> {
        let e = self
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown grad tensor `{name}`"))?;
        anyhow::ensure!(e.shape == grad.shape(), "grad shape mismatch for {name}");
        let dst = &mut self.flat[e.offset..e.offset + e.numel()];
        for (d, s) in dst.iter_mut().zip(grad.as_f32()?) {
            *d += s;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_round_trip() {
        let flat: Vec<f32> = (0..103).map(|i| i as f32).collect();
        let s = ShardedStore::from_flat(&flat, 4);
        assert_eq!(s.shard_len, 26);
        assert_eq!(s.to_flat(), flat);
    }

    #[test]
    fn gather_range_crosses_shard_boundaries() {
        let flat: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let s = ShardedStore::from_flat(&flat, 3); // shard_len 7
        let g = Group::new(3);
        assert_eq!(s.gather_range(&g, 5..10).unwrap(), vec![5.0, 6.0, 7.0, 8.0, 9.0]);
        assert_eq!(g.stats().all_gather_bytes, 20);
    }

    #[test]
    fn reduce_into_range_sums_across_ranks() {
        let mut s = ShardedStore::zeros(8, 2);
        let g = Group::new(2);
        let a = vec![1.0f32; 4];
        let b = vec![2.0f32; 4];
        s.reduce_into_range(&g, 2..6, &[&a, &b]).unwrap();
        let flat = s.to_flat();
        assert_eq!(flat, vec![0.0, 0.0, 3.0, 3.0, 3.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn shard_bytes_shrink_with_world() {
        let s1 = ShardedStore::zeros(1000, 1);
        let s8 = ShardedStore::zeros(1000, 8);
        assert!(s8.shard_bytes() * 7 < s1.shard_bytes());
    }
}
