//! Ulysses all-to-all relayout (paper §3.2) and head-shard math (§3.2.1).
//!
//! Forward, at each attention boundary:
//!   every rank holds `[S/sp, n_heads, D]` (its sequence shard, ALL heads)
//!   -> all-to-all ->
//!   every rank holds `[S, n_heads/sp, D]` (FULL sequence, its head shard)
//! and the inverse after attention. kv tensors replicate when
//! `n_kv_heads < sp`; the backward of that replication SUMS the gradient
//! contributions from every consumer rank.

use crate::collectives::Group;
use crate::runtime::tensor::HostTensor;

/// First global head owned by `rank` when `n_heads` are distributed over
/// `sp` ranks. Handles both the contiguous-split (n_heads >= sp) and the
/// replicated (n_heads < sp) regimes; in the latter, consumer ranks of the
/// same head group share a source head — exactly the paper's kv
/// replication rule.
pub fn head_start(rank: usize, n_heads: usize, sp: usize) -> usize {
    (rank * n_heads) / sp
}

/// Per-rank head count after sharding (q: n/sp; kv: max(n/sp, 1)).
pub fn heads_per_rank(n_heads: usize, sp: usize) -> usize {
    if n_heads >= sp {
        assert_eq!(n_heads % sp, 0, "head count not divisible by sp");
        n_heads / sp
    } else {
        1
    }
}

/// Validity of an SP degree for a (q, kv) head pair — §7.1 limits.
pub fn sp_is_valid(n_q: usize, n_kv: usize, sp: usize) -> bool {
    sp >= 1
        && sp <= n_q
        && n_q % sp == 0
        && (n_kv >= sp && n_kv % sp == 0 || n_kv < sp)
}

/// seq->head all-to-all.
///
/// `shards[r]`: rank r's `[ssh, n_heads, d]` tensor. Returns per dst rank
/// the `[ssh*sp, h_out, d]` full-sequence head shard, where
/// `h_out = heads_per_rank(n_heads, sp)`. Copies are contiguous per
/// (src, seq-row): heads are the middle axis.
pub fn a2a_seq_to_head(group: &Group, shards: &[HostTensor]) -> Vec<HostTensor> {
    let sp = shards.len();
    assert_eq!(sp, group.world);
    let dims = shards[0].shape();
    assert_eq!(dims.len(), 3, "expected [ssh, heads, d]");
    let (ssh, n_heads, d) = (dims[0], dims[1], dims[2]);
    let h_out = heads_per_rank(n_heads, sp);
    let seq = ssh * sp;

    let mut out = Vec::with_capacity(sp);
    for dst in 0..sp {
        let h0 = if n_heads >= sp { dst * h_out } else { head_start(dst, n_heads, sp) };
        let mut data = vec![0f32; seq * h_out * d];
        for (src, shard) in shards.iter().enumerate() {
            let src_data = shard.as_f32().expect("f32 relayout");
            for s in 0..ssh {
                let from = (s * n_heads + h0) * d;
                let to = ((src * ssh + s) * h_out) * d;
                data[to..to + h_out * d]
                    .copy_from_slice(&src_data[from..from + h_out * d]);
            }
        }
        out.push(HostTensor::f32(vec![seq, h_out, d], data));
    }
    // Every element of every output crossed the (simulated) wire once.
    let bytes: u64 = out.iter().map(|t| t.size_bytes() as u64).sum();
    group.account_all_to_all(bytes);
    out
}

/// head->seq all-to-all (inverse of `a2a_seq_to_head`).
///
/// `shards[r]`: rank r's `[seq, h_sh, d]`. Returns per dst rank the
/// `[ssh, n_heads_total, d]` sequence shard with all heads. With
/// `sum_replicas` (backward of kv replication), gradient pieces from
/// ranks sharing a head are accumulated instead of overwritten.
pub fn a2a_head_to_seq(
    group: &Group,
    shards: &[HostTensor],
    n_heads_total: usize,
    sum_replicas: bool,
) -> Vec<HostTensor> {
    let sp = shards.len();
    assert_eq!(sp, group.world);
    let dims = shards[0].shape();
    assert_eq!(dims.len(), 3, "expected [seq, h_sh, d]");
    let (seq, h_sh, d) = (dims[0], dims[1], dims[2]);
    assert_eq!(seq % sp, 0);
    let ssh = seq / sp;

    let mut out = Vec::with_capacity(sp);
    for dst in 0..sp {
        let mut data = vec![0f32; ssh * n_heads_total * d];
        for (src, shard) in shards.iter().enumerate() {
            let h0 = if n_heads_total >= sp {
                src * h_sh
            } else {
                head_start(src, n_heads_total, sp)
            };
            let src_data = shard.as_f32().expect("f32 relayout");
            for s in 0..ssh {
                let from = ((dst * ssh + s) * h_sh) * d;
                let to = (s * n_heads_total + h0) * d;
                let src_slice = &src_data[from..from + h_sh * d];
                let dst_slice = &mut data[to..to + h_sh * d];
                if sum_replicas {
                    for (a, b) in dst_slice.iter_mut().zip(src_slice) {
                        *a += b;
                    }
                } else {
                    dst_slice.copy_from_slice(src_slice);
                }
            }
        }
        out.push(HostTensor::f32(vec![ssh, n_heads_total, d], data));
    }
    let bytes: u64 = shards.iter().map(|t| t.size_bytes() as u64).sum();
    group.account_all_to_all(bytes);
    out
}

/// Per-step all-to-all wire volume for one attention block, in bytes —
/// the closed form the perf model uses and tests assert against.
/// q + k + v forward (seq->head) plus o backward (head->seq): each moves
/// its full logical size once per direction.
pub fn a2a_bytes_per_block(
    seq: usize,
    n_q: usize,
    n_kv: usize,
    head_dim: usize,
    sp: usize,
    elem_bytes: usize,
) -> u64 {
    let q_sh = heads_per_rank(n_q, sp);
    let kv_sh = heads_per_rank(n_kv, sp);
    // outputs of the forward a2a across ranks:
    let q = seq * q_sh * head_dim * sp;
    let kv = 2 * seq * kv_sh * head_dim * sp;
    // inverse a2a moves the o tensor (same logical volume as q):
    let o = q;
    ((q + kv + o) * elem_bytes) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(sp: usize, ssh: usize, heads: usize, d: usize) -> Vec<HostTensor> {
        // value encodes (rank, seq, head, dim) for exact relayout checks
        (0..sp)
            .map(|r| {
                let mut data = Vec::with_capacity(ssh * heads * d);
                for s in 0..ssh {
                    for h in 0..heads {
                        for k in 0..d {
                            data.push(
                                (r * 1000 + s * 100 + h * 10 + k) as f32,
                            );
                        }
                    }
                }
                HostTensor::f32(vec![ssh, heads, d], data)
            })
            .collect()
    }

    #[test]
    fn seq_to_head_places_rows_globally() {
        let (sp, ssh, heads, d) = (2, 2, 4, 1);
        let g = Group::new(sp);
        let out = a2a_seq_to_head(&g, &mk(sp, ssh, heads, d));
        // dst rank 1, global seq row 2 (= src rank 1, local row 0), its
        // head block starts at head 2
        let r1 = out[1].as_f32().unwrap();
        // [seq=4, h_out=2, d=1]; row 2, local head 0 = src(1, s0, h2)
        assert_eq!(r1[(2 * 2 + 0) * 1], 1020.0);
        assert_eq!(r1[(2 * 2 + 1) * 1], 1030.0);
        // dst rank 0 row 1 head 1 = src(0, s1, h1)
        let r0 = out[0].as_f32().unwrap();
        assert_eq!(r0[(1 * 2 + 1) * 1], 110.0);
    }

    #[test]
    fn round_trip_is_identity() {
        for (sp, heads) in [(2, 4), (4, 4), (2, 2), (4, 8)] {
            let (ssh, d) = (4, 3);
            let g = Group::new(sp);
            let orig = mk(sp, ssh, heads, d);
            let full = a2a_seq_to_head(&g, &orig);
            let back = a2a_head_to_seq(&g, &full, heads, false);
            assert_eq!(orig, back, "sp={sp} heads={heads}");
        }
    }

    #[test]
    fn replication_shares_source_heads() {
        // kv = 2 heads, sp = 4: ranks (0,1) see head 0; (2,3) see head 1
        let (sp, ssh, heads, d) = (4, 2, 2, 1);
        let g = Group::new(sp);
        let out = a2a_seq_to_head(&g, &mk(sp, ssh, heads, d));
        assert_eq!(out[0], out[1]);
        assert_eq!(out[2], out[3]);
        assert_ne!(out[0], out[2]);
    }

    #[test]
    fn replication_backward_sums() {
        let (sp, seq, d) = (4, 4, 1);
        // each rank holds [seq, 1, d] of ones * (rank+1)
        let shards: Vec<HostTensor> = (0..sp)
            .map(|r| HostTensor::f32(vec![seq, 1, d], vec![(r + 1) as f32; seq]))
            .collect();
        let g = Group::new(sp);
        let back = a2a_head_to_seq(&g, &shards, 2, true);
        for dst in 0..sp {
            let data = back[dst].as_f32().unwrap();
            // head 0 <- ranks 0+1 = 3; head 1 <- ranks 2+3 = 7
            assert_eq!(data[0], 3.0);
            assert_eq!(data[1], 7.0);
        }
    }

    #[test]
    fn paper_head_shard_examples() {
        // §3.2.1 worked examples
        assert_eq!(heads_per_rank(32, 8), 4);
        assert_eq!(heads_per_rank(8, 8), 1);
        assert_eq!(heads_per_rank(8, 32), 1); // replicated
        assert_eq!(heads_per_rank(4, 8), 1);  // replicated
        assert!(sp_is_valid(32, 8, 8));
        assert!(sp_is_valid(32, 8, 32));
        assert!(!sp_is_valid(32, 8, 3));      // 32 % 3 != 0
        assert!(!sp_is_valid(9, 3, 8));       // §7.1: 9 q heads -> sp 1/3/9
        assert!(sp_is_valid(9, 3, 3));
        assert!(sp_is_valid(9, 3, 9));
    }

    #[test]
    fn a2a_byte_accounting_matches_closed_form() {
        let (sp, ssh, heads, d) = (4, 8, 8, 16);
        let g = Group::new(sp);
        let q = mk(sp, ssh, heads, d);
        let full = a2a_seq_to_head(&g, &q);
        let _ = a2a_head_to_seq(&g, &full, heads, false);
        // each direction moves seq*heads*d floats total across ranks
        let logical = (sp * ssh * heads * d * 4) as u64;
        assert_eq!(g.stats().all_to_all_bytes, 2 * logical);
    }
}
