//! Training-state snapshots: save/restore flat parameters + AdamW state +
//! step counter, so post-training runs can resume (a framework necessity
//! the paper's ArcticTraining recipes rely on).
//!
//! Format (little-endian): magic "ALST", u32 version, u64 step,
//! u64 total_numel, then three f32 arrays (params, adam m, adam v).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::optimizer::AdamW;
use crate::coordinator::zero::ShardedStore;

const MAGIC: &[u8; 4] = b"ALST";
const VERSION: u32 = 1;

pub struct Snapshot {
    pub step: u64,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    // one pass, 64KiB chunks to avoid a full byte-copy of the array
    let mut buf = Vec::with_capacity(64 * 1024);
    for chunk in xs.chunks(16 * 1024) {
        buf.clear();
        for x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// Save (params, optimizer, step) to `path`.
pub fn save(path: &Path, step: u64, params: &ShardedStore, opt: &AdamW) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&step.to_le_bytes())?;
    f.write_all(&(params.total as u64).to_le_bytes())?;
    write_f32s(&mut f, &params.to_flat())?;
    write_f32s(&mut f, &opt.m.to_flat())?;
    write_f32s(&mut f, &opt.v.to_flat())?;
    Ok(())
}

/// Load a snapshot; caller re-shards it for the current world size (the
/// snapshot is world-agnostic — resume on a different SP degree works).
pub fn load(path: &Path) -> Result<Snapshot> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an ALST snapshot (bad magic)");
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    if version != VERSION {
        bail!("unsupported snapshot version {version}");
    }
    let mut u64b = [0u8; 8];
    f.read_exact(&mut u64b)?;
    let step = u64::from_le_bytes(u64b);
    f.read_exact(&mut u64b)?;
    let total = u64::from_le_bytes(u64b) as usize;
    let params = read_f32s(&mut f, total)?;
    let m = read_f32s(&mut f, total)?;
    let v = read_f32s(&mut f, total)?;
    Ok(Snapshot { step, params, m, v })
}

/// Restore a snapshot into live training state (re-sharding to `world`).
pub fn restore(
    snap: &Snapshot,
    params: &mut ShardedStore,
    opt: &mut AdamW,
) -> Result<()> {
    if snap.params.len() != params.total {
        bail!(
            "snapshot has {} params, model needs {}",
            snap.params.len(),
            params.total
        );
    }
    let world = params.world();
    *params = ShardedStore::from_flat(&snap.params, world);
    opt.m = ShardedStore::from_flat(&snap.m, world);
    opt.v = ShardedStore::from_flat(&snap.v, world);
    opt.step = snap.step;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optimizer::AdamWConfig;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alst-snapshot-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip_preserves_everything() {
        let flat: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 7.0).collect();
        let params = ShardedStore::from_flat(&flat, 4);
        let mut opt = AdamW::new(AdamWConfig::default(), 1000, 4);
        opt.step = 42;
        opt.m = ShardedStore::from_flat(&vec![0.25; 1000], 4);
        opt.v = ShardedStore::from_flat(&vec![0.125; 1000], 4);

        let path = tmpfile("roundtrip.alst");
        save(&path, 42, &params, &opt).unwrap();
        let snap = load(&path).unwrap();
        assert_eq!(snap.step, 42);
        assert_eq!(snap.params, flat);
        assert_eq!(snap.m, vec![0.25; 1000]);

        // resume on a DIFFERENT world size
        let mut p2 = ShardedStore::zeros(1000, 8);
        let mut o2 = AdamW::new(AdamWConfig::default(), 1000, 8);
        restore(&snap, &mut p2, &mut o2).unwrap();
        assert_eq!(p2.to_flat(), flat);
        assert_eq!(o2.step, 42);
        assert_eq!(p2.world(), 8);
    }

    #[test]
    fn rejects_wrong_magic_and_size() {
        let path = tmpfile("bad.alst");
        std::fs::write(&path, b"NOPEnope").unwrap();
        assert!(load(&path).is_err());

        let params = ShardedStore::from_flat(&[1.0; 10], 2);
        let opt = AdamW::new(AdamWConfig::default(), 10, 2);
        let path = tmpfile("small.alst");
        save(&path, 1, &params, &opt).unwrap();
        let snap = load(&path).unwrap();
        let mut wrong = ShardedStore::zeros(20, 2);
        let mut o = AdamW::new(AdamWConfig::default(), 20, 2);
        assert!(restore(&snap, &mut wrong, &mut o).is_err());
    }
}
