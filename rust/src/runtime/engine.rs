//! PJRT execution engine: compile HLO-text artifacts once, execute many.
//!
//! One `Engine` is shared by all simulated ranks (the CPU client is a
//! single device; rank-parallelism is data isolation in the coordinator,
//! not device parallelism — see DESIGN.md substitutions).

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::collectives::faults::{self, lock_clean, FaultInjector, FaultSite, RetryPolicy};
use crate::obs::{self, Category, Tracer};
use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::HostTensor;

/// Cumulative execution statistics (perf pass; EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub executions: u64,
    pub exec_time: Duration,
    /// host->device literal construction time (the L3-side overhead).
    pub marshal_time: Duration,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Executions per stage key. This is how tests pin execution-count
    /// contracts, e.g. "per-document losses cost `n_tiles` loss-stage
    /// runs, not `n_tiles + n_docs`" for the tiled loss sweep.
    pub per_stage: BTreeMap<String, u64>,
}

pub struct Engine {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Behind a mutex (not a RefCell) so `&Engine` can be shared with the
    /// scoped rank threads; every update is a commutative sum, so the
    /// totals are deterministic under any thread interleaving.
    stats: Mutex<EngineStats>,
    /// Span recorder; the shared disabled handle unless `set_tracer`
    /// installed a live one. Exec/marshal spans carry the *same*
    /// `Duration` values the stats ledger accumulates, so span sums
    /// reconcile with `EngineStats` exactly.
    tracer: Arc<Tracer>,
    /// Optional fault injector for chaos runs: stage executions are gated
    /// per rank (the caller's `obs::current_rank`), with transient faults
    /// absorbed by the retry policy before the stage runs.
    injector: Option<Arc<FaultInjector>>,
    retry: RetryPolicy,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            executables: HashMap::new(),
            stats: Mutex::default(),
            tracer: Tracer::off(),
            injector: None,
            retry: RetryPolicy::default(),
        })
    }

    pub fn set_tracer(&mut self, tracer: Arc<Tracer>) {
        self.tracer = tracer;
    }

    pub fn set_injector(&mut self, injector: Arc<FaultInjector>) {
        self.injector = Some(injector);
    }

    pub fn injector(&self) -> Option<&Arc<FaultInjector>> {
        self.injector.as_ref()
    }

    pub fn set_retry_policy(&mut self, retry: RetryPolicy) {
        self.retry = retry;
    }

    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact under `key`.
    pub fn load_stage(&mut self, key: &str, path: &Path) -> Result<()> {
        if self.executables.contains_key(key) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        self.executables.insert(key.to_string(), exe);
        Ok(())
    }

    /// Load every stage of a manifest, keyed `<manifest-config>/<stage>`.
    pub fn load_manifest(&mut self, m: &Manifest) -> Result<()> {
        for (name, st) in &m.stages {
            let key = Self::stage_key(m, name);
            self.load_stage(&key, &m.dir.join(&st.file))?;
        }
        Ok(())
    }

    pub fn stage_key(m: &Manifest, stage: &str) -> String {
        format!("{}-sp{}-seq{}/{stage}", m.config.name, m.sp, m.seq)
    }

    /// Upload a host tensor to a device buffer (single copy). Cached
    /// buffers are the §Perf fast path: parameters go up once per step
    /// instead of twice per stage call (to_literal + execute's internal
    /// device copy).
    pub fn to_buffer(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let mut span = self.tracer.span(Category::Marshal, "to_buffer");
        let t0 = Instant::now();
        let buf = match t {
            HostTensor::F32 { shape, data } => {
                self.client.buffer_from_host_buffer(data, shape, None)?
            }
            HostTensor::I32 { shape, data } => {
                self.client.buffer_from_host_buffer(data, shape, None)?
            }
        };
        let marshal = t0.elapsed();
        let mut s = lock_clean(&self.stats);
        s.marshal_time += marshal;
        s.bytes_in += t.size_bytes() as u64;
        span.set_dur(marshal);
        span.set_bytes(t.size_bytes() as u64);
        Ok(buf)
    }

    /// Execute a loaded stage on device buffers (the hot path).
    pub fn execute_buffers(
        &self,
        key: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<HostTensor>> {
        // fault gate before any device work: a lost rank leaves the
        // stage unexecuted and the stats ledger untouched
        faults::site_gate(
            &self.injector,
            FaultSite::StageExec,
            obs::current_rank().unwrap_or(0),
            &self.retry,
            &self.tracer,
        )?;
        let exe = self
            .executables
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("stage `{key}` not loaded"))?;
        let mut span = self.tracer.span(Category::Exec, key);
        let t1 = Instant::now();
        let result = exe.execute_b::<&xla::PjRtBuffer>(inputs)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        let exec = t1.elapsed();

        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        let parts = tuple.decompose_tuple()?;
        let outputs: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        let bytes_out = outputs.iter().map(|t| t.size_bytes() as u64).sum::<u64>();

        let mut s = lock_clean(&self.stats);
        s.executions += 1;
        *s.per_stage.entry(key.to_string()).or_insert(0) += 1;
        s.exec_time += exec;
        s.bytes_out += bytes_out;
        span.set_dur(exec);
        span.set_bytes(bytes_out);
        Ok(outputs)
    }

    /// Executions recorded for one stage key (see `Engine::stage_key`);
    /// 0 if the stage never ran since the last `reset_stats`.
    pub fn executions_for(&self, key: &str) -> u64 {
        lock_clean(&self.stats).per_stage.get(key).copied().unwrap_or(0)
    }

    /// Execute a loaded stage from host tensors (upload + run).
    pub fn execute(&self, key: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| self.to_buffer(t))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.execute_buffers(key, &refs)
    }

    /// Execute with shape validation against the manifest (debug builds
    /// and tests; the hot path uses `execute`).
    pub fn execute_checked(
        &self,
        m: &Manifest,
        stage: &str,
        inputs: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let io = m.stage(stage);
        anyhow::ensure!(
            inputs.len() == io.inputs.len(),
            "stage {stage}: {} inputs given, {} expected",
            inputs.len(),
            io.inputs.len()
        );
        for (t, meta) in inputs.iter().zip(&io.inputs) {
            anyhow::ensure!(
                t.shape() == meta.shape.as_slice(),
                "stage {stage} input `{}`: shape {:?} != manifest {:?}",
                meta.name,
                t.shape(),
                meta.shape
            );
        }
        let out = self.execute(&Self::stage_key(m, stage), inputs)?;
        for (t, meta) in out.iter().zip(&io.outputs) {
            anyhow::ensure!(
                t.shape() == meta.shape.as_slice(),
                "stage {stage} output shape {:?} != manifest {:?}",
                t.shape(),
                meta.shape
            );
        }
        Ok(out)
    }

    pub fn stats(&self) -> EngineStats {
        lock_clean(&self.stats).clone()
    }

    pub fn reset_stats(&self) {
        *lock_clean(&self.stats) = EngineStats::default();
    }

    pub fn loaded_stages(&self) -> usize {
        self.executables.len()
    }
}
