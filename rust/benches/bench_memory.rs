//! Bench: estimator + max-seqlen search throughput (these run inside every
//! table regeneration, so they must stay cheap), plus memory-tracker and
//! host-pool hot paths.

use alst::config::{preset, ClusterConfig, FeatureFlags};
use alst::memory::{max_seqlen_search, Estimator, HostPool, MemoryTracker};
use alst::util::bench::quick;

fn main() {
    println!("bench_memory\n");

    let model = preset("llama3-8b").unwrap();
    let est = Estimator::new(model, ClusterConfig::h100(4), FeatureFlags::alst());

    quick("estimator breakdown (1 call)", || {
        let b = est.breakdown(3_700_000, 32);
        std::hint::black_box(&b);
    });

    quick("max_seqlen_search (llama8b, 32 gpus)", || {
        let out = max_seqlen_search(&est, 32);
        std::hint::black_box(&out);
    });

    let est70 = Estimator::new(
        preset("llama3-70b").unwrap(),
        ClusterConfig::h100(8),
        FeatureFlags::alst(),
    );
    quick("max_seqlen_search (llama70b, 64 gpus)", || {
        let out = max_seqlen_search(&est70, 64);
        std::hint::black_box(&out);
    });

    quick("tracker alloc/free x1000", || {
        let mut t = MemoryTracker::new(1 << 40);
        for i in 0..1000u64 {
            t.alloc(i % 4096 + 1, "x").unwrap();
        }
        for i in 0..1000u64 {
            t.free(i % 4096 + 1, "x");
        }
        std::hint::black_box(t.peak());
    });

    quick("host pool alloc/free x1000", || {
        let mut p = HostPool::new(1 << 40);
        for i in 0..1000u64 {
            p.alloc(i % 4096 + 1).unwrap();
        }
        for i in 0..1000u64 {
            p.free(i % 4096 + 1);
        }
        std::hint::black_box(p.peak());
    });
}
