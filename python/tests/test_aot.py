"""AOT export sanity: manifests are consistent, HLO text is loadable."""
from __future__ import annotations

import json
import pathlib

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    path = aot.export(M.CONFIGS["tiny"], seq=64, sp=2, out_root=out)
    return path, json.loads((path / "manifest.json").read_text())


def test_all_stage_files_exist(exported):
    path, manifest = exported
    assert set(manifest["stages"]) == {
        "embed_fwd", "embed_bwd", "pre_attn_fwd", "pre_attn_bwd",
        "attn_fwd", "attn_bwd", "post_attn_fwd", "post_attn_bwd",
        "loss_fwd", "loss_bwd",
        # optional tiled-execution stages (rust loads manifests without
        # them; new exports always carry them)
        "loss_fwd_tile", "loss_bwd_tile", "mlp_fwd_tile", "mlp_bwd_tile",
    }
    for st in manifest["stages"].values():
        text = (path / st["file"]).read_text()
        assert text.startswith("HloModule"), st["file"]


def test_tile_stage_shapes(exported):
    """Tile stages are row-sliced copies of their monolithic parents; the
    manifest's informational tile_rows echo must match the stage IO (the
    rust driver derives rows from the stage shapes)."""
    _, m = exported
    st, cfg = m["stages"], m["config"]
    t_loss = m["tile_rows"]["loss"]
    t_mlp = m["tile_rows"]["mlp"]
    h_in = next(e for e in st["loss_fwd_tile"]["inputs"] if e["name"] == "h")
    assert h_in["shape"] == [t_loss, cfg["hidden"]]
    # per-row loss out, not a scalar pair
    assert st["loss_fwd_tile"]["outputs"][0]["shape"] == [t_loss]
    # loss_bwd_tile mirrors loss_bwd's outputs at tile shapes
    assert st["loss_bwd_tile"]["outputs"][2]["shape"] == [t_loss, cfg["hidden"]]
    mlp_h = next(e for e in st["mlp_fwd_tile"]["inputs"] if e["name"] == "h_in")
    assert mlp_h["shape"] == [t_mlp, cfg["hidden"]]
    assert st["mlp_fwd_tile"]["outputs"][0]["shape"] == [t_mlp, cfg["hidden"]]
    # mlp_bwd_tile: 5 weight grads + d_h_in + d_attn
    assert len(st["mlp_bwd_tile"]["outputs"]) == 7
    assert st["mlp_bwd_tile"]["outputs"][5]["shape"] == [t_mlp, cfg["hidden"]]


def test_manifest_shapes_consistent(exported):
    _, m = exported
    cfg, ssh = m["config"], m["seq_shard"]
    assert ssh == m["seq"] // m["sp"]
    st = m["stages"]
    # pre_attn: h input is a sequence shard; q output has ALL q heads.
    h_in = next(e for e in st["pre_attn_fwd"]["inputs"] if e["name"] == "h")
    assert h_in["shape"] == [ssh, cfg["hidden"]]
    q_out = st["pre_attn_fwd"]["outputs"][0]
    assert q_out["shape"] == [ssh, cfg["n_q_heads"], cfg["head_dim"]]
    # attn core: full sequence, head shard only.
    q_in = next(e for e in st["attn_fwd"]["inputs"] if e["name"] == "q")
    assert q_in["shape"] == [m["seq"], m["q_heads_shard"], cfg["head_dim"]]
    k_in = next(e for e in st["attn_fwd"]["inputs"] if e["name"] == "k")
    assert k_in["shape"] == [m["seq"], m["kv_heads_shard"], cfg["head_dim"]]
    # loss: scalar outputs.
    assert all(e["shape"] == [] for e in st["loss_fwd"]["outputs"])


def test_kv_replication_in_manifest(exported):
    """tiny has kv=2 < sp when sp=4: kv_heads_shard must clamp to 1."""
    _, m2 = exported
    assert m2["kv_heads_shard"] == 1        # sp=2, kv=2 -> 1 (divisible)
    cfg = M.CONFIGS["tiny"]
    assert cfg.head_shard(4) == (1, 1)      # sp=4 > kv=2 -> replicate


def test_param_layout_covers_model(exported):
    _, m = exported
    layout = m["param_layout"]
    def group_size(g):
        return sum(
            int(pathlib_prod(t["shape"])) for t in layout[g]
        )
    total = (group_size("embed") + m["config"]["n_layers"] * group_size("layer")
             + group_size("final"))
    assert total == m["config"]["params_count"]


def pathlib_prod(shape):
    out = 1
    for s in shape:
        out *= s
    return out
