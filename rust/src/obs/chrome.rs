//! Chrome trace-event JSON export (loads in `chrome://tracing` and
//! Perfetto). Mapping: pid = simulated rank (coordinator work gets pid 0,
//! rank r gets pid r+1), tid = subsystem (`Category::tid`), ts in
//! microseconds since the tracer epoch. Spans are emitted as `B`/`E`
//! duration-event pairs per (pid, tid) lane — the format the CI validator
//! checks: every `B` closed by a matching `E`, ts monotonic per lane.
//! `MemoryTracker` events additionally become `C` counter events so the
//! device-byte curve renders under the coordinator process.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::tracer::{MemEvent, Span};
use crate::util::json::Json;

/// pid of coordinator-side (rank-less) spans.
pub const COORD_PID: u64 = 0;

/// tid of the memory counter lane (outside `Category::tid` range).
const MEM_TID: u64 = 99;

fn pid_of(rank: Option<usize>) -> u64 {
    match rank {
        Some(r) => r as u64 + 1,
        None => COORD_PID,
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn ts_us(ts_ns: u64) -> Json {
    Json::Num(ts_ns as f64 / 1000.0)
}

fn meta_event(name: &str, pid: u64, tid: u64, value: &str) -> Json {
    obj(vec![
        ("ph", Json::Str("M".into())),
        ("name", Json::Str(name.into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", obj(vec![("name", Json::Str(value.into()))])),
    ])
}

fn begin_event(pid: u64, tid: u64, s: &Span) -> Json {
    let mut args: Vec<(&str, Json)> = vec![("span_id", Json::Num(s.id as f64))];
    if s.bytes > 0 {
        args.push(("bytes", Json::Num(s.bytes as f64)));
    }
    if let Some(step) = s.step {
        args.push(("step", Json::Num(step as f64)));
    }
    if s.arena_hits > 0 || s.arena_misses > 0 {
        args.push(("arena_hits", Json::Num(s.arena_hits as f64)));
        args.push(("arena_misses", Json::Num(s.arena_misses as f64)));
    }
    if s.mem_delta != 0 {
        args.push(("mem_delta", Json::Num(s.mem_delta as f64)));
    }
    obj(vec![
        ("ph", Json::Str("B".into())),
        ("name", Json::Str(s.name.clone())),
        ("cat", Json::Str(s.cat.as_str().into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", ts_us(s.start_ns)),
        ("args", obj(args)),
    ])
}

fn end_event(pid: u64, tid: u64, name: &str, ts_ns: u64) -> Json {
    obj(vec![
        ("ph", Json::Str("E".into())),
        ("name", Json::Str(name.into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", ts_us(ts_ns)),
    ])
}

/// Build the trace document: `{"traceEvents": [...]}`.
///
/// Spans recorded by one logical actor are sequential or properly nested,
/// so each (pid, tid) lane is emitted with a stack walk: sort by
/// (start, longest-first), close stacked spans that end before the next
/// span opens, flush the rest at the end. End timestamps are clamped to
/// the lane cursor so ts stays monotonic even for degenerate input.
pub fn trace_events(spans: &[Span], mem: &[MemEvent]) -> Json {
    let mut events: Vec<Json> = Vec::new();

    let mut lanes: BTreeMap<(u64, u64), Vec<&Span>> = BTreeMap::new();
    for s in spans {
        lanes.entry((pid_of(s.rank), s.cat.tid())).or_default().push(s);
    }

    // Metadata: name every process and lane up front.
    let mut pids: Vec<u64> = lanes.keys().map(|&(p, _)| p).collect();
    if !mem.is_empty() {
        pids.push(COORD_PID);
    }
    pids.sort_unstable();
    pids.dedup();
    for &pid in &pids {
        let pname = if pid == COORD_PID {
            "coordinator".to_string()
        } else {
            format!("rank {}", pid - 1)
        };
        events.push(meta_event("process_name", pid, 0, &pname));
    }
    for (&(pid, tid), lane) in &lanes {
        events.push(meta_event("thread_name", pid, tid, lane[0].cat.as_str()));
    }
    if !mem.is_empty() {
        events.push(meta_event("thread_name", COORD_PID, MEM_TID, "device memory"));
    }

    for ((pid, tid), mut lane) in lanes {
        lane.sort_by_key(|s| (s.start_ns, std::cmp::Reverse(s.dur_ns), s.id));
        let mut stack: Vec<&Span> = Vec::new();
        let mut cursor = 0u64;
        for s in lane {
            while let Some(&top) = stack.last() {
                if top.end_ns() <= s.start_ns {
                    cursor = cursor.max(top.end_ns());
                    events.push(end_event(pid, tid, &top.name, cursor));
                    stack.pop();
                } else {
                    break;
                }
            }
            cursor = cursor.max(s.start_ns);
            events.push(begin_event(pid, tid, s));
            stack.push(s);
        }
        while let Some(top) = stack.pop() {
            cursor = cursor.max(top.end_ns());
            events.push(end_event(pid, tid, &top.name, cursor));
        }
    }

    for e in mem {
        events.push(obj(vec![
            ("ph", Json::Str("C".into())),
            ("name", Json::Str("device_bytes".into())),
            ("pid", Json::Num(COORD_PID as f64)),
            ("tid", Json::Num(MEM_TID as f64)),
            ("ts", ts_us(e.ts_ns)),
            ("args", obj(vec![("bytes", Json::Num(e.current as f64))])),
        ]));
    }

    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

/// Write the trace document to `path`.
pub fn write_trace(path: &Path, spans: &[Span], mem: &[MemEvent]) -> Result<()> {
    let doc = trace_events(spans, mem);
    std::fs::write(path, doc.to_string_pretty())
        .with_context(|| format!("writing trace to {}", path.display()))
}

/// Validate a trace-event document: known phases only, every event carries
/// pid/tid/ts, timestamps are monotonic (non-decreasing) per (pid, tid)
/// lane, every `B` is closed by an `E` with the same name (LIFO), and the
/// offload copy-stream lanes (`cat` `copy_d2h`/`copy_h2d`) never stack:
/// one worker serializes each stream, so an open copy span when another
/// begins means two copies overlapped within one stream.
/// This is the contract the CI bench-smoke job checks on `trace.json`.
pub fn validate_trace(doc: &Json) -> Result<()> {
    let events = doc
        .field("traceEvents")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("traceEvents is not an array"))?;
    ensure!(!events.is_empty(), "traceEvents is empty");

    // Per-lane state: (last ts, stack of open B names).
    let mut lanes: BTreeMap<(i64, i64), (f64, Vec<String>)> = BTreeMap::new();
    let mut durations = 0usize;
    for e in events {
        let ph = e.str_field("ph")?;
        if ph == "M" {
            continue;
        }
        if !matches!(ph, "B" | "E" | "C" | "i") {
            bail!("unknown event phase `{ph}`");
        }
        let pid = e
            .field("pid")?
            .as_i64()
            .ok_or_else(|| anyhow::anyhow!("pid is not a number"))?;
        let tid = e
            .field("tid")?
            .as_i64()
            .ok_or_else(|| anyhow::anyhow!("tid is not a number"))?;
        let ts = e.f64_field("ts")?;
        ensure!(ts >= 0.0, "negative ts");
        let lane = lanes.entry((pid, tid)).or_insert((f64::NEG_INFINITY, Vec::new()));
        ensure!(
            ts >= lane.0,
            "ts not monotonic in lane pid={pid} tid={tid}: {ts} < {}",
            lane.0
        );
        lane.0 = ts;
        match ph {
            "B" => {
                if let Some(cat) = e.get("cat").and_then(|c| c.as_str()) {
                    ensure!(
                        !(matches!(cat, "copy_d2h" | "copy_h2d") && !lane.1.is_empty()),
                        "copy-stream span overlaps `{}` in lane pid={pid} tid={tid}: \
                         one stream must serialize its copies",
                        lane.1.last().unwrap()
                    );
                }
                lane.1.push(e.str_field("name")?.to_string());
                durations += 1;
            }
            "E" => {
                let open = lane
                    .1
                    .pop()
                    .ok_or_else(|| anyhow::anyhow!("E without open B in lane pid={pid} tid={tid}"))?;
                if let Some(name) = e.get("name").and_then(|n| n.as_str()) {
                    ensure!(
                        name == open,
                        "E name `{name}` does not close B `{open}` in lane pid={pid} tid={tid}"
                    );
                }
            }
            _ => {}
        }
    }
    for ((pid, tid), (_, open)) in lanes {
        ensure!(
            open.is_empty(),
            "unclosed B [{}] in lane pid={pid} tid={tid}",
            open.join(", ")
        );
    }
    ensure!(durations > 0, "trace contains no duration events");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::tracer::{Category, Tracer};

    fn sample_spans() -> Vec<Span> {
        let t = Tracer::new(true);
        {
            let mut step = t.span(Category::Step, "train_step");
            step.set_step(1);
            {
                let mut g = t.span(Category::Exec, "tiny-sp2-seq256/attn_fwd");
                g.set_rank(0);
                g.set_bytes(4096);
            }
            {
                let mut g = t.span(Category::Collective, "all_gather");
                g.set_rank(1);
                g.set_bytes(24);
                g.set_dur(std::time::Duration::ZERO);
            }
        }
        t.drain()
    }

    #[test]
    fn export_passes_validator() {
        let spans = sample_spans();
        let mem = vec![MemEvent {
            ts_ns: 10,
            span_id: Some(spans[0].id),
            tag: "mlp".into(),
            delta: 1024,
            current: 1024,
        }];
        let doc = trace_events(&spans, &mem);
        validate_trace(&doc).unwrap();
        // Round-trips through the in-tree parser.
        let parsed = Json::parse(&doc.to_string_pretty()).unwrap();
        validate_trace(&parsed).unwrap();
    }

    #[test]
    fn pid_maps_rank_and_coordinator() {
        assert_eq!(pid_of(None), COORD_PID);
        assert_eq!(pid_of(Some(0)), 1);
        assert_eq!(pid_of(Some(7)), 8);
    }

    #[test]
    fn validator_rejects_unbalanced_and_nonmonotonic() {
        // Unclosed B.
        let doc = Json::parse(
            r#"{"traceEvents": [{"ph": "B", "name": "x", "pid": 0, "tid": 0, "ts": 1}]}"#,
        )
        .unwrap();
        assert!(validate_trace(&doc).is_err());
        // E without B.
        let doc = Json::parse(
            r#"{"traceEvents": [{"ph": "E", "name": "x", "pid": 0, "tid": 0, "ts": 1}]}"#,
        )
        .unwrap();
        assert!(validate_trace(&doc).is_err());
        // Non-monotonic ts within one lane.
        let doc = Json::parse(
            r#"{"traceEvents": [
                {"ph": "B", "name": "x", "pid": 0, "tid": 0, "ts": 5},
                {"ph": "E", "name": "x", "pid": 0, "tid": 0, "ts": 3}
            ]}"#,
        )
        .unwrap();
        assert!(validate_trace(&doc).is_err());
        // Balanced + monotonic passes.
        let doc = Json::parse(
            r#"{"traceEvents": [
                {"ph": "B", "name": "x", "pid": 0, "tid": 0, "ts": 3},
                {"ph": "E", "name": "x", "pid": 0, "tid": 0, "ts": 5}
            ]}"#,
        )
        .unwrap();
        validate_trace(&doc).unwrap();
    }

    #[test]
    fn validator_rejects_overlapping_copy_stream_spans() {
        // Two d2h copies stacked in one lane: a stream cannot run two
        // copies at once, so validation must fail.
        let doc = Json::parse(
            r#"{"traceEvents": [
                {"ph": "B", "name": "d2h_copy", "cat": "copy_d2h", "pid": 0, "tid": 8, "ts": 1},
                {"ph": "B", "name": "d2h_copy", "cat": "copy_d2h", "pid": 0, "tid": 8, "ts": 2},
                {"ph": "E", "name": "d2h_copy", "pid": 0, "tid": 8, "ts": 3},
                {"ph": "E", "name": "d2h_copy", "pid": 0, "tid": 8, "ts": 4}
            ]}"#,
        )
        .unwrap();
        let err = validate_trace(&doc).unwrap_err().to_string();
        assert!(err.contains("copy-stream"), "{err}");
        // Back-to-back copies in the same lane are fine.
        let doc = Json::parse(
            r#"{"traceEvents": [
                {"ph": "B", "name": "d2h_copy", "cat": "copy_d2h", "pid": 0, "tid": 8, "ts": 1},
                {"ph": "E", "name": "d2h_copy", "pid": 0, "tid": 8, "ts": 2},
                {"ph": "B", "name": "d2h_copy", "cat": "copy_d2h", "pid": 0, "tid": 8, "ts": 2},
                {"ph": "E", "name": "d2h_copy", "pid": 0, "tid": 8, "ts": 3}
            ]}"#,
        )
        .unwrap();
        validate_trace(&doc).unwrap();
        // Nesting in a non-copy lane is still allowed (step > exec).
        let doc = Json::parse(
            r#"{"traceEvents": [
                {"ph": "B", "name": "step", "cat": "step", "pid": 0, "tid": 0, "ts": 1},
                {"ph": "B", "name": "fwd", "cat": "step", "pid": 0, "tid": 0, "ts": 2},
                {"ph": "E", "name": "fwd", "pid": 0, "tid": 0, "ts": 3},
                {"ph": "E", "name": "step", "pid": 0, "tid": 0, "ts": 4}
            ]}"#,
        )
        .unwrap();
        validate_trace(&doc).unwrap();
    }

    #[test]
    fn zero_duration_spans_emit_balanced_pairs() {
        let t = Tracer::new(true);
        for i in 0..3 {
            let mut g = t.span(Category::Collective, "account");
            g.set_bytes(i);
            g.set_dur(std::time::Duration::ZERO);
        }
        let doc = trace_events(&t.drain(), &[]);
        validate_trace(&doc).unwrap();
    }
}
