//! Device-memory model + allocation tracker.
//!
//! Models one GPU's memory the way the paper's profiling describes it
//! (§2.1 "runtime overheads"): total capacity minus CUDA context (~1 GiB)
//! minus NCCL buffers, with a fragmentation headroom that shrinks when the
//! expandable-segments allocator is enabled (§3.3).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::GIB;
use crate::obs::{self, MemEvent, Tracer};

#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub capacity: u64,
    /// CUDA context + driver reservations (paper: ~1 GiB).
    pub cuda_reserved: u64,
    /// NCCL internal buffers ("multiple gigabytes", §2.1; grows with the
    /// number of communicators — we model 1 GiB + 256 MiB per 8 ranks).
    pub nccl_reserved: u64,
    /// Fraction of usable memory lost to fragmentation. The paper's
    /// expandable-segments fix "provided massive improvements": we model
    /// 12% headroom without it, 3% with it.
    pub frag_fraction: f64,
}

impl DeviceModel {
    pub fn h100(world: usize, expandable_segments: bool) -> DeviceModel {
        DeviceModel {
            capacity: 80 * GIB,
            cuda_reserved: GIB,
            nccl_reserved: GIB + (world as u64).div_ceil(8) * 256 * (1 << 20),
            frag_fraction: if expandable_segments { 0.03 } else { 0.12 },
        }
    }

    /// Bytes actually available to tensors.
    pub fn usable(&self) -> u64 {
        let after_reserved = self
            .capacity
            .saturating_sub(self.cuda_reserved + self.nccl_reserved);
        (after_reserved as f64 * (1.0 - self.frag_fraction)) as u64
    }
}

#[derive(Debug)]
pub struct OomError {
    pub requested: u64,
    pub in_use: u64,
    pub usable: u64,
    pub tag: String,
}

impl std::fmt::Display for OomError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "OOM allocating {} MiB for `{}`: {} / {} MiB in use",
            self.requested >> 20,
            self.tag,
            self.in_use >> 20,
            self.usable >> 20
        )
    }
}

impl std::error::Error for OomError {}

/// Allocation tracker for one simulated device. Tags give the per-category
/// breakdown the paper's memory-profiler plots show (Figures 3, 4, 7).
#[derive(Debug)]
pub struct MemoryTracker {
    usable: u64,
    current: u64,
    peak: u64,
    by_tag: BTreeMap<String, u64>,
    /// High-water mark per tag (what the tiled-execution tests assert:
    /// the loss-head tag's peak drops by `TilePlan::savings()`).
    tag_peaks: BTreeMap<String, u64>,
    /// (time-ordered) samples of `current` for timeline plots.
    pub timeline: Vec<u64>,
    /// Span correlation: when an enabled tracer is attached, every
    /// alloc/free also records a [`MemEvent`] naming the innermost open
    /// span, so a memory peak can name the span that caused it.
    tracer: Option<Arc<Tracer>>,
    events: Vec<MemEvent>,
    /// See [`MemoryTracker::underflow_events`].
    underflow_events: u64,
}

impl MemoryTracker {
    pub fn new(usable: u64) -> MemoryTracker {
        MemoryTracker {
            usable,
            current: 0,
            peak: 0,
            by_tag: BTreeMap::new(),
            tag_peaks: BTreeMap::new(),
            timeline: Vec::new(),
            tracer: None,
            events: Vec::new(),
            underflow_events: 0,
        }
    }

    /// Attach a tracer for span-correlated memory events. With the shared
    /// disabled tracer (or none) the alloc/free hot path is unchanged.
    pub fn set_tracer(&mut self, tracer: Arc<Tracer>) {
        self.tracer = Some(tracer);
    }

    fn record_event(&mut self, tag: &str, delta: i64) {
        if let Some(t) = &self.tracer {
            if t.enabled() {
                obs::note_mem(delta);
                self.events.push(MemEvent {
                    ts_ns: t.now_ns(),
                    span_id: obs::current_span(),
                    tag: tag.to_string(),
                    delta,
                    current: self.current,
                });
            }
        }
    }

    /// Span-correlated events recorded since construction (or the last
    /// `take_events`). Unlike `timeline`, these survive `reset_peak` so a
    /// multi-step traced run keeps its full memory history.
    pub fn events(&self) -> &[MemEvent] {
        &self.events
    }

    pub fn take_events(&mut self) -> Vec<MemEvent> {
        std::mem::take(&mut self.events)
    }

    pub fn from_model(m: &DeviceModel) -> MemoryTracker {
        Self::new(m.usable())
    }

    pub fn alloc(&mut self, bytes: u64, tag: &str) -> Result<(), anyhow::Error> {
        // checked_add: a u64 overflow must OOM, not wrap past the check
        // (same hazard as `HostPool::alloc`, fixed in PR 2).
        let want = self.current.checked_add(bytes);
        if !want.is_some_and(|w| w <= self.usable) {
            return Err(OomError {
                requested: bytes,
                in_use: self.current,
                usable: self.usable,
                tag: tag.to_string(),
            }
            .into());
        }
        self.current = want.unwrap();
        self.peak = self.peak.max(self.current);
        let cur_tag = self.by_tag.entry(tag.to_string()).or_insert(0);
        *cur_tag += bytes;
        let cur_tag = *cur_tag;
        let tag_peak = self.tag_peaks.entry(tag.to_string()).or_insert(0);
        *tag_peak = (*tag_peak).max(cur_tag);
        self.timeline.push(self.current);
        self.record_event(tag, bytes as i64);
        Ok(())
    }

    pub fn free(&mut self, bytes: u64, tag: &str) {
        // Same hardening as `HostPool::free`: saturate instead of wrapping,
        // but count the mismatch so tests can assert clean pairing.
        if bytes > self.current {
            debug_assert!(false, "free underflow: {} > {} (`{}`)", bytes, self.current, tag);
            self.underflow_events += 1;
        }
        self.current = self.current.saturating_sub(bytes);
        if let Some(v) = self.by_tag.get_mut(tag) {
            *v = v.saturating_sub(bytes);
        }
        self.timeline.push(self.current);
        self.record_event(tag, -(bytes as i64));
    }

    /// Number of `free` calls that exceeded the live byte count (0 on any
    /// correct alloc/free pairing).
    pub fn underflow_events(&self) -> u64 {
        self.underflow_events
    }

    pub fn current(&self) -> u64 {
        self.current
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn usable(&self) -> u64 {
        self.usable
    }

    pub fn tag_bytes(&self, tag: &str) -> u64 {
        self.by_tag.get(tag).copied().unwrap_or(0)
    }

    /// High-water mark of `tag`'s live bytes since construction or the
    /// last `reset_peak`.
    pub fn tag_peak(&self, tag: &str) -> u64 {
        self.tag_peaks.get(tag).copied().unwrap_or(0)
    }

    pub fn breakdown(&self) -> &BTreeMap<String, u64> {
        &self.by_tag
    }

    pub fn reset_peak(&mut self) {
        self.peak = self.current;
        self.tag_peaks = self.by_tag.clone();
        self.timeline.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_usable_is_below_capacity() {
        let m = DeviceModel::h100(8, true);
        assert!(m.usable() < 80 * GIB);
        assert!(m.usable() > 70 * GIB);
        // expandable segments buys real headroom (paper §3.3)
        let frag = DeviceModel::h100(8, false);
        assert!(m.usable() > frag.usable() + 5 * GIB);
    }

    #[test]
    fn nccl_reservation_grows_with_world() {
        assert!(
            DeviceModel::h100(64, true).nccl_reserved
                > DeviceModel::h100(8, true).nccl_reserved
        );
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut t = MemoryTracker::new(1000);
        t.alloc(600, "a").unwrap();
        t.free(600, "a");
        t.alloc(100, "b").unwrap();
        assert_eq!(t.peak(), 600);
        assert_eq!(t.current(), 100);
    }

    #[test]
    fn tag_peak_is_per_tag_high_water() {
        let mut t = MemoryTracker::new(10_000);
        t.alloc(600, "logits").unwrap();
        t.alloc(300, "ckpt").unwrap();
        t.free(600, "logits");
        t.alloc(200, "logits").unwrap();
        assert_eq!(t.tag_peak("logits"), 600);
        assert_eq!(t.tag_peak("ckpt"), 300);
        assert_eq!(t.tag_bytes("logits"), 200);
        assert_eq!(t.tag_peak("nope"), 0);
        // reset_peak rebases tag peaks on the live bytes
        t.reset_peak();
        assert_eq!(t.tag_peak("logits"), 200);
        t.alloc(50, "logits").unwrap();
        assert_eq!(t.tag_peak("logits"), 250);
    }

    #[test]
    fn oom_reports_context() {
        let mut t = MemoryTracker::new(100);
        t.alloc(90, "w").unwrap();
        let err = t.alloc(20, "act").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("act"), "{msg}");
    }

    #[test]
    fn overflow_sized_alloc_reports_oom_not_wraparound() {
        let mut t = MemoryTracker::new(u64::MAX);
        t.alloc(u64::MAX - 10, "w").unwrap();
        // current + bytes would wrap u64 and skip the OOM check.
        let err = t.alloc(u64::MAX, "huge").unwrap_err();
        assert!(format!("{err}").contains("huge"));
        assert_eq!(t.current(), u64::MAX - 10, "current not corrupted");
        assert_eq!(t.peak(), u64::MAX - 10);
    }

    #[test]
    fn events_correlate_allocs_to_open_span() {
        use crate::obs::{Category, Tracer};
        let tracer = Arc::new(Tracer::new(true));
        let mut t = MemoryTracker::new(10_000);
        t.set_tracer(tracer.clone());
        let sweep_id = {
            let g = tracer.span(Category::Tile, "sweep");
            t.alloc(600, "loss_head").unwrap();
            t.free(600, "loss_head");
            g.id()
        };
        t.alloc(100, "ckpt").unwrap();
        let events = t.take_events();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].span_id, Some(sweep_id));
        assert_eq!(events[0].delta, 600);
        assert_eq!(events[0].current, 600);
        assert_eq!(events[1].delta, -600);
        assert_eq!(events[2].span_id, None, "alloc outside any span");
        // The sweep span carries the net device delta seen while open.
        let sweep = tracer.drain().into_iter().find(|s| s.name == "sweep").unwrap();
        assert_eq!(sweep.mem_delta, 0, "alloc+free cancel");
        assert!(t.events().is_empty(), "take_events drains");
    }

    #[test]
    fn disabled_tracer_records_no_events() {
        let mut t = MemoryTracker::new(1000);
        t.set_tracer(Tracer::off());
        t.alloc(100, "a").unwrap();
        t.free(100, "a");
        assert!(t.events().is_empty());
    }

    #[test]
    fn timeline_records_hill_shape() {
        let mut t = MemoryTracker::new(10_000);
        for _ in 0..5 {
            t.alloc(100, "ckpt").unwrap();
        }
        for _ in 0..5 {
            t.free(100, "ckpt");
        }
        let max = *t.timeline.iter().max().unwrap();
        assert_eq!(max, 500);
        assert_eq!(*t.timeline.last().unwrap(), 0);
    }
}
