//! Self-contained utilities (the image has no network registry, so JSON,
//! CLI parsing, RNG, and the bench harness are implemented in-tree).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
