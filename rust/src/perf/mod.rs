//! Performance model: the Megatron-style flos formula the paper uses for
//! its TFLOPS columns (§5.4 "standard Megatron-LM flos estimation taking
//! into account repeated forwards"), plus a roofline iteration-time model.

mod flos;
mod roofline;

pub use flos::{
    flos_per_layer, packed_attention_ratio, train_flos, train_flos_packed, FlosBreakdown,
};
pub use roofline::{iteration_time, iteration_time_packed, IterationModel, PerfResult};
