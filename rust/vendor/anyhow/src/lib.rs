//! Minimal offline shim for the `anyhow` API surface this workspace uses:
//! `Result`, `Error`, `anyhow!`, `bail!`, `ensure!`, and the `Context`
//! extension trait. Error values carry a context chain; `{e}` prints the
//! outermost message, `{e:#}` (and `{e:?}`) print the whole chain
//! outermost-first joined by `": "` — matching real anyhow closely enough
//! for the error-message assertions in the test suite.

use std::fmt;

/// Chain of messages, innermost cause first.
pub struct Error {
    msgs: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msgs: vec![m.to_string()] }
    }

    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.msgs.push(c.to_string());
        self
    }

    /// Context chain, outermost first (mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().rev().map(String::as_str)
    }

    fn fmt_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, m) in self.msgs.iter().rev().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{m}")?;
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.fmt_chain(f)
        } else {
            // outermost context only, like anyhow's non-alternate Display
            write!(f, "{}", self.msgs.last().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.fmt_chain(f)
    }
}

// NB: deliberately NOT `impl std::error::Error for Error` — exactly like
// real anyhow — so the blanket From below does not collide with the
// reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.insert(0, s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// `.context(..)` / `.with_context(|| ..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(c)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => { $crate::Error::msg(format!($($t)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            $crate::bail!($($t)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_fail() -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "inner cause"))
    }

    #[test]
    fn context_chain_formats_outermost_first() {
        let e: Error = io_fail().context("outer layer").unwrap_err();
        assert_eq!(format!("{e}"), "outer layer");
        let full = format!("{e:#}");
        assert!(full.starts_with("outer layer"), "{full}");
        assert!(full.contains("inner cause"), "{full}");
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner() -> Result<()> {
            crate::ensure!(1 + 1 == 3, "math broke: {}", 2);
            Ok(())
        }
        fn outer() -> Result<()> {
            inner().with_context(|| format!("step {}", 7))?;
            Ok(())
        }
        let e = outer().unwrap_err();
        let s = format!("{e:#}");
        assert!(s.contains("step 7") && s.contains("math broke: 2"), "{s}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }
}
