"""Segment-aware blocked causal attention for PACKED samples (paper §3.4,
§7.2).

When many short samples are packed into one long sequence, plain causal
attention lets tokens attend across sample boundaries. The paper's fix is
position-id-aware FlashAttention-2 (a 4-D mask would need O(S^2) memory —
29 GiB at 125K). This kernel is that fix for the ALST-RS stack: the same
blocked online-softmax as `flash_attn`, with a per-token segment id; a
`[TQ, TK]` boolean block `seg_q == seg_k & causal` replaces the O(S^2)
mask at O(tile^2) memory.

The paper also warns (§7.2) that SDPA *ignores* position ids and silently
attends across packed samples — `ref.attention_naive` on packed input
reproduces that wrong behaviour, and the tests assert the difference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, sq_ref, sk_ref, acc_ref, m_ref, l_ref,
            o_ref, *, tile_q: int, tile_k: int, scale: float, n_k: int):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...][:, 0, :]
    k = k_ref[...][:, 0, :]
    v = v_ref[...][:, 0, :]
    scores = (q @ k.T) * scale

    q_ids = i * tile_q + jax.lax.iota(jnp.int32, tile_q)
    k_ids = j * tile_k + jax.lax.iota(jnp.int32, tile_k)
    causal = q_ids[:, None] >= k_ids[None, :]
    same_seg = sq_ref[...][:, None] == sk_ref[...][None, :]
    mask = causal & same_seg                      # O(tile^2), never O(S^2)
    scores = jnp.where(mask, scores, NEG_INF)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, scores.max(axis=-1))
    p = jnp.where(mask, jnp.exp(scores - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        # every token attends at least to itself, so l > 0
        o_ref[...] = (acc_ref[...] / l_ref[...][:, None])[:, None, :]


def packed_flash_attention(q, k, v, seg_ids, *, tile_q: int = 128,
                           tile_k: int = 128, interpret: bool = True):
    """Causal attention restricted to same-segment tokens.

    q: [S, Hq, D]; k, v: [S, Hkv, D]; seg_ids: [S] i32 sample index
    (non-decreasing for packed batches, but any labelling works).
    """
    s, hq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    rep = hq // hkv
    tile_q, tile_k = min(tile_q, s), min(tile_k, s)
    assert s % tile_q == 0 and s % tile_k == 0
    n_q, n_k = s // tile_q, s // tile_k
    kernel = functools.partial(
        _kernel, tile_q=tile_q, tile_k=tile_k, scale=1.0 / d**0.5, n_k=n_k
    )
    _, _, _, o = pl.pallas_call(
        kernel,
        grid=(hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((tile_q, 1, d), lambda h, i, j: (i, h, 0)),
            pl.BlockSpec((tile_k, 1, d), lambda h, i, j: (j, h // rep, 0)),
            pl.BlockSpec((tile_k, 1, d), lambda h, i, j: (j, h // rep, 0)),
            pl.BlockSpec((tile_q,), lambda h, i, j: (i,)),
            pl.BlockSpec((tile_k,), lambda h, i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, d), lambda h, i, j: (i, 0)),
            pl.BlockSpec((tile_q,), lambda h, i, j: (i,)),
            pl.BlockSpec((tile_q,), lambda h, i, j: (i,)),
            pl.BlockSpec((tile_q, 1, d), lambda h, i, j: (i, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, d), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((s, hq, d), q.dtype),
        ],
        interpret=interpret,
    )(q, k, v, seg_ids, seg_ids)
    return o


def attention_naive_packed(q, k, v, seg_ids):
    """Reference: full-mask segment-aware attention (materializes the
    [S, S] mask the paper's §3.4 shows is infeasible at long S)."""
    s, hq, d = q.shape
    hkv = k.shape[1]
    kr = jnp.repeat(k, hq // hkv, axis=1)
    vr = jnp.repeat(v, hq // hkv, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = jnp.einsum("qhd,khd->hqk", q, kr) * scale
    causal = jnp.tril(jnp.ones((s, s), bool))
    same = seg_ids[:, None] == seg_ids[None, :]
    scores = jnp.where((causal & same)[None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,khd->qhd", probs, vr)


def make_packed_segments(sample_lengths):
    """seg_ids + position_ids for samples packed back to back. The
    position ids reset per sample — the paper's [bs, seqlen] O(S)
    replacement for the 4-D mask."""
    seg, pos = [], []
    for i, n in enumerate(sample_lengths):
        seg.extend([i] * n)
        pos.extend(range(n))
    return jnp.asarray(seg, jnp.int32), jnp.asarray(pos, jnp.int32)
