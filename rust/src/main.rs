//! `alst` — the launcher CLI.
//!
//! Subcommands:
//!   train     — run real training through the PJRT pipeline
//!               (--artifacts DIR --config tiny --sp 2 --seq 256 --steps N)
//!   search    — simulator max-seqlen search per (model, GPUs, features)
//!   ablate    — Table 1 feature-ablation ladder
//!   estimate  — memory breakdown for a (model, seq, world)
//!   tables    — regenerate every paper table/figure dataset to CSV
//!   trace     — run N traced steps, write Chrome trace-event JSON +
//!               print the per-step attribution table (works without
//!               artifacts: falls back to a synthetic coordinator step)
//!   chaos     — fault-injection drill: unfaulted reference run, a
//!               transient fault absorbed by retry/backoff, and a lost
//!               rank recovered from snapshot — each checked for
//!               bit-identity against the reference; exports the traced
//!               recovery (Fault lane) and the per-step CSV. With
//!               `--transport socket` the drill re-runs over spawned rank
//!               processes and a REAL fault (a SIGKILLed worker), holding
//!               the same recovery contract.
//!
//! Shared knobs: `--transport {local,socket}` (train/trace/chaos) selects
//! the collective frame carrier; `--retries N --retry-base-us U
//! --no-retry-jitter` tune the wire retry policy; `--op-timeout-ms T`
//! bounds one collective frame roundtrip.
//!
//! There is also a hidden `rank-worker` subcommand: the per-rank echo
//! process `SocketTransport::spawn` launches. Its flags are emitted by
//! `launch_rank` and are not a public interface.

use anyhow::{Context, Result};

use alst::config::{preset, ClusterConfig, FeatureFlags, PlanKind, GIB};
use alst::coordinator::dataloader::{MarkovSource, UlyssesDataLoader};
use alst::coordinator::pipeline::{Trainer, TrainerOptions};
use alst::memory::{max_seqlen_search, Estimator};
use alst::metrics::RunLog;
use alst::perf::{iteration_time, IterationModel};
use alst::util::bench::{fmt_duration_hms, fmt_seqlen};
use alst::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("train") => cmd_train(&args),
        Some("search") => cmd_search(&args),
        Some("ablate") => cmd_ablate(&args),
        Some("estimate") => cmd_estimate(&args),
        Some("tables") => cmd_tables(&args),
        Some("validate") => cmd_validate(&args),
        Some("trace") => cmd_trace(&args),
        Some("chaos") => cmd_chaos(&args),
        // hidden: the per-rank echo worker SocketTransport spawns
        Some("rank-worker") => cmd_rank_worker(&args),
        _ => {
            eprintln!(
                "usage: alst <train|search|ablate|estimate|tables|validate|trace|chaos> [--key value ...]"
            );
            std::process::exit(2);
        }
    }
}

/// The per-rank worker process behind `SocketTransport`. Parses exactly
/// the argv `transport::launch_rank` emits — the two must stay in
/// lockstep — then runs the framed echo loop until the coordinator shuts
/// the channel down (or a planned failure fires).
fn cmd_rank_worker(args: &Args) -> Result<()> {
    use alst::collectives::transport::{
        run_worker, WorkerConfig, WorkerFailMode, WorkerFailure,
    };
    let rank = args.usize("rank", 0);
    let main_path = args.get("main").context("rank-worker: --main is required")?;
    let hb_path = args.get("hb").context("rank-worker: --hb is required")?;
    let failure = match args.get("fail-mode") {
        None => None,
        Some(m) => {
            let mode: WorkerFailMode =
                m.parse().map_err(|e: String| anyhow::anyhow!(e))?;
            Some(WorkerFailure { rank, mode, after: args.u64("fail-after", 0) })
        }
    };
    run_worker(&WorkerConfig {
        rank,
        main_path: std::path::PathBuf::from(main_path),
        hb_path: std::path::PathBuf::from(hb_path),
        hb_interval: std::time::Duration::from_micros(args.u64("hb-interval-us", 50_000)),
        connect_timeout: std::time::Duration::from_millis(
            args.u64("connect-timeout-ms", 10_000),
        ),
        failure,
        exit_hard: true,
    })
}

/// `--retries` / `--retry-base-us` / `--no-retry-jitter` over the
/// default policy (the jitter seed stays fixed: reruns reproduce).
fn retry_from_args(args: &Args) -> alst::collectives::faults::RetryPolicy {
    let mut r = alst::collectives::faults::RetryPolicy::default();
    r.max_retries = args.u64("retries", r.max_retries as u64) as u32;
    r.base = std::time::Duration::from_micros(
        args.u64("retry-base-us", r.base.as_micros() as u64),
    );
    if args.flag("no-retry-jitter") {
        r.jitter = false;
    }
    r
}

fn transport_from_args(args: &Args) -> Result<alst::collectives::transport::TransportKind> {
    args.get_or("transport", "local")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))
}

fn op_timeout_from_args(args: &Args) -> Option<std::time::Duration> {
    args.get("op-timeout-ms")
        .and_then(|v| v.parse::<u64>().ok())
        .map(std::time::Duration::from_millis)
}

fn flags_from_args(args: &Args) -> FeatureFlags {
    let mut f = if args.flag("baseline") {
        FeatureFlags::baseline()
    } else {
        FeatureFlags::alst()
    };
    if args.flag("weights-offload") {
        f.weights_offload = true;
    }
    if args.flag("no-offload") {
        f.ckpt_offload = false;
    }
    if args.flag("no-tiled-mlp") {
        f.tiled_mlp = false;
    }
    f
}

fn cmd_train(args: &Args) -> Result<()> {
    let root = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let config = args.get_or("config", "tiny");
    let sp = args.usize("sp", 2);
    let seq = args.usize("seq", 256);
    let steps = args.usize("steps", 20);
    let seed = args.usize("seed", 0) as u64;
    let dir = alst::runtime::Manifest::artifact_dir(&root, &config, sp, seq);
    println!("loading artifacts from {}", dir.display());

    // --plan ring swaps the attention relayout protocol: KV-block
    // rotation over send_recv instead of the seq<->head all-to-alls
    // (lifts the heads >= sp bound; see coordinator::ring)
    let plan_arg = args.get_or("plan", "ulysses");
    let plan = PlanKind::parse(&plan_arg)
        .ok_or_else(|| anyhow::anyhow!("unknown --plan {plan_arg} (ulysses|ring)"))?;
    let mut opts = TrainerOptions {
        flags: flags_from_args(args),
        seed,
        checked: args.flag("checked"),
        // tiled EXECUTION (requires artifacts with the *_tile stages)
        tiled_loss: args.flag("tiled-loss"),
        tiled_mlp: args.flag("tiled-mlp"),
        plan,
        retry: retry_from_args(args),
        op_timeout: op_timeout_from_args(args),
        transport: transport_from_args(args)?,
        ..Default::default()
    };
    opts.adamw.lr = args.f64("lr", opts.adamw.lr as f64) as f32;
    if let Some(warmup) = args.get("warmup") {
        opts.lr_schedule = Some(alst::coordinator::pipeline::LrSchedule {
            peak_lr: opts.adamw.lr,
            warmup_steps: warmup.parse().unwrap_or(10),
            total_steps: steps as u64,
            min_lr: opts.adamw.lr * 0.1,
        });
    }
    let mut trainer = Trainer::new(&dir, opts)?;
    if let Some(resume) = args.get("resume") {
        trainer.load_snapshot(std::path::Path::new(resume))?;
        println!("resumed from {resume} at step {}", trainer.step_count());
    }
    println!(
        "model={} params={} sp={} seq={} kernels={}",
        trainer.manifest.config.name,
        trainer.manifest.config.params_count,
        trainer.sp(),
        trainer.manifest.seq,
        trainer.manifest.config.kernels,
    );

    // --data FILE trains on a byte-tokenized real corpus (needs vocab>=256);
    // default is the learnable synthetic Markov stream.
    let source: Box<dyn alst::coordinator::dataloader::BatchSource> =
        if let Some(path) = args.get("data") {
            anyhow::ensure!(
                trainer.manifest.config.vocab >= 256,
                "byte-level corpus needs vocab >= 256"
            );
            Box::new(alst::coordinator::dataloader::CorpusSource::from_file(
                std::path::Path::new(path),
                seq,
                seed,
            )?)
        } else {
            Box::new(MarkovSource::new(
                trainer.manifest.config.vocab,
                seq,
                0.05,
                seed ^ 1,
            ))
        };
    let mut loader = UlyssesDataLoader::new(source, sp);
    let gas = args.usize("gas", 1);
    let mut log = RunLog::default();
    for step in 0..steps {
        let batches: Vec<Vec<i32>> = (0..gas).map(|_| loader.next().0).collect();
        let m = trainer.train_step_accum(&batches)?;
        if step % args.usize("log-every", 1) == 0 {
            println!(
                "step {:>4}  loss {:.4}  gnorm {:.3}  {:.1}ms  a2a {:.1}MiB  ring {:.1}MiB",
                m.step,
                m.loss,
                m.grad_norm,
                m.step_time.as_secs_f64() * 1e3,
                m.a2a_bytes as f64 / (1 << 20) as f64,
                m.send_recv_bytes as f64 / (1 << 20) as f64,
            );
        }
        log.push(m);
    }
    println!("{}", log.ascii_loss_curve(60, 12));
    if let Some(path) = args.get("csv") {
        log.write_csv(std::path::Path::new(path))?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("save") {
        trainer.save_snapshot(std::path::Path::new(path))?;
        println!("snapshot saved to {path}");
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let model = preset(&args.get_or("model", "llama3-8b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model preset"))?;
    let world = args.usize("gpus", 8);
    let nodes = world.div_ceil(8);
    let flags = flags_from_args(args);
    let est = Estimator::new(model, ClusterConfig::h100(nodes), flags);
    let out = max_seqlen_search(&est, world);
    let perf = iteration_time(
        &IterationModel {
            model: model.clone(),
            cluster: ClusterConfig::h100(nodes),
            flags,
            plan: PlanKind::Ulysses,
        },
        out.max_seqlen.max(1),
        world,
    );
    println!(
        "{} on {} GPUs [{}]: max seqlen {} (bound by {}), modeled iter {} @ {:.1} TFLOPS/GPU",
        model.name,
        world,
        flags.describe(),
        fmt_seqlen(out.max_seqlen),
        out.binding,
        fmt_duration_hms(std::time::Duration::from_secs_f64(perf.iteration_s)),
        perf.tflops_per_gpu,
    );
    Ok(())
}

fn cmd_ablate(args: &Args) -> Result<()> {
    let model = preset(&args.get_or("model", "llama3-8b")).unwrap();
    let world = args.usize("gpus", 8);
    let table = alst::paper::table1_ablations(model, world);
    table.print();
    Ok(())
}

fn cmd_estimate(args: &Args) -> Result<()> {
    let model = preset(&args.get_or("model", "llama3-8b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model preset"))?;
    let world = args.usize("gpus", 8);
    let seq = args.usize("seq", 32_768);
    let flags = flags_from_args(args);
    let est = Estimator::new(model, ClusterConfig::h100(world.div_ceil(8)), flags);
    let b = est.breakdown(seq, world);
    let gib = |x: u64| x as f64 / GIB as f64;
    println!(
        "per-GPU memory for {} @ seq {} on {} GPUs [{}]:",
        model.name,
        fmt_seqlen(seq),
        world,
        flags.describe()
    );
    println!("  weights (device)   {:>8.2} GiB", gib(b.weights_device));
    println!("  grads   (device)   {:>8.2} GiB", gib(b.grads_device));
    println!("  optim   (device)   {:>8.2} GiB", gib(b.optim_device));
    println!("  ckpt    (device)   {:>8.2} GiB", gib(b.acts.ckpt_device));
    println!("  attn work          {:>8.2} GiB", gib(b.acts.attn_work));
    println!("  mlp work           {:>8.2} GiB", gib(b.acts.mlp_work));
    println!("  logits work        {:>8.2} GiB", gib(b.acts.logits_work));
    println!("  resid work         {:>8.2} GiB", gib(b.acts.resid_work));
    println!("  misc               {:>8.2} GiB", gib(b.misc));
    println!("  TOTAL device       {:>8.2} GiB", gib(b.device_total()));
    println!("  host per rank      {:>8.2} GiB", gib(b.host_per_rank));
    println!("  fits: {}", est.fits(seq, world));
    Ok(())
}

/// Artifact doctor: load a manifest, compile every stage, execute each
/// with zero-filled inputs, and verify the output shapes — catches stale
/// or mismatched artifacts before a long training run does.
fn cmd_validate(args: &Args) -> Result<()> {
    use alst::runtime::{Engine, HostTensor, Manifest};
    let root = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let dirs: Vec<std::path::PathBuf> = if let Some(cfg) = args.get("config") {
        vec![Manifest::artifact_dir(
            &root,
            cfg,
            args.usize("sp", 1),
            args.usize("seq", 256),
        )]
    } else {
        std::fs::read_dir(&root)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.join("manifest.json").exists())
            .collect()
    };
    anyhow::ensure!(!dirs.is_empty(), "no artifact dirs under {}", root.display());

    let mut failures = 0;
    for dir in dirs {
        print!("{} ... ", dir.display());
        let check = (|| -> Result<usize> {
            let m = Manifest::load(&dir)?;
            let mut engine = Engine::cpu()?;
            engine.load_manifest(&m)?;
            for (name, io) in &m.stages {
                let inputs: Vec<HostTensor> = io
                    .inputs
                    .iter()
                    .map(|t| match t.dtype {
                        alst::runtime::Dtype::F32 => HostTensor::zeros(&t.shape),
                        alst::runtime::Dtype::I32 => HostTensor::i32(
                            t.shape.clone(),
                            vec![0; t.shape.iter().product()],
                        ),
                    })
                    .collect();
                let refs: Vec<&HostTensor> = inputs.iter().collect();
                engine
                    .execute_checked(&m, name, &refs)
                    .with_context(|| format!("stage {name}"))?;
            }
            Ok(m.stages.len())
        })();
        match check {
            Ok(n) => println!("OK ({n} stages)"),
            Err(e) => {
                println!("FAIL: {e:#}");
                failures += 1;
            }
        }
    }
    anyhow::ensure!(failures == 0, "{failures} artifact dir(s) failed validation");
    println!("all artifacts valid");
    Ok(())
}

/// Run N traced steps and export the two observability artifacts:
/// Chrome trace-event JSON (`--out`, default trace.json — loads in
/// Perfetto) and the per-step attribution table on stdout. With compiled
/// artifacts present the steps are real PJRT train steps; without them
/// (CI, fresh checkouts) a synthetic coordinator-only step exercises
/// every traced subsystem so the emitted trace is still representative.
fn cmd_trace(args: &Args) -> Result<()> {
    let root = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let config = args.get_or("config", "tiny");
    let sp = args.usize("sp", 2);
    let seq = args.usize("seq", 256);
    let steps = args.usize("steps", 2);
    let out = args.get_or("out", "trace.json");
    let out_path = std::path::PathBuf::from(&out);

    let dir = alst::runtime::Manifest::artifact_dir(&root, &config, sp, seq);
    let (spans, mem) = if dir.join("manifest.json").exists() {
        println!("tracing {steps} PJRT train steps from {}", dir.display());
        let flags = flags_from_args(args);
        let opts = TrainerOptions {
            // whenever checkpoints offload, trace the async engine so the
            // copy-stream lanes and stall spans appear in the export
            async_offload: flags
                .ckpt_offload
                .then(alst::coordinator::offload::OffloadConfig::default),
            flags,
            seed: args.usize("seed", 0) as u64,
            trace: true,
            // serial ranks: per-rank spans don't overlap in wall time, so
            // the attribution table reads as a fraction of the step
            parallel_ranks: false,
            tiled_loss: args.flag("tiled-loss"),
            tiled_mlp: args.flag("tiled-mlp"),
            retry: retry_from_args(args),
            op_timeout: op_timeout_from_args(args),
            transport: transport_from_args(args)?,
            ..Default::default()
        };
        let mut trainer = Trainer::new(&dir, opts)?;
        let vocab = trainer.manifest.config.vocab;
        let mut loader =
            UlyssesDataLoader::new(MarkovSource::new(vocab, seq, 0.05, 1), sp);
        for _ in 0..steps {
            let (ids, _) = loader.next();
            let m = trainer.train_step_accum(&[ids])?;
            println!(
                "step {:>4}  loss {:.4}  {:.1}ms",
                m.step,
                m.loss,
                m.step_time.as_secs_f64() * 1e3
            );
        }
        let spans = trainer.tracer().drain();
        let mem = trainer.device.take_events();
        (spans, mem)
    } else {
        println!(
            "no artifacts at {} — tracing the synthetic coordinator step \
             (relayouts, collectives, ring rotation, checkpoint tape, tiled \
             loss sweep, marshal)",
            dir.display()
        );
        synthetic_trace(sp, steps, transport_from_args(args)?)?
    };

    let doc = alst::obs::trace_events(&spans, &mem);
    alst::obs::validate_trace(&doc).context("emitted trace failed validation")?;
    std::fs::write(&out_path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", out_path.display()))?;
    println!(
        "wrote {} ({} spans, {} memory events)",
        out_path.display(),
        spans.len(),
        mem.len()
    );

    let report = alst::obs::AttributionReport::build(&spans, &mem);
    report.to_table().print();
    for line in report.summary_lines() {
        println!("{line}");
    }
    Ok(())
}

/// The artifact-free traced workload: per step, a Step span wrapping
/// relayout cycles (Relayout + Collective spans and the byte ledger), a
/// ring-plan forward/backward (per-rank Ring fold lanes, `send_recv`
/// Collective spans, and the rotation's overlap Stall span),
/// checkpoint store/prefetch/fetch through the async offload engine
/// (Offload spans, CopyD2H/CopyH2D stream lanes, Stall spans, and
/// `MemoryTracker` events), real `Engine::to_buffer` uploads (Marshal
/// spans), and a tiled loss sweep over the host reference head (Tile
/// spans, per-rank via `rank_scope`).
fn synthetic_trace(
    sp: usize,
    steps: usize,
    transport: alst::collectives::TransportKind,
) -> Result<(Vec<alst::obs::Span>, Vec<alst::obs::MemEvent>)> {
    use alst::coordinator::dataloader::IGNORE_INDEX;
    use alst::coordinator::offload::{AsyncOffloadEngine, OffloadConfig, CKPT_TAG};
    use alst::coordinator::plan::{AttnShape, ParallelPlan};
    use alst::coordinator::ring::RingPlan;
    use alst::coordinator::ulysses::{a2a_head_to_seq_into, a2a_seq_to_head_into};
    use alst::obs::{Category, Tracer};
    use alst::tiling::exec::{HostLossHead, TiledLossExec};
    use std::sync::Arc;

    let fast = alst::util::bench::fast_mode();
    let (ssh, n_q, d) = if fast { (256, 8, 16) } else { (1024, 16, 32) };
    let (hidden, vocab, rows) = if fast { (32, 64, 64) } else { (64, 256, 256) };
    let n_layers = 2;

    let tracer = Arc::new(Tracer::new(true));
    let mut engine = alst::runtime::Engine::cpu()?;
    engine.set_tracer(tracer.clone());
    let mut group = match transport {
        alst::collectives::TransportKind::Local => alst::collectives::Group::new(sp),
        alst::collectives::TransportKind::Socket => {
            // real rank processes behind the synthetic step: the trace
            // gains the wire-wait Stall spans the local queues never pay
            let st = alst::collectives::SocketTransport::spawn(
                sp,
                alst::collectives::SocketOptions::default(),
                tracer.clone(),
            )?;
            alst::collectives::Group::with_transport(sp, st)
        }
    };
    group.set_tracer(tracer.clone());
    let mut device = alst::memory::MemoryTracker::new(1 << 40);
    device.set_tracer(tracer.clone());
    let mut host = alst::memory::HostPool::new(1 << 40);
    let arena = Arc::new(alst::runtime::ScratchArena::new());
    let offload =
        AsyncOffloadEngine::new(arena.clone(), tracer.clone(), OffloadConfig::default());
    let mut rng = alst::util::rng::Rng::new(7);

    let q: Vec<alst::runtime::HostTensor> = (0..sp)
        .map(|_| {
            alst::runtime::HostTensor::f32(
                vec![ssh, n_q, d],
                rng.normal_vec(ssh * n_q * d, 1.0),
            )
        })
        .collect();
    let head = HostLossHead::new(
        hidden,
        vocab,
        IGNORE_INDEX,
        vec![1.0; hidden],
        rng.normal_vec(hidden * vocab, 0.02),
    )?;
    let h = alst::runtime::HostTensor::f32(
        vec![ssh, hidden],
        rng.normal_vec(ssh * hidden, 1.0),
    );
    let labels: Vec<i32> = (0..ssh).map(|i| (i % vocab) as i32).collect();

    // Ring-plan inputs (smaller than the relayout tensors — the host
    // reference attention is O(seq^2 d) per head, the rotation spans are
    // what the trace needs, not the flops)
    let ring = RingPlan::default();
    let (rsh, rq, rd) = if fast { (64, 2, 8) } else { (128, 4, 16) };
    let rshape = AttnShape::new(rq, rq, rd);
    let rcu = vec![0, (rsh * sp) as i32];
    let mut ring_in = || -> Vec<alst::runtime::HostTensor> {
        (0..sp)
            .map(|_| {
                alst::runtime::HostTensor::f32(
                    vec![rsh, rq, rd],
                    rng.normal_vec(rsh * rq * rd, 1.0),
                )
            })
            .collect()
    };
    let (rqs, rks, rvs) = (ring_in(), ring_in(), ring_in());

    for step in 0..steps as u64 {
        let mut step_span = tracer.span(Category::Step, "trace_step");
        step_span.set_step(step + 1);

        for _ in 0..n_layers {
            let full = a2a_seq_to_head_into(&group, &q, &arena)?;
            let back = a2a_head_to_seq_into(&group, &full, n_q, false, &arena)?;
            arena.recycle_all(full);
            arena.recycle_all(back);
        }

        // Ring plan forward + backward: the KV rotation's send_recv
        // Collective spans, the per-rank Ring fold lanes, and the
        // measured-overlap Stall span all land in the export
        let (ro, rsaved) =
            ring.attention_forward(&group, &arena, &rqs, &rks, &rvs, &rshape, &rcu)?;
        let (rdq, rdk, rdv) = ring.attention_backward(
            &group, &arena, &rqs, &rks, &rvs, &ro, &rsaved, &rshape, &rcu,
        )?;
        rsaved.recycle(&arena);
        arena.recycle_all(ro);
        arena.recycle_all(rdq);
        arena.recycle_all(rdk);
        arena.recycle_all(rdv);

        for li in 0..n_layers {
            for r in 0..sp {
                let t = alst::runtime::HostTensor::zeros(&[ssh, hidden]);
                offload.store(li, r, t, &mut host)?;
            }
        }
        // double-buffered restore: prefetch the top layer, then fetch each
        // layer while the one below copies behind the marshal work
        offload.prefetch_layer(n_layers - 1, sp)?;
        for li in (0..n_layers).rev() {
            if li > 0 {
                offload.prefetch_layer(li - 1, sp)?;
            }
            for r in 0..sp {
                let t = offload.fetch(li, r, &mut device, &mut host)?;
                // marshal: a real host->device literal build on the CPU client
                std::hint::black_box(engine.to_buffer(&t)?);
                // fetched checkpoints stay device-charged until consumed
                device.free(t.size_bytes() as u64, CKPT_TAG);
                arena.recycle(t);
            }
        }

        for r in 0..sp {
            let _rank = alst::obs::rank_scope(r);
            let drv = TiledLossExec::new(ssh, hidden, vocab, rows, IGNORE_INDEX, &arena)?
                .with_tracer(tracer.clone());
            let sweep = drv.forward(&mut device, &h, &labels, |ht, lt| {
                let losses = head.per_row_losses(ht.as_f32()?, lt.as_i32()?)?;
                Ok(alst::runtime::HostTensor::f32(vec![losses.len()], losses))
            })?;
            arena.recycle_f32(sweep.per_row_loss);
        }
    }
    Ok((tracer.drain(), device.take_events()))
}

/// The fault-injection drill. Three runs of the chaos harness (real
/// collectives, offload copy streams, per-rank stage gates, a real
/// `ParallelPlan`): an unfaulted reference; a transient collective fault
/// that the retry/backoff gates must absorb without a restore; and a
/// lost rank that the resilient supervisor must recover from snapshot.
/// Both faulted runs are checked for bit-identical final parameters
/// against the reference and for balanced host/device ledgers — any
/// mismatch exits nonzero. The recovered run is traced: the export gets
/// the `Category::Fault` lane (retry backoff, snapshot saves, the
/// recovery restore), and `--csv` writes per-step metrics including the
/// `retries`/`recoveries` columns.
fn cmd_chaos(args: &Args) -> Result<()> {
    use alst::collectives::faults::{FaultKind, FaultPlan, FaultSite};
    use alst::collectives::{SocketOptions, TransportKind, WorkerFailMode, WorkerFailure};
    use alst::coordinator::recover::{
        run_resilient, ChaosConfig, ChaosHarness, Recoverable, ResilienceOptions,
    };
    use alst::obs::Category;
    use std::time::Duration;

    let fast = alst::util::bench::fast_mode();
    let sp = args.usize("sp", 4);
    let steps = args.usize("steps", 4) as u64;
    let seq = args.usize("seq", if fast { 16 } else { 32 });
    let n_layers = args.usize("layers", 2);
    let k = args.usize("k", 2) as u64;
    let plan_arg = args.get_or("plan", "ulysses");
    let plan = PlanKind::parse(&plan_arg)
        .ok_or_else(|| anyhow::anyhow!("unknown --plan {plan_arg} (ulysses|ring)"))?;
    let out = args.get_or("out", "chaos_trace.json");
    anyhow::ensure!(steps >= 1, "--steps must be >= 1");
    let snap_dir = std::env::temp_dir().join("alst-chaos");
    std::fs::create_dir_all(&snap_dir)?;
    let transport = transport_from_args(args)?;
    let base = ChaosConfig {
        sp,
        seq,
        n_layers,
        plan,
        threaded: true,
        trace: false,
        fault_plan: None,
        ..ChaosConfig::default()
    };

    // 1. The unfaulted reference (same supervisor, same snapshot cadence,
    //    nothing to recover from).
    let mut reference = ChaosHarness::new(base.clone())?;
    let opts = ResilienceOptions {
        snapshot_every: k,
        ..ResilienceOptions::new(snap_dir.join("ref.alst"))
    };
    let ref_report = run_resilient(&mut reference, steps, &opts)?;
    println!(
        "reference: {steps} steps, plan {plan_arg}, sp {sp}, final loss {:.4}",
        ref_report.metrics.last().map(|m| m.loss).unwrap_or(0.0)
    );

    // 2. A transient collective fault: the per-site retry gate absorbs it
    //    in place; the supervisor must never see it.
    let transient = FaultPlan {
        site: FaultSite::Collective,
        kind: FaultKind::Transient,
        rank: 0,
        at_op: 2,
        seed: 7,
    };
    let mut h = ChaosHarness::new(ChaosConfig {
        fault_plan: Some(transient),
        ..base.clone()
    })?;
    let opts = ResilienceOptions {
        snapshot_every: k,
        ..ResilienceOptions::new(snap_dir.join("transient.alst"))
    };
    let rep = run_resilient(&mut h, steps, &opts)?;
    anyhow::ensure!(
        rep.fault.injected == 1 && rep.fault.retries >= 1,
        "transient fault was not injected/retried (stats {:?})",
        rep.fault
    );
    anyhow::ensure!(rep.recoveries == 0, "transient fault must not trigger a restore");
    anyhow::ensure!(
        h.params_flat() == reference.params_flat(),
        "retried run diverged from the unfaulted reference"
    );
    println!(
        "transient: absorbed by {} retry(ies), no restore — bit-identical",
        rep.fault.retries
    );

    // 3. A lost rank mid-run: abort, restore from the last snapshot,
    //    replay. Traced, so the export carries the Fault lane.
    let target_step = steps.min(3);
    let lost = FaultPlan {
        site: FaultSite::StageExec,
        kind: FaultKind::LostRank,
        rank: 1 % sp,
        at_op: (target_step - 1) * n_layers as u64,
        seed: 13,
    };
    let mut h = ChaosHarness::new(ChaosConfig {
        trace: true,
        fault_plan: Some(lost),
        ..base.clone()
    })?;
    let opts = ResilienceOptions {
        snapshot_every: k,
        ..ResilienceOptions::new(snap_dir.join("lost.alst"))
    };
    let rep = run_resilient(&mut h, steps, &opts)?;
    anyhow::ensure!(
        rep.recoveries == 1,
        "lost rank must trigger exactly one restore, got {}",
        rep.recoveries
    );
    anyhow::ensure!(
        h.params_flat() == reference.params_flat(),
        "recovered run diverged from the unfaulted reference"
    );
    anyhow::ensure!(
        h.host_bytes() == 0 && h.device_bytes() == 0,
        "ledgers must balance after recovery (host {}, device {})",
        h.host_bytes(),
        h.device_bytes()
    );
    println!(
        "lost rank: {} restore at step {target_step} — bit-identical, ledgers clean",
        rep.recoveries
    );

    // 4. `--transport socket`: the same contract over REAL faults. A
    //    clean run over spawned rank processes must match the local
    //    reference bit-for-bit; then the victim's worker is SIGKILLed
    //    mid-run (a frame-count fuse measured from the clean run) and the
    //    supervisor must detect the death on the wire, restore once, and
    //    land on identical parameters with balanced ledgers. The traced
    //    export and the CSV then come from the real-fault run.
    let (h, rep) = if transport == TransportKind::Socket {
        let sopts = SocketOptions {
            heartbeat_interval: Duration::from_millis(25),
            heartbeat_timeout: Duration::from_secs(2),
            ..Default::default()
        };
        let socket_base = ChaosConfig {
            transport: TransportKind::Socket,
            socket: Some(sopts.clone()),
            op_timeout: Some(Duration::from_secs(5)),
            ..base
        };
        let mut clean = ChaosHarness::new(socket_base.clone())?;
        let opts = ResilienceOptions {
            snapshot_every: k,
            ..ResilienceOptions::new(snap_dir.join("socket-ref.alst"))
        };
        let clean_rep = run_resilient(&mut clean, steps, &opts)?;
        anyhow::ensure!(
            clean_rep.recoveries == 0,
            "clean socket run must not restore, got {}",
            clean_rep.recoveries
        );
        anyhow::ensure!(
            clean.params_flat() == reference.params_flat(),
            "socket transport diverged from the local reference"
        );
        let victim = 1 % sp;
        let st = clean.socket_transport().expect("socket harness").clone();
        let total = st.frames_via(victim);
        anyhow::ensure!(total >= steps, "no frames relayed via rank {victim}");
        // Blow the fuse halfway through the target step's frame budget:
        // the worker dies mid-collective, not between steps.
        let per_step = total / steps;
        let after = per_step * (target_step - 1) + per_step / 2;
        println!(
            "socket: clean run bit-identical ({total} frames via rank {victim}); \
             SIGKILL its worker after {after}"
        );
        let mut hk = ChaosHarness::new(ChaosConfig {
            trace: true,
            socket: Some(SocketOptions {
                failure: Some(WorkerFailure {
                    rank: victim,
                    mode: WorkerFailMode::Kill,
                    after,
                }),
                ..sopts
            }),
            ..socket_base
        })?;
        let opts = ResilienceOptions {
            snapshot_every: k,
            ..ResilienceOptions::new(snap_dir.join("socket-lost.alst"))
        };
        let rep = run_resilient(&mut hk, steps, &opts)?;
        anyhow::ensure!(
            rep.recoveries == 1,
            "SIGKILLed worker must trigger exactly one restore, got {}",
            rep.recoveries
        );
        anyhow::ensure!(
            hk.params_flat() == reference.params_flat(),
            "socket recovery diverged from the unfaulted reference"
        );
        anyhow::ensure!(
            hk.host_bytes() == 0 && hk.device_bytes() == 0,
            "ledgers must balance after socket recovery (host {}, device {})",
            hk.host_bytes(),
            hk.device_bytes()
        );
        println!("socket lost rank: 1 restore — bit-identical, ledgers clean");
        (hk, rep)
    } else {
        (h, rep)
    };

    let spans = h.tracer().drain();
    let fault_spans = spans.iter().filter(|s| s.cat == Category::Fault).count();
    anyhow::ensure!(
        fault_spans >= 2,
        "expected snapshot/restore spans on the Fault lane, got {fault_spans}"
    );
    let doc = alst::obs::trace_events(&spans, &[]);
    alst::obs::validate_trace(&doc).context("chaos trace failed validation")?;
    std::fs::write(&out, doc.to_string_pretty())
        .with_context(|| format!("writing {out}"))?;
    println!("wrote {out} ({} spans, {fault_spans} on the fault lane)", spans.len());

    if let Some(path) = args.get("csv") {
        let mut log = RunLog::default();
        for m in rep.metrics {
            log.push(m);
        }
        log.write_csv(std::path::Path::new(path))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    for (name, table) in alst::paper::all_tables() {
        table.print();
        std::fs::write(out_dir.join(format!("{name}.csv")), table.to_csv())?;
    }
    println!("\nCSV written to {}", out_dir.display());
    Ok(())
}
