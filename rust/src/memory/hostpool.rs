//! Host (CPU) memory pool for offload traffic.
//!
//! The paper's §5.3.2/§5.3.3 finding — 1.9 TiB of node RAM becomes the
//! binding constraint for Llama-70B/Qwen-32B long-sequence configs — falls
//! out of this pool's capacity check.

use anyhow::Result;

#[derive(Debug, Clone)]
pub struct HostPool {
    capacity: u64,
    current: u64,
    peak: u64,
    /// Frees that exceeded `current` (each one is an accounting bug in the
    /// caller: bytes freed that were never alloc'd here). `free` saturates
    /// instead of wrapping — a wrapped `current` near u64::MAX would make
    /// every later capacity check fail — but the mismatch is counted so
    /// tests can assert it never happens on the offload paths.
    underflow_events: u64,
}

impl HostPool {
    pub fn new(capacity: u64) -> HostPool {
        HostPool { capacity, current: 0, peak: 0, underflow_events: 0 }
    }

    /// The paper's per-node budget: 1.9 TiB shared by `gpus_per_node`
    /// ranks; we model a per-rank slice.
    pub fn per_rank(node_capacity: u64, gpus_per_node: usize) -> HostPool {
        HostPool::new(node_capacity / gpus_per_node as u64)
    }

    pub fn alloc(&mut self, bytes: u64) -> Result<()> {
        // checked_add: a pathological `bytes` near u64::MAX must report
        // exhaustion, not wrap the capacity comparison around to success
        let want = self.current.checked_add(bytes);
        anyhow::ensure!(
            want.is_some_and(|w| w <= self.capacity),
            "host memory exhausted: {} + {} MiB > {} MiB (paper §5.3.2: CPU \
             RAM becomes the limiting factor)",
            self.current >> 20,
            bytes >> 20,
            self.capacity >> 20
        );
        self.current = want.unwrap();
        self.peak = self.peak.max(self.current);
        Ok(())
    }

    pub fn free(&mut self, bytes: u64) {
        if bytes > self.current {
            debug_assert!(false, "host pool free underflow: {} > {}", bytes, self.current);
            self.underflow_events += 1;
        }
        self.current = self.current.saturating_sub(bytes);
    }

    /// Number of `free` calls that exceeded the live byte count (0 on any
    /// correct alloc/free pairing; see the field doc).
    pub fn underflow_events(&self) -> u64 {
        self.underflow_events
    }

    pub fn current(&self) -> u64 {
        self.current
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enforces_capacity() {
        let mut p = HostPool::new(100);
        p.alloc(60).unwrap();
        assert!(p.alloc(50).is_err());
        p.free(30);
        p.alloc(50).unwrap();
        assert_eq!(p.peak(), 80);
    }

    #[test]
    fn per_rank_splits_node_budget() {
        let p = HostPool::per_rank(1 << 40, 8);
        assert_eq!(p.capacity(), (1 << 40) / 8);
    }

    #[test]
    fn over_free_saturates_and_is_counted() {
        let mut p = HostPool::new(100);
        p.alloc(40).unwrap();
        // Freeing more than is live must clamp to zero (not wrap to a
        // near-u64::MAX `current` that poisons every later alloc) and the
        // mismatch must be observable.
        if cfg!(debug_assertions) {
            // debug builds trip the debug_assert instead; exercise the
            // release-path semantics via catch_unwind
            let r = std::panic::catch_unwind(move || {
                p.free(100);
            });
            assert!(r.is_err(), "debug_assert fires on underflow");
        } else {
            p.free(100);
            assert_eq!(p.current(), 0);
            assert_eq!(p.underflow_events(), 1);
            p.alloc(100).unwrap();
            assert_eq!(p.current(), 100);
        }
        // Exact pairing never counts an underflow in either build.
        let mut q = HostPool::new(100);
        q.alloc(40).unwrap();
        q.free(40);
        assert_eq!(q.underflow_events(), 0);
        assert_eq!(q.current(), 0);
    }

    #[test]
    fn overflow_sized_alloc_reports_exhaustion_not_wraparound() {
        let mut p = HostPool::new(u64::MAX);
        p.alloc(16).unwrap();
        // current + bytes would wrap past zero; must be an error, and the
        // pool must be left untouched
        let err = p.alloc(u64::MAX).unwrap_err();
        assert!(format!("{err:#}").contains("host memory exhausted"));
        assert_eq!(p.current(), 16);
        assert_eq!(p.peak(), 16);
        // exactly filling the remaining capacity still succeeds
        p.alloc(u64::MAX - 16).unwrap();
        assert_eq!(p.current(), u64::MAX);
    }
}
