//! The `ParallelPlan` trait: how an SP group moves attention data.
//!
//! ALST's original protocol (Ulysses) relayouts seq<->head with
//! all-to-alls and runs dense per-head attention; Blockwise RingAttention
//! (Liu et al. 2024) instead rotates KV blocks rank-to-rank while each
//! rank folds online-softmax partials. Both are expressed against this
//! trait so the trainer, estimator, roofline, and equivalence suite are
//! plan-generic, and hybrid plans (Ulysses intra-node, ring inter-node)
//! can slot in later without touching callers.
//!
//! ## Summation-order contract
//!
//! Floating-point attention is only reproducible modulo a stated
//! reduction order. The contract pinned by the equivalence suite:
//!
//! * Within one KV block, keys fold in ascending global key order
//!   (two-pass: block max first, then exp/accumulate ascending).
//! * The dense reference is one block covering the whole sequence, so a
//!   single-block plan invocation (`sp == 1`, or ring's own-shard hop)
//!   is **bit-identical** to the reference by construction.
//! * Across blocks, ring rank `r` folds blocks in *descending* global
//!   block order (`r, r-1, …, 0` — the causal-skip rotation's arrival
//!   order), merging running `(m, l, acc)` stats by `exp(m_old - m_new)`
//!   rescaling. Cross-block merges round differently than the one-block
//!   reference, so `sp > 1` parity is tolerance-based, not bitwise.
//! * In backward, a KV block's `dk`/`dv` partials accumulate q-rank
//!   contributions in ascending global query order (the block visits
//!   ranks `b, b+1, …, sp-1`), matching the reference's ascending query
//!   loop; `dq` accumulates locally in the forward's block order.

use anyhow::Result;

use crate::collectives::Group;
use crate::config::PlanKind;
use crate::runtime::tensor::{HostTensor, ScratchArena};

/// Attention-problem geometry shared by every plan. `n_q` / `n_kv` are
/// global head counts (GQA when `n_kv < n_q`), `head_dim` the per-head
/// width.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnShape {
    pub n_q: usize,
    pub n_kv: usize,
    pub head_dim: usize,
}

impl AttnShape {
    pub fn new(n_q: usize, n_kv: usize, head_dim: usize) -> AttnShape {
        assert!(n_q >= 1 && n_kv >= 1 && head_dim >= 1);
        assert_eq!(n_q % n_kv, 0, "GQA needs n_q divisible by n_kv");
        AttnShape { n_q, n_kv, head_dim }
    }

    /// Query heads per KV head (1 for MHA, >1 for GQA/MQA).
    pub fn q_group(&self) -> usize {
        self.n_q / self.n_kv
    }

    pub fn scale(&self) -> f32 {
        1.0 / (self.head_dim as f32).sqrt()
    }
}

/// What `attention_forward` saves for `attention_backward`. Each plan
/// saves what its real protocol would keep resident: Ulysses recomputes
/// everything from the relayout replay (activation-checkpoint style),
/// ring keeps the per-row log-sum-exp and output so backward can rebuild
/// softmax probabilities without a second forward rotation.
pub enum PlanSaved {
    Ulysses,
    Ring {
        /// Per rank: `[shard_rows, n_q, head_dim]` forward output.
        o: Vec<HostTensor>,
        /// Per rank: `[shard_rows, n_q]` log-sum-exp (`m + ln l`).
        lse: Vec<HostTensor>,
    },
}

impl PlanSaved {
    /// Return any saved buffers to the arena pool.
    pub fn recycle(self, arena: &ScratchArena) {
        match self {
            PlanSaved::Ulysses => {}
            PlanSaved::Ring { o, lse } => {
                arena.recycle_all(o);
                arena.recycle_all(lse);
            }
        }
    }
}

/// A sequence-parallel attention protocol. Inputs and outputs are
/// seq-sharded host tensors, one per rank, each `[shard_rows, heads,
/// head_dim]`; `cu_seqlens` is the packed segment prefix over the
/// *global* sequence and drives segment-aware causal masking.
pub trait ParallelPlan: Send + Sync {
    fn kind(&self) -> PlanKind;

    fn name(&self) -> &'static str {
        self.kind().as_str()
    }

    /// Can this plan run `(n_q, n_kv)` heads over `sp` ranks? Errors are
    /// actionable ("sp=16 > 8 heads: use ring plan"), never silent.
    fn validate(&self, n_q: usize, n_kv: usize, sp: usize) -> Result<()>;

    /// Exact wire bytes this plan's forward+backward moves per layer (the
    /// closed form the `CommStats` ledger is pinned against).
    fn comm_bytes_per_layer(
        &self,
        seq: usize,
        shape: &AttnShape,
        sp: usize,
        elem_bytes: usize,
    ) -> u64;

    /// Sequence-parallel attention forward: per-rank `[ssh, n_q, d]`
    /// outputs plus whatever this plan saves for backward.
    #[allow(clippy::too_many_arguments)]
    fn attention_forward(
        &self,
        group: &Group,
        arena: &ScratchArena,
        q: &[HostTensor],
        k: &[HostTensor],
        v: &[HostTensor],
        shape: &AttnShape,
        cu_seqlens: &[i32],
    ) -> Result<(Vec<HostTensor>, PlanSaved)>;

    /// Backward: per-rank seq-sharded `(d_q, d_k, d_v)` from the upstream
    /// `d_o` and the forward's saved state.
    #[allow(clippy::too_many_arguments)]
    fn attention_backward(
        &self,
        group: &Group,
        arena: &ScratchArena,
        q: &[HostTensor],
        k: &[HostTensor],
        v: &[HostTensor],
        d_o: &[HostTensor],
        saved: &PlanSaved,
        shape: &AttnShape,
        cu_seqlens: &[i32],
    ) -> Result<(Vec<HostTensor>, Vec<HostTensor>, Vec<HostTensor>)>;
}

/// Factory keyed by the config enum.
pub fn plan_for(kind: PlanKind) -> Box<dyn ParallelPlan> {
    match kind {
        PlanKind::Ulysses => Box::new(super::ulysses::UlyssesPlan),
        PlanKind::Ring => Box::new(super::ring::RingPlan::default()),
    }
}

/// Segment id per global token position, from the packed `cu_seqlens`
/// prefix (`[0, d0, d0+d1, …, seq]`).
pub fn seg_ids_from_cu(cu: &[i32], seq: usize) -> Vec<usize> {
    assert!(cu.len() >= 2 && cu[0] == 0, "cu_seqlens must start at 0");
    assert_eq!(
        *cu.last().unwrap() as usize,
        seq,
        "cu_seqlens must end at the sequence length"
    );
    let mut seg = vec![0usize; seq];
    for (s, w) in cu.windows(2).enumerate() {
        assert!(w[1] > w[0], "cu_seqlens must be strictly increasing");
        for t in &mut seg[w[0] as usize..w[1] as usize] {
            *t = s;
        }
    }
    seg
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Fold one KV block into a block of query rows' online-softmax running
/// state `(m, l, acc)`. Two passes per (row, head): all causally-allowed
/// scores into `scores` scratch with the block max, then exp/accumulate
/// in ascending key order, rescaling the running state by
/// `exp(m_old - m_new)`. `exp(-inf - m_new) == 0` makes the first fold a
/// plain overwrite, and a block with no allowed keys for a row leaves
/// that row's state untouched (avoiding `-inf - -inf` NaNs).
///
/// Layouts: `q` is `[q_rows, n_q, d]` starting at global row `q_base`;
/// `k`/`v` are `[kv_rows, n_kv, d]` starting at `kv_base`; `m`/`l` are
/// `[q_rows * n_q]`, `acc` `[q_rows * n_q, d]`, `scores` scratch of at
/// least `kv_rows`.
#[allow(clippy::too_many_arguments)]
pub fn attn_block_fold(
    q: &[f32],
    q_rows: usize,
    q_base: usize,
    k: &[f32],
    v: &[f32],
    kv_rows: usize,
    kv_base: usize,
    shape: &AttnShape,
    seg: &[usize],
    m: &mut [f32],
    l: &mut [f32],
    acc: &mut [f32],
    scores: &mut [f32],
) {
    let (nq, nkv, d) = (shape.n_q, shape.n_kv, shape.head_dim);
    let group = shape.q_group();
    let scale = shape.scale();
    for i in 0..q_rows {
        let gi = q_base + i;
        for h in 0..nq {
            let kvh = h / group;
            let idx = i * nq + h;
            let qrow = &q[idx * d..(idx + 1) * d];
            let mut bm = f32::NEG_INFINITY;
            for j in 0..kv_rows {
                let gj = kv_base + j;
                let s = if gj <= gi && seg[gj] == seg[gi] {
                    scale * dot(qrow, &k[(j * nkv + kvh) * d..(j * nkv + kvh + 1) * d])
                } else {
                    f32::NEG_INFINITY
                };
                scores[j] = s;
                if s > bm {
                    bm = s;
                }
            }
            if bm == f32::NEG_INFINITY {
                continue;
            }
            let m_new = m[idx].max(bm);
            let c = (m[idx] - m_new).exp();
            m[idx] = m_new;
            l[idx] *= c;
            let arow = &mut acc[idx * d..(idx + 1) * d];
            if c != 1.0 {
                for a in arow.iter_mut() {
                    *a *= c;
                }
            }
            for j in 0..kv_rows {
                if scores[j] == f32::NEG_INFINITY {
                    continue;
                }
                let e = (scores[j] - m_new).exp();
                l[idx] += e;
                let vrow = &v[(j * nkv + kvh) * d..(j * nkv + kvh + 1) * d];
                for (a, &vv) in arow.iter_mut().zip(vrow) {
                    *a += e * vv;
                }
            }
        }
    }
}

/// Turn completed running stats into the attention output (in place in
/// `acc`) and the per-row log-sum-exp. Every row must have folded at
/// least its own key (causal self-attention guarantees this when the
/// row's own block was processed).
pub fn finalize_online_softmax(m: &[f32], l: &[f32], acc: &mut [f32], lse: &mut [f32], d: usize) {
    for (idx, (&mi, &li)) in m.iter().zip(l).enumerate() {
        assert!(li > 0.0, "attention row {} folded no keys", idx);
        let inv = 1.0 / li;
        for a in &mut acc[idx * d..(idx + 1) * d] {
            *a *= inv;
        }
        lse[idx] = mi + li.ln();
    }
}

/// Backward fold of one KV block: accumulate `dq` for the query rows and
/// `dk`/`dv` for the block, given the forward's per-row `lse` and output
/// `o`. Standard flash-style backward: `D_i = dO·O`, `p = exp(z - lse)`,
/// `dv += p dO`, `dz = p (dO·v - D_i)`, `dq += dz·scale·k`,
/// `dk += dz·scale·q`. Query heads fold into their shared GQA KV head in
/// ascending q-head order.
#[allow(clippy::too_many_arguments)]
pub fn attn_block_bwd_fold(
    q: &[f32],
    d_o: &[f32],
    o: &[f32],
    lse: &[f32],
    q_rows: usize,
    q_base: usize,
    k: &[f32],
    v: &[f32],
    kv_rows: usize,
    kv_base: usize,
    shape: &AttnShape,
    seg: &[usize],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    let (nq, nkv, d) = (shape.n_q, shape.n_kv, shape.head_dim);
    let group = shape.q_group();
    let scale = shape.scale();
    for i in 0..q_rows {
        let gi = q_base + i;
        for h in 0..nq {
            let kvh = h / group;
            let idx = i * nq + h;
            let qrow = &q[idx * d..(idx + 1) * d];
            let dorow = &d_o[idx * d..(idx + 1) * d];
            let orow = &o[idx * d..(idx + 1) * d];
            let di = dot(dorow, orow);
            let lse_i = lse[idx];
            for j in 0..kv_rows {
                let gj = kv_base + j;
                if gj > gi || seg[gj] != seg[gi] {
                    continue;
                }
                let kv_off = (j * nkv + kvh) * d;
                let krow = &k[kv_off..kv_off + d];
                let vrow = &v[kv_off..kv_off + d];
                let z = scale * dot(qrow, krow);
                let p = (z - lse_i).exp();
                let dp = dot(dorow, vrow);
                let dz = p * (dp - di);
                for t in 0..d {
                    dq[idx * d + t] += dz * scale * krow[t];
                    dk[kv_off + t] += dz * scale * qrow[t];
                    dv[kv_off + t] += p * dorow[t];
                }
            }
        }
    }
}

/// The dense reference: segment-aware causal attention over the whole
/// sequence as a single KV block. Returns `([seq, n_q, d]` output,
/// `[seq, n_q]` log-sum-exp)`; both come from the arena.
pub fn dense_attention(
    q: &HostTensor,
    k: &HostTensor,
    v: &HostTensor,
    shape: &AttnShape,
    cu: &[i32],
    arena: &ScratchArena,
) -> Result<(HostTensor, HostTensor)> {
    let seq = q.shape()[0];
    let seg = seg_ids_from_cu(cu, seq);
    let (qd, kd, vd) = (q.as_f32()?, k.as_f32()?, v.as_f32()?);
    let n = seq * shape.n_q;
    let mut m = arena.take_f32(n);
    m.fill(f32::NEG_INFINITY);
    let mut l = arena.take_f32(n);
    l.fill(0.0);
    let mut acc = arena.take_f32(n * shape.head_dim);
    acc.fill(0.0);
    let mut scores = arena.take_f32(seq);
    attn_block_fold(qd, seq, 0, kd, vd, seq, 0, shape, &seg, &mut m, &mut l, &mut acc, &mut scores);
    let mut lse = arena.take_f32(n);
    finalize_online_softmax(&m, &l, &mut acc, &mut lse, shape.head_dim);
    arena.recycle_f32(m);
    arena.recycle_f32(l);
    arena.recycle_f32(scores);
    Ok((
        HostTensor::f32(vec![seq, shape.n_q, shape.head_dim], acc),
        HostTensor::f32(vec![seq, shape.n_q], lse),
    ))
}

/// Dense reference backward (single full-range block). Returns
/// `(d_q, d_k, d_v)` with the input layouts.
#[allow(clippy::too_many_arguments)]
pub fn dense_attention_bwd(
    q: &HostTensor,
    k: &HostTensor,
    v: &HostTensor,
    o: &HostTensor,
    lse: &HostTensor,
    d_o: &HostTensor,
    shape: &AttnShape,
    cu: &[i32],
    arena: &ScratchArena,
) -> Result<(HostTensor, HostTensor, HostTensor)> {
    let seq = q.shape()[0];
    let seg = seg_ids_from_cu(cu, seq);
    let mut dq = arena.take_f32(seq * shape.n_q * shape.head_dim);
    dq.fill(0.0);
    let mut dk = arena.take_f32(seq * shape.n_kv * shape.head_dim);
    dk.fill(0.0);
    let mut dv = arena.take_f32(seq * shape.n_kv * shape.head_dim);
    dv.fill(0.0);
    attn_block_bwd_fold(
        q.as_f32()?,
        d_o.as_f32()?,
        o.as_f32()?,
        lse.as_f32()?,
        seq,
        0,
        k.as_f32()?,
        v.as_f32()?,
        seq,
        0,
        shape,
        &seg,
        &mut dq,
        &mut dk,
        &mut dv,
    );
    Ok((
        HostTensor::f32(vec![seq, shape.n_q, shape.head_dim], dq),
        HostTensor::f32(vec![seq, shape.n_kv, shape.head_dim], dk),
        HostTensor::f32(vec![seq, shape.n_kv, shape.head_dim], dv),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random fill (tests must not use RNG state).
    fn fill(t: &mut [f32], seed: u64) {
        let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        for x in t.iter_mut() {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            *x = ((s >> 33) as f32 / (1u64 << 31) as f32) - 0.5;
        }
    }

    fn rand_t(shape: Vec<usize>, seed: u64) -> HostTensor {
        let n: usize = shape.iter().product();
        let mut d = vec![0.0f32; n];
        fill(&mut d, seed);
        HostTensor::f32(shape, d)
    }

    #[test]
    fn seg_ids_expand_cu_prefix() {
        assert_eq!(seg_ids_from_cu(&[0, 3, 5], 5), vec![0, 0, 0, 1, 1]);
        assert_eq!(seg_ids_from_cu(&[0, 4], 4), vec![0, 0, 0, 0]);
    }

    #[test]
    #[should_panic(expected = "end at the sequence length")]
    fn seg_ids_reject_short_cu() {
        seg_ids_from_cu(&[0, 3], 5);
    }

    #[test]
    fn dense_first_token_attends_only_itself() {
        let shape = AttnShape::new(2, 2, 4);
        let arena = ScratchArena::new();
        let q = rand_t(vec![6, 2, 4], 1);
        let k = rand_t(vec![6, 2, 4], 2);
        let v = rand_t(vec![6, 2, 4], 3);
        let (o, _lse) = dense_attention(&q, &k, &v, &shape, &[0, 6], &arena).unwrap();
        // softmax over a single key is exactly that key's value row
        assert_eq!(o.as_f32().unwrap()[..8], v.as_f32().unwrap()[..8]);
    }

    #[test]
    fn dense_masks_across_segment_boundaries() {
        let shape = AttnShape::new(1, 1, 2);
        let arena = ScratchArena::new();
        let q = rand_t(vec![4, 1, 2], 4);
        let k = rand_t(vec![4, 1, 2], 5);
        let v = rand_t(vec![4, 1, 2], 6);
        // packed [0,2,4]: token 2 starts doc 1 and must ignore doc 0
        let (o, _) = dense_attention(&q, &k, &v, &shape, &[0, 2, 4], &arena).unwrap();
        assert_eq!(o.as_f32().unwrap()[4..6], v.as_f32().unwrap()[4..6]);
        // and differs from the unpacked result for the same row
        let (o_full, _) = dense_attention(&q, &k, &v, &shape, &[0, 4], &arena).unwrap();
        assert_ne!(o.as_f32().unwrap()[4..6], o_full.as_f32().unwrap()[4..6]);
    }

    #[test]
    fn uniform_values_pass_through_softmax() {
        // When every value row is the same vector, any softmax mix of
        // them returns that vector (up to rounding).
        let shape = AttnShape::new(2, 1, 3);
        let arena = ScratchArena::new();
        let q = rand_t(vec![5, 2, 3], 7);
        let k = rand_t(vec![5, 1, 3], 8);
        let v = HostTensor::f32(vec![5, 1, 3], [2.0f32, -1.0, 0.5].repeat(5));
        let (o, _) = dense_attention(&q, &k, &v, &shape, &[0, 5], &arena).unwrap();
        for row in o.as_f32().unwrap().chunks(3) {
            assert!((row[0] - 2.0).abs() < 1e-5);
            assert!((row[1] + 1.0).abs() < 1e-5);
            assert!((row[2] - 0.5).abs() < 1e-5);
        }
    }

    #[test]
    fn dense_backward_matches_finite_differences() {
        let shape = AttnShape::new(2, 1, 3);
        let cu = [0, 3, 5];
        let arena = ScratchArena::new();
        let q = rand_t(vec![5, 2, 3], 11);
        let k = rand_t(vec![5, 1, 3], 12);
        let v = rand_t(vec![5, 1, 3], 13);
        let w = rand_t(vec![5, 2, 3], 14); // loss = sum(o * w) => d_o = w
        let loss = |q: &HostTensor, k: &HostTensor, v: &HostTensor| -> f64 {
            let (o, _) = dense_attention(q, k, v, &shape, &cu, &arena).unwrap();
            o.as_f32()
                .unwrap()
                .iter()
                .zip(w.as_f32().unwrap())
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum()
        };
        let (o, lse) = dense_attention(&q, &k, &v, &shape, &cu, &arena).unwrap();
        let (dq, dk, dv) =
            dense_attention_bwd(&q, &k, &v, &o, &lse, &w, &shape, &cu, &arena).unwrap();
        let eps = 1e-2f32;
        let check = |t: &HostTensor, g: &HostTensor, which: usize| {
            let n = t.as_f32().unwrap().len();
            for idx in (0..n).step_by(7) {
                let mut bumped = t.as_f32().unwrap().to_vec();
                bumped[idx] += eps;
                let tp = HostTensor::f32(t.shape().to_vec(), bumped.clone());
                bumped[idx] -= 2.0 * eps;
                let tm = HostTensor::f32(t.shape().to_vec(), bumped);
                let (lp, lm) = match which {
                    0 => (loss(&tp, &k, &v), loss(&tm, &k, &v)),
                    1 => (loss(&q, &tp, &v), loss(&q, &tm, &v)),
                    _ => (loss(&q, &k, &tp), loss(&q, &k, &tm)),
                };
                let num = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let ana = g.as_f32().unwrap()[idx];
                assert!(
                    (num - ana).abs() < 2e-2 * (1.0 + ana.abs()),
                    "grad {} idx {}: numeric {} vs analytic {}",
                    which,
                    idx,
                    num,
                    ana
                );
            }
        };
        check(&q, &dq, 0);
        check(&k, &dk, 1);
        check(&v, &dv, 2);
    }

    #[test]
    fn plan_factory_returns_matching_kinds() {
        assert_eq!(plan_for(PlanKind::Ulysses).kind(), PlanKind::Ulysses);
        assert_eq!(plan_for(PlanKind::Ring).kind(), PlanKind::Ring);
        assert_eq!(plan_for(PlanKind::Ring).name(), "ring");
    }
}
