//! Figures 1, 8, 9, 10, 12 + Tables 2-4: maximum achievable sequence
//! length per (model, GPU count, feature set), from the calibrated H100
//! memory simulator driven by the coordinator's shard/tile decisions.
//!
//!     cargo run --release --example max_seqlen_search
//!     cargo run --release --example max_seqlen_search -- --fig2

use alst::config::preset;
use alst::paper;
use alst::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.flag("fig2") {
        paper::fig2_activation_memory().print();
        return Ok(());
    }

    let m8 = preset("llama3-8b").unwrap();

    // Figure 1 / 12 + Tables 2-4: the headline baseline-vs-ALST bars.
    let t = paper::tables_2_3_4(m8);
    t.print();
    println!(
        "\npaper reference: 16x (1 GPU), 116x (8 GPUs), 469x (32 GPUs) — \
         Llama-8B, Tables 2-4 / Figure 12"
    );

    // Figures 8/9/10: per-model GPU scaling.
    paper::fig_8_9_10("llama3-8b", &[1, 2, 4, 8, 16, 32]).print();
    println!("paper reference (Fig 8): 500K @ 1 GPU, 3.7M @ 8, 15M @ 32");
    paper::fig_8_9_10("llama3-70b", &[16, 32, 64]).print();
    println!("paper reference (Fig 9): host-RAM-bound at 4+ nodes (1.9 TiB)");
    paper::fig_8_9_10("qwen3-32b", &[1, 8, 16, 32, 64]).print();
    println!("paper reference (Fig 10): 1 GPU needs weights offload; host-RAM caps big configs");

    // The memory-plot figures.
    paper::fig2_activation_memory().print();
    paper::fig3_tiled_loss().print();
    println!("paper reference (Fig 3): 50 -> 36 GiB peak at 16K (28% whole-model reduction)");
    paper::fig4_tiled_mlp().print();
    println!("paper reference (Fig 4): ~10x on the 256K x 4096 single-layer example, 63 shards");
    paper::fig7_offload_hill().print();
    println!("paper reference (Fig 7): offload flattens the per-layer checkpoint 'hill'");
    Ok(())
}
