//! Best-fit-decreasing bin-packing of variable-length documents into
//! fixed-capacity sequences (the paper's assumed data recipe: "multiple
//! samples packed into one long sequence", §3.4).
//!
//! Documents are sorted longest-first (ties broken by id for
//! determinism) and each is placed in the open pack with the SMALLEST
//! remaining capacity that still fits, found through an ordered
//! free-capacity index (`BTreeMap` keyed by remaining space) — O(n log n)
//! total instead of the first-fit linear scan's O(n·packs), at the same
//! 11/9·OPT+1 worst-case guarantee. The historical linear first-fit
//! survives as `pack_first_fit_reference`; the property suite asserts
//! best-fit never packs worse on the same corpus.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::Result;

/// One variable-length sample with a stable provenance id (used by the
/// per-document loss reporting in `metrics`).
#[derive(Debug, Clone, PartialEq)]
pub struct Document {
    pub id: u64,
    pub tokens: Vec<i32>,
}

impl Document {
    pub fn new(id: u64, tokens: Vec<i32>) -> Document {
        Document { id, tokens }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// One packed bin: documents laid back to back, `capacity - used()`
/// trailing tokens of padding once materialized as a `PackedSequence`.
#[derive(Debug, Clone, PartialEq)]
pub struct Pack {
    pub capacity: usize,
    pub docs: Vec<Document>,
}

impl Pack {
    pub fn used(&self) -> usize {
        self.docs.iter().map(Document::len).sum()
    }

    pub fn waste(&self) -> usize {
        self.capacity - self.used()
    }

    pub fn remaining(&self) -> usize {
        self.waste()
    }
}

/// Aggregate packing efficiency/waste accounting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PackingStats {
    pub n_docs: usize,
    pub n_packs: usize,
    pub capacity: usize,
    /// Real (document) tokens across all packs.
    pub total_tokens: usize,
    /// Padding tokens across all packs.
    pub padded_tokens: usize,
}

impl PackingStats {
    pub fn from_packs(packs: &[Pack]) -> PackingStats {
        let mut s = PackingStats::default();
        for p in packs {
            s.n_docs += p.docs.len();
            s.n_packs += 1;
            s.capacity = p.capacity;
            s.total_tokens += p.used();
            s.padded_tokens += p.waste();
        }
        s
    }

    /// Fraction of emitted tokens that are real documents (1.0 = no waste).
    pub fn efficiency(&self) -> f64 {
        let emitted = self.total_tokens + self.padded_tokens;
        if emitted == 0 {
            return 1.0;
        }
        self.total_tokens as f64 / emitted as f64
    }

    /// Packs the same corpus would need at one document per sequence —
    /// the naive padding baseline the bench compares against.
    pub fn naive_sequences(&self) -> usize {
        self.n_docs
    }

    pub fn merge(&mut self, other: &PackingStats) {
        self.n_docs += other.n_docs;
        self.n_packs += other.n_packs;
        self.capacity = self.capacity.max(other.capacity);
        self.total_tokens += other.total_tokens;
        self.padded_tokens += other.padded_tokens;
    }
}

fn validate_docs(docs: &[Document], capacity: usize) -> Result<()> {
    anyhow::ensure!(capacity > 0, "pack capacity must be positive");
    for d in docs {
        anyhow::ensure!(!d.is_empty(), "document {} is empty", d.id);
        anyhow::ensure!(
            d.len() <= capacity,
            "document {} has {} tokens > capacity {} (chunk it first)",
            d.id,
            d.len(),
            capacity
        );
    }
    Ok(())
}

fn sort_decreasing(mut docs: Vec<Document>) -> Vec<Document> {
    docs.sort_by(|a, b| b.len().cmp(&a.len()).then(a.id.cmp(&b.id)));
    docs
}

/// Best-fit-decreasing: sort by length descending (ties by id for
/// determinism), place each document in the open pack with the smallest
/// remaining capacity that fits (ties broken by lowest pack index). The
/// free-capacity index makes each placement O(log n).
///
/// Every document must be non-empty and no longer than `capacity`
/// (`PackedDataLoader` pre-chunks oversize documents before calling this).
///
/// (The name is historical — this entry point started as first-fit; see
/// `pack_first_fit_reference` for the original scan.)
pub fn pack_ffd(docs: Vec<Document>, capacity: usize) -> Result<Vec<Pack>> {
    validate_docs(&docs, capacity)?;
    let mut packs: Vec<Pack> = Vec::new();
    // remaining capacity -> open pack indices with exactly that much room
    let mut open: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
    for doc in sort_decreasing(docs) {
        let n = doc.len();
        // smallest remaining >= n; among equals, the lowest pack index
        let slot = open
            .range(n..)
            .next()
            .map(|(&rem, set)| (rem, *set.iter().next().expect("empty capacity class")));
        match slot {
            Some((rem, idx)) => {
                let class = open.get_mut(&rem).unwrap();
                class.remove(&idx);
                if class.is_empty() {
                    open.remove(&rem);
                }
                packs[idx].docs.push(doc);
                if rem - n > 0 {
                    open.entry(rem - n).or_default().insert(idx);
                }
            }
            None => {
                let idx = packs.len();
                packs.push(Pack { capacity, docs: vec![doc] });
                let rem = packs[idx].remaining();
                if rem > 0 {
                    open.entry(rem).or_default().insert(idx);
                }
            }
        }
    }
    Ok(packs)
}

/// The original first-fit-decreasing linear scan, kept as the reference
/// the property suite compares `pack_ffd` against (best-fit must never
/// produce more packs on the same corpus) and as the O(n·packs) baseline
/// for the packer bench.
pub fn pack_first_fit_reference(docs: Vec<Document>, capacity: usize) -> Result<Vec<Pack>> {
    validate_docs(&docs, capacity)?;
    let mut packs: Vec<Pack> = Vec::new();
    for doc in sort_decreasing(docs) {
        match packs.iter_mut().find(|p| p.remaining() >= doc.len()) {
            Some(p) => p.docs.push(doc),
            None => packs.push(Pack { capacity, docs: vec![doc] }),
        }
    }
    Ok(packs)
}

/// Split one oversize token stream into capacity-sized documents (the
/// long-document fallback: each chunk keeps the source id).
pub fn chunk_document(doc: Document, capacity: usize) -> Vec<Document> {
    if doc.len() <= capacity {
        return vec![doc];
    }
    doc.tokens
        .chunks(capacity)
        .map(|c| Document::new(doc.id, c.to_vec()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(id: u64, n: usize) -> Document {
        Document::new(id, vec![id as i32; n])
    }

    #[test]
    fn ffd_packs_the_classic_example() {
        // capacity 10; lengths 7,5,4,3,1 -> FFD: [7,3], [5,4,1] = 2 packs
        let packs = pack_ffd(
            vec![doc(0, 7), doc(1, 5), doc(2, 4), doc(3, 3), doc(4, 1)],
            10,
        )
        .unwrap();
        assert_eq!(packs.len(), 2);
        assert_eq!(packs[0].used(), 10);
        assert_eq!(packs[1].used(), 10);
        let stats = PackingStats::from_packs(&packs);
        assert_eq!(stats.efficiency(), 1.0);
        assert_eq!(stats.padded_tokens, 0);
    }

    #[test]
    fn ffd_is_deterministic_under_ties() {
        let a = pack_ffd(vec![doc(2, 4), doc(0, 4), doc(1, 4)], 8).unwrap();
        let b = pack_ffd(vec![doc(1, 4), doc(2, 4), doc(0, 4)], 8).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0].docs[0].id, 0); // ties broken by id
    }

    #[test]
    fn rejects_oversize_and_empty() {
        assert!(pack_ffd(vec![doc(0, 11)], 10).is_err());
        assert!(pack_ffd(vec![Document::new(0, vec![])], 10).is_err());
        assert!(pack_ffd(vec![], 0).is_err());
    }

    #[test]
    fn chunking_covers_all_tokens() {
        let d = Document::new(9, (0..23).collect());
        let chunks = chunk_document(d, 10);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks.iter().map(Document::len).sum::<usize>(), 23);
        let cat: Vec<i32> = chunks.iter().flat_map(|c| c.tokens.clone()).collect();
        assert_eq!(cat, (0..23).collect::<Vec<i32>>());
        assert!(chunks.iter().all(|c| c.id == 9));
    }

    #[test]
    fn best_fit_chooses_snuggest_pack() {
        // capacity 10, lengths 6,5,4,3: 6->p0(rem 4), 5->p1(rem 5),
        // 4 -> snuggest fit p0 (rem 4, not p1's rem 5), 3 -> p1.
        let packs =
            pack_ffd(vec![doc(0, 6), doc(1, 5), doc(2, 4), doc(3, 3)], 10).unwrap();
        assert_eq!(packs.len(), 2);
        assert_eq!(packs[0].used(), 10);
        assert_eq!(packs[1].used(), 8);
        assert_eq!(packs[0].docs.iter().map(|d| d.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(packs[1].docs.iter().map(|d| d.id).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn best_fit_matches_reference_on_the_classic_example() {
        let mk = || vec![doc(0, 7), doc(1, 5), doc(2, 4), doc(3, 3), doc(4, 1)];
        assert_eq!(pack_ffd(mk(), 10).unwrap(), pack_first_fit_reference(mk(), 10).unwrap());
    }

    #[test]
    fn stats_account_waste() {
        let packs = pack_ffd(vec![doc(0, 6), doc(1, 6)], 10).unwrap();
        assert_eq!(packs.len(), 2);
        let s = PackingStats::from_packs(&packs);
        assert_eq!(s.total_tokens, 12);
        assert_eq!(s.padded_tokens, 8);
        assert!((s.efficiency() - 0.6).abs() < 1e-12);
        assert_eq!(s.naive_sequences(), 2);
    }
}
