//! Blockwise RingAttention plan (Liu et al. 2024, PAPERS.md): KV blocks
//! rotate rank-to-rank over `Group::send_recv` while every rank folds
//! online-softmax partials for its own query shard. No head bound: `sp`
//! may exceed `n_heads`, which Ulysses cannot do.
//!
//! ## Causal-skip schedule
//!
//! Block `b` (rank `b`'s KV shard) is fully masked for every query rank
//! `< b`, so it never travels there: at hop `t`, rank `r` holds block
//! `r - t` (nothing once `r < t`), and the transfer into hop `t+1` only
//! has ranks `t..sp-1` sending to their `+1` neighbor. Each block's last
//! stop is rank `sp-1`. This halves wire traffic versus the full
//! rotation: per layer the forward moves `(sp-1)/sp * KV` bytes per rank
//! (vs the full rotation's `2(sp-1)/sp` priced in `perf/roofline.rs` —
//! both forms are exposed there), and in total
//! `sp(sp-1)/2` block hops = `(sp-1) * seq * n_kv * d` elements.
//!
//! ## Overlap model
//!
//! Hop `t+1`'s transfer runs on a scoped worker thread while the caller
//! folds hop `t`'s blocks — the offload engine's worker-stream pattern on
//! the rank-to-rank axis. The time the caller then blocks in `join` is
//! *measured* stall (a `Stall` span, `RingStats::stall_ns`); with
//! `overlap: false` the copy runs inline on the caller thread and is
//! charged entirely as stall, so `overlap_frac == 0` is the honest sync
//! baseline and anything above it is measured hiding, never asserted.
//!
//! Backward re-runs the rotation with `dk`/`dv` partial accumulators
//! riding along. The K/V leg of each hop overlaps compute as in forward;
//! the dKV leg cannot (it carries what the fold just produced) and is
//! charged as stall. Completed dKV blocks all land on rank `sp-1` and
//! are "homed" to their owner rank in one accounted exchange
//! (`account_send_recv`). See `plan.rs` for the summation-order contract.

use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::collectives::faults::{lock_clean, AlstError, FaultSite};
use crate::collectives::transport::Deadline;
use crate::collectives::Group;
use crate::config::PlanKind;
use crate::obs::{Category, Tracer};
use crate::runtime::tensor::{HostTensor, ScratchArena};

use super::plan::{
    attn_block_bwd_fold, attn_block_fold, finalize_online_softmax, seg_ids_from_cu, AttnShape,
    ParallelPlan, PlanSaved,
};

/// Measured transfer/stall accounting for the ring rotation, mirroring
/// the offload engine's stall ledger: `copy_ns` is wall time spent inside
/// `send_recv` (on the worker under overlap, inline otherwise), while
/// `stall_ns` is the part the critical path actually waited for.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct RingStats {
    /// Rotation hops performed (transfer rounds, forward + backward).
    pub hops: u64,
    pub copy_ns: u64,
    pub stall_ns: u64,
    pub bytes: u64,
}

impl RingStats {
    /// Fraction of transfer time hidden behind block compute. 0 for the
    /// inline baseline by construction; measured (not asserted) under
    /// overlap.
    pub fn overlap_frac(&self) -> f64 {
        if self.copy_ns == 0 {
            return 0.0;
        }
        (1.0 - self.stall_ns as f64 / self.copy_ns as f64).clamp(0.0, 1.0)
    }
}

/// Forward-rotation wire bytes under the causal-skip schedule:
/// `sp(sp-1)/2` block hops, each moving a K+V block of `(seq/sp) * n_kv
/// * d` elements. Exact for equal shards (the ledger tests pin that);
/// with ragged shards the ledger follows the actual block sizes and this
/// is the balanced-shard price.
pub fn ring_fwd_bytes(seq: usize, n_kv: usize, head_dim: usize, sp: usize, elem_bytes: usize) -> u64 {
    if sp <= 1 {
        return 0;
    }
    ((sp - 1) * seq * n_kv * head_dim * elem_bytes) as u64
}

/// Backward wire bytes: the rotation re-runs with dK/dV riding along
/// (twice the forward payload), plus homing every completed dKV block
/// from rank `sp-1` to its owner (all blocks but rank `sp-1`'s own).
pub fn ring_bwd_bytes(seq: usize, n_kv: usize, head_dim: usize, sp: usize, elem_bytes: usize) -> u64 {
    if sp <= 1 {
        return 0;
    }
    let home = (2 * (sp - 1) * seq.div_ceil(sp) * n_kv * head_dim * elem_bytes) as u64;
    2 * ring_fwd_bytes(seq, n_kv, head_dim, sp, elem_bytes) + home
}

/// One rotating payload: borrowed from the caller's shard at hop 0,
/// arena-owned once received over the wire.
enum Payload<'a> {
    Borrowed(&'a [f32]),
    Owned(Vec<f32>),
}

impl Payload<'_> {
    fn slice(&self) -> &[f32] {
        match self {
            Payload::Borrowed(s) => s,
            Payload::Owned(v) => v,
        }
    }

    fn recycle(self, arena: &ScratchArena) {
        if let Payload::Owned(v) = self {
            if !v.is_empty() {
                arena.recycle_f32(v);
            }
        }
    }
}

/// The KV block a rank currently holds (`idx` = global block id = owner
/// rank; block rows are the owner's shard rows).
struct RingBuf<'a> {
    k: Payload<'a>,
    v: Payload<'a>,
    idx: usize,
}

/// Fold the blocks held at `hop` into every active rank's running state.
#[allow(clippy::too_many_arguments)]
fn fold_ranks(
    hop: usize,
    cur: &[Option<RingBuf>],
    qd: &[&[f32]],
    rows: &[usize],
    bases: &[usize],
    shape: &AttnShape,
    seg: &[usize],
    m: &mut [Vec<f32>],
    l: &mut [Vec<f32>],
    acc: &mut [Vec<f32>],
    scores: &mut [Vec<f32>],
    tracer: &Tracer,
) {
    for (r, slot) in cur.iter().enumerate().skip(hop) {
        let Some(buf) = slot else { continue };
        let b = buf.idx;
        let mut span = tracer.span(Category::Ring, "ring_fold");
        span.set_rank(r);
        attn_block_fold(
            qd[r],
            rows[r],
            bases[r],
            buf.k.slice(),
            buf.v.slice(),
            rows[b],
            bases[b],
            shape,
            seg,
            &mut m[r],
            &mut l[r],
            &mut acc[r],
            &mut scores[r],
        );
    }
}

/// Backward fold: mutates each active rank's `dq` and the riding
/// `(dk, dv)` accumulators of the block it holds.
#[allow(clippy::too_many_arguments)]
fn fold_ranks_bwd(
    hop: usize,
    cur: &[Option<RingBuf>],
    dkv: &mut [Option<(Vec<f32>, Vec<f32>)>],
    qd: &[&[f32]],
    dod: &[&[f32]],
    od: &[&[f32]],
    lsed: &[&[f32]],
    rows: &[usize],
    bases: &[usize],
    shape: &AttnShape,
    seg: &[usize],
    dq: &mut [Vec<f32>],
    tracer: &Tracer,
) {
    for (r, slot) in cur.iter().enumerate().skip(hop) {
        let Some(buf) = slot else { continue };
        let b = buf.idx;
        let (dk, dv) = dkv[r].as_mut().expect("dkv rides with its kv block");
        let mut span = tracer.span(Category::Ring, "ring_fold_bwd");
        span.set_rank(r);
        attn_block_bwd_fold(
            qd[r],
            dod[r],
            od[r],
            lsed[r],
            rows[r],
            bases[r],
            buf.k.slice(),
            buf.v.slice(),
            rows[b],
            bases[b],
            shape,
            seg,
            &mut dq[r],
            dk,
            dv,
        );
    }
}

/// Blockwise RingAttention behind the [`ParallelPlan`] trait.
pub struct RingPlan {
    overlap: bool,
    stats: Mutex<RingStats>,
}

impl Default for RingPlan {
    fn default() -> Self {
        RingPlan::new(true)
    }
}

impl RingPlan {
    pub fn new(overlap: bool) -> RingPlan {
        RingPlan { overlap, stats: Mutex::default() }
    }

    pub fn overlap(&self) -> bool {
        self.overlap
    }

    pub fn stats(&self) -> RingStats {
        *lock_clean(&self.stats)
    }

    pub fn reset_stats(&self) {
        *lock_clean(&self.stats) = RingStats::default();
    }

    fn note_hop(&self, copy: Duration, stall: Duration, bytes: u64) {
        let mut st = lock_clean(&self.stats);
        st.hops += 1;
        st.copy_ns += copy.as_nanos() as u64;
        st.stall_ns += stall.as_nanos() as u64;
        st.bytes += bytes;
    }

    /// Rotate the blocks one hop under the causal-skip schedule: ranks
    /// `hop..sp-1` send to their `+1` neighbor. Returns the received
    /// (k, v) buffers and the measured in-transfer duration. Under
    /// `overlap` the caller passes `compute`, which runs on this thread
    /// while the worker moves data; the join wait is the measured stall.
    /// A wire fault that survives the group's retry loop (a lost rank)
    /// propagates typed; a panicked transfer worker surfaces as
    /// [`AlstError::WorkerDead`] instead of poisoning the caller.
    fn rotate_kv<'a, F: FnOnce()>(
        &self,
        group: &Group,
        arena: &ScratchArena,
        cur: &[Option<RingBuf<'a>>],
        hop: usize,
        compute: F,
    ) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>, u64)> {
        let sp = cur.len();
        let tracer = group.tracer();
        let mut ksends: Vec<&[f32]> = vec![&[]; sp];
        let mut vsends: Vec<&[f32]> = vec![&[]; sp];
        for r in hop..sp - 1 {
            if let Some(buf) = &cur[r] {
                ksends[r] = buf.k.slice();
                vsends[r] = buf.v.slice();
            }
        }
        let bytes: u64 =
            ksends.iter().chain(&vsends).map(|s| (s.len() * 4) as u64).sum();
        if self.overlap {
            // The join wait is bounded: the worker's two transfer legs are
            // each deadline-bounded per wire op, so the ceiling here (a
            // generous multiple of the group's op timeout) only expires if
            // the worker is stuck outside the wire — and then surfaces a
            // typed transient instead of blocking the step forever. The
            // handle is still joined afterwards so a worker panic is
            // consumed rather than poisoning the scope.
            let deadline = Deadline::after(group.op_timeout().saturating_mul(4));
            let (moved, copy, stall) = std::thread::scope(|s| {
                let (tx, rx) = mpsc::channel();
                let worker = s.spawn(move || {
                    let t0 = Instant::now();
                    let moved = ring_leg(group, arena, &ksends, &vsends);
                    let _ = tx.send((moved, t0.elapsed()));
                });
                compute();
                let joined = Instant::now();
                let mut sspan = tracer.span(Category::Stall, "stall_ring");
                let timeout = deadline.io_timeout().expect("after() is bounded");
                let received = rx.recv_timeout(timeout);
                let stall = joined.elapsed();
                sspan.set_dur(stall);
                drop(sspan);
                match received {
                    Ok((moved, copy)) => {
                        let _ = worker.join();
                        Ok((moved, copy, stall))
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        // Leave the worker to its own deadlines; the scope
                        // exit join below stays transitively bounded.
                        Err(anyhow::Error::new(AlstError::Transient {
                            site: FaultSite::Wire,
                            rank: 0,
                            attempt: 0,
                        }))
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        let _ = worker.join();
                        Err(anyhow::Error::new(AlstError::WorkerDead {
                            stream: "ring transfer",
                        }))
                    }
                }
            })?;
            let (kr, vr) = moved?;
            self.note_hop(copy, stall, bytes);
            Ok((kr, vr, bytes))
        } else {
            compute();
            let mut sspan = tracer.span(Category::Stall, "stall_ring");
            let t0 = Instant::now();
            let moved = ring_leg(group, arena, &ksends, &vsends);
            let copy = t0.elapsed();
            sspan.set_dur(copy);
            drop(sspan);
            let (kr, vr) = moved?;
            // inline: the critical path pays the whole copy
            self.note_hop(copy, copy, bytes);
            Ok((kr, vr, bytes))
        }
    }
}

/// One two-buffer transfer leg (K+V or dK+dV). If the second half
/// faults, the first half's received buffers go back to the pool before
/// the error propagates, so a retried or aborted step starts clean.
fn ring_leg(
    group: &Group,
    arena: &ScratchArena,
    first: &[&[f32]],
    second: &[&[f32]],
) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
    let fr = group.send_recv_into(first, 1, arena)?;
    match group.send_recv_into(second, 1, arena) {
        Ok(sr) => Ok((fr, sr)),
        Err(e) => {
            for b in fr {
                if !b.is_empty() {
                    arena.recycle_f32(b);
                }
            }
            Err(e)
        }
    }
}

/// Replace the held blocks after the hop `hop -> hop+1` transfer;
/// returns old owned buffers to the arena.
fn install<'a>(
    cur: &mut Vec<Option<RingBuf<'a>>>,
    kr: Vec<Vec<f32>>,
    vr: Vec<Vec<f32>>,
    hop: usize,
    arena: &ScratchArena,
) {
    let sp = cur.len();
    let mut next: Vec<Option<RingBuf<'a>>> = Vec::with_capacity(sp);
    for (r, (kb, vb)) in kr.into_iter().zip(vr).enumerate() {
        if kb.is_empty() {
            next.push(None);
        } else {
            next.push(Some(RingBuf {
                k: Payload::Owned(kb),
                v: Payload::Owned(vb),
                idx: r - hop - 1,
            }));
        }
    }
    for old in cur.drain(..) {
        if let Some(b) = old {
            b.k.recycle(arena);
            b.v.recycle(arena);
        }
    }
    *cur = next;
}

impl ParallelPlan for RingPlan {
    fn kind(&self) -> PlanKind {
        PlanKind::Ring
    }

    fn validate(&self, n_q: usize, n_kv: usize, sp: usize) -> Result<()> {
        anyhow::ensure!(sp >= 1, "sp must be >= 1, got {sp}");
        anyhow::ensure!(
            n_q % n_kv == 0,
            "ring plan: {n_q} query heads not divisible by {n_kv} kv heads \
             (GQA grouping needs an integer group size)"
        );
        // No head bound: every rank keeps all heads of its query shard,
        // so sp > n_q is fine — the configuration Ulysses rejects.
        Ok(())
    }

    fn comm_bytes_per_layer(
        &self,
        seq: usize,
        shape: &AttnShape,
        sp: usize,
        elem_bytes: usize,
    ) -> u64 {
        ring_fwd_bytes(seq, shape.n_kv, shape.head_dim, sp, elem_bytes)
            + ring_bwd_bytes(seq, shape.n_kv, shape.head_dim, sp, elem_bytes)
    }

    fn attention_forward(
        &self,
        group: &Group,
        arena: &ScratchArena,
        q: &[HostTensor],
        k: &[HostTensor],
        v: &[HostTensor],
        shape: &AttnShape,
        cu_seqlens: &[i32],
    ) -> Result<(Vec<HostTensor>, PlanSaved)> {
        let sp = group.world;
        assert_eq!(q.len(), sp);
        self.validate(shape.n_q, shape.n_kv, sp)?;
        let (nq, d) = (shape.n_q, shape.head_dim);
        let rows: Vec<usize> = q.iter().map(|t| t.shape()[0]).collect();
        let bases: Vec<usize> = rows
            .iter()
            .scan(0usize, |a, r| {
                let b = *a;
                *a += r;
                Some(b)
            })
            .collect();
        let seq: usize = rows.iter().sum();
        let seg = seg_ids_from_cu(cu_seqlens, seq);
        let qd: Vec<&[f32]> = q.iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
        let kd: Vec<&[f32]> = k.iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
        let vd: Vec<&[f32]> = v.iter().map(|t| t.as_f32()).collect::<Result<_>>()?;

        let max_rows = rows.iter().copied().max().unwrap_or(0);
        let (mut m, mut l, mut acc, mut scores) =
            (Vec::with_capacity(sp), Vec::with_capacity(sp), Vec::with_capacity(sp), Vec::with_capacity(sp));
        for r in 0..sp {
            let n = rows[r] * nq;
            let mut mr = arena.take_f32(n);
            mr.fill(f32::NEG_INFINITY);
            m.push(mr);
            let mut lr = arena.take_f32(n);
            lr.fill(0.0);
            l.push(lr);
            let mut ar = arena.take_f32(n * d);
            ar.fill(0.0);
            acc.push(ar);
            scores.push(arena.take_f32(max_rows));
        }

        let mut cur: Vec<Option<RingBuf>> = (0..sp)
            .map(|r| {
                Some(RingBuf { k: Payload::Borrowed(kd[r]), v: Payload::Borrowed(vd[r]), idx: r })
            })
            .collect();

        let tracer = group.tracer().clone();
        for hop in 0..sp {
            if hop + 1 == sp {
                fold_ranks(
                    hop, &cur, &qd, &rows, &bases, shape, &seg, &mut m, &mut l, &mut acc,
                    &mut scores, &tracer,
                );
            } else {
                let (kr, vr, _bytes) = self.rotate_kv(group, arena, &cur, hop, || {
                    fold_ranks(
                        hop, &cur, &qd, &rows, &bases, shape, &seg, &mut m, &mut l, &mut acc,
                        &mut scores, &tracer,
                    );
                })?;
                install(&mut cur, kr, vr, hop, arena);
            }
        }
        for slot in cur {
            if let Some(b) = slot {
                b.k.recycle(arena);
                b.v.recycle(arena);
            }
        }

        let (mut o_out, mut o_saved, mut lse_saved) =
            (Vec::with_capacity(sp), Vec::with_capacity(sp), Vec::with_capacity(sp));
        for r in 0..sp {
            let mut lse = arena.take_f32(rows[r] * nq);
            let mut acc_r = std::mem::take(&mut acc[r]);
            finalize_online_softmax(&m[r], &l[r], &mut acc_r, &mut lse, d);
            let o = HostTensor::f32(vec![rows[r], nq, d], acc_r);
            // saved copy survives downstream consumption of the output
            o_saved.push(arena.copy_tensor(&o));
            lse_saved.push(HostTensor::f32(vec![rows[r], nq], lse));
            o_out.push(o);
        }
        for b in m.into_iter().chain(l).chain(scores) {
            arena.recycle_f32(b);
        }
        Ok((o_out, PlanSaved::Ring { o: o_saved, lse: lse_saved }))
    }

    fn attention_backward(
        &self,
        group: &Group,
        arena: &ScratchArena,
        q: &[HostTensor],
        k: &[HostTensor],
        v: &[HostTensor],
        d_o: &[HostTensor],
        saved: &PlanSaved,
        shape: &AttnShape,
        cu_seqlens: &[i32],
    ) -> Result<(Vec<HostTensor>, Vec<HostTensor>, Vec<HostTensor>)> {
        let PlanSaved::Ring { o, lse } = saved else {
            anyhow::bail!("ring backward needs ring-saved (o, lse) state")
        };
        let sp = group.world;
        assert_eq!(q.len(), sp);
        self.validate(shape.n_q, shape.n_kv, sp)?;
        let (nq, nkv, d) = (shape.n_q, shape.n_kv, shape.head_dim);
        let rows: Vec<usize> = q.iter().map(|t| t.shape()[0]).collect();
        let bases: Vec<usize> = rows
            .iter()
            .scan(0usize, |a, r| {
                let b = *a;
                *a += r;
                Some(b)
            })
            .collect();
        let seq: usize = rows.iter().sum();
        let seg = seg_ids_from_cu(cu_seqlens, seq);
        let qd: Vec<&[f32]> = q.iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
        let kd: Vec<&[f32]> = k.iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
        let vd: Vec<&[f32]> = v.iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
        let dod: Vec<&[f32]> = d_o.iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
        let od: Vec<&[f32]> = o.iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
        let lsed: Vec<&[f32]> = lse.iter().map(|t| t.as_f32()).collect::<Result<_>>()?;

        let mut dq: Vec<Vec<f32>> = (0..sp)
            .map(|r| {
                let mut b = arena.take_f32(rows[r] * nq * d);
                b.fill(0.0);
                b
            })
            .collect();
        let mut cur: Vec<Option<RingBuf>> = (0..sp)
            .map(|r| {
                Some(RingBuf { k: Payload::Borrowed(kd[r]), v: Payload::Borrowed(vd[r]), idx: r })
            })
            .collect();
        // dkv accumulators ride with the block each rank holds
        let mut dkv: Vec<Option<(Vec<f32>, Vec<f32>)>> = (0..sp)
            .map(|r| {
                let n = rows[r] * nkv * d;
                let mut a = arena.take_f32(n);
                a.fill(0.0);
                let mut b = arena.take_f32(n);
                b.fill(0.0);
                Some((a, b))
            })
            .collect();
        // finished[b]: block b's completed (dk, dv), captured at rank sp-1
        let mut finished: Vec<Option<(Vec<f32>, Vec<f32>)>> = (0..sp).map(|_| None).collect();

        let tracer = group.tracer().clone();
        for hop in 0..sp {
            let last = hop + 1 == sp;
            if last {
                fold_ranks_bwd(
                    hop, &cur, &mut dkv, &qd, &dod, &od, &lsed, &rows, &bases, shape, &seg,
                    &mut dq, &tracer,
                );
            } else {
                // K/V leg overlaps the fold; the dKV leg below cannot —
                // it carries what the fold just produced.
                let (kr, vr, _bytes) = self.rotate_kv(group, arena, &cur, hop, || {
                    fold_ranks_bwd(
                        hop, &cur, &mut dkv, &qd, &dod, &od, &lsed, &rows, &bases, shape, &seg,
                        &mut dq, &tracer,
                    );
                })?;
                // capture the block whose ride just ended at rank sp-1
                if let Some(buf) = &cur[sp - 1] {
                    finished[buf.idx] = dkv[sp - 1].take();
                }
                let mut dksends: Vec<&[f32]> = vec![&[]; sp];
                let mut dvsends: Vec<&[f32]> = vec![&[]; sp];
                for r in hop..sp - 1 {
                    if let Some((dk_, dv_)) = &dkv[r] {
                        dksends[r] = dk_;
                        dvsends[r] = dv_;
                    }
                }
                let leg_bytes: u64 =
                    dksends.iter().chain(&dvsends).map(|s| (s.len() * 4) as u64).sum();
                let mut sspan = tracer.span(Category::Stall, "stall_ring");
                let t0 = Instant::now();
                let moved = ring_leg(group, arena, &dksends, &dvsends);
                let leg_copy = t0.elapsed();
                sspan.set_dur(leg_copy);
                drop(sspan);
                let (dkr, dvr) = moved?;
                self.note_hop(leg_copy, leg_copy, leg_bytes);
                install(&mut cur, kr, vr, hop, arena);
                // swap in the received dkv accumulators, recycling the sent
                let mut next_dkv: Vec<Option<(Vec<f32>, Vec<f32>)>> = Vec::with_capacity(sp);
                for (dk_, dv_) in dkr.into_iter().zip(dvr) {
                    if dk_.is_empty() {
                        next_dkv.push(None);
                    } else {
                        next_dkv.push(Some((dk_, dv_)));
                    }
                }
                for old in dkv.drain(..) {
                    if let Some((a, b)) = old {
                        arena.recycle_f32(a);
                        arena.recycle_f32(b);
                    }
                }
                dkv = next_dkv;
            }
        }
        // after the last fold, rank sp-1 holds the final completed block
        if let Some(Some(buf)) = cur.get(sp - 1) {
            finished[buf.idx] = dkv[sp - 1].take();
        }
        for slot in cur {
            if let Some(b) = slot {
                b.k.recycle(arena);
                b.v.recycle(arena);
            }
        }
        for slot in dkv.drain(..) {
            if let Some((a, b)) = slot {
                arena.recycle_f32(a);
                arena.recycle_f32(b);
            }
        }

        // home each completed dKV block from rank sp-1 to its owner; rank
        // sp-1's own block is already in place, every other crosses the
        // wire once
        let mut home_bytes = 0u64;
        for (b, slot) in finished.iter().enumerate() {
            let (dk_, dv_) = slot.as_ref().expect("every block's ride completes");
            if b != sp - 1 {
                home_bytes += ((dk_.len() + dv_.len()) * 4) as u64;
            }
        }
        if home_bytes > 0 {
            group.account_send_recv(home_bytes)?;
        }

        let mut d_q = Vec::with_capacity(sp);
        let mut d_k = Vec::with_capacity(sp);
        let mut d_v = Vec::with_capacity(sp);
        for (b, slot) in finished.into_iter().enumerate() {
            let (dk_, dv_) = slot.unwrap();
            d_q.push(HostTensor::f32(vec![rows[b], nq, d], std::mem::take(&mut dq[b])));
            d_k.push(HostTensor::f32(vec![rows[b], nkv, d], dk_));
            d_v.push(HostTensor::f32(vec![rows[b], nkv, d], dv_));
        }
        Ok((d_q, d_k, d_v))
    }
}

/// Drive the ring plan's *transfers only* through the arena — the
/// analogue of `ulysses::relayout_step_cycle` for byte benchmarking at
/// sequence lengths where the host reference attention itself would be
/// prohibitive. Performs, per layer, the forward causal-skip rotation
/// (K+V), the backward rotation (K+V+dK+dV), and the homing exchange,
/// with the exact ledger of the real plan.
pub fn ring_comm_cycle(
    group: &Group,
    arena: &ScratchArena,
    rows_per_rank: usize,
    n_kv: usize,
    head_dim: usize,
    n_layers: usize,
) -> Result<()> {
    let sp = group.world;
    if sp <= 1 {
        return Ok(());
    }
    let blk = rows_per_rank * n_kv * head_dim;
    let mut proto = arena.take_f32(blk);
    proto.fill(0.0);
    for _ in 0..n_layers {
        for bufs_per_hop in [2usize, 4] {
            for hop in 0..sp - 1 {
                for _ in 0..bufs_per_hop {
                    let mut sends: Vec<&[f32]> = vec![&[]; sp];
                    for s in sends.iter_mut().take(sp - 1).skip(hop) {
                        *s = &proto;
                    }
                    let recv = match group.send_recv_into(&sends, 1, arena) {
                        Ok(recv) => recv,
                        Err(e) => {
                            arena.recycle_f32(proto);
                            return Err(e);
                        }
                    };
                    for b in recv {
                        if !b.is_empty() {
                            arena.recycle_f32(b);
                        }
                    }
                }
            }
            if bufs_per_hop == 4 {
                // homing: every completed dKV block but rank sp-1's own
                if let Err(e) = group.account_send_recv((2 * (sp - 1) * blk * 4) as u64) {
                    arena.recycle_f32(proto);
                    return Err(e);
                }
            }
        }
    }
    arena.recycle_f32(proto);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan::{dense_attention, plan_for};

    fn fill(t: &mut [f32], seed: u64) {
        let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        for x in t.iter_mut() {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            *x = ((s >> 33) as f32 / (1u64 << 31) as f32) - 0.5;
        }
    }

    fn shard(full: &HostTensor, rows: &[usize]) -> Vec<HostTensor> {
        let dims = full.shape();
        let stride: usize = dims[1..].iter().product();
        let data = full.as_f32().unwrap();
        let mut out = Vec::new();
        let mut base = 0;
        for &r in rows {
            out.push(HostTensor::f32(
                vec![r, dims[1], dims[2]],
                data[base * stride..(base + r) * stride].to_vec(),
            ));
            base += r;
        }
        out
    }

    fn rand_t(shape: Vec<usize>, seed: u64) -> HostTensor {
        let n: usize = shape.iter().product();
        let mut d = vec![0.0f32; n];
        fill(&mut d, seed);
        HostTensor::f32(shape, d)
    }

    #[test]
    fn forward_ledger_matches_causal_skip_closed_form() {
        let (sp, ssh, n_q, n_kv, d) = (4, 4, 4, 2, 8);
        let seq = sp * ssh;
        let shape = AttnShape::new(n_q, n_kv, d);
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        let q = shard(&rand_t(vec![seq, n_q, d], 1), &[ssh; 4]);
        let k = shard(&rand_t(vec![seq, n_kv, d], 2), &[ssh; 4]);
        let v = shard(&rand_t(vec![seq, n_kv, d], 3), &[ssh; 4]);
        let plan = RingPlan::new(false);
        let cu = [0, seq as i32];
        let (_o, saved) = plan.attention_forward(&g, &arena, &q, &k, &v, &shape, &cu).unwrap();
        assert_eq!(g.stats().send_recv_bytes, ring_fwd_bytes(seq, n_kv, d, sp, 4));
        assert_eq!(g.stats().all_to_all_bytes, 0, "ring never uses a2a");
        saved.recycle(&arena);
    }

    #[test]
    fn full_cycle_ledger_matches_comm_bytes_per_layer() {
        let (sp, ssh, n_q, n_kv, d) = (4, 3, 4, 4, 4);
        let seq = sp * ssh;
        let shape = AttnShape::new(n_q, n_kv, d);
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        let q = shard(&rand_t(vec![seq, n_q, d], 4), &[ssh; 4]);
        let k = shard(&rand_t(vec![seq, n_kv, d], 5), &[ssh; 4]);
        let v = shard(&rand_t(vec![seq, n_kv, d], 6), &[ssh; 4]);
        let plan = RingPlan::new(false);
        let cu = [0, seq as i32];
        let (o, saved) = plan.attention_forward(&g, &arena, &q, &k, &v, &shape, &cu).unwrap();
        let _ = plan
            .attention_backward(&g, &arena, &q, &k, &v, &o, &saved, &shape, &cu)
            .unwrap();
        assert_eq!(
            g.stats().send_recv_bytes,
            plan.comm_bytes_per_layer(seq, &shape, sp, 4),
            "ledger must match the closed form"
        );
        saved.recycle(&arena);
    }

    #[test]
    fn ring_spans_pair_with_ledger_ops() {
        use std::sync::Arc;
        let (sp, ssh, n_q, n_kv, d) = (3, 2, 2, 1, 4);
        let seq = sp * ssh;
        let shape = AttnShape::new(n_q, n_kv, d);
        let mut g = Group::new(sp);
        let tracer = Arc::new(Tracer::new(true));
        g.set_tracer(tracer.clone());
        let arena = ScratchArena::new();
        let q = shard(&rand_t(vec![seq, n_q, d], 7), &[ssh; 3]);
        let k = shard(&rand_t(vec![seq, n_kv, d], 8), &[ssh; 3]);
        let v = shard(&rand_t(vec![seq, n_kv, d], 9), &[ssh; 3]);
        let plan = RingPlan::new(true);
        let cu = [0, seq as i32];
        let (o, saved) = plan.attention_forward(&g, &arena, &q, &k, &v, &shape, &cu).unwrap();
        let _ = plan
            .attention_backward(&g, &arena, &q, &k, &v, &o, &saved, &shape, &cu)
            .unwrap();
        let st = g.stats();
        let spans = tracer.drain();
        let coll: Vec<_> = spans.iter().filter(|s| s.cat == Category::Collective).collect();
        assert_eq!(coll.len() as u64, st.ops, "one Collective span per ledger op");
        let span_bytes: u64 = coll.iter().map(|s| s.bytes).sum();
        assert_eq!(span_bytes, st.total_bytes(), "span bytes == ledger bytes");
        assert!(
            spans.iter().any(|s| s.cat == Category::Ring),
            "block folds land on the ring lane"
        );
        assert!(
            spans.iter().any(|s| s.cat == Category::Stall && s.name == "stall_ring"),
            "transfer waits land on the stall lane"
        );
        saved.recycle(&arena);
    }

    #[test]
    fn inline_mode_charges_whole_copy_as_stall() {
        let (sp, ssh, n_q, n_kv, d) = (4, 2, 2, 2, 4);
        let seq = sp * ssh;
        let shape = AttnShape::new(n_q, n_kv, d);
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        let q = shard(&rand_t(vec![seq, n_q, d], 10), &[ssh; 4]);
        let k = shard(&rand_t(vec![seq, n_kv, d], 11), &[ssh; 4]);
        let v = shard(&rand_t(vec![seq, n_kv, d], 12), &[ssh; 4]);
        let cu = [0, seq as i32];
        let plan = RingPlan::new(false);
        let (_o, saved) = plan.attention_forward(&g, &arena, &q, &k, &v, &shape, &cu).unwrap();
        let st = plan.stats();
        assert_eq!(st.hops, (sp - 1) as u64);
        assert_eq!(st.copy_ns, st.stall_ns, "inline hides nothing");
        assert_eq!(st.overlap_frac(), 0.0);
        assert_eq!(st.bytes, ring_fwd_bytes(seq, n_kv, d, sp, 4));
        saved.recycle(&arena);

        let plan = RingPlan::new(true);
        let (_o, saved) = plan.attention_forward(&g, &arena, &q, &k, &v, &shape, &cu).unwrap();
        let st = plan.stats();
        assert!(st.copy_ns > 0);
        assert!((0.0..=1.0).contains(&st.overlap_frac()));
        saved.recycle(&arena);
    }

    #[test]
    fn comm_cycle_ledger_matches_plan_closed_form() {
        let (sp, ssh, n_kv, d, layers) = (4, 8, 2, 16, 3);
        let seq = sp * ssh;
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        ring_comm_cycle(&g, &arena, ssh, n_kv, d, layers).unwrap();
        let shape = AttnShape::new(n_kv, n_kv, d);
        let per_layer = RingPlan::new(false).comm_bytes_per_layer(seq, &shape, sp, 4);
        assert_eq!(g.stats().send_recv_bytes, layers as u64 * per_layer);
        // steady state: a second cycle is served from the pool
        let misses = arena.misses();
        ring_comm_cycle(&g, &arena, ssh, n_kv, d, layers).unwrap();
        assert_eq!(arena.misses(), misses, "comm cycle allocates only once");
    }

    #[test]
    fn plan_factory_ring_has_no_head_bound() {
        let plan = plan_for(PlanKind::Ring);
        assert!(plan.validate(4, 2, 16).is_ok(), "sp=16 > 4 heads is fine under ring");
        assert!(plan.validate(3, 2, 4).is_err(), "GQA grouping still checked");
        let ulysses = plan_for(PlanKind::Ulysses);
        assert!(ulysses.validate(4, 2, 16).is_err());
    }

    #[test]
    fn ring_comm_beats_a2a_at_the_gqa_acceptance_point() {
        // The BENCH_ring acceptance geometry: 32K tokens, 32 q heads,
        // GQA 8:1 (4 kv heads), d=128, sp=8. Ring moves strictly fewer
        // bytes per layer than the Ulysses a2a cycle. With MHA (n_kv=8+)
        // the ring actually loses at sp=8 — kept honest in bench rows.
        use crate::coordinator::ulysses::UlyssesPlan;
        let shape = AttnShape::new(32, 4, 128);
        let ring = RingPlan::new(false).comm_bytes_per_layer(32768, &shape, 8, 2);
        let a2a = UlyssesPlan.comm_bytes_per_layer(32768, &shape, 8, 2);
        assert!(
            ring < a2a,
            "ring {} bytes must undercut a2a {} bytes at the acceptance point",
            ring,
            a2a
        );
    }

    #[test]
    fn sp1_forward_is_bit_identical_to_dense_reference() {
        let (n_q, n_kv, d, seq) = (4, 2, 8, 16);
        let shape = AttnShape::new(n_q, n_kv, d);
        let g = Group::new(1);
        let arena = ScratchArena::new();
        let q = rand_t(vec![seq, n_q, d], 20);
        let k = rand_t(vec![seq, n_kv, d], 21);
        let v = rand_t(vec![seq, n_kv, d], 22);
        let cu = [0, 7, seq as i32];
        let plan = RingPlan::default();
        let (o, saved) = plan
            .attention_forward(
                &g,
                &arena,
                std::slice::from_ref(&q),
                std::slice::from_ref(&k),
                std::slice::from_ref(&v),
                &shape,
                &cu,
            )
            .unwrap();
        let (o_ref, lse_ref) = dense_attention(&q, &k, &v, &shape, &cu, &arena).unwrap();
        assert_eq!(o[0].as_f32().unwrap(), o_ref.as_f32().unwrap(), "sp=1 == dense, bitwise");
        assert_eq!(g.stats().send_recv_bytes, 0, "sp=1 moves nothing");
        let PlanSaved::Ring { lse, .. } = &saved else { panic!() };
        assert_eq!(lse[0].as_f32().unwrap(), lse_ref.as_f32().unwrap());
        saved.recycle(&arena);
    }
}
