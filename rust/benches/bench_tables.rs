//! Regenerates every paper table/figure dataset (DESIGN.md §4 experiment
//! index) and asserts the qualitative shapes the paper reports. This is
//! the `cargo bench` face of `alst tables`.

use alst::config::{preset, FeatureFlags};
use alst::paper;
use alst::util::bench::quick;

fn parse_seqlen(s: &str) -> f64 {
    if let Some(m) = s.strip_suffix('M') {
        m.parse::<f64>().unwrap() * 1e6
    } else if let Some(k) = s.strip_suffix('K') {
        k.parse::<f64>().unwrap() * 1e3
    } else {
        s.parse().unwrap_or(0.0)
    }
}

fn main() {
    println!("bench_tables: paper table/figure regeneration\n");

    for (name, table) in paper::all_tables() {
        table.print();
        std::fs::create_dir_all("results").ok();
        std::fs::write(format!("results/{name}.csv"), table.to_csv()).unwrap();
    }

    // ---- shape assertions (the reproduction criteria) ----------------------
    let m8 = preset("llama3-8b").unwrap();

    // Table 1: ladder monotone, baseline logits-bound, full-ALST largest.
    let t1 = paper::table1_ablations(m8, 8);
    let seqs: Vec<f64> = t1.rows.iter().map(|r| parse_seqlen(&r[1])).collect();
    assert!(seqs.windows(2).all(|w| w[1] >= w[0]), "ladder not monotone: {seqs:?}");
    assert!(seqs[5] / seqs[0] > 50.0, "full ALST must be >>50x baseline");
    assert_eq!(t1.rows[0][4], "logits", "baseline must be logits-bound");

    // Tables 2-4: improvements grow with GPU count, >=8x everywhere.
    let t234 = paper::tables_2_3_4(m8);
    let imp: Vec<f64> = t234
        .rows
        .iter()
        .filter(|r| r[1] == "ALST")
        .map(|r| r[5].trim_end_matches('x').parse().unwrap())
        .collect();
    assert!(imp.iter().all(|&x| x >= 8.0), "{imp:?}");
    assert!(imp[2] > imp[1] && imp[1] > imp[0], "{imp:?}");

    // Figure 8: near-linear scaling 1 -> 32 GPUs.
    let f8 = paper::fig_8_9_10("llama3-8b", &[1, 2, 4, 8, 16, 32]);
    let s: Vec<f64> = f8.rows.iter().map(|r| parse_seqlen(&r[2])).collect();
    // each doubling of GPUs buys >=1.4x seqlen (the 1-GPU point benefits
    // from grad offload, so 1->2 is sub-2x; paper's own 1->8 is 7.4x).
    assert!(s.windows(2).all(|w| w[1] > w[0] * 1.4), "sub-linear scaling: {s:?}");

    // Figure 9: 70B is host-RAM bound (the paper's 1.9 TiB wall).
    let f9 = paper::fig_8_9_10("llama3-70b", &[16, 32, 64]);
    assert!(
        f9.rows.iter().any(|r| r[3] == "host-ram"),
        "70B should hit the host-RAM wall"
    );

    // Figure 4 shape: TiledMLP saving ~= shard count, O(1) tile memory.
    let f4 = paper::fig4_tiled_mlp();
    let tile_gib: Vec<f64> = f4.rows.iter().map(|r| r[2].parse().unwrap()).collect();
    let spread = tile_gib.iter().cloned().fold(f64::MIN, f64::max)
        / tile_gib.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread < 1.2, "tile memory must be ~seq-independent: {tile_gib:?}");

    // Comm-sensitivity ablation: a2a time falls as inter-node BW rises;
    // offload time falls as PCIe BW rises (rows ordered per paper.rs).
    let cs = paper::comm_sensitivity_table();
    let a2a: Vec<f64> = cs.rows[..3].iter().map(|r| r[3].parse().unwrap()).collect();
    assert!(a2a[0] > a2a[1] && a2a[1] > a2a[2], "a2a not BW-monotone: {a2a:?}");
    let off25: f64 = cs.rows[3][4].parse().unwrap();
    let off100: f64 = cs.rows[4][4].parse().unwrap();
    assert!(off25 > off100, "offload not PCIe-monotone");

    // Timing: table generation itself is fast enough to live in CI.
    quick("all_tables() generation", || {
        let t = paper::all_tables();
        std::hint::black_box(&t);
    });

    // Feature-ladder sanity at a different GPU count (32): same shape.
    let t1_32 = paper::table1_ablations(m8, 32);
    let seqs32: Vec<f64> = t1_32.rows.iter().map(|r| parse_seqlen(&r[1])).collect();
    assert!(seqs32[5] > seqs[5], "more GPUs must allow longer sequences");

    // Baseline flags describe() round-trips the feature names.
    assert!(FeatureFlags::alst().describe().contains("ulysses"));

    println!("\nbench_tables: all paper-shape assertions PASSED");
    println!("CSV written to results/");
}
