//! Model / parallelism / feature / cluster configuration.
//!
//! Two families of model configs exist:
//! * **simulator presets** (`llama3-8b`, `llama3-70b`, `qwen3-32b`) — the
//!   paper's evaluation models, used by the memory simulator and perf model
//!   to regenerate every table and figure;
//! * **runnable manifests** — configs exported by `python/compile/aot.py`
//!   whose artifacts actually execute on the PJRT CPU client (`tiny`,
//!   `e2e-25m`, `e2e-100m`). Those are loaded from `artifacts/*/manifest.json`
//!   by `runtime::manifest`.

pub mod features;
pub mod model;
pub mod parallel;

pub use features::{FeatureFlags, Precision};
pub use model::{preset, ModelPreset, PRESETS};
pub use parallel::{ClusterConfig, ParallelConfig, PlanKind, GIB};
