//! Transport conformance suite: one set of behavioral rows run against
//! every [`Transport`] implementation, so `LocalTransport` and
//! `SocketTransport` cannot drift apart in the semantics `Group` relies
//! on — frame fidelity, ordering, stale-frame discard, typed length
//! mismatch, liveness gating, per-rank frame accounting, and typed
//! failure after `close`.
//!
//! The generic rows take `&dyn Transport` exactly as `Group` holds it.
//! Transport-specific rows cover what only one side can express: the
//! local test hooks (`fail_peer`, `corrupt_next_frames`), socket recv
//! deadline expiry against a silent wire, and — for spawned rank
//! *processes* — SIGKILL detection plus `heal()` bringing the fleet back.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use alst::collectives::{
    AlstError, Deadline, LocalTransport, SocketOptions, SocketTransport, Transport, TransportKind,
};
use alst::obs::Tracer;

/// Generous per-op bound: conformance rows must never hang, but none of
/// them should come anywhere near this either.
fn op_deadline() -> Deadline {
    Deadline::after(Duration::from_secs(5))
}

/// Deterministic payload with rank/size-dependent bit patterns.
fn payload(n: usize, salt: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let x = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(salt);
            ((x >> 33) as f32) * 1e-9 - 4.0
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// Generic rows (everything here must hold for every Transport)
// ---------------------------------------------------------------------------

fn roundtrips_are_bit_identical(t: &dyn Transport) {
    let world = t.world();
    for (k, n) in [0usize, 1, 7, 1024].into_iter().enumerate() {
        for src in 0..world {
            let dst = (src + 1) % world;
            let sent = payload(n, (k * world + src) as u64);
            let frame = t.send(src, dst, &sent, op_deadline()).expect("send");
            let mut got = vec![0.0f32; n];
            t.recv_into(src, dst, frame, &mut got, op_deadline()).expect("recv");
            assert_eq!(bits(&got), bits(&sent), "payload n={n} src={src} must roundtrip exactly");
        }
    }
}

fn frames_arrive_in_send_order(t: &dyn Transport) {
    let a = payload(16, 100);
    let b = payload(16, 200);
    let fa = t.send(0, 1, &a, op_deadline()).expect("send a");
    let fb = t.send(0, 1, &b, op_deadline()).expect("send b");
    assert!(fa < fb, "sequence numbers must be monotonic per transport");
    let mut out = vec![0.0f32; 16];
    t.recv_into(0, 1, fa, &mut out, op_deadline()).expect("recv a");
    assert_eq!(bits(&out), bits(&a));
    t.recv_into(0, 1, fb, &mut out, op_deadline()).expect("recv b");
    assert_eq!(bits(&out), bits(&b));
}

/// A frame older than the one requested is a late echo of a timed-out
/// attempt: it must be silently discarded, and the requested frame must
/// still arrive intact behind it.
fn stale_frames_are_discarded(t: &dyn Transport) {
    let stale = payload(8, 300);
    let wanted = payload(8, 400);
    let _ = t.send(0, 1, &stale, op_deadline()).expect("send stale");
    let f = t.send(0, 1, &wanted, op_deadline()).expect("send wanted");
    let mut out = vec![0.0f32; 8];
    t.recv_into(0, 1, f, &mut out, op_deadline()).expect("recv past stale");
    assert_eq!(bits(&out), bits(&wanted));
}

fn length_mismatch_is_corrupt_payload(t: &dyn Transport) {
    let sent = payload(4, 500);
    let f = t.send(0, 1, &sent, op_deadline()).expect("send");
    let mut wrong = vec![0.0f32; 8];
    let err = t.recv_into(0, 1, f, &mut wrong, op_deadline()).expect_err("length mismatch");
    assert!(
        matches!(err, AlstError::CorruptPayload { .. }),
        "length mismatch must be typed CorruptPayload, got {err:?}"
    );
    assert!(err.is_retryable(), "a torn frame is retryable (resend), got {err:?}");
}

fn healthy_fleet_passes_check_peers(t: &dyn Transport) {
    for _ in 0..3 {
        t.check_peers().expect("healthy fleet must pass the liveness gate");
    }
}

fn frames_via_counts_sends(t: &dyn Transport) {
    let before = t.frames_via(0);
    let p = payload(4, 600);
    for _ in 0..3 {
        let f = t.send(0, 1, &p, op_deadline()).expect("send");
        let mut out = vec![0.0f32; 4];
        t.recv_into(0, 1, f, &mut out, op_deadline()).expect("recv");
    }
    assert_eq!(
        t.frames_via(0),
        before + 3,
        "frames_via must count frames sent via the source rank"
    );
}

/// Destructive: run last. After `close`, further traffic must fail with
/// the typed peer-death signal, never hang or panic.
fn close_makes_later_sends_typed(t: &dyn Transport) {
    t.close();
    let p = payload(4, 700);
    let err = t.send(0, 1, &p, op_deadline()).expect_err("send after close");
    assert!(
        matches!(err, AlstError::LostRank { .. }),
        "send after close must be typed LostRank, got {err:?}"
    );
}

/// Every row, in order; `close` last because it is terminal.
fn conformance(t: &dyn Transport, expect_kind: TransportKind, expect_world: usize) {
    assert_eq!(t.kind(), expect_kind);
    assert_eq!(t.world(), expect_world);
    roundtrips_are_bit_identical(t);
    frames_arrive_in_send_order(t);
    stale_frames_are_discarded(t);
    length_mismatch_is_corrupt_payload(t);
    healthy_fleet_passes_check_peers(t);
    frames_via_counts_sends(t);
    close_makes_later_sends_typed(t);
}

// ---------------------------------------------------------------------------
// Instantiations
// ---------------------------------------------------------------------------

#[test]
fn local_transport_conforms() {
    let t = LocalTransport::new(3);
    conformance(&*t, TransportKind::Local, 3);
}

fn thread_socket(world: usize) -> Arc<SocketTransport> {
    let opts = SocketOptions {
        connect_timeout: Duration::from_secs(10),
        in_thread: true,
        ..SocketOptions::default()
    };
    SocketTransport::spawn(world, opts, Tracer::off()).expect("spawn in-thread socket transport")
}

#[test]
fn socket_transport_conforms_in_thread() {
    let t = thread_socket(3);
    conformance(&*t, TransportKind::Socket, 3);
}

// ---------------------------------------------------------------------------
// Transport-specific rows
// ---------------------------------------------------------------------------

#[test]
fn local_dead_peer_is_typed_lost_rank_everywhere() {
    let t = LocalTransport::new(2);
    t.fail_peer(1);
    let p = payload(4, 800);
    let send_err = t.send(0, 1, &p, op_deadline()).expect_err("send to dead peer");
    assert_eq!(send_err.rank(), Some(1));
    assert!(matches!(send_err, AlstError::LostRank { .. }));
    let gate_err = t.check_peers().expect_err("liveness gate");
    assert!(matches!(gate_err, AlstError::LostRank { rank: 1, .. }));
    t.revive_peer(1);
    t.check_peers().expect("revived fleet is healthy");
    let f = t.send(0, 1, &p, op_deadline()).expect("send after revive");
    let mut out = vec![0.0f32; 4];
    t.recv_into(0, 1, f, &mut out, op_deadline()).expect("recv after revive");
    t.close();
}

#[test]
fn local_wire_corruption_fails_the_digest_check() {
    let t = LocalTransport::new(2);
    t.corrupt_next_frames(1);
    let p = payload(16, 900);
    let f = t.send(0, 1, &p, op_deadline()).expect("send");
    let mut out = vec![0.0f32; 16];
    let err = t.recv_into(0, 1, f, &mut out, op_deadline()).expect_err("digest must fail");
    assert!(matches!(err, AlstError::CorruptPayload { .. }), "got {err:?}");
    assert!(err.is_retryable());
    // The corruption budget is spent: the next frame is clean.
    let f = t.send(0, 1, &p, op_deadline()).expect("send clean");
    t.recv_into(0, 1, f, &mut out, op_deadline()).expect("clean frame verifies");
    assert_eq!(bits(&out), bits(&p));
    t.close();
}

#[test]
fn local_recv_with_no_frame_expires_typed() {
    let t = LocalTransport::new(2);
    let mut out = vec![0.0f32; 4];
    let err = t
        .recv_into(0, 1, 0, &mut out, Deadline::after(Duration::from_millis(30)))
        .expect_err("no frame ever arrives");
    assert!(matches!(err, AlstError::Transient { .. }), "deadline expiry is Transient, got {err:?}");
    assert!(err.is_retryable());
    t.close();
}

#[test]
fn socket_recv_against_silent_wire_expires_typed() {
    let t = thread_socket(2);
    let mut out = vec![0.0f32; 4];
    // Frame 0 was never sent: the data socket stays silent and the read
    // deadline must surface as a typed Transient, not a hang.
    let err = t
        .recv_into(0, 1, 0, &mut out, Deadline::after(Duration::from_millis(50)))
        .expect_err("silent wire");
    assert!(matches!(err, AlstError::Transient { .. }), "got {err:?}");
    assert!(err.is_retryable());
    t.close();
}

/// The process-mode row the acceptance contract names: real rank
/// processes spawned from the built `alst` binary, a real SIGKILL, typed
/// detection through the side channels, and `heal()` restoring service.
#[test]
fn socket_process_workers_survive_kill_and_heal() {
    let opts = SocketOptions {
        worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_alst"))),
        connect_timeout: Duration::from_secs(10),
        heartbeat_interval: Duration::from_millis(10),
        heartbeat_timeout: Duration::from_millis(500),
        ..SocketOptions::default()
    };
    let t = SocketTransport::spawn(2, opts, Tracer::off()).expect("spawn rank processes");

    // Healthy fleet carries traffic.
    roundtrips_are_bit_identical(&*t);
    healthy_fleet_passes_check_peers(&*t);
    let frames_before_kill = t.frames_via(1);
    assert!(frames_before_kill > 0, "roundtrips must have moved frames via rank 1");

    // Genuinely external death: SIGKILL the rank-1 worker process. The
    // liveness gate must *detect* it (EOF or heartbeat silence on the
    // side channel) as a typed LostRank — bounded, never hanging.
    t.kill_rank(1);
    let detect = Deadline::after(Duration::from_secs(5));
    let err = loop {
        match t.check_peers() {
            Err(e) => break e,
            Ok(()) => {
                assert!(!detect.expired(), "kill of rank 1 was never detected");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    };
    assert!(matches!(err, AlstError::LostRank { rank: 1, .. }), "got {err:?}");

    // heal() respawns exactly the dead rank with a clean worker and
    // resets its frame counter; the fleet then carries traffic again.
    assert_eq!(t.heal().expect("heal"), 1);
    assert_eq!(t.frames_via(1), 0, "healed rank restarts its frame count");
    t.check_peers().expect("healed fleet is healthy");
    roundtrips_are_bit_identical(&*t);
    t.close();
}
