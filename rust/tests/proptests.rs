//! Property-based tests (hand-rolled runner — proptest is unavailable in
//! this offline image; `check` runs each property over many seeded random
//! cases and reports the failing case on panic).

use alst::collectives::Group;
use alst::config::{preset, ClusterConfig, FeatureFlags, ParallelConfig};
use alst::coordinator::dataloader::{shard_sequence, shift_labels, IGNORE_INDEX};
use alst::coordinator::optimizer::{AdamW, AdamWConfig};
use alst::coordinator::ulysses::{
    a2a_head_to_seq, a2a_seq_to_head, head_start, heads_per_rank, sp_is_valid,
};
use alst::coordinator::zero::ShardedStore;
use alst::memory::{max_seqlen_search, Estimator};
use alst::runtime::HostTensor;
use alst::util::json::Json;
use alst::util::rng::Rng;

/// Run `prop` over `cases` seeded cases; on failure, re-panic with the seed.
fn check(name: &str, cases: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(seed * 7919 + 13);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            panic!("property `{name}` failed at seed {seed}: {e:?}");
        }
    }
}

fn random_tensor(rng: &mut Rng, shape: &[usize]) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::f32(shape.to_vec(), rng.normal_vec(n, 1.0))
}

// ---------------------------------------------------------------------------
// Ulysses relayout properties
// ---------------------------------------------------------------------------

#[test]
fn prop_a2a_round_trip_identity() {
    check("a2a round trip", 40, |rng| {
        let sp = [1usize, 2, 4, 8][rng.below(4)];
        let heads = sp * (1 + rng.below(3)); // divisible, no replication
        let ssh = 1 + rng.below(16);
        let d = 1 + rng.below(8);
        let shards: Vec<HostTensor> =
            (0..sp).map(|_| random_tensor(rng, &[ssh, heads, d])).collect();
        let g = Group::new(sp);
        let full = a2a_seq_to_head(&g, &shards);
        let back = a2a_head_to_seq(&g, &full, heads, false);
        assert_eq!(shards, back);
    });
}

#[test]
fn prop_a2a_replication_grad_flow_conserves_sum() {
    // sum over all gradient elements is conserved by the backward a2a,
    // including the kv-replication (sum_replicas) case.
    check("a2a grad conservation", 40, |rng| {
        let sp = [2usize, 4, 8][rng.below(3)];
        let n_kv = 1 + rng.below(sp); // may be < sp (replication)
        if !sp_is_valid(sp * 4, n_kv, sp) {
            return;
        }
        let kv_sh = heads_per_rank(n_kv, sp);
        let seq = sp * (1 + rng.below(8));
        let d = 1 + rng.below(4);
        let shards: Vec<HostTensor> =
            (0..sp).map(|_| random_tensor(rng, &[seq, kv_sh, d])).collect();
        let total_in: f64 = shards
            .iter()
            .map(|t| t.as_f32().unwrap().iter().map(|&x| x as f64).sum::<f64>())
            .sum();
        let g = Group::new(sp);
        let back = a2a_head_to_seq(&g, &shards, n_kv, true);
        let total_out: f64 = back
            .iter()
            .map(|t| t.as_f32().unwrap().iter().map(|&x| x as f64).sum::<f64>())
            .sum();
        assert!(
            (total_in - total_out).abs() < 1e-3 * total_in.abs().max(1.0),
            "{total_in} vs {total_out}"
        );
    });
}

#[test]
fn prop_head_assignment_partitions_q_heads() {
    // Every q head is owned by exactly one rank; kv head ownership covers
    // all ranks' needs (paper §3.2.1).
    check("head partition", 60, |rng| {
        let sp = 1 << rng.below(6);
        let n_q = sp * (1 + rng.below(4));
        let q_sh = heads_per_rank(n_q, sp);
        let mut seen = vec![0usize; n_q];
        for r in 0..sp {
            let start = r * q_sh;
            for h in start..start + q_sh {
                seen[h] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "q heads not partitioned");
        // kv: head_start is monotone and within range
        let n_kv = 1 + rng.below(n_q);
        let mut prev = 0;
        for r in 0..sp {
            let h = head_start(r, n_kv, sp);
            assert!(h < n_kv);
            assert!(h >= prev);
            prev = h;
        }
    });
}

// ---------------------------------------------------------------------------
// ZeRO sharding properties
// ---------------------------------------------------------------------------

#[test]
fn prop_flat_shard_round_trip() {
    check("sharded store round trip", 60, |rng| {
        let total = 1 + rng.below(4000);
        let world = 1 + rng.below(16);
        let flat: Vec<f32> = (0..total).map(|_| rng.normal() as f32).collect();
        let store = ShardedStore::from_flat(&flat, world);
        assert_eq!(store.to_flat(), flat);
        // arbitrary range gather equals the slice
        let a = rng.below(total);
        let b = a + rng.below(total - a + 1);
        let g = Group::new(world);
        assert_eq!(store.gather_range(&g, a..b).unwrap(), flat[a..b]);
    });
}

#[test]
fn prop_reduce_into_range_equals_direct_sum() {
    check("reduce-scatter correctness", 40, |rng| {
        let total = 16 + rng.below(500);
        let world = 1 + rng.below(8);
        let a = rng.below(total);
        let b = (a + 1 + rng.below(total - a)).min(total);
        let mut store = ShardedStore::zeros(total, world);
        let contribs: Vec<Vec<f32>> = (0..world)
            .map(|_| (0..b - a).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = contribs.iter().map(|c| c.as_slice()).collect();
        let g = Group::new(world);
        store.reduce_into_range(&g, a..b, &refs).unwrap();
        let flat = store.to_flat();
        for i in 0..total {
            let want: f32 = if (a..b).contains(&i) {
                contribs.iter().map(|c| c[i - a]).sum()
            } else {
                0.0
            };
            assert!((flat[i] - want).abs() < 1e-4, "idx {i}");
        }
    });
}

#[test]
fn prop_adamw_world_invariance() {
    check("adamw sharding invariance", 20, |rng| {
        let n = 8 + rng.below(64);
        let init: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let grads: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut outs = Vec::new();
        for world in [1usize, 3, 8] {
            let mut p = ShardedStore::from_flat(&init, world);
            let g = ShardedStore::from_flat(&grads, world);
            let mut opt = AdamW::new(AdamWConfig::default(), n, world);
            opt.step(&mut p, &g);
            outs.push(p.to_flat());
        }
        for w in 1..outs.len() {
            for i in 0..n {
                assert!(
                    (outs[0][i] - outs[w][i]).abs() < 1e-6,
                    "divergence at {i}"
                );
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Dataloader / labels properties
// ---------------------------------------------------------------------------

#[test]
fn prop_shifted_labels_partition_tokens() {
    // Concatenated shard labels == shift(full) exactly; nothing dropped
    // at shard boundaries for ANY valid sp (the §4.3 bug class).
    check("label sharding", 60, |rng| {
        let sp = [1usize, 2, 4, 8][rng.below(4)];
        let ssh = 1 + rng.below(32);
        let seq = sp * ssh;
        let ids: Vec<i32> = (0..seq).map(|_| rng.below(1000) as i32).collect();
        let shards = shard_sequence(&ids, sp);
        let flat: Vec<i32> =
            shards.iter().flat_map(|s| s.labels.clone()).collect();
        assert_eq!(flat, shift_labels(&ids));
        assert_eq!(flat.iter().filter(|&&l| l == IGNORE_INDEX).count(), 1);
        // positions are the identity permutation
        let pos: Vec<i32> =
            shards.iter().flat_map(|s| s.positions.clone()).collect();
        assert_eq!(pos, (0..seq as i32).collect::<Vec<_>>());
    });
}

// ---------------------------------------------------------------------------
// Memory simulator properties
// ---------------------------------------------------------------------------

#[test]
fn prop_estimator_monotone_in_seq() {
    check("estimator seq monotonicity", 20, |rng| {
        let model = preset(["llama3-8b", "llama3-70b", "qwen3-32b"][rng.below(3)]).unwrap();
        let flags = if rng.below(2) == 0 {
            FeatureFlags::baseline()
        } else {
            FeatureFlags::alst()
        };
        let est = Estimator::new(model, ClusterConfig::h100(1), flags);
        let s1 = 1_000 + rng.below(1_000_000);
        let s2 = s1 * 2;
        let b1 = est.breakdown(s1, 8).device_total();
        let b2 = est.breakdown(s2, 8).device_total();
        assert!(b2 >= b1, "seq {s1}->{s2}: {b1} -> {b2}");
    });
}

#[test]
fn prop_search_result_is_tight() {
    check("search tightness", 12, |rng| {
        let model = preset(["llama3-8b", "qwen3-32b"][rng.below(2)]).unwrap();
        let world = [8usize, 16, 32][rng.below(3)];
        let est = Estimator::new(
            model,
            ClusterConfig::h100(world.div_ceil(8)),
            FeatureFlags::alst(),
        );
        let out = max_seqlen_search(&est, world);
        if out.max_seqlen > 0 {
            assert!(est.fits(out.max_seqlen, world), "reported max must fit");
            assert!(
                !est.fits(out.max_seqlen + 2_000, world),
                "max+2K must not fit (quantum 1K)"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// Topology + util properties
// ---------------------------------------------------------------------------

#[test]
fn prop_grid_bijection() {
    check("dp x sp grid bijection", 40, |rng| {
        let dp = 1 + rng.below(8);
        let sp = 1 + rng.below(8);
        let p = ParallelConfig::new(dp, sp);
        let mut seen = vec![false; p.world_size()];
        for d in 0..dp {
            for s in 0..sp {
                let r = p.rank_of(d, s);
                assert!(!seen[r]);
                seen[r] = true;
                assert_eq!(p.coords(r), (d, s));
            }
        }
        // groups are consistent
        for r in 0..p.world_size() {
            assert!(p.sp_group(r).contains(&r));
            assert!(p.dp_group(r).contains(&r));
            assert_eq!(p.sp_group(r).len(), sp);
            assert_eq!(p.dp_group(r).len(), dp);
        }
    });
}

#[test]
fn prop_json_round_trip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(100000) as f64) - 50000.0),
            3 => Json::Str(format!("s{}", rng.below(1000))),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json round trip", 80, |rng| {
        let j = random_json(rng, 3);
        let text = j.to_string_pretty();
        let back = Json::parse(&text).expect("reparse");
        assert_eq!(j, back, "{text}");
    });
}

#[test]
fn prop_alst_features_never_hurt_max_seqlen() {
    // adding any single ALST feature to any base flag set must not
    // DECREASE the achievable sequence length (memory monotonicity).
    check("feature monotonicity", 16, |rng| {
        let model = preset(["llama3-8b", "qwen3-32b"][rng.below(2)]).unwrap();
        let world = [8usize, 32][rng.below(2)];
        let mut base = FeatureFlags::baseline();
        // random subset of ALST features already on
        if rng.below(2) == 0 { base.tiled_loss = true; }
        if rng.below(2) == 0 { base.ulysses_sp = true; }
        if rng.below(2) == 0 { base.tiled_mlp = true; }
        let cluster = ClusterConfig::h100(world.div_ceil(8));
        let before =
            max_seqlen_search(&Estimator::new(model, cluster.clone(), base), world).max_seqlen;
        for add in 0..4 {
            let mut f = base;
            match add {
                0 => f.tiled_loss = true,
                1 => f.ulysses_sp = true,
                2 => f.tiled_mlp = true,
                _ => f.ckpt_offload = true,
            }
            let after =
                max_seqlen_search(&Estimator::new(model, cluster.clone(), f), world).max_seqlen;
            assert!(
                after >= before,
                "feature {add} hurt: {before} -> {after} ({})",
                f.describe()
            );
        }
    });
}

// ---------------------------------------------------------------------------
// Sequence-packing properties
// ---------------------------------------------------------------------------

use alst::packing::{
    gather_shards, pack_ffd, pack_first_fit_reference, shard_packed, Document, Pack,
    PackedSequence, PackingStats,
};

fn random_docs(rng: &mut Rng, capacity: usize) -> Vec<Document> {
    let n = 1 + rng.below(24);
    (0..n)
        .map(|i| {
            let len = 1 + rng.below(capacity);
            Document::new(
                i as u64,
                (0..len).map(|_| rng.below(1000) as i32).collect(),
            )
        })
        .collect()
}

#[test]
fn prop_packer_loses_and_duplicates_nothing() {
    // every token of every document appears exactly once across all packs,
    // in order within its document, and capacity is never exceeded.
    check("packer conservation", 60, |rng| {
        let capacity = 8 + rng.below(120);
        let docs = random_docs(rng, capacity);
        let total: usize = docs.iter().map(Document::len).sum();
        let packs = pack_ffd(docs.clone(), capacity).unwrap();
        let mut seen: Vec<Option<&Document>> = vec![None; docs.len()];
        for p in &packs {
            assert!(p.used() <= p.capacity, "pack over capacity");
            assert_eq!(p.capacity, capacity);
            for d in &p.docs {
                assert!(seen[d.id as usize].is_none(), "doc {} duplicated", d.id);
                seen[d.id as usize] = Some(d);
            }
        }
        for (i, s) in seen.iter().enumerate() {
            let d = s.unwrap_or_else(|| panic!("doc {i} lost"));
            assert_eq!(d.tokens, docs[i].tokens, "doc {i} tokens mutated");
        }
        assert_eq!(packs.iter().map(Pack::used).sum::<usize>(), total);
        let stats = PackingStats::from_packs(&packs);
        assert!(stats.efficiency() > 0.0 && stats.efficiency() <= 1.0);
        assert!(stats.n_packs >= total.div_ceil(capacity), "impossible pack count");
    });
}

#[test]
fn prop_best_fit_never_packs_worse_than_first_fit() {
    // the ordered-index best-fit packer must match or beat the retained
    // linear first-fit reference in pack count (=> identical-or-better
    // efficiency) on the same corpus, at O(n log n) instead of O(n·bins).
    //
    // CAVEAT: BFD vs FFD dominance is NOT a theorem — the two heuristics
    // are incomparable on adversarial instances. This check is pinned to
    // the fixed SplitMix64 seeds below (pre-verified exhaustively, plus a
    // 5000-instance sweep with zero BFD>FFD cases); if the seed formula,
    // case count, or random_docs distribution changes, re-verify rather
    // than assuming the inequality transfers.
    check("best-fit vs first-fit", 60, |rng| {
        let capacity = 8 + rng.below(120);
        let docs = random_docs(rng, capacity);
        let best = pack_ffd(docs.clone(), capacity).unwrap();
        let first = pack_first_fit_reference(docs, capacity).unwrap();
        assert!(
            best.len() <= first.len(),
            "best-fit used {} packs, first-fit {}",
            best.len(),
            first.len()
        );
        // same corpus either way: token totals agree
        assert_eq!(
            best.iter().map(Pack::used).sum::<usize>(),
            first.iter().map(Pack::used).sum::<usize>()
        );
        let (eb, ef) = (
            PackingStats::from_packs(&best).efficiency(),
            PackingStats::from_packs(&first).efficiency(),
        );
        assert!(eb >= ef - 1e-12, "efficiency regressed: {eb} < {ef}");
    });
}

#[test]
fn prop_positions_reset_at_every_cu_boundary() {
    // for ANY document-length distribution: position ids are 0 at each
    // cu_seqlens boundary and increment by 1 inside a segment; segment
    // ids are contiguous (each segment is one uninterrupted run).
    check("packed position reset", 60, |rng| {
        let capacity = 8 + rng.below(200);
        let docs = random_docs(rng, capacity);
        let p = PackedSequence::from_documents(&docs).unwrap();
        assert_eq!(p.cu_seqlens.len(), p.n_segments() + 1);
        for s in 0..p.n_segments() {
            let r = p.segment_range(s);
            assert_eq!(p.positions[r.start], 0, "position not reset at segment {s}");
            for (off, i) in r.clone().enumerate() {
                assert_eq!(p.positions[i], off as i32, "non-monotone position");
                assert_eq!(p.seg_ids[i], s as i32, "segment {s} not contiguous");
            }
        }
        // seg ids are non-decreasing overall (packed layout)
        assert!(p.seg_ids.windows(2).all(|w| w[0] <= w[1]));
    });
}

#[test]
fn prop_packed_labels_stay_in_segment() {
    // acceptance criterion: shift_labels_packed never emits a target
    // token belonging to a different segment.
    check("packed label isolation", 60, |rng| {
        let capacity = 8 + rng.below(100);
        let docs = random_docs(rng, capacity);
        let packs = pack_ffd(docs, capacity).unwrap();
        for pack in &packs {
            let p = PackedSequence::from_pack(pack).unwrap();
            let labels = p.labels();
            assert_eq!(labels.len(), p.len());
            let mut masked = 0;
            for (i, &l) in labels.iter().enumerate() {
                if l == alst::coordinator::dataloader::IGNORE_INDEX {
                    masked += 1;
                } else {
                    assert_eq!(l, p.ids[i + 1], "label is not the next token");
                    assert_eq!(
                        p.seg_ids[i],
                        p.seg_ids[i + 1],
                        "label at {i} crosses a segment boundary"
                    );
                }
            }
            // every segment masks its last token; the pad segment (if any)
            // is fully masked.
            let pad = if p.has_padding() {
                p.segment_range(p.n_docs()).len()
            } else {
                0
            };
            assert_eq!(masked, p.n_docs() + pad);
        }
    });
}

#[test]
fn prop_shard_packed_preserves_all_metadata() {
    // sharding for any valid sp: concatenating the shards reproduces the
    // full packed sequence (ids, positions, segment ids, labels), local
    // boundaries map back onto global cu_seqlens, and global metadata is
    // replicated on every rank.
    check("packed sharding round trip", 40, |rng| {
        let sp = [1usize, 2, 4, 8][rng.below(4)];
        let capacity = sp * (4 + rng.below(40));
        let docs = random_docs(rng, capacity);
        for pack in pack_ffd(docs, capacity).unwrap() {
            let p = PackedSequence::from_pack(&pack).unwrap();
            let shards = shard_packed(&p, sp);
            let ssh = p.len() / sp;
            let g = gather_shards(&shards);
            assert_eq!(g.ids, p.ids);
            assert_eq!(g.seg_ids, p.seg_ids);
            assert_eq!(g.positions, p.positions);
            assert_eq!(g.labels, p.labels());
            for (r, s) in shards.iter().enumerate() {
                assert_eq!(s.cu_seqlens, p.cu_seqlens, "global metadata lost");
                assert_eq!(*s.cu_seqlens_local.first().unwrap(), 0);
                assert_eq!(*s.cu_seqlens_local.last().unwrap(), ssh as i32);
                for &c in &s.cu_seqlens_local[1..s.cu_seqlens_local.len() - 1] {
                    let global = (r * ssh) as i32 + c;
                    assert!(
                        p.cu_seqlens.contains(&global),
                        "local boundary {c} on rank {r} is not a global boundary"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_lr_schedule_is_continuous_and_bounded() {
    use alst::coordinator::pipeline::LrSchedule;
    check("lr schedule bounds", 30, |rng| {
        let sched = LrSchedule {
            peak_lr: 1e-4 + rng.uniform() as f32 * 1e-2,
            warmup_steps: rng.below(50) as u64,
            total_steps: 50 + rng.below(500) as u64,
            min_lr: 1e-6,
        };
        let mut prev = sched.lr_at(0);
        assert!(prev > 0.0);
        for step in 1..sched.total_steps + 10 {
            let lr = sched.lr_at(step);
            assert!(lr >= sched.min_lr - 1e-9, "below min at {step}");
            assert!(lr <= sched.peak_lr + 1e-9, "above peak at {step}");
            // no discontinuity bigger than the warmup ramp quantum
            let max_jump = sched.peak_lr / sched.warmup_steps.max(1) as f32
                + sched.peak_lr * 0.1;
            assert!((lr - prev).abs() <= max_jump, "jump at {step}: {prev} -> {lr}");
            prev = lr;
        }
        // decay phase ends at min_lr
        assert!((sched.lr_at(sched.total_steps) - sched.min_lr).abs() < 1e-6);
    });
}

#[test]
fn prop_timeline_peak_bounded_by_estimator_style_sum() {
    // the replayed timeline's device peak is consistent: positive, and
    // strictly higher without offload than with, for any seq/sp.
    check("timeline offload dominance", 16, |rng| {
        let model = preset("llama3-8b").unwrap();
        let sp = [1usize, 2, 4, 8][rng.below(4)];
        let seq = sp * (1_000 + rng.below(500_000));
        let mut on = FeatureFlags::alst();
        on.ckpt_offload = true;
        let mut off = FeatureFlags::alst();
        off.ckpt_offload = false;
        let r_on =
            alst::memory::simulate_step(model, seq, sp, &on, 1 << 50, 1 << 50).unwrap();
        let r_off =
            alst::memory::simulate_step(model, seq, sp, &off, 1 << 50, 1 << 50).unwrap();
        assert!(r_on.device_peak > 0);
        assert!(r_off.device_peak >= r_on.device_peak);
        assert_eq!(r_off.host_peak, 0);
    });
}
