//! Per-step attribution: where did the step's wall-clock go?
//!
//! Each `Step` span defines a window; every *leaf* span (exec, marshal,
//! relayout, collective, offload, optimizer, stall) that starts inside the
//! window is summed into its category (fault-lane spans — retry backoff,
//! snapshot saves, recovery restores — included, so chaos runs show where
//! resilience time went). Container spans (`Step`, `Tile`)
//! are excluded so a tile sweep's time is not counted twice alongside the
//! exec spans it encloses, and the offload copy-stream lanes
//! (`CopyD2H`/`CopyH2D`) are excluded because they overlap compute — the
//! critical-path cost of a copy is the `Stall` leaf recorded where the
//! step blocked on it, so "untracked" no longer absorbs copy waits. The
//! "untracked" column is `max(0, step_time - sum(leaf durations))` — the
//! gap no span explains.
//!
//! Attribution reads as a *fraction of the step* only when rank work does
//! not overlap in time (`parallel_ranks: false`, the `trace` subcommand's
//! default); under threaded ranks the leaf sums can legitimately exceed
//! step_time because concurrent spans stack.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::bench::Table;

use super::tracer::{Category, MemEvent, Span};

#[derive(Debug, Clone, Copy, Default)]
pub struct CatTotals {
    pub dur: Duration,
    pub bytes: u64,
    pub spans: usize,
}

#[derive(Debug, Clone)]
pub struct StepAttribution {
    /// The step span's `step` attribute (optimizer step counter).
    pub step: Option<u64>,
    /// The step span's duration — set from the exact `Duration` stored in
    /// `StepMetrics::step_time`, so the two agree bit-for-bit.
    pub step_time: Duration,
    /// Leaf categories only.
    pub by_cat: BTreeMap<Category, CatTotals>,
    pub untracked: Duration,
}

impl StepAttribution {
    pub fn cat(&self, c: Category) -> CatTotals {
        self.by_cat.get(&c).copied().unwrap_or_default()
    }

    /// Sum of all leaf-category durations in this step.
    pub fn tracked(&self) -> Duration {
        self.by_cat.values().map(|t| t.dur).sum()
    }
}

#[derive(Debug, Clone)]
pub struct MemPeak {
    pub bytes: u64,
    pub span_id: Option<u64>,
    /// Name of the span that was open when the peak was reached, or
    /// `"(no span)"` when the peak happened outside any span.
    pub span_name: String,
    pub tag: String,
}

#[derive(Debug, Clone)]
pub struct AttributionReport {
    pub steps: Vec<StepAttribution>,
    /// Per-category totals over *all* spans in the trace (every category,
    /// in- and outside step windows) — the reconciliation surface:
    /// exec/marshal totals equal `EngineStats` times exactly, collective
    /// bytes equal the `CommStats` ledger.
    pub totals: BTreeMap<Category, CatTotals>,
    pub mem_peak: Option<MemPeak>,
}

fn acc(map: &mut BTreeMap<Category, CatTotals>, s: &Span) {
    let t = map.entry(s.cat).or_default();
    t.dur += s.dur();
    t.bytes += s.bytes;
    t.spans += 1;
}

impl AttributionReport {
    pub fn build(spans: &[Span], mem: &[MemEvent]) -> AttributionReport {
        let mut totals = BTreeMap::new();
        for s in spans {
            acc(&mut totals, s);
        }

        let mut step_spans: Vec<&Span> =
            spans.iter().filter(|s| s.cat == Category::Step).collect();
        step_spans.sort_by_key(|s| (s.start_ns, s.id));

        let mut steps = Vec::new();
        for ss in &step_spans {
            let mut by_cat = BTreeMap::new();
            for s in spans {
                if s.cat.is_leaf() && s.start_ns >= ss.start_ns && s.start_ns < ss.end_ns() {
                    acc(&mut by_cat, s);
                }
            }
            let step_time = ss.dur();
            let tracked: Duration = by_cat.values().map(|t: &CatTotals| t.dur).sum();
            steps.push(StepAttribution {
                step: ss.step,
                step_time,
                by_cat,
                untracked: step_time.saturating_sub(tracked),
            });
        }

        let mem_peak = mem.iter().max_by_key(|e| e.current).map(|e| {
            let span_name = e
                .span_id
                .and_then(|id| spans.iter().find(|s| s.id == id))
                .map(|s| s.name.clone())
                .unwrap_or_else(|| "(no span)".to_string());
            MemPeak {
                bytes: e.current,
                span_id: e.span_id,
                span_name,
                tag: e.tag.clone(),
            }
        });

        AttributionReport { steps, totals, mem_peak }
    }

    pub fn total(&self, c: Category) -> CatTotals {
        self.totals.get(&c).copied().unwrap_or_default()
    }

    /// The ASCII attribution table (milliseconds per category per step).
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            "per-step attribution (ms)",
            &[
                "step",
                "total",
                "exec",
                "marshal",
                "relayout",
                "collective",
                "offload",
                "optimizer",
                "ring",
                "stall",
                "fault",
                "untracked",
            ],
        );
        let ms = |d: Duration| format!("{:.3}", d.as_secs_f64() * 1e3);
        for s in &self.steps {
            t.row(&[
                s.step.map(|v| v.to_string()).unwrap_or_else(|| "-".into()),
                ms(s.step_time),
                ms(s.cat(Category::Exec).dur),
                ms(s.cat(Category::Marshal).dur),
                ms(s.cat(Category::Relayout).dur),
                ms(s.cat(Category::Collective).dur),
                ms(s.cat(Category::Offload).dur),
                ms(s.cat(Category::Optimizer).dur),
                ms(s.cat(Category::Ring).dur),
                ms(s.cat(Category::Stall).dur),
                ms(s.cat(Category::Fault).dur),
                ms(s.untracked),
            ]);
        }
        t
    }

    /// Byte-ledger and memory-peak summary lines printed under the table.
    pub fn summary_lines(&self) -> Vec<String> {
        let mut out = Vec::new();
        for c in Category::ALL {
            let t = self.total(c);
            if t.spans > 0 {
                out.push(format!(
                    "  {:<10} {:>6} spans  {:>12} bytes  {:>10.3} ms",
                    c.as_str(),
                    t.spans,
                    t.bytes,
                    t.dur.as_secs_f64() * 1e3
                ));
            }
        }
        if let Some(p) = &self.mem_peak {
            out.push(format!(
                "  memory peak: {} bytes (tag `{}`) inside span `{}`",
                p.bytes, p.tag, p.span_name
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::tracer::Tracer;

    fn span(
        t: &Tracer,
        cat: Category,
        name: &str,
        dur_ns: u64,
        bytes: u64,
        step: Option<u64>,
    ) {
        let mut g = t.span(cat, name);
        g.set_dur(Duration::from_nanos(dur_ns));
        g.set_bytes(bytes);
        if let Some(s) = step {
            g.set_step(s);
        }
    }

    #[test]
    fn untracked_gap_is_step_minus_leaf_sum() {
        let t = Tracer::new(true);
        // A wide synthetic window: the leaf guards below are created some
        // real microseconds after the step span opens and must land inside.
        let step_time = Duration::from_secs(1);
        {
            let mut stp = t.span(Category::Step, "train_step");
            stp.set_dur(step_time);
            stp.set_step(1);
            span(&t, Category::Exec, "fwd", 300, 0, None);
            span(&t, Category::Marshal, "upload", 100, 64, None);
            span(&t, Category::Collective, "a2a", 50, 128, None);
            // Containers never enter the sums.
            span(&t, Category::Tile, "sweep", 400, 0, None);
        }
        let rep = AttributionReport::build(&t.drain(), &[]);
        assert_eq!(rep.steps.len(), 1);
        let s = &rep.steps[0];
        assert_eq!(s.step, Some(1));
        assert_eq!(s.step_time, step_time);
        assert_eq!(s.tracked(), Duration::from_nanos(450));
        assert_eq!(s.untracked, step_time - Duration::from_nanos(450));
        assert_eq!(s.cat(Category::Exec).dur, Duration::from_nanos(300));
        assert_eq!(s.cat(Category::Collective).bytes, 128);
        assert!(s.by_cat.get(&Category::Tile).is_none());
    }

    #[test]
    fn spans_outside_step_windows_count_only_in_totals() {
        let t = Tracer::new(true);
        span(&t, Category::Marshal, "warmup", 10, 32, None);
        // Ensure the step window opens strictly after the warmup span.
        std::thread::sleep(Duration::from_millis(1));
        {
            let mut stp = t.span(Category::Step, "train_step");
            stp.set_dur(Duration::from_secs(1));
            span(&t, Category::Exec, "fwd", 40, 0, None);
        }
        let rep = AttributionReport::build(&t.drain(), &[]);
        assert_eq!(rep.steps.len(), 1);
        assert_eq!(rep.steps[0].cat(Category::Marshal).spans, 0);
        assert_eq!(rep.total(Category::Marshal).bytes, 32);
        assert_eq!(rep.total(Category::Exec).spans, 1);
    }

    #[test]
    fn mem_peak_names_causing_span() {
        let t = Tracer::new(true);
        let id = {
            let mut g = t.span(Category::Tile, "loss_fwd_tiles");
            g.set_dur(Duration::from_nanos(10));
            g.id()
        };
        let mem = vec![
            MemEvent { ts_ns: 1, span_id: Some(id), tag: "loss_head".into(), delta: 512, current: 512 },
            MemEvent { ts_ns: 2, span_id: None, tag: "mlp".into(), delta: 128, current: 128 },
        ];
        let rep = AttributionReport::build(&t.drain(), &mem);
        let p = rep.mem_peak.unwrap();
        assert_eq!(p.bytes, 512);
        assert_eq!(p.span_name, "loss_fwd_tiles");
        assert_eq!(p.tag, "loss_head");
    }

    #[test]
    fn table_has_one_row_per_step() {
        let t = Tracer::new(true);
        for i in 0..3u64 {
            let mut stp = t.span(Category::Step, "train_step");
            stp.set_dur(Duration::from_nanos(100));
            stp.set_step(i + 1);
        }
        let rep = AttributionReport::build(&t.drain(), &[]);
        let table = rep.to_table();
        assert_eq!(table.rows.len(), 3);
        assert_eq!(table.header.len(), 12);
        assert!(table.to_csv().starts_with("step,total,exec"));
        assert!(table.header.contains(&"stall".to_string()));
        assert!(table.header.contains(&"fault".to_string()));
    }

    #[test]
    fn stall_is_attributed_but_overlapped_copies_are_not() {
        let t = Tracer::new(true);
        let step_time = Duration::from_secs(1);
        {
            let mut stp = t.span(Category::Step, "train_step");
            stp.set_dur(step_time);
            stp.set_step(1);
            span(&t, Category::Exec, "fwd", 500, 0, None);
            // The engine blocked 200ns waiting for an H2D copy: that IS
            // critical-path time and must not land in "untracked".
            span(&t, Category::Stall, "stall_h2d", 200, 64, None);
            // The copies themselves ran on the stream workers, overlapped
            // with the exec above — summing them would double-count.
            span(&t, Category::CopyD2H, "d2h_copy", 400, 64, None);
            span(&t, Category::CopyH2D, "h2d_copy", 300, 64, None);
        }
        let rep = AttributionReport::build(&t.drain(), &[]);
        let s = &rep.steps[0];
        assert_eq!(s.cat(Category::Stall).dur, Duration::from_nanos(200));
        assert_eq!(s.tracked(), Duration::from_nanos(700));
        assert_eq!(s.untracked, step_time - Duration::from_nanos(700));
        assert!(s.by_cat.get(&Category::CopyD2H).is_none());
        assert!(s.by_cat.get(&Category::CopyH2D).is_none());
        // Copy lanes still reconcile in the whole-trace totals.
        assert_eq!(rep.total(Category::CopyD2H).bytes, 64);
        assert_eq!(rep.total(Category::CopyH2D).spans, 1);
    }
}
