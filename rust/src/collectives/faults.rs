//! Typed fault taxonomy, deterministic fault injection, and the retry
//! policy behind resilient training (DESIGN.md §Fault model & recovery).
//!
//! The headline multi-million-token runs take minutes per step; a rank
//! failure mid-step must unwind as a *value*, not a poison cascade. This
//! module provides the three pieces everything else builds on:
//!
//! * [`AlstError`] — the typed failure set. Collective ops, offload
//!   copies, and stage executions return these (wrapped in `anyhow`) so a
//!   supervisor can `downcast_ref::<AlstError>()` and decide: retryable
//!   faults ([`AlstError::is_retryable`]) are absorbed in place with
//!   exponential backoff; `LostRank` aborts the step and restores from the
//!   last snapshot (`coordinator::recover`).
//! * [`FaultInjector`] — a deterministic, seeded chaos source. A
//!   [`FaultPlan`] names one site class (Nth collective op / Nth offload
//!   copy on a rank / Nth stage exec on a rank) and a [`FaultKind`]; the
//!   injector fires exactly once at that index, so a faulted run is
//!   reproducible and the retry that follows deterministically succeeds.
//!   `CorruptPayload` faults are *real*: the op's output bytes are
//!   corrupted post-compute and must be caught by the per-transfer
//!   checksum ([`checksum_f32s`]) before the retry.
//! * [`lock_clean`] — poison-recovering lock access for the shared
//!   ledgers (`CommStats`, `EngineStats`, tracer shards, offload state).
//!   Every guarded update in this codebase is a commutative increment or
//!   a whole-value swap, so the data is consistent even if the holder
//!   panicked mid-critical-section; recovering the guard lets the panic
//!   surface once, as a typed `RankPanic`, instead of cascading poison
//!   panics through every other rank's ledger access.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use crate::obs::{Category, Tracer};
use crate::runtime::tensor::HostTensor;

/// Lock a mutex, recovering from poisoning. See the module docs for why
/// this is sound for every ledger in this crate: guarded state is either
/// a commutative counter or replaced wholesale, never left half-built.
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Where in a step a fault can strike.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// A `Group` collective (direct op or `account_*` ledger entry).
    /// Collectives are group-wide: the op index alone selects the fault.
    Collective,
    /// One D2H/H2D copy in the async offload engine (indexed per rank).
    OffloadCopy,
    /// One stage execution on a rank (indexed per rank).
    StageExec,
    /// The transport itself: a framed send/recv, a deadline expiry, a
    /// heartbeat lapse. Never produced by the `FaultInjector` — these are
    /// real I/O failures mapped by `collectives::transport` — but they
    /// flow through the same `AlstError` taxonomy so supervisors treat
    /// simulated and real faults identically.
    Wire,
}

impl FaultSite {
    pub fn as_str(self) -> &'static str {
        match self {
            FaultSite::Collective => "collective",
            FaultSite::OffloadCopy => "offload_copy",
            FaultSite::StageExec => "stage_exec",
            FaultSite::Wire => "wire",
        }
    }
}

/// What kind of failure the injector produces at the chosen site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A transient transport hiccup: the op fails before moving data and
    /// succeeds on retry. Absorbed by backoff; never reaches a supervisor.
    Transient,
    /// The rank is gone. Non-retryable: the step aborts and recovery
    /// restores from the last snapshot (optionally at a degraded world).
    LostRank,
    /// In-flight payload corruption: the op completes but its output
    /// bytes are damaged; the per-transfer checksum catches the mismatch
    /// and the op retransmits. Retryable.
    CorruptPayload,
}

/// The typed failure set. Implements `std::error::Error`, so `?` lifts
/// these into `anyhow::Error` and supervisors recover them with
/// `err.downcast_ref::<AlstError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AlstError {
    /// Transient transport failure (escapes only when retries exhaust).
    Transient { site: FaultSite, rank: usize, attempt: u32 },
    /// A rank died; the in-flight step cannot complete.
    LostRank { site: FaultSite, rank: usize },
    /// Per-transfer checksum mismatch (escapes only when retries exhaust).
    CorruptPayload { site: FaultSite, rank: usize, expect: u64, got: u64 },
    /// A rank closure panicked inside `run_ranks`; the payload message is
    /// preserved so the panic surfaces once, typed, instead of poisoning
    /// every shared ledger.
    RankPanic { rank: usize, msg: String },
    /// An offload stream worker is gone (channel closed or died on a
    /// non-retryable fault recorded in the engine state).
    WorkerDead { stream: &'static str },
}

impl AlstError {
    /// Build the error a fired fault maps to. Gate-style sites (no real
    /// payload at hand, e.g. `account_*` ledger entries) model a
    /// `CorruptPayload` as a receiver-side checksum failure with unknown
    /// digests.
    pub fn from_kind(kind: FaultKind, site: FaultSite, rank: usize) -> AlstError {
        match kind {
            FaultKind::Transient => AlstError::Transient { site, rank, attempt: 0 },
            FaultKind::LostRank => AlstError::LostRank { site, rank },
            FaultKind::CorruptPayload => {
                AlstError::CorruptPayload { site, rank, expect: 0, got: 0 }
            }
        }
    }

    /// Retry-with-backoff absorbs these; everything else unwinds the step.
    pub fn is_retryable(&self) -> bool {
        matches!(self, AlstError::Transient { .. } | AlstError::CorruptPayload { .. })
    }

    /// The rank the failure is attributed to.
    pub fn rank(&self) -> Option<usize> {
        match self {
            AlstError::Transient { rank, .. }
            | AlstError::LostRank { rank, .. }
            | AlstError::CorruptPayload { rank, .. }
            | AlstError::RankPanic { rank, .. } => Some(*rank),
            AlstError::WorkerDead { .. } => None,
        }
    }
}

impl fmt::Display for AlstError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AlstError::Transient { site, rank, attempt } => write!(
                f,
                "transient fault at {} (rank {rank}, attempt {attempt})",
                site.as_str()
            ),
            AlstError::LostRank { site, rank } => {
                write!(f, "rank {rank} lost at {}", site.as_str())
            }
            AlstError::CorruptPayload { site, rank, expect, got } => write!(
                f,
                "payload checksum mismatch at {} (rank {rank}): expect {expect:#018x}, got {got:#018x}",
                site.as_str()
            ),
            AlstError::RankPanic { rank, msg } => {
                write!(f, "rank {rank} panicked: {msg}")
            }
            AlstError::WorkerDead { stream } => {
                write!(f, "{stream} stream worker is gone")
            }
        }
    }
}

impl std::error::Error for AlstError {}

/// Exponential backoff schedule for retryable faults. The simulated wire
/// uses sub-millisecond delays so chaos tests stay fast; a real transport
/// would scale `base` up, not change the shape.
///
/// Backoff is decorrelated-jittered by default: retry number `attempt`
/// sleeps a deterministic point in `[base, base * mult^attempt]` drawn
/// from SplitMix64 (`util::rng`) seeded by `(jitter_seed, salt, attempt)`
/// — herd-safe like AWS's decorrelated jitter, but reproducible, so chaos
/// tests replay the exact same schedule. `jitter: false` restores the
/// plain exponential curve.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub max_retries: u32,
    pub base: Duration,
    pub multiplier: u32,
    /// Spread each backoff over `[base, full]` instead of sleeping the
    /// full exponential value.
    pub jitter: bool,
    /// Seeds the deterministic jitter stream; forked per (salt, attempt).
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 4,
            base: Duration::from_micros(200),
            multiplier: 2,
            jitter: true,
            jitter_seed: 0x414c_5354, // "ALST"
        }
    }
}

impl RetryPolicy {
    /// Undithered backoff ceiling before retry number `attempt` (0-based):
    /// `base * mult^attempt`.
    pub fn backoff(&self, attempt: u32) -> Duration {
        self.base * self.multiplier.saturating_pow(attempt)
    }

    /// The sleep actually taken before retry `attempt`: the jittered point
    /// in `[base, backoff(attempt)]` (or the ceiling itself with jitter
    /// off). `salt` decorrelates concurrent retriers — callers pass a
    /// stable site/rank tag so two ranks backing off from the same fault
    /// don't re-collide, while the same (seed, salt, attempt) triple
    /// always sleeps the same duration.
    pub fn backoff_for(&self, attempt: u32, salt: u64) -> Duration {
        let full = self.backoff(attempt);
        if !self.jitter || full <= self.base {
            return full;
        }
        let mut rng = crate::util::rng::Rng::new(
            self.jitter_seed
                ^ salt.wrapping_mul(0xA24B_AED4_963E_E407)
                ^ (attempt as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let span = (full - self.base).as_nanos() as u64;
        self.base + Duration::from_nanos((rng.uniform() * span as f64) as u64)
    }
}

/// Point-in-time view of the injector's event counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Faults actually fired (0 or 1 per one-shot plan).
    pub injected: u64,
    /// Retry attempts taken after retryable faults.
    pub retries: u64,
    /// Snapshot restores performed by a supervisor.
    pub recoveries: u64,
}

/// One deterministic fault: fire `kind` at the `at_op`-th operation of
/// `site`'s class. For the per-rank sites (`OffloadCopy`, `StageExec`) the
/// index counts only `rank`'s operations, so the trigger point is
/// deterministic under threaded ranks; collectives are group-wide and
/// totally ordered, so their global index suffices (`rank` then names the
/// rank the failure is attributed to).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub site: FaultSite,
    pub kind: FaultKind,
    pub rank: usize,
    /// Zero-based operation index at which the fault fires (one-shot).
    pub at_op: u64,
    /// Seeds the corrupted-bit choice for `CorruptPayload`.
    pub seed: u64,
}

/// The deterministic chaos source, shared (`Arc`) by the collectives
/// group, the offload engine, and the execution engine. One-shot: after
/// the planned fault fires, every later check passes — which is exactly
/// what makes the retry deterministic.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    armed: AtomicBool,
    fired: AtomicBool,
    /// Op counters per (site, rank-key); Collective uses key 0.
    counters: Mutex<HashMap<(FaultSite, usize), u64>>,
    injected: AtomicU64,
    retries: AtomicU64,
    recoveries: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Arc<FaultInjector> {
        Arc::new(FaultInjector {
            plan,
            armed: AtomicBool::new(true),
            fired: AtomicBool::new(false),
            counters: Mutex::new(HashMap::new()),
            injected: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
        })
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Count one operation of `site`'s class and decide whether the
    /// planned fault fires here. `rank` is required for the per-rank
    /// sites; `None` is the group-wide collective path.
    pub fn check(&self, site: FaultSite, rank: Option<usize>) -> Option<FaultKind> {
        let key_rank = match site {
            FaultSite::Collective => 0,
            _ => rank.unwrap_or(0),
        };
        let idx = {
            let mut c = lock_clean(&self.counters);
            let seen = c.entry((site, key_rank)).or_insert(0);
            let idx = *seen;
            *seen += 1;
            idx
        };
        if site != self.plan.site
            || (site != FaultSite::Collective && rank != Some(self.plan.rank))
            || idx != self.plan.at_op
            || !self.armed.load(Ordering::SeqCst)
        {
            return None;
        }
        if self.fired.swap(true, Ordering::SeqCst) {
            return None;
        }
        self.injected.fetch_add(1, Ordering::SeqCst);
        Some(self.plan.kind)
    }

    /// Stop injecting (supervisors disarm before replaying recovered
    /// steps; the one-shot `fired` latch already guarantees this, the
    /// disarm makes it explicit).
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::SeqCst);
    }

    pub fn fired(&self) -> bool {
        self.fired.load(Ordering::SeqCst)
    }

    pub fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::SeqCst);
    }

    pub fn note_recovery(&self) {
        self.recoveries.fetch_add(1, Ordering::SeqCst);
    }

    pub fn stats(&self) -> FaultStats {
        FaultStats {
            injected: self.injected.load(Ordering::SeqCst),
            retries: self.retries.load(Ordering::SeqCst),
            recoveries: self.recoveries.load(Ordering::SeqCst),
        }
    }

    /// Re-arm and zero the counters (fresh run on the same plan).
    pub fn reset(&self) {
        lock_clean(&self.counters).clear();
        self.fired.store(false, Ordering::SeqCst);
        self.armed.store(true, Ordering::SeqCst);
        self.injected.store(0, Ordering::SeqCst);
        self.retries.store(0, Ordering::SeqCst);
        self.recoveries.store(0, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// Per-transfer checksums
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Continue an FNV-1a digest over one f32 slice's little-endian bytes.
pub fn checksum_chain(mut h: u64, xs: &[f32]) -> u64 {
    for x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// FNV-1a digest of one f32 payload — the per-transfer integrity check
/// a `CorruptPayload` fault must be caught by. Bit-exact: distinguishes
/// `-0.0` from `+0.0` and every NaN payload.
pub fn checksum_f32s(xs: &[f32]) -> u64 {
    checksum_chain(FNV_OFFSET, xs)
}

/// Digest of a host tensor's payload (either dtype).
pub fn checksum_tensor(t: &HostTensor) -> u64 {
    match t.as_f32() {
        Ok(xs) => checksum_f32s(xs),
        Err(_) => {
            let mut h = FNV_OFFSET;
            if let Ok(xs) = t.as_i32() {
                for x in xs {
                    for b in x.to_le_bytes() {
                        h ^= b as u64;
                        h = h.wrapping_mul(FNV_PRIME);
                    }
                }
            }
            h
        }
    }
}

/// Simulated in-flight corruption: flip the low bit of one seeded element.
/// Guaranteed to change the payload's bit pattern (and so its checksum).
pub fn corrupt_f32s(xs: &mut [f32], seed: u64) {
    if xs.is_empty() {
        return;
    }
    let i = (seed as usize) % xs.len();
    xs[i] = f32::from_bits(xs[i].to_bits() ^ 1);
}

// ---------------------------------------------------------------------------
// Shared retry gate for the per-rank sites
// ---------------------------------------------------------------------------

/// Record one retry on the `Fault` trace lane and sleep out the backoff.
/// `injector: None` is the real-fault path (wire errors retried without a
/// chaos source armed): the pause and span still happen, only the
/// injector's retry counter has nobody to tell.
pub fn retry_pause(
    tracer: &Tracer,
    injector: Option<&FaultInjector>,
    retry: &RetryPolicy,
    rank: Option<usize>,
    attempt: u32,
) {
    if let Some(inj) = injector {
        inj.note_retry();
    }
    let rank = rank.or(injector.map(|i| i.plan().rank));
    let backoff = retry.backoff_for(attempt, rank.unwrap_or(0) as u64);
    {
        let mut sp = tracer.span(Category::Fault, "retry_backoff");
        if let Some(r) = rank {
            sp.set_rank(r);
        }
        sp.set_dur(backoff);
    }
    std::thread::sleep(backoff);
}

/// Gate one operation of a per-rank site (`StageExec` / `OffloadCopy`)
/// on the injector, absorbing retryable faults with backoff. Returns the
/// typed error for non-retryable faults. Used by `Engine::execute_buffers`
/// and the chaos harness's rank closures; the offload copy streams inline
/// the same logic around their real corrupt-then-verify copies.
pub fn site_gate(
    injector: &Option<Arc<FaultInjector>>,
    site: FaultSite,
    rank: usize,
    retry: &RetryPolicy,
    tracer: &Tracer,
) -> Result<(), AlstError> {
    let Some(inj) = injector else { return Ok(()) };
    let mut attempt = 0u32;
    loop {
        match inj.check(site, Some(rank)) {
            None => return Ok(()),
            Some(FaultKind::LostRank) => {
                return Err(AlstError::LostRank { site, rank });
            }
            Some(kind) => {
                if attempt >= retry.max_retries {
                    return Err(AlstError::from_kind(kind, site, rank));
                }
                retry_pause(tracer, Some(inj.as_ref()), retry, Some(rank), attempt);
                attempt += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(site: FaultSite, kind: FaultKind, rank: usize, at_op: u64) -> FaultPlan {
        FaultPlan { site, kind, rank, at_op, seed: 7 }
    }

    #[test]
    fn checksum_is_bit_exact_and_corruption_is_caught() {
        let a = vec![1.0f32, -0.0, f32::NAN, 3.5];
        let b = vec![1.0f32, 0.0, f32::NAN, 3.5];
        assert_ne!(checksum_f32s(&a), checksum_f32s(&b), "-0.0 != +0.0 bitwise");
        assert_eq!(checksum_f32s(&a), checksum_f32s(&a.clone()));
        let mut c = a.clone();
        corrupt_f32s(&mut c, 123);
        assert_ne!(checksum_f32s(&a), checksum_f32s(&c), "one flipped bit changes the digest");
        // exactly one element differs, by exactly one bit
        let diffs: Vec<u32> = a
            .iter()
            .zip(&c)
            .map(|(x, y)| (x.to_bits() ^ y.to_bits()).count_ones())
            .collect();
        assert_eq!(diffs.iter().sum::<u32>(), 1);
        // chaining over slices equals the digest of the concatenation
        let h = checksum_chain(checksum_chain(FNV_OFFSET, &a[..2]), &a[2..]);
        assert_eq!(h, checksum_f32s(&a));
    }

    #[test]
    fn corrupt_empty_is_noop() {
        let mut e: Vec<f32> = Vec::new();
        corrupt_f32s(&mut e, 5);
        assert!(e.is_empty());
    }

    #[test]
    fn injector_fires_once_at_the_planned_index() {
        let inj = FaultInjector::new(plan(FaultSite::Collective, FaultKind::Transient, 1, 2));
        assert_eq!(inj.check(FaultSite::Collective, None), None); // op 0
        assert_eq!(inj.check(FaultSite::StageExec, Some(1)), None); // other site
        assert_eq!(inj.check(FaultSite::Collective, None), None); // op 1
        assert_eq!(inj.check(FaultSite::Collective, None), Some(FaultKind::Transient)); // op 2
        assert_eq!(inj.check(FaultSite::Collective, None), None, "one-shot");
        assert!(inj.fired());
        assert_eq!(inj.stats().injected, 1);
        inj.reset();
        assert!(!inj.fired());
        assert_eq!(inj.stats(), FaultStats::default());
    }

    #[test]
    fn per_rank_sites_count_each_rank_independently() {
        let inj = FaultInjector::new(plan(FaultSite::StageExec, FaultKind::LostRank, 1, 1));
        // rank 0's ops never trigger a rank-1 plan, and don't advance
        // rank 1's counter
        assert_eq!(inj.check(FaultSite::StageExec, Some(0)), None);
        assert_eq!(inj.check(FaultSite::StageExec, Some(0)), None);
        assert_eq!(inj.check(FaultSite::StageExec, Some(1)), None); // rank1 op 0
        assert_eq!(
            inj.check(FaultSite::StageExec, Some(1)),
            Some(FaultKind::LostRank) // rank1 op 1
        );
    }

    #[test]
    fn disarm_suppresses_injection() {
        let inj = FaultInjector::new(plan(FaultSite::Collective, FaultKind::LostRank, 0, 0));
        inj.disarm();
        assert_eq!(inj.check(FaultSite::Collective, None), None);
        assert!(!inj.fired());
    }

    #[test]
    fn error_taxonomy_classifies_retryability() {
        let t = AlstError::Transient { site: FaultSite::Collective, rank: 2, attempt: 1 };
        let c = AlstError::CorruptPayload {
            site: FaultSite::OffloadCopy,
            rank: 0,
            expect: 1,
            got: 2,
        };
        let l = AlstError::LostRank { site: FaultSite::StageExec, rank: 3 };
        let p = AlstError::RankPanic { rank: 1, msg: "boom".into() };
        assert!(t.is_retryable() && c.is_retryable());
        assert!(!l.is_retryable() && !p.is_retryable());
        assert_eq!(l.rank(), Some(3));
        // Display carries the site and rank; anyhow round-trips the type.
        let any: anyhow::Error = l.clone().into();
        assert_eq!(any.downcast_ref::<AlstError>(), Some(&l));
        assert!(any.to_string().contains("rank 3 lost at stage_exec"));
    }

    #[test]
    fn retry_policy_backoff_is_exponential() {
        let r = RetryPolicy {
            max_retries: 3,
            base: Duration::from_micros(100),
            multiplier: 2,
            ..Default::default()
        };
        assert_eq!(r.backoff(0), Duration::from_micros(100));
        assert_eq!(r.backoff(1), Duration::from_micros(200));
        assert_eq!(r.backoff(3), Duration::from_micros(800));
    }

    #[test]
    fn jittered_backoff_is_bounded_deterministic_and_decorrelated() {
        let r = RetryPolicy {
            max_retries: 4,
            base: Duration::from_micros(100),
            multiplier: 2,
            jitter: true,
            jitter_seed: 42,
        };
        for attempt in 0..4u32 {
            let d = r.backoff_for(attempt, 1);
            assert!(d >= r.base, "jitter never sleeps under base");
            assert!(d <= r.backoff(attempt), "jitter never exceeds the ceiling");
            // deterministic: same (seed, salt, attempt) → same sleep
            assert_eq!(d, r.backoff_for(attempt, 1));
        }
        // attempt 0's range is degenerate: [base, base]
        assert_eq!(r.backoff_for(0, 9), r.base);
        // different salts (ranks) decorrelate the later attempts
        assert_ne!(r.backoff_for(3, 0), r.backoff_for(3, 1));
        // different seeds decorrelate too
        let r2 = RetryPolicy { jitter_seed: 43, ..r };
        assert_ne!(r.backoff_for(3, 1), r2.backoff_for(3, 1));
        // jitter off restores the plain exponential curve
        let plain = RetryPolicy { jitter: false, ..r };
        assert_eq!(plain.backoff_for(3, 1), plain.backoff(3));
    }

    #[test]
    fn site_gate_absorbs_transients_and_surfaces_lost_rank() {
        let retry = RetryPolicy { base: Duration::from_micros(10), ..Default::default() };
        let tracer = Tracer::off();

        let inj = Some(FaultInjector::new(plan(
            FaultSite::StageExec,
            FaultKind::Transient,
            0,
            0,
        )));
        site_gate(&inj, FaultSite::StageExec, 0, &retry, &tracer).unwrap();
        let stats = inj.as_ref().unwrap().stats();
        assert_eq!((stats.injected, stats.retries), (1, 1));

        let inj = Some(FaultInjector::new(plan(
            FaultSite::StageExec,
            FaultKind::LostRank,
            0,
            0,
        )));
        let err = site_gate(&inj, FaultSite::StageExec, 0, &retry, &tracer).unwrap_err();
        assert_eq!(err, AlstError::LostRank { site: FaultSite::StageExec, rank: 0 });
        assert!(!err.is_retryable());

        // no injector: free pass
        site_gate(&None, FaultSite::StageExec, 0, &retry, &tracer).unwrap();
    }

    #[test]
    fn lock_clean_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(41u64));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex is poisoned");
        let mut g = lock_clean(&m);
        *g += 1;
        assert_eq!(*g, 42);
    }
}
