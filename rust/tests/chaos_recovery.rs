//! The fault-site sweep: the recovery contract must hold no matter WHERE
//! a fault lands, not just at hand-picked spots.
//!
//! For each plan (Ulysses, Ring), world (sp 2 and 4), and rank-execution
//! mode (threaded, serial), an unfaulted 2-step chaos-harness run counts
//! its collective ops; then one faulted run per op index injects a fault
//! at exactly that op — alternating a lost rank (must restore from
//! snapshot and replay) with a transient (must be absorbed in place by
//! retry/backoff) — and every run must end with parameters bit-identical
//! to the unfaulted reference, balanced host/device ledgers, and (sampled)
//! a steady-state arena. Companion sweeps cover the per-rank stage-exec
//! gates and the checksummed offload copy streams (corrupt payloads
//! included).

use alst::collectives::faults::{FaultKind, FaultPlan, FaultSite};
use alst::collectives::{SocketOptions, TransportKind, WorkerFailMode, WorkerFailure};
use alst::config::PlanKind;
use alst::coordinator::recover::{
    run_resilient, ChaosConfig, ChaosHarness, Recoverable, ResilienceOptions,
};
use std::time::Duration;

fn snap(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("alst-chaos-sweep");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.alst"))
}

fn cfg(
    plan: PlanKind,
    sp: usize,
    threaded: bool,
    fault: Option<FaultPlan>,
) -> ChaosConfig {
    ChaosConfig {
        sp,
        seq: 16,
        n_layers: 2,
        plan,
        threaded,
        trace: false,
        fault_plan: fault,
        ..ChaosConfig::default()
    }
}

/// Unfaulted 2-step run: final params + the sweep bound (successful
/// collective ops across both steps).
fn reference(plan: PlanKind, sp: usize, threaded: bool) -> (Vec<f32>, u64) {
    let mut h = ChaosHarness::new(cfg(plan, sp, threaded, None)).unwrap();
    let opts = ResilienceOptions {
        snapshot_every: 1,
        ..ResilienceOptions::new(snap(&format!("ref-{plan:?}-{sp}-{threaded}")))
    };
    run_resilient(&mut h, 2, &opts).unwrap();
    (h.params_flat(), h.collective_ops())
}

/// One faulted run at one (site, rank, op) point; asserts the full
/// recovery contract against `want`.
fn check_point(
    plan: PlanKind,
    sp: usize,
    threaded: bool,
    fault: FaultPlan,
    want: &[f32],
    steady_check: bool,
) {
    let tag = format!(
        "{plan:?}-{sp}-{threaded}-{:?}-{:?}-r{}-op{}",
        fault.site, fault.kind, fault.rank, fault.at_op
    );
    let kind = fault.kind;
    let mut h = ChaosHarness::new(cfg(plan, sp, threaded, Some(fault))).unwrap();
    let opts = ResilienceOptions {
        snapshot_every: 1,
        ..ResilienceOptions::new(snap(&tag))
    };
    let report = run_resilient(&mut h, 2, &opts)
        .unwrap_or_else(|e| panic!("{tag}: supervisor failed: {e:#}"));
    assert_eq!(report.fault.injected, 1, "{tag}: fault never fired");
    match kind {
        FaultKind::LostRank => {
            assert_eq!(report.recoveries, 1, "{tag}: lost rank must restore once");
        }
        FaultKind::Transient | FaultKind::CorruptPayload => {
            assert_eq!(report.recoveries, 0, "{tag}: retryable fault must not restore");
            assert!(report.fault.retries >= 1, "{tag}: retryable fault never retried");
        }
    }
    assert_eq!(h.params_flat(), want, "{tag}: diverged from unfaulted reference");
    assert_eq!(h.host_bytes(), 0, "{tag}: leaked host bytes");
    assert_eq!(h.device_bytes(), 0, "{tag}: leaked device bytes");
    if steady_check {
        // two further unfaulted steps take/recycle in balance: the pool
        // footprint stops changing once recovery settled
        h.step_once().unwrap();
        let one = (h.arena().pooled(), h.arena().pooled_bytes());
        h.step_once().unwrap();
        let two = (h.arena().pooled(), h.arena().pooled_bytes());
        assert_eq!(one, two, "{tag}: arena not steady after recovery");
    }
}

fn sweep_collectives(plan: PlanKind) {
    for sp in [2usize, 4] {
        for threaded in [true, false] {
            let (want, total_ops) = reference(plan, sp, threaded);
            assert!(
                total_ops >= 10,
                "{plan:?} sp={sp}: suspicious sweep bound {total_ops}"
            );
            for op in 0..total_ops {
                let kind = if op % 2 == 0 {
                    FaultKind::LostRank
                } else {
                    FaultKind::Transient
                };
                let fault = FaultPlan {
                    site: FaultSite::Collective,
                    kind,
                    rank: 0,
                    at_op: op,
                    seed: op ^ 0xa5,
                };
                check_point(plan, sp, threaded, fault, &want, op % 7 == 0);
            }
        }
    }
}

#[test]
fn every_collective_op_recovers_under_ulysses() {
    sweep_collectives(PlanKind::Ulysses);
}

#[test]
fn every_collective_op_recovers_under_ring() {
    sweep_collectives(PlanKind::Ring);
}

/// Per-rank stage gates: every (rank, gate index) of the 2-step run, both
/// thread modes, lost ranks alternating with transients.
#[test]
fn every_stage_gate_recovers() {
    let (plan, sp, n_layers) = (PlanKind::Ulysses, 4usize, 2u64);
    for threaded in [true, false] {
        let (want, _) = reference(plan, sp, threaded);
        for rank in 0..sp {
            for op in 0..2 * n_layers {
                let kind = if (op + rank as u64) % 2 == 0 {
                    FaultKind::LostRank
                } else {
                    FaultKind::Transient
                };
                let fault = FaultPlan {
                    site: FaultSite::StageExec,
                    kind,
                    rank,
                    at_op: op,
                    seed: 31 + op,
                };
                check_point(plan, sp, threaded, fault, &want, op == 0);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Real faults over SocketTransport: the same contract, nothing simulated
// ---------------------------------------------------------------------------

/// Socket-mode config: spawned rank processes (the test binary's own
/// `alst rank-worker`), fast heartbeats, short timeouts so a failing
/// detection shows up as a typed error, never a hung test.
fn socket_cfg(failure: Option<WorkerFailure>) -> ChaosConfig {
    ChaosConfig {
        sp: 2,
        seq: 16,
        n_layers: 2,
        plan: PlanKind::Ulysses,
        threaded: false,
        trace: false,
        fault_plan: None,
        transport: TransportKind::Socket,
        socket: Some(SocketOptions {
            worker_bin: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_alst"))),
            heartbeat_interval: Duration::from_millis(10),
            heartbeat_timeout: Duration::from_millis(300),
            failure,
            ..SocketOptions::default()
        }),
        op_timeout: Some(Duration::from_secs(5)),
    }
}

/// The local-transport reference the socket runs must match bit-for-bit.
fn local_reference(tag: &str) -> Vec<f32> {
    let mut h = ChaosHarness::new(cfg(PlanKind::Ulysses, 2, false, None)).unwrap();
    let opts = ResilienceOptions {
        snapshot_every: 1,
        ..ResilienceOptions::new(snap(&format!("real-{tag}-ref")))
    };
    run_resilient(&mut h, 2, &opts).unwrap();
    h.params_flat()
}

/// One real worker-failure mode through the full supervisor loop: clean
/// socket run pins bit-identity and measures the per-step frame budget,
/// then the victim's worker misbehaves mid-step-2 and the run must
/// detect it on the wire, restore exactly once, and land on the
/// reference parameters with balanced ledgers.
fn real_fault_roundtrip(mode: WorkerFailMode, tag: &str) {
    let want = local_reference(tag);
    let mut clean = ChaosHarness::new(socket_cfg(None)).unwrap();
    let opts = ResilienceOptions {
        snapshot_every: 1,
        ..ResilienceOptions::new(snap(&format!("real-{tag}-clean")))
    };
    let clean_rep = run_resilient(&mut clean, 2, &opts).unwrap();
    assert_eq!(clean_rep.recoveries, 0, "{tag}: clean socket run restored");
    assert_eq!(clean.params_flat(), want, "{tag}: socket transport not bit-identical");
    let total = clean.socket_transport().unwrap().frames_via(1);
    assert!(total >= 4, "{tag}: rank 1 relayed only {total} frames");
    // Fuse at 1.5x the per-step budget: the failure fires mid-collective
    // in step 2, after the step-1 snapshot exists.
    let after = total / 2 + total / 4;
    let failure = WorkerFailure { rank: 1, mode, after };
    let mut h = ChaosHarness::new(socket_cfg(Some(failure))).unwrap();
    let opts = ResilienceOptions {
        snapshot_every: 1,
        ..ResilienceOptions::new(snap(&format!("real-{tag}-fault")))
    };
    let report = run_resilient(&mut h, 2, &opts)
        .unwrap_or_else(|e| panic!("{tag}: supervisor failed: {e:#}"));
    assert_eq!(report.recoveries, 1, "{tag}: must restore exactly once");
    assert_eq!(h.params_flat(), want, "{tag}: diverged from the reference");
    assert_eq!(h.host_bytes(), 0, "{tag}: leaked host bytes");
    assert_eq!(h.device_bytes(), 0, "{tag}: leaked device bytes");
}

/// A rank process dying mid-collective (the worker hard-exits once its
/// frame fuse blows; `heal` must respawn it at a bumped generation).
#[test]
fn killed_rank_process_recovers_bit_identically() {
    real_fault_roundtrip(WorkerFailMode::Kill, "kill");
}

/// A frame torn mid-payload: the echo stops halfway and the process
/// exits. The receiver sees a short read, surfaces it as a retryable
/// corrupt payload, and the retry against the now-dead peer escalates to
/// the typed lost rank the supervisor recovers from.
#[test]
fn truncated_frame_recovers_bit_identically() {
    real_fault_roundtrip(WorkerFailMode::Truncate, "truncate");
}

/// A hung-but-not-dead peer: the data socket stays open while the
/// heartbeat side-channel falls silent. Liveness gating must call it a
/// lost rank once the silence outlives the timeout — distinguishing hung
/// from merely slow — and the supervisor recovers as for a death.
#[test]
fn stalled_heartbeat_is_detected_and_recovered() {
    let want = local_reference("stall");
    let failure =
        Some(WorkerFailure { rank: 1, mode: WorkerFailMode::StallHeartbeat, after: 2 });
    let mut h = ChaosHarness::new(socket_cfg(failure)).unwrap();
    let st = h.socket_transport().unwrap().clone();
    // Wait for the two beats the victim will ever send, then let the
    // silence outlive the 300ms heartbeat timeout before stepping.
    let t0 = std::time::Instant::now();
    while st.beats_from(1) < 2 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(st.beats_from(1) >= 2, "victim never started beating");
    std::thread::sleep(Duration::from_millis(450));
    let opts = ResilienceOptions {
        snapshot_every: 1,
        ..ResilienceOptions::new(snap("real-stall-fault"))
    };
    let report = run_resilient(&mut h, 2, &opts).unwrap();
    assert_eq!(report.recoveries, 1, "hung peer must trigger exactly one restore");
    assert_eq!(h.params_flat(), want, "recovered run diverged from the reference");
    assert_eq!((h.host_bytes(), h.device_bytes()), (0, 0), "leaked bytes");
}

/// Offload copy streams: every copy op of one rank's 2-step run — D2H
/// stores and H2D fetches interleave, so the sweep hits both directions.
/// Corrupt payloads are caught by the per-transfer checksums and retried
/// from the intact source; lost ranks latch the engine and recover
/// through abort + restore.
#[test]
fn every_offload_copy_op_recovers() {
    let (plan, sp, n_layers) = (PlanKind::Ulysses, 2usize, 2u64);
    let threaded = true;
    let (want, _) = reference(plan, sp, threaded);
    // per step per rank: n_layers d2h stores + n_layers h2d fetches
    for op in 0..2 * (2 * n_layers) {
        let kind = match op % 3 {
            0 => FaultKind::LostRank,
            1 => FaultKind::CorruptPayload,
            _ => FaultKind::Transient,
        };
        let fault = FaultPlan {
            site: FaultSite::OffloadCopy,
            kind,
            rank: 1,
            at_op: op,
            seed: 77 + op,
        };
        check_point(plan, sp, threaded, fault, &want, op % 3 == 0);
    }
}
