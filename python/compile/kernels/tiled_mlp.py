"""Sequence-tiled SwiGLU MLP (paper §3.1.1 TiledMLP).

The paper chunks `hidden_states` on the sequence dimension so that the
`[TS, F]` gate/up intermediates — not the full `[S, F]` — are live at any
moment, reporting ~10x layer memory savings at 256K×4096 (Figure 4) with
`ceil(seqlen / hidden) = 63` auto-deduced shards.

Here the same schedule is a 1-D Pallas grid over sequence tiles: BlockSpec
streams one `[TS, H]` slab of x through VMEM per step while the weights stay
resident. Backward is a `custom_vjp` with the identical tiling written as a
`lax.scan` (one tile's intermediates recomputed per step), mirroring the
paper's per-shard autograd replay.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def auto_shards(seqlen: int, hidden: int) -> int:
    """Paper's shard deduction: ceil(seqlen / hidden_size)."""
    return max(1, math.ceil(seqlen / hidden))


def _mlp_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = x_ref[...]                                   # [TS, H] slab in VMEM
    g = x @ wg_ref[...]                              # [TS, F]
    u = x @ wu_ref[...]
    o_ref[...] = (jax.nn.silu(g) * u) @ wd_ref[...]  # back to [TS, H]


def mlp_forward(x, wg, wu, wd, *, tile_s: int, interpret: bool = True):
    s, h = x.shape
    f = wg.shape[1]
    assert s % tile_s == 0, (s, tile_s)
    return pl.pallas_call(
        _mlp_kernel,
        grid=(s // tile_s,),
        in_specs=[
            pl.BlockSpec((tile_s, h), lambda i: (i, 0)),
            pl.BlockSpec((h, f), lambda i: (0, 0)),
            pl.BlockSpec((h, f), lambda i: (0, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_s, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, h), x.dtype),
        interpret=interpret,
    )(x, wg, wu, wd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def mlp_tiled(x, wg, wu, wd, tile_s: int = 128):
    """Sequence-tiled SwiGLU MLP: y = (silu(x@wg) * (x@wu)) @ wd."""
    return _mlp_fwd(x, wg, wu, wd, tile_s)[0]


def _mlp_fwd(x, wg, wu, wd, tile_s):
    y = mlp_forward(x, wg, wu, wd, tile_s=tile_s)
    return y, (x, wg, wu, wd)


def _mlp_bwd(tile_s, res, d_y):
    x, wg, wu, wd = res
    s, h = x.shape
    n = s // tile_s

    def body(carry, idx):
        d_wg, d_wu, d_wd = carry
        xs = jax.lax.dynamic_slice_in_dim(x, idx * tile_s, tile_s, 0)
        d_ys = jax.lax.dynamic_slice_in_dim(d_y, idx * tile_s, tile_s, 0)
        # Recompute this tile's forward intermediates (TiledCompute replay).
        g = xs @ wg
        u = xs @ wu
        sg = jax.nn.sigmoid(g)
        silu_g = g * sg
        a = silu_g * u                     # [TS, F]
        d_a = d_ys @ wd.T
        d_u = d_a * silu_g
        d_silu = d_a * u
        d_g = d_silu * (sg + g * sg * (1.0 - sg))   # d silu(g)/dg
        d_xs = d_g @ wg.T + d_u @ wu.T
        return (
            d_wg + xs.T @ d_g,
            d_wu + xs.T @ d_u,
            d_wd + a.T @ d_ys,
        ), d_xs

    zeros = (jnp.zeros_like(wg), jnp.zeros_like(wu), jnp.zeros_like(wd))
    (d_wg, d_wu, d_wd), d_x_tiles = jax.lax.scan(body, zeros, jnp.arange(n))
    return d_x_tiles.reshape(s, h), d_wg, d_wu, d_wd


mlp_tiled.defvjp(_mlp_fwd, _mlp_bwd)
