//! Micro-bench harness (criterion is unavailable offline): warmup, timed
//! iterations, mean/median/p95 reporting, table emission for the paper
//! reproduction benches, and machine-readable `BENCH_*.json` reports —
//! the perf trajectory the repo commits alongside optimization PRs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// CI smoke mode: `ALST_BENCH_FAST=1` shrinks every bench to a handful of
/// iterations so the whole suite finishes in seconds. The JSON reports
/// are still emitted (and record `fast_mode`), the numbers are just not
/// meaningful for comparison.
pub fn fast_mode() -> bool {
    std::env::var_os("ALST_BENCH_FAST").is_some_and(|v| v != "0" && !v.is_empty())
}

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// Logical bytes moved per iteration (set with `with_bytes`); powers
    /// the GiB/s column of the JSON report.
    pub bytes_per_iter: Option<u64>,
    /// Bench-specific numeric fields (set with `with_extra`), serialized
    /// verbatim into the JSON record — e.g. the offload rows' `stall_ms`
    /// / `copy_ms` / `overlap_frac` that CI bench-smoke validates.
    pub extras: BTreeMap<String, f64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12?}  median {:>12?}  p95 {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.median, self.p95, self.min
        )
    }

    /// Attach the per-iteration data volume (for throughput reporting).
    pub fn with_bytes(mut self, bytes: u64) -> BenchResult {
        self.bytes_per_iter = Some(bytes);
        self
    }

    /// Attach a bench-specific numeric field to the JSON record.
    pub fn with_extra(mut self, key: &str, value: f64) -> BenchResult {
        self.extras.insert(key.to_string(), value);
        self
    }

    /// Median-based throughput in GiB/s, when a data volume is attached.
    pub fn gib_per_s(&self) -> Option<f64> {
        let b = self.bytes_per_iter?;
        let s = self.median.as_secs_f64();
        if s <= 0.0 {
            return None;
        }
        Some(b as f64 / s / (1u64 << 30) as f64)
    }

    /// Machine-readable record (BENCH_*.json schema, documented in
    /// DESIGN.md): times in integer nanoseconds, bytes as logical volume
    /// per iteration, `gib_per_s` derived from the median.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_ns".to_string(), Json::Num(self.mean.as_nanos() as f64));
        m.insert("median_ns".to_string(), Json::Num(self.median.as_nanos() as f64));
        m.insert("p95_ns".to_string(), Json::Num(self.p95.as_nanos() as f64));
        m.insert("min_ns".to_string(), Json::Num(self.min.as_nanos() as f64));
        if let Some(b) = self.bytes_per_iter {
            m.insert("bytes_per_iter".to_string(), Json::Num(b as f64));
        }
        if let Some(g) = self.gib_per_s() {
            m.insert("gib_per_s".to_string(), Json::Num(g));
        }
        for (k, v) in &self.extras {
            m.insert(k.clone(), Json::Num(*v));
        }
        Json::Obj(m)
    }
}

/// Run `f` repeatedly: `warmup` throwaway iterations, then at least
/// `min_iters` and at least `min_time` of measurement. Under `fast_mode`
/// the warmup/iteration/time floors are clamped for CI smoke runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize,
                         min_time: Duration, mut f: F) -> BenchResult {
    let (warmup, min_iters, min_time) = if fast_mode() {
        (warmup.min(1), min_iters.min(2), min_time.min(Duration::from_millis(5)))
    } else {
        (warmup, min_iters, min_time)
    };
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters.max(1) || start.elapsed() < min_time {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        median: samples[samples.len() / 2],
        p95: samples[(samples.len() as f64 * 0.95) as usize % samples.len()],
        min: samples[0],
        bytes_per_iter: None,
        extras: BTreeMap::new(),
    };
    println!("{}", res.report());
    res
}

/// Quick default: 2 warmups, >=10 iters, >=300ms.
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 2, 10, Duration::from_millis(300), f)
}

/// Accumulates `BenchResult`s into the repo-root `BENCH_<name>.json`
/// perf-trajectory file. Schema (see DESIGN.md §Bench trajectory):
///
/// ```json
/// { "bench": "ulysses", "schema": 1, "fast_mode": false,
///   "results": [ { "name": ..., "iters": ..., "mean_ns": ...,
///                  "median_ns": ..., "p95_ns": ..., "min_ns": ...,
///                  "bytes_per_iter": ..., "gib_per_s": ... } ] }
/// ```
pub struct BenchReport {
    bench: String,
    results: Vec<Json>,
}

impl BenchReport {
    pub fn new(bench: &str) -> BenchReport {
        BenchReport { bench: bench.to_string(), results: Vec::new() }
    }

    pub fn push(&mut self, r: &BenchResult) {
        self.results.push(r.to_json());
    }

    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("bench".to_string(), Json::Str(self.bench.clone()));
        m.insert("schema".to_string(), Json::Num(1.0));
        m.insert("fast_mode".to_string(), Json::Bool(fast_mode()));
        m.insert(
            "generated_by".to_string(),
            Json::Str(format!("cargo bench --bench bench_{}", self.bench)),
        );
        m.insert("results".to_string(), Json::Arr(self.results.clone()));
        Json::Obj(m)
    }

    /// Write `BENCH_<bench>.json` at the repo root (the parent of the
    /// rust crate — resolved from the compile-time manifest dir, so it
    /// lands in the same place regardless of the invocation cwd).
    pub fn write_repo_root(&self) -> std::io::Result<PathBuf> {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("crate dir has a parent")
            .to_path_buf();
        let path = root.join(format!("BENCH_{}.json", self.bench));
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(&path, text)?;
        Ok(path)
    }
}

/// Fixed-width table printer for the paper-table benches.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    /// Also emit machine-readable CSV (used by EXPERIMENTS.md collection).
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.header.join(","));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

/// Human formatting for sequence lengths (paper style: 32K, 3.7M, 15M).
pub fn fmt_seqlen(s: usize) -> String {
    if s >= 1_000_000 {
        let m = s as f64 / 1_000_000.0;
        if m >= 10.0 { format!("{:.0}M", m) } else { format!("{:.1}M", m) }
    } else if s >= 1_000 {
        format!("{}K", s / 1_000)
    } else {
        s.to_string()
    }
}

pub fn fmt_duration_hms(d: Duration) -> String {
    let total = d.as_secs();
    format!("{}:{:02}:{:02}", total / 3600, (total % 3600) / 60, total % 60)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 1, 5, Duration::from_millis(1), || {});
        assert!(r.iters >= 2);
        assert!(r.min <= r.median && r.median <= r.p95);
    }

    #[test]
    fn bench_result_json_round_trips() {
        let r = BenchResult {
            name: "a2a seq->head".to_string(),
            iters: 12,
            mean: Duration::from_nanos(1_500),
            median: Duration::from_nanos(1_000),
            p95: Duration::from_nanos(3_000),
            min: Duration::from_nanos(900),
            bytes_per_iter: None,
            extras: BTreeMap::new(),
        }
        .with_bytes(1 << 30)
        .with_extra("stall_ms", 1.25)
        .with_extra("overlap_frac", 0.5);
        // 1 GiB in 1000ns -> 1e6 GiB/s
        assert!((r.gib_per_s().unwrap() - 1e6).abs() < 1.0);
        let j = r.to_json();
        assert_eq!(j.str_field("name").unwrap(), "a2a seq->head");
        assert_eq!(j.usize_field("median_ns").unwrap(), 1_000);
        assert_eq!(j.usize_field("bytes_per_iter").unwrap(), 1 << 30);
        // extras serialize verbatim as numeric fields
        assert!((j.f64_field("stall_ms").unwrap() - 1.25).abs() < 1e-12);
        assert!((j.f64_field("overlap_frac").unwrap() - 0.5).abs() < 1e-12);
        // report wraps it with schema metadata and reparses cleanly
        let mut rep = BenchReport::new("ulysses");
        rep.push(&r);
        assert_eq!(rep.len(), 1);
        let text = rep.to_json().to_string_pretty();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.str_field("bench").unwrap(), "ulysses");
        assert_eq!(back.field("results").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(back.usize_field("schema").unwrap(), 1);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,bb\n1,2\n");
    }

    #[test]
    fn seqlen_formatting_matches_paper_style() {
        assert_eq!(fmt_seqlen(32_768), "32K");
        assert_eq!(fmt_seqlen(500_000), "500K");
        assert_eq!(fmt_seqlen(3_700_000), "3.7M");
        assert_eq!(fmt_seqlen(15_000_000), "15M");
    }

    #[test]
    fn hms_formatting() {
        assert_eq!(fmt_duration_hms(Duration::from_secs(17)), "0:00:17");
        assert_eq!(fmt_duration_hms(Duration::from_secs(6455)), "1:47:35");
    }
}
