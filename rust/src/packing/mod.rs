//! Sequence-packing subsystem: variable-length corpora end-to-end.
//!
//! The paper's recipe assumes real corpora — many variable-length
//! documents packed into one multi-million-token sequence, with
//! position-id-aware attention so tokens never attend across sample
//! boundaries (§3.4) and labels that never target across them (§4.3,
//! §7.2's SDPA warning). This module is that data path for the rust
//! coordinator:
//!
//! * `packer`   — first-fit-decreasing bin-packing + efficiency stats.
//! * `sequence` — `PackedSequence` (ids, segment ids, per-document
//!   position ids, FlashAttention-style `cu_seqlens`) and the
//!   segment-aware label shift `shift_labels_packed`.
//! * `adapter`  — SP sharding that preserves segment metadata across
//!   rank boundaries, `DocumentSource` streams, and `PackedDataLoader`.
//!
//! Downstream: `coordinator::pipeline::Trainer::train_step_packed`
//! consumes packed shards and reports per-document loss;
//! `perf::train_flos_packed` / `memory`'s packed arithmetic model the
//! cost as Σᵢ Sᵢ² instead of S². The segment/position layout convention
//! is pinned to `python/compile/kernels/packed_attn.py` and
//! cross-checked by `rust/tests/packed_integration.rs`.

pub mod adapter;
pub mod packer;
pub mod sequence;

pub use adapter::{
    gather_shards, shard_packed, DocumentSource, GatheredSequence, MixedLengthSource,
    PackedDataLoader, PackedShard,
};
pub use packer::{
    chunk_document, pack_ffd, pack_first_fit_reference, Document, Pack, PackingStats,
};
pub use sequence::{shift_labels_packed, PackedSequence, PAD_TOKEN};
