//! In-process collectives over per-rank buffers, with exact byte
//! accounting fed to the perf model.
//!
//! Substitution note (DESIGN.md): the paper runs NCCL over NVLink/EFA;
//! here an SP/DP group is a set of rank-indexed `HostTensor` slots and a
//! collective is a deterministic data relayout. The *logic* (who sends
//! what where, replication, reduction) is identical — transport differs.
//! Byte counts are asserted against the closed-form volumes, and the
//! roofline model turns them into modeled wire time.
//!
//! Buffer discipline: every collective has an `_into` variant that writes
//! its output into `ScratchArena`-recycled buffers and accumulates in
//! place — at steady state the simulated wire allocates nothing (the
//! FPDT observation that buffer reuse, not bandwidth, decides long-
//! sequence throughput). The ledger sits behind a `Mutex` so a `Group`
//! can be shared with the scoped rank threads; each op is one commutative
//! integer update, so the totals are deterministic under any
//! interleaving.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::obs::{Category, Tracer};
use crate::runtime::tensor::{HostTensor, ScratchArena};

/// Traffic ledger for one process group.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CommStats {
    pub all_gather_bytes: u64,
    pub reduce_scatter_bytes: u64,
    pub all_to_all_bytes: u64,
    pub all_reduce_bytes: u64,
    /// Neighbor-exchange (ring send/recv) traffic — the transport of the
    /// ring attention plan's rotating KV blocks.
    pub send_recv_bytes: u64,
    pub ops: u64,
}

impl CommStats {
    pub fn total_bytes(&self) -> u64 {
        self.all_gather_bytes
            + self.reduce_scatter_bytes
            + self.all_to_all_bytes
            + self.all_reduce_bytes
            + self.send_recv_bytes
    }
}

/// A communicator over `world` in-process ranks.
#[derive(Debug)]
pub struct Group {
    pub world: usize,
    stats: Mutex<CommStats>,
    /// Span recorder (the shared disabled handle by default). Every
    /// ledger increment — a collective performed here or an `account_*`
    /// call from a data-structure owner — pairs with exactly one
    /// `Collective` span carrying the same byte count, so the span byte
    /// sum equals `CommStats::total_bytes()` under tracing.
    tracer: Arc<Tracer>,
}

impl Group {
    pub fn new(world: usize) -> Group {
        assert!(world >= 1);
        Group { world, stats: Mutex::default(), tracer: Tracer::off() }
    }

    pub fn set_tracer(&mut self, tracer: Arc<Tracer>) {
        self.tracer = tracer;
    }

    /// The group's tracer handle — relayouts and other callers that ledger
    /// through `account_*` use it to wrap their own timed spans.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    pub fn stats(&self) -> CommStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.lock().unwrap() = CommStats::default();
    }

    // -- silent ledger (no spans; the public surface pairs each increment
    //    with exactly one Collective span) --------------------------------
    fn ledger_gather(&self, bytes: u64) {
        let mut st = self.stats.lock().unwrap();
        st.all_gather_bytes += bytes;
        st.ops += 1;
    }

    fn ledger_reduce_scatter(&self, bytes: u64) {
        let mut st = self.stats.lock().unwrap();
        st.reduce_scatter_bytes += bytes;
        st.ops += 1;
    }

    fn ledger_all_to_all(&self, bytes: u64) {
        let mut st = self.stats.lock().unwrap();
        st.all_to_all_bytes += bytes;
        st.ops += 1;
    }

    fn ledger_all_reduce(&self, bytes: u64) {
        let mut st = self.stats.lock().unwrap();
        st.all_reduce_bytes += bytes;
        st.ops += 1;
    }

    fn ledger_send_recv(&self, bytes: u64) {
        let mut st = self.stats.lock().unwrap();
        st.send_recv_bytes += bytes;
        st.ops += 1;
    }

    /// All-gather of equal-length f32 shards: each rank contributes its
    /// shard; result is the concatenation (same for all ranks). Wire
    /// volume per rank: (world-1)/world * total (ring), accounted as the
    /// full gathered size for simplicity on the ledger, matching NCCL's
    /// algbw convention.
    pub fn all_gather(&self, shards: &[&[f32]]) -> Vec<f32> {
        let mut span = self.tracer.span(Category::Collective, "all_gather");
        assert_eq!(shards.len(), self.world);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        let mut out = Vec::with_capacity(total);
        for s in shards {
            out.extend_from_slice(s);
        }
        self.ledger_gather((total * 4) as u64);
        span.set_bytes((total * 4) as u64);
        out
    }

    /// `all_gather` into an arena-recycled buffer (allocation-free at
    /// steady state; caller recycles the result when done).
    pub fn all_gather_into(&self, shards: &[&[f32]], arena: &ScratchArena) -> Vec<f32> {
        let mut span = self.tracer.span(Category::Collective, "all_gather");
        assert_eq!(shards.len(), self.world);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        let mut out = arena.take_f32(total);
        let mut off = 0;
        for s in shards {
            out[off..off + s.len()].copy_from_slice(s);
            off += s.len();
        }
        self.ledger_gather((total * 4) as u64);
        span.set_bytes((total * 4) as u64);
        out
    }

    /// Reduce-scatter (sum): input is one full-length gradient per rank;
    /// output is rank r's reduced shard. Shard boundaries are equal
    /// `total/world` splits (caller pads to divisibility). Accumulation
    /// is in place: rank 0's slice seeds the output, the rest add.
    pub fn reduce_scatter(&self, fulls: &[&[f32]]) -> Vec<Vec<f32>> {
        let arena = ScratchArena::new(); // one-shot: plain allocations
        self.reduce_scatter_into(fulls, &arena)
    }

    /// `reduce_scatter` into arena-recycled shard buffers.
    pub fn reduce_scatter_into(
        &self,
        fulls: &[&[f32]],
        arena: &ScratchArena,
    ) -> Vec<Vec<f32>> {
        let mut span = self.tracer.span(Category::Collective, "reduce_scatter");
        assert_eq!(fulls.len(), self.world);
        let total = fulls[0].len();
        assert!(fulls.iter().all(|f| f.len() == total), "ragged reduce-scatter");
        assert_eq!(total % self.world, 0, "reduce-scatter needs padded input");
        let shard = total / self.world;
        let mut out = Vec::with_capacity(self.world);
        for r in 0..self.world {
            let base = r * shard;
            let mut dst = arena.take_f32(shard);
            dst.copy_from_slice(&fulls[0][base..base + shard]);
            for f in &fulls[1..] {
                for (d, s) in dst.iter_mut().zip(&f[base..base + shard]) {
                    *d += s;
                }
            }
            out.push(dst);
        }
        self.ledger_reduce_scatter((total * 4) as u64);
        span.set_bytes((total * 4) as u64);
        out
    }

    /// All-to-all of equal blocks: `sends[r]` holds `world` contiguous
    /// blocks; output `out[d]` is the concatenation over `r` of
    /// `sends[r]`'s block `d` (NCCL `ncclAllToAll` semantics). The
    /// head/seq-aware relayout lives in `coordinator::ulysses`; this is
    /// the generic primitive. Outputs come from the arena.
    pub fn all_to_all(&self, sends: &[&[f32]], arena: &ScratchArena) -> Vec<Vec<f32>> {
        let mut span = self.tracer.span(Category::Collective, "all_to_all");
        assert_eq!(sends.len(), self.world);
        let per_rank = sends[0].len();
        assert!(sends.iter().all(|s| s.len() == per_rank), "ragged all-to-all");
        assert_eq!(per_rank % self.world, 0, "all-to-all needs equal blocks");
        let blk = per_rank / self.world;
        let mut out = Vec::with_capacity(self.world);
        for d in 0..self.world {
            let mut dst = arena.take_f32(per_rank);
            for (r, s) in sends.iter().enumerate() {
                dst[r * blk..(r + 1) * blk].copy_from_slice(&s[d * blk..(d + 1) * blk]);
            }
            out.push(dst);
        }
        self.ledger_all_to_all((self.world * per_rank * 4) as u64);
        span.set_bytes((self.world * per_rank * 4) as u64);
        out
    }

    /// Ring neighbor exchange: rank r's buffer is delivered to rank
    /// `(r + shift) % world`, i.e. `out[d] = sends[(d + world - shift) % world]`.
    /// Unlike `all_to_all`, per-rank payloads may be ragged or empty — a
    /// rank with nothing to pass (e.g. the causal-skip ring schedule,
    /// where fully-masked KV blocks stop travelling) sends `&[]` and its
    /// neighbor receives an empty buffer at zero wire cost. Ledger volume
    /// is the sum of payload bytes actually moved.
    pub fn send_recv(&self, sends: &[&[f32]], shift: usize) -> Vec<Vec<f32>> {
        let arena = ScratchArena::new(); // one-shot: plain allocations
        self.send_recv_into(sends, shift, &arena)
    }

    /// `send_recv` into arena-recycled buffers (empty payloads bypass the
    /// pool so steady-state hit accounting only counts real traffic).
    pub fn send_recv_into(
        &self,
        sends: &[&[f32]],
        shift: usize,
        arena: &ScratchArena,
    ) -> Vec<Vec<f32>> {
        let mut span = self.tracer.span(Category::Collective, "send_recv");
        assert_eq!(sends.len(), self.world);
        assert!(
            shift % self.world != 0,
            "send_recv with shift {} over world {} moves nothing",
            shift,
            self.world
        );
        let shift = shift % self.world;
        let mut bytes = 0usize;
        let mut out = Vec::with_capacity(self.world);
        for dst in 0..self.world {
            let src = sends[(dst + self.world - shift) % self.world];
            if src.is_empty() {
                out.push(Vec::new());
                continue;
            }
            let mut buf = arena.take_f32(src.len());
            buf.copy_from_slice(src);
            bytes += src.len() * 4;
            out.push(buf);
        }
        self.ledger_send_recv(bytes as u64);
        span.set_bytes(bytes as u64);
        out
    }

    /// All-reduce (sum) of scalars — loss_sum/token-count reduction. The
    /// paper specifically replaced `all_reduce_object` with plain
    /// all_reduce to save >3 GiB/GPU (§3.3); we only ever move the scalars.
    pub fn all_reduce_scalars(&self, vals: &[f32]) -> f32 {
        let mut span = self.tracer.span(Category::Collective, "all_reduce_scalars");
        assert_eq!(vals.len(), self.world);
        self.ledger_all_reduce((vals.len() * 4) as u64);
        span.set_bytes((vals.len() * 4) as u64);
        vals.iter().sum()
    }

    /// All-reduce (sum) of one tensor per rank: returns the summed tensor
    /// each rank would hold. Accumulates in place into one output buffer
    /// (no `tensors[0].clone()` round trip through a second allocation).
    pub fn all_reduce_sum(&self, tensors: &[&HostTensor]) -> Result<HostTensor> {
        let arena = ScratchArena::new();
        self.all_reduce_sum_into(tensors, &arena)
    }

    /// `all_reduce_sum` into an arena-recycled output buffer.
    pub fn all_reduce_sum_into(
        &self,
        tensors: &[&HostTensor],
        arena: &ScratchArena,
    ) -> Result<HostTensor> {
        let mut span = self.tracer.span(Category::Collective, "all_reduce_sum");
        assert_eq!(tensors.len(), self.world);
        let shape = tensors[0].shape().to_vec();
        let first = tensors[0].as_f32()?;
        let mut acc = arena.take_f32(first.len());
        acc.copy_from_slice(first);
        for t in &tensors[1..] {
            anyhow::ensure!(t.shape() == shape.as_slice(), "shape mismatch in add");
            for (d, s) in acc.iter_mut().zip(t.as_f32()?) {
                *d += s;
            }
        }
        let out = HostTensor::f32(shape, acc);
        // ring all-reduce moves 2*(w-1)/w * bytes; ledger the logical size
        self.ledger_all_reduce(out.size_bytes() as u64);
        span.set_bytes(out.size_bytes() as u64);
        Ok(out)
    }

    /// Zero-duration instant span for an `account_*` ledger entry: the
    /// data movement happened inside the caller (which wraps its own
    /// timed span, e.g. a `Relayout`), but the byte must still appear on
    /// the Collective lane once for ledger parity.
    fn account_span(&self, name: &'static str, bytes: u64) {
        if self.tracer.enabled() {
            let mut span = self.tracer.span(Category::Collective, name);
            span.set_bytes(bytes);
            span.set_dur(Duration::ZERO);
        }
    }

    /// Record an all-to-all's traffic (the relayout itself is done by
    /// `coordinator::ulysses`, which owns the head/seq math).
    pub fn account_all_to_all(&self, bytes: u64) {
        self.account_span("all_to_all", bytes);
        self.ledger_all_to_all(bytes);
    }

    /// Ledger an all-gather performed by a data-structure owner (e.g. the
    /// ZeRO store's just-in-time parameter gather).
    pub fn account_gather(&self, bytes: u64) {
        self.account_span("all_gather", bytes);
        self.ledger_gather(bytes);
    }

    /// Ledger a reduce-scatter performed by a data-structure owner.
    pub fn account_reduce_scatter(&self, bytes: u64) {
        self.account_span("reduce_scatter", bytes);
        self.ledger_reduce_scatter(bytes);
    }

    /// Ledger a point-to-point exchange performed by a data-structure
    /// owner (e.g. the ring plan homing completed dKV block partials to
    /// their owner rank without a full rotation).
    pub fn account_send_recv(&self, bytes: u64) {
        self.account_span("send_recv", bytes);
        self.ledger_send_recv(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_gather_concatenates_in_rank_order() {
        let g = Group::new(3);
        let out = g.all_gather(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(g.stats().all_gather_bytes, 24);
    }

    #[test]
    fn all_gather_into_reuses_pooled_buffers() {
        let g = Group::new(2);
        let arena = ScratchArena::new();
        let out = g.all_gather_into(&[&[1.0, 2.0], &[3.0, 4.0]], &arena);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
        arena.recycle_f32(out);
        let out2 = g.all_gather_into(&[&[5.0, 6.0], &[7.0, 8.0]], &arena);
        assert_eq!(out2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!((arena.hits(), arena.misses()), (1, 1));
    }

    #[test]
    fn reduce_scatter_sums_and_shards() {
        let g = Group::new(2);
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let b = vec![10.0f32, 20.0, 30.0, 40.0];
        let out = g.reduce_scatter(&[&a, &b]);
        assert_eq!(out[0], vec![11.0, 22.0]);
        assert_eq!(out[1], vec![33.0, 44.0]);
        assert_eq!(g.stats().reduce_scatter_bytes, 16);
    }

    #[test]
    fn gather_then_scatter_identity() {
        // reduce_scatter(all_gather(x) replicated) == world * x shards
        let g = Group::new(2);
        let full = g.all_gather(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let out = g.reduce_scatter(&[&full, &full]);
        assert_eq!(out[0], vec![2.0, 4.0]);
        assert_eq!(out[1], vec![6.0, 8.0]);
    }

    #[test]
    fn all_to_all_transposes_blocks() {
        let g = Group::new(2);
        let arena = ScratchArena::new();
        // rank 0 sends [1,2 | 3,4]; rank 1 sends [5,6 | 7,8]
        let out = g.all_to_all(&[&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]], &arena);
        assert_eq!(out[0], vec![1.0, 2.0, 5.0, 6.0]);
        assert_eq!(out[1], vec![3.0, 4.0, 7.0, 8.0]);
        assert_eq!(g.stats().all_to_all_bytes, 32);
        // steady state: second call hits the pool after recycling
        for v in out {
            arena.recycle_f32(v);
        }
        let _ = g.all_to_all(&[&[0.0; 4], &[0.0; 4]], &arena);
        assert_eq!(arena.misses(), 2);
        assert_eq!(arena.hits(), 2);
    }

    #[test]
    fn scalar_all_reduce() {
        let g = Group::new(4);
        assert_eq!(g.all_reduce_scalars(&[1.0, 2.0, 3.0, 4.0]), 10.0);
    }

    #[test]
    fn tensor_all_reduce_sums_in_place() {
        let g = Group::new(3);
        let a = HostTensor::f32(vec![2], vec![1.0, 2.0]);
        let b = HostTensor::f32(vec![2], vec![10.0, 20.0]);
        let c = HostTensor::f32(vec![2], vec![100.0, 200.0]);
        let out = g.all_reduce_sum(&[&a, &b, &c]).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[111.0, 222.0]);
        assert_eq!(g.stats().all_reduce_bytes, 8);
        // shape mismatch is an error
        let bad = HostTensor::zeros(&[3]);
        assert!(g.all_reduce_sum(&[&a, &b, &bad]).is_err());
    }

    #[test]
    fn every_ledger_increment_pairs_one_collective_span() {
        use crate::obs::{Category, Tracer};
        let mut g = Group::new(2);
        let tracer = Arc::new(Tracer::new(true));
        g.set_tracer(tracer.clone());
        let arena = ScratchArena::new();
        let _ = g.all_gather(&[&[1.0], &[2.0]]);
        let _ = g.all_to_all(&[&[1.0, 2.0], &[3.0, 4.0]], &arena);
        let _ = g.reduce_scatter(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let _ = g.all_reduce_scalars(&[1.0, 2.0]);
        let a = HostTensor::f32(vec![2], vec![1.0, 2.0]);
        let _ = g.all_reduce_sum(&[&a, &a]).unwrap();
        let _ = g.send_recv(&[&[1.0, 2.0], &[3.0]], 1);
        g.account_gather(100);
        g.account_all_to_all(200);
        g.account_reduce_scatter(300);
        g.account_send_recv(400);
        let st = g.stats();
        let spans = tracer.drain();
        assert!(spans.iter().all(|s| s.cat == Category::Collective));
        assert_eq!(spans.len() as u64, st.ops, "one span per ledger op");
        let span_bytes: u64 = spans.iter().map(|s| s.bytes).sum();
        assert_eq!(span_bytes, st.total_bytes(), "span bytes == ledger bytes");
        // The account_* instant spans are zero-duration.
        assert!(spans
            .iter()
            .filter(|s| s.bytes >= 100)
            .all(|s| s.dur_ns == 0));
    }

    #[test]
    fn send_recv_rotates_by_shift() {
        let g = Group::new(4);
        let bufs: [&[f32]; 4] = [&[0.0], &[1.0], &[2.0], &[3.0]];
        let out = g.send_recv(&bufs, 1);
        // rank r receives rank (r-1)'s payload
        assert_eq!(out, vec![vec![3.0], vec![0.0], vec![1.0], vec![2.0]]);
        assert_eq!(g.stats().send_recv_bytes, 16);
        assert_eq!(g.stats().ops, 1);
        let out2 = g.send_recv(&bufs, 3);
        assert_eq!(out2, vec![vec![1.0], vec![2.0], vec![3.0], vec![0.0]]);
    }

    #[test]
    fn send_recv_allows_ragged_and_empty_payloads() {
        let g = Group::new(3);
        let bufs: [&[f32]; 3] = [&[1.0, 2.0, 3.0], &[], &[4.0]];
        let out = g.send_recv(&bufs, 1);
        assert_eq!(out[0], vec![4.0]);
        assert_eq!(out[1], vec![1.0, 2.0, 3.0]);
        assert!(out[2].is_empty());
        // only real payloads hit the wire: (3 + 1) * 4 bytes
        assert_eq!(g.stats().send_recv_bytes, 16);
        assert_eq!(g.stats().total_bytes(), 16);
    }

    #[test]
    fn send_recv_into_reuses_pooled_buffers() {
        let g = Group::new(2);
        let arena = ScratchArena::new();
        let out = g.send_recv_into(&[&[1.0, 2.0], &[3.0, 4.0]], 1, &arena);
        assert_eq!(out[0], vec![3.0, 4.0]);
        assert_eq!(out[1], vec![1.0, 2.0]);
        for v in out {
            arena.recycle_f32(v);
        }
        let _ = g.send_recv_into(&[&[5.0, 6.0], &[7.0, 8.0]], 1, &arena);
        assert_eq!((arena.hits(), arena.misses()), (2, 2));
    }

    #[test]
    #[should_panic(expected = "moves nothing")]
    fn send_recv_zero_shift_rejected() {
        let g = Group::new(2);
        g.send_recv(&[&[1.0], &[2.0]], 2);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_reduce_scatter_rejected() {
        let g = Group::new(2);
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 2];
        g.reduce_scatter(&[&a, &b]);
    }
}
