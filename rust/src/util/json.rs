//! Minimal JSON parser + writer — enough for `artifacts/*/manifest.json`
//! and config files. Strict on structure, permissive on whitespace.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports the missing key.
    pub fn field(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.field(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a number"))
    }

    pub fn f64_field(&self, key: &str) -> anyhow::Result<f64> {
        self.field(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a number"))
    }

    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.field(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a string"))
    }

    /// Shape fields: `[128, 64]` -> vec![128, 64].
    pub fn shape_field(&self, key: &str) -> anyhow::Result<Vec<usize>> {
        let arr = self
            .field(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not an array"))?;
        arr.iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("non-numeric dim in `{key}`"))
            })
            .collect()
    }

    // -- writer --------------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    e.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push_str("{\n");
                let pad = " ".repeat(indent + 1);
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    out.push('"');
                    out.push_str(k);
                    out.push_str("\": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (fast path, keeps UTF-8 intact)
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.field("a").unwrap().as_arr().unwrap().len(), 3);
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"config": {"hidden": 64, "name": "tiny"}, "seq": 256}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn shape_field_extracts_dims() {
        let j = Json::parse(r#"{"shape": [256, 4, 16]}"#).unwrap();
        assert_eq!(j.shape_field("shape").unwrap(), vec![256, 4, 16]);
    }

    #[test]
    fn unicode_survives() {
        let j = Json::parse("\"⇄ ulysses\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "⇄ ulysses");
    }
}
