"""Pure-jnp oracles for the ALST kernels.

Every Pallas kernel in this package has a reference implementation here that
materializes the full intermediates (the memory-hungry way the paper's
baseline does it). pytest asserts kernel == ref to tolerance; the memory
benches use the naive variants as the "before" side of Figures 3 and 4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE_INDEX = -100


# ---------------------------------------------------------------------------
# Cross-entropy (logits fully materialized) — baseline for tiled_ce.
# ---------------------------------------------------------------------------
def ce_naive(hidden, unembed, labels):
    """Full-materialization causal-LM cross entropy.

    hidden:  [S, H] f32
    unembed: [H, V] f32
    labels:  [S] i32, pre-shifted; IGNORE_INDEX entries contribute 0 loss.
    Returns (loss_sum, count) — sum over non-ignored tokens and their count.
    """
    logits = hidden @ unembed                      # [S, V] — the 8 GiB tensor
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    mask = labels != IGNORE_INDEX
    safe = jnp.where(mask, labels, 0)
    tgt = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
    per_tok = jnp.where(mask, lse - tgt, 0.0)
    return per_tok.sum(), mask.sum().astype(jnp.float32)


# ---------------------------------------------------------------------------
# SwiGLU MLP (full sequence in one pass) — baseline for tiled_mlp.
# ---------------------------------------------------------------------------
def mlp_naive(x, wg, wu, wd):
    """SwiGLU: (silu(x@wg) * (x@wu)) @ wd.

    x: [S, H], wg/wu: [H, F], wd: [F, H].
    """
    g = x @ wg
    u = x @ wu
    return (jax.nn.silu(g) * u) @ wd


# ---------------------------------------------------------------------------
# Causal attention (full [S, S] score matrix) — baseline for flash_attn.
# ---------------------------------------------------------------------------
def attention_naive(q, k, v):
    """Causal multi-head attention with GQA head repetition.

    q: [S, Hq, D], k/v: [S, Hkv, D] with Hq % Hkv == 0.
    Returns [S, Hq, D].
    """
    s, hq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale   # [Hq, S, S]
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,khd->qhd", probs, v)


# ---------------------------------------------------------------------------
# Tiled-jnp variants: same O(tile) schedule as the Pallas kernels but written
# with lax.scan — used for kernel VJPs and as the `--kernels ref` artifact
# path (compact HLO for the big e2e config on the single-core CPU runner).
# ---------------------------------------------------------------------------
def ce_tiled_jnp(hidden, unembed, labels, tile_s: int = 128):
    """Sequence-tiled fused CE with the same reduction as ce_naive."""
    s, h = hidden.shape
    assert s % tile_s == 0, (s, tile_s)
    n = s // tile_s

    def body(carry, idx):
        loss_sum, count = carry
        hs = jax.lax.dynamic_slice_in_dim(hidden, idx * tile_s, tile_s, 0)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * tile_s, tile_s, 0)
        tl, tc = ce_naive(hs, unembed, ls)
        return (loss_sum + tl, count + tc), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), jnp.arange(n)
    )
    return loss_sum, count


def mlp_tiled_jnp(x, wg, wu, wd, tile_s: int = 128):
    """Sequence-tiled SwiGLU: only one [tile_s, F] intermediate lives at once."""
    s, h = x.shape
    assert s % tile_s == 0, (s, tile_s)
    n = s // tile_s

    def body(_, idx):
        xs = jax.lax.dynamic_slice_in_dim(x, idx * tile_s, tile_s, 0)
        return None, mlp_naive(xs, wg, wu, wd)

    _, tiles = jax.lax.scan(body, None, jnp.arange(n))
    return tiles.reshape(s, wd.shape[1])
