//! Async double-buffered offload engine (FPDT-style; PAPERS.md, Yao et
//! al. 2024): two simulated copy streams — D2H for forward checkpoint
//! stores, H2D for backward prefetches — each backed by one dedicated
//! worker thread copying through the shared [`ScratchArena`].
//!
//! The sync [`CheckpointTape`] is a passive ledger: store/fetch account
//! bytes on the step's critical path and move the tensor by value. This
//! engine makes the traffic *real* (every transfer is an arena-backed
//! memcpy, so the data path is bit-preserving) and *overlappable*:
//!
//! * **Store (forward)** enqueues a non-blocking D2H copy. A
//!   `tokens_in_flight`-style byte cap bounds copies enqueued but not yet
//!   staged; the caller blocks only when the window is full (backpressure
//!   — recorded as a `Stall` span, never silently).
//! * **Prefetch (backward)** enqueues the H2D restore of layer `li-1`'s
//!   checkpoint before layer `li`'s recompute begins, when the schedule
//!   derived from `memory::timeline::prefetch_schedule` says the device
//!   can hold it. The fetch the paper notes "cannot overlap much" then
//!   completes behind compute; `fetch` blocks only on a copy that hasn't
//!   landed (a `Stall` span again).
//!
//! Each stream serializes its copies — one worker, one copy at a time —
//! which is the single-stream invariant the trace validator checks on the
//! `copy_d2h`/`copy_h2d` lanes. Stall accounting is split per direction
//! and reconciles exactly with the recorded `Stall` spans; the copy spans
//! themselves are *excluded* from per-step attribution because they
//! overlap compute (see `obs::report`).
//!
//! Inline mode (`OffloadConfig::overlap = false`) runs the identical copy
//! code on the caller thread. Every copy is then critical-path time and is
//! counted as stall, which makes it the fair "synchronous offload"
//! baseline: `stall(sync) == total copy time`, and the bench's
//! `overlap_frac = 1 - stall/copy_time` is pinned `> 0` for the async row.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::collectives::faults::{
    self, lock_clean, AlstError, FaultInjector, FaultKind, FaultSite, RetryPolicy,
};
use crate::collectives::transport::Deadline;
use crate::memory::{HostPool, MemoryTracker};
use crate::obs::{Category, Tracer};
use crate::runtime::tensor::{HostTensor, ScratchArena};

use super::tape::CheckpointTape;

/// Device-tracker tag for resident checkpoint bytes (shared with the sync
/// tape's accounting).
pub const CKPT_TAG: &str = "ckpt";

#[derive(Debug, Clone)]
pub struct OffloadConfig {
    /// Byte cap on D2H copies enqueued but not yet staged host-side (the
    /// paper's tokens-in-flight window, in bytes). `store` blocks only
    /// while the window is full. A single store larger than the cap is
    /// admitted alone once the window drains (it could otherwise never
    /// proceed).
    pub in_flight_cap: u64,
    /// `true`: copies run on the two stream worker threads and overlap
    /// compute. `false`: the same copies run inline on the caller thread
    /// and are counted as stall — the synchronous reference the bench
    /// compares against.
    pub overlap: bool,
    /// Ceiling on any single blocking wait against the engine (`store`
    /// backpressure, `fetch` on an unlanded copy, `drain`). On expiry the
    /// wait surfaces a typed error instead of hanging on a stream that
    /// will never make progress.
    pub wait_timeout: Duration,
}

impl Default for OffloadConfig {
    fn default() -> OffloadConfig {
        OffloadConfig {
            in_flight_cap: 256 << 20,
            overlap: true,
            wait_timeout: Duration::from_secs(60),
        }
    }
}

/// Time the step spent blocked on the engine, per direction.
#[derive(Debug, Clone, Copy, Default)]
pub struct StallStats {
    /// Blocked in `store` because the in-flight window was full (plus, in
    /// inline mode, the D2H copy time itself).
    pub d2h_wait: Duration,
    /// Blocked in `fetch` on an H2D copy that had not landed (plus, in
    /// inline mode, the H2D copy time itself).
    pub h2d_wait: Duration,
    pub d2h_events: u64,
    pub h2d_events: u64,
}

impl StallStats {
    pub fn total(&self) -> Duration {
        self.d2h_wait + self.h2d_wait
    }
}

/// What the copy streams did (worker-side ledger).
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamStats {
    pub copy_time_d2h: Duration,
    pub copy_time_h2d: Duration,
    pub copies_d2h: u64,
    pub copies_h2d: u64,
    /// Bytes moved across both streams — the figure that must equal the
    /// sync tape's `transfer_bytes` for the same schedule.
    pub transfer_bytes: u64,
    /// High-water mark of the D2H in-flight window (never above the cap).
    pub max_in_flight: u64,
}

impl StreamStats {
    pub fn copy_time(&self) -> Duration {
        self.copy_time_d2h + self.copy_time_h2d
    }
}

/// Fraction of copy time hidden behind compute: `1 - stall/copy`,
/// clamped to [0, 1]. Inline mode yields 0 by construction.
pub fn overlap_frac(stalls: &StallStats, stream: &StreamStats) -> f64 {
    let copy = stream.copy_time().as_secs_f64();
    if copy <= 0.0 {
        return 0.0;
    }
    (1.0 - stalls.total().as_secs_f64() / copy).clamp(0.0, 1.0)
}

/// A checkpoint's position in the store→stage→restore lifecycle.
enum SlotState {
    /// D2H copy enqueued; tensor is with the worker.
    StoreQueued { bytes: u64 },
    /// Host-resident (D2H done); `HostPool` holds its byte charge.
    Staged { tensor: HostTensor, bytes: u64 },
    /// H2D copy in progress; tensor is with the worker.
    FetchQueued { bytes: u64 },
    /// Restored; `fetch` hands it out.
    Ready { tensor: HostTensor, bytes: u64 },
    /// The copy died on a non-retryable fault. The buffer is recycled but
    /// the host charge is kept so `abort_step` balances the ledger.
    Failed { bytes: u64 },
}

impl SlotState {
    fn bytes(&self) -> u64 {
        match self {
            SlotState::StoreQueued { bytes }
            | SlotState::Staged { bytes, .. }
            | SlotState::FetchQueued { bytes }
            | SlotState::Ready { bytes, .. }
            | SlotState::Failed { bytes } => *bytes,
        }
    }
}

#[derive(Default)]
struct EngineState {
    slots: HashMap<(usize, usize), SlotState>,
    /// True per key once an H2D copy has been enqueued (idempotent
    /// prefetch; cleared when `fetch` consumes the slot).
    h2d_queued: HashMap<(usize, usize), bool>,
    /// Bytes enqueued D2H but not yet staged (the backpressure window).
    in_flight_d2h: u64,
    /// Copies enqueued but not yet completed, per stream (`drain` waits
    /// on both hitting zero).
    d2h_pending: usize,
    h2d_pending: usize,
    stream: StreamStats,
    stalls: StallStats,
    /// First non-retryable copy fault. Latches until `abort_step`; every
    /// API call fails fast with a clone while set, which is how a dead
    /// stream surfaces as a typed error instead of a silent hang.
    failed: Option<AlstError>,
}

struct Shared {
    arena: Arc<ScratchArena>,
    tracer: Arc<Tracer>,
    state: Mutex<EngineState>,
    cv: Condvar,
    /// Chaos-run fault injector (None in production). Behind a mutex so it
    /// can be installed after the engine is Arc-shared with its workers.
    injector: Mutex<Option<Arc<FaultInjector>>>,
    retry: RetryPolicy,
    /// Test hook: while set, the stream workers park before touching a
    /// job, holding the in-flight window full deterministically so the
    /// bounded waits can be driven to expiry.
    #[cfg(test)]
    pause_workers: std::sync::atomic::AtomicBool,
}

/// Poison-recovering condvar wait (see `faults::lock_clean` for why the
/// guarded state stays sound after a panicking holder).
fn wait_clean<'a>(
    cv: &Condvar,
    g: MutexGuard<'a, EngineState>,
) -> MutexGuard<'a, EngineState> {
    cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// `wait_clean` with a ceiling: sleeps until notified or `deadline`
/// passes, returning whether the deadline has expired so the caller can
/// surface a typed error instead of blocking forever on a stream that
/// stopped making progress.
fn wait_clean_deadline<'a>(
    cv: &Condvar,
    g: MutexGuard<'a, EngineState>,
    deadline: Deadline,
) -> (MutexGuard<'a, EngineState>, bool) {
    match deadline.io_timeout() {
        None => (wait_clean(cv, g), false),
        Some(t) => {
            let (g, _) = cv
                .wait_timeout(g, t)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            (g, deadline.expired())
        }
    }
}

struct CopyJob {
    li: usize,
    rank: usize,
    /// `Some` for D2H (the device tensor to stage); `None` for H2D (the
    /// worker takes the staged tensor out of the slot itself).
    tensor: Option<HostTensor>,
    bytes: u64,
}

/// The async offload engine. One per `Trainer`; shared as
/// `Arc<AsyncOffloadEngine>` so a step can hold a handle while the trainer
/// is mutably borrowed for stage execution.
pub struct AsyncOffloadEngine {
    shared: Arc<Shared>,
    d2h_tx: Option<Sender<CopyJob>>,
    h2d_tx: Option<Sender<CopyJob>>,
    workers: Vec<JoinHandle<()>>,
    cap: u64,
    overlap: bool,
    wait_timeout: Duration,
}

/// The arena copy behind both streams, run through the fault gate: a
/// transient gate fault backs off and retries; a corrupt wire is caught
/// by comparing the source checksum against the landed copy's, which is
/// then recycled and re-copied; a lost rank propagates typed. The source
/// tensor stays with the caller either way.
fn checked_copy(shared: &Shared, src: &HostTensor, rank: usize) -> Result<HostTensor, AlstError> {
    let injector = lock_clean(&shared.injector).clone();
    let Some(inj) = injector else {
        return Ok(shared.arena.copy_tensor(src));
    };
    let mut attempt = 0u32;
    loop {
        match inj.check(FaultSite::OffloadCopy, Some(rank)) {
            None => return Ok(shared.arena.copy_tensor(src)),
            Some(FaultKind::LostRank) => {
                return Err(AlstError::LostRank { site: FaultSite::OffloadCopy, rank });
            }
            Some(FaultKind::Transient) => {
                if attempt >= shared.retry.max_retries {
                    return Err(AlstError::Transient {
                        site: FaultSite::OffloadCopy,
                        rank,
                        attempt,
                    });
                }
                faults::retry_pause(&shared.tracer, Some(&*inj), &shared.retry, Some(rank), attempt);
                attempt += 1;
            }
            Some(FaultKind::CorruptPayload) => {
                let expect = faults::checksum_tensor(src);
                let mut copy = shared.arena.copy_tensor(src);
                if let Ok(d) = copy.as_f32_mut() {
                    faults::corrupt_f32s(d, inj.plan().seed);
                }
                let got = faults::checksum_tensor(&copy);
                if got == expect {
                    // empty payload: the bit flip had nothing to land on
                    return Ok(copy);
                }
                shared.arena.recycle(copy);
                if attempt >= shared.retry.max_retries {
                    return Err(AlstError::CorruptPayload {
                        site: FaultSite::OffloadCopy,
                        rank,
                        expect,
                        got,
                    });
                }
                faults::retry_pause(&shared.tracer, Some(&*inj), &shared.retry, Some(rank), attempt);
                attempt += 1;
            }
        }
    }
}

/// Stage one checkpoint host-side: the simulated D2H transfer. Runs on
/// the D2H worker (overlap) or the caller thread (inline, counted as
/// stall). A non-retryable fault marks the slot `Failed` (host charge
/// kept for `abort_step`), latches the engine error, and wakes every
/// waiter — no counter is left dangling.
fn d2h_copy(shared: &Shared, job: CopyJob, count_as_stall: bool) {
    #[cfg(test)]
    while shared.pause_workers.load(std::sync::atomic::Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(1));
    }
    let tensor = job.tensor.expect("d2h job carries the tensor");
    let mut stall = count_as_stall.then(|| {
        let mut s = shared.tracer.span(Category::Stall, "stall_d2h");
        s.set_rank(job.rank);
        s.set_bytes(job.bytes);
        s
    });
    let d = {
        let mut span = shared.tracer.span(Category::CopyD2H, "d2h_copy");
        span.set_bytes(job.bytes);
        let t0 = Instant::now();
        let copied = checked_copy(shared, &tensor, job.rank);
        shared.arena.recycle(tensor);
        let d = t0.elapsed();
        // Publish before the span guard drops so end_ns <= the state
        // update the in-flight reconstruction reads the copy span for.
        let mut st = lock_clean(&shared.state);
        match copied {
            Ok(staged) => {
                span.set_dur(d);
                st.slots.insert(
                    (job.li, job.rank),
                    SlotState::Staged { tensor: staged, bytes: job.bytes },
                );
                st.stream.copies_d2h += 1;
                st.stream.copy_time_d2h += d;
                st.stream.transfer_bytes += job.bytes;
                if count_as_stall {
                    st.stalls.d2h_wait += d;
                    st.stalls.d2h_events += 1;
                }
            }
            Err(e) => {
                span.cancel();
                st.slots.insert((job.li, job.rank), SlotState::Failed { bytes: job.bytes });
                st.failed.get_or_insert(e);
            }
        }
        // Saturating: an `abort_step` after a timed-out drain may already
        // have zeroed the window a late-retiring copy would decrement.
        st.in_flight_d2h = st.in_flight_d2h.saturating_sub(job.bytes);
        st.d2h_pending = st.d2h_pending.saturating_sub(1);
        shared.cv.notify_all();
        d
    };
    if let Some(s) = &mut stall {
        s.set_dur(d);
    }
}

/// Restore one staged checkpoint: the simulated H2D transfer. Waits for
/// the D2H stage to land first (the streams chain per slot), then copies
/// outside the lock.
fn h2d_copy(shared: &Shared, job: CopyJob, count_as_stall: bool) {
    #[cfg(test)]
    while shared.pause_workers.load(std::sync::atomic::Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(1));
    }
    let key = (job.li, job.rank);
    let (staged, bytes) = {
        let mut st = lock_clean(&shared.state);
        loop {
            match st.slots.get(&key) {
                Some(SlotState::Staged { .. }) => break,
                Some(SlotState::Failed { .. }) => {
                    // The D2H leg already died. Retire the job.
                    st.h2d_pending = st.h2d_pending.saturating_sub(1);
                    shared.cv.notify_all();
                    return;
                }
                Some(_) => st = wait_clean(&shared.cv, st),
                None => {
                    // Slot vanished (aborted step). Retire the job.
                    st.h2d_pending = st.h2d_pending.saturating_sub(1);
                    shared.cv.notify_all();
                    return;
                }
            }
        }
        let Some(SlotState::Staged { tensor, bytes }) =
            st.slots.insert(key, SlotState::FetchQueued { bytes: 0 })
        else {
            unreachable!("checked Staged under the same lock");
        };
        st.slots.insert(key, SlotState::FetchQueued { bytes });
        (tensor, bytes)
    };
    let mut stall = count_as_stall.then(|| {
        let mut s = shared.tracer.span(Category::Stall, "stall_h2d");
        s.set_rank(job.rank);
        s.set_bytes(bytes);
        s
    });
    let mut span = shared.tracer.span(Category::CopyH2D, "h2d_copy");
    span.set_bytes(bytes);
    let t0 = Instant::now();
    let copied = checked_copy(shared, &staged, job.rank);
    shared.arena.recycle(staged);
    let d = t0.elapsed();
    match copied {
        Ok(restored) => {
            span.set_dur(d);
            drop(span);
            if let Some(s) = &mut stall {
                s.set_dur(d);
            }
            drop(stall);
            let mut st = lock_clean(&shared.state);
            st.slots.insert(key, SlotState::Ready { tensor: restored, bytes });
            st.h2d_pending = st.h2d_pending.saturating_sub(1);
            st.stream.copies_h2d += 1;
            st.stream.copy_time_h2d += d;
            st.stream.transfer_bytes += bytes;
            if count_as_stall {
                st.stalls.h2d_wait += d;
                st.stalls.h2d_events += 1;
            }
            shared.cv.notify_all();
        }
        Err(e) => {
            span.cancel();
            drop(span);
            drop(stall);
            let mut st = lock_clean(&shared.state);
            st.slots.insert(key, SlotState::Failed { bytes });
            st.failed.get_or_insert(e);
            st.h2d_pending = st.h2d_pending.saturating_sub(1);
            shared.cv.notify_all();
        }
    }
}

impl AsyncOffloadEngine {
    pub fn new(arena: Arc<ScratchArena>, tracer: Arc<Tracer>, cfg: OffloadConfig) -> Self {
        let shared = Arc::new(Shared {
            arena,
            tracer,
            state: Mutex::new(EngineState::default()),
            cv: Condvar::new(),
            injector: Mutex::new(None),
            retry: RetryPolicy::default(),
            #[cfg(test)]
            pause_workers: std::sync::atomic::AtomicBool::new(false),
        });
        let (mut d2h_tx, mut h2d_tx, mut workers) = (None, None, Vec::new());
        if cfg.overlap {
            let spawn = |name: &str,
                         sh: Arc<Shared>,
                         rx: Receiver<CopyJob>,
                         f: fn(&Shared, CopyJob, bool)|
             -> JoinHandle<()> {
                std::thread::Builder::new()
                    .name(name.to_string())
                    .spawn(move || {
                        for job in rx {
                            f(&sh, job, false);
                        }
                    })
                    .expect("spawning offload stream worker")
            };
            let (tx, rx) = mpsc::channel();
            workers.push(spawn("alst-offload-d2h", shared.clone(), rx, d2h_copy));
            d2h_tx = Some(tx);
            let (tx, rx) = mpsc::channel();
            workers.push(spawn("alst-offload-h2d", shared.clone(), rx, h2d_copy));
            h2d_tx = Some(tx);
        }
        AsyncOffloadEngine {
            shared,
            d2h_tx,
            h2d_tx,
            workers,
            cap: cfg.in_flight_cap.max(1),
            overlap: cfg.overlap,
            wait_timeout: cfg.wait_timeout,
        }
    }

    pub fn overlap(&self) -> bool {
        self.overlap
    }

    /// Install a chaos-run fault injector into both copy streams. Safe
    /// after the engine is shared: the injector slot is its own lock.
    pub fn set_injector(&self, injector: Arc<FaultInjector>) {
        *lock_clean(&self.shared.injector) = Some(injector);
    }

    /// The latched non-retryable fault, if a copy stream died. Cleared by
    /// `abort_step`.
    pub fn failed(&self) -> Option<AlstError> {
        lock_clean(&self.shared.state).failed.clone()
    }

    /// Enqueue the D2H store of layer `li`'s checkpoint for `rank`.
    /// Non-blocking unless the in-flight window is full (backpressure,
    /// recorded as a `stall_d2h` span). Host capacity is charged here,
    /// synchronously, so exhaustion surfaces at the same point as on the
    /// sync tape.
    pub fn store(
        &self,
        li: usize,
        rank: usize,
        tensor: HostTensor,
        host: &mut HostPool,
    ) -> Result<()> {
        let bytes = tensor.size_bytes() as u64;
        {
            let mut st = lock_clean(&self.shared.state);
            if let Some(e) = &st.failed {
                return Err(anyhow::Error::new(e.clone()));
            }
            ensure!(
                !st.slots.contains_key(&(li, rank)),
                "checkpoint ({li},{rank}) already stored"
            );
            host.alloc(bytes)?;
            if st.in_flight_d2h > 0 && st.in_flight_d2h.saturating_add(bytes) > self.cap {
                let mut stall = self.shared.tracer.span(Category::Stall, "stall_d2h");
                stall.set_rank(rank);
                stall.set_bytes(bytes);
                let deadline = Deadline::after(self.wait_timeout);
                let t0 = Instant::now();
                while st.failed.is_none()
                    && st.in_flight_d2h > 0
                    && st.in_flight_d2h.saturating_add(bytes) > self.cap
                {
                    let expired;
                    (st, expired) = wait_clean_deadline(&self.shared.cv, st, deadline);
                    if expired
                        && st.failed.is_none()
                        && st.in_flight_d2h > 0
                        && st.in_flight_d2h.saturating_add(bytes) > self.cap
                    {
                        // The window never drained: a stream stopped making
                        // progress. Undo the host charge and surface typed.
                        let d = t0.elapsed();
                        stall.set_dur(d);
                        st.stalls.d2h_wait += d;
                        st.stalls.d2h_events += 1;
                        drop(st);
                        host.free(bytes);
                        return Err(anyhow::Error::new(AlstError::Transient {
                            site: FaultSite::OffloadCopy,
                            rank,
                            attempt: 0,
                        }));
                    }
                }
                let d = t0.elapsed();
                stall.set_dur(d);
                st.stalls.d2h_wait += d;
                st.stalls.d2h_events += 1;
            }
            if let Some(e) = &st.failed {
                let e = e.clone();
                drop(st);
                host.free(bytes);
                return Err(anyhow::Error::new(e));
            }
            st.in_flight_d2h += bytes;
            st.stream.max_in_flight = st.stream.max_in_flight.max(st.in_flight_d2h);
            st.d2h_pending += 1;
            st.slots.insert((li, rank), SlotState::StoreQueued { bytes });
        }
        // Instant marker at enqueue time: the +bytes edge the in-flight
        // reconstruction test pairs with the d2h_copy span's -bytes edge.
        {
            let mut sp = self.shared.tracer.span(Category::Offload, "ckpt_store_async");
            sp.set_rank(rank);
            sp.set_bytes(bytes);
            sp.set_dur(Duration::ZERO);
        }
        let job = CopyJob { li, rank, tensor: Some(tensor), bytes };
        match &self.d2h_tx {
            Some(tx) => tx.send(job).ok().context("d2h stream worker is gone")?,
            None => d2h_copy(&self.shared, job, true),
        }
        Ok(())
    }

    /// Enqueue the H2D restore of `(li, rank)` so it lands before the
    /// recompute needs it. Idempotent; errors if the slot was never
    /// stored (or already fetched).
    pub fn prefetch(&self, li: usize, rank: usize) -> Result<()> {
        let key = (li, rank);
        {
            let mut st = lock_clean(&self.shared.state);
            if let Some(e) = &st.failed {
                return Err(anyhow::Error::new(e.clone()));
            }
            if !st.slots.contains_key(&key) {
                bail!("checkpoint ({li},{rank}) missing");
            }
            if st.h2d_queued.contains_key(&key) {
                return Ok(());
            }
            st.h2d_queued.insert(key, true);
            st.h2d_pending += 1;
        }
        let job = CopyJob { li, rank, tensor: None, bytes: 0 };
        match &self.h2d_tx {
            Some(tx) => tx.send(job).ok().context("h2d stream worker is gone")?,
            None => h2d_copy(&self.shared, job, true),
        }
        Ok(())
    }

    /// Prefetch layer `li`'s checkpoint for every rank in `0..world`.
    pub fn prefetch_layer(&self, li: usize, world: usize) -> Result<()> {
        for rank in 0..world {
            self.prefetch(li, rank)?;
        }
        Ok(())
    }

    /// Take the restored checkpoint, blocking on the H2D copy if it has
    /// not landed (a `stall_h2d` span — zero at steady state when the
    /// prefetch schedule hid it behind compute). Accounting matches
    /// `CheckpointTape::fetch`: the host charge is released and `bytes`
    /// is charged to the device `ckpt` tag until the caller frees it.
    pub fn fetch(
        &self,
        li: usize,
        rank: usize,
        device: &mut MemoryTracker,
        host: &mut HostPool,
    ) -> Result<HostTensor> {
        self.prefetch(li, rank)?;
        let key = (li, rank);
        let (tensor, bytes) = {
            let mut st = lock_clean(&self.shared.state);
            if !matches!(st.slots.get(&key), Some(SlotState::Ready { .. })) {
                let mut stall = self.shared.tracer.span(Category::Stall, "stall_h2d");
                stall.set_rank(rank);
                let deadline = Deadline::after(self.wait_timeout);
                let t0 = Instant::now();
                while st.failed.is_none()
                    && !matches!(st.slots.get(&key), Some(SlotState::Ready { .. }))
                {
                    let expired;
                    (st, expired) = wait_clean_deadline(&self.shared.cv, st, deadline);
                    if expired
                        && st.failed.is_none()
                        && !matches!(st.slots.get(&key), Some(SlotState::Ready { .. }))
                    {
                        // The restore never landed. The slot (and its host
                        // charge) stays with the engine for `abort_step`.
                        let d = t0.elapsed();
                        stall.set_dur(d);
                        st.stalls.h2d_wait += d;
                        st.stalls.h2d_events += 1;
                        return Err(anyhow::Error::new(AlstError::Transient {
                            site: FaultSite::OffloadCopy,
                            rank,
                            attempt: 0,
                        }));
                    }
                }
                let d = t0.elapsed();
                stall.set_dur(d);
                stall.set_bytes(st.slots.get(&key).map_or(0, SlotState::bytes));
                st.stalls.h2d_wait += d;
                st.stalls.h2d_events += 1;
            }
            if let Some(e) = &st.failed {
                return Err(anyhow::Error::new(e.clone()));
            }
            let Some(SlotState::Ready { tensor, bytes }) = st.slots.remove(&key) else {
                unreachable!("waited for Ready under the same lock");
            };
            st.h2d_queued.remove(&key);
            (tensor, bytes)
        };
        if let Err(e) = device.alloc(bytes, CKPT_TAG) {
            // Put the slot back so abort/retry sees consistent ledgers.
            let mut st = lock_clean(&self.shared.state);
            st.slots.insert(key, SlotState::Ready { tensor, bytes });
            st.h2d_queued.insert(key, true);
            return Err(e);
        }
        host.free(bytes);
        {
            let mut sp = self.shared.tracer.span(Category::Offload, "ckpt_fetch_async");
            sp.set_rank(rank);
            sp.set_bytes(bytes);
            sp.set_dur(Duration::ZERO);
        }
        Ok(tensor)
    }

    /// Block until both streams are idle (no copy enqueued or running).
    /// Terminates even after a fault: a failed copy still retires its
    /// pending count. Bounded: if a stream stops retiring copies within
    /// the wait timeout, the engine latches `WorkerDead` and returns, so
    /// the next API call fails typed instead of deadlocking.
    pub fn drain(&self) {
        let deadline = Deadline::after(self.wait_timeout);
        let mut st = lock_clean(&self.shared.state);
        while st.d2h_pending > 0 || st.h2d_pending > 0 {
            let expired;
            (st, expired) = wait_clean_deadline(&self.shared.cv, st, deadline);
            if expired && (st.d2h_pending > 0 || st.h2d_pending > 0) {
                st.failed.get_or_insert(AlstError::WorkerDead { stream: "offload" });
                return;
            }
        }
    }

    /// Deterministic mid-step teardown: drain both streams, then discard
    /// every remaining slot — host charges released, staged buffers
    /// recycled into the arena. Leaves the engine reusable for the next
    /// step. (Device charges for already-fetched checkpoints are the
    /// caller's to release; `StepTape::abort` does both.)
    pub fn abort_step(&self, host: &mut HostPool) {
        self.drain();
        let mut st = lock_clean(&self.shared.state);
        for (_, slot) in st.slots.drain() {
            match slot {
                SlotState::Staged { tensor, bytes } | SlotState::Ready { tensor, bytes } => {
                    host.free(bytes);
                    self.shared.arena.recycle(tensor);
                }
                // A faulted copy recycled its buffer but kept the charge.
                SlotState::Failed { bytes } => host.free(bytes),
                // Reachable only after a timed-out drain (dead stream): the
                // buffer is with the worker, but the charge is ours to undo.
                SlotState::StoreQueued { bytes } | SlotState::FetchQueued { bytes } => {
                    host.free(bytes)
                }
            }
        }
        st.h2d_queued.clear();
        st.in_flight_d2h = 0;
        st.failed = None;
    }

    /// Checkpoints currently held by the engine (any lifecycle state).
    pub fn pending(&self) -> usize {
        lock_clean(&self.shared.state).slots.len()
    }

    pub fn stalls(&self) -> StallStats {
        lock_clean(&self.shared.state).stalls
    }

    pub fn stream_stats(&self) -> StreamStats {
        lock_clean(&self.shared.state).stream
    }

    /// Cumulative bytes moved across both streams since construction (or
    /// the last `reset_stats`).
    pub fn transfer_bytes(&self) -> u64 {
        lock_clean(&self.shared.state).stream.transfer_bytes
    }

    /// Zero the stall/stream ledgers (per-bench-row isolation). Slots in
    /// flight are unaffected.
    pub fn reset_stats(&self) {
        let mut st = lock_clean(&self.shared.state);
        st.stream = StreamStats::default();
        st.stalls = StallStats::default();
    }

    #[cfg(test)]
    fn lock_state(&self) -> std::sync::MutexGuard<'_, EngineState> {
        lock_clean(&self.shared.state)
    }
}

impl Drop for AsyncOffloadEngine {
    fn drop(&mut self) {
        // Closing the channels ends the worker loops.
        self.d2h_tx.take();
        self.h2d_tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// StepTape: one step's checkpoint traffic, sync or async
// ---------------------------------------------------------------------------

enum TapeKind {
    Sync(CheckpointTape),
    Async { engine: Arc<AsyncOffloadEngine>, start_transfer: u64 },
}

/// The pipeline's per-step view over either checkpoint path. Also owns
/// the *fetched-outstanding* ledger: bytes of restored checkpoints that
/// are device-charged (`ckpt` tag) until the recompute recycles them —
/// the accounting rule both paths now share — so the mid-step error path
/// can release exactly what is still held.
pub struct StepTape {
    kind: TapeKind,
    fetched_outstanding: u64,
}

impl StepTape {
    pub fn sync(tape: CheckpointTape) -> StepTape {
        StepTape { kind: TapeKind::Sync(tape), fetched_outstanding: 0 }
    }

    pub fn with_engine(engine: Arc<AsyncOffloadEngine>) -> StepTape {
        let start_transfer = engine.transfer_bytes();
        StepTape { kind: TapeKind::Async { engine, start_transfer }, fetched_outstanding: 0 }
    }

    pub fn is_async(&self) -> bool {
        matches!(self.kind, TapeKind::Async { .. })
    }

    pub fn store(
        &mut self,
        li: usize,
        rank: usize,
        tensor: HostTensor,
        device: &mut MemoryTracker,
        host: &mut HostPool,
    ) -> Result<()> {
        match &mut self.kind {
            TapeKind::Sync(t) => t.store(li, rank, tensor, device, host),
            TapeKind::Async { engine, .. } => engine.store(li, rank, tensor, host),
        }
    }

    /// Hint that layer `li`'s checkpoints (all `world` ranks) will be
    /// fetched soon. No-op on the sync tape.
    pub fn prefetch_layer(&self, li: usize, world: usize) -> Result<()> {
        match &self.kind {
            TapeKind::Sync(_) => Ok(()),
            TapeKind::Async { engine, .. } => engine.prefetch_layer(li, world),
        }
    }

    pub fn fetch(
        &mut self,
        li: usize,
        rank: usize,
        device: &mut MemoryTracker,
        host: &mut HostPool,
    ) -> Result<HostTensor> {
        let t = match &mut self.kind {
            TapeKind::Sync(tape) => tape.fetch(li, rank, device, host)?,
            TapeKind::Async { engine, .. } => engine.fetch(li, rank, device, host)?,
        };
        self.fetched_outstanding += t.size_bytes() as u64;
        Ok(t)
    }

    /// Release the device charge of fetched checkpoints the recompute has
    /// recycled (end of each backward layer).
    pub fn release_fetched(&mut self, bytes: u64, device: &mut MemoryTracker) {
        debug_assert!(bytes <= self.fetched_outstanding, "releasing more than fetched");
        if bytes > 0 {
            device.free(bytes, CKPT_TAG);
            self.fetched_outstanding = self.fetched_outstanding.saturating_sub(bytes);
        }
    }

    /// Device/host transfer volume this step (both directions).
    pub fn transfer_bytes(&self) -> u64 {
        match &self.kind {
            TapeKind::Sync(t) => t.transfer_bytes,
            TapeKind::Async { engine, start_transfer } => {
                engine.transfer_bytes() - start_transfer
            }
        }
    }

    /// Mid-step error teardown: drain the streams, drop the un-fetched
    /// slots (host charges released, buffers recycled), and release the
    /// device charge of checkpoints that were fetched but whose backward
    /// never finished. After this, no pool holds phantom bytes and no
    /// arena buffer is leaked.
    pub fn abort(
        &mut self,
        device: &mut MemoryTracker,
        host: &mut HostPool,
        arena: &ScratchArena,
    ) {
        if self.fetched_outstanding > 0 {
            device.free(self.fetched_outstanding, CKPT_TAG);
            self.fetched_outstanding = 0;
        }
        match &mut self.kind {
            TapeKind::Sync(t) => t.clear(device, host, arena),
            TapeKind::Async { engine, .. } => engine.abort_step(host),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tensor(rng: &mut Rng, n: usize) -> HostTensor {
        HostTensor::f32(vec![n], rng.normal_vec(n, 1.0))
    }

    /// The trainer shares `&self` (holding an `Arc` of the engine) across
    /// `run_ranks` scoped threads, so the engine must be `Send + Sync` —
    /// true on stable since `mpsc::Sender: Sync` (Rust 1.72); this pins it
    /// at compile time.
    #[test]
    fn engine_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<AsyncOffloadEngine>();
        assert_send_sync::<StepTape>();
    }

    fn engine(overlap: bool, cap: u64) -> AsyncOffloadEngine {
        AsyncOffloadEngine::new(
            Arc::new(ScratchArena::new()),
            Tracer::off(),
            OffloadConfig { in_flight_cap: cap, overlap, ..OffloadConfig::default() },
        )
    }

    /// Overlap-mode engine with a short wait ceiling, for driving the
    /// bounded waits to expiry against paused workers.
    fn engine_with_timeout(cap: u64, wait_timeout: Duration) -> AsyncOffloadEngine {
        AsyncOffloadEngine::new(
            Arc::new(ScratchArena::new()),
            Tracer::off(),
            OffloadConfig { in_flight_cap: cap, overlap: true, wait_timeout },
        )
    }

    fn pause_workers(eng: &AsyncOffloadEngine, on: bool) {
        eng.shared.pause_workers.store(on, std::sync::atomic::Ordering::SeqCst);
    }

    #[test]
    fn roundtrip_is_bit_identical_both_modes() {
        for overlap in [false, true] {
            let eng = engine(overlap, 1 << 30);
            let mut dev = MemoryTracker::new(1 << 30);
            let mut host = HostPool::new(1 << 30);
            let mut rng = Rng::new(7);
            let originals: Vec<HostTensor> =
                (0..3).map(|_| tensor(&mut rng, 128)).collect();
            for (li, t) in originals.iter().enumerate() {
                eng.store(li, 0, t.clone(), &mut host).unwrap();
            }
            eng.drain();
            assert_eq!(host.current(), 3 * 512, "staged bytes charged to host");
            for li in (0..3).rev() {
                let got = eng.fetch(li, 0, &mut dev, &mut host).unwrap();
                for (a, b) in got
                    .as_f32()
                    .unwrap()
                    .iter()
                    .zip(originals[li].as_f32().unwrap())
                {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                dev.free(got.size_bytes() as u64, CKPT_TAG);
            }
            assert_eq!(eng.pending(), 0);
            assert_eq!(host.current(), 0);
            assert_eq!(dev.current(), 0);
            // Both directions moved every byte once.
            assert_eq!(eng.transfer_bytes(), 2 * 3 * 512);
        }
    }

    #[test]
    fn inline_mode_counts_copies_as_stall() {
        let eng = engine(false, 1 << 30);
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut rng = Rng::new(3);
        eng.store(0, 0, tensor(&mut rng, 4096), &mut host).unwrap();
        let t = eng.fetch(0, 0, &mut dev, &mut host).unwrap();
        dev.free(t.size_bytes() as u64, CKPT_TAG);
        let (stalls, stream) = (eng.stalls(), eng.stream_stats());
        assert_eq!(stalls.d2h_events, 1);
        assert_eq!(stalls.h2d_events, 1);
        // Inline: every copied nanosecond is stalled — the sync baseline.
        assert_eq!(stalls.d2h_wait, stream.copy_time_d2h);
        assert_eq!(stalls.h2d_wait, stream.copy_time_h2d);
        assert_eq!(overlap_frac(&stalls, &stream), 0.0);
    }

    #[test]
    fn duplicate_store_and_missing_fetch_error() {
        let eng = engine(true, 1 << 30);
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut rng = Rng::new(1);
        eng.store(0, 0, tensor(&mut rng, 16), &mut host).unwrap();
        assert!(eng.store(0, 0, tensor(&mut rng, 16), &mut host).is_err());
        assert!(eng.fetch(5, 0, &mut dev, &mut host).is_err());
        assert!(eng.prefetch(5, 0).is_err());
        // The failed duplicate must not have leaked a host charge.
        let t = eng.fetch(0, 0, &mut dev, &mut host).unwrap();
        dev.free(t.size_bytes() as u64, CKPT_TAG);
        assert_eq!(host.current(), 0);
    }

    #[test]
    fn host_exhaustion_surfaces_at_store() {
        let eng = engine(true, 1 << 30);
        let mut host = HostPool::new(100);
        let mut rng = Rng::new(1);
        assert!(eng.store(0, 0, tensor(&mut rng, 64), &mut host).is_err());
        assert_eq!(eng.pending(), 0);
        assert_eq!(host.current(), 0);
    }

    #[test]
    fn oversized_store_is_admitted_alone() {
        // A store above the cap waits for an empty window, then proceeds;
        // it must not deadlock.
        let eng = engine(true, 64);
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut rng = Rng::new(2);
        eng.store(0, 0, tensor(&mut rng, 1024), &mut host).unwrap(); // 4 KiB > 64 B
        eng.store(1, 0, tensor(&mut rng, 1024), &mut host).unwrap();
        eng.drain();
        for li in (0..2).rev() {
            let t = eng.fetch(li, 0, &mut dev, &mut host).unwrap();
            dev.free(t.size_bytes() as u64, CKPT_TAG);
        }
        assert_eq!(host.current(), 0);
    }

    #[test]
    fn abort_step_leaves_engine_reusable() {
        let eng = engine(true, 1 << 30);
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut rng = Rng::new(9);
        for li in 0..3 {
            eng.store(li, 0, tensor(&mut rng, 64), &mut host).unwrap();
        }
        eng.prefetch(2, 0).unwrap();
        eng.abort_step(&mut host);
        assert_eq!(eng.pending(), 0);
        assert_eq!(host.current(), 0, "no phantom host bytes after abort");
        assert_eq!(host.underflow_events(), 0);
        {
            let st = eng.lock_state();
            assert_eq!((st.d2h_pending, st.h2d_pending, st.in_flight_d2h), (0, 0, 0));
        }
        // Next step works on the same engine.
        eng.store(0, 0, tensor(&mut rng, 64), &mut host).unwrap();
        let t = eng.fetch(0, 0, &mut dev, &mut host).unwrap();
        dev.free(t.size_bytes() as u64, CKPT_TAG);
        assert_eq!((host.current(), dev.current()), (0, 0));
    }

    #[test]
    fn step_tape_abort_releases_fetched_device_charge() {
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let arena = ScratchArena::new();
        let eng = Arc::new(engine(true, 1 << 30));
        let mut tape = StepTape::with_engine(eng);
        let mut rng = Rng::new(4);
        tape.store(0, 0, tensor(&mut rng, 64), &mut dev, &mut host).unwrap();
        tape.store(1, 0, tensor(&mut rng, 64), &mut dev, &mut host).unwrap();
        let t = tape.fetch(1, 0, &mut dev, &mut host).unwrap();
        assert_eq!(dev.tag_bytes(CKPT_TAG), 256);
        arena.recycle(t); // the recompute consumed it; step then errors
        tape.abort(&mut dev, &mut host, &arena);
        assert_eq!(dev.tag_bytes(CKPT_TAG), 0, "fetched charge released");
        assert_eq!(host.current(), 0);
        assert_eq!(dev.underflow_events() + host.underflow_events(), 0);
    }

    #[test]
    fn transient_and_corrupt_copy_faults_are_retried_bit_identically() {
        use crate::collectives::faults::FaultPlan;
        for kind in [FaultKind::Transient, FaultKind::CorruptPayload] {
            let eng = engine(true, 1 << 30);
            let inj = FaultInjector::new(FaultPlan {
                site: FaultSite::OffloadCopy,
                kind,
                rank: 0,
                at_op: 0,
                seed: 5,
            });
            eng.set_injector(inj.clone());
            let mut dev = MemoryTracker::new(1 << 30);
            let mut host = HostPool::new(1 << 30);
            let mut rng = Rng::new(7);
            let orig = tensor(&mut rng, 256);
            eng.store(0, 0, orig.clone(), &mut host).unwrap();
            let got = eng.fetch(0, 0, &mut dev, &mut host).unwrap();
            for (a, b) in got.as_f32().unwrap().iter().zip(orig.as_f32().unwrap()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            dev.free(got.size_bytes() as u64, CKPT_TAG);
            assert!(inj.fired(), "the planned fault fired");
            assert_eq!(inj.stats().retries, 1, "absorbed by exactly one retry");
            assert!(eng.failed().is_none());
            assert_eq!((host.current(), dev.current()), (0, 0));
        }
    }

    #[test]
    fn lost_rank_copy_latches_typed_error_and_abort_recovers() {
        use crate::collectives::faults::FaultPlan;
        let eng = engine(true, 1 << 30);
        eng.set_injector(FaultInjector::new(FaultPlan {
            site: FaultSite::OffloadCopy,
            kind: FaultKind::LostRank,
            rank: 0,
            at_op: 0,
            seed: 1,
        }));
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut rng = Rng::new(8);
        eng.store(0, 0, tensor(&mut rng, 64), &mut host).unwrap();
        let err = eng.fetch(0, 0, &mut dev, &mut host).unwrap_err();
        let alst = err.downcast_ref::<AlstError>().expect("typed fault");
        assert_eq!(*alst, AlstError::LostRank { site: FaultSite::OffloadCopy, rank: 0 });
        // later calls fail fast on the latched error, without new charges
        assert!(eng.store(1, 0, tensor(&mut rng, 64), &mut host).is_err());
        assert_eq!(host.current(), 256, "faulted slot keeps its host charge");
        eng.abort_step(&mut host);
        assert!(eng.failed().is_none(), "abort clears the latch");
        assert_eq!((eng.pending(), host.current()), (0, 0));
        // the same engine serves the next step cleanly
        eng.store(0, 0, tensor(&mut rng, 64), &mut host).unwrap();
        let t = eng.fetch(0, 0, &mut dev, &mut host).unwrap();
        dev.free(t.size_bytes() as u64, CKPT_TAG);
        assert_eq!((host.current(), dev.current()), (0, 0));
    }

    #[test]
    fn full_window_store_times_out_typed_instead_of_hanging() {
        let eng = engine_with_timeout(256, Duration::from_millis(50));
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut rng = Rng::new(11);
        pause_workers(&eng, true);
        // First store fills the 256-byte window; the paused worker never
        // drains it, so the second store's backpressure wait must expire.
        eng.store(0, 0, tensor(&mut rng, 64), &mut host).unwrap();
        let err = eng.store(1, 0, tensor(&mut rng, 64), &mut host).unwrap_err();
        let alst = err.downcast_ref::<AlstError>().expect("typed timeout");
        assert!(
            matches!(alst, AlstError::Transient { site: FaultSite::OffloadCopy, .. }),
            "window timeout surfaces as a transient offload fault, got {alst:?}"
        );
        assert!(alst.is_retryable());
        assert_eq!(host.current(), 256, "timed-out store undid its host charge");
        assert_eq!(eng.stalls().d2h_events, 1, "the bounded wait was counted as stall");
        assert!(eng.failed().is_none(), "a timed-out wait does not latch the engine");
        // Resume the worker: the same engine completes the step cleanly.
        pause_workers(&eng, false);
        eng.drain();
        let t = eng.fetch(0, 0, &mut dev, &mut host).unwrap();
        dev.free(t.size_bytes() as u64, CKPT_TAG);
        assert_eq!((host.current(), dev.current()), (0, 0));
        assert_eq!(host.underflow_events(), 0);
    }

    #[test]
    fn fetch_on_stuck_stream_times_out_typed() {
        let eng = engine_with_timeout(1 << 30, Duration::from_millis(50));
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut rng = Rng::new(12);
        pause_workers(&eng, true);
        eng.store(0, 0, tensor(&mut rng, 64), &mut host).unwrap();
        let err = eng.fetch(0, 0, &mut dev, &mut host).unwrap_err();
        let alst = err.downcast_ref::<AlstError>().expect("typed timeout");
        assert!(
            matches!(alst, AlstError::Transient { site: FaultSite::OffloadCopy, .. }),
            "fetch timeout surfaces as a transient offload fault, got {alst:?}"
        );
        assert_eq!(host.current(), 256, "the slot and its charge stay with the engine");
        assert_eq!(dev.current(), 0, "no device charge for a fetch that never landed");
        // Recovery path: resume, tear the step down, ledgers balance.
        pause_workers(&eng, false);
        eng.abort_step(&mut host);
        assert_eq!((eng.pending(), host.current()), (0, 0));
        assert_eq!(host.underflow_events(), 0);
    }

    #[test]
    fn timed_out_drain_latches_worker_dead() {
        let eng = engine_with_timeout(1 << 30, Duration::from_millis(50));
        let mut host = HostPool::new(1 << 30);
        let mut rng = Rng::new(13);
        pause_workers(&eng, true);
        eng.store(0, 0, tensor(&mut rng, 64), &mut host).unwrap();
        eng.drain(); // expires: the paused stream retires nothing
        assert!(
            matches!(eng.failed(), Some(AlstError::WorkerDead { stream: "offload" })),
            "timed-out drain latches a dead-stream fault"
        );
        // Every later call fails fast on the latch instead of waiting again.
        assert!(eng.store(1, 0, tensor(&mut rng, 64), &mut host).is_err());
        pause_workers(&eng, false);
        eng.abort_step(&mut host);
        assert!(eng.failed().is_none(), "abort clears the latch");
        assert_eq!((eng.pending(), host.current()), (0, 0));
        assert_eq!(host.underflow_events(), 0);
    }

    #[test]
    fn overlap_frac_clamps() {
        let mut stalls = StallStats::default();
        let mut stream = StreamStats::default();
        assert_eq!(overlap_frac(&stalls, &stream), 0.0, "no copies: nothing hidden");
        stream.copy_time_d2h = Duration::from_millis(10);
        assert_eq!(overlap_frac(&stalls, &stream), 1.0);
        stalls.d2h_wait = Duration::from_millis(4);
        assert!((overlap_frac(&stalls, &stream) - 0.6).abs() < 1e-9);
        stalls.d2h_wait = Duration::from_millis(40);
        assert_eq!(overlap_frac(&stalls, &stream), 0.0);
    }
}
