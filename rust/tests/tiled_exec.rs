//! Tiled-execution equivalence and memory tests (no PJRT needed).
//!
//! These drive `tiling::exec`'s drivers with the `HostLossHead`
//! reference executor — the same naive-reference pattern as
//! `relayout_equiv.rs`. THE SUMMATION-ORDER CONTRACT (documented in
//! `tiling/exec.rs`):
//!
//!   * per-row losses, the total loss/count reduction, and every row of
//!     d_h are bit-identical between tiled and untiled execution under
//!     ANY tiling (row-local math + driver-side ascending-row sums);
//!   * cross-row weight-gradient reductions are pinned TILE-MAJOR
//!     (rows ascending within a tile, tile partials ascending), so they
//!     are bit-identical against an untiled reference that replays the
//!     same pinned schedule, and tolerance-close to any other order.
//!
//! Plus the two measured acceptance properties: the tracker-measured
//! loss-head peak drops by >= 0.8 x `TilePlan::savings()` on the
//! 32K/vocab-128K config, and per-document losses from ONE tiled sweep
//! equal the old masked-label re-execution exactly.

use alst::memory::MemoryTracker;
use alst::runtime::HostTensor;
use alst::runtime::ScratchArena;
use alst::tiling::exec::{
    untiled_loss_bwd_bytes, untiled_loss_fwd_bytes, HostLossHead, TiledLossExec,
    TiledMlpExec, LOSS_HEAD_TAG, MLP_TAG,
};
use alst::tiling::{plan_logits, plan_logits_rows};
use alst::util::rng::Rng;

const IGNORE: i32 = -100;

fn make_head(hidden: usize, vocab: usize, seed: u64) -> HostLossHead {
    let mut rng = Rng::new(seed);
    let lnf: Vec<f32> = (0..hidden)
        .map(|_| 1.0 + 0.05 * rng.normal() as f32)
        .collect();
    let unembed = rng.normal_vec(hidden * vocab, 0.08);
    HostLossHead::new(hidden, vocab, IGNORE, lnf, unembed).unwrap()
}

fn make_inputs(s: usize, hidden: usize, vocab: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed ^ 0x5eed);
    let h = rng.normal_vec(s * hidden, 1.0);
    let mut labels: Vec<i32> = (0..s).map(|_| rng.below(vocab) as i32).collect();
    // sprinkle ignored rows (shard tail + mid-sequence boundaries)
    labels[s - 1] = IGNORE;
    if s > 7 {
        labels[7] = IGNORE;
    }
    (h, labels)
}

fn fwd_fn<'a>(
    head: &'a HostLossHead,
) -> impl FnMut(&HostTensor, &HostTensor) -> anyhow::Result<HostTensor> + 'a {
    move |ht, lt| {
        let labels = lt.as_i32()?;
        let per = head.per_row_losses(ht.as_f32()?, labels)?;
        Ok(HostTensor::f32(vec![labels.len()], per))
    }
}

fn bwd_fn<'a>(
    head: &'a HostLossHead,
    ct: f32,
) -> impl FnMut(&HostTensor, &HostTensor) -> anyhow::Result<(HostTensor, HostTensor, HostTensor)> + 'a
{
    let (hd, v) = (head.hidden, head.vocab);
    move |ht, lt| {
        let labels = lt.as_i32()?;
        let rows = labels.len();
        let mut dl = vec![0f32; hd];
        let mut dw = vec![0f32; hd * v];
        let mut dh = vec![0f32; rows * hd];
        head.backward(ht.as_f32()?, labels, ct, &mut dl, &mut dw, &mut dh)?;
        Ok((
            HostTensor::f32(vec![hd], dl),
            HostTensor::f32(vec![hd, v], dw),
            HostTensor::f32(vec![rows, hd], dh),
        ))
    }
}

/// The untiled reference replaying the driver's pinned tile-major
/// weight-grad schedule (see the contract above). Memory profile is the
/// untiled one — full d_h etc. live at once — only the reduction order
/// is shared with the driver.
fn replayed_backward(
    head: &HostLossHead,
    h: &[f32],
    labels: &[i32],
    ct: f32,
    rows_per_tile: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (hd, v) = (head.hidden, head.vocab);
    let s = labels.len();
    let mut d_lnf = vec![0f32; hd];
    let mut d_unembed = vec![0f32; hd * v];
    let mut d_h = vec![0f32; s * hd];
    let mut lo = 0;
    while lo < s {
        let hi = (lo + rows_per_tile).min(s);
        let mut pl = vec![0f32; hd];
        let mut pw = vec![0f32; hd * v];
        head.backward(
            &h[lo * hd..hi * hd],
            &labels[lo..hi],
            ct,
            &mut pl,
            &mut pw,
            &mut d_h[lo * hd..hi * hd],
        )
        .unwrap();
        for (a, b) in d_lnf.iter_mut().zip(&pl) {
            *a += b;
        }
        for (a, b) in d_unembed.iter_mut().zip(&pw) {
            *a += b;
        }
        lo = hi;
    }
    (d_lnf, d_unembed, d_h)
}

#[test]
fn tiled_forward_is_bit_identical_to_untiled() {
    let (hidden, vocab, s) = (8, 32, 64);
    let head = make_head(hidden, vocab, 1);
    let (h, labels) = make_inputs(s, hidden, vocab, 1);
    let want_rows = head.per_row_losses(&h, &labels).unwrap();
    let (want_sum, want_count) = head.untiled_loss(&h, &labels).unwrap();

    let arena = ScratchArena::new();
    let mut tracker = MemoryTracker::new(1 << 40);
    let h_t = HostTensor::f32(vec![s, hidden], h.clone());
    // includes ragged (5, 7), even (16), and degenerate 1-tile (64, 100)
    for rows in [5usize, 7, 16, 64, 100] {
        let drv = TiledLossExec::new(s, hidden, vocab, rows, IGNORE, &arena).unwrap();
        let sweep = drv
            .forward(&mut tracker, &h_t, &labels, fwd_fn(&head))
            .unwrap();
        assert_eq!(sweep.per_row_loss, want_rows, "rows={rows}");
        assert_eq!(sweep.loss_sum.to_bits(), want_sum.to_bits(), "rows={rows}");
        assert_eq!(sweep.count, want_count);
        assert_eq!(sweep.tiles_run, s.div_ceil(rows.min(s)));
        arena.recycle_f32(sweep.per_row_loss);
    }
}

#[test]
fn tiled_backward_matches_pinned_schedule_reference() {
    let (hidden, vocab, s) = (8, 32, 48);
    let head = make_head(hidden, vocab, 2);
    let (h, labels) = make_inputs(s, hidden, vocab, 2);
    let ct = 1.0 / 46.0;
    let h_t = HostTensor::f32(vec![s, hidden], h.clone());

    for rows in [5usize, 16, 48] {
        let arena = ScratchArena::new();
        let mut tracker = MemoryTracker::new(1 << 40);
        let drv = TiledLossExec::new(s, hidden, vocab, rows, IGNORE, &arena).unwrap();
        let mut d_lnf = vec![0f32; hidden];
        let mut d_unembed = vec![0f32; hidden * vocab];
        let d_h = drv
            .backward(
                &mut tracker,
                &h_t,
                &labels,
                &mut d_lnf,
                &mut d_unembed,
                bwd_fn(&head, ct),
            )
            .unwrap();

        // bit-identity against the untiled reference replaying the
        // pinned tile-major schedule
        let (want_lnf, want_unembed, want_dh) =
            replayed_backward(&head, &h, &labels, ct, rows);
        assert_eq!(d_lnf, want_lnf, "rows={rows}");
        assert_eq!(d_unembed, want_unembed, "rows={rows}");
        assert_eq!(d_h.as_f32().unwrap(), &want_dh[..], "rows={rows}");

        // d_h is row-local: ALSO bit-identical to the plain row-order
        // untiled backward; the weight grads only tolerance-match it
        // (different fp summation order — the documented exception)
        let (row_lnf, row_unembed, row_dh) =
            replayed_backward(&head, &h, &labels, ct, s);
        assert_eq!(d_h.as_f32().unwrap(), &row_dh[..]);
        for (a, b) in d_lnf.iter().zip(&row_lnf) {
            assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0), "{a} vs {b}");
        }
        for (a, b) in d_unembed.iter().zip(&row_unembed) {
            assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0), "{a} vs {b}");
        }
    }
}

#[test]
fn ragged_tail_padding_rows_are_masked_out() {
    // s=10 with rows=4: the last tile holds 2 live + 2 padding rows;
    // padding must change nothing versus untiled.
    let (hidden, vocab, s) = (4, 16, 10);
    let head = make_head(hidden, vocab, 3);
    let (h, labels) = make_inputs(s, hidden, vocab, 3);
    let h_t = HostTensor::f32(vec![s, hidden], h.clone());
    let arena = ScratchArena::new();
    let mut tracker = MemoryTracker::new(1 << 40);

    let drv = TiledLossExec::new(s, hidden, vocab, 4, IGNORE, &arena).unwrap();
    assert_eq!(drv.plan.n_tiles, 3);
    let sweep = drv
        .forward(&mut tracker, &h_t, &labels, fwd_fn(&head))
        .unwrap();
    let (want_sum, _) = head.untiled_loss(&h, &labels).unwrap();
    assert_eq!(sweep.loss_sum.to_bits(), want_sum.to_bits());

    let mut d_lnf = vec![0f32; hidden];
    let mut d_unembed = vec![0f32; hidden * vocab];
    let d_h = drv
        .backward(
            &mut tracker,
            &h_t,
            &labels,
            &mut d_lnf,
            &mut d_unembed,
            bwd_fn(&head, 0.25),
        )
        .unwrap();
    let (want_lnf, want_unembed, want_dh) = replayed_backward(&head, &h, &labels, 0.25, 4);
    assert_eq!(d_lnf, want_lnf);
    assert_eq!(d_unembed, want_unembed);
    assert_eq!(d_h.as_f32().unwrap(), &want_dh[..]);
}

#[test]
fn per_document_bucketing_equals_masked_label_rerun() {
    // ISSUE acceptance: per-document losses from the single tiled sweep
    // match the old n_docs re-execution (labels masked to one document
    // at a time) EXACTLY. The old path's per-doc sum is the same set of
    // row losses reduced in the same ascending order.
    let (hidden, vocab, s) = (8, 32, 64);
    let head = make_head(hidden, vocab, 4);
    let (h, mut labels) = make_inputs(s, hidden, vocab, 4);
    let bounds = [0usize, 20, 45, 64]; // three "documents"
    for &b in &bounds[1..] {
        labels[b - 1] = IGNORE; // no cross-document target
    }
    let h_t = HostTensor::f32(vec![s, hidden], h.clone());
    let arena = ScratchArena::new();
    let mut tracker = MemoryTracker::new(1 << 40);
    let drv = TiledLossExec::new(s, hidden, vocab, 16, IGNORE, &arena).unwrap();
    let sweep = drv
        .forward(&mut tracker, &h_t, &labels, fwd_fn(&head))
        .unwrap();

    for d in 0..3 {
        let (lo, hi) = (bounds[d], bounds[d + 1]);
        // new path: bucket the sweep's per-row losses
        let (mut sum_new, mut count_new) = (0f32, 0f32);
        for i in lo..hi {
            if labels[i] != IGNORE {
                sum_new += sweep.per_row_loss[i];
                count_new += 1.0;
            }
        }
        // old path: full re-run with labels masked to this document
        let mut masked = vec![IGNORE; s];
        masked[lo..hi].copy_from_slice(&labels[lo..hi]);
        let (sum_old, count_old) = head.untiled_loss(&h, &masked).unwrap();
        assert_eq!(sum_new.to_bits(), sum_old.to_bits(), "doc {d}");
        assert_eq!(count_new, count_old, "doc {d}");
    }
}

#[test]
fn measured_loss_head_peak_drops_by_plan_savings() {
    // ISSUE acceptance, on the 32K / vocab-128K config: the tracker-
    // MEASURED loss-head tag peak must drop by >= 0.8 x
    // TilePlan::savings() versus untiled. Tile executors are no-ops
    // (shape-correct zeros) — the measurement under test is the
    // driver's instrumentation, not the arithmetic.
    let (s, vocab, hidden) = (32_768usize, 128_256usize, 8usize);
    let plan = plan_logits(s, vocab, alst::config::GIB);
    assert!(plan.n_tiles > 1, "config must actually tile: {:?}", plan);

    let arena = ScratchArena::new();
    let h_t = HostTensor::f32(vec![s, hidden], vec![0.0; s * hidden]);
    let labels = vec![1i32; s];

    // untiled: what the monolithic loss stages hold (1 copy fwd, 2 bwd)
    let mut untiled = MemoryTracker::new(1 << 44);
    let fwd = untiled_loss_fwd_bytes(s, vocab);
    untiled.alloc(fwd, LOSS_HEAD_TAG).unwrap();
    untiled.free(fwd, LOSS_HEAD_TAG);
    let bwd = untiled_loss_bwd_bytes(s, vocab);
    untiled.alloc(bwd, LOSS_HEAD_TAG).unwrap();
    untiled.free(bwd, LOSS_HEAD_TAG);
    let untiled_peak = untiled.tag_peak(LOSS_HEAD_TAG);
    assert_eq!(untiled_peak, plan.untiled_bytes);

    // tiled: the driver's per-tile charges
    let mut tiled = MemoryTracker::new(1 << 44);
    let drv =
        TiledLossExec::new(s, hidden, vocab, plan.rows_per_tile, IGNORE, &arena).unwrap();
    let rows = plan.rows_per_tile;
    let sweep = drv
        .forward(&mut tiled, &h_t, &labels, |_, lt| {
            Ok(HostTensor::f32(vec![lt.numel()], vec![0.0; lt.numel()]))
        })
        .unwrap();
    arena.recycle_f32(sweep.per_row_loss);
    let mut d_lnf = vec![0f32; hidden];
    let mut d_unembed = vec![0f32; hidden * vocab];
    let d_h = drv
        .backward(&mut tiled, &h_t, &labels, &mut d_lnf, &mut d_unembed, |_, lt| {
            let n = lt.numel();
            assert_eq!(n, rows);
            Ok((
                HostTensor::f32(vec![hidden], vec![0.0; hidden]),
                HostTensor::f32(vec![hidden, vocab], vec![0.0; hidden * vocab]),
                HostTensor::f32(vec![n, hidden], vec![0.0; n * hidden]),
            ))
        })
        .unwrap();
    drop(d_h);
    let tiled_peak = tiled.tag_peak(LOSS_HEAD_TAG);
    assert_eq!(tiled_peak, plan.tile_bytes, "tiled peak == plan tile bytes");

    let drop_bytes = untiled_peak - tiled_peak;
    assert!(
        drop_bytes as f64 >= 0.8 * plan.savings() as f64,
        "measured drop {} < 0.8 x plan savings {}",
        drop_bytes,
        plan.savings()
    );
    // and the plan's O(1)-in-seq claim holds for the measured tile peak
    let plan_64k = plan_logits_rows(2 * s, vocab, plan.rows_per_tile);
    assert_eq!(plan_64k.tile_bytes, plan.tile_bytes);
}

#[test]
fn steady_state_sweeps_are_allocation_free() {
    let (hidden, vocab, s) = (8, 32, 48);
    let head = make_head(hidden, vocab, 5);
    let (h, labels) = make_inputs(s, hidden, vocab, 5);
    let h_t = HostTensor::f32(vec![s, hidden], h);
    let arena = ScratchArena::new();
    let mut tracker = MemoryTracker::new(1 << 40);
    let drv = TiledLossExec::new(s, hidden, vocab, 16, IGNORE, &arena).unwrap();

    // warmup sweep populates the pool (the closure's fresh outputs are
    // recycled by the driver, like real stage outputs)
    let sweep = drv
        .forward(&mut tracker, &h_t, &labels, fwd_fn(&head))
        .unwrap();
    arena.recycle_f32(sweep.per_row_loss);
    let misses_after_warmup = arena.misses();
    for _ in 0..3 {
        let sweep = drv
            .forward(&mut tracker, &h_t, &labels, fwd_fn(&head))
            .unwrap();
        arena.recycle_f32(sweep.per_row_loss);
    }
    assert_eq!(
        arena.misses(),
        misses_after_warmup,
        "steady-state forward sweeps must not allocate"
    );
    assert!(arena.hit_rate() > 0.0);
}

#[test]
fn mlp_driver_assembles_rowwise_function_exactly() {
    // The MLP driver is executor-agnostic; with a row-wise host function
    // (y = 2*h_in + attn-row-sum broadcast) tiled output and cotangents
    // must reassemble the untiled result bit-for-bit.
    let (s, hidden, nq, dh) = (10usize, 4usize, 2, 3);
    let ab = nq * dh;
    let mut rng = Rng::new(9);
    let h_in = HostTensor::f32(vec![s, hidden], rng.normal_vec(s * hidden, 1.0));
    let attn = HostTensor::f32(vec![s, nq, dh], rng.normal_vec(s * ab, 1.0));
    let d_out = HostTensor::f32(vec![s, hidden], rng.normal_vec(s * hidden, 1.0));

    let row_fn = |hrow: &[f32], arow: &[f32], out: &mut [f32]| {
        let asum: f32 = arow.iter().sum();
        for (o, &x) in out.iter_mut().zip(hrow) {
            *o = 2.0 * x + asum;
        }
    };

    let arena = ScratchArena::new();
    let mut tracker = MemoryTracker::new(1 << 40);
    let drv = TiledMlpExec::new(s, hidden, 16, 4, nq, dh, &arena).unwrap();
    assert_eq!(drv.plan.n_tiles, 3); // ragged tail: 4+4+2
    let got = drv
        .forward(&mut tracker, &h_in, &attn, |ht, at| {
            let (hs, ats) = (ht.as_f32()?, at.as_f32()?);
            let rows = ht.shape()[0];
            let mut out = vec![0f32; rows * hidden];
            for r in 0..rows {
                row_fn(
                    &hs[r * hidden..(r + 1) * hidden],
                    &ats[r * ab..(r + 1) * ab],
                    &mut out[r * hidden..(r + 1) * hidden],
                );
            }
            Ok(HostTensor::f32(vec![rows, hidden], out))
        })
        .unwrap();
    // untiled: same row function over the full shard
    let (hs, ats) = (h_in.as_f32().unwrap(), attn.as_f32().unwrap());
    let mut want = vec![0f32; s * hidden];
    for r in 0..s {
        row_fn(
            &hs[r * hidden..(r + 1) * hidden],
            &ats[r * ab..(r + 1) * ab],
            &mut want[r * hidden..(r + 1) * hidden],
        );
    }
    assert_eq!(got.as_f32().unwrap(), &want[..]);
    assert_eq!(tracker.tag_peak(MLP_TAG), drv.plan.tile_bytes);

    // backward: d_h_in = 2*d_out, d_attn rows broadcast the d_out row sum
    let (dh_got, da_got) = drv
        .backward(&mut tracker, &h_in, &attn, &d_out, |_, _, dt| {
            let ds = dt.as_f32()?;
            let rows = dt.shape()[0];
            let mut dhi = vec![0f32; rows * hidden];
            let mut dat = vec![0f32; rows * ab];
            for r in 0..rows {
                let drow = &ds[r * hidden..(r + 1) * hidden];
                let dsum: f32 = drow.iter().sum();
                for (o, &x) in dhi[r * hidden..(r + 1) * hidden].iter_mut().zip(drow) {
                    *o = 2.0 * x;
                }
                dat[r * ab..(r + 1) * ab].fill(dsum);
            }
            Ok((
                HostTensor::f32(vec![rows, hidden], dhi),
                HostTensor::f32(vec![rows, nq, dh], dat),
            ))
        })
        .unwrap();
    let ds = d_out.as_f32().unwrap();
    for r in 0..s {
        let drow = &ds[r * hidden..(r + 1) * hidden];
        let dsum: f32 = drow.iter().sum();
        for j in 0..hidden {
            assert_eq!(dh_got.as_f32().unwrap()[r * hidden + j], 2.0 * drow[j]);
        }
        for k in 0..ab {
            assert_eq!(da_got.as_f32().unwrap()[r * ab + k], dsum);
        }
    }
    assert_eq!(dh_got.shape(), &[s, hidden]);
    assert_eq!(da_got.shape(), &[s, nq, dh]);
}

#[test]
fn host_loss_head_gradients_match_finite_differences() {
    // HostLossHead is the hand-derived oracle everything above trusts —
    // check it against central differences on a tiny problem.
    let (hidden, vocab, s) = (4usize, 6usize, 3usize);
    let head = make_head(hidden, vocab, 7);
    let mut rng = Rng::new(77);
    let h = rng.normal_vec(s * hidden, 0.7);
    let labels = vec![2i32, IGNORE, 4];
    let ct = 0.5f32;

    let loss = |head: &HostLossHead, h: &[f32]| -> f32 {
        let (sum, _) = head.untiled_loss(h, &labels).unwrap();
        ct * sum
    };

    let mut d_lnf = vec![0f32; hidden];
    let mut d_unembed = vec![0f32; hidden * vocab];
    let mut d_h = vec![0f32; s * hidden];
    head.backward(&h, &labels, ct, &mut d_lnf, &mut d_unembed, &mut d_h)
        .unwrap();

    let eps = 1e-2f32;
    // d_h
    for i in 0..s * hidden {
        let mut hp = h.clone();
        hp[i] += eps;
        let mut hm = h.clone();
        hm[i] -= eps;
        let num = (loss(&head, &hp) - loss(&head, &hm)) / (2.0 * eps);
        assert!(
            (num - d_h[i]).abs() < 2e-2 * d_h[i].abs().max(1.0),
            "d_h[{i}]: fd {num} vs analytic {}",
            d_h[i]
        );
    }
    // d_unembed (spot-check a stripe) and d_lnf
    for i in (0..hidden * vocab).step_by(5) {
        let mut hp = head.unembed.clone();
        hp[i] += eps;
        let mut hm = head.unembed.clone();
        hm[i] -= eps;
        let head_p = HostLossHead::new(hidden, vocab, IGNORE, head.lnf.clone(), hp).unwrap();
        let head_m = HostLossHead::new(hidden, vocab, IGNORE, head.lnf.clone(), hm).unwrap();
        let num = (loss(&head_p, &h) - loss(&head_m, &h)) / (2.0 * eps);
        assert!(
            (num - d_unembed[i]).abs() < 2e-2 * d_unembed[i].abs().max(1.0),
            "d_unembed[{i}]: fd {num} vs analytic {}",
            d_unembed[i]
        );
    }
    for j in 0..hidden {
        let mut lp = head.lnf.clone();
        lp[j] += eps;
        let mut lm = head.lnf.clone();
        lm[j] -= eps;
        let head_p =
            HostLossHead::new(hidden, vocab, IGNORE, lp, head.unembed.clone()).unwrap();
        let head_m =
            HostLossHead::new(hidden, vocab, IGNORE, lm, head.unembed.clone()).unwrap();
        let num = (loss(&head_p, &h) - loss(&head_m, &h)) / (2.0 * eps);
        assert!(
            (num - d_lnf[j]).abs() < 2e-2 * d_lnf[j].abs().max(1.0),
            "d_lnf[{j}]: fd {num} vs analytic {}",
            d_lnf[j]
        );
    }
}

#[test]
fn degenerate_driver_configs_are_rejected() {
    let arena = ScratchArena::new();
    assert!(TiledLossExec::new(0, 8, 32, 4, IGNORE, &arena).is_err());
    assert!(TiledLossExec::new(16, 8, 32, 0, IGNORE, &arena).is_err());
    assert!(TiledMlpExec::new(0, 8, 16, 4, 2, 4, &arena).is_err());
    assert!(TiledMlpExec::new(16, 8, 16, 0, 2, 4, &arena).is_err());
    // shape mismatches surface as errors, not corruption
    let drv = TiledLossExec::new(8, 4, 16, 4, IGNORE, &arena).unwrap();
    let bad_h = HostTensor::f32(vec![4, 4], vec![0.0; 16]);
    let mut tracker = MemoryTracker::new(1 << 30);
    assert!(drv
        .forward(&mut tracker, &bad_h, &[0; 8], |_, lt| Ok(HostTensor::f32(
            vec![lt.numel()],
            vec![0.0; lt.numel()]
        )))
        .is_err());
}
