//! The distributed train step: Ulysses SP forward/backward over AOT PJRT
//! stages, with ZeRO-3 just-in-time parameter gathering, activation
//! checkpointing (+ optional CPU offload), recompute-based backward, and
//! sharded AdamW.
//!
//! Rank execution is SPMD simulated in-process: every rank's buffers are
//! isolated; collectives are the explicit relayouts in
//! `coordinator::ulysses` / `collectives::Group`. The stage programs are
//! exactly the jax functions `python/compile/aot.py` lowered — python
//! never runs here.
//!
//! §Perf note: parameters are uploaded to device buffers ONCE per step
//! (`StepParams`) and reused across ranks / forward / recompute / backward.
//! On real hardware ZeRO-3 would re-gather per layer in backward — the
//! collective LEDGER still records those gathers (the perf model consumes
//! protocol-accurate volumes); only the redundant single-device memcpys
//! are elided. Before this change a 100M-param step re-marshaled every
//! layer's weights 12x (4 ranks x 3 passes); see EXPERIMENTS.md §Perf.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::collectives::faults::{AlstError, FaultInjector, FaultPlan, FaultStats, RetryPolicy};
use crate::collectives::transport::{SocketOptions, SocketTransport, TransportKind};
use crate::collectives::Group;
use crate::config::{FeatureFlags, PlanKind};
use crate::coordinator::dataloader::{shard_sequence, ShardedBatch, IGNORE_INDEX};
use crate::packing::{shard_packed, PackedSequence};
use crate::coordinator::offload::{AsyncOffloadEngine, OffloadConfig, StepTape};
use crate::coordinator::optimizer::{AdamW, AdamWConfig};
use crate::coordinator::plan::{plan_for, AttnShape, ParallelPlan, PlanSaved};
use crate::coordinator::ring::{RingPlan, RingStats};
use crate::coordinator::tape::CheckpointTape;
use crate::coordinator::ulysses::{a2a_head_to_seq_into, a2a_seq_to_head_into};
use crate::coordinator::zero::{init_flat_params, slice_group, GroupGrads, ShardedStore};
use crate::memory::{prefetch_schedule, HostPool, MemoryTracker};
use crate::obs::{self, Category, Tracer};
use crate::runtime::{Engine, HostTensor, Manifest, ScratchArena};
use crate::tiling::exec::{
    untiled_loss_bwd_bytes, untiled_loss_fwd_bytes, untiled_mlp_fwd_bytes, TiledLossExec,
    TiledMlpExec, LOSS_HEAD_TAG, MLP_TAG,
};

/// Execute `f` once per rank, returning the per-rank results in rank
/// order. With `parallel` (and at least two ranks) the ranks run
/// concurrently on `std::thread::scope` threads — legal because the
/// simulated ranks share no mutable state by design (DESIGN.md
/// substitutions: rank-parallelism is data isolation in the coordinator),
/// and the `Group`/`Engine` ledgers sit behind locks whose per-op updates
/// are commutative sums, so the accounted totals are byte-identical to a
/// serial run regardless of thread interleaving (pinned by
/// `rust/tests/relayout_equiv.rs`).
pub fn run_ranks<T, F>(sp: usize, parallel: bool, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    if !parallel || sp < 2 {
        // tag spans opened inside `f` with the scoped rank (restored on
        // exit — the serial path reuses one thread for every rank)
        return (0..sp)
            .map(|r| {
                let _rank = obs::rank_scope(r);
                f(r)
            })
            .collect();
    }
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = (0..sp)
            .map(|r| {
                scope.spawn(move || {
                    let _rank = obs::rank_scope(r);
                    f(r)
                })
            })
            .collect();
        handles
            .into_iter()
            .enumerate()
            .map(|(r, h)| {
                h.join().map_err(|payload| {
                    // a panicking rank thread becomes a typed error the
                    // supervisor can match on, carrying the panic message
                    // instead of swallowing it
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "opaque panic payload".to_string());
                    anyhow::Error::new(AlstError::RankPanic { rank: r, msg })
                })?
            })
            .collect()
    })
}

/// Linear-warmup + cosine-decay learning-rate schedule.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub peak_lr: f32,
    pub warmup_steps: u64,
    pub total_steps: u64,
    pub min_lr: f32,
}

impl LrSchedule {
    pub fn lr_at(&self, step: u64) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.peak_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        let decay_steps = self.total_steps.saturating_sub(self.warmup_steps).max(1);
        let t = (step.saturating_sub(self.warmup_steps)).min(decay_steps) as f32
            / decay_steps as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
        self.min_lr + (self.peak_lr - self.min_lr) * cos
    }
}

#[derive(Debug, Clone)]
pub struct TrainerOptions {
    pub flags: FeatureFlags,
    pub adamw: AdamWConfig,
    /// Optional LR schedule; overrides `adamw.lr` per step when set.
    pub lr_schedule: Option<LrSchedule>,
    pub seed: u64,
    /// Simulated per-rank device budget for checkpoint accounting. Large
    /// default: the real constraint analysis lives in `memory::search`.
    pub device_bytes: u64,
    /// Host pool for checkpoint offload.
    pub host_bytes: u64,
    /// Validate every stage's shapes against the manifest (tests; ~free).
    pub checked: bool,
    /// Extract per-document losses on packed steps. With `tiled_loss`
    /// this is FREE (per-row losses from the tiled sweep are bucketed by
    /// segment id). On the monolithic path it costs n_docs extra
    /// loss-head passes (the logits matmul — the most expensive single
    /// stage at large vocab) per step; turn off for steady-state
    /// training where only the aggregate loss matters.
    pub per_doc_loss: bool,
    /// Run the data-isolated per-rank stage executions on scoped threads
    /// (`run_ranks`). Accounting stays deterministic (see `run_ranks`);
    /// turn off to debug with strictly serial rank order. Note: assumes
    /// the linked `xla` crate's buffers are `Sync` (true of the vendored
    /// stub's host-side buffers). Cost model: each stage call spawns and
    /// joins `sp` scoped threads (scoped spawning is what lets the
    /// closures borrow per-call rank state safely), so the win
    /// materializes when per-rank stage work dominates the ~tens-of-µs
    /// spawn cost — the multi-K-token regime; for toy configs where a
    /// stage is microseconds, serial can be faster.
    pub parallel_ranks: bool,
    /// Pooled-byte budget per dtype for the relayout scratch arena.
    /// Raise it when the per-step relayout working set exceeds the
    /// default (see `runtime::tensor::DEFAULT_POOL_BYTE_BUDGET`) or the
    /// pool sheds buffers and every checkout allocates.
    pub arena_byte_budget: usize,
    /// EXECUTE the loss head as a row-tiled sweep (`tiling::exec`):
    /// `loss_fwd_tile`/`loss_bwd_tile` stream `[rows_per_tile, vocab]`
    /// logits tiles instead of one full-shard `loss_fwd`/`loss_bwd`,
    /// and per-document losses fall out of the SAME sweep (per-row
    /// losses bucketed by segment id — zero extra stage executions,
    /// versus n_docs loss-head re-runs on the monolithic path).
    /// Requires an artifact that exports the optional tile stages
    /// (`Trainer::new` refuses otherwise). Unlike `FeatureFlags`, which
    /// drive the memory/perf *model*, this changes what actually runs.
    pub tiled_loss: bool,
    /// EXECUTE the post-attention block (projection + residual +
    /// RMSNorm + SwiGLU MLP — all row-wise) as a row-tiled sweep via
    /// `mlp_fwd_tile`/`mlp_bwd_tile`. Same artifact requirement.
    pub tiled_mlp: bool,
    /// Run checkpoint offload through the async double-buffered engine
    /// (`coordinator::offload`): forward stores become non-blocking D2H
    /// copies bounded by the config's in-flight byte cap, and backward
    /// H2D restores are prefetched one phase early wherever the
    /// `memory::prefetch_schedule` says the device has headroom. Requires
    /// `flags.ckpt_offload` (there is nothing to overlap on the
    /// device-resident tape). `None` keeps the synchronous
    /// [`CheckpointTape`] — the reference path the async engine must
    /// match bit-for-bit (losses) and byte-for-byte (`transfer_bytes`).
    pub async_offload: Option<OffloadConfig>,
    /// Record structured spans (`obs::Tracer`) across the engine, the
    /// collective group, the relayouts, the checkpoint tape, the tile
    /// sweeps, and the step loop. Off by default: every span site then
    /// costs one branch on the shared disabled handle (see
    /// DESIGN.md §Observability for the overhead contract). Drain with
    /// `Trainer::tracer()` + `Tracer::drain` and export via
    /// `obs::write_trace` / `obs::AttributionReport`.
    pub trace: bool,
    /// Which `ParallelPlan` moves attention data across the SP group.
    /// `Ulysses` (default) runs the seq<->head all-to-alls around the
    /// device `attn_fwd`/`attn_bwd` stages. `Ring` skips the relayouts
    /// entirely: q/k/v stay sequence-sharded and the host RingAttention
    /// plan streams KV blocks rank-to-rank over `Group::send_recv` with
    /// measured transfer/compute overlap — no heads >= sp bound, so sp
    /// can exceed `n_q_heads`. `Trainer::new` validates the chosen
    /// plan's predicate against the manifest's head counts.
    pub plan: PlanKind,
    /// Deterministic fault injection for chaos/resilience runs: the plan
    /// fires exactly once (at the Nth operation of its site on its rank),
    /// and the shared [`FaultInjector`] is installed into the collective
    /// group, the engine, and the async offload copy streams. `None` (the
    /// default) adds zero overhead beyond an `Option` check per site.
    pub fault_plan: Option<FaultPlan>,
    /// Retry/backoff policy installed into the collective group — governs
    /// how many times a transient or corrupt wire fault is absorbed and
    /// how the (jittered) backoff between attempts grows. Exposed on the
    /// CLI as `--retries` / `--retry-base-us` / `--no-retry-jitter`.
    pub retry: RetryPolicy,
    /// Per-wire-op deadline for the group's collectives (`None` keeps the
    /// group default). Real-transport runs size this to the expected
    /// collective latency so a hung peer surfaces as a typed transient
    /// instead of a stalled step.
    pub op_timeout: Option<Duration>,
    /// Frame carrier under the collective group: in-process queues (the
    /// default, bit-identical and allocation-pooled) or spawned rank
    /// processes over Unix-domain sockets, where peer death and hung
    /// peers are detected for real (heartbeats, deadlines).
    pub transport: TransportKind,
    /// Socket-mode knobs (worker binary, connect/heartbeat timeouts).
    /// Ignored under `TransportKind::Local`; `None` takes the defaults.
    pub socket: Option<SocketOptions>,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            flags: FeatureFlags::alst(),
            adamw: AdamWConfig::default(),
            lr_schedule: None,
            seed: 0,
            device_bytes: 1 << 40,
            host_bytes: 1 << 40,
            checked: false,
            per_doc_loss: true,
            parallel_ranks: true,
            arena_byte_budget: crate::runtime::tensor::DEFAULT_POOL_BYTE_BUDGET,
            tiled_loss: false,
            tiled_mlp: false,
            async_offload: None,
            trace: false,
            plan: PlanKind::Ulysses,
            fault_plan: None,
            retry: RetryPolicy::default(),
            op_timeout: None,
            transport: TransportKind::Local,
            socket: None,
        }
    }
}

/// Per-step record (metrics.rs aggregates these).
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: u64,
    pub loss: f32,
    pub grad_norm: f64,
    pub tokens: usize,
    pub step_time: Duration,
    pub a2a_bytes: u64,
    /// Ring-wire bytes (`Group::send_recv`) — the ring plan's KV/grad
    /// rotation traffic; zero under the Ulysses plan.
    pub send_recv_bytes: u64,
    pub gather_bytes: u64,
    pub reduce_scatter_bytes: u64,
    pub ckpt_transfer_bytes: u64,
    pub device_peak_bytes: u64,
    /// Cumulative fault-injection retry count (`FaultStats::retries`) at
    /// the time this step completed; 0 when no injector is installed.
    pub retries: u64,
    /// Cumulative recovery count (`FaultStats::recoveries`) — bumped by
    /// the resilient supervisor (`coordinator::recover`) on each
    /// snapshot-restore, so a recovered run's metrics show where the
    /// restore happened.
    pub recoveries: u64,
}

/// Loss attributed to one document of a packed batch (`metrics` logs
/// these; `tokens` is the document length, so `tokens - 1` targets).
#[derive(Debug, Clone, PartialEq)]
pub struct DocumentLoss {
    pub doc_id: u64,
    pub tokens: usize,
    pub loss: f32,
}

/// Per-step record for a packed batch: the aggregate step metrics plus
/// the per-document loss breakdown and packing accounting.
#[derive(Debug, Clone)]
pub struct PackedStepMetrics {
    pub metrics: StepMetrics,
    pub doc_losses: Vec<DocumentLoss>,
    /// Document tokens in the pack (excludes padding).
    pub real_tokens: usize,
    /// Trailing padding tokens (loss-masked).
    pub padding_tokens: usize,
}

/// Device-resident parameter buffers for one step (perf fast path).
struct StepParams {
    embed: Vec<xla::PjRtBuffer>,
    layers: Vec<Vec<xla::PjRtBuffer>>,
    final_: Vec<xla::PjRtBuffer>,
}

pub struct Trainer {
    pub manifest: Manifest,
    pub engine: Engine,
    pub flags: FeatureFlags,
    pub group: Group,
    pub params: ShardedStore,
    pub grads: ShardedStore,
    pub opt: AdamW,
    pub device: MemoryTracker,
    pub host: HostPool,
    lr_schedule: Option<LrSchedule>,
    step: u64,
    checked: bool,
    per_doc_loss: bool,
    parallel_ranks: bool,
    /// Tiled-execution gates (see `TrainerOptions`); the `*_tile_rows`
    /// are read back from the manifest's tile-stage shapes at load.
    tiled_loss: bool,
    tiled_mlp: bool,
    loss_tile_rows: usize,
    mlp_tile_rows: usize,
    /// Scratch-buffer pool the step loop's relayouts ping-pong through:
    /// after the first forward/backward cycle populates it, the 2×n_layers
    /// relayouts of every later step are allocation-free. `Arc` so the
    /// offload engine's copy-stream workers share the same pool (deref
    /// keeps every `&self.arena` call site unchanged).
    arena: Arc<ScratchArena>,
    /// The async offload engine (`TrainerOptions::async_offload`); `None`
    /// runs the synchronous tape.
    offload: Option<Arc<AsyncOffloadEngine>>,
    /// Per-layer H2D prefetch schedule (`memory::prefetch_schedule`),
    /// derived once at construction from the artifact's shard shapes and
    /// the device budget; consulted only on the async path.
    prefetch_ok: Vec<bool>,
    /// Step tracer shared with the engine, the group, and the device
    /// tracker; the global disabled handle unless `TrainerOptions::trace`.
    tracer: Arc<Tracer>,
    /// Which attention `ParallelPlan` the step loop runs (see
    /// `TrainerOptions::plan`).
    plan: PlanKind,
    /// The ring plan instance (owns the overlap-vs-stall accounting);
    /// only exercised when `plan == PlanKind::Ring`.
    ring_plan: RingPlan,
    /// The shared fault injector when `TrainerOptions::fault_plan` was
    /// set (installed into group/engine/offload at construction); the
    /// step loop reads its counters into `StepMetrics`.
    injector: Option<Arc<FaultInjector>>,
    /// Attention-mask segment boundaries for the ring plan, matching the
    /// exported `attn_fwd` stage's mask: the device stage computes DENSE
    /// causal attention (packed segment isolation in this runtime lives
    /// in the labels/positions, not the attention stage), so the ring
    /// plan gets the single-segment `[0, seq]` prefix. Segment-aware
    /// `cu_seqlens` flows are exercised at the plan level
    /// (`tests/plan_equiv.rs`).
    step_cu: Vec<i32>,
}

impl Trainer {
    /// Build a trainer from an artifact directory (manifest + HLO stages).
    pub fn new(artifact_dir: &std::path::Path, opts: TrainerOptions) -> Result<Trainer> {
        let manifest = Manifest::load(artifact_dir)
            .with_context(|| format!("loading manifest from {}", artifact_dir.display()))?;
        let tracer = if opts.trace {
            Arc::new(Tracer::new(true))
        } else {
            Tracer::off()
        };
        let mut engine = Engine::cpu()?;
        engine.set_tracer(tracer.clone());
        engine.load_manifest(&manifest)?;

        // Tiled execution needs the optional tile stages; refusing at
        // load beats silently falling back (the caller asked for a
        // different memory profile).
        let loss_tile_rows = if opts.tiled_loss {
            anyhow::ensure!(
                manifest.has_tiled_loss(),
                "TrainerOptions::tiled_loss set but artifact `{}` exports no \
                 loss_fwd_tile/loss_bwd_tile stages — re-export with the \
                 current compile.aot",
                artifact_dir.display()
            );
            manifest.loss_tile_rows().unwrap_or(0)
        } else {
            0
        };
        let mlp_tile_rows = if opts.tiled_mlp {
            anyhow::ensure!(
                manifest.has_tiled_mlp(),
                "TrainerOptions::tiled_mlp set but artifact `{}` exports no \
                 mlp_fwd_tile/mlp_bwd_tile stages — re-export with the \
                 current compile.aot",
                artifact_dir.display()
            );
            manifest.mlp_tile_rows().unwrap_or(0)
        } else {
            0
        };

        let sp = manifest.sp;
        // The chosen plan must accept this (heads, sp) combination up
        // front — the Ulysses predicate's error names the ring plan as
        // the fix when sp exceeds the head count.
        let c = &manifest.config;
        plan_for(opts.plan)
            .validate(c.n_q_heads, c.n_kv_heads, sp)
            .with_context(|| {
                format!("{} plan rejected the manifest", opts.plan.as_str())
            })?;
        // ZeRO-3 shards over the SP group; without zero3 every rank holds
        // a full replica (world=1 sharding on a shared store).
        let shard_world = if opts.flags.zero3 { sp } else { 1 };
        let flat = init_flat_params(&manifest.params, opts.seed, 0.02);
        let total = flat.len();
        let params = ShardedStore::from_flat(&flat, shard_world);
        let grads = ShardedStore::zeros(total, shard_world);
        let opt = AdamW::new(opts.adamw, total, shard_world);

        let mut group = match opts.transport {
            TransportKind::Local => Group::new(sp),
            TransportKind::Socket => {
                let sopts = opts.socket.clone().unwrap_or_default();
                let st = SocketTransport::spawn(sp, sopts, tracer.clone())
                    .context("spawning socket-transport rank workers")?;
                Group::with_transport(sp, st)
            }
        };
        group.set_tracer(tracer.clone());
        group.set_retry_policy(opts.retry);
        if let Some(t) = opts.op_timeout {
            group.set_op_timeout(t);
        }
        // One injector instance shared by every gated site, so "fire at
        // the Nth op" means the Nth across the whole run regardless of
        // which subsystem performs it.
        let injector = opts.fault_plan.map(FaultInjector::new);
        if let Some(inj) = &injector {
            group.set_injector(inj.clone());
            engine.set_injector(inj.clone());
        }
        let mut device = MemoryTracker::new(opts.device_bytes);
        device.set_tracer(tracer.clone());

        let arena = Arc::new(ScratchArena::with_byte_budget(opts.arena_byte_budget));
        let (offload, prefetch_ok) = if let Some(cfg) = &opts.async_offload {
            anyhow::ensure!(
                opts.flags.ckpt_offload,
                "TrainerOptions::async_offload requires flags.ckpt_offload — a \
                 device-resident tape has no host traffic to overlap"
            );
            let engine = Arc::new(AsyncOffloadEngine::new(
                arena.clone(),
                tracer.clone(),
                cfg.clone(),
            ));
            if let Some(inj) = &injector {
                engine.set_injector(inj.clone());
            }
            // Schedule derivation uses the monolithic (untiled) working-set
            // formulas even when tiled execution is on: the tiled sets are
            // strictly smaller, so the schedule errs toward fewer early
            // fetches — never toward device pressure.
            let c = &manifest.config;
            let ssh = manifest.seq_shard;
            let resident =
                if opts.parallel_ranks && sp > 1 { sp as u64 } else { 1 };
            let ckpt = (sp * ssh * c.hidden * 4) as u64; // all ranks, one layer
            let work = resident * untiled_mlp_fwd_bytes(ssh, c.hidden, c.ffn);
            let head = resident * untiled_loss_bwd_bytes(ssh, c.vocab);
            let ok = prefetch_schedule(c.n_layers, ckpt, work, head, opts.device_bytes);
            (Some(engine), ok)
        } else {
            (None, Vec::new())
        };

        let step_cu = vec![0, manifest.seq as i32];
        Ok(Trainer {
            manifest,
            engine,
            flags: opts.flags,
            group,
            params,
            grads,
            opt,
            device,
            host: HostPool::new(opts.host_bytes),
            lr_schedule: opts.lr_schedule,
            step: 0,
            checked: opts.checked,
            per_doc_loss: opts.per_doc_loss,
            parallel_ranks: opts.parallel_ranks,
            tiled_loss: opts.tiled_loss,
            tiled_mlp: opts.tiled_mlp,
            loss_tile_rows,
            mlp_tile_rows,
            arena,
            offload,
            prefetch_ok,
            tracer,
            plan: opts.plan,
            ring_plan: RingPlan::default(),
            injector,
            step_cu,
        })
    }

    /// The shared fault injector (`TrainerOptions::fault_plan`); the
    /// resilient supervisor disarms/reads it between steps.
    pub fn injector(&self) -> Option<&Arc<FaultInjector>> {
        self.injector.as_ref()
    }

    /// Injection/retry/recovery counters, all-zero without an injector.
    pub fn fault_stats(&self) -> FaultStats {
        self.injector.as_ref().map(|i| i.stats()).unwrap_or_default()
    }

    /// The attention plan this trainer runs.
    pub fn plan_kind(&self) -> PlanKind {
        self.plan
    }

    /// Ring-plan transfer/stall accounting (hops, copy/stall ns, bytes),
    /// cumulative since construction or the last
    /// [`RingPlan::reset_stats`]; all-zero under the Ulysses plan.
    pub fn ring_stats(&self) -> RingStats {
        self.ring_plan.stats()
    }

    /// The async offload engine when `TrainerOptions::async_offload` was
    /// set (stall/stream accounting for benches and tests).
    pub fn offload_engine(&self) -> Option<&Arc<AsyncOffloadEngine>> {
        self.offload.as_ref()
    }

    /// The step tracer (the shared disabled handle unless
    /// `TrainerOptions::trace` was set). Drain it between steps or after
    /// a run to export `obs::write_trace` / build an
    /// `obs::AttributionReport`.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    pub fn sp(&self) -> usize {
        self.manifest.sp
    }

    /// The trainer's relayout scratch pool (hit/miss counters readable by
    /// tests and benches; steady-state hit rate should be 1.0).
    pub fn arena(&self) -> &ScratchArena {
        &self.arena
    }

    pub fn n_layers(&self) -> usize {
        self.manifest.config.n_layers
    }

    fn exec(&self, stage: &str, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        let out = self
            .engine
            .execute_buffers(&Engine::stage_key(&self.manifest, stage), inputs)
            .with_context(|| format!("executing stage {stage}"))?;
        if self.checked {
            let io = self.manifest.stage(stage);
            for (t, meta) in out.iter().zip(&io.outputs) {
                anyhow::ensure!(
                    t.shape() == meta.shape.as_slice(),
                    "stage {stage} output shape {:?} != manifest {:?}",
                    t.shape(),
                    meta.shape
                );
            }
        }
        Ok(out)
    }

    fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        self.engine.to_buffer(t)
    }

    fn upload_all(&self, ts: &[HostTensor]) -> Result<Vec<xla::PjRtBuffer>> {
        ts.iter().map(|t| self.upload(t)).collect()
    }

    /// Gather + upload every parameter group for this step. Each group's
    /// all-gather is ledgered once here; backward ledgers its re-gathers
    /// explicitly (see `account_bwd_regather`).
    fn build_step_params(&self) -> Result<StepParams> {
        let p = &self.manifest.params;
        let embed_flat = self.params.gather_range(&self.group, 0..p.embed_numel)?;
        let embed = self.upload_all(&slice_group(&embed_flat, &p.embed))?;
        let mut layers = Vec::with_capacity(p.n_layers);
        for li in 0..p.n_layers {
            let flat = self.params.gather_range(&self.group, p.layer_range(li))?;
            layers.push(self.upload_all(&slice_group(&flat, &p.layer))?);
        }
        let fstart = p.embed_numel + p.n_layers * p.layer_numel;
        let final_flat = self
            .params
            .gather_range(&self.group, fstart..fstart + p.final_numel)?;
        let final_ = self.upload_all(&slice_group(&final_flat, &p.final_))?;
        Ok(StepParams { embed, layers, final_ })
    }

    /// Ledger the ZeRO-3 backward re-gather of one layer (the data itself
    /// is served from the step cache on this single-device runtime).
    fn account_bwd_regather(&self, li: usize) -> Result<()> {
        let range = self.manifest.params.layer_range(li);
        self.group.account_gather(range.len() as u64 * 4)
    }

    /// Ranks whose stage working sets are resident at once on the
    /// monolithic (untiled) paths: all `sp` under the scoped-thread
    /// executor, one when ranks run serially. Tracker charges scale by
    /// this so `parallel_ranks: false` runs are not overstated.
    fn resident_ranks(&self) -> u64 {
        if self.parallel_ranks && self.manifest.sp > 1 {
            self.manifest.sp as u64
        } else {
            1
        }
    }

    /// Forward through one layer for all ranks; returns (new_h, saved)
    /// where `saved` holds what backward reuses after recompute (qkv +
    /// attention-output buffers, device-side). `h_host` is the host copy
    /// of `h` — the tiled post-attention sweep slices its row tiles from
    /// it (`&mut self` only for the MemoryTracker instrumentation).
    fn layer_forward(
        &mut self,
        lp: &[xla::PjRtBuffer],
        h: &[xla::PjRtBuffer],
        h_host: &[HostTensor],
        pos: &[xla::PjRtBuffer],
    ) -> Result<(Vec<xla::PjRtBuffer>, LayerAct)> {
        let sp = self.sp();
        let (ln1, wq, wk, wv) = (&lp[0], &lp[1], &lp[2], &lp[3]);
        let (wo, ln2, wg, wu, wd) = (&lp[4], &lp[5], &lp[6], &lp[7], &lp[8]);

        // Per-rank stage executions run concurrently (scoped threads) —
        // ranks are data-isolated; see `run_ranks`.
        let qkv = run_ranks(sp, self.parallel_ranks, |r| {
            let out = self.exec("pre_attn_fwd", &[ln1, wq, wk, wv, &h[r], &pos[r]])?;
            let mut it = out.into_iter();
            Ok((it.next().unwrap(), it.next().unwrap(), it.next().unwrap()))
        })?;
        let mut qs = Vec::with_capacity(sp);
        let mut ks = Vec::with_capacity(sp);
        let mut vs = Vec::with_capacity(sp);
        for (q, k, v) in qkv {
            qs.push(q);
            ks.push(k);
            vs.push(v);
        }
        let (q_full_b, k_full_b, v_full_b, o_sh, q_seq, k_seq, v_seq, ring_saved) =
            if self.plan == PlanKind::Ring {
                // Ring plan: NO relayout. q/k/v stay sequence-sharded; the
                // plan rotates KV blocks rank-to-rank over
                // `Group::send_recv` (byte-ledgered, overlap measured) and
                // returns seq-sharded outputs directly. The inputs and the
                // saved (o, lse) ride the LayerAct: backward reruns the
                // rotation from them instead of the device `attn_bwd`.
                let c = &self.manifest.config;
                let shape = AttnShape::new(c.n_q_heads, c.n_kv_heads, c.head_dim);
                let (o_sh, saved) = self.ring_plan.attention_forward(
                    &self.group,
                    &self.arena,
                    &qs,
                    &ks,
                    &vs,
                    &shape,
                    &self.step_cu,
                )?;
                (Vec::new(), Vec::new(), Vec::new(), o_sh, qs, ks, vs, Some(saved))
            } else {
                // Ulysses boundary 1: sequence -> head layout, through the
                // arena: outputs land in recycled buffers, and both the
                // pre-relayout shards and the uploaded host copies go
                // straight back to the pool — the ping-pong that makes
                // steady-state relayout allocation-free.
                let q_full = a2a_seq_to_head_into(&self.group, &qs, &self.arena)?;
                let k_full = a2a_seq_to_head_into(&self.group, &ks, &self.arena)?;
                let v_full = a2a_seq_to_head_into(&self.group, &vs, &self.arena)?;
                self.arena.recycle_all(qs);
                self.arena.recycle_all(ks);
                self.arena.recycle_all(vs);
                let q_full_b = self.upload_all(&q_full)?;
                let k_full_b = self.upload_all(&k_full)?;
                let v_full_b = self.upload_all(&v_full)?;
                self.arena.recycle_all(q_full);
                self.arena.recycle_all(k_full);
                self.arena.recycle_all(v_full);

                let o_full = run_ranks(sp, self.parallel_ranks, |r| {
                    let out =
                        self.exec("attn_fwd", &[&q_full_b[r], &k_full_b[r], &v_full_b[r]])?;
                    Ok(out.into_iter().next().unwrap())
                })?;
                // Ulysses boundary 2: head -> sequence layout.
                let o_sh = a2a_head_to_seq_into(
                    &self.group,
                    &o_full,
                    self.manifest.config.n_q_heads,
                    false,
                    &self.arena,
                )?;
                self.arena.recycle_all(o_full);
                (q_full_b, k_full_b, v_full_b, o_sh, Vec::new(), Vec::new(), Vec::new(), None)
            };

        let mut h_out = Vec::with_capacity(sp);
        let mut h_out_host = Vec::with_capacity(sp);
        let mut o_sh_b = Vec::new();
        let o_sh_host = if self.tiled_mlp {
            // Row-tiled post-attention sweep: h/attn tiles sliced from
            // the host copies, one `[rows, ffn]`-scale working set at a
            // time. The o_sh host tensors ride along in the LayerAct —
            // backward's tile sweep slices the same inputs. No full
            // o_sh device upload: only tile-sized buffers go up.
            let post = self.tiled_post_attn_forward(lp, h_host, &o_sh)?;
            for (b, t) in post {
                h_out.push(b);
                h_out_host.push(t);
            }
            o_sh
        } else {
            o_sh_b = self.upload_all(&o_sh)?;
            self.arena.recycle_all(o_sh);
            // untiled: the full-shard gate/up working set, one copy per
            // resident rank
            let c = &self.manifest.config;
            let ssh = self.manifest.seq_shard;
            let bytes = self.resident_ranks() * untiled_mlp_fwd_bytes(ssh, c.hidden, c.ffn);
            self.device.alloc(bytes, MLP_TAG)?;
            let post = run_ranks(sp, self.parallel_ranks, |r| {
                let out =
                    self.exec("post_attn_fwd", &[wo, ln2, wg, wu, wd, &h[r], &o_sh_b[r]])?;
                let t = out.into_iter().next().unwrap();
                let b = self.upload(&t)?;
                Ok((b, t))
            });
            // free before `?`: a failed stage must not leave phantom
            // bytes charged on the reusable tracker
            self.device.free(bytes, MLP_TAG);
            let post = post?;
            for (b, t) in post {
                h_out.push(b);
                h_out_host.push(t);
            }
            Vec::new()
        };
        Ok((
            h_out,
            LayerAct {
                q_full: q_full_b,
                k_full: k_full_b,
                v_full: v_full_b,
                o_sh: o_sh_b,
                o_sh_host,
                h_out_host,
                q_seq,
                k_seq,
                v_seq,
                ring_saved,
            },
        ))
    }

    /// Return a `LayerAct`'s ring-plan buffers (seq-sharded q/k/v plus
    /// the saved (o, lse)) to the arena pool. No-op under the Ulysses
    /// plan, whose acts keep those fields empty.
    fn recycle_plan_act(&self, act: &mut LayerAct) {
        self.arena.recycle_all(std::mem::take(&mut act.q_seq));
        self.arena.recycle_all(std::mem::take(&mut act.k_seq));
        self.arena.recycle_all(std::mem::take(&mut act.v_seq));
        if let Some(saved) = act.ring_saved.take() {
            saved.recycle(&self.arena);
        }
    }

    /// The tiled post-attention forward sweep: per rank, slice
    /// `(h_in, attn)` row tiles and stream them through `mlp_fwd_tile`.
    /// Serial over ranks — tiles must accumulate nothing here, but the
    /// driver's tracker charges want a single writer.
    fn tiled_post_attn_forward(
        &mut self,
        lp: &[xla::PjRtBuffer],
        h_host: &[HostTensor],
        o_sh: &[HostTensor],
    ) -> Result<Vec<(xla::PjRtBuffer, HostTensor)>> {
        let sp = self.manifest.sp;
        let ssh = self.manifest.seq_shard;
        let rows = self.mlp_tile_rows;
        let key = Engine::stage_key(&self.manifest, "mlp_fwd_tile");
        let (wo, ln2, wg, wu, wd) = (&lp[4], &lp[5], &lp[6], &lp[7], &lp[8]);
        let c = &self.manifest.config;
        let (engine, arena, device) = (&self.engine, &self.arena, &mut self.device);
        let tracer = &self.tracer;
        let mut out = Vec::with_capacity(sp);
        for r in 0..sp {
            let _rank = obs::rank_scope(r);
            let drv = TiledMlpExec::new(
                ssh, c.hidden, c.ffn, rows, c.n_q_heads, c.head_dim, arena,
            )?
            .with_tracer(tracer.clone());
            let h_out = drv.forward(device, &h_host[r], &o_sh[r], |ht, at| {
                let hb = engine.to_buffer(ht)?;
                let ab = engine.to_buffer(at)?;
                let o = engine.execute_buffers(&key, &[wo, ln2, wg, wu, wd, &hb, &ab])?;
                Ok(o.into_iter().next().unwrap())
            })?;
            let b = engine.to_buffer(&h_out)?;
            out.push((b, h_out));
        }
        Ok(out)
    }

    /// The tiled post-attention backward sweep: per rank, stream
    /// `(h_in, attn, d_out)` tiles through `mlp_bwd_tile`, accumulating
    /// the five weight-grad partials into `layer_grads[r]` in ascending
    /// tile order (the pinned accumulation contract) and assembling the
    /// full `(d_h_resid, d_attn)` shards.
    fn tiled_post_attn_backward(
        &mut self,
        lp: &[xla::PjRtBuffer],
        h_in_host: &[HostTensor],
        o_sh_host: &[HostTensor],
        d_h_host: &[HostTensor],
        layer_grads: &mut [GroupGrads],
    ) -> Result<(Vec<HostTensor>, Vec<HostTensor>)> {
        let sp = self.manifest.sp;
        let ssh = self.manifest.seq_shard;
        let rows = self.mlp_tile_rows;
        let key = Engine::stage_key(&self.manifest, "mlp_bwd_tile");
        let (wo, ln2, wg, wu, wd) = (&lp[4], &lp[5], &lp[6], &lp[7], &lp[8]);
        let c = &self.manifest.config;
        let (engine, arena, device) = (&self.engine, &self.arena, &mut self.device);
        let tracer = &self.tracer;
        let mut d_h_resid = Vec::with_capacity(sp);
        let mut d_attn = Vec::with_capacity(sp);
        for r in 0..sp {
            let _rank = obs::rank_scope(r);
            let drv = TiledMlpExec::new(
                ssh, c.hidden, c.ffn, rows, c.n_q_heads, c.head_dim, arena,
            )?
            .with_tracer(tracer.clone());
            let lg = &mut layer_grads[r];
            let (dh, da) = drv.backward(
                device,
                &h_in_host[r],
                &o_sh_host[r],
                &d_h_host[r],
                |ht, at, dt| {
                    let hb = engine.to_buffer(ht)?;
                    let ab = engine.to_buffer(at)?;
                    let db = engine.to_buffer(dt)?;
                    let o = engine
                        .execute_buffers(&key, &[wo, ln2, wg, wu, wd, &hb, &ab, &db])?;
                    let mut it = o.into_iter();
                    for name in ["wo", "ln2", "wg", "wu", "wd"] {
                        lg.accumulate(name, &it.next().unwrap())?;
                    }
                    Ok((it.next().unwrap(), it.next().unwrap()))
                },
            )?;
            d_h_resid.push(dh);
            d_attn.push(da);
        }
        Ok((d_h_resid, d_attn))
    }

    /// One full training step on one global sequence (effective batch 1,
    /// matching the paper's evaluation protocol): forward/backward + a
    /// single optimizer step.
    pub fn train_step(&mut self, ids: &[i32]) -> Result<StepMetrics> {
        self.train_step_accum(std::slice::from_ref(&ids.to_vec()))
    }

    /// Training step with gradient accumulation (paper §5.6 uses GAS=8 to
    /// equalize data between the DP baseline and the SP run). Each micro
    /// batch runs forward/backward; gradients accumulate in the ZeRO
    /// shards; ONE optimizer step follows. With synchronized replicas this
    /// is mathematically identical to data parallelism over
    /// `micro_batches.len()` ranks-groups.
    pub fn train_step_accum(&mut self, micro_batches: &[Vec<i32>]) -> Result<StepMetrics> {
        anyhow::ensure!(!micro_batches.is_empty(), "need at least one micro batch");
        let t0 = Instant::now();
        // clone first: a guard borrowing `self.tracer` would pin `self`
        let tracer = self.tracer.clone();
        let mut span = tracer.span(Category::Step, "train_step");
        self.group.reset_stats();
        self.device.reset_peak();

        let mut loss_acc = 0f32;
        let mut tokens = 0usize;
        let mut ckpt_transfer = 0u64;
        let n = micro_batches.len() as f32;
        for ids in micro_batches {
            let (loss, transfer) = self.forward_backward(ids, 1.0 / n)?;
            loss_acc += loss / n;
            tokens += ids.len();
            ckpt_transfer += transfer;
        }

        let grad_norm = self.optimizer_step();
        let comm = self.group.stats();
        let step_time = t0.elapsed();
        // the span carries the SAME duration `StepMetrics.step_time`
        // reports — the attribution report reconciles against it exactly
        span.set_step(self.step);
        span.set_dur(step_time);
        drop(span);
        let fstats = self.fault_stats();
        Ok(StepMetrics {
            step: self.step,
            loss: loss_acc,
            grad_norm,
            tokens,
            step_time,
            a2a_bytes: comm.all_to_all_bytes,
            send_recv_bytes: comm.send_recv_bytes,
            gather_bytes: comm.all_gather_bytes,
            reduce_scatter_bytes: comm.reduce_scatter_bytes,
            ckpt_transfer_bytes: ckpt_transfer,
            device_peak_bytes: self.device.peak(),
            retries: fstats.retries,
            recoveries: fstats.recoveries,
        })
    }

    /// Apply the accumulated gradients (AdamW on the owned shards) and
    /// clear them. Returns the pre-clip global gradient norm. Uses the
    /// scheduled learning rate if a schedule is configured.
    pub fn optimizer_step(&mut self) -> f64 {
        let tracer = self.tracer.clone();
        let mut span = tracer.span(Category::Optimizer, "optimizer_step");
        if let Some(sched) = &self.lr_schedule {
            self.opt.cfg.lr = sched.lr_at(self.step);
        }
        let norm = self.opt.step(&mut self.params, &self.grads);
        self.grads.zero_fill();
        self.step += 1;
        // post-increment, matching `StepMetrics::step` and the step span
        span.set_step(self.step);
        norm
    }

    /// One forward+backward pass over one sequence, scaling the loss
    /// cotangent by `loss_scale` (1/GAS for accumulation). Gradients are
    /// ADDED to the ZeRO shards; no optimizer step. Returns
    /// (mean loss, checkpoint transfer bytes).
    fn forward_backward(&mut self, ids: &[i32], loss_scale: f32) -> Result<(f32, u64)> {
        anyhow::ensure!(
            ids.len() == self.manifest.seq,
            "sequence length {} != artifact seq {}",
            ids.len(),
            self.manifest.seq
        );
        let shards: Vec<ShardedBatch> = shard_sequence(ids, self.manifest.sp);
        let (loss, transfer, _) = self.forward_backward_shards(&shards, loss_scale, None)?;
        Ok((loss, transfer))
    }

    /// Shard-level forward+backward shared by the whole-sequence and
    /// packed paths. With `packed` (and `per_doc_loss` on), per-document
    /// losses are extracted at the loss head. Tiled loss: ONE sweep
    /// emits per-row losses, documents are row buckets — no extra stage
    /// executions. Monolithic loss: each document's labels isolated in
    /// turn (everything else `IGNORE_INDEX`), run only on ranks whose
    /// shard overlaps the document — n_docs extra loss-head logits
    /// matmuls per step; disable `TrainerOptions::per_doc_loss` for
    /// steady-state training on that path.
    fn forward_backward_shards(
        &mut self,
        shards: &[ShardedBatch],
        loss_scale: f32,
        packed: Option<&PackedSequence>,
    ) -> Result<(f32, u64, Vec<DocumentLoss>)> {
        let mut tape = match &self.offload {
            Some(engine) => StepTape::with_engine(engine.clone()),
            None => StepTape::sync(
                CheckpointTape::new(self.n_layers(), self.manifest.sp, self.flags.ckpt_offload)
                    .with_tracer(self.tracer.clone()),
            ),
        };
        let out = self.forward_backward_shards_inner(&mut tape, shards, loss_scale, packed);
        if out.is_err() {
            // Deterministic mid-step teardown: drain the copy streams,
            // release every checkpoint charge still held (host-staged and
            // device-fetched), recycle the buffers. The trainer stays
            // reusable after a failed step with no phantom pool bytes.
            tape.abort(&mut self.device, &mut self.host, &self.arena);
        }
        out
    }

    /// The step body `forward_backward_shards` wraps; checkpoint traffic
    /// goes through `tape` (sync or async), whose cleanup on error is the
    /// wrapper's job.
    fn forward_backward_shards_inner(
        &mut self,
        tape: &mut StepTape,
        shards: &[ShardedBatch],
        loss_scale: f32,
        packed: Option<&PackedSequence>,
    ) -> Result<(f32, u64, Vec<DocumentLoss>)> {
        let sp = self.manifest.sp;
        anyhow::ensure!(
            shards.len() == sp,
            "expected {sp} shards, got {}",
            shards.len()
        );
        let total: usize = shards.iter().map(|s| s.ids.len()).sum();
        anyhow::ensure!(
            total == self.manifest.seq,
            "sharded sequence length {} != artifact seq {}",
            total,
            self.manifest.seq
        );
        let mut ids_b = Vec::with_capacity(sp);
        let mut pos_b = Vec::with_capacity(sp);
        let mut lab_b = Vec::with_capacity(sp);
        for s in shards {
            ids_b.push(self.upload(&HostTensor::i32(vec![s.ids.len()], s.ids.clone()))?);
            pos_b.push(self.upload(&HostTensor::i32(
                vec![s.positions.len()],
                s.positions.clone(),
            ))?);
            // the tiled loss sweeps slice labels host-side per tile —
            // no full-shard label upload on that path
            if !self.tiled_loss {
                lab_b.push(
                    self.upload(&HostTensor::i32(vec![s.labels.len()], s.labels.clone()))?,
                );
            }
        }

        // ---- forward -------------------------------------------------------
        let dev_params = self.build_step_params()?;
        let n_layers = self.n_layers();
        let embed_out = run_ranks(sp, self.parallel_ranks, |r| {
            let out = self.exec("embed_fwd", &[&dev_params.embed[0], &ids_b[r]])?;
            let t = out.into_iter().next().unwrap();
            let b = self.upload(&t)?;
            Ok((b, t))
        })?;
        let mut h: Vec<xla::PjRtBuffer> = Vec::with_capacity(sp);
        let mut h_host: Vec<HostTensor> = Vec::with_capacity(sp);
        for (b, t) in embed_out {
            h.push(b);
            h_host.push(t);
        }

        for li in 0..n_layers {
            // run the layer first (the tiled MLP sweep slices row tiles
            // from the live h_host copies), THEN checkpoint the layer
            // INPUT (host side, offloadable — §3.3)
            let (h_new, mut act) =
                self.layer_forward(&dev_params.layers[li], &h, &h_host, &pos_b)?;
            for (r, hr) in h_host.drain(..).enumerate() {
                tape.store(li, r, hr, &mut self.device, &mut self.host)?;
            }
            // fwd pass keeps no per-layer hosts: backward recomputes
            // (the ring plan's saved state included)
            self.recycle_plan_act(&mut act);
            self.arena.recycle_all(act.o_sh_host);
            h_host = act.h_out_host;
            h = h_new;
        }
        // Async path: the top layer's backward is the first fetch; start
        // its H2D restore now so it lands behind the loss head (when the
        // schedule says the device can hold it alongside the logits).
        if n_layers > 0 && self.prefetch_ok.last() == Some(&true) {
            tape.prefetch_layer(n_layers - 1, sp)?;
        }

        let (lnf, unembed) = (&dev_params.final_[0], &dev_params.final_[1]);
        let ssh = self.manifest.seq_shard;
        let vocab = self.manifest.config.vocab;
        // Per-row losses per rank, tiled path only (consumed by the
        // single-pass per-document bucketing, then recycled).
        let mut per_row_ranks: Vec<Vec<f32>> = Vec::new();
        let (loss_sums, counts): (Vec<f32>, Vec<f32>) = if self.tiled_loss {
            // Row-tiled sweep: one [rows, vocab] fp32 logits tile at a
            // time, serial over ranks (single tracker writer; the pinned
            // ascending-row reduction needs no cross-rank order anyway).
            let hidden = self.manifest.config.hidden;
            let ignore = self.manifest.ignore_index;
            let rows = self.loss_tile_rows;
            let key = Engine::stage_key(&self.manifest, "loss_fwd_tile");
            let (engine, arena, device) = (&self.engine, &self.arena, &mut self.device);
            let tracer = &self.tracer;
            let mut sums = Vec::with_capacity(sp);
            let mut cnts = Vec::with_capacity(sp);
            for r in 0..sp {
                let _rank = obs::rank_scope(r);
                let drv = TiledLossExec::new(ssh, hidden, vocab, rows, ignore, arena)?
                    .with_tracer(tracer.clone());
                let sweep =
                    drv.forward(device, &h_host[r], &shards[r].labels, |ht, lt| {
                        let hb = engine.to_buffer(ht)?;
                        let lb = engine.to_buffer(lt)?;
                        let out =
                            engine.execute_buffers(&key, &[lnf, unembed, &hb, &lb])?;
                        Ok(out.into_iter().next().unwrap())
                    })?;
                sums.push(sweep.loss_sum);
                cnts.push(sweep.count);
                per_row_ranks.push(sweep.per_row_loss);
            }
            (sums, cnts)
        } else {
            // untiled: each resident rank holds its full-shard fp32
            // logits copy (the §3.1 monster the tracker tags)
            let bytes = self.resident_ranks() * untiled_loss_fwd_bytes(ssh, vocab);
            self.device.alloc(bytes, LOSS_HEAD_TAG)?;
            let loss_out = run_ranks(sp, self.parallel_ranks, |r| {
                let out = self.exec("loss_fwd", &[lnf, unembed, &h[r], &lab_b[r]])?;
                Ok((out[0].scalar_f32()?, out[1].scalar_f32()?))
            });
            self.device.free(bytes, LOSS_HEAD_TAG);
            loss_out?.into_iter().unzip()
        };
        let loss_sum = self.group.all_reduce_scalars(&loss_sums)?;
        let count = self.group.all_reduce_scalars(&counts)?;
        // Reachable on packed batches (e.g. every document length 1 =>
        // all labels IGNORE_INDEX): without this check loss is NaN and
        // the backward cotangent 1/count is inf, silently poisoning the
        // weights.
        anyhow::ensure!(
            count > 0.0,
            "batch has no trainable targets (all labels are IGNORE_INDEX)"
        );
        let loss = loss_sum / count;

        // Per-document loss (packed batches, opt-out via
        // `TrainerOptions::per_doc_loss`). Tiled path: FREE — the sweep
        // already produced per-row losses, so documents are just row
        // buckets (ascending-row sums, same pinned order as the
        // aggregate); engine executions for the loss stage stay at
        // n_tiles. Untiled path: the old n_docs re-execution, re-running
        // the loss head with labels masked to one document at a time —
        // kept as the reference the equivalence tests compare against.
        // A document with a single token has no target; it reports loss
        // 0 over 0 targets either way.
        let mut doc_losses = Vec::new();
        if let Some(p) = packed.filter(|_| self.per_doc_loss) {
            let ignore = self.manifest.ignore_index;
            for d in 0..p.n_docs() {
                let range = p.segment_range(d);
                let (mut sum_d, mut count_d) = (0f32, 0f32);
                if self.tiled_loss {
                    for i in range.clone() {
                        let (r, off) = (i / ssh, i % ssh);
                        if shards[r].labels[off] != ignore {
                            sum_d += per_row_ranks[r][off];
                            count_d += 1.0;
                        }
                    }
                } else {
                    for r in 0..sp {
                        let (a, b) = (r * ssh, (r + 1) * ssh);
                        if range.end <= a || range.start >= b {
                            continue; // no overlap: all-IGNORE shard adds 0/0
                        }
                        let (lo, hi) = (range.start.max(a), range.end.min(b));
                        let mut masked = self.arena.take_i32(ssh);
                        masked.fill(IGNORE_INDEX);
                        masked[lo - a..hi - a]
                            .copy_from_slice(&shards[r].labels[lo - a..hi - a]);
                        let masked_t = HostTensor::i32(vec![ssh], masked);
                        let lab = self.upload(&masked_t)?;
                        self.arena.recycle(masked_t);
                        // each re-run holds one rank's full logits copy
                        let bytes = untiled_loss_fwd_bytes(ssh, vocab);
                        self.device.alloc(bytes, LOSS_HEAD_TAG)?;
                        let out = self.exec("loss_fwd", &[lnf, unembed, &h[r], &lab]);
                        self.device.free(bytes, LOSS_HEAD_TAG);
                        let out = out?;
                        sum_d += out[0].scalar_f32()?;
                        count_d += out[1].scalar_f32()?;
                    }
                }
                doc_losses.push(DocumentLoss {
                    doc_id: p.doc_ids[d],
                    tokens: range.len(),
                    loss: if count_d > 0.0 { sum_d / count_d } else { 0.0 },
                });
            }
        }
        // per-row sweep buffers are arena-sourced; complete the ping-pong
        for v in per_row_ranks.drain(..) {
            self.arena.recycle_f32(v);
        }

        // ---- backward ------------------------------------------------------
        let ct = self.upload(&HostTensor::scalar(loss_scale / count))?;
        let mut final_grads: Vec<GroupGrads> = (0..sp)
            .map(|_| GroupGrads::zeros(&self.manifest.params.final_))
            .collect();
        let mut d_h: Vec<xla::PjRtBuffer> = Vec::with_capacity(sp);
        // host copies of d_h ride along only when the tiled MLP backward
        // needs to slice row tiles from them
        let mut d_h_host: Vec<HostTensor> = Vec::with_capacity(sp);
        if self.tiled_loss {
            // Tiled sweep: d_lnf/d_unembed tile partials accumulate
            // straight into the rank's GroupGrads flat buffer in the
            // pinned ascending-tile order; d_h tiles assemble in place.
            let hidden = self.manifest.config.hidden;
            let ignore = self.manifest.ignore_index;
            let rows = self.loss_tile_rows;
            let keep_host = self.tiled_mlp;
            let key = Engine::stage_key(&self.manifest, "loss_bwd_tile");
            let (engine, arena, device) = (&self.engine, &self.arena, &mut self.device);
            let tracer = &self.tracer;
            for r in 0..sp {
                let _rank = obs::rank_scope(r);
                let drv = TiledLossExec::new(ssh, hidden, vocab, rows, ignore, arena)?
                    .with_tracer(tracer.clone());
                let g = &mut final_grads[r];
                anyhow::ensure!(
                    g.entries.len() == 2 && g.entries[0].name == "lnf",
                    "final param group layout changed (expected [lnf, unembed])"
                );
                let (dl, dw) = g.flat.split_at_mut(g.entries[1].offset);
                let dh = drv.backward(
                    device,
                    &h_host[r],
                    &shards[r].labels,
                    dl,
                    dw,
                    |ht, lt| {
                        let hb = engine.to_buffer(ht)?;
                        let lb = engine.to_buffer(lt)?;
                        let out = engine
                            .execute_buffers(&key, &[lnf, unembed, &hb, &lb, &ct])?;
                        let mut it = out.into_iter();
                        Ok((it.next().unwrap(), it.next().unwrap(), it.next().unwrap()))
                    },
                )?;
                // under tiled_mlp the backward consumes d_h host-side
                // (tile slices); the device copy is only materialized
                // for embed_bwd after the layer loop
                if keep_host {
                    d_h_host.push(dh);
                } else {
                    d_h.push(engine.to_buffer(&dh)?);
                    arena.recycle(dh);
                }
            }
        } else {
            // untiled: logits + d_logits fp32 copies per resident rank
            // ("2 times of 8GiB", §3.1)
            let bytes = self.resident_ranks() * untiled_loss_bwd_bytes(ssh, vocab);
            self.device.alloc(bytes, LOSS_HEAD_TAG)?;
            let loss_bwd_out = run_ranks(sp, self.parallel_ranks, |r| {
                let out = self.exec("loss_bwd", &[lnf, unembed, &h[r], &lab_b[r], &ct])?;
                let mut it = out.into_iter();
                let d_lnf = it.next().unwrap();
                let d_unembed = it.next().unwrap();
                let d_h_t = it.next().unwrap();
                // tiled_mlp consumes d_h host-side; skip the device copy
                let d_h_b = if self.tiled_mlp {
                    None
                } else {
                    Some(self.upload(&d_h_t)?)
                };
                Ok((d_lnf, d_unembed, d_h_t, d_h_b))
            });
            self.device.free(bytes, LOSS_HEAD_TAG);
            for (r, (d_lnf, d_unembed, d_h_t, d_h_b)) in
                loss_bwd_out?.into_iter().enumerate()
            {
                final_grads[r].accumulate("lnf", &d_lnf)?;
                final_grads[r].accumulate("unembed", &d_unembed)?;
                if let Some(b) = d_h_b {
                    d_h.push(b);
                }
                if self.tiled_mlp {
                    d_h_host.push(d_h_t);
                }
            }
        }
        // the final-layer host outputs' last reader is the loss sweep
        self.arena.recycle_all(h_host);
        {
            let p = &self.manifest.params;
            let start = p.embed_numel + p.n_layers * p.layer_numel;
            let range = start..start + p.final_numel;
            let contribs: Vec<&[f32]> =
                final_grads.iter().map(|g| g.flat.as_slice()).collect();
            self.grads.reduce_into_range(&self.group, range, &contribs)?;
        }
        drop(h);

        for li in (0..n_layers).rev() {
            // Restore the layer-input checkpoint (host->device if offloaded)
            let mut h_in_host = Vec::with_capacity(sp);
            for r in 0..sp {
                h_in_host.push(tape.fetch(li, r, &mut self.device, &mut self.host)?);
            }
            // Double-buffer: with this layer's checkpoints in hand, start
            // layer li-1's H2D restore so it copies behind our recompute
            // (async path; schedule-gated so the early fetch never
            // overcommits the device).
            if li > 0 && self.prefetch_ok.get(li - 1) == Some(&true) {
                tape.prefetch_layer(li - 1, sp)?;
            }
            let h_in = self.upload_all(&h_in_host)?;
            // ZeRO-3 re-gathers the layer's params for backward (ledger).
            self.account_bwd_regather(li)?;
            let lp = &dev_params.layers[li];
            // Recompute forward through the layer (activation checkpointing
            // replays the all-to-alls too — the paper's flos model counts
            // this extra forward).
            let (_h_out, mut act) = self.layer_forward(lp, &h_in, &h_in_host, &pos_b)?;
            // backward never reads the recompute's layer OUTPUT; recycle
            // the host copies (arena-sourced under tiled_mlp) instead of
            // dropping them
            self.arena.recycle_all(std::mem::take(&mut act.h_out_host));

            let (ln1, wq, wk, wv) = (&lp[0], &lp[1], &lp[2], &lp[3]);
            let (wo, ln2, wg, wu, wd) = (&lp[4], &lp[5], &lp[6], &lp[7], &lp[8]);
            let mut layer_grads: Vec<GroupGrads> = (0..sp)
                .map(|_| GroupGrads::zeros(&self.manifest.params.layer))
                .collect();

            // post_attn backward. Tiled: row-tile sweep over
            // (h_in, attn, d_h) host copies, weight-grad partials in
            // pinned tile order. Untiled: per-rank exec in parallel; the
            // grad ledger merges serially in rank order — deterministic.
            let (d_h_resid, d_attn) = if self.tiled_mlp {
                let o_sh_host = std::mem::take(&mut act.o_sh_host);
                let out = self.tiled_post_attn_backward(
                    lp,
                    &h_in_host,
                    &o_sh_host,
                    &d_h_host,
                    &mut layer_grads,
                )?;
                self.arena.recycle_all(o_sh_host);
                out
            } else {
                let c = &self.manifest.config;
                let bytes =
                    2 * self.resident_ranks() * untiled_mlp_fwd_bytes(ssh, c.hidden, c.ffn);
                self.device.alloc(bytes, MLP_TAG)?;
                let post_out = run_ranks(sp, self.parallel_ranks, |r| {
                    self.exec(
                        "post_attn_bwd",
                        &[wo, ln2, wg, wu, wd, &h_in[r], &act.o_sh[r], &d_h[r]],
                    )
                });
                self.device.free(bytes, MLP_TAG);
                let post_out = post_out?;
                let mut d_h_resid = Vec::with_capacity(sp);
                let mut d_attn = Vec::with_capacity(sp);
                for (r, out) in post_out.into_iter().enumerate() {
                    let mut it = out.into_iter();
                    for name in ["wo", "ln2", "wg", "wu", "wd"] {
                        layer_grads[r].accumulate(name, &it.next().unwrap())?;
                    }
                    d_h_resid.push(it.next().unwrap());
                    d_attn.push(it.next().unwrap());
                }
                (d_h_resid, d_attn)
            };

            let (d_q, d_k, d_v) = if self.plan == PlanKind::Ring {
                // Ring backward: rerun the KV rotation from the
                // recompute's seq-sharded q/k/v and saved (o, lse) —
                // d_attn IS the plan's d_o (both seq layout), and the
                // plan's grads come back seq-sharded, exactly what
                // `pre_attn_bwd` consumes. No relayout either direction.
                let c = &self.manifest.config;
                let shape = AttnShape::new(c.n_q_heads, c.n_kv_heads, c.head_dim);
                let saved = act
                    .ring_saved
                    .take()
                    .expect("ring recompute must save (o, lse)");
                let grads = self.ring_plan.attention_backward(
                    &self.group,
                    &self.arena,
                    &act.q_seq,
                    &act.k_seq,
                    &act.v_seq,
                    &d_attn,
                    &saved,
                    &shape,
                    &self.step_cu,
                )?;
                saved.recycle(&self.arena);
                self.arena.recycle_all(d_attn);
                grads
            } else {
                // transposed all-to-all: d_attn (seq layout) -> head layout
                let d_o_full = a2a_seq_to_head_into(&self.group, &d_attn, &self.arena)?;
                self.arena.recycle_all(d_attn);
                let d_o_full_b = self.upload_all(&d_o_full)?;
                self.arena.recycle_all(d_o_full);
                let attn_out = run_ranks(sp, self.parallel_ranks, |r| {
                    let out = self.exec(
                        "attn_bwd",
                        &[&act.q_full[r], &act.k_full[r], &act.v_full[r], &d_o_full_b[r]],
                    )?;
                    let mut it = out.into_iter();
                    Ok((it.next().unwrap(), it.next().unwrap(), it.next().unwrap()))
                })?;
                let mut d_q_full = Vec::with_capacity(sp);
                let mut d_k_full = Vec::with_capacity(sp);
                let mut d_v_full = Vec::with_capacity(sp);
                for (q, k, v) in attn_out {
                    d_q_full.push(q);
                    d_k_full.push(k);
                    d_v_full.push(v);
                }
                // inverse a2a; kv grads SUM over replica consumers (fused
                // copy-first/accumulate-rest pass inside the relayout).
                let nq = self.manifest.config.n_q_heads;
                let nkv = self.manifest.config.n_kv_heads;
                let d_q =
                    a2a_head_to_seq_into(&self.group, &d_q_full, nq, true, &self.arena)?;
                let d_k =
                    a2a_head_to_seq_into(&self.group, &d_k_full, nkv, true, &self.arena)?;
                let d_v =
                    a2a_head_to_seq_into(&self.group, &d_v_full, nkv, true, &self.arena)?;
                self.arena.recycle_all(d_q_full);
                self.arena.recycle_all(d_k_full);
                self.arena.recycle_all(d_v_full);
                (d_q, d_k, d_v)
            };
            // spent: the ring inputs/saved state the recompute produced
            self.recycle_plan_act(&mut act);

            // pre_attn backward; d_h = qkv path + residual path
            let pre_out = run_ranks(sp, self.parallel_ranks, |r| {
                let d_q_b = self.upload(&d_q[r])?;
                let d_k_b = self.upload(&d_k[r])?;
                let d_v_b = self.upload(&d_v[r])?;
                self.exec(
                    "pre_attn_bwd",
                    &[ln1, wq, wk, wv, &h_in[r], &pos_b[r], &d_q_b, &d_k_b, &d_v_b],
                )
            })?;
            self.arena.recycle_all(d_q);
            self.arena.recycle_all(d_k);
            self.arena.recycle_all(d_v);
            let mut new_d_h = Vec::with_capacity(sp);
            let mut new_d_h_host = Vec::with_capacity(sp);
            for (r, (out, resid)) in pre_out.into_iter().zip(d_h_resid).enumerate() {
                let mut it = out.into_iter();
                for name in ["ln1", "wq", "wk", "wv"] {
                    layer_grads[r].accumulate(name, &it.next().unwrap())?;
                }
                let mut d_hr = it.next().unwrap();
                d_hr.add_assign(&resid)?;
                if self.tiled_mlp {
                    // next layer's tile sweep slices d_h host-side; the
                    // device copy is only needed once, for embed_bwd
                    new_d_h_host.push(d_hr);
                } else {
                    new_d_h.push(self.upload(&d_hr)?);
                    self.arena.recycle(d_hr);
                }
                self.arena.recycle(resid);
            }
            d_h = new_d_h;
            self.arena.recycle_all(d_h_host.drain(..));
            d_h_host = new_d_h_host;

            let contribs: Vec<&[f32]> =
                layer_grads.iter().map(|g| g.flat.as_slice()).collect();
            let range = self.manifest.params.layer_range(li);
            self.grads.reduce_into_range(&self.group, range, &contribs)?;
            // tape-fetched checkpoints are spent; back to the pool
            // (arena-sourced under tiled_mlp — keeps sweeps
            // allocation-free at steady state), and their device charge
            // (held since fetch — see `CheckpointTape::fetch`) ends here
            let fetched: u64 = h_in_host.iter().map(|t| t.size_bytes() as u64).sum();
            self.arena.recycle_all(h_in_host);
            tape.release_fetched(fetched, &mut self.device);
        }

        // embed backward; under tiled_mlp the device d_h is materialized
        // only here (the one place backward actually executes against it)
        if self.tiled_mlp {
            d_h = self.upload_all(&d_h_host)?;
        }
        self.arena.recycle_all(d_h_host.drain(..));
        let mut embed_grads: Vec<GroupGrads> = (0..sp)
            .map(|_| GroupGrads::zeros(&self.manifest.params.embed))
            .collect();
        let embed_bwd_out = run_ranks(sp, self.parallel_ranks, |r| {
            self.exec("embed_bwd", &[&dev_params.embed[0], &ids_b[r], &d_h[r]])
        })?;
        for (r, out) in embed_bwd_out.into_iter().enumerate() {
            embed_grads[r].accumulate("embed", &out[0])?;
        }
        let contribs: Vec<&[f32]> =
            embed_grads.iter().map(|g| g.flat.as_slice()).collect();
        let embed_numel = self.manifest.params.embed_numel;
        self.grads
            .reduce_into_range(&self.group, 0..embed_numel, &contribs)?;

        Ok((loss, tape.transfer_bytes(), doc_losses))
    }

    /// One training step on a PACKED batch of variable-length documents
    /// (paper §3.4/§7.2): segment-aware labels (no cross-document
    /// targets), per-document position ids (RoPE resets at boundaries),
    /// and a per-document loss breakdown in the returned metrics
    /// (empty when `TrainerOptions::per_doc_loss` is off — it costs one
    /// loss-head pass per document).
    ///
    /// §7.2 caveat, stated loudly: the compiled `attn_fwd` stage is dense
    /// causal over the full sequence and does not consume segment ids —
    /// exactly the SDPA behaviour the paper warns about, so attention can
    /// still read across boundaries inside this CPU artifact. The Pallas
    /// layer's `packed_attn.py` kernel is the masked implementation; the
    /// coordinator threads `cu_seqlens`/segment ids through every shard
    /// (see `packing::PackedShard`) so a packed-attention artifact drops
    /// in without coordinator changes. Labels and loss accounting are
    /// already fully segment-correct.
    pub fn train_step_packed(&mut self, p: &PackedSequence) -> Result<PackedStepMetrics> {
        let t0 = Instant::now(); // sharding counts toward step_time
        anyhow::ensure!(
            p.len() == self.manifest.seq,
            "packed length {} != artifact seq {}",
            p.len(),
            self.manifest.seq
        );
        let batches: Vec<ShardedBatch> = shard_packed(p, self.manifest.sp)
            .into_iter()
            .map(|s| s.batch)
            .collect();
        // shard_packed output is correct by construction — skip the
        // caller-input validation the pre-sharded entry point performs
        self.packed_step_core(p, batches, t0)
    }

    /// `train_step_packed` on PRE-SHARDED batches. When the caller already
    /// holds a shard set at the trainer's SP degree (e.g. from
    /// `PackedDataLoader::next`), this consumes it directly instead of
    /// re-running the per-rank slicing — the double materialization
    /// `PackedDataLoader::next_sequence` used to warn about.
    pub fn train_step_packed_shards(
        &mut self,
        p: &PackedSequence,
        batches: Vec<ShardedBatch>,
    ) -> Result<PackedStepMetrics> {
        let t0 = Instant::now(); // validation counts toward step_time
        anyhow::ensure!(
            p.len() == self.manifest.seq,
            "packed length {} != artifact seq {}",
            p.len(),
            self.manifest.seq
        );
        // A stale or foreign shard set satisfies the count/length checks
        // downstream while silently mis-attributing per-document losses —
        // or, worse, training on cross-document targets if the caller
        // sharded with the whole-sequence helper (the §4.3 bug class).
        // Allocation-free O(S) guards, always on: shards must be
        // equal-length (the per-doc loss slicing assumes seq/sp each) and
        // ids/positions must reassemble the pack (whole-sequence sharding
        // fails the positions check — no per-document resets).
        let ssh = p.len() / self.manifest.sp;
        anyhow::ensure!(
            batches.iter().all(|b| b.ids.len() == ssh
                && b.positions.len() == ssh
                && b.labels.len() == ssh)
                && batches.len() * ssh == p.len(),
            "packed shards must be {} equal-length rank batches (seq/sp = {ssh})",
            self.manifest.sp
        );
        anyhow::ensure!(
            batches.iter().flat_map(|b| b.ids.iter()).eq(p.ids.iter())
                && batches
                    .iter()
                    .flat_map(|b| b.positions.iter())
                    .eq(p.positions.iter()),
            "shard set does not reassemble the packed sequence (mismatched \
             sequence/shards pair, or sharded without segment awareness?)"
        );
        // Labels must be the pack's segment-aware shift, checked
        // element-wise against ids/seg_ids — allocation-free, so it stays
        // on unconditionally (the rule mirrors `shift_labels_packed` +
        // the padding mask of `PackedSequence::labels`). Whole-sequence
        // shifting fails at the first boundary: one leaked cross-document
        // target per boundary is the §4.3 bug.
        let pad_seg = if p.has_padding() { Some(p.n_docs() as i32) } else { None };
        let labels_ok =
            batches
                .iter()
                .flat_map(|b| b.labels.iter())
                .enumerate()
                .all(|(i, &l)| {
                    let expect = if Some(p.seg_ids[i]) == pad_seg {
                        IGNORE_INDEX
                    } else if i + 1 < p.len() && p.seg_ids[i + 1] == p.seg_ids[i] {
                        p.ids[i + 1]
                    } else {
                        IGNORE_INDEX
                    };
                    l == expect
                });
        anyhow::ensure!(
            labels_ok,
            "shard labels are not the segment-aware shift of the packed \
             sequence (sharded with the whole-sequence helper? see \
             packing::shift_labels_packed)"
        );
        self.packed_step_core(p, batches, t0)
    }

    /// The metered packed step both entry points share (inputs already
    /// validated or correct by construction). `t0` is the entry-point
    /// start time, so sharding/validation stay inside `step_time` as they
    /// were before the entry points split.
    fn packed_step_core(
        &mut self,
        p: &PackedSequence,
        batches: Vec<ShardedBatch>,
        t0: Instant,
    ) -> Result<PackedStepMetrics> {
        let tracer = self.tracer.clone();
        let mut span = tracer.span(Category::Step, "packed_step");
        self.group.reset_stats();
        self.device.reset_peak();

        let (loss, ckpt_transfer, doc_losses) =
            self.forward_backward_shards(&batches, 1.0, Some(p))?;
        let grad_norm = self.optimizer_step();
        let comm = self.group.stats();
        let step_time = t0.elapsed();
        span.set_step(self.step);
        span.set_dur(step_time);
        drop(span);
        let real_tokens: usize = p.doc_lengths().iter().sum();
        let fstats = self.fault_stats();
        Ok(PackedStepMetrics {
            metrics: StepMetrics {
                step: self.step,
                loss,
                grad_norm,
                tokens: p.len(),
                step_time,
                a2a_bytes: comm.all_to_all_bytes,
                send_recv_bytes: comm.send_recv_bytes,
                gather_bytes: comm.all_gather_bytes,
                reduce_scatter_bytes: comm.reduce_scatter_bytes,
                ckpt_transfer_bytes: ckpt_transfer,
                device_peak_bytes: self.device.peak(),
                retries: fstats.retries,
                recoveries: fstats.recoveries,
            },
            doc_losses,
            real_tokens,
            padding_tokens: p.len() - real_tokens,
        })
    }

    /// Save training state (params + optimizer + step) to `path`.
    pub fn save_snapshot(&self, path: &std::path::Path) -> Result<()> {
        crate::coordinator::snapshot::save(path, self.step, &self.params, &self.opt)
    }

    /// Resume training state from `path` (re-sharded to this SP degree —
    /// snapshots are world-agnostic).
    pub fn load_snapshot(&mut self, path: &std::path::Path) -> Result<()> {
        let snap = crate::coordinator::snapshot::load(path)?;
        crate::coordinator::snapshot::restore(&snap, &mut self.params, &mut self.opt)?;
        self.step = snap.step;
        Ok(())
    }

    /// Current optimizer step count.
    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Forward-only evaluation loss on one sequence (the loss head runs
    /// the monolithic `loss_fwd` stage — eval allocates no backward
    /// state, so the tiled sweep's memory win does not apply here).
    pub fn eval_loss(&mut self, ids: &[i32]) -> Result<f32> {
        let sp = self.manifest.sp;
        anyhow::ensure!(ids.len() == self.manifest.seq, "bad sequence length");
        let shards = shard_sequence(ids, sp);
        let dev_params = self.build_step_params()?;
        let mut h = Vec::with_capacity(sp);
        let mut h_host = Vec::with_capacity(sp);
        let mut pos_b = Vec::with_capacity(sp);
        for s in &shards {
            let ids_t = self.upload(&HostTensor::i32(vec![s.ids.len()], s.ids.clone()))?;
            pos_b.push(self.upload(&HostTensor::i32(
                vec![s.positions.len()],
                s.positions.clone(),
            ))?);
            let out = self.exec("embed_fwd", &[&dev_params.embed[0], &ids_t])?;
            let t = out.into_iter().next().unwrap();
            h.push(self.upload(&t)?);
            h_host.push(t);
        }
        for li in 0..self.n_layers() {
            let (h_new, mut act) =
                self.layer_forward(&dev_params.layers[li], &h, &h_host, &pos_b)?;
            self.recycle_plan_act(&mut act);
            self.arena.recycle_all(h_host);
            self.arena.recycle_all(act.o_sh_host);
            h_host = act.h_out_host;
            h = h_new;
        }
        self.arena.recycle_all(h_host.drain(..));
        let mut sums = Vec::new();
        let mut counts = Vec::new();
        for (r, s) in shards.iter().enumerate() {
            let lab = self.upload(&HostTensor::i32(vec![s.labels.len()], s.labels.clone()))?;
            let out = self.exec(
                "loss_fwd",
                &[&dev_params.final_[0], &dev_params.final_[1], &h[r], &lab],
            )?;
            sums.push(out[0].scalar_f32()?);
            counts.push(out[1].scalar_f32()?);
        }
        Ok(sums.iter().sum::<f32>() / counts.iter().sum::<f32>())
    }
}

/// Per-layer activations the backward pass reuses after recompute, plus
/// host copies of the layer output (checkpointed as the next layer input).
struct LayerAct {
    q_full: Vec<xla::PjRtBuffer>,
    k_full: Vec<xla::PjRtBuffer>,
    v_full: Vec<xla::PjRtBuffer>,
    /// Full attention-output device shards — consumed by the monolithic
    /// `post_attn_bwd`; EMPTY under `tiled_mlp` (only tile-sized
    /// buffers are uploaded on that path).
    o_sh: Vec<xla::PjRtBuffer>,
    /// Host copies of the attention output shards — populated only under
    /// `tiled_mlp` (the backward tile sweep slices them); empty and free
    /// otherwise. Recycle into the arena when done.
    o_sh_host: Vec<HostTensor>,
    h_out_host: Vec<HostTensor>,
    /// Ring plan only: the sequence-sharded q/k/v the plan consumed —
    /// backward reruns the KV rotation from these (there is no
    /// head-layout buffer to reuse). Empty under Ulysses. Recycle via
    /// `Trainer::recycle_plan_act`.
    q_seq: Vec<HostTensor>,
    k_seq: Vec<HostTensor>,
    v_seq: Vec<HostTensor>,
    /// Ring plan only: the forward's saved (o, lse) per rank.
    ring_saved: Option<PlanSaved>,
}
