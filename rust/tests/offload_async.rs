//! Concurrency suite for the async offload engine (`coordinator/offload`).
//!
//! Artifact-free sections always run: threaded-vs-inline bit-identity of
//! the staged payloads, `transfer_bytes` equality against the sync
//! `CheckpointTape` on the same schedule, the in-flight byte cap
//! reconstructed from drained spans, exact stall-span/ledger
//! reconciliation, single-stream serialization of the copy lanes under
//! the CI trace validator, and deterministic teardown on a mid-backward
//! error. The end-to-end trainer section (async path must be bit-identical
//! to the sync tape in losses, parameters, and transfer volume) gates on
//! `artifacts/` like the rest of the integration suite.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use alst::config::FeatureFlags;
use alst::coordinator::dataloader::{MarkovSource, UlyssesDataLoader};
use alst::coordinator::offload::{
    AsyncOffloadEngine, OffloadConfig, StepTape, CKPT_TAG,
};
use alst::coordinator::pipeline::{Trainer, TrainerOptions};
use alst::coordinator::tape::CheckpointTape;
use alst::memory::{HostPool, MemoryTracker};
use alst::obs::{trace_events, validate_trace, Category, Span, Tracer};
use alst::runtime::{HostTensor, Manifest, ScratchArena};
use alst::util::rng::Rng;

fn artifacts(config: &str, sp: usize, seq: usize) -> Option<PathBuf> {
    let dir = Manifest::artifact_dir(Path::new("artifacts"), config, sp, seq);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: {} missing — run `make artifacts`", dir.display());
        None
    }
}

fn payload(rng: &mut Rng, n: usize) -> HostTensor {
    HostTensor::f32(vec![n], rng.normal_vec(n, 1.0))
}

fn engine(overlap: bool, cap: u64, tracer: Arc<Tracer>) -> AsyncOffloadEngine {
    AsyncOffloadEngine::new(
        Arc::new(ScratchArena::new()),
        tracer,
        OffloadConfig { in_flight_cap: cap, overlap, ..OffloadConfig::default() },
    )
}

/// Drive one full store→prefetch→fetch schedule (layers-major forward,
/// reverse backward — the pipeline's order) and return the fetched
/// payload bit patterns in backward order.
fn run_schedule(
    eng: &AsyncOffloadEngine,
    layers: usize,
    sp: usize,
    seed: u64,
) -> Vec<Vec<u32>> {
    let mut dev = MemoryTracker::new(1 << 30);
    let mut host = HostPool::new(1 << 30);
    let mut rng = Rng::new(seed);
    for li in 0..layers {
        for r in 0..sp {
            eng.store(li, r, payload(&mut rng, 256 + li * sp + r), &mut host)
                .unwrap();
        }
    }
    eng.prefetch_layer(layers - 1, sp).unwrap();
    let mut out = Vec::new();
    for li in (0..layers).rev() {
        if li > 0 {
            eng.prefetch_layer(li - 1, sp).unwrap();
        }
        for r in 0..sp {
            let t = eng.fetch(li, r, &mut dev, &mut host).unwrap();
            out.push(t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect());
            dev.free(t.size_bytes() as u64, CKPT_TAG);
        }
    }
    eng.drain();
    assert_eq!(eng.pending(), 0);
    assert_eq!(host.current(), 0, "all staged bytes released");
    assert_eq!(dev.current(), 0, "all fetched charges released");
    out
}

/// ISSUE satellite: threaded-vs-serial bit-identity. The overlap engine
/// (two worker threads) and the inline engine (caller thread) must hand
/// back byte-for-byte identical checkpoints for the same schedule, and
/// move the same number of bytes.
#[test]
fn threaded_and_inline_engines_agree_bitwise() {
    let (layers, sp) = (3usize, 2usize);
    let t_eng = engine(true, 1 << 30, Tracer::off());
    let i_eng = engine(false, 1 << 30, Tracer::off());
    let threaded = run_schedule(&t_eng, layers, sp, 21);
    let inline = run_schedule(&i_eng, layers, sp, 21);
    assert_eq!(threaded, inline, "payload bits differ across modes");
    assert_eq!(t_eng.transfer_bytes(), i_eng.transfer_bytes());
    // The threaded run hid at least some copy time; the inline run none.
    assert!(t_eng.stream_stats().copies_d2h > 0);
}

/// ISSUE satellite: `transfer_bytes` equality with the sync tape. The
/// engine's two streams must ledger exactly the bytes the passive
/// `CheckpointTape` counts for the identical store/fetch schedule.
#[test]
fn engine_transfer_bytes_match_sync_tape() {
    let (layers, sp) = (3usize, 2usize);
    let eng = engine(true, 1 << 30, Tracer::off());
    let _ = run_schedule(&eng, layers, sp, 5);

    let mut tape = CheckpointTape::new(layers, sp, true);
    let mut dev = MemoryTracker::new(1 << 30);
    let mut host = HostPool::new(1 << 30);
    let arena = ScratchArena::new();
    let mut rng = Rng::new(5);
    for li in 0..layers {
        for r in 0..sp {
            tape.store(li, r, payload(&mut rng, 256 + li * sp + r), &mut dev, &mut host)
                .unwrap();
        }
    }
    for li in (0..layers).rev() {
        for r in 0..sp {
            let t = tape.fetch(li, r, &mut dev, &mut host).unwrap();
            dev.free(t.size_bytes() as u64, CKPT_TAG);
            arena.recycle(t);
        }
    }
    assert_eq!(
        eng.transfer_bytes(),
        tape.transfer_bytes,
        "async streams must move exactly the sync tape's bytes"
    );
}

/// ISSUE satellite: the in-flight cap is never exceeded, asserted from
/// drained spans. Every `ckpt_store_async` instant span marks a `+bytes`
/// edge at its end; every `d2h_copy` span marks the `-bytes` edge at its
/// end (its duration is pinned to the copy via `set_dur`, so the span
/// ends no later than the window decrement). Replaying the edges — minus
/// before plus on ties, the conservative order — the running window must
/// stay within the configured cap.
#[test]
fn in_flight_cap_reconstructed_from_spans_stays_bounded() {
    let n = 384usize; // bytes per checkpoint: 96 f32s
    let cap = (3 * n) as u64;
    let tracer = Arc::new(Tracer::new(true));
    let eng = engine(true, cap, tracer.clone());
    let mut dev = MemoryTracker::new(1 << 30);
    let mut host = HostPool::new(1 << 30);
    let mut rng = Rng::new(11);
    for li in 0..12 {
        eng.store(li, 0, payload(&mut rng, n / 4), &mut host).unwrap();
    }
    eng.drain();
    for li in (0..12).rev() {
        let t = eng.fetch(li, 0, &mut dev, &mut host).unwrap();
        dev.free(t.size_bytes() as u64, CKPT_TAG);
    }
    eng.drain();

    let spans = tracer.drain();
    // (timestamp, signed delta); minus-first tie-break keeps the replay a
    // lower bound of the true window, which the engine bounds by `cap`.
    let mut edges: Vec<(u64, i64)> = Vec::new();
    for s in &spans {
        match (s.cat, s.name.as_str()) {
            (Category::Offload, "ckpt_store_async") => {
                edges.push((s.end_ns(), s.bytes as i64))
            }
            (Category::CopyD2H, "d2h_copy") => {
                edges.push((s.end_ns(), -(s.bytes as i64)))
            }
            _ => {}
        }
    }
    assert_eq!(edges.len(), 24, "12 store edges + 12 copy edges");
    edges.sort_by_key(|&(ts, delta)| (ts, delta));
    let (mut window, mut max) = (0i64, 0i64);
    for (_, delta) in edges {
        window += delta;
        max = max.max(window);
    }
    assert!(
        max as u64 <= cap,
        "span-reconstructed in-flight window {max} exceeds cap {cap}"
    );
    let stream = eng.stream_stats();
    assert!(stream.max_in_flight <= cap, "engine high-water {} > cap", stream.max_in_flight);
    assert!(stream.max_in_flight > 0);
}

/// Stall ledger and `Stall` spans carry the SAME `Duration` values —
/// sums agree bit-for-bit in both modes (inline counts every copy as
/// stall; threaded counts only real waits).
#[test]
fn stall_spans_reconcile_with_stall_stats_exactly() {
    for overlap in [false, true] {
        let tracer = Arc::new(Tracer::new(true));
        let eng = engine(overlap, 1 << 30, tracer.clone());
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut rng = Rng::new(17);
        for li in 0..4 {
            eng.store(li, 0, payload(&mut rng, 2048), &mut host).unwrap();
        }
        // Fetch straight away — the threaded engine may genuinely stall
        // here, the inline engine stalls on every copy by definition.
        for li in (0..4).rev() {
            let t = eng.fetch(li, 0, &mut dev, &mut host).unwrap();
            dev.free(t.size_bytes() as u64, CKPT_TAG);
        }
        eng.drain();
        let stalls = eng.stalls();
        let spans = tracer.drain();
        let span_stall: Duration = spans
            .iter()
            .filter(|s| s.cat == Category::Stall)
            .map(Span::dur)
            .sum();
        assert_eq!(
            span_stall,
            stalls.total(),
            "stall spans must reconcile exactly (overlap={overlap})"
        );
        let span_events =
            spans.iter().filter(|s| s.cat == Category::Stall).count() as u64;
        assert_eq!(span_events, stalls.d2h_events + stalls.h2d_events);
        if !overlap {
            // Inline mode: stall == copy time — the sync baseline.
            assert_eq!(stalls.total(), eng.stream_stats().copy_time());
        }
    }
}

/// The copy lanes must pass the CI trace validator, and within each
/// stream the copy spans must serialize — one worker, one copy at a
/// time, so span intervals never overlap.
#[test]
fn copy_lane_spans_validate_and_serialize_per_stream() {
    let tracer = Arc::new(Tracer::new(true));
    let eng = engine(true, 1 << 30, tracer.clone());
    let _ = run_schedule(&eng, 4, 2, 31);

    let spans = tracer.drain();
    // (Stall is not in this list: whether the threaded engine stalls here
    // is a race; its spans are pinned deterministically in the inline-mode
    // reconciliation test.)
    for cat in [Category::CopyD2H, Category::CopyH2D, Category::Offload] {
        assert!(spans.iter().any(|s| s.cat == cat), "no {cat:?} span recorded");
    }
    let doc = trace_events(&spans, &[]);
    validate_trace(&doc).unwrap();

    for cat in [Category::CopyD2H, Category::CopyH2D] {
        let mut lane: Vec<&Span> = spans.iter().filter(|s| s.cat == cat).collect();
        assert_eq!(lane.len(), 8, "one copy per checkpoint on the {cat:?} lane");
        lane.sort_by_key(|s| s.start_ns);
        for w in lane.windows(2) {
            assert!(
                w[1].start_ns >= w[0].end_ns(),
                "{cat:?} copies overlap within one stream"
            );
        }
    }
}

/// ISSUE satellite: deterministic drain on a mid-backward error. Abort
/// after a partial backward must leave no phantom tracker bytes, no
/// leaked host charge, no underflow, and a reusable engine — in both
/// modes, through the `StepTape` wrapper the pipeline uses.
#[test]
fn mid_backward_abort_drains_deterministically() {
    for overlap in [false, true] {
        let arena = ScratchArena::new();
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let eng = Arc::new(engine(overlap, 1 << 30, Tracer::off()));
        let mut tape = StepTape::with_engine(eng.clone());
        let mut rng = Rng::new(13);
        for li in 0..4 {
            for r in 0..2 {
                tape.store(li, r, payload(&mut rng, 128), &mut dev, &mut host)
                    .unwrap();
            }
        }
        tape.prefetch_layer(3, 2).unwrap();
        // Backward gets through layer 3's fetches, then the stage errors
        // with its checkpoints still device-charged and a prefetch for
        // layer 2 already in flight.
        let mut fetched = Vec::new();
        for r in 0..2 {
            fetched.push(tape.fetch(3, r, &mut dev, &mut host).unwrap());
        }
        tape.prefetch_layer(2, 2).unwrap();
        assert_eq!(dev.tag_bytes(CKPT_TAG), 2 * 512);
        arena.recycle_all(fetched); // recompute consumed them before erroring

        tape.abort(&mut dev, &mut host, &arena);
        assert_eq!(dev.tag_bytes(CKPT_TAG), 0, "no phantom device bytes");
        assert_eq!(dev.current(), 0);
        assert_eq!(host.current(), 0, "no phantom host bytes");
        assert_eq!(dev.underflow_events() + host.underflow_events(), 0);
        assert_eq!(eng.pending(), 0, "engine drained (overlap={overlap})");

        // The engine survives for the next step on both paths.
        let mut tape = StepTape::with_engine(eng);
        tape.store(0, 0, payload(&mut rng, 128), &mut dev, &mut host).unwrap();
        let t = tape.fetch(0, 0, &mut dev, &mut host).unwrap();
        let bytes = t.size_bytes() as u64;
        arena.recycle(t);
        tape.release_fetched(bytes, &mut dev);
        assert_eq!((dev.current(), host.current()), (0, 0));
    }
}

// ---------------------------------------------------------------------------
// End-to-end (needs artifacts): async path vs sync tape, bit for bit
// ---------------------------------------------------------------------------

struct RunOut {
    losses: Vec<f32>,
    transfer: Vec<u64>,
    params: Vec<f32>,
}

fn run_steps(dir: &Path, sp: usize, steps: usize, opts: TrainerOptions) -> RunOut {
    let mut t = Trainer::new(dir, opts).expect("trainer");
    let vocab = t.manifest.config.vocab;
    let seq = t.manifest.seq;
    let mut loader =
        UlyssesDataLoader::new(MarkovSource::new(vocab, seq, 0.05, 7), sp);
    let mut losses = Vec::new();
    let mut transfer = Vec::new();
    for _ in 0..steps {
        let (ids, _) = loader.next();
        let m = t.train_step(&ids).expect("step");
        losses.push(m.loss);
        transfer.push(m.ckpt_transfer_bytes);
    }
    RunOut { losses, transfer, params: t.params.to_flat() }
}

/// The acceptance contract: with checkpoint offload on, the async engine
/// (threaded or inline, serial or threaded ranks) must reproduce the
/// sync `CheckpointTape` run EXACTLY — same per-step losses to the bit,
/// same final parameters to the bit, same per-step transfer volume.
#[test]
fn async_offload_matches_sync_tape_bit_for_bit() {
    let steps = 3;
    for sp in [1usize, 2, 4] {
        let Some(dir) = artifacts("tiny", sp, 256) else { continue };
        let base = |parallel| TrainerOptions {
            flags: FeatureFlags::alst(),
            seed: 9,
            parallel_ranks: parallel,
            ..Default::default()
        };
        let sync = run_steps(&dir, sp, steps, base(false));
        assert!(sync.transfer.iter().all(|&b| b > 0), "offload moved bytes");

        let variants = [
            ("async threaded", true, false),
            ("async inline", false, false),
            ("async threaded + threaded ranks", true, true),
        ];
        for (label, overlap, parallel) in variants {
            let opts = TrainerOptions {
                async_offload: Some(OffloadConfig {
                    overlap,
                    ..OffloadConfig::default()
                }),
                ..base(parallel)
            };
            let got = run_steps(&dir, sp, steps, opts);
            for (i, (a, b)) in sync.losses.iter().zip(&got.losses).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "sp={sp} {label}: loss diverged at step {i}: {a} vs {b}"
                );
            }
            assert_eq!(
                sync.transfer, got.transfer,
                "sp={sp} {label}: transfer_bytes diverged"
            );
            assert_eq!(sync.params.len(), got.params.len());
            for (i, (a, b)) in sync.params.iter().zip(&got.params).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "sp={sp} {label}: param {i} diverged: {a} vs {b}"
                );
            }
        }
    }
}
