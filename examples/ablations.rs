//! Table 1 / Figure 11: the single-node (8x H100) feature-ablation ladder
//! — max sequence length, modeled iteration time, and TFLOPS for each
//! cumulative feature set, plus which resource binds.
//!
//!     cargo run --release --example ablations [-- --model llama3-8b --gpus 8]

use alst::config::preset;
use alst::paper::table1_ablations;
use alst::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = preset(&args.get_or("model", "llama3-8b"))
        .ok_or_else(|| anyhow::anyhow!("unknown preset (llama3-8b, llama3-70b, qwen3-32b)"))?;
    let gpus = args.usize("gpus", 8);

    let t = table1_ablations(model, gpus);
    t.print();

    println!("\npaper Table 1 (Llama-8B, 8x H100):");
    println!("  baseline                        32K   0:00:17   231.6");
    println!("  +tiled logits&loss             160K   0:02:03   514.4");
    println!("  +ulysses sp                    1.1M   0:09:24   576.1");
    println!("  +tiled mlp                     1.2M   0:11:43   548.7");
    println!("  +ckpt offload (no tiled mlp)   2.4M   0:43:30   585.8");
    println!("  full alst                      3.7M   1:47:35   590.6");
    println!(
        "\nshape checks: ladder monotone; tiled-MLP matters little until ckpt \
         offload unlocks multi-M sequences; TFLOPS plateau near 590 as \
         attention dominates."
    );
    Ok(())
}
