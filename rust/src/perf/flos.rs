//! flos (floating-point operations — the BLOOM-coined spelling the paper
//! adopts, fn.22) for one training iteration at batch size 1.
//!
//! Forward per layer: QKVO projections + attention scores/values + SwiGLU
//! MLP; plus the logits matmul once. Training = 3x forward (fwd + bwd)
//! + 1x forward again when activation checkpointing recomputes (§5.4's
//! "repeated forwards" — our backward literally re-runs the layer).

use crate::config::ModelPreset;

#[derive(Debug, Clone, Default)]
pub struct FlosBreakdown {
    pub proj: f64,
    pub attention: f64,
    pub mlp: f64,
    pub logits: f64,
}

impl FlosBreakdown {
    pub fn forward_total(&self) -> f64 {
        self.proj + self.attention + self.mlp + self.logits
    }

    /// Fraction of forward flos spent in attention — the paper's "at such
    /// long sequence lengths attention renders MLP compute negligible".
    pub fn attention_fraction(&self) -> f64 {
        self.attention / self.forward_total()
    }
}

/// Forward flos for ONE layer at sequence length `s` (batch 1).
pub fn flos_per_layer(m: &ModelPreset, s: usize) -> (f64, f64, f64) {
    let s = s as f64;
    let h = m.hidden as f64;
    let hq = (m.n_q_heads * m.head_dim) as f64;
    let hkv = (m.n_kv_heads * m.head_dim) as f64;
    let f = m.ffn as f64;
    // q,o: 2*s*h*hq each; k,v: 2*s*h*hkv each (GQA-aware)
    let proj = 2.0 * s * h * (2.0 * hq + 2.0 * hkv);
    // scores (2*s^2*hq) + values (2*s^2*hq); Megatron convention: no
    // causal halving.
    let attention = 4.0 * s * s * hq;
    // SwiGLU: gate, up, down matmuls
    let mlp = 6.0 * s * h * f;
    (proj, attention, mlp)
}

/// Total training flos for one iteration over one full sequence `s`.
/// `recompute` adds the checkpointing forward (4x vs 3x forward).
pub fn train_flos(m: &ModelPreset, s: usize, recompute: bool) -> FlosBreakdown {
    let (proj, attention, mlp) = flos_per_layer(m, s);
    let l = m.n_layers as f64;
    let logits = 2.0 * s as f64 * m.hidden as f64 * m.vocab as f64;
    let mult = if recompute { 4.0 } else { 3.0 };
    FlosBreakdown {
        proj: proj * l * mult,
        attention: attention * l * mult,
        mlp: mlp * l * mult,
        logits: logits * mult,
    }
}

/// Packed-batch flos (paper §3.4): attention is the SUM OF PER-SEGMENT
/// SQUARES — tokens never attend across document boundaries, so a packed
/// batch of segments S₁..Sₖ costs Σᵢ 4·Sᵢ²·hq per layer, not 4·(ΣSᵢ)²·hq.
/// Every other term (projections, MLP, logits) is linear in the token
/// count and unchanged. Packing k equal documents into one sequence costs
/// 1/k of the single-document attention flos at the same token count.
pub fn train_flos_packed(
    m: &ModelPreset,
    seg_lens: &[usize],
    recompute: bool,
) -> FlosBreakdown {
    let total: usize = seg_lens.iter().sum();
    let mut b = train_flos(m, total, recompute);
    let hq = (m.n_q_heads * m.head_dim) as f64;
    let mult = if recompute { 4.0 } else { 3.0 };
    let attn_layer: f64 = seg_lens
        .iter()
        .map(|&s| 4.0 * s as f64 * s as f64 * hq)
        .sum();
    b.attention = attn_layer * m.n_layers as f64 * mult;
    b
}

/// Packed/unpacked attention-flos ratio at equal total tokens:
/// Σᵢ Sᵢ² / (Σᵢ Sᵢ)². Equals 1/k for k equal segments, 1.0 for a single
/// document.
pub fn packed_attention_ratio(seg_lens: &[usize]) -> f64 {
    let total: f64 = seg_lens.iter().map(|&s| s as f64).sum();
    if total == 0.0 {
        return 1.0;
    }
    let sq: f64 = seg_lens.iter().map(|&s| s as f64 * s as f64).sum();
    sq / (total * total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::preset;

    #[test]
    fn llama8b_32k_forward_magnitude() {
        // Hand-computed: ~1.05e15 forward flos at 32K (see DESIGN.md).
        let m = preset("llama3-8b").unwrap();
        let b = train_flos(m, 32_768, true);
        let fwd = b.forward_total() / 4.0;
        assert!((fwd - 1.05e15).abs() / 1.05e15 < 0.05, "{fwd:e}");
    }

    #[test]
    fn attention_dominates_at_multi_million() {
        let m = preset("llama3-8b").unwrap();
        let short = train_flos(m, 8_192, true);
        let long = train_flos(m, 3_700_000, true);
        assert!(short.attention_fraction() < 0.3);
        assert!(long.attention_fraction() > 0.95); // §5.4's observation
    }

    #[test]
    fn recompute_multiplier_is_4_over_3() {
        let m = preset("llama3-8b").unwrap();
        let with = train_flos(m, 65_536, true).forward_total();
        let without = train_flos(m, 65_536, false).forward_total();
        assert!(((with / without) - 4.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn quadratic_attention_scaling() {
        let m = preset("llama3-8b").unwrap();
        let a = train_flos(m, 100_000, true).attention;
        let b = train_flos(m, 200_000, true).attention;
        assert!((b / a - 4.0).abs() < 1e-9);
    }

    #[test]
    fn packed_equal_segments_cost_one_kth_attention() {
        // Acceptance: k equal segments at the SAME total token count report
        // attention flos ~= 1/k of the single-document figure.
        let m = preset("llama3-8b").unwrap();
        let total = 1_048_576usize;
        let single = train_flos(m, total, true);
        for k in [2usize, 8, 64] {
            let segs = vec![total / k; k];
            let packed = train_flos_packed(m, &segs, true);
            let ratio = packed.attention / single.attention;
            assert!(
                (ratio - 1.0 / k as f64).abs() < 1e-9,
                "k={k}: ratio {ratio}"
            );
            // linear terms unchanged by packing
            assert_eq!(packed.proj, single.proj);
            assert_eq!(packed.mlp, single.mlp);
            assert_eq!(packed.logits, single.logits);
            assert!(packed.forward_total() < single.forward_total());
        }
    }

    #[test]
    fn packed_ratio_formula() {
        assert_eq!(packed_attention_ratio(&[100]), 1.0);
        assert!((packed_attention_ratio(&[50, 50]) - 0.5).abs() < 1e-12);
        // skew: one long doc dominates the cost
        let skew = packed_attention_ratio(&[900, 50, 50]);
        assert!(skew > 0.8 && skew < 1.0, "{skew}");
        assert_eq!(packed_attention_ratio(&[]), 1.0);
    }

    #[test]
    fn single_segment_packed_equals_unpacked() {
        let m = preset("llama3-8b").unwrap();
        let a = train_flos(m, 65_536, true);
        let b = train_flos_packed(m, &[65_536], true);
        assert_eq!(a.attention, b.attention);
        assert_eq!(a.forward_total(), b.forward_total());
    }

    #[test]
    fn gqa_reduces_proj_flos() {
        let m = preset("llama3-8b").unwrap(); // 32q/8kv
        let mha = ModelPreset { n_kv_heads: 32, ..m.clone() };
        let (p_gqa, ..) = flos_per_layer(m, 10_000);
        let (p_mha, ..) = flos_per_layer(&mha, 10_000);
        assert!(p_gqa < p_mha);
    }
}
