"""Packed-sample attention (paper §3.4 + §7.2): segment isolation without
an O(S^2) mask, and the SDPA-ignores-position-ids failure mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import packed_attn, ref

SETTINGS = dict(max_examples=10, deadline=None)


def rand_qkv(seed, s, hq, hkv, d):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (s, hq, d)),
        jax.random.normal(ks[1], (s, hkv, d)),
        jax.random.normal(ks[2], (s, hkv, d)),
    )


class TestPackedAttention:
    @settings(**SETTINGS)
    @given(
        lengths=st.lists(st.sampled_from([16, 32, 48]), min_size=1, max_size=4),
        heads=st.sampled_from([(2, 2), (4, 2), (2, 1)]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_naive_packed_reference(self, lengths, heads, seed):
        seg, _ = packed_attn.make_packed_segments(lengths)
        s = int(seg.shape[0])
        # pad to a tile boundary with a trailing segment
        pad = (-s) % 16
        if pad:
            seg = jnp.concatenate([seg, jnp.full((pad,), 1000, jnp.int32)])
            s += pad
        hq, hkv = heads
        q, k, v = rand_qkv(seed, s, hq, hkv, 8)
        got = packed_attn.packed_flash_attention(q, k, v, seg, tile_q=16, tile_k=16)
        want = packed_attn.attention_naive_packed(q, k, v, seg)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_segments_are_isolated(self):
        """Changing sample A must not change sample B's outputs."""
        seg, _ = packed_attn.make_packed_segments([32, 32])
        q, k, v = rand_qkv(0, 64, 2, 2, 8)
        o1 = packed_attn.packed_flash_attention(q, k, v, seg, tile_q=32, tile_k=32)
        # perturb sample 0's keys/values wildly
        k2 = k.at[:32].add(100.0)
        v2 = v.at[:32].add(-77.0)
        o2 = packed_attn.packed_flash_attention(q, k2, v2, seg, tile_q=32, tile_k=32)
        np.testing.assert_allclose(o1[32:], o2[32:], rtol=1e-5, atol=1e-6)
        assert not np.allclose(o1[:32], o2[:32], atol=1e-2)

    def test_sdpa_failure_mode_paper_7_2(self):
        """Plain causal attention (SDPA without position ids) attends
        ACROSS packed samples — the wrong behaviour the paper warns about."""
        seg, _ = packed_attn.make_packed_segments([32, 32])
        q, k, v = rand_qkv(3, 64, 2, 2, 8)
        right = packed_attn.packed_flash_attention(q, k, v, seg, tile_q=32, tile_k=32)
        wrong = ref.attention_naive(q, k, v)   # ignores segments, like SDPA
        # first sample identical (nothing before it to leak from)
        np.testing.assert_allclose(right[:32], wrong[:32], rtol=1e-4, atol=1e-5)
        # second sample differs: it leaked attention into sample 0
        assert not np.allclose(right[32:], wrong[32:], atol=1e-3)

    def test_single_segment_equals_plain_flash(self):
        seg = jnp.zeros((64,), jnp.int32)
        q, k, v = rand_qkv(5, 64, 4, 2, 8)
        a = packed_attn.packed_flash_attention(q, k, v, seg, tile_q=32, tile_k=32)
        b = ref.attention_naive(q, k, v)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_position_ids_reset_per_sample(self):
        seg, pos = packed_attn.make_packed_segments([3, 2, 4])
        np.testing.assert_array_equal(seg, [0, 0, 0, 1, 1, 2, 2, 2, 2])
        np.testing.assert_array_equal(pos, [0, 1, 2, 0, 1, 0, 1, 2, 3])

    def test_mask_memory_is_tile_sized_not_seq_squared(self):
        """The §3.4 point: 125K x 125K bf16 mask = 29 GiB; tiles are KB."""
        s, tile = 125_000, 128
        full_mask_gib = s * s * 2 / 2**30
        tile_mask_bytes = tile * tile  # bool block inside the kernel
        assert full_mask_gib > 28.0
        assert tile_mask_bytes < 64 * 1024
