//! The ALST feature ladder (paper Table 1) as a flag set.

/// Numeric precision used for byte-size arithmetic in the memory model.
/// The real CPU-PJRT pipeline runs f32 (see DESIGN.md substitutions); the
/// simulator models the paper's bf16 mixed-precision recipe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Bf16Mixed,
    F32,
}

impl Precision {
    pub fn activation_bytes(&self) -> u64 {
        match self {
            Precision::Bf16Mixed => 2,
            Precision::F32 => 4,
        }
    }
}

/// Every toggle in the paper's ablation ladder (§5.4) plus the baseline
/// features that are always on in evaluation (§5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FeatureFlags {
    // --- baseline features (on in every row of Table 1) ---
    /// DeepSpeed ZeRO Stage 3 weight/grad/optimizer sharding.
    pub zero3: bool,
    /// Gradient/activation checkpointing (recompute in backward).
    pub activation_checkpointing: bool,
    /// Optimizer states offloaded to host memory.
    pub optimizer_offload: bool,
    /// PYTORCH_CUDA_ALLOC_CONF=expandable_segments:True analogue: reduces
    /// the fragmentation headroom the allocator model reserves.
    pub expandable_segments: bool,
    // --- the ALST ladder (Table 1 columns) ---
    /// Fused tiled logits+loss (Liger-style / our tiled_ce kernel).
    pub tiled_loss: bool,
    /// Ulysses sequence parallelism across the SP group.
    pub ulysses_sp: bool,
    /// TiledMLP (sequence-tiled MLP compute).
    pub tiled_mlp: bool,
    /// Activation-checkpoint hidden_states offload to CPU.
    pub ckpt_offload: bool,
    /// Model weights offload to CPU (single-GPU configs, §5.2).
    pub weights_offload: bool,
}

impl FeatureFlags {
    /// The paper's baseline (§5.4): ZeRO-3 + ckpt + optim offload +
    /// expandable segments + FA2, nothing else.
    pub fn baseline() -> Self {
        FeatureFlags {
            zero3: true,
            activation_checkpointing: true,
            optimizer_offload: true,
            expandable_segments: true,
            tiled_loss: false,
            ulysses_sp: false,
            tiled_mlp: false,
            ckpt_offload: false,
            weights_offload: false,
        }
    }

    /// Full ALST (last row of Table 1).
    pub fn alst() -> Self {
        FeatureFlags {
            tiled_loss: true,
            ulysses_sp: true,
            tiled_mlp: true,
            ckpt_offload: true,
            ..Self::baseline()
        }
    }

    /// The ablation ladder exactly as Table 1 lists it (top to bottom).
    pub fn table1_ladder() -> Vec<(&'static str, Self)> {
        let b = Self::baseline();
        vec![
            ("baseline", b),
            ("+tiled logits&loss", FeatureFlags { tiled_loss: true, ..b }),
            ("+ulysses sp", FeatureFlags { tiled_loss: true, ulysses_sp: true, ..b }),
            (
                "+tiled mlp",
                FeatureFlags {
                    tiled_loss: true,
                    ulysses_sp: true,
                    tiled_mlp: true,
                    ..b
                },
            ),
            (
                "+ckpt offload (no tiled mlp)",
                FeatureFlags {
                    tiled_loss: true,
                    ulysses_sp: true,
                    ckpt_offload: true,
                    ..b
                },
            ),
            ("full alst", Self::alst()),
        ]
    }

    pub fn describe(&self) -> String {
        let mut on = Vec::new();
        for (name, v) in [
            ("zero3", self.zero3),
            ("ckpt", self.activation_checkpointing),
            ("opt-offload", self.optimizer_offload),
            ("expandable", self.expandable_segments),
            ("tiled-loss", self.tiled_loss),
            ("ulysses", self.ulysses_sp),
            ("tiled-mlp", self.tiled_mlp),
            ("ckpt-offload", self.ckpt_offload),
            ("weights-offload", self.weights_offload),
        ] {
            if v {
                on.push(name);
            }
        }
        on.join("+")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_matches_table1_shape() {
        let ladder = FeatureFlags::table1_ladder();
        assert_eq!(ladder.len(), 6);
        assert_eq!(ladder[0].1, FeatureFlags::baseline());
        assert_eq!(ladder[5].1, FeatureFlags::alst());
        // Row 5 (ckpt offload without tiled mlp) per Table 1 row 5
        assert!(ladder[4].1.ckpt_offload && !ladder[4].1.tiled_mlp);
    }

    #[test]
    fn baseline_has_no_alst_features() {
        let b = FeatureFlags::baseline();
        assert!(!b.tiled_loss && !b.ulysses_sp && !b.tiled_mlp && !b.ckpt_offload);
        assert!(b.zero3 && b.activation_checkpointing && b.optimizer_offload);
    }
}
