"""A pure-python Ulysses SP coordinator — the executable spec for rust/.

This mirrors, stage for stage and collective for collective, what
`rust/src/coordinator/pipeline.rs` does at training time: shard the
sequence, run the AOT stage functions per rank, perform the seq<->head
all-to-alls (with GQA kv replication), checkpoint layer inputs, replay
stages backward with transposed all-to-alls, and reduce gradients.

test_model.py asserts that this pipeline's loss and gradients equal
`jax.grad(full_loss)` — which is exactly the paper's Figure 13 claim
(ALST == baseline), proven at the algorithm level. The rust integration
tests then assert the same property through the PJRT artifacts.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from compile import model as M


def kv_head_start(rank: int, n_kv: int, sp: int) -> int:
    """First (global) kv head owned by `rank` after the all-to-all.

    Covers both GQA cases of paper §3.2.1: if `n_kv >= sp` heads are split
    contiguously; otherwise each rank gets the single kv head its q-head
    group reuses (replication).
    """
    return (rank * n_kv) // sp


def a2a_seq_to_head(shards, n_heads_out, sp):
    """Forward all-to-all: [Ssh, H, D] per rank -> [S, H/sp(.), D] per rank.

    `shards[r]` holds rank r's sequence shard with ALL heads. Returns, for
    each destination rank, the FULL sequence restricted to its head shard.
    `n_heads_out` is the per-rank head count (q_sh, or kv_sh incl.
    replication).
    """
    n_heads_in = shards[0].shape[1]
    out = []
    for dst in range(sp):
        if n_heads_in >= sp:                      # split heads contiguously
            h0 = dst * n_heads_out
        else:                                     # replicate (kv < sp)
            h0 = kv_head_start(dst, n_heads_in, sp)
        full = np.concatenate(
            [np.asarray(s[:, h0:h0 + n_heads_out, :]) for s in shards], axis=0
        )
        out.append(full)
    return out


def a2a_head_to_seq(shards, n_heads_total, sp, sum_replicas=False):
    """Inverse all-to-all: [S, h_sh, D] per rank -> [Ssh, n_heads_total, D].

    With `sum_replicas` (the backward of kv replication) multiple source
    ranks contribute gradients to the same head, which are summed.
    """
    s_full, h_sh, d = shards[0].shape
    ssh = s_full // sp
    out = []
    for dst in range(sp):
        acc = np.zeros((ssh, n_heads_total, d), np.float32)
        for src in range(sp):
            if n_heads_total >= sp:
                h0 = src * h_sh
            else:
                h0 = kv_head_start(src, n_heads_total, sp)
            piece = np.asarray(shards[src][dst * ssh:(dst + 1) * ssh, :, :])
            if sum_replicas:
                acc[:, h0:h0 + h_sh, :] += piece
            else:
                acc[:, h0:h0 + h_sh, :] = piece
        out.append(acc)
    return out


def shift_and_shard_labels(ids: np.ndarray, sp: int):
    """Paper §4.3: pre-shift on the full sequence, then shard."""
    shifted = np.concatenate(
        [ids[1:], np.full((1,), M.IGNORE_INDEX, ids.dtype)]
    )
    return np.split(shifted, sp)


def run_step(cfg: M.ModelConfig, params: dict, ids: np.ndarray, sp: int):
    """One fwd+bwd step through the staged Ulysses pipeline.

    Returns (mean_loss, grads) where grads mirrors the params dict. All
    collectives are explicit; everything else calls the same stage
    functions aot.py lowers.
    """
    seq = ids.shape[0]
    assert seq % sp == 0
    ssh = seq // sp
    q_sh, kv_sh = cfg.head_shard(sp)
    ids_shards = np.split(ids, sp)
    pos_shards = np.split(np.arange(seq, dtype=np.int32), sp)
    label_shards = shift_and_shard_labels(ids, sp)

    # ---- forward ----------------------------------------------------------
    h = [M.embed_fwd(cfg, params["embed"], jnp.asarray(i))[0]
         for i in ids_shards]
    checkpoints = []                      # layer-input shards (offloadable)
    for lp in params["layers"]:
        checkpoints.append([np.asarray(x) for x in h])
        qkv = [M.pre_attn_fwd(cfg, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                              h[r], jnp.asarray(pos_shards[r]))
               for r in range(sp)]
        q_full = a2a_seq_to_head([x[0] for x in qkv], q_sh, sp)
        k_full = a2a_seq_to_head([x[1] for x in qkv], kv_sh, sp)
        v_full = a2a_seq_to_head([x[2] for x in qkv], kv_sh, sp)
        o_full = [M.attn_core_fwd(cfg, jnp.asarray(q_full[r]),
                                  jnp.asarray(k_full[r]),
                                  jnp.asarray(v_full[r]))[0]
                  for r in range(sp)]
        o_sh = a2a_head_to_seq(o_full, cfg.n_q_heads, sp)
        h = [M.post_attn_fwd(cfg, lp["wo"], lp["ln2"], lp["wg"], lp["wu"],
                             lp["wd"], h[r], jnp.asarray(o_sh[r]))[0]
             for r in range(sp)]
    final_h = [np.asarray(x) for x in h]
    parts = [M.loss_fwd(cfg, params["lnf"], params["unembed"], h[r],
                        jnp.asarray(label_shards[r])) for r in range(sp)]
    loss_sum = sum(float(p[0]) for p in parts)    # all-reduce
    count = sum(float(p[1]) for p in parts)
    mean_loss = loss_sum / count

    # ---- backward (recompute from layer-input checkpoints) ----------------
    ct = jnp.float32(1.0 / count)
    grads = {
        "embed": np.zeros_like(np.asarray(params["embed"])),
        "lnf": np.zeros_like(np.asarray(params["lnf"])),
        "unembed": np.zeros_like(np.asarray(params["unembed"])),
        "layers": [
            {k: np.zeros_like(np.asarray(v)) for k, v in lp.items()}
            for lp in params["layers"]
        ],
    }
    d_h = []
    for r in range(sp):
        d_lnf, d_unembed, d_hr = M.loss_bwd(
            cfg, params["lnf"], params["unembed"], jnp.asarray(final_h[r]),
            jnp.asarray(label_shards[r]), ct)
        grads["lnf"] += np.asarray(d_lnf)          # grad all-reduce
        grads["unembed"] += np.asarray(d_unembed)
        d_h.append(np.asarray(d_hr))

    for li in reversed(range(cfg.n_layers)):
        lp, g = params["layers"][li], grads["layers"][li]
        h_in = checkpoints[li]
        # Recompute forward to the attention output (checkpoint replay,
        # including the forward all-to-alls — paper §3.3 cost model).
        qkv = [M.pre_attn_fwd(cfg, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                              jnp.asarray(h_in[r]), jnp.asarray(pos_shards[r]))
               for r in range(sp)]
        q_full = a2a_seq_to_head([x[0] for x in qkv], q_sh, sp)
        k_full = a2a_seq_to_head([x[1] for x in qkv], kv_sh, sp)
        v_full = a2a_seq_to_head([x[2] for x in qkv], kv_sh, sp)
        o_full = [M.attn_core_fwd(cfg, jnp.asarray(q_full[r]),
                                  jnp.asarray(k_full[r]),
                                  jnp.asarray(v_full[r]))[0]
                  for r in range(sp)]
        o_sh = a2a_head_to_seq(o_full, cfg.n_q_heads, sp)

        # post_attn bwd
        d_h_resid, d_attn = [], []
        for r in range(sp):
            d_wo, d_ln2, d_wg, d_wu, d_wd, d_hin, d_att = M.post_attn_bwd(
                cfg, lp["wo"], lp["ln2"], lp["wg"], lp["wu"], lp["wd"],
                jnp.asarray(h_in[r]), jnp.asarray(o_sh[r]),
                jnp.asarray(d_h[r]))
            for name, val in [("wo", d_wo), ("ln2", d_ln2), ("wg", d_wg),
                              ("wu", d_wu), ("wd", d_wd)]:
                g[name] += np.asarray(val)
            d_h_resid.append(np.asarray(d_hin))
            d_attn.append(np.asarray(d_att))

        # transposed all-to-all: d_attn seq-shard -> head-shard
        d_o_full = a2a_seq_to_head(d_attn, q_sh, sp)
        d_qkv_full = [M.attn_core_bwd(cfg, jnp.asarray(q_full[r]),
                                      jnp.asarray(k_full[r]),
                                      jnp.asarray(v_full[r]),
                                      jnp.asarray(d_o_full[r]))
                      for r in range(sp)]
        d_q = a2a_head_to_seq([np.asarray(x[0]) for x in d_qkv_full],
                              cfg.n_q_heads, sp)
        d_k = a2a_head_to_seq([np.asarray(x[1]) for x in d_qkv_full],
                              cfg.n_kv_heads, sp, sum_replicas=True)
        d_v = a2a_head_to_seq([np.asarray(x[2]) for x in d_qkv_full],
                              cfg.n_kv_heads, sp, sum_replicas=True)

        # pre_attn bwd; total d_h = residual path + qkv path
        new_d_h = []
        for r in range(sp):
            d_ln1, d_wq, d_wk, d_wv, d_hr = M.pre_attn_bwd(
                cfg, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                jnp.asarray(h_in[r]), jnp.asarray(pos_shards[r]),
                jnp.asarray(d_q[r]), jnp.asarray(d_k[r]),
                jnp.asarray(d_v[r]))
            for name, val in [("ln1", d_ln1), ("wq", d_wq), ("wk", d_wk),
                              ("wv", d_wv)]:
                g[name] += np.asarray(val)
            new_d_h.append(np.asarray(d_hr) + d_h_resid[r])
        d_h = new_d_h

    for r in range(sp):
        (d_emb,) = M.embed_bwd(cfg, params["embed"],
                               jnp.asarray(ids_shards[r]),
                               jnp.asarray(d_h[r]))
        grads["embed"] += np.asarray(d_emb)

    return mean_loss, grads
