//! Row-tile EXECUTION drivers (paper §3.1 executed, not just planned).
//!
//! [`TiledLossExec`] and [`TiledMlpExec`] stream a sequence shard through
//! a fixed-shape tile stage: shard rows are sliced into arena-backed
//! `[rows_per_tile, ...]` tiles with `copy_rows` (zero steady-state
//! allocation once the arena is warm), the ragged tail tile is padded
//! with zero rows and `ignore_index` labels (masked padding — 0 loss, 0
//! gradient, pinned by `python/tests/test_tiled_stages.py`), and results
//! are accumulated in place. The drivers are generic over the tile
//! executor closure, so the trainer plugs in AOT'd PJRT stages
//! (`loss_fwd_tile` / `mlp_fwd_tile` ...) while the tier-1 tests and
//! benches plug in [`HostLossHead`], a PJRT-free host reference — the
//! same split `relayout_equiv.rs` uses.
//!
//! # Summation-order contract
//!
//! Like the relayout bit-identity contract in `rust/tests/relayout_equiv.rs`,
//! equality between tiled and untiled execution is exact only because the
//! accumulation order is pinned:
//!
//! * **Per-row quantities** (per-row loss, each row of `d_h`) are
//!   row-local: bit-identical under ANY tiling.
//! * **The scalar loss/count reduction** is performed by the driver over
//!   the per-row vector in ascending global row order — also
//!   tiling-invariant, so tiled-vs-untiled total loss is bit-identical.
//! * **Cross-row weight-gradient reductions** (`d_lnf`, `d_unembed`, the
//!   MLP weight grads) are pinned TILE-MAJOR: rows accumulate in
//!   ascending order *within* a tile (each tile partial starts from
//!   zero), and tile partials are added elementwise in ascending tile
//!   order. An untiled reference that replays the same schedule matches
//!   bit-for-bit; changing `rows_per_tile` re-rounds these sums like any
//!   resharding (the same class of exception as the relayout contract's
//!   sign-of-zero note) and agrees only to fp tolerance.
//!
//! # Memory instrumentation
//!
//! Each tile execution charges the [`MemoryTracker`] with the §3.1 fp32
//! logits-copy arithmetic (`TilePlan::tile_bytes` = 2 copies, fwd+bwd)
//! under [`LOSS_HEAD_TAG`], and the untiled trainer path charges the
//! full-shard equivalent, so `tracker.tag_peak(LOSS_HEAD_TAG)` measures
//! the drop `TilePlan::savings()` predicts. MLP tiles charge
//! [`MLP_TAG`] with the gate/up/down working set, doubled in backward
//! (the estimator's `bwd_factor`).

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::memory::MemoryTracker;
use crate::obs::{Category, Tracer};
use crate::runtime::tensor::{copy_rows, HostTensor, ScratchArena};
use crate::tiling::{plan_logits_rows, plan_mlp_rows, TilePlan};

/// Tracker tag for loss-head (logits+CE) working bytes, both paths.
pub const LOSS_HEAD_TAG: &str = "loss_head";
/// Tracker tag for MLP-phase working bytes, both paths.
pub const MLP_TAG: &str = "mlp";

/// Untiled loss-head forward working set: one fp32 `[rows, vocab]`
/// logits copy (what the monolithic `loss_fwd` stage holds). Half the
/// plan's 2-copy (fwd+bwd) `untiled_bytes` — the copy convention lives
/// in ONE place, `TilePlan`, exactly like the estimator's pricing.
pub fn untiled_loss_fwd_bytes(rows: usize, vocab: usize) -> u64 {
    untiled_loss_bwd_bytes(rows, vocab) / 2
}

/// Untiled loss-head backward working set: logits + d_logits fp32
/// copies ("2 times of 8 GiB", §3.1) — the plan's `untiled_bytes`.
pub fn untiled_loss_bwd_bytes(rows: usize, vocab: usize) -> u64 {
    plan_logits_rows(rows, vocab, rows).untiled_bytes
}

/// Untiled MLP forward working set: gate + up `[rows, ffn]` + down
/// input — the plan's `untiled_bytes` at fp32.
pub fn untiled_mlp_fwd_bytes(rows: usize, hidden: usize, ffn: usize) -> u64 {
    plan_mlp_rows(rows, hidden, ffn, rows, 4).untiled_bytes
}

/// Result of one tiled loss-head forward sweep.
pub struct LossFwdSweep {
    /// Per-row loss over the whole shard (0.0 at `ignore_index` rows) —
    /// what per-document bucketing consumes. Arena-sourced: recycle it
    /// (`arena.recycle_f32`) when done to keep the sweep allocation-free.
    pub per_row_loss: Vec<f32>,
    /// Ascending-row sum of per-row losses (the pinned reduction).
    pub loss_sum: f32,
    /// Number of non-ignored rows, as f32 (matches the stage contract).
    pub count: f32,
    pub tiles_run: usize,
}

/// Row-tiled loss-head driver: `[seqlen, hidden]` hidden states + labels
/// -> per-row losses (forward) and `d_lnf`/`d_unembed`/`d_h` (backward),
/// never holding more than one `[rows_per_tile, vocab]` logits tile.
pub struct TiledLossExec<'a> {
    pub plan: TilePlan,
    seqlen: usize,
    hidden: usize,
    ignore_index: i32,
    arena: &'a ScratchArena,
    tracer: Arc<Tracer>,
}

impl<'a> TiledLossExec<'a> {
    pub fn new(
        seqlen: usize,
        hidden: usize,
        vocab: usize,
        rows_per_tile: usize,
        ignore_index: i32,
        arena: &'a ScratchArena,
    ) -> Result<TiledLossExec<'a>> {
        ensure!(seqlen > 0, "tiled loss over an empty shard");
        ensure!(hidden > 0 && vocab > 0, "tiled loss needs hidden/vocab > 0");
        ensure!(rows_per_tile > 0, "tiled loss needs rows_per_tile > 0");
        Ok(TiledLossExec {
            plan: plan_logits_rows(seqlen, vocab, rows_per_tile),
            seqlen,
            hidden,
            ignore_index,
            arena,
            tracer: Tracer::off(),
        })
    }

    /// Builder: record a `Tile` container span per sweep on `tracer`.
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> TiledLossExec<'a> {
        self.tracer = tracer;
        self
    }

    /// Open the per-sweep container span (inert when tracing is off).
    fn sweep_span(&self, name: &'static str) -> (crate::obs::SpanGuard<'_>, u64, u64) {
        let (hits0, misses0) = if self.tracer.enabled() {
            (self.arena.hits(), self.arena.misses())
        } else {
            (0, 0)
        };
        (self.tracer.span(Category::Tile, name), hits0, misses0)
    }

    /// Slice the `[lo, hi)` row range of `(h, labels)` into a padded
    /// `[rows_per_tile, ...]` tile pair from the arena.
    fn slice_tile(
        &self,
        hs: &[f32],
        labels: &[i32],
        lo: usize,
        hi: usize,
    ) -> (HostTensor, HostTensor) {
        let (rows, hd) = (self.plan.rows_per_tile, self.hidden);
        let n = hi - lo;
        let mut ht = self.arena.take_f32(rows * hd);
        copy_rows(&mut ht, 0, hd, hs, lo * hd, hd, n, hd);
        ht[n * hd..].fill(0.0); // masked padding rows (ragged tail)
        let mut lt = self.arena.take_i32(rows);
        lt[..n].copy_from_slice(&labels[lo..hi]);
        lt[n..].fill(self.ignore_index);
        (
            HostTensor::f32(vec![rows, hd], ht),
            HostTensor::i32(vec![rows], lt),
        )
    }

    /// Forward sweep. `tile_fn(h_tile [T,H], labels_tile [T])` must
    /// return the `[T]` per-row loss tensor (the `loss_fwd_tile` stage).
    pub fn forward<F>(
        &self,
        tracker: &mut MemoryTracker,
        h: &HostTensor,
        labels: &[i32],
        mut tile_fn: F,
    ) -> Result<LossFwdSweep>
    where
        F: FnMut(&HostTensor, &HostTensor) -> Result<HostTensor>,
    {
        let (s, hd, rows) = (self.seqlen, self.hidden, self.plan.rows_per_tile);
        ensure!(
            h.shape() == [s, hd],
            "tiled loss: h shape {:?} != [{s}, {hd}]",
            h.shape()
        );
        ensure!(labels.len() == s, "tiled loss: {} labels != {s}", labels.len());
        let (mut span, hits0, misses0) = self.sweep_span("loss_fwd_tiles");
        let hs = h.as_f32()?;
        let mut per_row = self.arena.take_f32(s);
        // one fp32 [T, vocab] logits copy lives during a forward tile
        let fwd_bytes = self.plan.tile_bytes / 2;
        for t in 0..self.plan.n_tiles {
            let lo = t * rows;
            let hi = (lo + rows).min(s);
            let (ht, lt) = self.slice_tile(hs, labels, lo, hi);
            tracker.alloc(fwd_bytes, LOSS_HEAD_TAG)?;
            let out = tile_fn(&ht, &lt);
            // free before surfacing errors: a failed tile must not leave
            // phantom bytes charged on the (reusable) tracker
            tracker.free(fwd_bytes, LOSS_HEAD_TAG);
            self.arena.recycle(ht);
            self.arena.recycle(lt);
            let out = out?;
            ensure!(
                out.numel() == rows,
                "loss tile {t}: {} per-row losses != rows_per_tile {rows}",
                out.numel()
            );
            per_row[lo..hi].copy_from_slice(&out.as_f32()?[..hi - lo]);
            self.arena.recycle(out);
        }
        // Pinned reduction: ascending global row order, skipping ignored
        // rows (their per-row loss is exactly 0 by the stage contract).
        let (mut loss_sum, mut count) = (0f32, 0f32);
        for (i, &l) in labels.iter().enumerate() {
            if l != self.ignore_index {
                loss_sum += per_row[i];
                count += 1.0;
            }
        }
        span.set_bytes(fwd_bytes * self.plan.n_tiles as u64);
        if span.active() {
            span.set_arena_delta(self.arena.hits() - hits0, self.arena.misses() - misses0);
        }
        Ok(LossFwdSweep {
            per_row_loss: per_row,
            loss_sum,
            count,
            tiles_run: self.plan.n_tiles,
        })
    }

    /// Backward sweep. `tile_fn(h_tile, labels_tile)` must return the
    /// `(d_lnf [H], d_unembed [H,V], d_h_tile [T,H])` partials of the
    /// tile (the `loss_bwd_tile` stage; the scalar cotangent is the
    /// caller's to capture in the closure). Weight-grad partials are
    /// accumulated into `d_lnf`/`d_unembed` in the pinned tile-major
    /// order; returns the assembled `[S, H]` d_h (arena-sourced).
    pub fn backward<F>(
        &self,
        tracker: &mut MemoryTracker,
        h: &HostTensor,
        labels: &[i32],
        d_lnf: &mut [f32],
        d_unembed: &mut [f32],
        mut tile_fn: F,
    ) -> Result<HostTensor>
    where
        F: FnMut(&HostTensor, &HostTensor) -> Result<(HostTensor, HostTensor, HostTensor)>,
    {
        let (s, hd, rows) = (self.seqlen, self.hidden, self.plan.rows_per_tile);
        ensure!(
            h.shape() == [s, hd],
            "tiled loss bwd: h shape {:?} != [{s}, {hd}]",
            h.shape()
        );
        ensure!(labels.len() == s, "tiled loss bwd: {} labels != {s}", labels.len());
        ensure!(d_lnf.len() == hd, "d_lnf accumulator length");
        let (mut span, hits0, misses0) = self.sweep_span("loss_bwd_tiles");
        let hs = h.as_f32()?;
        let mut d_h = self.arena.take_f32(s * hd);
        // logits + d_logits fp32 copies live during a backward tile
        let bwd_bytes = self.plan.tile_bytes;
        for t in 0..self.plan.n_tiles {
            let lo = t * rows;
            let hi = (lo + rows).min(s);
            let (ht, lt) = self.slice_tile(hs, labels, lo, hi);
            tracker.alloc(bwd_bytes, LOSS_HEAD_TAG)?;
            let out = tile_fn(&ht, &lt);
            tracker.free(bwd_bytes, LOSS_HEAD_TAG);
            self.arena.recycle(ht);
            self.arena.recycle(lt);
            let (dl, dw, dht) = out?;
            ensure!(dl.numel() == hd, "loss tile {t}: bad d_lnf partial shape");
            ensure!(
                dw.numel() == d_unembed.len(),
                "loss tile {t}: bad d_unembed partial shape"
            );
            ensure!(
                dht.shape() == [rows, hd],
                "loss tile {t}: bad d_h tile shape {:?}",
                dht.shape()
            );
            for (a, b) in d_lnf.iter_mut().zip(dl.as_f32()?) {
                *a += b;
            }
            for (a, b) in d_unembed.iter_mut().zip(dw.as_f32()?) {
                *a += b;
            }
            copy_rows(&mut d_h, lo * hd, hd, dht.as_f32()?, 0, hd, hi - lo, hd);
            self.arena.recycle(dl);
            self.arena.recycle(dw);
            self.arena.recycle(dht);
        }
        span.set_bytes(bwd_bytes * self.plan.n_tiles as u64);
        if span.active() {
            span.set_arena_delta(self.arena.hits() - hits0, self.arena.misses() - misses0);
        }
        Ok(HostTensor::f32(vec![s, hd], d_h))
    }
}

/// Row-tiled post-attention/MLP driver. The whole post-attention block
/// (output projection, residual, RMSNorm, SwiGLU MLP) is row-wise, so
/// one `[rows_per_tile, ...]` slice of `(h_in, attn)` yields the same
/// output rows as the monolithic stage.
pub struct TiledMlpExec<'a> {
    pub plan: TilePlan,
    seqlen: usize,
    hidden: usize,
    /// attn row block = n_q_heads * head_dim elements.
    attn_block: usize,
    /// Tile shape of the attn input, `[rows, n_q_heads, head_dim]`.
    attn_tile_shape: Vec<usize>,
    arena: &'a ScratchArena,
    tracer: Arc<Tracer>,
}

impl<'a> TiledMlpExec<'a> {
    pub fn new(
        seqlen: usize,
        hidden: usize,
        ffn: usize,
        rows_per_tile: usize,
        n_q_heads: usize,
        head_dim: usize,
        arena: &'a ScratchArena,
    ) -> Result<TiledMlpExec<'a>> {
        ensure!(seqlen > 0, "tiled MLP over an empty shard");
        ensure!(hidden > 0 && ffn > 0, "tiled MLP needs hidden/ffn > 0");
        ensure!(rows_per_tile > 0, "tiled MLP needs rows_per_tile > 0");
        let plan = plan_mlp_rows(seqlen, hidden, ffn, rows_per_tile, 4);
        let rows = plan.rows_per_tile;
        Ok(TiledMlpExec {
            plan,
            seqlen,
            hidden,
            attn_block: n_q_heads * head_dim,
            attn_tile_shape: vec![rows, n_q_heads, head_dim],
            arena,
            tracer: Tracer::off(),
        })
    }

    /// Builder: record a `Tile` container span per sweep on `tracer`.
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> TiledMlpExec<'a> {
        self.tracer = tracer;
        self
    }

    /// Open the per-sweep container span (inert when tracing is off).
    fn sweep_span(&self, name: &'static str) -> (crate::obs::SpanGuard<'_>, u64, u64) {
        let (hits0, misses0) = if self.tracer.enabled() {
            (self.arena.hits(), self.arena.misses())
        } else {
            (0, 0)
        };
        (self.tracer.span(Category::Tile, name), hits0, misses0)
    }

    fn slice_pair(
        &self,
        h_in: &[f32],
        attn: &[f32],
        lo: usize,
        hi: usize,
    ) -> (HostTensor, HostTensor) {
        let (rows, hd, ab) = (self.plan.rows_per_tile, self.hidden, self.attn_block);
        let n = hi - lo;
        let mut ht = self.arena.take_f32(rows * hd);
        copy_rows(&mut ht, 0, hd, h_in, lo * hd, hd, n, hd);
        ht[n * hd..].fill(0.0);
        let mut at = self.arena.take_f32(rows * ab);
        copy_rows(&mut at, 0, ab, attn, lo * ab, ab, n, ab);
        at[n * ab..].fill(0.0);
        (
            HostTensor::f32(vec![rows, hd], ht),
            HostTensor::f32(self.attn_tile_shape.clone(), at),
        )
    }

    fn check_inputs(&self, h_in: &HostTensor, attn: &HostTensor) -> Result<()> {
        let (s, hd, ab) = (self.seqlen, self.hidden, self.attn_block);
        ensure!(
            h_in.shape() == [s, hd],
            "tiled MLP: h_in shape {:?} != [{s}, {hd}]",
            h_in.shape()
        );
        ensure!(
            attn.numel() == s * ab && attn.shape()[0] == s,
            "tiled MLP: attn shape {:?} != [{s}, heads*dim = {ab}]",
            attn.shape()
        );
        Ok(())
    }

    /// Forward sweep. `tile_fn(h_in_tile [T,H], attn_tile [T,nq,d])`
    /// must return the `[T, H]` output tile (the `mlp_fwd_tile` stage,
    /// weights captured by the closure). Returns the `[S, H]` output
    /// (arena-sourced).
    pub fn forward<F>(
        &self,
        tracker: &mut MemoryTracker,
        h_in: &HostTensor,
        attn: &HostTensor,
        mut tile_fn: F,
    ) -> Result<HostTensor>
    where
        F: FnMut(&HostTensor, &HostTensor) -> Result<HostTensor>,
    {
        self.check_inputs(h_in, attn)?;
        let (mut span, hits0, misses0) = self.sweep_span("mlp_fwd_tiles");
        let (s, hd, rows) = (self.seqlen, self.hidden, self.plan.rows_per_tile);
        let (hs, ats) = (h_in.as_f32()?, attn.as_f32()?);
        let mut h_out = self.arena.take_f32(s * hd);
        for t in 0..self.plan.n_tiles {
            let lo = t * rows;
            let hi = (lo + rows).min(s);
            let (ht, at) = self.slice_pair(hs, ats, lo, hi);
            tracker.alloc(self.plan.tile_bytes, MLP_TAG)?;
            let out = tile_fn(&ht, &at);
            tracker.free(self.plan.tile_bytes, MLP_TAG);
            self.arena.recycle(ht);
            self.arena.recycle(at);
            let out = out?;
            ensure!(
                out.shape() == [rows, hd],
                "mlp tile {t}: bad output shape {:?}",
                out.shape()
            );
            copy_rows(&mut h_out, lo * hd, hd, out.as_f32()?, 0, hd, hi - lo, hd);
            self.arena.recycle(out);
        }
        span.set_bytes(self.plan.tile_bytes * self.plan.n_tiles as u64);
        if span.active() {
            span.set_arena_delta(self.arena.hits() - hits0, self.arena.misses() - misses0);
        }
        Ok(HostTensor::f32(vec![s, hd], h_out))
    }

    /// Backward sweep. `tile_fn(h_in_tile, attn_tile, d_out_tile)` must
    /// return `(d_h_in_tile [T,H], d_attn_tile [T,nq,d])` and is itself
    /// responsible for accumulating the five weight-grad partials it
    /// also receives from the stage (tiles are invoked in ascending
    /// order — the pinned accumulation order). Returns the assembled
    /// `(d_h_in [S,H], d_attn [S,nq,d])`, both arena-sourced.
    pub fn backward<F>(
        &self,
        tracker: &mut MemoryTracker,
        h_in: &HostTensor,
        attn: &HostTensor,
        d_out: &HostTensor,
        mut tile_fn: F,
    ) -> Result<(HostTensor, HostTensor)>
    where
        F: FnMut(&HostTensor, &HostTensor, &HostTensor) -> Result<(HostTensor, HostTensor)>,
    {
        self.check_inputs(h_in, attn)?;
        let (s, hd, ab, rows) =
            (self.seqlen, self.hidden, self.attn_block, self.plan.rows_per_tile);
        ensure!(
            d_out.shape() == [s, hd],
            "tiled MLP bwd: d_out shape {:?} != [{s}, {hd}]",
            d_out.shape()
        );
        let (mut span, hits0, misses0) = self.sweep_span("mlp_bwd_tiles");
        let (hs, ats, dos) = (h_in.as_f32()?, attn.as_f32()?, d_out.as_f32()?);
        let mut d_h_in = self.arena.take_f32(s * hd);
        let mut d_attn = self.arena.take_f32(s * ab);
        for t in 0..self.plan.n_tiles {
            let lo = t * rows;
            let hi = (lo + rows).min(s);
            let n = hi - lo;
            let (ht, at) = self.slice_pair(hs, ats, lo, hi);
            let mut dt = self.arena.take_f32(rows * hd);
            copy_rows(&mut dt, 0, hd, dos, lo * hd, hd, n, hd);
            dt[n * hd..].fill(0.0);
            let dt_t = HostTensor::f32(vec![rows, hd], dt);
            // backward holds ~2x the forward working set (recompute +
            // cotangents — the estimator's bwd_factor)
            tracker.alloc(2 * self.plan.tile_bytes, MLP_TAG)?;
            let out = tile_fn(&ht, &at, &dt_t);
            tracker.free(2 * self.plan.tile_bytes, MLP_TAG);
            self.arena.recycle(ht);
            self.arena.recycle(at);
            self.arena.recycle(dt_t);
            let (dh_t, da_t) = out?;
            ensure!(
                dh_t.shape() == [rows, hd],
                "mlp tile {t}: bad d_h_in shape {:?}",
                dh_t.shape()
            );
            ensure!(
                da_t.numel() == rows * ab,
                "mlp tile {t}: bad d_attn shape {:?}",
                da_t.shape()
            );
            copy_rows(&mut d_h_in, lo * hd, hd, dh_t.as_f32()?, 0, hd, n, hd);
            copy_rows(&mut d_attn, lo * ab, ab, da_t.as_f32()?, 0, ab, n, ab);
            self.arena.recycle(dh_t);
            self.arena.recycle(da_t);
        }
        span.set_bytes(2 * self.plan.tile_bytes * self.plan.n_tiles as u64);
        if span.active() {
            span.set_arena_delta(self.arena.hits() - hits0, self.arena.misses() - misses0);
        }
        let mut attn_shape = self.attn_tile_shape.clone();
        attn_shape[0] = s;
        Ok((
            HostTensor::f32(vec![s, hd], d_h_in),
            HostTensor::f32(attn_shape, d_attn),
        ))
    }
}

// ---------------------------------------------------------------------------
// HostLossHead: the PJRT-free reference executor
// ---------------------------------------------------------------------------

/// Host-side loss head (final RMSNorm + logits + CE) with fully pinned
/// arithmetic: every cross-element reduction runs in ascending index
/// order, one element at a time. Serves as (a) the tile executor the
/// tier-1 tests and `bench_tiling` plug into the drivers — no PJRT
/// backend exists offline — and (b) the untiled reference whose pinned
/// row-major schedule the bit-identity tests compare against (the
/// `pack_first_fit_reference` pattern).
pub struct HostLossHead {
    pub hidden: usize,
    pub vocab: usize,
    pub eps: f32,
    pub ignore_index: i32,
    /// `[hidden]` final-norm weight.
    pub lnf: Vec<f32>,
    /// `[hidden, vocab]` row-major unembedding.
    pub unembed: Vec<f32>,
}

impl HostLossHead {
    pub fn new(
        hidden: usize,
        vocab: usize,
        ignore_index: i32,
        lnf: Vec<f32>,
        unembed: Vec<f32>,
    ) -> Result<HostLossHead> {
        ensure!(lnf.len() == hidden, "lnf length != hidden");
        ensure!(unembed.len() == hidden * vocab, "unembed length != hidden*vocab");
        Ok(HostLossHead { hidden, vocab, eps: 1e-5, ignore_index, lnf, unembed })
    }

    /// RMS-normalize one row into `x`; returns the inverse-rms factor.
    fn norm_row(&self, hr: &[f32], x: &mut [f32]) -> f32 {
        let mut var = 0f32;
        for &a in hr {
            var += a * a;
        }
        var /= self.hidden as f32;
        let inv = 1.0 / (var + self.eps).sqrt();
        for (j, xo) in x.iter_mut().enumerate() {
            *xo = hr[j] * inv * self.lnf[j];
        }
        inv
    }

    /// `logits = x @ unembed`, accumulated in ascending-j order.
    fn row_logits(&self, x: &[f32], logits: &mut [f32]) {
        logits.fill(0.0);
        for (j, &xj) in x.iter().enumerate() {
            let w = &self.unembed[j * self.vocab..(j + 1) * self.vocab];
            for (l, &wv) in logits.iter_mut().zip(w) {
                *l += xj * wv;
            }
        }
    }

    /// log-sum-exp over one logits row (ascending-v max and sum).
    fn row_lse(logits: &[f32]) -> f32 {
        let mut m = f32::NEG_INFINITY;
        for &l in logits {
            m = m.max(l);
        }
        let mut sum = 0f32;
        for &l in logits {
            sum += (l - m).exp();
        }
        m + sum.ln()
    }

    /// Per-row losses for a `[rows, hidden]` block (0.0 at ignored rows).
    /// Row values are row-local: identical under any tiling of the rows.
    pub fn per_row_losses(&self, h: &[f32], labels: &[i32]) -> Result<Vec<f32>> {
        let (hd, v) = (self.hidden, self.vocab);
        ensure!(h.len() == labels.len() * hd, "h/labels row mismatch");
        let mut x = vec![0f32; hd];
        let mut logits = vec![0f32; v];
        let mut out = vec![0f32; labels.len()];
        for (r, &lab) in labels.iter().enumerate() {
            if lab == self.ignore_index {
                continue;
            }
            ensure!((lab as usize) < v, "label {lab} out of vocab {v}");
            self.norm_row(&h[r * hd..(r + 1) * hd], &mut x);
            self.row_logits(&x, &mut logits);
            out[r] = Self::row_lse(&logits) - logits[lab as usize];
        }
        Ok(out)
    }

    /// Untiled reference forward: per-row losses reduced in ascending
    /// row order. Returns (loss_sum, count).
    pub fn untiled_loss(&self, h: &[f32], labels: &[i32]) -> Result<(f32, f32)> {
        let per = self.per_row_losses(h, labels)?;
        let (mut sum, mut count) = (0f32, 0f32);
        for (i, &l) in labels.iter().enumerate() {
            if l != self.ignore_index {
                sum += per[i];
                count += 1.0;
            }
        }
        Ok((sum, count))
    }

    /// Backward for a `[rows, hidden]` block with scalar cotangent `ct`
    /// on the loss sum. ACCUMULATES into `d_lnf [H]` / `d_unembed [H,V]`
    /// row-by-row in ascending order; OVERWRITES `d_h [rows, H]`.
    /// Ignored rows contribute exactly 0 everywhere.
    pub fn backward(
        &self,
        h: &[f32],
        labels: &[i32],
        ct: f32,
        d_lnf: &mut [f32],
        d_unembed: &mut [f32],
        d_h: &mut [f32],
    ) -> Result<()> {
        let (hd, v) = (self.hidden, self.vocab);
        ensure!(h.len() == labels.len() * hd, "h/labels row mismatch");
        ensure!(d_lnf.len() == hd && d_unembed.len() == hd * v, "grad buffer shapes");
        ensure!(d_h.len() == h.len(), "d_h shape");
        let mut x = vec![0f32; hd];
        let mut logits = vec![0f32; v];
        let mut d_x = vec![0f32; hd];
        for (r, &lab) in labels.iter().enumerate() {
            let d_hr = &mut d_h[r * hd..(r + 1) * hd];
            if lab == self.ignore_index {
                d_hr.fill(0.0);
                continue;
            }
            let hr = &h[r * hd..(r + 1) * hd];
            let inv = self.norm_row(hr, &mut x);
            self.row_logits(&x, &mut logits);
            let lse = Self::row_lse(&logits);
            // d_logits = (softmax - onehot) * ct, folded in place
            for (vi, l) in logits.iter_mut().enumerate() {
                let p = (*l - lse).exp();
                let oh = if vi == lab as usize { 1.0 } else { 0.0 };
                *l = (p - oh) * ct;
            }
            // d_x and d_unembed from the logits matmul
            for j in 0..hd {
                let w = &self.unembed[j * v..(j + 1) * v];
                let dw = &mut d_unembed[j * v..(j + 1) * v];
                let mut acc = 0f32;
                for (vi, &dl) in logits.iter().enumerate() {
                    acc += dl * w[vi];
                    dw[vi] += x[j] * dl;
                }
                d_x[j] = acc;
            }
            // RMSNorm backward: x[j] = hr[j] * inv * lnf[j]
            let mut s = 0f32;
            for j in 0..hd {
                d_lnf[j] += d_x[j] * hr[j] * inv;
                s += d_x[j] * self.lnf[j] * hr[j];
            }
            let k = inv * inv * inv * s / hd as f32;
            for j in 0..hd {
                d_hr[j] = inv * d_x[j] * self.lnf[j] - k * hr[j];
            }
        }
        Ok(())
    }
}
