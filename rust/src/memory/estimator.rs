//! Per-GPU memory estimator implementing the paper's own byte arithmetic
//! (§2.1 model states, §3.1 logits/MLP tiling, §3.3 checkpoint offload),
//! driven by the same shard math the real pipeline uses.
//!
//! Every worked number in the paper's text is a unit test here:
//!   * 144 GiB of model states for Llama-8B (§2.1)
//!   * 7.65 GiB of fp32 logits at 16K (§3.1)
//!   * 30.5 GiB of checkpoints at 125K (§3.3)
//!   * 915/305/152/76 GiB host offload for 70B/32B (§5.3.2, §5.3.3)
//!
//! The absolute max-seqlen results depend on two calibration constants
//! (backward working-set multiplier, misc overhead); the *shape* —
//! which term binds in which ablation row, the crossovers, near-linear
//! GPU scaling — is structural.

use crate::config::{ClusterConfig, FeatureFlags, ModelPreset, PlanKind, Precision, GIB};
use crate::coordinator::ulysses::heads_per_rank;
use crate::tiling::{plan_logits, plan_mlp, TilePlan};

/// Activation-side working memory, by phase (the max over phases is what
/// the allocator must satisfy at peak).
#[derive(Debug, Clone, Default)]
pub struct ActivationBreakdown {
    /// Checkpointed hidden_states on device (0 when offloaded).
    pub ckpt_device: u64,
    /// Checkpointed hidden_states on host (0 unless offloaded).
    pub ckpt_host: u64,
    /// Attention-phase working set (a2a send+recv + attn fwd/bwd buffers).
    pub attn_work: u64,
    /// MLP-phase working set (gate/up intermediates; tiny when tiled).
    pub mlp_work: u64,
    /// Logits+loss working set (the §3.1 fp32 monster; capped when tiled).
    pub logits_work: u64,
    /// Residual-stream temporaries ([T_r, H] copies through the layer).
    pub resid_work: u64,
}

impl ActivationBreakdown {
    /// Peak device activation demand: checkpoints coexist with the worst
    /// single phase (attention, MLP, or the loss head).
    pub fn device_peak(&self) -> u64 {
        self.ckpt_device
            + self.resid_work
            + self.attn_work.max(self.mlp_work).max(self.logits_work)
    }
}

#[derive(Debug, Clone, Default)]
pub struct MemoryBreakdown {
    /// bf16 weights resident on device (ZeRO-sharded; 0 if weights-offload).
    pub weights_device: u64,
    /// fp32 gradient shard on device.
    pub grads_device: u64,
    /// Optimizer states + master weights on device (0 when offloaded).
    pub optim_device: u64,
    pub acts: ActivationBreakdown,
    /// Host bytes PER RANK (optimizer offload + weight offload + ckpts).
    pub host_per_rank: u64,
    /// Misc constant overhead (workspace, dataloader staging, NaN margin).
    pub misc: u64,
}

impl MemoryBreakdown {
    pub fn device_total(&self) -> u64 {
        self.weights_device
            + self.grads_device
            + self.optim_device
            + self.acts.device_peak()
            + self.misc
    }
}

/// Calibration constants (DESIGN.md §Perf documents the fit).
#[derive(Debug, Clone, Copy)]
pub struct Calibration {
    /// Backward/recompute working-set multiplier over the forward set.
    pub bwd_factor: f64,
    /// Residual-stream copy multiplier (h, normed h, h1, d_h...).
    pub resid_copies: f64,
    /// Constant per-GPU overhead in bytes (workspace, staging, the paper's
    /// "don't use the last few GiB or loss goes NaN" margin, fn.17).
    pub misc_bytes: u64,
    /// Extra fp32 logits copies in the UNtiled loss path (HF materializes
    /// logits, upcasts, and the backward holds its own copy — the paper
    /// measured "2 times of 8GiB"; the upcast makes it 3 in practice).
    pub untiled_logits_copies: f64,
    /// fp32 logits copies in the tiled path (fwd + bwd per chunk).
    pub tiled_logits_copies: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            bwd_factor: 2.0,
            resid_copies: 3.0,
            misc_bytes: 3 * GIB,
            untiled_logits_copies: 3.0,
            tiled_logits_copies: 2.0,
        }
    }
}

pub struct Estimator {
    pub model: ModelPreset,
    pub cluster: ClusterConfig,
    pub flags: FeatureFlags,
    pub precision: Precision,
    pub cal: Calibration,
    /// Which `ParallelPlan` the attention phase is priced for.
    pub plan: PlanKind,
}

impl Estimator {
    pub fn new(model: &ModelPreset, cluster: ClusterConfig, flags: FeatureFlags) -> Estimator {
        Estimator {
            model: model.clone(),
            cluster,
            flags,
            precision: Precision::Bf16Mixed,
            cal: Calibration::default(),
            plan: PlanKind::Ulysses,
        }
    }

    pub fn with_plan(mut self, plan: PlanKind) -> Estimator {
        self.plan = plan;
        self
    }

    /// Effective SP degree for a given world size under the flags.
    pub fn sp_degree(&self, world: usize) -> usize {
        if !self.flags.ulysses_sp {
            return 1;
        }
        // Ring has no heads >= sp bound: every rank keeps all heads of
        // its query shard, so the full world is always a valid degree.
        if self.plan == PlanKind::Ring {
            return world;
        }
        // Largest valid SP degree <= world (paper uses SP = world in eval).
        self.model
            .valid_sp_degrees(world)
            .into_iter()
            .max()
            .unwrap_or(1)
    }

    /// Model-state bytes (§2.1: 2 weights + 4 grads + 8 optim + 4 master
    /// per param), before any sharding/offload. Returns the four parts.
    pub fn model_state_bytes(&self) -> (u64, u64, u64, u64) {
        let p = self.model.params;
        (2 * p, 4 * p, 8 * p, 4 * p) // (bf16 w, fp32 g, adam m+v, fp32 master)
    }

    /// Full per-GPU breakdown at sequence length `seq` on `world` GPUs.
    pub fn breakdown(&self, seq: usize, world: usize) -> MemoryBreakdown {
        let m = &self.model;
        let f = &self.flags;
        let act_b = self.precision.activation_bytes();
        let sp = self.sp_degree(world);
        let t_r = seq.div_ceil(sp); // per-rank sequence tokens (bs=1)
        let zero_w = if f.zero3 { world as u64 } else { 1 };

        // ---- model states ---------------------------------------------------
        let (w_b, g_b, opt_b, master_b) = self.model_state_bytes();
        let mut host_per_rank = 0u64;
        let weights_device = if f.weights_offload {
            // weights stream from host; device holds ~2 layers' worth
            host_per_rank += w_b / zero_w;
            2 * (w_b / m.n_layers as u64)
        } else {
            w_b / zero_w
        };
        // Single-GPU recipe (weights offload) uses ZeRO-Offload semantics:
        // fp32 grads stream to host as they are produced; the device keeps
        // a ~2-layer working buffer. Otherwise grads stay sharded on device.
        let grads_device = if f.weights_offload && f.optimizer_offload {
            host_per_rank += g_b / zero_w;
            2 * (g_b / m.n_layers as u64)
        } else {
            g_b / zero_w
        };
        let optim_device = if f.optimizer_offload {
            host_per_rank += (opt_b + master_b) / zero_w;
            0
        } else {
            (opt_b + master_b) / zero_w
        };

        // ---- activations -----------------------------------------------------
        let h = m.hidden as u64;
        let layers = m.n_layers as u64;
        let d = m.head_dim as u64;
        // Head shards only exist under Ulysses; ring keeps all heads
        // local (and its sp need not divide the head counts at all).
        let (q_sh, kv_sh) = if sp > 1 && self.plan == PlanKind::Ulysses {
            (
                heads_per_rank(m.n_q_heads, sp) as u64,
                heads_per_rank(m.n_kv_heads, sp) as u64,
            )
        } else {
            (m.n_q_heads as u64, m.n_kv_heads as u64)
        };

        // checkpointed layer inputs: [t_r, hidden] x layers (§3.3)
        let ckpt = if f.activation_checkpointing {
            t_r as u64 * h * act_b * layers
        } else {
            // no checkpointing: every layer's intermediates persist —
            // model ~8 residual-sized tensors per layer (qkv, attn, mlp)
            t_r as u64 * h * act_b * layers * 8
        };
        let (ckpt_device, ckpt_host) = if f.ckpt_offload {
            host_per_rank += ckpt;
            (0, ckpt)
        } else {
            (ckpt, 0)
        };

        // attention phase, priced per plan:
        //  * ulysses: a2a send (seq-layout, all heads) + recv (head-layout,
        //    full seq) + o + o send-back; bwd doubles it.
        //  * ring: the rank never holds the full sequence — q + o shards
        //    (all heads) plus two double-buffered in-flight KV blocks
        //    (block i compute + block i+1 transfer) and the m/l running
        //    stats. Everything scales with t_r, not seq: this is why ring
        //    keeps working where the a2a recv buffer would not fit.
        let nq = m.n_q_heads as u64;
        let nkv = m.n_kv_heads as u64;
        let attn_fwd = match self.plan {
            PlanKind::Ulysses => {
                let send = t_r as u64 * (nq + 2 * nkv) * d;
                let recv = seq as u64 * (q_sh + 2 * kv_sh) * d;
                let o = seq as u64 * q_sh * d;
                let o_send = t_r as u64 * nq * d;
                (send + recv + o + o_send) * act_b
            }
            PlanKind::Ring => {
                let q_o = 2 * t_r as u64 * nq * d;
                let kv_blocks = 4 * t_r as u64 * nkv * d; // 2 blocks x (k+v)
                let stats = 2 * t_r as u64 * nq; // m + l per (row, head)
                (q_o + kv_blocks + stats) * act_b
            }
        };
        let attn_work = (attn_fwd as f64 * self.cal.bwd_factor) as u64;

        // MLP phase: priced from the SAME TilePlan the execution driver
        // runs (§3.1.1 auto-shards), so the estimator cannot disagree
        // with the planner — `tiled_pricing_matches_tile_plan_bytes`
        // pins the equality. Untiled takes the plan's full-shard bytes.
        let mlp_plan = self.mlp_plan(t_r);
        let mlp_fwd = if f.tiled_mlp {
            mlp_plan.tile_bytes
        } else {
            mlp_plan.untiled_bytes
        };
        let mlp_work = (mlp_fwd as f64 * self.cal.bwd_factor) as u64;

        // logits phase (§3.1): fp32 [rows, vocab], priced from the
        // TilePlan (which owns the 2-copy fwd+bwd convention); the
        // calibration's copy counts scale relative to those 2 copies.
        let logits_plan = self.logits_plan(t_r);
        let (logits_base, logits_copies) = if f.tiled_loss {
            (logits_plan.tile_bytes, self.cal.tiled_logits_copies)
        } else {
            (logits_plan.untiled_bytes, self.cal.untiled_logits_copies)
        };
        let logits_work = (logits_base as f64 * logits_copies / 2.0) as u64;

        let resid_work =
            (t_r as f64 * h as f64 * act_b as f64 * self.cal.resid_copies) as u64;

        MemoryBreakdown {
            weights_device,
            grads_device,
            optim_device,
            acts: ActivationBreakdown {
                ckpt_device,
                ckpt_host,
                attn_work,
                mlp_work,
                logits_work,
                resid_work,
            },
            host_per_rank,
            misc: self.cal.misc_bytes,
        }
    }

    /// The loss-head tile plan priced at `rows` per-rank tokens, from
    /// the same PLANNER the executor's plans come from, at the paper's
    /// 1 GiB chunk. An actual artifact may bake different rows (custom
    /// `--chunk-bytes`, pallas tile_s alignment) — for a loaded
    /// manifest, price with `tiling::plan_logits_rows(.., manifest
    /// rows)` instead; this estimator models paper-scale presets that
    /// have no artifact.
    pub fn logits_plan(&self, rows: usize) -> TilePlan {
        plan_logits(rows, self.model.vocab, GIB)
    }

    /// The MLP tile plan at `rows` per-rank tokens (§3.1.1 auto-shards;
    /// same caveat as [`Estimator::logits_plan`] for real artifacts).
    pub fn mlp_plan(&self, rows: usize) -> TilePlan {
        plan_mlp(
            rows,
            self.model.hidden,
            self.model.ffn,
            self.precision.activation_bytes(),
        )
    }

    /// Does `seq` fit on `world` GPUs (device AND host constraints)?
    pub fn fits(&self, seq: usize, world: usize) -> bool {
        let b = self.breakdown(seq, world);
        let dev = crate::memory::DeviceModel::h100(world, self.flags.expandable_segments);
        if b.device_total() > dev.usable() {
            return false;
        }
        // host: per-node budget shared by the node's ranks
        let per_node = b.host_per_rank * self.cluster.gpus_per_node as u64;
        per_node <= self.cluster.host_mem_bytes
    }

    /// Packed-batch breakdown at the same total token count. Every term
    /// the estimator tracks is sequence-LINEAR (flash-style attention,
    /// a2a buffers, checkpoints, logits) so the peak equals
    /// `breakdown(total)`; what packing changes is the O(S²) arithmetic a
    /// NAIVE implementation would need — see `packed_mask_bytes` /
    /// `naive_scores_bytes`, the §3.4 numbers.
    pub fn breakdown_packed(&self, seg_lens: &[usize], world: usize) -> MemoryBreakdown {
        let total: usize = seg_lens.iter().sum();
        self.breakdown(total, world)
    }

    /// Bytes a score-materializing segment-aware attention would hold:
    /// the sum of per-segment squares, Σᵢ Sᵢ² (one activation-precision
    /// element per in-segment score pair), versus S² for one document at
    /// the same token count. The packed/unpacked ratio is 1/k for k equal
    /// segments — same shape as the flos saving.
    pub fn naive_scores_bytes(&self, seg_lens: &[usize]) -> u64 {
        let act_b = self.precision.activation_bytes();
        seg_lens
            .iter()
            .map(|&s| s as u64 * s as u64 * act_b)
            .sum()
    }

    /// Which resource binds at this (seq, world)? For the narrative tables.
    pub fn binding_constraint(&self, seq: usize, world: usize) -> &'static str {
        let b = self.breakdown(seq, world);
        let per_node = b.host_per_rank * self.cluster.gpus_per_node as u64;
        if per_node > self.cluster.host_mem_bytes {
            return "host-ram";
        }
        let a = &b.acts;
        let phase = a.attn_work.max(a.mlp_work).max(a.logits_work);
        if a.ckpt_device > phase {
            "ckpt"
        } else if phase == a.logits_work {
            "logits"
        } else if phase == a.mlp_work {
            "mlp"
        } else {
            "attention"
        }
    }
}

/// Paper §3.4: the 4-D additive attention mask a naive packed
/// implementation materializes is `[1, 1, S, S]` bf16 — "29 GiB at 125K".
pub fn packed_mask_bytes(seq: usize) -> u64 {
    2 * seq as u64 * seq as u64
}

/// The paper's replacement: per-token position ids that reset at each
/// document boundary — one i32 per token, O(S) instead of O(S²).
pub fn position_ids_bytes(seq: usize) -> u64 {
    4 * seq as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::preset;

    fn est(flags: FeatureFlags) -> Estimator {
        Estimator::new(preset("llama3-8b").unwrap(), ClusterConfig::h100(1), flags)
    }

    #[test]
    fn paper_2_1_model_states_144gib() {
        // §2.1: Llama-8B needs 16+64+32+32 = 144 "GiB" of model states.
        // (The paper's arithmetic is actually decimal GB: 8.03e9 params x
        // 18 bytes = 144.5e9 bytes = 134.6 GiB; we match their numbers in
        // their own units.)
        let e = est(FeatureFlags::baseline());
        let (w, g, opt, master) = e.model_state_bytes();
        let total_gb = (w + g + opt + master) as f64 / 1e9;
        assert!((total_gb - 144.0).abs() < 3.0, "{total_gb}");
        assert_eq!((w + g + opt + master) / e.model.params, 18); // 18 B/param
    }

    #[test]
    fn paper_3_1_logits_7_65gib_at_16k() {
        // §3.1: 4 * 16_000 * 128_256 / 2^30 = 7.65 GiB for one fp32 copy.
        let one_copy = 4.0 * 16_000.0 * 128_256.0 / GIB as f64;
        assert!((one_copy - 7.65).abs() < 0.1);
        // untiled loss holds multiple copies; tiled caps at the 1GiB chunk
        let mut f = FeatureFlags::baseline();
        let b_untiled = est(f).breakdown(16_000, 8);
        f.tiled_loss = true;
        let b_tiled = est(f).breakdown(16_000, 8);
        assert!(b_untiled.acts.logits_work > 2 * b_tiled.acts.logits_work);
    }

    #[test]
    fn paper_3_3_ckpt_30_5gib_at_125k() {
        // §3.3: 125_000 x 4096 x 2 x 32 = 30.5 GiB of checkpoints.
        let e = est(FeatureFlags::baseline());
        let b = e.breakdown(125_000, 8);
        let gib = b.acts.ckpt_device as f64 / GIB as f64;
        assert!((gib - 30.5).abs() < 0.5, "{gib}");
        // offload moves them to host (Figure 7: the hill is gone)
        let mut f = FeatureFlags::baseline();
        f.ckpt_offload = true;
        let b2 = est(f).breakdown(125_000, 8);
        assert_eq!(b2.acts.ckpt_device, 0);
        assert!((b2.acts.ckpt_host as f64 / GIB as f64 - 30.5).abs() < 0.5);
    }

    #[test]
    fn paper_5_3_2_llama70b_host_305gib_per_node_at_1m() {
        // §5.3.2, 4 nodes (32 GPUs): 1M/32 x 8192 x 80 x 2 x 8 = 305 GiB
        // of ckpt-offload host memory per node per 1M tokens.
        let mut f = FeatureFlags::alst();
        f.optimizer_offload = false; // isolate the ckpt term
        let e = Estimator::new(
            preset("llama3-70b").unwrap(),
            ClusterConfig::h100(4),
            f,
        );
        let b = e.breakdown(1_000_000, 32);
        let per_node = b.acts.ckpt_host * 8;
        let gib = per_node as f64 / GIB as f64;
        assert!((gib - 305.0).abs() < 5.0, "{gib}");
        // 8 nodes: halves to ~152 GiB
        let e8 = Estimator::new(
            preset("llama3-70b").unwrap(),
            ClusterConfig::h100(8),
            f,
        );
        let b8 = e8.breakdown(1_000_000, 64);
        let gib8 = (b8.acts.ckpt_host * 8) as f64 / GIB as f64;
        assert!((gib8 - 152.0).abs() < 4.0, "{gib8}");
    }

    #[test]
    fn paper_5_3_3_qwen32b_host_152gib_per_node_at_1m() {
        // §5.3.3, 4 nodes: 1M/32 x 5120 x 64 x 2 x 8 = 152 GiB per node.
        let mut f = FeatureFlags::alst();
        f.optimizer_offload = false;
        let e = Estimator::new(
            preset("qwen3-32b").unwrap(),
            ClusterConfig::h100(4),
            f,
        );
        let b = e.breakdown(1_000_000, 32);
        let gib = (b.acts.ckpt_host * 8) as f64 / GIB as f64;
        assert!((gib - 152.0).abs() < 3.0, "{gib}");
    }

    #[test]
    fn paper_3_4_packed_mask_29gib_at_125k() {
        // §3.4: a [1,1,125K,125K] bf16 mask is ~29 GiB; the position-id
        // replacement is half a megabyte.
        let gib = packed_mask_bytes(125_000) as f64 / GIB as f64;
        assert!((gib - 29.1).abs() < 0.3, "{gib}");
        assert_eq!(position_ids_bytes(125_000), 500_000);
        assert!(position_ids_bytes(125_000) * 50_000 < packed_mask_bytes(125_000));
    }

    #[test]
    fn packed_scores_are_sum_of_segment_squares() {
        let e = est(FeatureFlags::alst());
        let total = 131_072usize;
        let one = e.naive_scores_bytes(&[total]);
        for k in [2usize, 8, 32] {
            let packed = e.naive_scores_bytes(&vec![total / k; k]);
            assert_eq!(packed, one / k as u64, "k={k}");
        }
    }

    #[test]
    fn packed_breakdown_matches_total_token_count() {
        // linear-memory terms see only the total token count
        let e = est(FeatureFlags::alst());
        let packed = e.breakdown_packed(&[400_000, 80_000, 20_000], 8);
        let whole = e.breakdown(500_000, 8);
        assert_eq!(packed.device_total(), whole.device_total());
        assert_eq!(packed.acts.ckpt_host, whole.acts.ckpt_host);
    }

    #[test]
    fn tiled_pricing_matches_tile_plan_bytes() {
        // Satellite contract: when tiling is on, the estimator's
        // loss-head and MLP bytes ARE the TilePlan's bytes (no separate
        // arithmetic to drift). Default calibration: tiled logits = the
        // plan's 2 fwd+bwd copies; MLP work = plan tile bytes x
        // bwd_factor.
        let mut f = FeatureFlags::alst();
        f.ulysses_sp = false; // t_r == seq, keeps the plan inputs obvious
        let e = est(f);
        let seq = 500_000;
        let b = e.breakdown(seq, 8);
        assert_eq!(b.acts.logits_work, e.logits_plan(seq).tile_bytes);
        assert_eq!(
            b.acts.mlp_work,
            (e.mlp_plan(seq).tile_bytes as f64 * e.cal.bwd_factor) as u64
        );
        // untiled prices from the SAME plan's full-shard bytes
        let eb = est(FeatureFlags::baseline());
        let ub = eb.breakdown(seq, 8);
        assert_eq!(
            ub.acts.logits_work,
            (eb.logits_plan(seq).untiled_bytes as f64
                * eb.cal.untiled_logits_copies
                / 2.0) as u64
        );
        assert_eq!(
            ub.acts.mlp_work,
            (eb.mlp_plan(seq).untiled_bytes as f64 * eb.cal.bwd_factor) as u64
        );
    }

    #[test]
    fn zero3_shrinks_device_states_with_world() {
        let e = est(FeatureFlags::baseline());
        let b8 = e.breakdown(32_768, 8);
        let b32 = e.breakdown(32_768, 32);
        assert!(b32.weights_device < b8.weights_device);
        assert!(b32.grads_device < b8.grads_device);
    }

    #[test]
    fn feature_flags_remove_their_term() {
        let base = est(FeatureFlags::baseline()).breakdown(500_000, 8);
        let mut f = FeatureFlags::baseline();
        f.tiled_loss = true;
        let tl = est(f).breakdown(500_000, 8);
        assert!(tl.acts.logits_work < base.acts.logits_work / 4);
        f.tiled_mlp = true;
        let tm = est(f).breakdown(500_000, 8);
        assert!(tm.acts.mlp_work < tl.acts.mlp_work / 4);
        f.ckpt_offload = true;
        let co = est(f).breakdown(500_000, 8);
        assert_eq!(co.acts.ckpt_device, 0);
    }

    #[test]
    fn ring_plan_lifts_the_sp_head_bound() {
        // llama3-8b has 32 q heads, so Ulysses tops out at sp=32 (§7.1);
        // ring scales to the full world — including worlds that don't
        // divide the head counts.
        let ul = est(FeatureFlags::alst());
        assert_eq!(ul.sp_degree(64), 32);
        let ring = est(FeatureFlags::alst()).with_plan(PlanKind::Ring);
        assert_eq!(ring.sp_degree(64), 64);
        assert_eq!(ring.sp_degree(24), 24, "non-divisor worlds are fine");
        // pricing at a non-divisor world must not panic
        let _ = ring.breakdown(120_000, 24);
    }

    #[test]
    fn ring_attention_working_set_scales_with_shard_not_seq() {
        // At matched sp=8 ring undercuts the a2a send+recv staging; the
        // structural win is that ring keeps dividing by sp past the head
        // bound (64 ranks: ~8x below its own sp=8 set, a regime Ulysses
        // cannot even configure for this model).
        let ul = est(FeatureFlags::alst());
        let ring = est(FeatureFlags::alst()).with_plan(PlanKind::Ring);
        let b_ul = ul.breakdown(1_000_000, 8);
        let b_ring = ring.breakdown(1_000_000, 8);
        assert!(b_ring.acts.attn_work < b_ul.acts.attn_work);
        let b_ring64 = ring.breakdown(1_000_000, 64);
        assert!(b_ring64.acts.attn_work < b_ring.acts.attn_work / 7);
    }

    #[test]
    fn default_plan_pricing_is_unchanged() {
        // Plan-generic refactor must not move the Ulysses numbers.
        let e = est(FeatureFlags::alst());
        assert_eq!(e.plan, PlanKind::Ulysses);
        let explicit = est(FeatureFlags::alst()).with_plan(PlanKind::Ulysses);
        assert_eq!(
            e.breakdown(500_000, 8).device_total(),
            explicit.breakdown(500_000, 8).device_total()
        );
    }

    #[test]
    fn ulysses_divides_per_rank_tokens() {
        let mut f = FeatureFlags::baseline();
        f.tiled_loss = true;
        let no_sp = est(f).breakdown(1_000_000, 8);
        f.ulysses_sp = true;
        let sp = est(f).breakdown(1_000_000, 8);
        assert!(sp.acts.ckpt_device * 7 < no_sp.acts.ckpt_device);
    }
}
