//! Sequence-tiling plans (paper §3.1): shard-count deduction, chunk sizing,
//! and the per-plan peak-memory arithmetic the estimator and Figure-3/4
//! benches consume.

/// TiledMLP shard count (§3.1.1): `ceil(seqlen / hidden_size)`.
/// The paper's example: ceil(256_000 / 4096) = 63.
pub fn mlp_auto_shards(seqlen: usize, hidden: usize) -> usize {
    seqlen.div_ceil(hidden).max(1)
}

/// Rows per MLP tile under the auto-shard rule.
pub fn mlp_tile_rows(seqlen: usize, hidden: usize) -> usize {
    seqlen.div_ceil(mlp_auto_shards(seqlen, hidden))
}

/// Tiled-logits chunk rows: the paper shards logits into ~`chunk_bytes`
/// fp32 pieces (§3.1 uses 1 GiB -> ~8 chunks for 16K x 128256).
pub fn logits_chunk_rows(vocab: usize, chunk_bytes: u64) -> usize {
    ((chunk_bytes / 4) as usize / vocab).max(1)
}

pub fn logits_chunk_count(seqlen: usize, vocab: usize, chunk_bytes: u64) -> usize {
    seqlen.div_ceil(logits_chunk_rows(vocab, chunk_bytes))
}

/// One tiled-compute plan: what runs per tile and what memory it needs.
#[derive(Debug, Clone)]
pub struct TilePlan {
    pub n_tiles: usize,
    pub rows_per_tile: usize,
    /// Peak live bytes for the tile's intermediates.
    pub tile_bytes: u64,
    /// What the untiled computation would have needed.
    pub untiled_bytes: u64,
}

impl TilePlan {
    pub fn saving_factor(&self) -> f64 {
        self.untiled_bytes as f64 / self.tile_bytes.max(1) as f64
    }
}

/// Plan a TiledMLP pass over `[seqlen, hidden]` with SwiGLU width `ffn`.
/// Intermediates per tile: gate + up `[rows, ffn]` + silu product, at
/// `elem_bytes` per element.
pub fn plan_mlp(seqlen: usize, hidden: usize, ffn: usize, elem_bytes: u64) -> TilePlan {
    let n_tiles = mlp_auto_shards(seqlen, hidden);
    let rows = seqlen.div_ceil(n_tiles);
    let per_row = (2 * ffn + hidden) as u64 * elem_bytes;
    TilePlan {
        n_tiles,
        rows_per_tile: rows,
        tile_bytes: rows as u64 * per_row,
        untiled_bytes: seqlen as u64 * per_row,
    }
}

/// Plan a tiled logits+loss pass (fp32, 2 copies fwd+bwd as §3.1 measures).
pub fn plan_logits(seqlen: usize, vocab: usize, chunk_bytes: u64) -> TilePlan {
    let rows = logits_chunk_rows(vocab, chunk_bytes).min(seqlen);
    let n_tiles = seqlen.div_ceil(rows);
    TilePlan {
        n_tiles,
        rows_per_tile: rows,
        tile_bytes: 2 * (rows * vocab) as u64 * 4,
        untiled_bytes: 2 * (seqlen * vocab) as u64 * 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GIB;

    #[test]
    fn paper_3_1_1_auto_shards_63() {
        assert_eq!(mlp_auto_shards(256_000, 4096), 63);
        assert_eq!(mlp_auto_shards(4096, 4096), 1);
        assert_eq!(mlp_auto_shards(1, 4096), 1);
    }

    #[test]
    fn paper_3_1_logits_chunks_about_8_at_16k() {
        // "using a 1GiB shard size divides the computation into about 8
        // chunks" for 16K x 128256 fp32.
        let n = logits_chunk_count(16_000, 128_256, GIB);
        assert!((7..=9).contains(&n), "{n}");
    }

    #[test]
    fn mlp_plan_saves_order_of_magnitude_at_256k() {
        // Figure 4: ~10x memory saved on the 256K x 4096 LlamaMLP example.
        let plan = plan_mlp(256_000, 4096, 14336, 2);
        assert!(plan.saving_factor() > 8.0, "{}", plan.saving_factor());
        assert_eq!(plan.n_tiles, 63);
    }

    #[test]
    fn logits_plan_saving_grows_with_seq() {
        let a = plan_logits(16_000, 128_256, GIB);
        let b = plan_logits(128_000, 128_256, GIB);
        assert!(b.saving_factor() > a.saving_factor());
        // chunk memory itself is seq-independent (the O(1) claim)
        assert_eq!(a.tile_bytes, b.tile_bytes);
    }

    #[test]
    fn tile_plans_cover_all_rows() {
        for seq in [100, 4096, 250_000, 1_000_000] {
            let p = plan_mlp(seq, 4096, 14336, 2);
            assert!(p.n_tiles * p.rows_per_tile >= seq);
        }
    }
}
