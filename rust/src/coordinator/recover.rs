//! The resilient-training supervisor: snapshot cadence, typed fault
//! recovery, and the artifact-free chaos harness that exercises it.
//!
//! The headline ALST runs take minutes per multi-million-token step, so a
//! rank failure must cost one snapshot window, not the run. The pieces:
//!
//! * [`Recoverable`] — what a training loop must expose to be supervised:
//!   one deterministic step keyed by its own step index, snapshot
//!   save/restore, in-flight teardown, and (optionally) re-sharding to a
//!   degraded world.
//! * [`run_resilient`] — the supervisor loop. Snapshots at step 0 and
//!   every `snapshot_every` completed steps; on a step that fails with a
//!   typed [`AlstError`] it tears the in-flight step down, optionally
//!   degrades the world after a lost rank, restores the last snapshot,
//!   and replays. Retryable faults (transient transport, checksum
//!   mismatch) never reach the supervisor — they are absorbed in place by
//!   the per-site retry/backoff gates; what arrives here is a lost rank,
//!   a rank panic, a dead stream worker, or a retryable fault whose retry
//!   budget exhausted.
//! * [`ChaosHarness`] — a small, artifact-free [`Recoverable`] model that
//!   drives every faultable site (collectives via ZeRO gather/reduce and
//!   a real `ParallelPlan` attention, offload copies via the async
//!   engine, per-rank stage gates) with fully deterministic math, so the
//!   recovery contract is testable as *bit-identity*: a faulted-and-
//!   recovered run equals an unfaulted run at every step index.
//!
//! Correctness contract (pinned by the tests here and in
//! `rust/tests/chaos_recovery.rs`): bit-identical parameters at equal
//! step indices, zero leaked host/device ledger bytes after recovery, and
//! steady-state arena pooling across post-recovery steps. Recovery events
//! land on the `Category::Fault` trace lane (`snapshot_save`,
//! `recovery_restore`, plus the gates' `retry_backoff` spans).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::collectives::faults::{
    self, AlstError, FaultInjector, FaultPlan, FaultSite, FaultStats, RetryPolicy,
};
use crate::collectives::transport::{SocketOptions, SocketTransport, TransportKind};
use crate::collectives::Group;
use crate::config::PlanKind;
use crate::coordinator::offload::{AsyncOffloadEngine, OffloadConfig, CKPT_TAG};
use crate::coordinator::optimizer::{AdamW, AdamWConfig};
use crate::coordinator::pipeline::{run_ranks, StepMetrics, Trainer};
use crate::coordinator::plan::{plan_for, AttnShape, ParallelPlan};
use crate::coordinator::snapshot;
use crate::coordinator::zero::ShardedStore;
use crate::memory::{HostPool, MemoryTracker};
use crate::obs::{Category, Tracer};
use crate::runtime::tensor::{HostTensor, ScratchArena};

/// Supervisor policy for [`run_resilient`].
#[derive(Debug, Clone)]
pub struct ResilienceOptions {
    /// Snapshot after every K completed steps (plus one at step 0 so the
    /// first window is covered). 0 keeps only the initial snapshot.
    pub snapshot_every: u64,
    /// Where the rolling snapshot lives (crash-safe: temp file + atomic
    /// rename, CRC-verified on load).
    pub snapshot_path: PathBuf,
    /// Abort the run if more than this many restores are needed — a
    /// deterministic fault that survives recovery would otherwise loop
    /// forever.
    pub max_recoveries: u32,
    /// After a lost rank (or rank panic), ask the target to re-shard to a
    /// degraded world before restoring. Targets that cannot re-shard
    /// (compiled-artifact trainers) return `false` and recover at full
    /// world; the snapshot format is world-agnostic either way.
    pub degrade_on_lost_rank: bool,
    /// Keep this many step-stamped snapshots beside the live file
    /// ([`snapshot::rotate`]), GC'ing older stamps. 0 disables retention
    /// (only the live rolling snapshot exists — the historical behavior).
    pub keep_snapshots: usize,
}

impl ResilienceOptions {
    pub fn new(snapshot_path: impl Into<PathBuf>) -> ResilienceOptions {
        ResilienceOptions {
            snapshot_every: 4,
            snapshot_path: snapshot_path.into(),
            max_recoveries: 2,
            degrade_on_lost_rank: false,
            keep_snapshots: 0,
        }
    }
}

/// What [`run_resilient`] hands back: one metrics row per step index
/// (replayed steps replace the rows the fault rolled back), plus the
/// recovery accounting.
#[derive(Debug)]
pub struct RecoveryReport {
    pub metrics: Vec<StepMetrics>,
    /// Snapshot restores performed.
    pub recoveries: u64,
    /// Whether the run finished at a degraded world.
    pub degraded: bool,
    /// Final injector counters (all-zero without an injector).
    pub fault: FaultStats,
}

/// A training loop the supervisor can drive. `step_once` must be a
/// deterministic function of (state, `step_index`) — that is what makes
/// replay-after-restore bit-identical to a run that never faulted.
pub trait Recoverable {
    /// Run exactly one training step (the step at `step_index`).
    fn step_once(&mut self) -> Result<StepMetrics>;
    /// Completed-step count (== the next step's index).
    fn step_index(&self) -> u64;
    fn save_snapshot(&self, path: &Path) -> Result<()>;
    fn restore_snapshot(&mut self, path: &Path) -> Result<()>;
    /// Tear down whatever the failed step left in flight (offload slots,
    /// copy-stream fault latches, host charges). Must leave the target
    /// reusable; called before every restore.
    fn abort_inflight(&mut self);
    /// Re-shard to a smaller world after a lost rank. Return `false` when
    /// not supported (recovery then proceeds at the same world).
    fn degrade(&mut self) -> Result<bool>;
    fn injector(&self) -> Option<&Arc<FaultInjector>>;
    fn tracer(&self) -> Arc<Tracer>;
}

fn save_snapshot_spanned<R: Recoverable + ?Sized>(
    target: &R,
    tracer: &Tracer,
    opts: &ResilienceOptions,
) -> Result<()> {
    let mut sp = tracer.span(Category::Fault, "snapshot_save");
    sp.set_step(target.step_index());
    let t0 = Instant::now();
    target.save_snapshot(&opts.snapshot_path)?;
    if opts.keep_snapshots > 0 {
        snapshot::rotate(&opts.snapshot_path, target.step_index(), opts.keep_snapshots)?;
    }
    sp.set_dur(t0.elapsed());
    Ok(())
}

/// Supervise `target` until `steps` steps have completed, recovering from
/// typed faults by restoring the last snapshot. Errors that do not
/// downcast to [`AlstError`] propagate unchanged — they are bugs, not
/// chaos, and hiding them behind a restore would mask real breakage.
pub fn run_resilient<R: Recoverable + ?Sized>(
    target: &mut R,
    steps: u64,
    opts: &ResilienceOptions,
) -> Result<RecoveryReport> {
    let tracer = target.tracer();
    let mut metrics: Vec<StepMetrics> = Vec::new();
    let mut recoveries = 0u64;
    let mut degraded = false;
    // Step 0 snapshot: a fault in the very first window must have
    // something to restore.
    save_snapshot_spanned(target, &tracer, opts)?;
    while target.step_index() < steps {
        match target.step_once() {
            Ok(m) => {
                metrics.push(m);
                let done = target.step_index();
                if opts.snapshot_every > 0 && done % opts.snapshot_every == 0 && done < steps
                {
                    save_snapshot_spanned(target, &tracer, opts)?;
                }
            }
            Err(err) => {
                let Some(fault) = err.downcast_ref::<AlstError>().cloned() else {
                    return Err(err);
                };
                anyhow::ensure!(
                    recoveries < opts.max_recoveries as u64,
                    "recovery budget ({}) exhausted; last fault: {fault}",
                    opts.max_recoveries
                );
                recoveries += 1;
                if let Some(inj) = target.injector() {
                    inj.note_recovery();
                    // one-shot plans cannot re-fire, but disarming makes
                    // "the replay runs clean" explicit
                    inj.disarm();
                }
                target.abort_inflight();
                if opts.degrade_on_lost_rank
                    && !degraded
                    && matches!(
                        fault,
                        AlstError::LostRank { .. } | AlstError::RankPanic { .. }
                    )
                {
                    degraded = target.degrade()?;
                }
                {
                    let mut sp = tracer.span(Category::Fault, "recovery_restore");
                    if let Some(r) = fault.rank() {
                        sp.set_rank(r);
                    }
                    let t0 = Instant::now();
                    target.restore_snapshot(&opts.snapshot_path)?;
                    sp.set_dur(t0.elapsed());
                    sp.set_step(target.step_index());
                }
                // Steps past the snapshot are rolled back; drop their rows
                // so the report holds exactly one row per step index.
                let resumed = target.step_index();
                metrics.retain(|m| m.step <= resumed);
            }
        }
    }
    Ok(RecoveryReport {
        metrics,
        recoveries,
        degraded,
        fault: target.injector().map(|i| i.stats()).unwrap_or_default(),
    })
}

// ---------------------------------------------------------------------------
// Trainer adapter
// ---------------------------------------------------------------------------

/// Drives a [`Trainer`] under the supervisor; `data` maps a step index to
/// that step's token sequence, so replayed steps see identical inputs.
struct ResilientTrainer<'a, F> {
    trainer: &'a mut Trainer,
    data: F,
}

impl<F: Fn(u64) -> Vec<i32>> Recoverable for ResilientTrainer<'_, F> {
    fn step_once(&mut self) -> Result<StepMetrics> {
        let ids = (self.data)(self.trainer.step_count());
        self.trainer.train_step(&ids)
    }

    fn step_index(&self) -> u64 {
        self.trainer.step_count()
    }

    fn save_snapshot(&self, path: &Path) -> Result<()> {
        self.trainer.save_snapshot(path)
    }

    fn restore_snapshot(&mut self, path: &Path) -> Result<()> {
        self.trainer.load_snapshot(path)
    }

    fn abort_inflight(&mut self) {
        // The step wrapper already aborts its tape on error; this clears a
        // copy-stream fault latch if one survived (defensive, idempotent).
        if let Some(engine) = self.trainer.offload_engine().cloned() {
            if engine.failed().is_some() {
                engine.abort_step(&mut self.trainer.host);
            }
        }
    }

    fn degrade(&mut self) -> Result<bool> {
        // The compiled stages are sp-specific; a trainer cannot re-shard
        // in place. Recovery proceeds at the same world.
        Ok(false)
    }

    fn injector(&self) -> Option<&Arc<FaultInjector>> {
        self.trainer.injector()
    }

    fn tracer(&self) -> Arc<Tracer> {
        self.trainer.tracer().clone()
    }
}

impl Trainer {
    /// Run `steps` training steps under the resilient supervisor. `data`
    /// maps a step index to its token sequence (replayed steps must see
    /// the same tokens — the bit-identity contract).
    pub fn run_resilient<F: Fn(u64) -> Vec<i32>>(
        &mut self,
        steps: u64,
        data: F,
        opts: &ResilienceOptions,
    ) -> Result<RecoveryReport> {
        run_resilient(&mut ResilientTrainer { trainer: self, data }, steps, opts)
    }
}

// ---------------------------------------------------------------------------
// Chaos harness
// ---------------------------------------------------------------------------

/// Global head count the harness attends with (MHA so q/k/v shapes
/// match); divisible by every world in {1, 2, 4, 8}, so both plans
/// validate at every sweep point and after degrading.
const CHAOS_HEADS: usize = 8;
const CHAOS_HEAD_DIM: usize = 4;

#[derive(Debug, Clone)]
pub struct ChaosConfig {
    pub sp: usize,
    /// Global sequence length (must divide by `sp`, and keep dividing
    /// after each halving if `degrade_on_lost_rank` is on).
    pub seq: usize,
    pub n_layers: usize,
    pub plan: PlanKind,
    /// Run the per-rank stage closures on scoped threads (as the trainer
    /// does) or serially — the accounted totals and the math are
    /// identical either way.
    pub threaded: bool,
    pub trace: bool,
    pub fault_plan: Option<FaultPlan>,
    /// Frame carrier under the harness group: in-process queues (the
    /// default) or spawned rank processes over Unix-domain sockets, where
    /// faults are *real* (SIGKILL, torn frames, stalled heartbeats).
    pub transport: TransportKind,
    /// Socket-mode knobs (worker binary, timeouts, a deterministic
    /// worker-failure plan). Ignored under `TransportKind::Local`.
    pub socket: Option<SocketOptions>,
    /// Deadline for one collective frame roundtrip; `None` keeps the
    /// group default. Chaos tests shrink it so nothing hangs.
    pub op_timeout: Option<Duration>,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            sp: 4,
            seq: 32,
            n_layers: 2,
            plan: PlanKind::Ulysses,
            threaded: true,
            trace: false,
            fault_plan: None,
            transport: TransportKind::Local,
            socket: None,
            op_timeout: None,
        }
    }
}

/// A deterministic, artifact-free model of the resilient step, built from
/// the real subsystems: ZeRO sharded params gathered through a fault-
/// gated [`Group`], per-rank "stage" closures behind the same
/// [`faults::site_gate`] the engine uses, per-layer activations round-
/// tripped through the async offload engine's checksummed copy streams,
/// and attention moved by a real [`ParallelPlan`]. Every fetched byte and
/// every attention gradient folds into the parameter update, so a fault
/// anywhere that corrupted data without being caught would break the
/// bit-identity contract the tests pin.
pub struct ChaosHarness {
    sp: usize,
    seq: usize,
    n_layers: usize,
    shape: AttnShape,
    cu: Vec<i32>,
    plan: Box<dyn ParallelPlan>,
    group: Group,
    arena: Arc<ScratchArena>,
    offload: Arc<AsyncOffloadEngine>,
    device: MemoryTracker,
    host: HostPool,
    params: ShardedStore,
    grads: ShardedStore,
    opt: AdamW,
    step: u64,
    threaded: bool,
    tracer: Arc<Tracer>,
    injector: Option<Arc<FaultInjector>>,
    retry: RetryPolicy,
    /// Live socket transport when `ChaosConfig::transport` is `Socket`
    /// (`None` in local mode). Kept beside the group's `Arc<dyn>` handle
    /// for the concrete ops: `heal`, `kill_rank`, heartbeat accessors.
    socket: Option<Arc<SocketTransport>>,
    /// Socket knobs retained for respawning at a degraded world.
    socket_opts: Option<SocketOptions>,
    op_timeout: Option<Duration>,
    /// Cumulative successful collective ops (the sweep bound for
    /// `tests/chaos_recovery.rs`).
    collective_ops: u64,
}

/// Deterministic value noise (splitmix-style finalizer); no RNG state, so
/// a replayed step reproduces its inputs exactly.
fn mix(step: u64, layer: u64, rank: u64, i: u64) -> f32 {
    let mut s = step
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ layer.wrapping_mul(0xbf58_476d_1ce4_e5b9)
        ^ rank.wrapping_mul(0x94d0_49bb_1331_11eb)
        ^ i.wrapping_add(0x2545_f491_4f6c_dd1d);
    s ^= s >> 33;
    s = s.wrapping_mul(0xff51_afd7_ed55_8ccd);
    s ^= s >> 29;
    ((s >> 40) as f32 / (1u64 << 24) as f32) - 0.5
}

impl ChaosHarness {
    pub fn new(cfg: ChaosConfig) -> Result<ChaosHarness> {
        anyhow::ensure!(cfg.sp >= 1 && cfg.seq % cfg.sp == 0, "seq must divide by sp");
        let shape = AttnShape::new(CHAOS_HEADS, CHAOS_HEADS, CHAOS_HEAD_DIM);
        let plan = plan_for(cfg.plan);
        plan.validate(shape.n_q, shape.n_kv, cfg.sp)?;
        let tracer = if cfg.trace { Arc::new(Tracer::new(true)) } else { Tracer::off() };
        let injector = cfg.fault_plan.map(FaultInjector::new);
        let (mut group, socket, socket_opts) = match cfg.transport {
            TransportKind::Local => (Group::new(cfg.sp), None, None),
            TransportKind::Socket => {
                let opts = cfg.socket.clone().unwrap_or_default();
                let st = SocketTransport::spawn(cfg.sp, opts.clone(), tracer.clone())?;
                (Group::with_transport(cfg.sp, st.clone()), Some(st), Some(opts))
            }
        };
        if let Some(t) = cfg.op_timeout {
            group.set_op_timeout(t);
        }
        group.set_tracer(tracer.clone());
        if let Some(inj) = &injector {
            group.set_injector(inj.clone());
        }
        let arena = Arc::new(ScratchArena::new());
        let offload = Arc::new(AsyncOffloadEngine::new(
            arena.clone(),
            tracer.clone(),
            OffloadConfig::default(),
        ));
        if let Some(inj) = &injector {
            offload.set_injector(inj.clone());
        }
        let total = cfg.seq * shape.n_q * shape.head_dim;
        let flat: Vec<f32> = (0..total).map(|i| mix(0, 0, 0, i as u64) * 0.1).collect();
        let params = ShardedStore::from_flat(&flat, cfg.sp);
        let grads = ShardedStore::zeros(total, cfg.sp);
        let opt = AdamW::new(
            AdamWConfig { lr: 1e-2, ..AdamWConfig::default() },
            total,
            cfg.sp,
        );
        Ok(ChaosHarness {
            sp: cfg.sp,
            seq: cfg.seq,
            n_layers: cfg.n_layers,
            shape,
            cu: vec![0, cfg.seq as i32],
            plan,
            group,
            arena,
            offload,
            device: MemoryTracker::new(1 << 40),
            host: HostPool::new(1 << 40),
            params,
            grads,
            opt,
            step: 0,
            threaded: cfg.threaded,
            tracer,
            injector,
            retry: RetryPolicy::default(),
            socket,
            socket_opts,
            op_timeout: cfg.op_timeout,
            collective_ops: 0,
        })
    }

    /// The live socket transport in socket mode (kill a rank, count
    /// heartbeats); `None` under the local transport.
    pub fn socket_transport(&self) -> Option<&Arc<SocketTransport>> {
        self.socket.as_ref()
    }

    /// The group's frame carrier, whichever kind it is.
    pub fn transport_kind(&self) -> TransportKind {
        self.group.transport_kind()
    }

    pub fn sp(&self) -> usize {
        self.sp
    }

    pub fn params_flat(&self) -> Vec<f32> {
        self.params.to_flat()
    }

    pub fn arena(&self) -> &ScratchArena {
        &self.arena
    }

    pub fn host_bytes(&self) -> u64 {
        self.host.current()
    }

    pub fn device_bytes(&self) -> u64 {
        self.device.current()
    }

    /// Successful collective ops so far (== the injector's attempt count
    /// on an unfaulted run; the fault-site sweep bound).
    pub fn collective_ops(&self) -> u64 {
        self.collective_ops
    }

    pub fn offload_engine(&self) -> &Arc<AsyncOffloadEngine> {
        &self.offload
    }

    /// One deterministic "training step" touching every faultable site.
    fn run_step(&mut self) -> Result<StepMetrics> {
        let t0 = Instant::now();
        self.group.reset_stats();
        self.device.reset_peak();
        let (sp, seq, step) = (self.sp, self.seq, self.step);
        let ssh = seq / sp;
        let (nq, hd) = (self.shape.n_q, self.shape.head_dim);
        let rank_n = ssh * nq * hd;
        let total = self.params.total;

        // ZeRO JIT gather (Collective site).
        let flat = self.params.gather_range(&self.group, 0..total)?;

        let mut loss_ranks = vec![0f32; sp];
        let mut contribs: Vec<Vec<f32>> = vec![vec![0f32; total]; sp];
        for li in 0..self.n_layers {
            // Per-rank qkv "stage" behind the same gate the engine uses
            // (StageExec site, per-rank op counters).
            let (arena, injector, retry, tracer) =
                (&self.arena, &self.injector, &self.retry, &self.tracer);
            let flat_ref = &flat;
            let shape = self.shape;
            let qkv = run_ranks(sp, self.threaded, |r| {
                faults::site_gate(injector, FaultSite::StageExec, r, retry, tracer)?;
                let mut q = arena.take_f32(rank_n);
                let mut k = arena.take_f32(rank_n);
                let mut v = arena.take_f32(rank_n);
                for i in 0..rank_n {
                    let p = flat_ref[r * rank_n + i];
                    let n = mix(step + 1, li as u64, r as u64, i as u64);
                    q[i] = p + 0.1 * n;
                    k[i] = p * (1.0 + 0.05 * n);
                    v[i] = 0.5 * p - 0.02 * n;
                }
                let dims = vec![ssh, shape.n_q, shape.head_dim];
                Ok((
                    HostTensor::f32(dims.clone(), q),
                    HostTensor::f32(dims.clone(), k),
                    HostTensor::f32(dims, v),
                ))
            })?;
            let (mut qs, mut ks, mut vs) =
                (Vec::with_capacity(sp), Vec::with_capacity(sp), Vec::with_capacity(sp));
            for (q, k, v) in qkv {
                qs.push(q);
                ks.push(k);
                vs.push(v);
            }

            // Offload each rank's q as this layer's "checkpoint"
            // (OffloadCopy site, checksummed copy streams).
            for (r, q) in qs.iter().enumerate() {
                let mut buf = self.arena.take_f32(rank_n);
                buf.copy_from_slice(q.as_f32()?);
                let ck = HostTensor::f32(vec![ssh, nq, hd], buf);
                self.offload.store(li, r, ck, &mut self.host)?;
            }

            // Attention through the real plan (Collective sites: a2a under
            // Ulysses, send_recv rotation under ring).
            let (o, saved) = self.plan.attention_forward(
                &self.group,
                &self.arena,
                &qs,
                &ks,
                &vs,
                &self.shape,
                &self.cu,
            )?;
            let (dq, dk, dv) = self.plan.attention_backward(
                &self.group,
                &self.arena,
                &qs,
                &ks,
                &vs,
                &o,
                &saved,
                &self.shape,
                &self.cu,
            )?;
            saved.recycle(&self.arena);

            // Fetch the checkpoints back (OffloadCopy site) and fold
            // everything into the gradient contributions: a corrupted but
            // uncaught payload anywhere breaks bit-identity downstream.
            for r in 0..sp {
                let ck = self.offload.fetch(li, r, &mut self.device, &mut self.host)?;
                let bytes = ck.size_bytes() as u64;
                {
                    let (od, ckd) = (o[r].as_f32()?, ck.as_f32()?);
                    let (dqd, dkd, dvd) =
                        (dq[r].as_f32()?, dk[r].as_f32()?, dv[r].as_f32()?);
                    loss_ranks[r] += od.iter().sum::<f32>() / od.len() as f32;
                    let c = &mut contribs[r];
                    for i in 0..rank_n {
                        c[r * rank_n + i] += dqd[i] + dkd[i] + dvd[i] + 0.01 * ckd[i];
                    }
                }
                self.device.free(bytes, CKPT_TAG);
                self.arena.recycle(ck);
            }
            self.arena.recycle_all(qs);
            self.arena.recycle_all(ks);
            self.arena.recycle_all(vs);
            self.arena.recycle_all(o);
            self.arena.recycle_all(dq);
            self.arena.recycle_all(dk);
            self.arena.recycle_all(dv);
        }

        // Loss all-reduce + gradient reduce-scatter (Collective sites).
        let loss = self.group.all_reduce_scalars(&loss_ranks)? / sp as f32;
        let refs: Vec<&[f32]> = contribs.iter().map(|c| c.as_slice()).collect();
        self.grads.reduce_into_range(&self.group, 0..total, &refs)?;
        let grad_norm = self.opt.step(&mut self.params, &self.grads);
        self.grads.zero_fill();
        self.step += 1;

        let comm = self.group.stats();
        self.collective_ops += comm.ops;
        let fstats = self.injector.as_ref().map(|i| i.stats()).unwrap_or_default();
        Ok(StepMetrics {
            step: self.step,
            loss,
            grad_norm,
            tokens: seq,
            step_time: t0.elapsed(),
            a2a_bytes: comm.all_to_all_bytes,
            send_recv_bytes: comm.send_recv_bytes,
            gather_bytes: comm.all_gather_bytes,
            reduce_scatter_bytes: comm.reduce_scatter_bytes,
            ckpt_transfer_bytes: self.offload.transfer_bytes(),
            device_peak_bytes: self.device.peak(),
            retries: fstats.retries,
            recoveries: fstats.recoveries,
        })
    }
}

impl Recoverable for ChaosHarness {
    fn step_once(&mut self) -> Result<StepMetrics> {
        self.run_step()
    }

    fn step_index(&self) -> u64 {
        self.step
    }

    fn save_snapshot(&self, path: &Path) -> Result<()> {
        snapshot::save(path, self.step, &self.params, &self.opt)
    }

    fn restore_snapshot(&mut self, path: &Path) -> Result<()> {
        // Real faults leave real corpses: respawn dead or tainted rank
        // processes first so the replay sees a full, live world.
        if let Some(st) = &self.socket {
            st.heal()?;
        }
        let snap = snapshot::load(path)?;
        snapshot::restore(&snap, &mut self.params, &mut self.opt)?;
        self.step = snap.step;
        Ok(())
    }

    fn abort_inflight(&mut self) {
        // Drop every slot the failed step left behind, release its host
        // charges, and clear the copy-stream fault latch.
        self.offload.abort_step(&mut self.host);
    }

    fn degrade(&mut self) -> Result<bool> {
        let new_sp = self.sp / 2;
        if new_sp == 0 || self.seq % new_sp != 0 {
            return Ok(false);
        }
        self.plan.validate(self.shape.n_q, self.shape.n_kv, new_sp)?;
        let mut group = match &self.socket_opts {
            None => Group::new(new_sp),
            Some(opts) => {
                // A degraded world needs a fresh worker fleet; the failure
                // plan stays behind — replays must run clean.
                let opts = SocketOptions { failure: None, ..opts.clone() };
                let st = SocketTransport::spawn(new_sp, opts, self.tracer.clone())?;
                // dropping the old handles closes and reaps the old fleet
                self.socket = Some(st.clone());
                Group::with_transport(new_sp, st)
            }
        };
        if let Some(t) = self.op_timeout {
            group.set_op_timeout(t);
        }
        group.set_tracer(self.tracer.clone());
        if let Some(inj) = &self.injector {
            group.set_injector(inj.clone());
        }
        self.group = group;
        self.sp = new_sp;
        // Re-shard in place; the snapshot restore that follows overwrites
        // values, but the stores must already be at the new world.
        let total = self.params.total;
        self.params = ShardedStore::from_flat(&self.params.to_flat(), new_sp);
        self.grads = ShardedStore::zeros(total, new_sp);
        let mut opt = AdamW::new(self.opt.cfg, total, new_sp);
        opt.step = self.opt.step;
        opt.m = ShardedStore::from_flat(&self.opt.m.to_flat(), new_sp);
        opt.v = ShardedStore::from_flat(&self.opt.v.to_flat(), new_sp);
        self.opt = opt;
        Ok(true)
    }

    fn injector(&self) -> Option<&Arc<FaultInjector>> {
        self.injector.as_ref()
    }

    fn tracer(&self) -> Arc<Tracer> {
        self.tracer.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::faults::FaultKind;

    fn tmpsnap(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("alst-recover-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn cfg(plan: PlanKind, threaded: bool, fault: Option<FaultPlan>) -> ChaosConfig {
        ChaosConfig { plan, threaded, fault_plan: fault, ..ChaosConfig::default() }
    }

    /// Unfaulted reference: params after each of `steps` steps.
    fn reference(plan: PlanKind, steps: u64) -> (Vec<f32>, Vec<f32>) {
        let mut h = ChaosHarness::new(cfg(plan, true, None)).unwrap();
        let mut losses = Vec::new();
        for _ in 0..steps {
            losses.push(h.run_step().unwrap().loss);
        }
        (h.params_flat(), losses)
    }

    #[test]
    fn unfaulted_run_is_deterministic_across_thread_modes() {
        for plan in [PlanKind::Ulysses, PlanKind::Ring] {
            let mut a = ChaosHarness::new(cfg(plan, true, None)).unwrap();
            let mut b = ChaosHarness::new(cfg(plan, false, None)).unwrap();
            for _ in 0..2 {
                let (ma, mb) = (a.run_step().unwrap(), b.run_step().unwrap());
                assert_eq!(ma.loss.to_bits(), mb.loss.to_bits(), "{plan:?}");
                assert_eq!(ma.gather_bytes, mb.gather_bytes);
                assert_eq!(ma.a2a_bytes, mb.a2a_bytes);
                assert_eq!(ma.send_recv_bytes, mb.send_recv_bytes);
            }
            assert_eq!(a.params_flat(), b.params_flat(), "{plan:?}");
            assert_eq!(a.host_bytes(), 0);
            assert_eq!(a.device_bytes(), 0);
        }
    }

    #[test]
    fn transient_collective_fault_is_absorbed_without_recovery() {
        let (want, _) = reference(PlanKind::Ulysses, 3);
        let fault = FaultPlan {
            site: FaultSite::Collective,
            kind: FaultKind::Transient,
            rank: 0,
            at_op: 3,
            seed: 11,
        };
        let mut h =
            ChaosHarness::new(cfg(PlanKind::Ulysses, true, Some(fault))).unwrap();
        let opts = ResilienceOptions::new(tmpsnap("transient.alst"));
        let report = run_resilient(&mut h, 3, &opts).unwrap();
        assert_eq!(report.recoveries, 0, "transients never reach the supervisor");
        assert_eq!(report.fault.injected, 1);
        assert!(report.fault.retries >= 1);
        assert_eq!(report.metrics.len(), 3);
        assert_eq!(h.params_flat(), want, "retried run is bit-identical");
        assert_eq!(h.host_bytes(), 0);
        assert_eq!(h.device_bytes(), 0);
    }

    #[test]
    fn lost_rank_recovers_from_snapshot_bit_identically() {
        let (want, ref_losses) = reference(PlanKind::Ulysses, 4);
        // n_layers stage gates per rank per step: index 2*n_layers is the
        // third step's first gate on rank 1.
        let n_layers = ChaosConfig::default().n_layers as u64;
        let fault = FaultPlan {
            site: FaultSite::StageExec,
            kind: FaultKind::LostRank,
            rank: 1,
            at_op: 2 * n_layers,
            seed: 5,
        };
        let mut h =
            ChaosHarness::new(cfg(PlanKind::Ulysses, true, Some(fault))).unwrap();
        let opts = ResilienceOptions {
            snapshot_every: 2,
            ..ResilienceOptions::new(tmpsnap("lostrank.alst"))
        };
        let report = run_resilient(&mut h, 4, &opts).unwrap();
        assert_eq!(report.recoveries, 1);
        assert_eq!(report.fault.injected, 1);
        assert!(!report.degraded);
        // one row per step index, losses matching the unfaulted run
        let steps: Vec<u64> = report.metrics.iter().map(|m| m.step).collect();
        assert_eq!(steps, vec![1, 2, 3, 4]);
        for (m, want_loss) in report.metrics.iter().zip(&ref_losses) {
            assert_eq!(m.loss.to_bits(), want_loss.to_bits());
        }
        assert_eq!(h.params_flat(), want, "recovered run is bit-identical");
        assert_eq!(h.host_bytes(), 0, "host ledger balances after recovery");
        assert_eq!(h.device_bytes(), 0, "device ledger balances after recovery");
    }

    #[test]
    fn recovery_reaches_arena_steady_state() {
        let fault = FaultPlan {
            site: FaultSite::Collective,
            kind: FaultKind::LostRank,
            rank: 0,
            at_op: 6,
            seed: 3,
        };
        let mut h = ChaosHarness::new(cfg(PlanKind::Ring, true, Some(fault))).unwrap();
        let opts = ResilienceOptions {
            snapshot_every: 1,
            ..ResilienceOptions::new(tmpsnap("steady.alst"))
        };
        let report = run_resilient(&mut h, 3, &opts).unwrap();
        assert_eq!(report.recoveries, 1);
        // post-recovery steps take/recycle in balance: the pool footprint
        // stops changing between consecutive steps
        h.run_step().unwrap();
        let after_one = (h.arena().pooled(), h.arena().pooled_bytes());
        h.run_step().unwrap();
        let after_two = (h.arena().pooled(), h.arena().pooled_bytes());
        assert_eq!(after_one, after_two, "no leaked or hoarded arena buffers");
        assert_eq!(h.host_bytes(), 0);
        assert_eq!(h.device_bytes(), 0);
    }

    #[test]
    fn degraded_recovery_reshards_and_matches_degraded_reference() {
        // Reference: unfaulted sp=4 run to the snapshot point (step 2),
        // then a fresh sp=2 harness restored from that snapshot runs the
        // remaining steps — exactly what the degraded recovery replays.
        let snap = tmpsnap("degrade-ref.alst");
        let mut a = ChaosHarness::new(cfg(PlanKind::Ulysses, true, None)).unwrap();
        a.run_step().unwrap();
        a.run_step().unwrap();
        a.save_snapshot(&snap).unwrap();
        let mut b = ChaosHarness::new(ChaosConfig {
            sp: 2,
            ..cfg(PlanKind::Ulysses, true, None)
        })
        .unwrap();
        b.restore_snapshot(&snap).unwrap();
        b.run_step().unwrap();
        b.run_step().unwrap();

        let n_layers = ChaosConfig::default().n_layers as u64;
        let fault = FaultPlan {
            site: FaultSite::StageExec,
            kind: FaultKind::LostRank,
            rank: 3,
            at_op: 2 * n_layers,
            seed: 9,
        };
        let mut h =
            ChaosHarness::new(cfg(PlanKind::Ulysses, true, Some(fault))).unwrap();
        let opts = ResilienceOptions {
            snapshot_every: 2,
            degrade_on_lost_rank: true,
            ..ResilienceOptions::new(tmpsnap("degrade.alst"))
        };
        let report = run_resilient(&mut h, 4, &opts).unwrap();
        assert_eq!(report.recoveries, 1);
        assert!(report.degraded);
        assert_eq!(h.sp(), 2, "world degraded 4 -> 2");
        assert_eq!(
            h.params_flat(),
            b.params_flat(),
            "degraded continuation is bit-identical to the degraded reference"
        );
        assert_eq!(h.host_bytes(), 0);
        assert_eq!(h.device_bytes(), 0);
    }

    #[test]
    fn resilient_run_retains_stamped_snapshots() {
        let dir = std::env::temp_dir().join("alst-recover-retention");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ret.alst");
        let mut h = ChaosHarness::new(cfg(PlanKind::Ulysses, false, None)).unwrap();
        let opts = ResilienceOptions {
            snapshot_every: 1,
            keep_snapshots: 2,
            ..ResilienceOptions::new(path.clone())
        };
        let report = run_resilient(&mut h, 4, &opts).unwrap();
        assert_eq!(report.recoveries, 0);
        // snapshots at steps 0, 1, 2, 3 — retention keeps the newest two
        let stamps: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".step"))
            .collect();
        assert_eq!(stamps.len(), 2, "older stamps GC'd: {stamps:?}");
        assert!(stamps.contains(&"ret.alst.step2".to_string()), "{stamps:?}");
        assert!(stamps.contains(&"ret.alst.step3".to_string()), "{stamps:?}");
        let stamp = snapshot::load(&dir.join("ret.alst.step3")).unwrap();
        assert_eq!(stamp.step, 3, "stamps are complete loadable snapshots");
    }

    #[test]
    fn socket_transport_harness_matches_local_bit_identically() {
        let mut a = ChaosHarness::new(cfg(PlanKind::Ulysses, false, None)).unwrap();
        let mut b = ChaosHarness::new(ChaosConfig {
            transport: TransportKind::Socket,
            socket: Some(SocketOptions { in_thread: true, ..Default::default() }),
            op_timeout: Some(Duration::from_secs(5)),
            ..cfg(PlanKind::Ulysses, false, None)
        })
        .unwrap();
        assert_eq!(b.transport_kind(), TransportKind::Socket);
        for _ in 0..2 {
            let (ma, mb) = (a.run_step().unwrap(), b.run_step().unwrap());
            assert_eq!(ma.loss.to_bits(), mb.loss.to_bits(), "loss crosses the wire bit-exact");
            assert_eq!(ma.gather_bytes, mb.gather_bytes);
            assert_eq!(ma.a2a_bytes, mb.a2a_bytes);
            assert_eq!(ma.reduce_scatter_bytes, mb.reduce_scatter_bytes);
        }
        assert_eq!(a.params_flat(), b.params_flat(), "transport changes nothing");
        assert_eq!(b.host_bytes(), 0);
        assert_eq!(b.device_bytes(), 0);
    }

    #[test]
    fn non_fault_errors_propagate_unrecovered() {
        struct Broken(Arc<Tracer>);
        impl Recoverable for Broken {
            fn step_once(&mut self) -> Result<StepMetrics> {
                anyhow::bail!("logic bug, not chaos")
            }
            fn step_index(&self) -> u64 {
                0
            }
            fn save_snapshot(&self, _: &Path) -> Result<()> {
                Ok(())
            }
            fn restore_snapshot(&mut self, _: &Path) -> Result<()> {
                Ok(())
            }
            fn abort_inflight(&mut self) {}
            fn degrade(&mut self) -> Result<bool> {
                Ok(false)
            }
            fn injector(&self) -> Option<&Arc<FaultInjector>> {
                None
            }
            fn tracer(&self) -> Arc<Tracer> {
                self.0.clone()
            }
        }
        let mut b = Broken(Tracer::off());
        let err = run_resilient(&mut b, 1, &ResilienceOptions::new(tmpsnap("bug.alst")))
            .unwrap_err();
        assert!(err.to_string().contains("logic bug"));
    }

    #[test]
    fn recovery_budget_bounds_restore_loops() {
        // A fresh injector per attempt would re-fire forever; here the
        // one-shot plan fires once, but a zero budget must still refuse
        // the first restore.
        let fault = FaultPlan {
            site: FaultSite::Collective,
            kind: FaultKind::LostRank,
            rank: 0,
            at_op: 0,
            seed: 1,
        };
        let mut h =
            ChaosHarness::new(cfg(PlanKind::Ulysses, false, Some(fault))).unwrap();
        let opts = ResilienceOptions {
            max_recoveries: 0,
            ..ResilienceOptions::new(tmpsnap("budget.alst"))
        };
        let err = run_resilient(&mut h, 2, &opts).unwrap_err();
        assert!(err.to_string().contains("recovery budget"));
    }
}
