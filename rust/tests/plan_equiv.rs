//! Plan-generic attention equivalence suite.
//!
//! Both `ParallelPlan` implementations (Ulysses all-to-all, Blockwise
//! RingAttention) must produce the dense reference's forward output and
//! gradients under the summation-order contract documented in
//! `coordinator::plan`:
//!
//! * Ulysses forward is **bit-identical** to the reference for every
//!   valid (sp, heads) regime — the relayouts are pure copies and each
//!   head's fold is the same single-block arithmetic.
//! * Ring at `sp == 1` is bit-identical (one full-range block IS the
//!   reference); at `sp > 1` cross-block `(m, l, acc)` merges round
//!   differently, so parity is tolerance-based.
//! * Backward `dk`/`dv` are bit-identical for Ulysses only without kv
//!   replication (`n_kv >= sp`); replication reorders the per-head
//!   accumulation across ranks, so GQA backward parity is tolerance-based
//!   everywhere.
//!
//! Also pinned here: ring configs Ulysses cannot run (`sp > n_heads`,
//! ragged shards, single-token shards), packed `cu_seqlens` flows
//! including a document spanning every rank's shard, the plan-level
//! ledger/closed-form agreement, and measured overlap accounting.

use alst::collectives::Group;
use alst::config::PlanKind;
use alst::coordinator::plan::{
    dense_attention, dense_attention_bwd, plan_for, AttnShape, ParallelPlan, PlanSaved,
};
use alst::coordinator::ring::RingPlan;
use alst::coordinator::ulysses::UlyssesPlan;
use alst::runtime::{HostTensor, ScratchArena};

/// Deterministic pseudo-random fill (tests must not use RNG state).
fn fill(t: &mut [f32], seed: u64) {
    let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
    for x in t.iter_mut() {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *x = ((s >> 33) as f32 / (1u64 << 31) as f32) - 0.5;
    }
}

fn rand_t(shape: Vec<usize>, seed: u64) -> HostTensor {
    let n: usize = shape.iter().product();
    let mut d = vec![0.0f32; n];
    fill(&mut d, seed);
    HostTensor::f32(shape, d)
}

/// Row-split a `[seq, h, d]` tensor into per-rank seq shards.
fn shard(full: &HostTensor, rows: &[usize]) -> Vec<HostTensor> {
    let dims = full.shape();
    let (h, d) = (dims[1], dims[2]);
    let data = full.as_f32().unwrap();
    let mut out = Vec::with_capacity(rows.len());
    let mut base = 0usize;
    for &r in rows {
        out.push(HostTensor::f32(
            vec![r, h, d],
            data[base * h * d..(base + r) * h * d].to_vec(),
        ));
        base += r;
    }
    assert_eq!(base, dims[0], "shard rows must cover the sequence");
    out
}

/// Concatenate per-rank seq shards back into one `[seq, h, d]` tensor.
fn gather(shards: &[HostTensor]) -> HostTensor {
    let dims = shards[0].shape();
    let (h, d) = (dims[1], dims[2]);
    let mut data = Vec::new();
    let mut seq = 0usize;
    for s in shards {
        assert_eq!(&s.shape()[1..], &[h, d]);
        seq += s.shape()[0];
        data.extend_from_slice(s.as_f32().unwrap());
    }
    HostTensor::f32(vec![seq, h, d], data)
}

fn equal_rows(seq: usize, sp: usize) -> Vec<usize> {
    assert_eq!(seq % sp, 0);
    vec![seq / sp; sp]
}

fn assert_bit_identical(a: &HostTensor, b: &HostTensor, ctx: &str) {
    assert_eq!(a.shape(), b.shape(), "{ctx}: shape");
    for (i, (x, y)) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: elem {i}: {x} vs {y}");
    }
}

fn assert_close(a: &HostTensor, b: &HostTensor, tol: f32, ctx: &str) {
    assert_eq!(a.shape(), b.shape(), "{ctx}: shape");
    for (i, (x, y)) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()).enumerate() {
        let bound = tol * (1.0 + x.abs().max(y.abs()));
        assert!(
            (x - y).abs() <= bound,
            "{ctx}: elem {i}: {x} vs {y} (tol {bound})"
        );
    }
}

/// The quadratic readout both loss-parity tests use: `sum(o * w)` has
/// `d_o = w`, so one weight tensor exercises forward AND backward parity.
fn readout(o: &HostTensor, w: &HostTensor) -> f64 {
    o.as_f32()
        .unwrap()
        .iter()
        .zip(w.as_f32().unwrap())
        .map(|(a, b)| (*a as f64) * (*b as f64))
        .sum()
}

struct Problem {
    q: HostTensor,
    k: HostTensor,
    v: HostTensor,
    w: HostTensor,
    shape: AttnShape,
}

fn problem(seq: usize, n_q: usize, n_kv: usize, d: usize, seed: u64) -> Problem {
    Problem {
        q: rand_t(vec![seq, n_q, d], seed),
        k: rand_t(vec![seq, n_kv, d], seed + 1),
        v: rand_t(vec![seq, n_kv, d], seed + 2),
        w: rand_t(vec![seq, n_q, d], seed + 3),
        shape: AttnShape::new(n_q, n_kv, d),
    }
}

/// Run one plan end to end on row-sharded inputs; returns the gathered
/// forward output and gradients.
#[allow(clippy::type_complexity)]
fn run_plan(
    plan: &dyn ParallelPlan,
    p: &Problem,
    rows: &[usize],
    cu: &[i32],
) -> (HostTensor, HostTensor, HostTensor, HostTensor) {
    let g = Group::new(rows.len());
    let arena = ScratchArena::new();
    let qs = shard(&p.q, rows);
    let ks = shard(&p.k, rows);
    let vs = shard(&p.v, rows);
    let dos = shard(&p.w, rows);
    let (o, saved) = plan
        .attention_forward(&g, &arena, &qs, &ks, &vs, &p.shape, cu)
        .expect("plan forward");
    let (dq, dk, dv) = plan
        .attention_backward(&g, &arena, &qs, &ks, &vs, &dos, &saved, &p.shape, cu)
        .expect("plan backward");
    let out = (gather(&o), gather(&dq), gather(&dk), gather(&dv));
    saved.recycle(&arena);
    out
}

#[allow(clippy::type_complexity)]
fn run_dense(p: &Problem, cu: &[i32]) -> (HostTensor, HostTensor, HostTensor, HostTensor) {
    let arena = ScratchArena::new();
    let (o, lse) = dense_attention(&p.q, &p.k, &p.v, &p.shape, cu, &arena).unwrap();
    let (dq, dk, dv) =
        dense_attention_bwd(&p.q, &p.k, &p.v, &o, &lse, &p.w, &p.shape, cu, &arena).unwrap();
    (o, dq, dk, dv)
}

// ---------------------------------------------------------------------------
// Dense-reference parity across sp and head regimes
// ---------------------------------------------------------------------------

#[test]
fn both_plans_match_the_dense_reference_across_sp_and_heads() {
    let seq = 16usize;
    let d = 4usize;
    for sp in [1usize, 2, 4, 8] {
        for (n_q, n_kv) in [(8usize, 8usize), (8, 4), (8, 2), (4, 1)] {
            let p = problem(seq, n_q, n_kv, d, 1000 + (sp * 10 + n_kv) as u64);
            let cu = [0, seq as i32];
            let rows = equal_rows(seq, sp);
            let (o_ref, dq_ref, dk_ref, dv_ref) = run_dense(&p, &cu);

            let ring = plan_for(PlanKind::Ring);
            let (o, dq, dk, dv) = run_plan(ring.as_ref(), &p, &rows, &cu);
            let ctx = format!("ring sp={sp} n_q={n_q} n_kv={n_kv}");
            if sp == 1 {
                // single block == the reference, by construction
                assert_bit_identical(&o, &o_ref, &ctx);
            } else {
                assert_close(&o, &o_ref, 5e-5, &ctx);
            }
            assert_close(&dq, &dq_ref, 2e-4, &ctx);
            assert_close(&dk, &dk_ref, 2e-4, &ctx);
            assert_close(&dv, &dv_ref, 2e-4, &ctx);

            if UlyssesPlan.validate(n_q, n_kv, sp).is_ok() {
                let ul = plan_for(PlanKind::Ulysses);
                let (o, dq, dk, dv) = run_plan(ul.as_ref(), &p, &rows, &cu);
                let ctx = format!("ulysses sp={sp} n_q={n_q} n_kv={n_kv}");
                // per-head arithmetic is the reference's: bitwise forward
                assert_bit_identical(&o, &o_ref, &ctx);
                assert_bit_identical(&dq, &dq_ref, &ctx);
                if n_kv >= sp {
                    // no kv replication: one rank owns each kv head, same
                    // accumulation order as the reference
                    assert_bit_identical(&dk, &dk_ref, &ctx);
                    assert_bit_identical(&dv, &dv_ref, &ctx);
                } else {
                    // replica-summed kv grads reorder the per-head adds
                    assert_close(&dk, &dk_ref, 2e-4, &ctx);
                    assert_close(&dv, &dv_ref, 2e-4, &ctx);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Packed cu_seqlens, including a document spanning every rank's shard
// ---------------------------------------------------------------------------

#[test]
fn packed_segments_match_dense_including_rank_spanning_docs() {
    let (seq, n_q, n_kv, d, sp) = (8usize, 4usize, 2usize, 3usize, 4usize);
    let rows = equal_rows(seq, sp);
    // [0,1,8]: document 1 covers rows 1..8 — every rank's shard overlaps
    // it, so every rotation hop carries cross-rank same-segment keys
    for cu in [vec![0i32, 1, 8], vec![0, 2, 4, 6, 8], vec![0, 8]] {
        let p = problem(seq, n_q, n_kv, d, 7 + cu.len() as u64);
        let (o_ref, dq_ref, dk_ref, dv_ref) = run_dense(&p, &cu);
        let ring = plan_for(PlanKind::Ring);
        let (o, dq, dk, dv) = run_plan(ring.as_ref(), &p, &rows, &cu);
        let ctx = format!("ring packed cu={cu:?}");
        assert_close(&o, &o_ref, 5e-5, &ctx);
        assert_close(&dq, &dq_ref, 2e-4, &ctx);
        assert_close(&dk, &dk_ref, 2e-4, &ctx);
        assert_close(&dv, &dv_ref, 2e-4, &ctx);

        let ul = plan_for(PlanKind::Ulysses);
        let (o_u, dq_u, dk_u, dv_u) = run_plan(ul.as_ref(), &p, &rows, &cu);
        let ctx = format!("ulysses packed cu={cu:?}");
        assert_bit_identical(&o_u, &o_ref, &ctx);
        assert_close(&dq_u, &dq_ref, 2e-4, &ctx);
        assert_close(&dk_u, &dk_ref, 2e-4, &ctx);
        assert_close(&dv_u, &dv_ref, 2e-4, &ctx);

        // loss parity between the plans under the quadratic readout
        let lr = readout(&o, &p.w);
        let lu = readout(&o_u, &p.w);
        assert!(
            (lr - lu).abs() <= 1e-5 * (1.0 + lu.abs()),
            "loss parity cu={cu:?}: ring {lr} vs ulysses {lu}"
        );
    }
}

// ---------------------------------------------------------------------------
// Ring-only regimes: ragged shards, single-token shards, sp > n_heads
// ---------------------------------------------------------------------------

#[test]
fn ring_handles_ragged_and_single_token_shards() {
    // ragged: [3, 3, 2, 2] rows (Ulysses' relayout requires equal shards)
    let (n_q, n_kv, d) = (4usize, 2usize, 3usize);
    for cu in [vec![0i32, 10], vec![0, 4, 10]] {
        let p = problem(10, n_q, n_kv, d, 31 + cu.len() as u64);
        let (o_ref, dq_ref, dk_ref, dv_ref) = run_dense(&p, &cu);
        let ring = plan_for(PlanKind::Ring);
        let (o, dq, dk, dv) = run_plan(ring.as_ref(), &p, &[3, 3, 2, 2], &cu);
        let ctx = format!("ring ragged cu={cu:?}");
        assert_close(&o, &o_ref, 5e-5, &ctx);
        assert_close(&dq, &dq_ref, 2e-4, &ctx);
        assert_close(&dk, &dk_ref, 2e-4, &ctx);
        assert_close(&dv, &dv_ref, 2e-4, &ctx);
    }

    // seq == sp: every shard is a single token (one row per block)
    let p = problem(4, 2, 1, 4, 53);
    let cu = [0, 4];
    let (o_ref, dq_ref, dk_ref, dv_ref) = run_dense(&p, &cu);
    let ring = plan_for(PlanKind::Ring);
    let (o, dq, dk, dv) = run_plan(ring.as_ref(), &p, &[1, 1, 1, 1], &cu);
    assert_close(&o, &o_ref, 5e-5, "single-token shards");
    assert_close(&dq, &dq_ref, 2e-4, "single-token shards dq");
    assert_close(&dk, &dk_ref, 2e-4, "single-token shards dk");
    assert_close(&dv, &dv_ref, 2e-4, "single-token shards dv");
}

#[test]
fn sp_beyond_the_head_bound_runs_on_ring_and_errors_actionably_on_ulysses() {
    // sp=8 over 4 query heads: Ulysses cannot express this (a head can't
    // split across ranks); ring runs it end to end — the bound the plan
    // trait was introduced to lift
    let (seq, sp) = (16usize, 8usize);
    for (n_q, n_kv) in [(4usize, 4usize), (4, 1)] {
        let p = problem(seq, n_q, n_kv, 4, 71 + n_kv as u64);
        let cu = [0, seq as i32];
        let rows = equal_rows(seq, sp);
        let (o_ref, dq_ref, dk_ref, dv_ref) = run_dense(&p, &cu);
        let ring = plan_for(PlanKind::Ring);
        assert!(ring.validate(n_q, n_kv, sp).is_ok());
        let (o, dq, dk, dv) = run_plan(ring.as_ref(), &p, &rows, &cu);
        let ctx = format!("ring sp=8 n_q={n_q} n_kv={n_kv}");
        assert_close(&o, &o_ref, 5e-5, &ctx);
        assert_close(&dq, &dq_ref, 2e-4, &ctx);
        assert_close(&dk, &dk_ref, 2e-4, &ctx);
        assert_close(&dv, &dv_ref, 2e-4, &ctx);

        let err = UlyssesPlan.validate(n_q, n_kv, sp).unwrap_err().to_string();
        assert!(
            err.contains("ring"),
            "ulysses rejection must point at the ring plan: {err}"
        );
    }
}

// ---------------------------------------------------------------------------
// Ledger, overlap accounting, and arena stability at the suite level
// ---------------------------------------------------------------------------

#[test]
fn ring_ledger_matches_the_closed_form_and_overlap_is_measured() {
    let (seq, n_q, n_kv, d, sp) = (16usize, 4usize, 2usize, 4usize, 4usize);
    let p = problem(seq, n_q, n_kv, d, 91);
    let cu = [0, seq as i32];
    let rows = equal_rows(seq, sp);
    let shape = p.shape;

    for overlap in [true, false] {
        let plan = RingPlan::new(overlap);
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        let qs = shard(&p.q, &rows);
        let ks = shard(&p.k, &rows);
        let vs = shard(&p.v, &rows);
        let dos = shard(&p.w, &rows);
        let (o, saved) = plan
            .attention_forward(&g, &arena, &qs, &ks, &vs, &shape, &cu)
            .unwrap();
        let (dq, dk, dv) = plan
            .attention_backward(&g, &arena, &qs, &ks, &vs, &dos, &saved, &shape, &cu)
            .unwrap();
        let want = plan.comm_bytes_per_layer(seq, &shape, sp, 4);
        assert_eq!(
            g.stats().send_recv_bytes,
            want,
            "wire ledger vs closed form (overlap={overlap})"
        );
        assert_eq!(g.stats().all_to_all_bytes, 0, "ring never uses the a2a wire");
        let st = plan.stats();
        assert!(st.hops > 0 && st.copy_ns > 0);
        let frac = st.overlap_frac();
        if overlap {
            assert!((0.0..=1.0).contains(&frac), "overlap_frac {frac}");
        } else {
            // inline baseline: the whole copy is stall, by construction
            assert_eq!(st.copy_ns, st.stall_ns);
            assert_eq!(frac, 0.0);
        }
        saved.recycle(&arena);
        for t in [o, dq, dk, dv] {
            arena.recycle_all(t);
        }
    }
}

#[test]
fn repeated_ring_cycles_reuse_the_arena_pool() {
    // After the first forward/backward populates the pool, later cycles
    // must not grow it: the rotation's receive buffers and running-state
    // scratch all ping-pong through the arena.
    let (seq, n_q, n_kv, d, sp) = (16usize, 4usize, 2usize, 4usize, 4usize);
    let p = problem(seq, n_q, n_kv, d, 113);
    let cu = [0, seq as i32];
    let rows = equal_rows(seq, sp);
    let plan = plan_for(PlanKind::Ring);
    let g = Group::new(sp);
    let arena = ScratchArena::new();
    let qs = shard(&p.q, &rows);
    let ks = shard(&p.k, &rows);
    let vs = shard(&p.v, &rows);
    let dos = shard(&p.w, &rows);
    let mut misses = Vec::new();
    for _ in 0..3 {
        let (o, saved) = plan
            .attention_forward(&g, &arena, &qs, &ks, &vs, &p.shape, &cu)
            .unwrap();
        let (dq, dk, dv) = plan
            .attention_backward(&g, &arena, &qs, &ks, &vs, &dos, &saved, &p.shape, &cu)
            .unwrap();
        saved.recycle(&arena);
        for t in [o, dq, dk, dv] {
            arena.recycle_all(t);
        }
        misses.push(arena.misses());
    }
    assert!(misses[0] > 0, "first cycle must populate the pool");
    assert_eq!(misses[1], misses[2], "cycle 3 allocated: pool not at steady state");
}

// ---------------------------------------------------------------------------
// The saved-state contract
// ---------------------------------------------------------------------------

#[test]
fn ring_saved_state_carries_forward_output_and_lse() {
    let (seq, sp) = (8usize, 2usize);
    let p = problem(seq, 2, 2, 4, 131);
    let cu = [0, seq as i32];
    let rows = equal_rows(seq, sp);
    let plan = plan_for(PlanKind::Ring);
    let g = Group::new(sp);
    let arena = ScratchArena::new();
    let qs = shard(&p.q, &rows);
    let ks = shard(&p.k, &rows);
    let vs = shard(&p.v, &rows);
    let (o, saved) = plan
        .attention_forward(&g, &arena, &qs, &ks, &vs, &p.shape, &cu)
        .unwrap();
    match &saved {
        PlanSaved::Ring { o: so, lse } => {
            // the saved output is the forward output (backward rebuilds
            // softmax probabilities from it + lse without a re-forward)
            for (r, (a, b)) in o.iter().zip(so).enumerate() {
                assert_bit_identical(a, b, &format!("saved o rank {r}"));
            }
            assert_eq!(lse.len(), sp);
            for (r, t) in lse.iter().enumerate() {
                assert_eq!(t.shape(), &[rows[r], p.shape.n_q], "lse shape rank {r}");
                assert!(t.as_f32().unwrap().iter().all(|x| x.is_finite()));
            }
        }
        PlanSaved::Ulysses => panic!("ring must save Ring state"),
    }
    saved.recycle(&arena);
    arena.recycle_all(o);
}
