//! `alst` — the launcher CLI.
//!
//! Subcommands:
//!   train     — run real training through the PJRT pipeline
//!               (--artifacts DIR --config tiny --sp 2 --seq 256 --steps N)
//!   search    — simulator max-seqlen search per (model, GPUs, features)
//!   ablate    — Table 1 feature-ablation ladder
//!   estimate  — memory breakdown for a (model, seq, world)
//!   tables    — regenerate every paper table/figure dataset to CSV

use anyhow::{Context, Result};

use alst::config::{preset, ClusterConfig, FeatureFlags, GIB};
use alst::coordinator::dataloader::{MarkovSource, UlyssesDataLoader};
use alst::coordinator::pipeline::{Trainer, TrainerOptions};
use alst::memory::{max_seqlen_search, Estimator};
use alst::metrics::RunLog;
use alst::perf::{iteration_time, IterationModel};
use alst::util::bench::{fmt_duration_hms, fmt_seqlen};
use alst::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("train") => cmd_train(&args),
        Some("search") => cmd_search(&args),
        Some("ablate") => cmd_ablate(&args),
        Some("estimate") => cmd_estimate(&args),
        Some("tables") => cmd_tables(&args),
        Some("validate") => cmd_validate(&args),
        _ => {
            eprintln!(
                "usage: alst <train|search|ablate|estimate|tables|validate> [--key value ...]"
            );
            std::process::exit(2);
        }
    }
}

fn flags_from_args(args: &Args) -> FeatureFlags {
    let mut f = if args.flag("baseline") {
        FeatureFlags::baseline()
    } else {
        FeatureFlags::alst()
    };
    if args.flag("weights-offload") {
        f.weights_offload = true;
    }
    if args.flag("no-offload") {
        f.ckpt_offload = false;
    }
    if args.flag("no-tiled-mlp") {
        f.tiled_mlp = false;
    }
    f
}

fn cmd_train(args: &Args) -> Result<()> {
    let root = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let config = args.get_or("config", "tiny");
    let sp = args.usize("sp", 2);
    let seq = args.usize("seq", 256);
    let steps = args.usize("steps", 20);
    let seed = args.usize("seed", 0) as u64;
    let dir = alst::runtime::Manifest::artifact_dir(&root, &config, sp, seq);
    println!("loading artifacts from {}", dir.display());

    let mut opts = TrainerOptions {
        flags: flags_from_args(args),
        seed,
        checked: args.flag("checked"),
        // tiled EXECUTION (requires artifacts with the *_tile stages)
        tiled_loss: args.flag("tiled-loss"),
        tiled_mlp: args.flag("tiled-mlp"),
        ..Default::default()
    };
    opts.adamw.lr = args.f64("lr", opts.adamw.lr as f64) as f32;
    if let Some(warmup) = args.get("warmup") {
        opts.lr_schedule = Some(alst::coordinator::pipeline::LrSchedule {
            peak_lr: opts.adamw.lr,
            warmup_steps: warmup.parse().unwrap_or(10),
            total_steps: steps as u64,
            min_lr: opts.adamw.lr * 0.1,
        });
    }
    let mut trainer = Trainer::new(&dir, opts)?;
    if let Some(resume) = args.get("resume") {
        trainer.load_snapshot(std::path::Path::new(resume))?;
        println!("resumed from {resume} at step {}", trainer.step_count());
    }
    println!(
        "model={} params={} sp={} seq={} kernels={}",
        trainer.manifest.config.name,
        trainer.manifest.config.params_count,
        trainer.sp(),
        trainer.manifest.seq,
        trainer.manifest.config.kernels,
    );

    // --data FILE trains on a byte-tokenized real corpus (needs vocab>=256);
    // default is the learnable synthetic Markov stream.
    let source: Box<dyn alst::coordinator::dataloader::BatchSource> =
        if let Some(path) = args.get("data") {
            anyhow::ensure!(
                trainer.manifest.config.vocab >= 256,
                "byte-level corpus needs vocab >= 256"
            );
            Box::new(alst::coordinator::dataloader::CorpusSource::from_file(
                std::path::Path::new(path),
                seq,
                seed,
            )?)
        } else {
            Box::new(MarkovSource::new(
                trainer.manifest.config.vocab,
                seq,
                0.05,
                seed ^ 1,
            ))
        };
    let mut loader = UlyssesDataLoader::new(source, sp);
    let gas = args.usize("gas", 1);
    let mut log = RunLog::default();
    for step in 0..steps {
        let batches: Vec<Vec<i32>> = (0..gas).map(|_| loader.next().0).collect();
        let m = trainer.train_step_accum(&batches)?;
        if step % args.usize("log-every", 1) == 0 {
            println!(
                "step {:>4}  loss {:.4}  gnorm {:.3}  {:.1}ms  a2a {:.1}MiB",
                m.step,
                m.loss,
                m.grad_norm,
                m.step_time.as_secs_f64() * 1e3,
                m.a2a_bytes as f64 / (1 << 20) as f64,
            );
        }
        log.push(m);
    }
    println!("{}", log.ascii_loss_curve(60, 12));
    if let Some(path) = args.get("csv") {
        log.write_csv(std::path::Path::new(path))?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("save") {
        trainer.save_snapshot(std::path::Path::new(path))?;
        println!("snapshot saved to {path}");
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let model = preset(&args.get_or("model", "llama3-8b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model preset"))?;
    let world = args.usize("gpus", 8);
    let nodes = world.div_ceil(8);
    let flags = flags_from_args(args);
    let est = Estimator::new(model, ClusterConfig::h100(nodes), flags);
    let out = max_seqlen_search(&est, world);
    let perf = iteration_time(
        &IterationModel {
            model: model.clone(),
            cluster: ClusterConfig::h100(nodes),
            flags,
        },
        out.max_seqlen.max(1),
        world,
    );
    println!(
        "{} on {} GPUs [{}]: max seqlen {} (bound by {}), modeled iter {} @ {:.1} TFLOPS/GPU",
        model.name,
        world,
        flags.describe(),
        fmt_seqlen(out.max_seqlen),
        out.binding,
        fmt_duration_hms(std::time::Duration::from_secs_f64(perf.iteration_s)),
        perf.tflops_per_gpu,
    );
    Ok(())
}

fn cmd_ablate(args: &Args) -> Result<()> {
    let model = preset(&args.get_or("model", "llama3-8b")).unwrap();
    let world = args.usize("gpus", 8);
    let table = alst::paper::table1_ablations(model, world);
    table.print();
    Ok(())
}

fn cmd_estimate(args: &Args) -> Result<()> {
    let model = preset(&args.get_or("model", "llama3-8b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model preset"))?;
    let world = args.usize("gpus", 8);
    let seq = args.usize("seq", 32_768);
    let flags = flags_from_args(args);
    let est = Estimator::new(model, ClusterConfig::h100(world.div_ceil(8)), flags);
    let b = est.breakdown(seq, world);
    let gib = |x: u64| x as f64 / GIB as f64;
    println!(
        "per-GPU memory for {} @ seq {} on {} GPUs [{}]:",
        model.name,
        fmt_seqlen(seq),
        world,
        flags.describe()
    );
    println!("  weights (device)   {:>8.2} GiB", gib(b.weights_device));
    println!("  grads   (device)   {:>8.2} GiB", gib(b.grads_device));
    println!("  optim   (device)   {:>8.2} GiB", gib(b.optim_device));
    println!("  ckpt    (device)   {:>8.2} GiB", gib(b.acts.ckpt_device));
    println!("  attn work          {:>8.2} GiB", gib(b.acts.attn_work));
    println!("  mlp work           {:>8.2} GiB", gib(b.acts.mlp_work));
    println!("  logits work        {:>8.2} GiB", gib(b.acts.logits_work));
    println!("  resid work         {:>8.2} GiB", gib(b.acts.resid_work));
    println!("  misc               {:>8.2} GiB", gib(b.misc));
    println!("  TOTAL device       {:>8.2} GiB", gib(b.device_total()));
    println!("  host per rank      {:>8.2} GiB", gib(b.host_per_rank));
    println!("  fits: {}", est.fits(seq, world));
    Ok(())
}

/// Artifact doctor: load a manifest, compile every stage, execute each
/// with zero-filled inputs, and verify the output shapes — catches stale
/// or mismatched artifacts before a long training run does.
fn cmd_validate(args: &Args) -> Result<()> {
    use alst::runtime::{Engine, HostTensor, Manifest};
    let root = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let dirs: Vec<std::path::PathBuf> = if let Some(cfg) = args.get("config") {
        vec![Manifest::artifact_dir(
            &root,
            cfg,
            args.usize("sp", 1),
            args.usize("seq", 256),
        )]
    } else {
        std::fs::read_dir(&root)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.join("manifest.json").exists())
            .collect()
    };
    anyhow::ensure!(!dirs.is_empty(), "no artifact dirs under {}", root.display());

    let mut failures = 0;
    for dir in dirs {
        print!("{} ... ", dir.display());
        let check = (|| -> Result<usize> {
            let m = Manifest::load(&dir)?;
            let mut engine = Engine::cpu()?;
            engine.load_manifest(&m)?;
            for (name, io) in &m.stages {
                let inputs: Vec<HostTensor> = io
                    .inputs
                    .iter()
                    .map(|t| match t.dtype {
                        alst::runtime::Dtype::F32 => HostTensor::zeros(&t.shape),
                        alst::runtime::Dtype::I32 => HostTensor::i32(
                            t.shape.clone(),
                            vec![0; t.shape.iter().product()],
                        ),
                    })
                    .collect();
                let refs: Vec<&HostTensor> = inputs.iter().collect();
                engine
                    .execute_checked(&m, name, &refs)
                    .with_context(|| format!("stage {name}"))?;
            }
            Ok(m.stages.len())
        })();
        match check {
            Ok(n) => println!("OK ({n} stages)"),
            Err(e) => {
                println!("FAIL: {e:#}");
                failures += 1;
            }
        }
    }
    anyhow::ensure!(failures == 0, "{failures} artifact dir(s) failed validation");
    println!("all artifacts valid");
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    for (name, table) in alst::paper::all_tables() {
        table.print();
        std::fs::write(out_dir.join(format!("{name}.csv")), table.to_csv())?;
    }
    println!("\nCSV written to {}", out_dir.display());
    Ok(())
}
