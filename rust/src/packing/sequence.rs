//! `PackedSequence`: the SP-ready materialization of one pack — token
//! ids, per-token segment ids, per-document position ids that reset to 0
//! at every boundary (the paper's O(S) replacement for the O(S^2) 4-D
//! attention mask, §3.4), FlashAttention-style `cu_seqlens`, and the
//! segment-aware label shift.
//!
//! Layout convention is pinned to the Pallas side
//! (`python/compile/kernels/packed_attn.py::make_packed_segments`):
//! lengths [3, 2, 4] -> seg_ids [0 0 0 1 1 2 2 2 2],
//! positions [0 1 2 0 1 0 1 2 3], cu_seqlens [0 3 5 9].
//! `rust/tests/packed_integration.rs` cross-checks this fixture.

use anyhow::Result;

use crate::coordinator::dataloader::IGNORE_INDEX;
use crate::packing::packer::{Document, Pack};

/// Token id used for trailing padding (its whole segment is loss-masked,
/// so the value never trains).
pub const PAD_TOKEN: i32 = 0;

/// One pack, materialized: documents back to back plus optional trailing
/// padding as a final loss-masked segment.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedSequence {
    pub ids: Vec<i32>,
    /// Per-token segment index (non-decreasing; padding is the last one).
    pub seg_ids: Vec<i32>,
    /// Per-DOCUMENT position ids: reset to 0 at every boundary (§3.4).
    pub positions: Vec<i32>,
    /// FlashAttention-style cumulative boundaries, len = n_segments + 1;
    /// `cu_seqlens[s]..cu_seqlens[s+1]` is segment `s`.
    pub cu_seqlens: Vec<i32>,
    /// Provenance id per real document (padding excluded).
    pub doc_ids: Vec<u64>,
    n_docs: usize,
}

impl PackedSequence {
    /// Concatenate documents with no padding.
    pub fn from_documents(docs: &[Document]) -> Result<PackedSequence> {
        anyhow::ensure!(!docs.is_empty(), "cannot pack zero documents");
        let total: usize = docs.iter().map(Document::len).sum();
        let mut ids = Vec::with_capacity(total);
        let mut seg_ids = Vec::with_capacity(total);
        let mut positions = Vec::with_capacity(total);
        let mut cu_seqlens = Vec::with_capacity(docs.len() + 1);
        let mut doc_ids = Vec::with_capacity(docs.len());
        cu_seqlens.push(0);
        for (s, d) in docs.iter().enumerate() {
            anyhow::ensure!(!d.is_empty(), "document {} is empty", d.id);
            ids.extend_from_slice(&d.tokens);
            seg_ids.extend(std::iter::repeat(s as i32).take(d.len()));
            positions.extend(0..d.len() as i32);
            cu_seqlens.push(ids.len() as i32);
            doc_ids.push(d.id);
        }
        Ok(PackedSequence {
            ids,
            seg_ids,
            positions,
            cu_seqlens,
            doc_ids,
            n_docs: docs.len(),
        })
    }

    /// Materialize a pack at its full capacity; any tail becomes one
    /// padding segment whose labels are all `IGNORE_INDEX`.
    pub fn from_pack(pack: &Pack) -> Result<PackedSequence> {
        let mut p = Self::from_documents(&pack.docs)?;
        anyhow::ensure!(
            p.len() <= pack.capacity,
            "pack overflows capacity: {} > {}",
            p.len(),
            pack.capacity
        );
        let pad = pack.capacity - p.len();
        if pad > 0 {
            let seg = p.n_segments() as i32;
            p.ids.extend(std::iter::repeat(PAD_TOKEN).take(pad));
            p.seg_ids.extend(std::iter::repeat(seg).take(pad));
            p.positions.extend(0..pad as i32);
            p.cu_seqlens.push(pack.capacity as i32);
        }
        Ok(p)
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Real documents (padding segment excluded).
    pub fn n_docs(&self) -> usize {
        self.n_docs
    }

    /// Segments including the padding segment, if any.
    pub fn n_segments(&self) -> usize {
        self.cu_seqlens.len() - 1
    }

    pub fn has_padding(&self) -> bool {
        self.n_segments() > self.n_docs
    }

    pub fn segment_range(&self, s: usize) -> std::ops::Range<usize> {
        self.cu_seqlens[s] as usize..self.cu_seqlens[s + 1] as usize
    }

    /// Per-segment lengths (padding last, if present).
    pub fn segment_lengths(&self) -> Vec<usize> {
        self.cu_seqlens
            .windows(2)
            .map(|w| (w[1] - w[0]) as usize)
            .collect()
    }

    /// Per-document lengths (padding excluded) — what the packed flos
    /// model sums squares over.
    pub fn doc_lengths(&self) -> Vec<usize> {
        self.segment_lengths()[..self.n_docs].to_vec()
    }

    /// Segment-aware labels: shift within each document, mask each
    /// document's last token AND the whole padding segment.
    pub fn labels(&self) -> Vec<i32> {
        let mut labels = shift_labels_packed(&self.ids, &self.cu_seqlens);
        if self.has_padding() {
            let pad = self.segment_range(self.n_docs);
            for l in &mut labels[pad] {
                *l = IGNORE_INDEX;
            }
        }
        labels
    }
}

/// Paper §4.3, packed form: shift-left WITHIN each segment; the last
/// token of every segment gets `IGNORE_INDEX` instead of leaking the next
/// segment's first token as a target. This is the correctness fix for
/// `dataloader::shift_labels` on packed input (which leaks exactly one
/// cross-document target per boundary — see the counterexample test
/// there).
pub fn shift_labels_packed(ids: &[i32], cu_seqlens: &[i32]) -> Vec<i32> {
    assert!(cu_seqlens.len() >= 2, "need at least one segment");
    assert_eq!(cu_seqlens[0], 0, "cu_seqlens must start at 0");
    assert_eq!(
        *cu_seqlens.last().unwrap() as usize,
        ids.len(),
        "cu_seqlens must end at the sequence length"
    );
    let mut out = vec![IGNORE_INDEX; ids.len()];
    for w in cu_seqlens.windows(2) {
        let (a, b) = (w[0] as usize, w[1] as usize);
        assert!(a < b, "cu_seqlens must be strictly increasing");
        out[a..b - 1].copy_from_slice(&ids[a + 1..b]);
        // out[b - 1] stays IGNORE_INDEX: never target across the boundary
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn docs(lens: &[usize]) -> Vec<Document> {
        lens.iter()
            .enumerate()
            .map(|(i, &n)| {
                Document::new(i as u64, (0..n as i32).map(|t| 100 * (i as i32 + 1) + t).collect())
            })
            .collect()
    }

    #[test]
    fn layout_matches_pallas_convention() {
        // packed_attn.make_packed_segments([3, 2, 4]) fixture
        let p = PackedSequence::from_documents(&docs(&[3, 2, 4])).unwrap();
        assert_eq!(p.seg_ids, vec![0, 0, 0, 1, 1, 2, 2, 2, 2]);
        assert_eq!(p.positions, vec![0, 1, 2, 0, 1, 0, 1, 2, 3]);
        assert_eq!(p.cu_seqlens, vec![0, 3, 5, 9]);
        assert_eq!(p.doc_lengths(), vec![3, 2, 4]);
        assert!(!p.has_padding());
    }

    #[test]
    fn packed_shift_never_crosses_boundaries() {
        let p = PackedSequence::from_documents(&docs(&[3, 2, 4])).unwrap();
        let labels = p.labels();
        // doc 0 tokens 100,101,102 -> labels 101,102,IGN
        assert_eq!(&labels[..3], &[101, 102, IGNORE_INDEX]);
        // doc 1 tokens 200,201 -> labels 201,IGN
        assert_eq!(&labels[3..5], &[201, IGNORE_INDEX]);
        // doc 2 tokens 300..303 -> labels 301,302,303,IGN
        assert_eq!(&labels[5..], &[301, 302, 303, IGNORE_INDEX]);
        // global: a label never belongs to a different segment
        for (i, &l) in labels.iter().enumerate() {
            if l != IGNORE_INDEX {
                assert_eq!(p.seg_ids[i], p.seg_ids[i + 1], "label at {i} crosses");
                assert_eq!(l, p.ids[i + 1]);
            }
        }
    }

    #[test]
    fn padding_is_a_masked_segment() {
        let pack = Pack { capacity: 12, docs: docs(&[3, 2, 4]) };
        let p = PackedSequence::from_pack(&pack).unwrap();
        assert_eq!(p.len(), 12);
        assert_eq!(p.n_docs(), 3);
        assert_eq!(p.n_segments(), 4);
        assert!(p.has_padding());
        assert_eq!(p.cu_seqlens, vec![0, 3, 5, 9, 12]);
        assert_eq!(&p.seg_ids[9..], &[3, 3, 3]);
        assert_eq!(&p.positions[9..], &[0, 1, 2]);
        let labels = p.labels();
        assert!(labels[9..].iter().all(|&l| l == IGNORE_INDEX));
        // doc labels unchanged by padding
        assert_eq!(&labels[..3], &[101, 102, IGNORE_INDEX]);
    }

    #[test]
    fn single_document_matches_whole_sequence_shift() {
        use crate::coordinator::dataloader::shift_labels;
        let ids: Vec<i32> = (1..=8).collect();
        let packed = shift_labels_packed(&ids, &[0, 8]);
        assert_eq!(packed, shift_labels(&ids));
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn rejects_empty_segment() {
        shift_labels_packed(&[1, 2, 3], &[0, 2, 2, 3]);
    }
}
