//! ALST-RS: Arctic Long Sequence Training reproduced as a three-layer
//! Rust + JAX + Pallas stack. See DESIGN.md for the system inventory.
pub mod util;
pub mod config;
pub mod runtime;
pub mod collectives;
pub mod coordinator;
pub mod packing;
pub mod tiling;
pub mod memory;
pub mod perf;
pub mod metrics;
pub mod paper;
