"""AOT exporter: lower every Ulysses stage (fwd + vjp) to HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the rust `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

For a (config, seq, sp) triple this writes:

    artifacts/<config>-sp<sp>-seq<seq>/
        embed_fwd.hlo.txt ... loss_bwd.hlo.txt   (10 stage programs)
        manifest.json                            (shapes + param layout)

The manifest is the single source of truth the rust coordinator reads: it
drives the flat-parameter layout for ZeRO sharding, artifact input order,
and the Ulysses head-shard shapes.

Usage:  python -m compile.aot --config tiny --seq 256 --sp 2 --out ../artifacts
        python -m compile.aot --all --out ../artifacts      (default build set)
"""
from __future__ import annotations

import argparse
import functools
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def stage_specs(cfg: M.ModelConfig, seq: int, sp: int) -> dict:
    """Input ShapeDtypeStructs for every stage, keyed by stage name.

    Shapes follow the Ulysses layouts: `ssh = seq/sp` outside attention,
    full `seq` with per-rank head shards inside it.
    """
    assert seq % sp == 0, (seq, sp)
    ssh = seq // sp
    h, v, d = cfg.hidden, cfg.vocab, cfg.head_dim
    nq, nkv = cfg.n_q_heads, cfg.n_kv_heads
    q_sh, kv_sh = cfg.head_shard(sp)
    hq, hkv = nq * d, nkv * d

    emb = [("embed", spec((v, h))), ("ids", spec((ssh,), I32))]
    pre = [
        ("ln1", spec((h,))), ("wq", spec((h, hq))),
        ("wk", spec((h, hkv))), ("wv", spec((h, hkv))),
        ("h", spec((ssh, h))), ("pos", spec((ssh,), I32)),
    ]
    attn = [
        ("q", spec((seq, q_sh, d))),
        ("k", spec((seq, kv_sh, d))),
        ("v", spec((seq, kv_sh, d))),
    ]
    post = [
        ("wo", spec((hq, h))), ("ln2", spec((h,))),
        ("wg", spec((h, cfg.ffn))), ("wu", spec((h, cfg.ffn))),
        ("wd", spec((cfg.ffn, h))),
        ("h_in", spec((ssh, h))), ("attn", spec((ssh, nq, d))),
    ]
    loss = [
        ("lnf", spec((h,))), ("unembed", spec((h, v))),
        ("h", spec((ssh, h))), ("labels", spec((ssh,), I32)),
    ]
    return {
        "embed_fwd": (M.embed_fwd, emb),
        "embed_bwd": (M.embed_bwd, emb + [("d_h", spec((ssh, h)))]),
        "pre_attn_fwd": (M.pre_attn_fwd, pre),
        "pre_attn_bwd": (M.pre_attn_bwd, pre + [
            ("d_q", spec((ssh, nq, d))),
            ("d_k", spec((ssh, nkv, d))),
            ("d_v", spec((ssh, nkv, d))),
        ]),
        "attn_fwd": (M.attn_core_fwd, attn),
        "attn_bwd": (M.attn_core_bwd, attn + [("d_o", spec((seq, q_sh, d)))]),
        "post_attn_fwd": (M.post_attn_fwd, post),
        "post_attn_bwd": (M.post_attn_bwd, post + [("d_out", spec((ssh, h)))]),
        "loss_fwd": (M.loss_fwd, loss),
        "loss_bwd": (M.loss_bwd, loss + [("ct_sum", spec(()))]),
    }


# Parameter groups in flat-buffer order. Rust's ZeRO sharding flattens
# [embed group][layer 0]...[layer L-1][final group] in exactly this order.
def param_layout(cfg: M.ModelConfig) -> dict:
    h, v, d = cfg.hidden, cfg.vocab, cfg.head_dim
    hq, hkv = cfg.n_q_heads * d, cfg.n_kv_heads * d
    return {
        "embed": [("embed", [v, h], "normal")],
        "layer": [
            ("ln1", [h], "ones"),
            ("wq", [h, hq], "normal"),
            ("wk", [h, hkv], "normal"),
            ("wv", [h, hkv], "normal"),
            ("wo", [hq, h], "normal"),
            ("ln2", [h], "ones"),
            ("wg", [h, cfg.ffn], "normal"),
            ("wu", [h, cfg.ffn], "normal"),
            ("wd", [cfg.ffn, h], "zeros"),
        ],
        "final": [("lnf", [h], "ones"), ("unembed", [h, v], "normal")],
    }


def _shape_entry(name, s):
    return {
        "name": name,
        "shape": list(s.shape),
        "dtype": "i32" if s.dtype == jnp.int32 else "f32",
    }


def export(cfg: M.ModelConfig, seq: int, sp: int, out_root: pathlib.Path,
           kernels: str | None = None) -> pathlib.Path:
    if kernels and kernels != cfg.kernels:
        # Kernel-swap variant gets its own artifact dir (attention-agnostic
        # property: rust loads either with zero coordinator changes).
        cfg = dataclasses_replace(cfg, name=f"{cfg.name}-{kernels}",
                                  kernels=kernels)
    out = out_root / f"{cfg.name}-sp{sp}-seq{seq}"
    out.mkdir(parents=True, exist_ok=True)
    specs = stage_specs(cfg, seq, sp)
    stages = {}
    for name, (fn, inputs) in specs.items():
        bound = functools.partial(fn, cfg)
        # keep_unused: the stage signature IS the rust-side contract; jit
        # must not DCE arguments whose values a particular VJP ignores
        # (e.g. embed_bwd only uses the embedding's shape).
        lowered = jax.jit(bound, keep_unused=True).lower(*[s for _, s in inputs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out / fname).write_text(text)
        out_avals = jax.eval_shape(bound, *[s for _, s in inputs])
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        stages[name] = {
            "file": fname,
            "inputs": [_shape_entry(n, s) for n, s in inputs],
            "outputs": [_shape_entry(f"out{i}", s)
                        for i, s in enumerate(out_avals)],
        }
        print(f"  {name}: {len(text)} chars")
    q_sh, kv_sh = cfg.head_shard(sp)
    manifest = {
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "hidden": cfg.hidden,
            "n_layers": cfg.n_layers, "n_q_heads": cfg.n_q_heads,
            "n_kv_heads": cfg.n_kv_heads, "ffn": cfg.ffn,
            "head_dim": cfg.head_dim, "rope_theta": cfg.rope_theta,
            "norm_eps": cfg.norm_eps, "kernels": cfg.kernels,
            "params_count": cfg.params_count(),
        },
        "seq": seq, "sp": sp, "seq_shard": seq // sp,
        "q_heads_shard": q_sh, "kv_heads_shard": kv_sh,
        "ignore_index": M.IGNORE_INDEX,
        "stages": stages,
        "param_layout": {
            g: [{"name": n, "shape": sh, "init": init} for n, sh, init in tensors]
            for g, tensors in param_layout(cfg).items()
        },
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return out


def dataclasses_replace(cfg, **kw):
    import dataclasses
    return dataclasses.replace(cfg, **kw)


# The default build set: everything the examples, tests and benches load.
DEFAULT_BUILDS = [
    ("tiny", 256, 1, None),
    ("tiny", 256, 2, None),
    ("tiny", 256, 4, None),      # exercises kv replication (kv=2 < sp=4)
    ("tiny", 256, 2, "ref"),     # kernel-swap path (attention-agnostic test)
    ("e2e-25m", 512, 1, None),
    ("e2e-25m", 512, 4, None),
    ("e2e-100m", 512, 4, None),   # single-core-friendly e2e driver default
    ("e2e-100m", 1024, 4, None),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", choices=sorted(M.CONFIGS), default=None)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--sp", type=int, default=1)
    ap.add_argument("--kernels", choices=["pallas", "ref"], default=None)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--all", action="store_true",
                    help="build the default artifact set")
    args = ap.parse_args()
    out_root = pathlib.Path(args.out)
    if args.all or args.config is None:
        builds = DEFAULT_BUILDS
    else:
        builds = [(args.config, args.seq, args.sp, args.kernels)]
    for name, seq, sp, kern in builds:
        cfg = M.CONFIGS[name]
        tag = f"{name}-sp{sp}-seq{seq}" + (f" [{kern}]" if kern else "")
        print(f"export {tag}")
        export(cfg, seq, sp, out_root, kernels=kern)
    print("done")


if __name__ == "__main__":
    main()
