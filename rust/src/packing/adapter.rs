//! SP-aware dataloading for packed batches: the packed analogue of
//! `coordinator::dataloader::UlyssesDataLoader`.
//!
//! Labels are segment-aware-shifted on the FULL packed sequence first,
//! then ids/positions/labels/segment-ids are sharded along the sequence
//! dimension — the same order of operations that makes the whole-sequence
//! path immune to the §4.3 boundary bug. Segment metadata crosses rank
//! boundaries intact: each shard keeps its local `seg_ids` slice for the
//! embedding-side ops AND the global `cu_seqlens`, because after the
//! `a2a_seq_to_head` relayout every rank attends over the FULL sequence
//! for its head shard and needs full-sequence boundaries. Replicating
//! `cu_seqlens` is O(n_docs) integers per rank — the paper's point that
//! position-id metadata is the cheap replacement for the O(S^2) mask.

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::dataloader::ShardedBatch;
use crate::packing::packer::{chunk_document, pack_ffd, Document, PackingStats};
use crate::packing::sequence::PackedSequence;
use crate::util::rng::Rng;

/// One rank's view of a packed training sample.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedShard {
    /// ids / per-document positions / segment-aware labels — the shape
    /// `pipeline::Trainer` consumes (positions feed RoPE, so documents
    /// are positionally independent; labels never cross a boundary).
    pub batch: ShardedBatch,
    /// This rank's slice of per-token segment ids.
    pub seg_ids: Vec<i32>,
    /// GLOBAL segment boundaries, replicated to every rank (needed on the
    /// attention side, where each rank sees the full sequence).
    pub cu_seqlens: Vec<i32>,
    /// Segment (or segment-fragment) boundaries local to this shard,
    /// offsets in `0..=ssh`. A document spanning a rank boundary
    /// contributes a fragment on each side.
    pub cu_seqlens_local: Vec<i32>,
}

/// A full-sequence view reassembled from a shard set (the inverse of
/// `shard_packed`, used by round-trip checks and the trainer-side
/// debugging utilities).
#[derive(Debug, Clone, PartialEq)]
pub struct GatheredSequence {
    pub ids: Vec<i32>,
    pub positions: Vec<i32>,
    pub labels: Vec<i32>,
    pub seg_ids: Vec<i32>,
}

/// Reassemble the full packed sequence from its shard set by borrowing
/// each shard's slices into one preallocated buffer per field — a single
/// `extend_from_slice` pass, no per-shard `Vec` clones (the
/// `flat_map(clone)` pattern this replaces allocated one throwaway vector
/// per rank per field).
pub fn gather_shards(shards: &[PackedShard]) -> GatheredSequence {
    let total: usize = shards.iter().map(|s| s.batch.ids.len()).sum();
    let mut out = GatheredSequence {
        ids: Vec::with_capacity(total),
        positions: Vec::with_capacity(total),
        labels: Vec::with_capacity(total),
        seg_ids: Vec::with_capacity(total),
    };
    for s in shards {
        out.ids.extend_from_slice(&s.batch.ids);
        out.positions.extend_from_slice(&s.batch.positions);
        out.labels.extend_from_slice(&s.batch.labels);
        out.seg_ids.extend_from_slice(&s.seg_ids);
    }
    out
}

/// Shard one packed sequence for `sp` ranks, preserving segment metadata.
pub fn shard_packed(p: &PackedSequence, sp: usize) -> Vec<PackedShard> {
    assert!(sp > 0, "sp must be positive");
    assert_eq!(p.len() % sp, 0, "packed length {} not divisible by sp {sp}", p.len());
    let labels = p.labels();
    let ssh = p.len() / sp;
    (0..sp)
        .map(|r| {
            let (a, b) = (r * ssh, (r + 1) * ssh);
            let mut local = vec![0i32];
            for &c in &p.cu_seqlens {
                if (c as usize) > a && (c as usize) < b {
                    local.push(c - a as i32);
                }
            }
            local.push(ssh as i32);
            PackedShard {
                batch: ShardedBatch {
                    ids: p.ids[a..b].to_vec(),
                    positions: p.positions[a..b].to_vec(),
                    labels: labels[a..b].to_vec(),
                },
                seg_ids: p.seg_ids[a..b].to_vec(),
                cu_seqlens: p.cu_seqlens.clone(),
                cu_seqlens_local: local,
            }
        })
        .collect()
}

/// A stream of variable-length documents.
pub trait DocumentSource {
    fn next_document(&mut self) -> Document;
}

impl DocumentSource for Box<dyn DocumentSource> {
    fn next_document(&mut self) -> Document {
        (**self).next_document()
    }
}

/// SFT-style mixed-length synthetic corpus: document lengths are
/// log-uniform in `[min_len, max_len]` (a long-tailed mix of short chats
/// and long articles), tokens uniform over the vocab. Deterministic by
/// seed.
pub struct MixedLengthSource {
    pub vocab: usize,
    pub min_len: usize,
    pub max_len: usize,
    rng: Rng,
    next_id: u64,
}

impl MixedLengthSource {
    pub fn new(vocab: usize, min_len: usize, max_len: usize, seed: u64) -> MixedLengthSource {
        assert!(min_len >= 1 && min_len <= max_len, "bad length range");
        MixedLengthSource { vocab, min_len, max_len, rng: Rng::new(seed), next_id: 0 }
    }

    fn sample_len(&mut self) -> usize {
        let (lo, hi) = (self.min_len as f64, self.max_len as f64);
        let ln = lo.ln() + self.rng.uniform() * (hi.ln() - lo.ln());
        (ln.exp().round() as usize).clamp(self.min_len, self.max_len)
    }
}

impl DocumentSource for MixedLengthSource {
    fn next_document(&mut self) -> Document {
        let n = self.sample_len();
        let tokens = (0..n).map(|_| self.rng.below(self.vocab) as i32).collect();
        let id = self.next_id;
        self.next_id += 1;
        Document::new(id, tokens)
    }
}

/// The packed adapter: buffers `lookahead_docs` documents (chunking any
/// longer than `capacity`), FFD-packs them, and yields capacity-length
/// `PackedSequence`s with their per-rank shard sets. Cumulative
/// efficiency/waste stats are kept for the run report.
pub struct PackedDataLoader<S: DocumentSource> {
    pub source: S,
    pub capacity: usize,
    pub sp: usize,
    pub lookahead_docs: usize,
    queue: VecDeque<PackedSequence>,
    stats: PackingStats,
}

impl<S: DocumentSource> PackedDataLoader<S> {
    pub fn new(source: S, capacity: usize, sp: usize, lookahead_docs: usize) -> Result<Self> {
        anyhow::ensure!(sp > 0, "sp must be positive");
        anyhow::ensure!(
            capacity > 0 && capacity % sp == 0,
            "capacity {capacity} must be positive and divisible by sp {sp}"
        );
        anyhow::ensure!(lookahead_docs > 0, "need a positive packing lookahead");
        Ok(PackedDataLoader {
            source,
            capacity,
            sp,
            lookahead_docs,
            queue: VecDeque::new(),
            stats: PackingStats::default(),
        })
    }

    fn refill(&mut self) -> Result<()> {
        let mut docs = Vec::with_capacity(self.lookahead_docs);
        while docs.len() < self.lookahead_docs {
            let d = self.source.next_document();
            docs.extend(chunk_document(d, self.capacity));
        }
        let packs = pack_ffd(docs, self.capacity)?;
        self.stats.merge(&PackingStats::from_packs(&packs));
        for pack in &packs {
            self.queue.push_back(PackedSequence::from_pack(pack)?);
        }
        Ok(())
    }

    /// Next packed batch as (full packed sequence, per-rank shards).
    pub fn next(&mut self) -> Result<(PackedSequence, Vec<PackedShard>)> {
        let p = self.next_sequence()?;
        let shards = shard_packed(&p, self.sp);
        Ok((p, shards))
    }

    /// Next packed sequence WITHOUT materializing shards — for callers
    /// that only need the sequence. When the loader's `sp` matches the
    /// trainer's, prefer `next()` + `Trainer::train_step_packed_shards`,
    /// which consumes the shard set directly (nothing is materialized
    /// twice on either path).
    pub fn next_sequence(&mut self) -> Result<PackedSequence> {
        if self.queue.is_empty() {
            self.refill()?;
        }
        Ok(self.queue.pop_front().expect("refill produced no packs"))
    }

    /// Cumulative packing stats over everything packed so far.
    pub fn stats(&self) -> &PackingStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dataloader::IGNORE_INDEX;

    fn seq(lens: &[usize]) -> PackedSequence {
        let docs: Vec<Document> = lens
            .iter()
            .enumerate()
            .map(|(i, &n)| Document::new(i as u64, (0..n as i32).map(|t| 10 * i as i32 + t).collect()))
            .collect();
        PackedSequence::from_documents(&docs).unwrap()
    }

    #[test]
    fn shards_reassemble_to_full_metadata() {
        let p = seq(&[5, 3, 8]); // len 16
        for sp in [1usize, 2, 4] {
            let shards = shard_packed(&p, sp);
            let g = gather_shards(&shards);
            assert_eq!(g.ids, p.ids);
            assert_eq!(g.positions, p.positions);
            assert_eq!(g.seg_ids, p.seg_ids);
            assert_eq!(g.labels, p.labels());
            for s in &shards {
                assert_eq!(s.cu_seqlens, p.cu_seqlens, "global metadata replicated");
            }
        }
    }

    #[test]
    fn boundary_spanning_document_keeps_labels_and_positions() {
        // doc 1 (len 3) spans the sp=2 rank boundary at token 8 of 16
        let p = seq(&[7, 3, 6]);
        let shards = shard_packed(&p, 2);
        // rank 0 holds doc1's first token (global 7), label = doc1's second
        assert_eq!(*shards[0].batch.ids.last().unwrap(), 10);
        assert_eq!(*shards[0].batch.labels.last().unwrap(), 11);
        // rank 1 starts mid-doc-1: position continues at 1, not 0
        assert_eq!(shards[1].batch.positions[0], 1);
        assert_eq!(shards[1].seg_ids[0], 1);
        // doc 0's last token label is masked, not doc 1's first token
        assert_eq!(shards[0].batch.labels[6], IGNORE_INDEX);
    }

    #[test]
    fn local_boundaries_are_shard_relative() {
        let p = seq(&[5, 3, 8]); // cu [0,5,8,16]
        let shards = shard_packed(&p, 2); // ssh = 8
        assert_eq!(shards[0].cu_seqlens_local, vec![0, 5, 8]);
        assert_eq!(shards[1].cu_seqlens_local, vec![0, 8]); // doc 2 only
        let shards4 = shard_packed(&p, 4); // ssh = 4
        assert_eq!(shards4[0].cu_seqlens_local, vec![0, 4]);
        assert_eq!(shards4[1].cu_seqlens_local, vec![0, 1, 4]);
    }

    #[test]
    fn loader_yields_capacity_sequences_and_stats() {
        let src = MixedLengthSource::new(100, 4, 60, 7);
        let mut dl = PackedDataLoader::new(src, 64, 2, 16).unwrap();
        for _ in 0..8 {
            let (p, shards) = dl.next().unwrap();
            assert_eq!(p.len(), 64);
            assert_eq!(shards.len(), 2);
            assert!(p.n_docs() >= 1);
            // every label is in-segment or masked
            let labels = p.labels();
            for (i, &l) in labels.iter().enumerate() {
                if l != IGNORE_INDEX {
                    assert_eq!(p.seg_ids[i], p.seg_ids[i + 1]);
                }
            }
        }
        let s = dl.stats();
        assert!(s.n_docs > 0 && s.n_packs >= 8);
        assert!(s.efficiency() > 0.5, "log-uniform mix should pack well: {s:?}");
    }

    #[test]
    fn mixed_length_source_is_deterministic_and_bounded() {
        let mut a = MixedLengthSource::new(50, 2, 30, 3);
        let mut b = MixedLengthSource::new(50, 2, 30, 3);
        for _ in 0..20 {
            let (da, db) = (a.next_document(), b.next_document());
            assert_eq!(da, db);
            assert!((2..=30).contains(&da.len()));
            assert!(da.tokens.iter().all(|&t| (0..50).contains(&t)));
        }
        assert_eq!(a.next_document().id, 20);
    }
}
