//! Figure 13: training-loss equality between the baseline (SP=1, plain
//! attention path) and full ALST (SP=4 with kv-head replication, tiled
//! kernels, ckpt offload accounting) on IDENTICAL data and init.
//!
//! The paper trains Llama-8B both ways at 32K and overlays the curves;
//! here both configurations run through the real PJRT pipeline and the
//! losses must agree to float tolerance at every step.
//!
//!     cargo run --release --example correctness [-- --config tiny --steps 20]

use alst::config::FeatureFlags;
use alst::coordinator::dataloader::{MarkovSource, UlyssesDataLoader};
use alst::coordinator::pipeline::{Trainer, TrainerOptions};
use alst::runtime::Manifest;
use alst::util::cli::Args;

fn run(
    config: &str,
    sp: usize,
    seq: usize,
    steps: usize,
    flags: FeatureFlags,
    seed: u64,
) -> anyhow::Result<Vec<f32>> {
    let dir = Manifest::artifact_dir(std::path::Path::new("artifacts"), config, sp, seq);
    let mut trainer =
        Trainer::new(&dir, TrainerOptions { flags, seed, ..Default::default() })?;
    // Same seed => same data stream regardless of sp (the loader shards
    // the SAME full sequence; SP only changes who computes what).
    let vocab = trainer.manifest.config.vocab;
    let mut loader =
        UlyssesDataLoader::new(MarkovSource::new(vocab, seq, 0.05, seed ^ 1), sp);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let (ids, _) = loader.next();
        losses.push(trainer.train_step(&ids)?.loss);
    }
    Ok(losses)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let config = args.get_or("config", "tiny");
    let seq = args.usize("seq", 256);
    let steps = args.usize("steps", 20);
    let seed = 42;

    println!("baseline: sp=1, no ALST features beyond ZeRO/ckpt");
    let baseline = run(&config, 1, seq, steps, FeatureFlags::baseline(), seed)?;

    println!("ALST: sp=4 (kv heads replicate), tiled kernels, ckpt offload");
    let alst = run(&config, 4, seq, steps, FeatureFlags::alst(), seed)?;

    println!("\n step | baseline  | ALST      | delta");
    println!("------+-----------+-----------+----------");
    let mut max_delta = 0f32;
    for (i, (b, a)) in baseline.iter().zip(&alst).enumerate() {
        let d = (b - a).abs();
        max_delta = max_delta.max(d);
        println!("{:>5} | {:>9.5} | {:>9.5} | {:.2e}", i + 1, b, a, d);
    }
    println!("\nmax |delta| = {max_delta:.3e}");
    // f32 pipeline: the curves must overlap to numerical noise — the
    // paper's "almost exact match" (fn.25), here actually exact-ish.
    assert!(max_delta < 2e-3, "ALST diverged from baseline: {max_delta}");
    println!("Figure 13 reproduced: ALST == baseline training quality");
    Ok(())
}
