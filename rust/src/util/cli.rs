//! Tiny argv parser: `--key value`, `--flag`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_mixed() {
        let a = args("train --sp 4 --offload --seq=1024 tiny");
        assert_eq!(a.positional, vec!["train", "tiny"]);
        assert_eq!(a.usize("sp", 1), 4);
        assert_eq!(a.usize("seq", 0), 1024);
        assert_eq!(a.u64("seq", 0), 1024);
        assert_eq!(a.u64("missing", 7), 7);
        assert!(a.flag("offload"));
        assert!(!a.flag("zero3"));
    }

    #[test]
    fn defaults_apply() {
        let a = args("run");
        assert_eq!(a.usize("sp", 2), 2);
        assert_eq!(a.get_or("config", "tiny"), "tiny");
        assert_eq!(a.f64("lr", 3e-4), 3e-4);
    }

    #[test]
    fn flag_before_positional() {
        // `--verbose run`: "run" is consumed as the value of --verbose
        // (documented limitation: place flags after positionals or use =).
        let a = args("--verbose=true run");
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("verbose"), Some("true"));
    }
}
