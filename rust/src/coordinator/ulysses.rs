//! Ulysses all-to-all relayout (paper §3.2) and head-shard math (§3.2.1).
//!
//! Forward, at each attention boundary:
//!   every rank holds `[S/sp, n_heads, D]` (its sequence shard, ALL heads)
//!   -> all-to-all ->
//!   every rank holds `[S, n_heads/sp, D]` (FULL sequence, its head shard)
//! and the inverse after attention. kv tensors replicate when
//! `n_kv_heads < sp`; the backward of that replication SUMS the gradient
//! contributions from every consumer rank.
//!
//! Hot-path discipline (the per-layer cost ALST's step time is dominated
//! by): the `_into` variants write into `ScratchArena`-recycled buffers —
//! zero allocation at steady state — and move data as one contiguous
//! block copy per (dst, src) rank pair (`copy_rows`): for a fixed source
//! rank the destination rows are adjacent, so only the source side is
//! strided. `sp == 1` degenerates to a single memcpy passthrough, and the
//! `n_kv < sp` backward runs a fused single pass that copies the first
//! replica's contribution and accumulates the rest — no zero-fill, no
//! second sweep. The naive per-(dst, src, s) reference lives on in
//! `rust/tests/relayout_equiv.rs`, which pins the rewrite bit-for-bit,
//! with one documented exception: the sign of zero. The reference's
//! zero-init-then-add computes `0.0 + x` for the first contribution,
//! which normalizes `x = -0.0` to `+0.0`; the fused copy preserves
//! `-0.0`'s bit pattern. Numerically identical under IEEE `==` either
//! way, and the addend ORDER of every replica sum is unchanged
//! (ascending source rank), so all nonzero results round identically.

use anyhow::Result;

use crate::collectives::Group;
use crate::config::PlanKind;
use crate::obs::Category;
use crate::runtime::tensor::{accumulate_rows, copy_rows, HostTensor, ScratchArena};

use super::plan::{dense_attention, dense_attention_bwd, AttnShape, ParallelPlan, PlanSaved};

/// First global head owned by `rank` when `n_heads` are distributed over
/// `sp` ranks. Handles both the contiguous-split (n_heads >= sp) and the
/// replicated (n_heads < sp) regimes; in the latter, consumer ranks of the
/// same head group share a source head — exactly the paper's kv
/// replication rule.
pub fn head_start(rank: usize, n_heads: usize, sp: usize) -> usize {
    (rank * n_heads) / sp
}

/// Per-rank head count after sharding (q: n/sp; kv: max(n/sp, 1)).
pub fn heads_per_rank(n_heads: usize, sp: usize) -> usize {
    if n_heads >= sp {
        assert_eq!(n_heads % sp, 0, "head count not divisible by sp");
        n_heads / sp
    } else {
        1
    }
}

/// Validity of an SP degree for a (q, kv) head pair — §7.1 limits.
/// Boolean back-compat wrapper around [`validate_ulysses`].
pub fn sp_is_valid(n_q: usize, n_kv: usize, sp: usize) -> bool {
    validate_ulysses(n_q, n_kv, sp).is_ok()
}

/// The §7.1 head limits as actionable errors instead of a silent invalid
/// config: each message says what failed and what to do about it (pick a
/// divisor sp, or switch to the ring plan, which has no head bound).
pub fn validate_ulysses(n_q: usize, n_kv: usize, sp: usize) -> Result<()> {
    anyhow::ensure!(sp >= 1, "sp must be >= 1, got {sp}");
    anyhow::ensure!(
        sp <= n_q,
        "ulysses plan: sp={sp} > {n_q} query heads — every rank needs at \
         least one query head; use the ring plan, which has no head bound"
    );
    anyhow::ensure!(
        n_q % sp == 0,
        "ulysses plan: {n_q} query heads not divisible by sp={sp}; pick sp \
         from the divisors of {n_q} or use the ring plan"
    );
    if n_kv >= sp {
        anyhow::ensure!(
            n_kv % sp == 0,
            "ulysses plan: {n_kv} kv heads not divisible by sp={sp} (kv \
             replication only applies when n_kv < sp); pick sp from the \
             divisors of {n_kv} or use the ring plan"
        );
    }
    Ok(())
}

/// seq->head all-to-all (one-shot buffers; see `a2a_seq_to_head_into`).
pub fn a2a_seq_to_head(group: &Group, shards: &[HostTensor]) -> Result<Vec<HostTensor>> {
    a2a_seq_to_head_into(group, shards, &ScratchArena::new())
}

/// seq->head all-to-all.
///
/// `shards[r]`: rank r's `[ssh, n_heads, d]` tensor. Returns per dst rank
/// the `[ssh*sp, h_out, d]` full-sequence head shard, where
/// `h_out = heads_per_rank(n_heads, sp)`, in buffers checked out of
/// `arena` (recycle them once consumed — the step loop ping-pongs the
/// same buffers through all 2×n_layers relayouts). Data movement is one
/// `copy_rows` call per (dst, src) pair; the destination side of each
/// pair is a single contiguous span.
pub fn a2a_seq_to_head_into(
    group: &Group,
    shards: &[HostTensor],
    arena: &ScratchArena,
) -> Result<Vec<HostTensor>> {
    let tracer = group.tracer();
    let (hits0, misses0) =
        if tracer.enabled() { (arena.hits(), arena.misses()) } else { (0, 0) };
    let mut span = tracer.span(Category::Relayout, "a2a_seq_to_head");
    let sp = shards.len();
    assert_eq!(sp, group.world);
    let dims = shards[0].shape();
    assert_eq!(dims.len(), 3, "expected [ssh, heads, d]");
    let (ssh, n_heads, d) = (dims[0], dims[1], dims[2]);
    let h_out = heads_per_rank(n_heads, sp);
    let seq = ssh * sp;
    let out_len = seq * h_out * d;

    let mut out = Vec::with_capacity(sp);
    if sp == 1 {
        // Passthrough fast path: the relayout is the identity; one memcpy.
        let src = shards[0].as_f32().expect("f32 relayout");
        let mut data = arena.take_f32(out_len);
        data.copy_from_slice(src);
        out.push(HostTensor::f32(vec![seq, h_out, d], data));
    } else {
        let blk = h_out * d;
        let row = n_heads * d;
        for dst in 0..sp {
            let h0 = if n_heads >= sp { dst * h_out } else { head_start(dst, n_heads, sp) };
            // contents unspecified: every element is overwritten below
            let mut data = arena.take_f32(out_len);
            for (src, shard) in shards.iter().enumerate() {
                let src_data = shard.as_f32().expect("f32 relayout");
                copy_rows(&mut data, src * ssh * blk, blk, src_data, h0 * d, row, ssh, blk);
            }
            out.push(HostTensor::f32(vec![seq, h_out, d], data));
        }
    }
    // Every element of every output crossed the (simulated) wire once.
    // A faulted wire cancels the relayout span and returns the buffers to
    // the pool before propagating, so the retry re-runs allocation-free.
    if let Err(e) = group.account_all_to_all((sp * out_len * 4) as u64) {
        span.cancel();
        arena.recycle_all(out);
        return Err(e);
    }
    span.set_bytes((sp * out_len * 4) as u64);
    if span.active() {
        span.set_arena_delta(arena.hits() - hits0, arena.misses() - misses0);
    }
    Ok(out)
}

/// head->seq all-to-all (one-shot buffers; see `a2a_head_to_seq_into`).
pub fn a2a_head_to_seq(
    group: &Group,
    shards: &[HostTensor],
    n_heads_total: usize,
    sum_replicas: bool,
) -> Result<Vec<HostTensor>> {
    a2a_head_to_seq_into(group, shards, n_heads_total, sum_replicas, &ScratchArena::new())
}

/// head->seq all-to-all (inverse of `a2a_seq_to_head`).
///
/// `shards[r]`: rank r's `[seq, h_sh, d]`. Returns per dst rank the
/// `[ssh, n_heads_total, d]` sequence shard with all heads, in
/// arena-recycled buffers. With `sum_replicas` (backward of kv
/// replication) and `n_heads_total < sp`, the ranks sharing a head are
/// fused in a single pass: the first replica's contribution is a copy,
/// the rest accumulate — replica sums land in ascending source-rank
/// order, identical to the naive zero-init-then-add reference.
pub fn a2a_head_to_seq_into(
    group: &Group,
    shards: &[HostTensor],
    n_heads_total: usize,
    sum_replicas: bool,
    arena: &ScratchArena,
) -> Result<Vec<HostTensor>> {
    let tracer = group.tracer();
    let (hits0, misses0) =
        if tracer.enabled() { (arena.hits(), arena.misses()) } else { (0, 0) };
    let mut span = tracer.span(Category::Relayout, "a2a_head_to_seq");
    let sp = shards.len();
    assert_eq!(sp, group.world);
    let dims = shards[0].shape();
    assert_eq!(dims.len(), 3, "expected [seq, h_sh, d]");
    let (seq, h_sh, d) = (dims[0], dims[1], dims[2]);
    assert_eq!(seq % sp, 0);
    let ssh = seq / sp;
    let out_len = ssh * n_heads_total * d;
    let in_bytes: u64 = shards.iter().map(|t| t.size_bytes() as u64).sum();

    let mut out = Vec::with_capacity(sp);
    if sp == 1 && h_sh == n_heads_total {
        // passthrough fast path: the relayout is the identity; one memcpy
        let src = shards[0].as_f32().expect("f32 relayout");
        let mut data = arena.take_f32(out_len);
        data.copy_from_slice(src);
        out.push(HostTensor::f32(vec![ssh, n_heads_total, d], data));
        if let Err(e) = group.account_all_to_all(in_bytes) {
            span.cancel();
            arena.recycle_all(out);
            return Err(e);
        }
        span.set_bytes(in_bytes);
        if span.active() {
            span.set_arena_delta(arena.hits() - hits0, arena.misses() - misses0);
        }
        return Ok(out);
    }

    // With n_heads_total >= sp the source head blocks partition the output
    // columns, so even under `sum_replicas` every element is written
    // exactly once (pure copy). Only the replicated regime accumulates.
    let replicated = sum_replicas && n_heads_total < sp;
    // The copy pass covers every output column exactly when the shard
    // heads tile n_heads_total (partitioned regime) or h_sh == 1 with
    // head_start surjective (replicated regime) — true for everything the
    // coordinator produces. A PARTIAL head view (h_sh * sp <
    // n_heads_total) leaves uncovered columns, which must read as zero
    // like the pre-arena implementation returned.
    let full_cover = if n_heads_total >= sp {
        sp * h_sh == n_heads_total
    } else {
        h_sh == 1
    };
    let blk = h_sh * d;
    let row = n_heads_total * d;
    for dst in 0..sp {
        let mut data = if full_cover {
            arena.take_f32(out_len) // contents unspecified: fully overwritten
        } else {
            arena.take_f32_zeroed(out_len)
        };
        for (src, shard) in shards.iter().enumerate() {
            let h0 = if n_heads_total >= sp {
                src * h_sh
            } else {
                head_start(src, n_heads_total, sp)
            };
            let src_data = shard.as_f32().expect("f32 relayout");
            // fused replica-sum: first writer of a head group copies,
            // later replicas accumulate onto it
            let first_writer =
                !replicated || src == 0 || head_start(src - 1, n_heads_total, sp) != h0;
            if first_writer {
                copy_rows(&mut data, h0 * d, row, src_data, dst * ssh * blk, blk, ssh, blk);
            } else {
                accumulate_rows(&mut data, h0 * d, row, src_data, dst * ssh * blk, blk, ssh, blk);
            }
        }
        out.push(HostTensor::f32(vec![ssh, n_heads_total, d], data));
    }
    if let Err(e) = group.account_all_to_all(in_bytes) {
        span.cancel();
        arena.recycle_all(out);
        return Err(e);
    }
    span.set_bytes(in_bytes);
    if span.active() {
        span.set_arena_delta(arena.hits() - hits0, arena.misses() - misses0);
    }
    Ok(out)
}

/// Drive one train step's worth of relayouts through `arena`, mirroring
/// `pipeline::Trainer`'s schedule. Forward, per layer: q/k/v seq->head +
/// o head->seq. Backward, per layer: activation checkpointing REPLAYS
/// the forward relayouts (recompute), then d_attn seq->head and the
/// three gradient head->seq relayouts (kv grads sum over replica
/// consumers). Every buffer ping-pongs through the arena exactly as the
/// pipeline does. This is the single source of the schedule for
/// `bench_pipeline`'s step-cycle row and the steady-state
/// allocation-freedom test — KEEP IN SYNC with `Trainer::layer_forward`
/// and its backward loop if the relayout order ever changes.
///
/// `q_shards[r]`: `[ssh, n_q, d]`; `kv_shards[r]`: `[ssh, n_kv, d]`.
pub fn relayout_step_cycle(
    group: &Group,
    arena: &ScratchArena,
    q_shards: &[HostTensor],
    kv_shards: &[HostTensor],
    n_layers: usize,
    n_q: usize,
    n_kv: usize,
) -> Result<()> {
    for _ in 0..n_layers {
        let qf = a2a_seq_to_head_into(group, q_shards, arena)?;
        let kf = a2a_seq_to_head_into(group, kv_shards, arena)?;
        let vf = a2a_seq_to_head_into(group, kv_shards, arena)?;
        let o = a2a_head_to_seq_into(group, &qf, n_q, false, arena)?;
        arena.recycle_all(qf);
        arena.recycle_all(kf);
        arena.recycle_all(vf);
        arena.recycle_all(o);
    }
    for _ in 0..n_layers {
        // recompute replay of the forward relayouts; qf/kf/vf stay live
        // through attn_bwd, as in the pipeline
        let qf = a2a_seq_to_head_into(group, q_shards, arena)?;
        let kf = a2a_seq_to_head_into(group, kv_shards, arena)?;
        let vf = a2a_seq_to_head_into(group, kv_shards, arena)?;
        let o = a2a_head_to_seq_into(group, &qf, n_q, false, arena)?;
        arena.recycle_all(o);
        // d_attn (q-shaped) seq->head, then dq/dk/dv head->seq
        let d_o = a2a_seq_to_head_into(group, q_shards, arena)?;
        let d_q = a2a_head_to_seq_into(group, &qf, n_q, true, arena)?;
        let d_k = a2a_head_to_seq_into(group, &kf, n_kv, true, arena)?;
        let d_v = a2a_head_to_seq_into(group, &vf, n_kv, true, arena)?;
        arena.recycle_all(qf);
        arena.recycle_all(kf);
        arena.recycle_all(vf);
        arena.recycle_all(d_o);
        arena.recycle_all(d_q);
        arena.recycle_all(d_k);
        arena.recycle_all(d_v);
    }
    Ok(())
}

/// Per-step all-to-all wire volume for one attention block, in bytes —
/// the closed form the perf model uses and tests assert against.
/// q + k + v forward (seq->head) plus o backward (head->seq): each moves
/// its full logical size once per direction.
pub fn a2a_bytes_per_block(
    seq: usize,
    n_q: usize,
    n_kv: usize,
    head_dim: usize,
    sp: usize,
    elem_bytes: usize,
) -> u64 {
    let q_sh = heads_per_rank(n_q, sp);
    let kv_sh = heads_per_rank(n_kv, sp);
    // outputs of the forward a2a across ranks:
    let q = seq * q_sh * head_dim * sp;
    let kv = 2 * seq * kv_sh * head_dim * sp;
    // inverse a2a moves the o tensor (same logical volume as q):
    let o = q;
    ((q + kv + o) * elem_bytes) as u64
}

/// The Ulysses protocol behind the [`ParallelPlan`] trait: a2a seq->head
/// relayouts, dense per-head-shard attention (the shared reference
/// kernel), a2a head->seq back. Backward replays the forward relayouts
/// (activation-checkpoint recompute, exactly the trainer's schedule) so
/// the plan's `CommStats` ledger matches `relayout_step_cycle`'s.
pub struct UlyssesPlan;

impl UlyssesPlan {
    /// Per-rank dense attention over `[seq, q_sh, d]` head shards. The
    /// local GQA mapping `h_local / (q_sh / kv_sh)` agrees with the
    /// global `h / (n_q / n_kv)` in both the partitioned and the
    /// replicated (`kv_sh == 1`) regime because head blocks are
    /// contiguous per rank.
    fn local_shape(&self, shape: &AttnShape, sp: usize) -> AttnShape {
        AttnShape::new(
            heads_per_rank(shape.n_q, sp),
            heads_per_rank(shape.n_kv, sp),
            shape.head_dim,
        )
    }
}

impl ParallelPlan for UlyssesPlan {
    fn kind(&self) -> PlanKind {
        PlanKind::Ulysses
    }

    fn validate(&self, n_q: usize, n_kv: usize, sp: usize) -> Result<()> {
        validate_ulysses(n_q, n_kv, sp)
    }

    /// fwd: q/k/v seq->head + o head->seq; bwd: relayout replay
    /// (recompute) + d_o seq->head + dq/dk/dv head->seq.
    fn comm_bytes_per_layer(
        &self,
        seq: usize,
        shape: &AttnShape,
        sp: usize,
        elem_bytes: usize,
    ) -> u64 {
        let q_vol =
            (seq * heads_per_rank(shape.n_q, sp) * shape.head_dim * sp * elem_bytes) as u64;
        let kv_vol =
            (seq * heads_per_rank(shape.n_kv, sp) * shape.head_dim * sp * elem_bytes) as u64;
        let fwd = a2a_bytes_per_block(seq, shape.n_q, shape.n_kv, shape.head_dim, sp, elem_bytes);
        // bwd = forward replay + d_o in + (dq, dk, dv) out
        2 * fwd + 2 * q_vol + 2 * kv_vol
    }

    fn attention_forward(
        &self,
        group: &Group,
        arena: &ScratchArena,
        q: &[HostTensor],
        k: &[HostTensor],
        v: &[HostTensor],
        shape: &AttnShape,
        cu_seqlens: &[i32],
    ) -> Result<(Vec<HostTensor>, PlanSaved)> {
        let sp = group.world;
        self.validate(shape.n_q, shape.n_kv, sp)?;
        let local = self.local_shape(shape, sp);
        let qf = a2a_seq_to_head_into(group, q, arena)?;
        let kf = a2a_seq_to_head_into(group, k, arena)?;
        let vf = a2a_seq_to_head_into(group, v, arena)?;
        let mut o_full = Vec::with_capacity(sp);
        for r in 0..sp {
            let (o, lse) = dense_attention(&qf[r], &kf[r], &vf[r], &local, cu_seqlens, arena)?;
            arena.recycle(lse);
            o_full.push(o);
        }
        let o = a2a_head_to_seq_into(group, &o_full, shape.n_q, false, arena)?;
        arena.recycle_all(qf);
        arena.recycle_all(kf);
        arena.recycle_all(vf);
        arena.recycle_all(o_full);
        Ok((o, PlanSaved::Ulysses))
    }

    fn attention_backward(
        &self,
        group: &Group,
        arena: &ScratchArena,
        q: &[HostTensor],
        k: &[HostTensor],
        v: &[HostTensor],
        d_o: &[HostTensor],
        _saved: &PlanSaved,
        shape: &AttnShape,
        cu_seqlens: &[i32],
    ) -> Result<(Vec<HostTensor>, Vec<HostTensor>, Vec<HostTensor>)> {
        let sp = group.world;
        self.validate(shape.n_q, shape.n_kv, sp)?;
        let local = self.local_shape(shape, sp);
        // recompute replay of the forward, as the checkpointed trainer does
        let qf = a2a_seq_to_head_into(group, q, arena)?;
        let kf = a2a_seq_to_head_into(group, k, arena)?;
        let vf = a2a_seq_to_head_into(group, v, arena)?;
        let mut o_full = Vec::with_capacity(sp);
        let mut lse_full = Vec::with_capacity(sp);
        for r in 0..sp {
            let (o, lse) = dense_attention(&qf[r], &kf[r], &vf[r], &local, cu_seqlens, arena)?;
            o_full.push(o);
            lse_full.push(lse);
        }
        let o_replay = a2a_head_to_seq_into(group, &o_full, shape.n_q, false, arena)?;
        arena.recycle_all(o_replay);
        let d_of = a2a_seq_to_head_into(group, d_o, arena)?;
        let (mut dqf, mut dkf, mut dvf) =
            (Vec::with_capacity(sp), Vec::with_capacity(sp), Vec::with_capacity(sp));
        for r in 0..sp {
            let (dq, dk, dv) = dense_attention_bwd(
                &qf[r], &kf[r], &vf[r], &o_full[r], &lse_full[r], &d_of[r], &local, cu_seqlens,
                arena,
            )?;
            dqf.push(dq);
            dkf.push(dk);
            dvf.push(dv);
        }
        let d_q = a2a_head_to_seq_into(group, &dqf, shape.n_q, true, arena)?;
        let d_k = a2a_head_to_seq_into(group, &dkf, shape.n_kv, true, arena)?;
        let d_v = a2a_head_to_seq_into(group, &dvf, shape.n_kv, true, arena)?;
        for bufs in [qf, kf, vf, o_full, lse_full, d_of, dqf, dkf, dvf] {
            arena.recycle_all(bufs);
        }
        Ok((d_q, d_k, d_v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(sp: usize, ssh: usize, heads: usize, d: usize) -> Vec<HostTensor> {
        // value encodes (rank, seq, head, dim) for exact relayout checks
        (0..sp)
            .map(|r| {
                let mut data = Vec::with_capacity(ssh * heads * d);
                for s in 0..ssh {
                    for h in 0..heads {
                        for k in 0..d {
                            data.push(
                                (r * 1000 + s * 100 + h * 10 + k) as f32,
                            );
                        }
                    }
                }
                HostTensor::f32(vec![ssh, heads, d], data)
            })
            .collect()
    }

    #[test]
    fn seq_to_head_places_rows_globally() {
        let (sp, ssh, heads, d) = (2, 2, 4, 1);
        let g = Group::new(sp);
        let out = a2a_seq_to_head(&g, &mk(sp, ssh, heads, d)).unwrap();
        // dst rank 1, global seq row 2 (= src rank 1, local row 0), its
        // head block starts at head 2
        let r1 = out[1].as_f32().unwrap();
        // [seq=4, h_out=2, d=1]; row 2, local head 0 = src(1, s0, h2)
        assert_eq!(r1[(2 * 2 + 0) * 1], 1020.0);
        assert_eq!(r1[(2 * 2 + 1) * 1], 1030.0);
        // dst rank 0 row 1 head 1 = src(0, s1, h1)
        let r0 = out[0].as_f32().unwrap();
        assert_eq!(r0[(1 * 2 + 1) * 1], 110.0);
    }

    #[test]
    fn round_trip_is_identity() {
        for (sp, heads) in [(1, 4), (2, 4), (4, 4), (2, 2), (4, 8)] {
            let (ssh, d) = (4, 3);
            let g = Group::new(sp);
            let orig = mk(sp, ssh, heads, d);
            let full = a2a_seq_to_head(&g, &orig).unwrap();
            let back = a2a_head_to_seq(&g, &full, heads, false).unwrap();
            assert_eq!(orig, back, "sp={sp} heads={heads}");
        }
    }

    #[test]
    fn sp1_passthrough_is_identity_and_accounted() {
        let g = Group::new(1);
        let orig = mk(1, 4, 8, 2);
        let full = a2a_seq_to_head(&g, &orig).unwrap();
        assert_eq!(full[0].as_f32().unwrap(), orig[0].as_f32().unwrap());
        assert_eq!(full[0].shape(), &[4, 8, 2]);
        assert_eq!(g.stats().all_to_all_bytes, (4 * 8 * 2 * 4) as u64);
    }

    #[test]
    fn relayout_reuses_arena_buffers_across_calls() {
        let (sp, ssh, heads, d) = (4, 4, 8, 3);
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        let input = mk(sp, ssh, heads, d);
        for cycle in 0..3 {
            let full = a2a_seq_to_head_into(&g, &input, &arena).unwrap();
            let back = a2a_head_to_seq_into(&g, &full, heads, false, &arena).unwrap();
            arena.recycle_all(full);
            assert_eq!(back, input);
            arena.recycle_all(back);
            if cycle == 0 {
                assert_eq!(arena.misses(), 2 * sp as u64, "first cycle allocates");
            }
        }
        // cycles 1 and 2 were served entirely from the pool
        assert_eq!(arena.misses(), 2 * sp as u64);
        assert_eq!(arena.hits(), 4 * sp as u64);
    }

    #[test]
    fn replication_shares_source_heads() {
        // kv = 2 heads, sp = 4: ranks (0,1) see head 0; (2,3) see head 1
        let (sp, ssh, heads, d) = (4, 2, 2, 1);
        let g = Group::new(sp);
        let out = a2a_seq_to_head(&g, &mk(sp, ssh, heads, d)).unwrap();
        assert_eq!(out[0], out[1]);
        assert_eq!(out[2], out[3]);
        assert_ne!(out[0], out[2]);
    }

    #[test]
    fn replication_backward_sums() {
        let (sp, seq, d) = (4, 4, 1);
        // each rank holds [seq, 1, d] of ones * (rank+1)
        let shards: Vec<HostTensor> = (0..sp)
            .map(|r| HostTensor::f32(vec![seq, 1, d], vec![(r + 1) as f32; seq]))
            .collect();
        let g = Group::new(sp);
        let back = a2a_head_to_seq(&g, &shards, 2, true).unwrap();
        for dst in 0..sp {
            let data = back[dst].as_f32().unwrap();
            // head 0 <- ranks 0+1 = 3; head 1 <- ranks 2+3 = 7
            assert_eq!(data[0], 3.0);
            assert_eq!(data[1], 7.0);
        }
    }

    #[test]
    fn paper_head_shard_examples() {
        // §3.2.1 worked examples
        assert_eq!(heads_per_rank(32, 8), 4);
        assert_eq!(heads_per_rank(8, 8), 1);
        assert_eq!(heads_per_rank(8, 32), 1); // replicated
        assert_eq!(heads_per_rank(4, 8), 1);  // replicated
        assert!(sp_is_valid(32, 8, 8));
        assert!(sp_is_valid(32, 8, 32));
        assert!(!sp_is_valid(32, 8, 3));      // 32 % 3 != 0
        assert!(!sp_is_valid(9, 3, 8));       // §7.1: 9 q heads -> sp 1/3/9
        assert!(sp_is_valid(9, 3, 3));
        assert!(sp_is_valid(9, 3, 9));
    }

    #[test]
    fn a2a_byte_accounting_matches_closed_form() {
        let (sp, ssh, heads, d) = (4, 8, 8, 16);
        let g = Group::new(sp);
        let q = mk(sp, ssh, heads, d);
        let full = a2a_seq_to_head(&g, &q).unwrap();
        let _ = a2a_head_to_seq(&g, &full, heads, false).unwrap();
        // each direction moves seq*heads*d floats total across ranks
        let logical = (sp * ssh * heads * d * 4) as u64;
        assert_eq!(g.stats().all_to_all_bytes, 2 * logical);
    }

    #[test]
    fn validate_ulysses_errors_are_actionable() {
        assert!(validate_ulysses(32, 8, 8).is_ok());
        assert!(validate_ulysses(8, 4, 16).is_ok(), "kv replication regime");
        let err = validate_ulysses(8, 8, 16).unwrap_err().to_string();
        assert!(err.contains("sp=16 > 8 query heads"), "{err}");
        assert!(err.contains("ring plan"), "must point at the fix: {err}");
        let err = validate_ulysses(9, 3, 8).unwrap_err().to_string();
        assert!(err.contains("not divisible"), "{err}");
        assert!(err.contains("ring"), "{err}");
    }

    #[test]
    fn ulysses_plan_ledger_matches_comm_closed_form() {
        use crate::coordinator::plan::AttnShape;
        let (sp, ssh, n_q, n_kv, d) = (4, 4, 8, 2, 8);
        let seq = sp * ssh;
        let shape = AttnShape::new(n_q, n_kv, d);
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        let q = mk(sp, ssh, n_q, d);
        let k = mk(sp, ssh, n_kv, d);
        let v = mk(sp, ssh, n_kv, d);
        let plan = UlyssesPlan;
        let cu = [0, seq as i32];
        let (o, saved) = plan
            .attention_forward(&g, &arena, &q, &k, &v, &shape, &cu)
            .unwrap();
        let _ = plan
            .attention_backward(&g, &arena, &q, &k, &v, &o, &saved, &shape, &cu)
            .unwrap();
        assert_eq!(
            g.stats().all_to_all_bytes,
            plan.comm_bytes_per_layer(seq, &shape, sp, 4),
            "ledger must match the closed form"
        );
        assert_eq!(g.stats().send_recv_bytes, 0, "ulysses never uses the ring wire");
    }
}
