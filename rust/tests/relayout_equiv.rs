//! Relayout equivalence suite.
//!
//! The zero-copy Ulysses relayout (`a2a_seq_to_head_into` /
//! `a2a_head_to_seq_into`) replaced the original naive per-(dst, src, s)
//! nested loops. The original implementation is RETAINED HERE as the
//! reference — `ref_a2a_seq_to_head` / `ref_a2a_head_to_seq` below are a
//! verbatim port of the pre-rewrite code — and the new path must be
//! bit-identical to it across every regime the coordinator exercises:
//! sp ∈ {1, 2, 4, 8}, head partitioning (`n_heads >= sp`) and kv
//! replication (`n_kv < sp`, including the `sum_replicas` backward), and
//! inputs derived from the packed-sequence shard adapter.
//!
//! Also pinned here: the steady-state allocation-freedom of the arena
//! (≥3 consecutive train-step relayout cycles with zero pool misses
//! after the first), and the determinism of the scoped-thread rank
//! executor's `CommStats` accounting.
//!
//! Known bit-identity exception (documented in `ulysses.rs`): on an
//! input element that is exactly `-0.0`, the fused replica-sum's first
//! write preserves the sign bit where the reference's `0.0 + (-0.0)`
//! yields `+0.0`. Numerically equal; the Box-Muller inputs here cannot
//! produce `-0.0`, so `to_bits` comparison is sound for this suite.

use alst::collectives::{CommStats, Group};
use alst::coordinator::pipeline::run_ranks;
use alst::coordinator::ulysses::{
    a2a_head_to_seq, a2a_head_to_seq_into, a2a_seq_to_head, a2a_seq_to_head_into,
    head_start, heads_per_rank, relayout_step_cycle,
};
use alst::packing::{shard_packed, Document, PackedSequence};
use alst::runtime::{HostTensor, ScratchArena};
use alst::util::rng::Rng;

// ---------------------------------------------------------------------------
// The naive nested-loop reference (the pre-rewrite implementation)
// ---------------------------------------------------------------------------

fn ref_a2a_seq_to_head(shards: &[HostTensor]) -> Vec<HostTensor> {
    let sp = shards.len();
    let dims = shards[0].shape();
    let (ssh, n_heads, d) = (dims[0], dims[1], dims[2]);
    let h_out = heads_per_rank(n_heads, sp);
    let seq = ssh * sp;
    let mut out = Vec::with_capacity(sp);
    for dst in 0..sp {
        let h0 = if n_heads >= sp { dst * h_out } else { head_start(dst, n_heads, sp) };
        let mut data = vec![0f32; seq * h_out * d];
        for (src, shard) in shards.iter().enumerate() {
            let src_data = shard.as_f32().unwrap();
            for s in 0..ssh {
                let from = (s * n_heads + h0) * d;
                let to = ((src * ssh + s) * h_out) * d;
                data[to..to + h_out * d].copy_from_slice(&src_data[from..from + h_out * d]);
            }
        }
        out.push(HostTensor::f32(vec![seq, h_out, d], data));
    }
    out
}

fn ref_a2a_head_to_seq(
    shards: &[HostTensor],
    n_heads_total: usize,
    sum_replicas: bool,
) -> Vec<HostTensor> {
    let sp = shards.len();
    let dims = shards[0].shape();
    let (seq, h_sh, d) = (dims[0], dims[1], dims[2]);
    let ssh = seq / sp;
    let mut out = Vec::with_capacity(sp);
    for dst in 0..sp {
        let mut data = vec![0f32; ssh * n_heads_total * d];
        for (src, shard) in shards.iter().enumerate() {
            let h0 = if n_heads_total >= sp {
                src * h_sh
            } else {
                head_start(src, n_heads_total, sp)
            };
            let src_data = shard.as_f32().unwrap();
            for s in 0..ssh {
                let from = ((dst * ssh + s) * h_sh) * d;
                let to = (s * n_heads_total + h0) * d;
                let src_slice = &src_data[from..from + h_sh * d];
                let dst_slice = &mut data[to..to + h_sh * d];
                if sum_replicas {
                    for (a, b) in dst_slice.iter_mut().zip(src_slice) {
                        *a += b;
                    }
                } else {
                    dst_slice.copy_from_slice(src_slice);
                }
            }
        }
        out.push(HostTensor::f32(vec![ssh, n_heads_total, d], data));
    }
    out
}

fn random_shards(rng: &mut Rng, sp: usize, ssh: usize, heads: usize, d: usize) -> Vec<HostTensor> {
    (0..sp)
        .map(|_| HostTensor::f32(vec![ssh, heads, d], rng.normal_vec(ssh * heads * d, 1.0)))
        .collect()
}

/// Assert two tensor sets are bit-identical (f32 bit patterns, not just
/// numeric equality).
fn assert_bit_identical(a: &[HostTensor], b: &[HostTensor], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: rank count");
    for (r, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.shape(), y.shape(), "{ctx}: shape on rank {r}");
        let (xs, ys) = (x.as_f32().unwrap(), y.as_f32().unwrap());
        for (i, (p, q)) in xs.iter().zip(ys).enumerate() {
            assert_eq!(
                p.to_bits(),
                q.to_bits(),
                "{ctx}: rank {r} elem {i}: {p} vs {q}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Equivalence across every sp / head regime
// ---------------------------------------------------------------------------

#[test]
fn zero_copy_seq_to_head_matches_reference_all_regimes() {
    let mut rng = Rng::new(11);
    for sp in [1usize, 2, 4, 8] {
        // partitioned (n_heads >= sp) and replicated (n_heads < sp) regimes
        for heads in [sp, sp * 2, sp * 4, 1, (sp / 2).max(1), (sp * 3) / 4] {
            if heads == 0 || (heads >= sp && heads % sp != 0) {
                continue;
            }
            for (ssh, d) in [(1usize, 1usize), (4, 3), (6, 8)] {
                let shards = random_shards(&mut rng, sp, ssh, heads, d);
                let g = Group::new(sp);
                let arena = ScratchArena::new();
                let want = ref_a2a_seq_to_head(&shards);
                let got = a2a_seq_to_head_into(&g, &shards, &arena).unwrap();
                assert_bit_identical(
                    &want,
                    &got,
                    &format!("seq->head sp={sp} heads={heads} ssh={ssh} d={d}"),
                );
            }
        }
    }
}

#[test]
fn zero_copy_head_to_seq_matches_reference_all_regimes() {
    let mut rng = Rng::new(23);
    for sp in [1usize, 2, 4, 8] {
        for heads in [sp, sp * 4, 1, (sp / 2).max(1)] {
            if heads >= sp && heads % sp != 0 {
                continue;
            }
            let h_sh = heads_per_rank(heads, sp);
            for (ssh, d) in [(2usize, 1usize), (5, 4)] {
                let seq = ssh * sp;
                // head-layout inputs: [seq, h_sh, d] per rank
                let shards = random_shards(&mut rng, sp, seq, h_sh, d);
                for sum_replicas in [false, true] {
                    let g = Group::new(sp);
                    let arena = ScratchArena::new();
                    let want = ref_a2a_head_to_seq(&shards, heads, sum_replicas);
                    let got =
                        a2a_head_to_seq_into(&g, &shards, heads, sum_replicas, &arena).unwrap();
                    assert_bit_identical(
                        &want,
                        &got,
                        &format!(
                            "head->seq sp={sp} heads={heads} ssh={ssh} d={d} sum={sum_replicas}"
                        ),
                    );
                }
            }
        }
    }
}

#[test]
fn kv_replication_backward_is_bit_identical_to_reference() {
    // The fused copy-first/accumulate-rest pass must reproduce the naive
    // zero-init-then-add sums exactly: same addends, same (ascending
    // source rank) order, so the same f32 rounding.
    let mut rng = Rng::new(37);
    for (sp, n_kv) in [(4usize, 2usize), (8, 4), (8, 2), (8, 1), (2, 1), (8, 6)] {
        assert!(n_kv < sp);
        let (ssh, d) = (3usize, 5usize);
        let seq = ssh * sp;
        let shards = random_shards(&mut rng, sp, seq, 1, d);
        let want = ref_a2a_head_to_seq(&shards, n_kv, true);
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        let got = a2a_head_to_seq_into(&g, &shards, n_kv, true, &arena).unwrap();
        assert_bit_identical(&want, &got, &format!("replica-sum sp={sp} n_kv={n_kv}"));
    }
}

#[test]
fn round_trip_through_wrappers_matches_reference_round_trip() {
    // The compat wrappers (fresh one-shot arenas) behave exactly like the
    // old entry points, byte accounting included.
    let mut rng = Rng::new(5);
    for (sp, heads) in [(2usize, 4usize), (4, 4), (8, 16)] {
        let shards = random_shards(&mut rng, sp, 4, heads, 3);
        let g_new = Group::new(sp);
        let full_new = a2a_seq_to_head(&g_new, &shards).unwrap();
        let back_new = a2a_head_to_seq(&g_new, &full_new, heads, false).unwrap();
        let full_ref = ref_a2a_seq_to_head(&shards);
        let back_ref = ref_a2a_head_to_seq(&full_ref, heads, false);
        assert_bit_identical(&full_new, &full_ref, "wrapper fwd");
        assert_bit_identical(&back_new, &back_ref, "wrapper inv");
        assert_bit_identical(&back_new, &shards, "round trip identity");
        // ledger: both directions account the full logical volume
        let logical = shards.iter().map(|t| t.size_bytes() as u64).sum::<u64>();
        assert_eq!(g_new.stats().all_to_all_bytes, 2 * logical);
    }
}

// ---------------------------------------------------------------------------
// Packed-sequence shard adapter feeding the relayout
// ---------------------------------------------------------------------------

#[test]
fn packed_shard_adapter_inputs_relayout_identically() {
    // Build per-rank "qkv" tensors deterministically from a packed
    // sequence's shard metadata (ids + per-document positions), the way
    // the embedding stage would, and check the zero-copy path on them —
    // ties the packed data path to the relayout equivalence suite.
    let docs: Vec<Document> = [7usize, 3, 6, 9, 7]
        .iter()
        .enumerate()
        .map(|(i, &n)| Document::new(i as u64, (0..n as i32).map(|t| 100 * i as i32 + t).collect()))
        .collect();
    let p = PackedSequence::from_documents(&docs).unwrap();
    for sp in [1usize, 2, 4, 8] {
        if p.len() % sp != 0 {
            continue;
        }
        let shards = shard_packed(&p, sp);
        let (heads, d) = (4usize, 2usize);
        let qkv: Vec<HostTensor> = shards
            .iter()
            .map(|s| {
                let ssh = s.batch.ids.len();
                let mut data = Vec::with_capacity(ssh * heads * d);
                for (i, (&id, &pos)) in
                    s.batch.ids.iter().zip(&s.batch.positions).enumerate()
                {
                    for h in 0..heads {
                        for k in 0..d {
                            data.push(
                                id as f32 * 0.01
                                    + pos as f32
                                    + (h * d + k) as f32 * 10.0
                                    + i as f32 * 0.001,
                            );
                        }
                    }
                }
                HostTensor::f32(vec![ssh, heads, d], data)
            })
            .collect();
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        let want = ref_a2a_seq_to_head(&qkv);
        let got = a2a_seq_to_head_into(&g, &qkv, &arena).unwrap();
        assert_bit_identical(&want, &got, &format!("packed adapter sp={sp}"));
        let back = a2a_head_to_seq_into(&g, &got, heads, false, &arena).unwrap();
        assert_bit_identical(&back, &qkv, &format!("packed adapter inverse sp={sp}"));
    }
}

// ---------------------------------------------------------------------------
// The ParallelPlan trait over the same relayouts
// ---------------------------------------------------------------------------

#[test]
fn ulysses_plan_is_the_manual_relayout_dense_composition() {
    // The plan-trait entry point must be exactly the composition this
    // suite already pins piecewise: reference seq->head relayout, per-rank
    // dense attention over the head shard, reference head->seq relayout.
    // Bit-identical — the trait refactor is behavior-preserving.
    use alst::config::PlanKind;
    use alst::coordinator::plan::{dense_attention, plan_for, AttnShape};

    let mut rng = Rng::new(61);
    for (sp, n_q, n_kv) in [(2usize, 4usize, 4usize), (4, 8, 2), (8, 8, 8)] {
        let (ssh, d) = (3usize, 4usize);
        let seq = ssh * sp;
        let cu = [0, seq as i32];
        let qs = random_shards(&mut rng, sp, ssh, n_q, d);
        let ks = random_shards(&mut rng, sp, ssh, n_kv, d);
        let vs = random_shards(&mut rng, sp, ssh, n_kv, d);

        // manual composition from this suite's reference relayouts
        let local = AttnShape::new(heads_per_rank(n_q, sp), heads_per_rank(n_kv, sp), d);
        let arena = ScratchArena::new();
        let q_full = ref_a2a_seq_to_head(&qs);
        let k_full = ref_a2a_seq_to_head(&ks);
        let v_full = ref_a2a_seq_to_head(&vs);
        let o_head: Vec<HostTensor> = (0..sp)
            .map(|r| {
                dense_attention(&q_full[r], &k_full[r], &v_full[r], &local, &cu, &arena)
                    .unwrap()
                    .0
            })
            .collect();
        let want = ref_a2a_head_to_seq(&o_head, n_q, false);

        let plan = plan_for(PlanKind::Ulysses);
        let g = Group::new(sp);
        let shape = AttnShape::new(n_q, n_kv, d);
        let (got, saved) = plan
            .attention_forward(&g, &arena, &qs, &ks, &vs, &shape, &cu)
            .unwrap();
        assert_bit_identical(
            &want,
            &got,
            &format!("plan vs manual composition sp={sp} n_q={n_q} n_kv={n_kv}"),
        );
        saved.recycle(&arena);
    }
}

// ---------------------------------------------------------------------------
// Steady-state allocation freedom (acceptance criterion)
// ---------------------------------------------------------------------------

#[test]
fn three_step_relayout_cycles_are_allocation_free_after_the_first() {
    // Drive the trainer's relayout schedule (the SHARED driver
    // `ulysses::relayout_step_cycle` — also the bench_pipeline
    // denominator, so the schedule can't drift between the two) through
    // one arena for 3 consecutive steps. After the first cycle populates
    // the pool, the pool must never miss again: zero new allocations at
    // steady state.
    let (sp, ssh, n_q, n_kv, d, n_layers) = (4usize, 8usize, 8usize, 2usize, 16usize, 3usize);
    let mut rng = Rng::new(99);
    let arena = ScratchArena::new();
    let g = Group::new(sp);
    let q = random_shards(&mut rng, sp, ssh, n_q, d);
    let kv = random_shards(&mut rng, sp, ssh, n_kv, d);
    let mut misses_after_cycle = Vec::new();
    for _step in 0..3 {
        relayout_step_cycle(&g, &arena, &q, &kv, n_layers, n_q, n_kv);
        misses_after_cycle.push(arena.misses());
    }
    assert!(misses_after_cycle[0] > 0, "first cycle must populate the pool");
    assert_eq!(
        misses_after_cycle[0], misses_after_cycle[1],
        "cycle 2 allocated: relayout is not allocation-free at steady state"
    );
    assert_eq!(
        misses_after_cycle[1], misses_after_cycle[2],
        "cycle 3 allocated: relayout is not allocation-free at steady state"
    );
    assert!(arena.hits() > 0);
    assert!(
        arena.hit_rate() > 0.5,
        "steady state should be pool-dominated: {}",
        arena.hit_rate()
    );
}

// ---------------------------------------------------------------------------
// Threaded rank executor: deterministic accounting
// ---------------------------------------------------------------------------

#[test]
fn threaded_rank_loop_commstats_match_serial_byte_for_byte() {
    let sp = 8usize;
    let drive = |parallel: bool| -> CommStats {
        let g = Group::new(sp);
        // several rounds of rank-parallel work that hammers the ledger
        // from every thread, with rank-dependent volumes
        for round in 0..5u64 {
            let out = run_ranks(sp, parallel, |r| {
                let r = r as u64;
                g.account_gather(1_000 * (r + 1) + round)?;
                g.account_all_to_all(77 * (r + 1))?;
                g.account_reduce_scatter(13 + r * r)?;
                Ok(r)
            })
            .unwrap();
            assert_eq!(out, (0..sp as u64).collect::<Vec<_>>());
            // a collective between the per-rank phases, as in the step loop
            let vals: Vec<f32> = (0..sp).map(|r| r as f32).collect();
            g.all_reduce_scalars(&vals).unwrap();
        }
        g.stats()
    };
    let serial = drive(false);
    let threaded = drive(true);
    assert_eq!(serial, threaded, "CommStats must be byte-identical");
    assert!(serial.ops > 0 && serial.total_bytes() > 0);
}

#[test]
fn run_ranks_propagates_errors_and_preserves_rank_order() {
    // results come back in rank order regardless of completion order
    let out = run_ranks(6, true, |r| Ok(r * 10)).unwrap();
    assert_eq!(out, vec![0, 10, 20, 30, 40, 50]);
    // an error from any rank surfaces
    let err = run_ranks(4, true, |r| {
        if r == 2 {
            Err(anyhow::anyhow!("rank 2 failed"))
        } else {
            Ok(r)
        }
    });
    assert!(err.is_err());
    // serial path behaves identically
    assert_eq!(run_ranks(3, false, |r| Ok(r + 1)).unwrap(), vec![1, 2, 3]);
}
