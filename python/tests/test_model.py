"""L2 model tests: the staged Ulysses pipeline equals the monolithic graph.

The headline assertion (paper Figure 13 at the algorithm level): for any SP
degree, the sharded stage pipeline — with its all-to-alls, kv replication,
checkpoint recompute, and pre-shifted labels — produces the same loss and
the same gradients as `jax.grad` of the unsharded model.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from tests import sp_sim

TINY = M.CONFIGS["tiny"]
SEQ = 128


@pytest.fixture(scope="module")
def setup():
    cfg = TINY
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # non-zero wd so MLP gradients flow
    for lp in params["layers"]:
        lp["wd"] = jax.random.normal(jax.random.PRNGKey(7), lp["wd"].shape) * 0.02
    ids = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (SEQ,), 0, cfg.vocab),
        np.int32)
    labels = np.concatenate([ids[1:], [M.IGNORE_INDEX]]).astype(np.int32)
    ref_loss, ref_grads = jax.value_and_grad(
        lambda p: M.full_loss(cfg, p, jnp.asarray(ids), jnp.asarray(labels))
    )(params)
    return cfg, params, ids, float(ref_loss), ref_grads


@pytest.mark.parametrize("sp", [1, 2, 4])
def test_pipeline_loss_matches_full_graph(setup, sp):
    cfg, params, ids, ref_loss, _ = setup
    loss, _ = sp_sim.run_step(cfg, params, ids, sp)
    np.testing.assert_allclose(loss, ref_loss, rtol=1e-5)


@pytest.mark.parametrize("sp", [1, 2, 4])
def test_pipeline_grads_match_full_graph(setup, sp):
    cfg, params, ids, _, ref_grads = setup
    _, grads = sp_sim.run_step(cfg, params, ids, sp)
    np.testing.assert_allclose(
        grads["embed"], np.asarray(ref_grads["embed"]), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(
        grads["unembed"], np.asarray(ref_grads["unembed"]), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(
        grads["lnf"], np.asarray(ref_grads["lnf"]), rtol=1e-4, atol=1e-6)
    for li in range(cfg.n_layers):
        for name in ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd"):
            np.testing.assert_allclose(
                grads["layers"][li][name],
                np.asarray(ref_grads["layers"][li][name]),
                rtol=1e-3, atol=1e-5,
                err_msg=f"layer {li} {name} sp mismatch")


def test_kernel_swap_is_transparent(setup):
    """Paper's attention-agnostic claim: pallas vs ref kernels, same loss."""
    cfg, params, ids, ref_loss, _ = setup
    cfg_ref = dataclasses.replace(cfg, kernels="ref")
    loss, _ = sp_sim.run_step(cfg_ref, params, ids, 2)
    np.testing.assert_allclose(loss, ref_loss, rtol=1e-5)


def test_shift_labels_paper_example():
    """§4.3 worked example: [1..8], sp=2 -> [2 3 4 5] [6 7 8 -100]."""
    ids = np.arange(1, 9, dtype=np.int32)
    shards = sp_sim.shift_and_shard_labels(ids, 2)
    np.testing.assert_array_equal(shards[0], [2, 3, 4, 5])
    np.testing.assert_array_equal(shards[1], [6, 7, 8, M.IGNORE_INDEX])


def test_naive_shard_then_shift_would_drop_tokens():
    """The failure mode §4.3 fixes: shifting per-shard loses a label."""
    ids = np.arange(1, 9, dtype=np.int32)
    naive = [np.concatenate([s[1:], [M.IGNORE_INDEX]])
             for s in np.split(ids, 2)]
    assert 5 not in np.concatenate(naive)          # token 5 dropped
    good = np.concatenate(sp_sim.shift_and_shard_labels(ids, 2))
    assert 5 in good


def test_kv_head_start_paper_examples():
    """§3.2.1: 32q/8kv sp=8 -> 1 kv each; sp=32 -> replicated; 32q/4kv sp=8."""
    # 32 q, 8 kv, sp=8: ranks own kv heads 0..7
    assert [sp_sim.kv_head_start(r, 8, 8) for r in range(8)] == list(range(8))
    # 32 q, 8 kv, sp=32: 4 ranks share each kv head
    starts = [sp_sim.kv_head_start(r, 8, 32) for r in range(32)]
    assert starts == [r // 4 for r in range(32)]
    # 32 q, 4 kv, sp=8: 2 ranks share each kv head
    starts = [sp_sim.kv_head_start(r, 4, 8) for r in range(8)]
    assert starts == [r // 2 for r in range(8)]


def test_head_shard_divisibility_limits():
    """§7.1: q_heads must be divisible by sp."""
    cfg = TINY  # 4 q heads
    assert cfg.head_shard(2) == (2, 1)
    assert cfg.head_shard(4) == (1, 1)
    with pytest.raises(AssertionError):
        cfg.head_shard(3)


def test_a2a_round_trip_identity():
    rng = np.random.default_rng(0)
    sp, ssh, heads, d = 4, 16, 8, 4
    shards = [rng.normal(size=(ssh, heads, d)).astype(np.float32)
              for _ in range(sp)]
    full = sp_sim.a2a_seq_to_head(shards, heads // sp, sp)
    back = sp_sim.a2a_head_to_seq(full, heads, sp)
    for a, b in zip(shards, back):
        np.testing.assert_array_equal(a, b)


def test_a2a_replication_backward_sums():
    """kv grads from replicated heads must sum across consumer ranks."""
    sp, ssh, n_kv, d = 4, 8, 2, 4
    full_shards = [np.ones((sp * ssh, 1, d), np.float32) * (r + 1)
                   for r in range(sp)]
    back = sp_sim.a2a_head_to_seq(full_shards, n_kv, sp, sum_replicas=True)
    # kv head 0 receives from ranks 0,1 (1+2=3); head 1 from ranks 2,3 (3+4=7)
    for dst in range(sp):
        np.testing.assert_allclose(back[dst][:, 0, :], 3.0)
        np.testing.assert_allclose(back[dst][:, 1, :], 7.0)


def test_rope_depends_on_global_positions():
    """A shard must use its global offset — pos 0-base would be wrong."""
    cfg = TINY
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    h = jax.random.normal(jax.random.PRNGKey(4), (32, cfg.hidden))
    lp = params["layers"][0]
    q1, _, _ = M.pre_attn_fwd(cfg, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                              h, jnp.arange(32, dtype=jnp.int32))
    q2, _, _ = M.pre_attn_fwd(cfg, lp["ln1"], lp["wq"], lp["wk"], lp["wv"],
                              h, jnp.arange(32, 64, dtype=jnp.int32))
    assert not np.allclose(np.asarray(q1), np.asarray(q2), atol=1e-4)


def test_params_count_tracks_config():
    cfg = M.CONFIGS["e2e-100m"]
    assert 90e6 < cfg.params_count() < 115e6
    assert 20e6 < M.CONFIGS["e2e-25m"].params_count() < 32e6


def test_rope_relative_shift_invariance():
    """RoPE attention scores depend only on RELATIVE positions: shifting
    all positions by a constant must not change q.k scores — this is what
    makes per-shard global positions compose correctly across ranks."""
    cfg = TINY
    d = cfg.head_dim
    q = jax.random.normal(jax.random.PRNGKey(0), (8, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (8, 1, d))
    def scores(shift):
        pos = jnp.arange(8, dtype=jnp.int32) + shift
        qr = M.rope(q, pos, cfg.rope_theta)
        kr = M.rope(k, pos, cfg.rope_theta)
        return jnp.einsum("qhd,khd->qk", qr, kr)
    np.testing.assert_allclose(scores(0), scores(1000), rtol=1e-4, atol=1e-4)


def test_rope_preserves_norm():
    """Rotations are isometries: token vectors keep their length."""
    cfg = TINY
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 2, cfg.head_dim))
    pos = jnp.arange(16, dtype=jnp.int32) * 37
    y = M.rope(x, pos, cfg.rope_theta)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1),
        rtol=1e-5, atol=1e-5)


def test_loss_normalization_with_uneven_ignore_across_shards():
    """The cross-shard mean must weight shards by their VALID token count,
    not per-shard means — §4.3's reduction done right."""
    cfg = TINY
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    h = jax.random.normal(jax.random.PRNGKey(6), (64, cfg.hidden))
    labels = jax.random.randint(jax.random.PRNGKey(7), (64,), 0, cfg.vocab)
    # ignore a big asymmetric chunk in the second half
    labels = labels.at[40:].set(M.IGNORE_INDEX).astype(jnp.int32)
    full = M.loss_fwd(cfg, params["lnf"], params["unembed"], h, labels)
    want = float(full[0]) / float(full[1])
    # shard into 2, reduce like the coordinator does
    parts = [
        M.loss_fwd(cfg, params["lnf"], params["unembed"], h[:32], labels[:32]),
        M.loss_fwd(cfg, params["lnf"], params["unembed"], h[32:], labels[32:]),
    ]
    got = sum(float(p[0]) for p in parts) / sum(float(p[1]) for p in parts)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # and per-shard-mean averaging would be WRONG here (8 vs 32 valid)
    naive = float(np.mean([float(p[0]) / max(float(p[1]), 1) for p in parts]))
    assert abs(naive - want) > 1e-4


def test_embed_bwd_scatters_only_used_rows():
    cfg = TINY
    params = M.init_params(cfg, jax.random.PRNGKey(8))
    ids = jnp.asarray([3, 3, 7], jnp.int32)
    d_h = jnp.ones((3, cfg.hidden))
    (d_embed,) = M.embed_bwd(cfg, params["embed"], ids, d_h)
    d = np.asarray(d_embed)
    assert np.allclose(d[3], 2.0)       # row used twice accumulates
    assert np.allclose(d[7], 1.0)
    mask = np.ones(cfg.vocab, bool); mask[[3, 7]] = False
    assert np.allclose(d[mask], 0.0)
