//! End-to-end step latency through the real PJRT pipeline (tiny config),
//! plus the L3-overhead split the §Perf log tracks: how much of a step is
//! PJRT execution vs coordinator marshaling/relayout.
//!
//! Requires `make artifacts` (skips gracefully otherwise).

use std::path::Path;

use alst::coordinator::dataloader::{MarkovSource, UlyssesDataLoader};
use alst::coordinator::pipeline::{Trainer, TrainerOptions};
use alst::runtime::Manifest;
use alst::util::bench::bench;

fn main() {
    let dir = Manifest::artifact_dir(Path::new("artifacts"), "tiny", 2, 256);
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP bench_pipeline: run `make artifacts` first");
        return;
    }
    println!("bench_pipeline: tiny config, sp=2, seq=256 (PJRT CPU)\n");

    let mut trainer = Trainer::new(&dir, TrainerOptions::default()).unwrap();
    let mut loader = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 1), 2);
    let (ids, _) = loader.next();

    // eval (forward only)
    let ids_c = ids.clone();
    trainer.eval_loss(&ids_c).unwrap(); // warm the executable cache
    trainer.engine.reset_stats();
    let r = bench(
        "eval_loss (fwd only)",
        1,
        10,
        std::time::Duration::from_secs(2),
        || {
            trainer.eval_loss(&ids_c).unwrap();
        },
    );
    let st = trainer.engine.stats();
    let exec_frac = st.exec_time.as_secs_f64()
        / (r.mean.as_secs_f64() * r.iters as f64);
    println!(
        "    -> {} PJRT executions; exec {:.0}% / marshal {:.0}% of step",
        st.executions as usize / r.iters,
        100.0 * exec_frac,
        100.0 * st.marshal_time.as_secs_f64() / (r.mean.as_secs_f64() * r.iters as f64),
    );

    // full train step (fwd + recompute + bwd + optimizer)
    trainer.engine.reset_stats();
    let r = bench(
        "train_step (fwd+bwd+adamw)",
        1,
        10,
        std::time::Duration::from_secs(3),
        || {
            trainer.train_step(&ids).unwrap();
        },
    );
    let st = trainer.engine.stats();
    println!(
        "    -> {} PJRT executions/step; exec {:.1}ms marshal {:.1}ms per step",
        st.executions as usize / r.iters,
        st.exec_time.as_secs_f64() * 1e3 / r.iters as f64,
        st.marshal_time.as_secs_f64() * 1e3 / r.iters as f64,
    );
}
