//! Runtime: load AOT artifacts (HLO text) and execute them on PJRT.
//!
//! Python is build-time only; after `make artifacts` the rust binary is
//! self-contained. The interchange is HLO *text* (the image's
//! xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id protos; the text
//! parser reassigns ids — see /opt/xla-example/README.md).

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::Engine;
pub use manifest::{Manifest, ParamLayout, StageIo, TensorMeta};
pub use tensor::{
    accumulate_rows, copy_rows, Dtype, HostTensor, ScratchArena, TensorData,
};
