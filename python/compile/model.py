"""L2: the Llama-style causal transformer, written as Ulysses stage functions.

The model is cut exactly at the paper's sequence-parallel boundaries
(§3.2): everything outside attention operates on a *sequence shard*
`[S/sp, ...]` with no cross-token dependencies; attention operates on the
*full sequence* for a *head shard* `[S, H/sp, D]`. The all-to-alls between
those layouts live in the Rust coordinator — Python never runs at training
time. Each stage has a forward and a VJP, both AOT-lowered by aot.py.

Stage graph per layer (* = rust-side collective):

    h --pre_attn--> q,k,v [Ssh, heads, D]
          * all-to-all (seq->head)
    q,k,v [S, heads/sp, D] --attn_core--> o [S, heads/sp, D]
          * all-to-all (head->seq)
    o [Ssh, heads, D] --post_attn_mlp(+TiledMLP)--> h' [Ssh, H]

plus `embed` before the stack and `loss_head` (fused tiled CE with
pre-shifted labels, §4.3) after it.

Kernel selection (`pallas` | `ref`): the attention core and the tiled
MLP/CE are swappable without touching stage signatures — this *is* the
paper's "attention-agnostic" property, exercised in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from .kernels import flash_attn, ref, tiled_ce, tiled_mlp

IGNORE_INDEX = ref.IGNORE_INDEX

KernelKind = Literal["pallas", "ref"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (Llama-style)."""

    name: str
    vocab: int
    hidden: int
    n_layers: int
    n_q_heads: int
    n_kv_heads: int
    ffn: int
    head_dim: int = 0
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    kernels: KernelKind = "pallas"
    # Pallas tile sizes (must divide the shard/sequence lengths used).
    tile_s: int = 64
    tile_v: int = 256
    tile_q: int = 64
    tile_k: int = 64

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.hidden // self.n_q_heads)
        assert self.n_q_heads % self.n_kv_heads == 0

    def params_count(self) -> int:
        a = self.hidden * (self.n_q_heads + 2 * self.n_kv_heads + self.n_q_heads) * self.head_dim
        m = 3 * self.hidden * self.ffn
        per_layer = a + m + 2 * self.hidden
        return (
            2 * self.vocab * self.hidden
            + self.n_layers * per_layer
            + self.hidden
        )

    def head_shard(self, sp: int) -> tuple[int, int]:
        """Per-rank (q_heads, kv_heads) under Ulysses SP (paper §3.2.1).

        §7.1 limits: q_heads (and kv_heads, when >= sp) must divide
        evenly; kv heads REPLICATE only when kv_heads < sp.
        """
        assert self.n_q_heads % sp == 0, (self.n_q_heads, sp)
        q_sh = self.n_q_heads // sp
        if self.n_kv_heads >= sp:
            assert self.n_kv_heads % sp == 0, (self.n_kv_heads, sp)
            kv_sh = self.n_kv_heads // sp
        else:
            kv_sh = 1
        return q_sh, kv_sh


# Runnable presets. The paper-scale models (Llama-8B/70B, Qwen3-32B) exist
# as Rust-side simulator presets; these are the real-compute configs.
CONFIGS = {
    # 2-layer GQA toy: fast artifacts, exercises every code path incl.
    # Pallas kernels and kv-head replication (kv=2 < sp=4).
    "tiny": ModelConfig(
        name="tiny", vocab=512, hidden=64, n_layers=2,
        n_q_heads=4, n_kv_heads=2, ffn=128, kernels="pallas",
        tile_s=32, tile_v=128, tile_q=32, tile_k=32,
    ),
    # ~25M params: the quickstart/correctness scale.
    "e2e-25m": ModelConfig(
        name="e2e-25m", vocab=8192, hidden=512, n_layers=6,
        n_q_heads=8, n_kv_heads=4, ffn=1280, kernels="ref",
    ),
    # ~100M params: the end-to-end training driver (EXPERIMENTS.md).
    # kv=4 so sp=4 shards evenly (q 12->3/rank, kv 4->1/rank, §7.1).
    "e2e-100m": ModelConfig(
        name="e2e-100m", vocab=16384, hidden=768, n_layers=12,
        n_q_heads=12, n_kv_heads=4, ffn=2048, kernels="ref",
    ),
}


# ---------------------------------------------------------------------------
# Primitive blocks
# ---------------------------------------------------------------------------
def rms_norm(x, w, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, pos, theta):
    """Rotary embedding. x: [S, H, D] (D even), pos: [S] global positions."""
    s, h, d = x.shape
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos[:, None].astype(jnp.float32) * freqs[None, :]       # [S, half]
    cos, sin = jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


# ---------------------------------------------------------------------------
# Stage forwards. All take flat tensor args and return tuples of tensors.
# Positions are inputs (not derived) because a rank only sees its shard —
# this is also what replaces the paper's 4-D mask (§3.4): position ids,
# O(S) instead of O(S^2).
# ---------------------------------------------------------------------------
def embed_fwd(cfg: ModelConfig, embed, ids):
    """embed: [V, H]; ids: [Ssh] i32 -> h [Ssh, H]."""
    return (jnp.take(embed, ids, axis=0),)


def pre_attn_fwd(cfg: ModelConfig, ln1, wq, wk, wv, h, pos):
    """RMSNorm + QKV projection + RoPE on a sequence shard.

    h: [Ssh, H] -> q [Ssh, nq, D], k/v [Ssh, nkv, D].
    """
    s = h.shape[0]
    x = rms_norm(h, ln1, cfg.norm_eps)
    q = (x @ wq).reshape(s, cfg.n_q_heads, cfg.head_dim)
    k = (x @ wk).reshape(s, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ wv).reshape(s, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def attn_core_fwd(cfg: ModelConfig, q, k, v):
    """Full-sequence causal attention on a head shard (post all-to-all)."""
    if cfg.kernels == "pallas":
        o = flash_attn.attention(q, k, v, cfg.tile_q, cfg.tile_k)
    else:
        o = ref.attention_naive(q, k, v)
    return (o,)


def post_attn_fwd(cfg: ModelConfig, wo, ln2, wg, wu, wd, h_in, attn):
    """Output projection + residual + TiledMLP block on a sequence shard.

    h_in: [Ssh, H] (the layer input, i.e. the residual stream),
    attn: [Ssh, nq, D] (attention output after the second all-to-all).
    """
    s = h_in.shape[0]
    h1 = h_in + attn.reshape(s, cfg.n_q_heads * cfg.head_dim) @ wo
    x = rms_norm(h1, ln2, cfg.norm_eps)
    if cfg.kernels == "pallas":
        # clamp tile_s to the row count: this stage is also lowered at
        # `[rows_per_tile, ...]` tile shapes (mlp_fwd_tile)
        y = tiled_mlp.mlp_tiled(x, wg, wu, wd, min(cfg.tile_s, s))
    else:
        y = ref.mlp_tiled_jnp(x, wg, wu, wd, tile_s=min(cfg.tile_s, s))
    return (h1 + y,)


def loss_fwd(cfg: ModelConfig, lnf, unembed, h, labels):
    """Final norm + fused tiled logits+CE over pre-shifted labels.

    Returns (loss_sum, count); the coordinator all-reduces both and
    divides — that is the cross-shard mean the paper's §4.3 makes exact.
    """
    x = rms_norm(h, lnf, cfg.norm_eps)
    if cfg.kernels == "pallas":
        # clamp tile_s to the row count: this stage is also lowered at
        # `[rows_per_tile, H]` tile shapes (loss_bwd_tile), where rows
        # may be smaller than the configured CE tile
        loss_sum, count = tiled_ce.ce_tiled(x, unembed, labels,
                                            min(cfg.tile_s, h.shape[0]),
                                            cfg.tile_v)
    else:
        loss_sum, count = ref.ce_tiled_jnp(x, unembed, labels,
                                           tile_s=min(cfg.tile_s, h.shape[0]))
    return loss_sum, count


# ---------------------------------------------------------------------------
# Stage VJPs. Each is a standalone jax function (diff args, nondiff args,
# cotangents) -> gradient tuple, lowered as its own artifact. jax.vjp
# recomputes the stage forward internally, which *is* the paper's
# activation-checkpoint recompute: the coordinator stores only layer-input
# shards (offloadable to host) and replays stages backward.
# ---------------------------------------------------------------------------
def embed_bwd(cfg, embed, ids, d_h):
    _, pull = jax.vjp(lambda e: embed_fwd(cfg, e, ids), embed)
    (d_embed,) = pull((d_h,))
    return (d_embed,)


def pre_attn_bwd(cfg, ln1, wq, wk, wv, h, pos, d_q, d_k, d_v):
    _, pull = jax.vjp(
        lambda *a: pre_attn_fwd(cfg, *a, pos), ln1, wq, wk, wv, h
    )
    return pull((d_q, d_k, d_v))          # (d_ln1, d_wq, d_wk, d_wv, d_h)


def attn_core_bwd(cfg, q, k, v, d_o):
    _, pull = jax.vjp(lambda *a: attn_core_fwd(cfg, *a), q, k, v)
    return pull((d_o,))                   # (d_q, d_k, d_v)


def post_attn_bwd(cfg, wo, ln2, wg, wu, wd, h_in, attn, d_out):
    _, pull = jax.vjp(
        lambda *a: post_attn_fwd(cfg, *a), wo, ln2, wg, wu, wd, h_in, attn
    )
    return pull((d_out,))   # (d_wo, d_ln2, d_wg, d_wu, d_wd, d_h_in, d_attn)


def loss_bwd(cfg, lnf, unembed, h, labels, ct_sum):
    """ct_sum is the scalar cotangent on loss_sum (1 / global token count)."""
    _, pull = jax.vjp(
        lambda *a: loss_fwd(cfg, *a, labels)[0], lnf, unembed, h
    )
    return pull(ct_sum)                   # (d_lnf, d_unembed, d_h)


# ---------------------------------------------------------------------------
# Row-tiled execution stages (paper §3.1 EXECUTED, not just planned).
#
# The rust coordinator's `tiling::exec` driver slices a sequence shard into
# fixed `[T, ...]` row tiles and streams them through these programs; the
# ragged tail tile is padded with zero rows and IGNORE_INDEX labels, so
# padding contributes exactly 0 loss and 0 gradient. The full-shard
# `[Ssh, vocab]` logits tensor never exists — only one `[T, vocab]` tile
# (Liger-style, §3.1's 1-GiB chunks).
#
# `loss_bwd_tile` is `loss_bwd` lowered at tile shapes, and
# `mlp_{fwd,bwd}_tile` are `post_attn_{fwd,bwd}` at tile shapes — every op
# in the post-attention block (output projection, residual, RMSNorm,
# SwiGLU) is row-wise, so the same stage function tiles for free. Only the
# loss-head forward needs a new function: the monolithic `loss_fwd` emits
# a scalar (sum, count) pair, while the tiled sweep needs PER-ROW losses
# so the driver can (a) sum rows in the pinned ascending order of the
# summation contract and (b) bucket rows by segment id, yielding
# per-document losses from the same single pass — no per-document re-run.
# ---------------------------------------------------------------------------
def loss_fwd_tile(cfg: ModelConfig, lnf, unembed, h, labels):
    """Per-row fused CE over one `[T, H]` sequence tile.

    Returns the `[T]` per-row loss vector; IGNORE_INDEX rows emit exactly
    0.0 (this is what makes the driver's masked padding rows free).
    """
    x = rms_norm(h, lnf, cfg.norm_eps)
    mask = labels != IGNORE_INDEX
    if cfg.kernels == "pallas":
        t = x.shape[0]
        m, l, tgt = tiled_ce.ce_forward_parts(
            x, unembed, labels, tile_s=min(cfg.tile_s, t), tile_v=cfg.tile_v
        )
        per = (m + jnp.log(l)) - tgt
    else:
        logits = x @ unembed              # [T, V]: the tile working set
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        safe = jnp.where(mask, labels, 0)
        tgt = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
        per = lse - tgt
    return (jnp.where(mask, per, 0.0),)


# ---------------------------------------------------------------------------
# Full-graph reference (pytest ground truth; never exported to Rust).
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key) -> dict:
    """Deterministic init. Rust does its own init; loss-equality tests
    always compare two rust runs sharing one init, so the RNGs need not
    match across languages."""
    keys = jax.random.split(key, 3 + cfg.n_layers)
    std = 0.02
    p = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.hidden)) * std,
        "lnf": jnp.ones((cfg.hidden,)),
        "unembed": jax.random.normal(keys[1], (cfg.hidden, cfg.vocab)) * std,
        "layers": [],
    }
    hq = cfg.n_q_heads * cfg.head_dim
    hkv = cfg.n_kv_heads * cfg.head_dim
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[3 + i], 6)
        p["layers"].append({
            "ln1": jnp.ones((cfg.hidden,)),
            "wq": jax.random.normal(ks[0], (cfg.hidden, hq)) * std,
            "wk": jax.random.normal(ks[1], (cfg.hidden, hkv)) * std,
            "wv": jax.random.normal(ks[2], (cfg.hidden, hkv)) * std,
            "wo": jax.random.normal(ks[3], (hq, cfg.hidden)) * std,
            "ln2": jnp.ones((cfg.hidden,)),
            "wg": jax.random.normal(ks[4], (cfg.hidden, cfg.ffn)) * std,
            "wu": jax.random.normal(ks[5], (cfg.hidden, cfg.ffn)) * std,
            "wd": jnp.zeros((cfg.ffn, cfg.hidden)),
        })
    return p


def full_loss(cfg: ModelConfig, params, ids, labels):
    """Whole model on the whole sequence (sp=1 path), mean loss."""
    pos = jnp.arange(ids.shape[0], dtype=jnp.int32)
    (h,) = embed_fwd(cfg, params["embed"], ids)
    for lp in params["layers"]:
        q, k, v = pre_attn_fwd(cfg, lp["ln1"], lp["wq"], lp["wk"], lp["wv"], h, pos)
        (o,) = attn_core_fwd(cfg, q, k, v)
        (h,) = post_attn_fwd(cfg, lp["wo"], lp["ln2"], lp["wg"], lp["wu"],
                             lp["wd"], h, o)
    loss_sum, count = loss_fwd(cfg, params["lnf"], params["unembed"], h, labels)
    return loss_sum / count


def shift_labels(ids):
    """Paper §4.3: pre-shift once on the *full* sequence, then shard."""
    return jnp.concatenate(
        [ids[1:], jnp.full((1,), IGNORE_INDEX, ids.dtype)]
    )
