//! Activation-checkpoint tape with CPU offload (paper §3.3).
//!
//! Forward stores ONE tensor per (layer, rank): the layer-input hidden
//! shard `[S/sp, hidden]`. Backward pops them in reverse and replays the
//! layer (the stage VJPs recompute internals — §3.3's activation
//! checkpointing). With `offload` enabled the checkpoint is accounted
//! against the *host* pool instead of the device tracker, which is what
//! flattens the paper's Figure-7 memory "hill": peak device usage stops
//! depending on layer count.

use std::sync::Arc;

use anyhow::Result;

use crate::memory::{HostPool, MemoryTracker};
use crate::obs::{Category, Tracer};
use crate::runtime::tensor::HostTensor;

/// Where a checkpoint currently resides.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residence {
    Device,
    Host,
}

struct Slot {
    tensor: HostTensor,
    residence: Residence,
    bytes: u64,
}

/// Per-rank checkpoint tape for one step.
pub struct CheckpointTape {
    pub offload: bool,
    slots: Vec<Vec<Option<Slot>>>, // [layer][rank]
    /// Cumulative device<->host transfer volume this step (both ways).
    pub transfer_bytes: u64,
    tracer: Arc<Tracer>,
}

impl CheckpointTape {
    pub fn new(n_layers: usize, world: usize, offload: bool) -> CheckpointTape {
        CheckpointTape {
            offload,
            slots: (0..n_layers)
                .map(|_| (0..world).map(|_| None).collect())
                .collect(),
            transfer_bytes: 0,
            tracer: Tracer::off(),
        }
    }

    /// Builder: record `Offload` spans for store/fetch on `tracer`.
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> CheckpointTape {
        self.tracer = tracer;
        self
    }

    /// Store layer `li`'s input for `rank`. Device tracker sees the
    /// checkpoint only while it's device-resident.
    pub fn store(
        &mut self,
        li: usize,
        rank: usize,
        tensor: HostTensor,
        device: &mut MemoryTracker,
        host: &mut HostPool,
    ) -> Result<()> {
        let bytes = tensor.size_bytes() as u64;
        let mut span = self.tracer.span(
            Category::Offload,
            if self.offload { "ckpt_store_host" } else { "ckpt_store_device" },
        );
        span.set_rank(rank);
        span.set_bytes(bytes);
        let residence = if self.offload {
            host.alloc(bytes)?;            // may fail: host RAM is finite
            self.transfer_bytes += bytes;  // device -> host copy
            Residence::Host
        } else {
            device.alloc(bytes, "ckpt")?;
            Residence::Device
        };
        self.slots[li][rank] = Some(Slot { tensor, residence, bytes });
        Ok(())
    }

    /// Fetch layer `li`'s input back for recompute; restores to device
    /// (backward needs it on-GPU — the paper notes this copy cannot
    /// overlap much in backward).
    pub fn fetch(
        &mut self,
        li: usize,
        rank: usize,
        device: &mut MemoryTracker,
        host: &mut HostPool,
    ) -> Result<HostTensor> {
        let slot = self.slots[li][rank]
            .take()
            .ok_or_else(|| anyhow::anyhow!("checkpoint ({li},{rank}) missing"))?;
        let mut span = self.tracer.span(
            Category::Offload,
            match slot.residence {
                Residence::Host => "ckpt_fetch_host",
                Residence::Device => "ckpt_fetch_device",
            },
        );
        span.set_rank(rank);
        span.set_bytes(slot.bytes);
        match slot.residence {
            Residence::Host => {
                host.free(slot.bytes);
                self.transfer_bytes += slot.bytes; // host -> device copy
            }
            Residence::Device => device.free(slot.bytes, "ckpt"),
        }
        Ok(slot.tensor)
    }

    /// Device-resident checkpoint bytes right now (Figure 7's "hill").
    pub fn device_bytes(&self) -> u64 {
        self.slots
            .iter()
            .flatten()
            .flatten()
            .filter(|s| s.residence == Residence::Device)
            .map(|s| s.bytes)
            .sum()
    }

    pub fn host_bytes(&self) -> u64 {
        self.slots
            .iter()
            .flatten()
            .flatten()
            .filter(|s| s.residence == Residence::Host)
            .map(|s| s.bytes)
            .sum()
    }

    pub fn stored(&self) -> usize {
        self.slots.iter().flatten().flatten().count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::{HostPool, MemoryTracker};

    fn t(n: usize) -> HostTensor {
        HostTensor::zeros(&[n])
    }

    #[test]
    fn device_tape_grows_then_shrinks() {
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut tape = CheckpointTape::new(3, 1, false);
        for li in 0..3 {
            tape.store(li, 0, t(256), &mut dev, &mut host).unwrap();
        }
        assert_eq!(tape.device_bytes(), 3 * 1024);
        assert_eq!(dev.current(), 3 * 1024);
        for li in (0..3).rev() {
            tape.fetch(li, 0, &mut dev, &mut host).unwrap();
        }
        assert_eq!(dev.current(), 0);
        assert_eq!(tape.stored(), 0);
    }

    #[test]
    fn offload_keeps_device_flat() {
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut tape = CheckpointTape::new(4, 2, true);
        for li in 0..4 {
            for r in 0..2 {
                tape.store(li, r, t(100), &mut dev, &mut host).unwrap();
            }
        }
        assert_eq!(tape.device_bytes(), 0);        // Figure 7: hill is gone
        assert_eq!(dev.current(), 0);
        assert_eq!(host.current(), 8 * 400);
        assert_eq!(tape.transfer_bytes, 8 * 400);  // device->host copies
    }

    #[test]
    fn host_pool_exhaustion_surfaces() {
        // The paper §5.3.2: 1.9TiB host RAM capped Llama-70B seqlen.
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(500);
        let mut tape = CheckpointTape::new(2, 1, true);
        tape.store(0, 0, t(100), &mut dev, &mut host).unwrap();
        let err = tape.store(1, 0, t(100), &mut dev, &mut host);
        assert!(err.is_err());
    }

    #[test]
    fn traced_tape_emits_offload_spans() {
        use crate::obs::{Category, Tracer};
        let tracer = Arc::new(Tracer::new(true));
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut tape = CheckpointTape::new(1, 1, true).with_tracer(tracer.clone());
        tape.store(0, 0, t(64), &mut dev, &mut host).unwrap();
        tape.fetch(0, 0, &mut dev, &mut host).unwrap();
        let spans = tracer.drain();
        assert_eq!(spans.len(), 2);
        assert!(spans
            .iter()
            .all(|s| s.cat == Category::Offload && s.rank == Some(0) && s.bytes == 256));
        assert_eq!(spans[0].name, "ckpt_store_host");
        assert_eq!(spans[1].name, "ckpt_fetch_host");
    }

    #[test]
    fn double_fetch_is_an_error() {
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut tape = CheckpointTape::new(1, 1, false);
        tape.store(0, 0, t(4), &mut dev, &mut host).unwrap();
        tape.fetch(0, 0, &mut dev, &mut host).unwrap();
        assert!(tape.fetch(0, 0, &mut dev, &mut host).is_err());
    }
}
