//! In-process collectives over per-rank buffers, with exact byte
//! accounting fed to the perf model.
//!
//! Substitution note (DESIGN.md): the paper runs NCCL over NVLink/EFA;
//! here an SP/DP group is a set of rank-indexed `HostTensor` slots and a
//! collective is a deterministic data relayout. The *logic* (who sends
//! what where, replication, reduction) is identical — transport differs.
//! Byte counts are asserted against the closed-form volumes, and the
//! roofline model turns them into modeled wire time.
//!
//! Since the transport PR the relayout is no longer a bare `memcpy`: each
//! collective moves its payload as checksummed frames through a
//! [`transport::Transport`] — in-process queues by default
//! ([`transport::LocalTransport`], pinned bit-identical to the historical
//! behavior), or real Unix-domain sockets between spawned rank processes
//! ([`transport::SocketTransport`]), where a SIGKILLed worker, a torn
//! frame, or an expired heartbeat surfaces through the same typed
//! [`faults::AlstError`] taxonomy the simulated faults use (DESIGN.md
//! §Transport has the mapping table).
//!
//! Buffer discipline: every collective has an `_into` variant that writes
//! its output into `ScratchArena`-recycled buffers and accumulates in
//! place — at steady state the simulated wire allocates nothing (the
//! FPDT observation that buffer reuse, not bandwidth, decides long-
//! sequence throughput). The ledger sits behind a `Mutex` so a `Group`
//! can be shared with the scoped rank threads; each op is one commutative
//! integer update, so the totals are deterministic under any
//! interleaving, and access is poison-recovering ([`faults::lock_clean`])
//! so one rank's panic cannot cascade through the others' ledger calls.
//!
//! Fault semantics (DESIGN.md §Fault model & recovery): every op is
//! fallible. With no [`faults::FaultInjector`] installed the ops cannot
//! fail (beyond their existing shape `assert!`s) and cost one extra
//! branch. With an injector armed, the planned operation runs the
//! wire-failure protocol: a `Transient` fault aborts the attempt before
//! data moves; a `CorruptPayload` fault *really* flips a bit in the
//! computed output, which the sender-side checksum / receiver-side verify
//! pair must catch. Both are retried in place with exponential backoff. A
//! `LostRank` fault escapes as a typed [`faults::AlstError`] for the
//! resilient supervisor. Failed attempts ledger nothing and emit no
//! `Collective` span (only a `Fault`-lane retry span), so the pinned
//! span==ledger pairing survives chaos runs bit-exactly.

pub mod faults;
pub mod transport;

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

pub use faults::{AlstError, FaultInjector, FaultKind, FaultPlan, FaultSite, RetryPolicy};
pub use transport::{
    Deadline, LocalTransport, SocketOptions, SocketTransport, Transport, TransportKind,
    WorkerFailMode, WorkerFailure,
};

use faults::{checksum_chain, checksum_f32s, corrupt_f32s, lock_clean};

use crate::obs::{Category, Tracer};
use crate::runtime::tensor::{HostTensor, ScratchArena};

/// Traffic ledger for one process group.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CommStats {
    pub all_gather_bytes: u64,
    pub reduce_scatter_bytes: u64,
    pub all_to_all_bytes: u64,
    pub all_reduce_bytes: u64,
    /// Neighbor-exchange (ring send/recv) traffic — the transport of the
    /// ring attention plan's rotating KV blocks.
    pub send_recv_bytes: u64,
    pub ops: u64,
}

impl CommStats {
    pub fn total_bytes(&self) -> u64 {
        self.all_gather_bytes
            + self.reduce_scatter_bytes
            + self.all_to_all_bytes
            + self.all_reduce_bytes
            + self.send_recv_bytes
    }
}

/// A communicator over `world` in-process ranks.
#[derive(Debug)]
pub struct Group {
    pub world: usize,
    stats: Mutex<CommStats>,
    /// Span recorder (the shared disabled handle by default). Every
    /// ledger increment — a collective performed here or an `account_*`
    /// call from a data-structure owner — pairs with exactly one
    /// `Collective` span carrying the same byte count, so the span byte
    /// sum equals `CommStats::total_bytes()` under tracing.
    tracer: Arc<Tracer>,
    /// Chaos source; `None` (the default) means ops cannot fault and
    /// checksums are never computed.
    injector: Option<Arc<FaultInjector>>,
    retry: RetryPolicy,
    /// Frame carrier. Every payload collective moves its bytes as framed
    /// roundtrips through this — `LocalTransport` (in-process queues,
    /// bit-identical home of the historical behavior) by default, or
    /// `SocketTransport` (spawned rank processes over Unix sockets). The
    /// ledger, Collective spans, and retry gates above it are
    /// transport-agnostic.
    transport: Arc<dyn Transport>,
    /// Deadline budget for one transport roundtrip; an expiry surfaces as
    /// retryable `Transient { site: Wire }` instead of a hung step.
    op_timeout: Duration,
}

impl Group {
    pub fn new(world: usize) -> Group {
        Group::with_transport(world, LocalTransport::new(world))
    }

    /// A group whose frames ride a caller-provided transport (socket mode
    /// or a test double). `transport.world()` must match.
    pub fn with_transport(world: usize, transport: Arc<dyn Transport>) -> Group {
        assert!(world >= 1);
        assert_eq!(transport.world(), world, "transport world mismatch");
        Group {
            world,
            stats: Mutex::default(),
            tracer: Tracer::off(),
            injector: None,
            retry: RetryPolicy::default(),
            transport,
            op_timeout: Duration::from_secs(30),
        }
    }

    pub fn set_tracer(&mut self, tracer: Arc<Tracer>) {
        self.tracer = tracer;
    }

    /// The group's tracer handle — relayouts and other callers that ledger
    /// through `account_*` use it to wrap their own timed spans.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// Arm deterministic fault injection on this group's collectives.
    pub fn set_injector(&mut self, injector: Arc<FaultInjector>) {
        self.injector = Some(injector);
    }

    pub fn injector(&self) -> Option<&Arc<FaultInjector>> {
        self.injector.as_ref()
    }

    pub fn set_retry_policy(&mut self, retry: RetryPolicy) {
        self.retry = retry;
    }

    pub fn retry_policy(&self) -> RetryPolicy {
        self.retry
    }

    /// The frame carrier under this group's collectives.
    pub fn transport(&self) -> &Arc<dyn Transport> {
        &self.transport
    }

    pub fn transport_kind(&self) -> TransportKind {
        self.transport.kind()
    }

    /// Bound every transport roundtrip (send + matching recv) by `t`.
    pub fn set_op_timeout(&mut self, t: Duration) {
        self.op_timeout = t;
    }

    pub fn op_timeout(&self) -> Duration {
        self.op_timeout
    }

    pub fn stats(&self) -> CommStats {
        lock_clean(&self.stats).clone()
    }

    pub fn reset_stats(&self) {
        *lock_clean(&self.stats) = CommStats::default();
    }

    // -- fault plumbing ---------------------------------------------------

    /// Drive one collective through the retry loop: each attempt sees
    /// whether the injector fired at this op index; retryable failures
    /// (injected transients, checksum mismatches, and *real* wire faults
    /// — recv deadline expiry, torn frames — which need no injector) back
    /// off with jitter on the `Fault` lane and re-run; everything else
    /// propagates typed.
    fn with_faults<T>(&self, mut attempt: impl FnMut(Option<FaultKind>) -> Result<T>) -> Result<T> {
        let mut tries = 0u32;
        loop {
            let kind = self.injector.as_ref().and_then(|inj| inj.check(FaultSite::Collective, None));
            match attempt(kind) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    let retryable = e
                        .downcast_ref::<AlstError>()
                        .is_some_and(AlstError::is_retryable);
                    if !retryable || tries >= self.retry.max_retries {
                        return Err(e);
                    }
                    faults::retry_pause(
                        &self.tracer,
                        self.injector.as_deref(),
                        &self.retry,
                        None,
                        tries,
                    );
                    tries += 1;
                }
            }
        }
    }

    fn fault_rank(&self) -> usize {
        self.injector.as_ref().map_or(0, |i| i.plan().rank)
    }

    fn fault_seed(&self) -> u64 {
        self.injector.as_ref().map_or(0, |i| i.plan().seed)
    }

    /// Faults that strike *before* any data moves: a dead transport peer
    /// (real, detected via heartbeat/EOF) or an injected pre-wire fault.
    /// `CorruptPayload` is not one of them — it damages the payload
    /// post-compute and is handled by the checksum verify.
    fn gate(&self, fault: Option<FaultKind>) -> Result<(), AlstError> {
        self.transport.check_peers()?;
        match fault {
            Some(FaultKind::Transient) => Err(AlstError::Transient {
                site: FaultSite::Collective,
                rank: self.fault_rank(),
                attempt: 0,
            }),
            Some(FaultKind::LostRank) => {
                Err(AlstError::LostRank { site: FaultSite::Collective, rank: self.fault_rank() })
            }
            _ => Ok(()),
        }
    }

    /// Sender-checksum → seeded wire corruption → receiver-verify over one
    /// payload. Only runs when this attempt's fault is `CorruptPayload`;
    /// an unfaulted op never pays for a digest.
    fn verify_payload(&self, fault: Option<FaultKind>, payload: &mut [f32]) -> Result<(), AlstError> {
        if fault != Some(FaultKind::CorruptPayload) {
            return Ok(());
        }
        let expect = checksum_f32s(payload);
        corrupt_f32s(payload, self.fault_seed());
        let got = checksum_f32s(payload);
        if got == expect {
            return Ok(()); // empty payload: nothing to corrupt
        }
        Err(AlstError::CorruptPayload {
            site: FaultSite::Collective,
            rank: self.fault_rank(),
            expect,
            got,
        })
    }

    /// `verify_payload` for multi-buffer outputs: one digest chains over
    /// all buffers; corruption lands in the faulted rank's buffer.
    fn verify_payloads(&self, fault: Option<FaultKind>, outs: &mut [Vec<f32>]) -> Result<(), AlstError> {
        if fault != Some(FaultKind::CorruptPayload) {
            return Ok(());
        }
        let digest =
            |bufs: &[Vec<f32>]| bufs.iter().fold(checksum_f32s(&[]), |h, b| checksum_chain(h, b));
        let expect = digest(outs);
        let n = outs.len();
        if let Some(target) = (0..n)
            .map(|i| (self.fault_rank() + i) % n)
            .find(|&i| !outs[i].is_empty())
        {
            corrupt_f32s(&mut outs[target], self.fault_seed());
        }
        let got = digest(outs);
        if got == expect {
            return Ok(());
        }
        Err(AlstError::CorruptPayload {
            site: FaultSite::Collective,
            rank: self.fault_rank(),
            expect,
            got,
        })
    }

    /// Recycle a failed attempt's pooled output buffers (empty payloads
    /// never came from the pool and stay out of it).
    fn recycle_failed(arena: &ScratchArena, outs: Vec<Vec<f32>>) {
        for buf in outs {
            if !buf.is_empty() {
                arena.recycle_f32(buf);
            }
        }
    }

    // -- wire ------------------------------------------------------------

    /// One framed roundtrip: rank `src`'s payload crosses the transport
    /// (through rank `src`'s process in socket mode) and lands in `out`.
    /// Send and matching recv share one deadline, so a hung peer becomes
    /// a typed `Transient { site: Wire }` instead of a stuck step.
    fn wire(&self, src: usize, dst: usize, payload: &[f32], out: &mut [f32]) -> Result<(), AlstError> {
        let deadline = Deadline::after(self.op_timeout);
        let frame = self.transport.send(src, dst, payload, deadline)?;
        self.transport.recv_into(src, dst, frame, out, deadline)
    }

    /// `wire` where the payload buffer is also the destination (reduce
    /// outputs, all-reduce accumulators).
    fn wire_inplace(&self, src: usize, dst: usize, buf: &mut [f32]) -> Result<(), AlstError> {
        let deadline = Deadline::after(self.op_timeout);
        let frame = self.transport.send(src, dst, buf, deadline)?;
        self.transport.recv_into(src, dst, frame, buf, deadline)
    }

    // -- silent ledger (no spans; the public surface pairs each increment
    //    with exactly one Collective span) --------------------------------
    fn ledger_gather(&self, bytes: u64) {
        let mut st = lock_clean(&self.stats);
        st.all_gather_bytes += bytes;
        st.ops += 1;
    }

    fn ledger_reduce_scatter(&self, bytes: u64) {
        let mut st = lock_clean(&self.stats);
        st.reduce_scatter_bytes += bytes;
        st.ops += 1;
    }

    fn ledger_all_to_all(&self, bytes: u64) {
        let mut st = lock_clean(&self.stats);
        st.all_to_all_bytes += bytes;
        st.ops += 1;
    }

    fn ledger_all_reduce(&self, bytes: u64) {
        let mut st = lock_clean(&self.stats);
        st.all_reduce_bytes += bytes;
        st.ops += 1;
    }

    fn ledger_send_recv(&self, bytes: u64) {
        let mut st = lock_clean(&self.stats);
        st.send_recv_bytes += bytes;
        st.ops += 1;
    }

    /// All-gather of equal-length f32 shards: each rank contributes its
    /// shard; result is the concatenation (same for all ranks). Wire
    /// volume per rank: (world-1)/world * total (ring), accounted as the
    /// full gathered size for simplicity on the ledger, matching NCCL's
    /// algbw convention.
    pub fn all_gather(&self, shards: &[&[f32]]) -> Result<Vec<f32>> {
        assert_eq!(shards.len(), self.world);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        self.with_faults(|fault| {
            self.gate(fault)?;
            let mut span = self.tracer.span(Category::Collective, "all_gather");
            let mut out = vec![0.0f32; total];
            let mut off = 0;
            for (src, s) in shards.iter().enumerate() {
                if let Err(e) = self.wire(src, src, s, &mut out[off..off + s.len()]) {
                    span.cancel();
                    return Err(e.into());
                }
                off += s.len();
            }
            if let Err(e) = self.verify_payload(fault, &mut out) {
                span.cancel();
                return Err(e.into());
            }
            self.ledger_gather((total * 4) as u64);
            span.set_bytes((total * 4) as u64);
            Ok(out)
        })
    }

    /// `all_gather` into an arena-recycled buffer (allocation-free at
    /// steady state; caller recycles the result when done).
    pub fn all_gather_into(&self, shards: &[&[f32]], arena: &ScratchArena) -> Result<Vec<f32>> {
        assert_eq!(shards.len(), self.world);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        self.with_faults(|fault| {
            self.gate(fault)?;
            let mut span = self.tracer.span(Category::Collective, "all_gather");
            let mut out = arena.take_f32(total);
            let mut off = 0;
            for (src, s) in shards.iter().enumerate() {
                if let Err(e) = self.wire(src, src, s, &mut out[off..off + s.len()]) {
                    span.cancel();
                    arena.recycle_f32(out);
                    return Err(e.into());
                }
                off += s.len();
            }
            if let Err(e) = self.verify_payload(fault, &mut out) {
                span.cancel();
                arena.recycle_f32(out);
                return Err(e.into());
            }
            self.ledger_gather((total * 4) as u64);
            span.set_bytes((total * 4) as u64);
            Ok(out)
        })
    }

    /// Reduce-scatter (sum): input is one full-length gradient per rank;
    /// output is rank r's reduced shard. Shard boundaries are equal
    /// `total/world` splits (caller pads to divisibility). Accumulation
    /// is in place: rank 0's slice seeds the output, the rest add.
    pub fn reduce_scatter(&self, fulls: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let arena = ScratchArena::new(); // one-shot: plain allocations
        self.reduce_scatter_into(fulls, &arena)
    }

    /// `reduce_scatter` into arena-recycled shard buffers.
    pub fn reduce_scatter_into(
        &self,
        fulls: &[&[f32]],
        arena: &ScratchArena,
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(fulls.len(), self.world);
        let total = fulls[0].len();
        assert!(fulls.iter().all(|f| f.len() == total), "ragged reduce-scatter");
        assert_eq!(total % self.world, 0, "reduce-scatter needs padded input");
        let shard = total / self.world;
        self.with_faults(|fault| {
            self.gate(fault)?;
            let mut span = self.tracer.span(Category::Collective, "reduce_scatter");
            let mut out = Vec::with_capacity(self.world);
            for r in 0..self.world {
                let base = r * shard;
                let mut dst = arena.take_f32(shard);
                dst.copy_from_slice(&fulls[0][base..base + shard]);
                for f in &fulls[1..] {
                    for (d, s) in dst.iter_mut().zip(&f[base..base + shard]) {
                        *d += s;
                    }
                }
                out.push(dst);
            }
            // Each reduced shard crosses the wire once, relayed via the
            // rank that holds the last partial in the ring schedule.
            for r in 0..self.world {
                let src_rank = (r + self.world - 1) % self.world;
                if let Err(e) = self.wire_inplace(src_rank, r, &mut out[r]) {
                    span.cancel();
                    Group::recycle_failed(arena, out);
                    return Err(e.into());
                }
            }
            if let Err(e) = self.verify_payloads(fault, &mut out) {
                span.cancel();
                Group::recycle_failed(arena, out);
                return Err(e.into());
            }
            self.ledger_reduce_scatter((total * 4) as u64);
            span.set_bytes((total * 4) as u64);
            Ok(out)
        })
    }

    /// All-to-all of equal blocks: `sends[r]` holds `world` contiguous
    /// blocks; output `out[d]` is the concatenation over `r` of
    /// `sends[r]`'s block `d` (NCCL `ncclAllToAll` semantics). The
    /// head/seq-aware relayout lives in `coordinator::ulysses`; this is
    /// the generic primitive. Outputs come from the arena.
    pub fn all_to_all(&self, sends: &[&[f32]], arena: &ScratchArena) -> Result<Vec<Vec<f32>>> {
        assert_eq!(sends.len(), self.world);
        let per_rank = sends[0].len();
        assert!(sends.iter().all(|s| s.len() == per_rank), "ragged all-to-all");
        assert_eq!(per_rank % self.world, 0, "all-to-all needs equal blocks");
        let blk = per_rank / self.world;
        self.with_faults(|fault| {
            self.gate(fault)?;
            let mut span = self.tracer.span(Category::Collective, "all_to_all");
            let mut out = Vec::with_capacity(self.world);
            for _ in 0..self.world {
                out.push(arena.take_f32(per_rank));
            }
            // world² frames: block (r → d) travels through rank r.
            for d in 0..self.world {
                for (r, s) in sends.iter().enumerate() {
                    if let Err(e) =
                        self.wire(r, d, &s[d * blk..(d + 1) * blk], &mut out[d][r * blk..(r + 1) * blk])
                    {
                        span.cancel();
                        Group::recycle_failed(arena, out);
                        return Err(e.into());
                    }
                }
            }
            if let Err(e) = self.verify_payloads(fault, &mut out) {
                span.cancel();
                Group::recycle_failed(arena, out);
                return Err(e.into());
            }
            self.ledger_all_to_all((self.world * per_rank * 4) as u64);
            span.set_bytes((self.world * per_rank * 4) as u64);
            Ok(out)
        })
    }

    /// Ring neighbor exchange: rank r's buffer is delivered to rank
    /// `(r + shift) % world`, i.e. `out[d] = sends[(d + world - shift) % world]`.
    /// Unlike `all_to_all`, per-rank payloads may be ragged or empty — a
    /// rank with nothing to pass (e.g. the causal-skip ring schedule,
    /// where fully-masked KV blocks stop travelling) sends `&[]` and its
    /// neighbor receives an empty buffer at zero wire cost. Ledger volume
    /// is the sum of payload bytes actually moved.
    pub fn send_recv(&self, sends: &[&[f32]], shift: usize) -> Result<Vec<Vec<f32>>> {
        let arena = ScratchArena::new(); // one-shot: plain allocations
        self.send_recv_into(sends, shift, &arena)
    }

    /// `send_recv` into arena-recycled buffers (empty payloads bypass the
    /// pool so steady-state hit accounting only counts real traffic).
    pub fn send_recv_into(
        &self,
        sends: &[&[f32]],
        shift: usize,
        arena: &ScratchArena,
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(sends.len(), self.world);
        assert!(
            shift % self.world != 0,
            "send_recv with shift {} over world {} moves nothing",
            shift,
            self.world
        );
        let shift = shift % self.world;
        self.with_faults(|fault| {
            self.gate(fault)?;
            let mut span = self.tracer.span(Category::Collective, "send_recv");
            let mut bytes = 0usize;
            let mut out = Vec::with_capacity(self.world);
            for dst in 0..self.world {
                let src_rank = (dst + self.world - shift) % self.world;
                let src = sends[src_rank];
                if src.is_empty() {
                    out.push(Vec::new());
                    continue;
                }
                let mut buf = arena.take_f32(src.len());
                if let Err(e) = self.wire(src_rank, dst, src, &mut buf) {
                    arena.recycle_f32(buf);
                    span.cancel();
                    Group::recycle_failed(arena, out);
                    return Err(e.into());
                }
                bytes += src.len() * 4;
                out.push(buf);
            }
            if let Err(e) = self.verify_payloads(fault, &mut out) {
                span.cancel();
                Group::recycle_failed(arena, out);
                return Err(e.into());
            }
            self.ledger_send_recv(bytes as u64);
            span.set_bytes(bytes as u64);
            Ok(out)
        })
    }

    /// All-reduce (sum) of scalars — loss_sum/token-count reduction. The
    /// paper specifically replaced `all_reduce_object` with plain
    /// all_reduce to save >3 GiB/GPU (§3.3); we only ever move the scalars.
    pub fn all_reduce_scalars(&self, vals: &[f32]) -> Result<f32> {
        assert_eq!(vals.len(), self.world);
        self.with_faults(|fault| {
            self.gate(fault)?;
            let mut span = self.tracer.span(Category::Collective, "all_reduce_scalars");
            // Every rank's scalar crosses the wire to the root; summing in
            // rank order keeps the result bit-identical to `iter().sum()`.
            let mut acc = 0.0f32;
            let mut got = [0.0f32];
            for (r, v) in vals.iter().enumerate() {
                if let Err(e) = self.wire(r, 0, &[*v], &mut got) {
                    span.cancel();
                    return Err(e.into());
                }
                acc += got[0];
            }
            let mut sum = [acc];
            if let Err(e) = self.verify_payload(fault, &mut sum) {
                span.cancel();
                return Err(e.into());
            }
            self.ledger_all_reduce((vals.len() * 4) as u64);
            span.set_bytes((vals.len() * 4) as u64);
            Ok(sum[0])
        })
    }

    /// All-reduce (sum) of one tensor per rank: returns the summed tensor
    /// each rank would hold. Accumulates in place into one output buffer
    /// (no `tensors[0].clone()` round trip through a second allocation).
    pub fn all_reduce_sum(&self, tensors: &[&HostTensor]) -> Result<HostTensor> {
        let arena = ScratchArena::new();
        self.all_reduce_sum_into(tensors, &arena)
    }

    /// `all_reduce_sum` into an arena-recycled output buffer.
    pub fn all_reduce_sum_into(
        &self,
        tensors: &[&HostTensor],
        arena: &ScratchArena,
    ) -> Result<HostTensor> {
        assert_eq!(tensors.len(), self.world);
        let shape = tensors[0].shape().to_vec();
        self.with_faults(|fault| {
            self.gate(fault)?;
            let mut span = self.tracer.span(Category::Collective, "all_reduce_sum");
            let first = match tensors[0].as_f32() {
                Ok(f) => f,
                Err(e) => {
                    span.cancel();
                    return Err(e);
                }
            };
            let mut acc = arena.take_f32(first.len());
            acc.copy_from_slice(first);
            for t in &tensors[1..] {
                let src = if t.shape() != shape.as_slice() {
                    Err(anyhow::anyhow!("shape mismatch in add"))
                } else {
                    t.as_f32()
                };
                let src = match src {
                    Ok(s) => s,
                    Err(e) => {
                        span.cancel();
                        arena.recycle_f32(acc);
                        return Err(e);
                    }
                };
                for (d, s) in acc.iter_mut().zip(src) {
                    *d += s;
                }
            }
            // One roundtrip of the reduced tensor stands in for the ring's
            // 2(w-1)/w passes; the ledger keeps the logical size below.
            if let Err(e) = self.wire_inplace(self.world - 1, 0, &mut acc) {
                span.cancel();
                arena.recycle_f32(acc);
                return Err(e.into());
            }
            if let Err(e) = self.verify_payload(fault, &mut acc) {
                span.cancel();
                arena.recycle_f32(acc);
                return Err(e.into());
            }
            let out = HostTensor::f32(shape.clone(), acc);
            // ring all-reduce moves 2*(w-1)/w * bytes; ledger the logical size
            self.ledger_all_reduce(out.size_bytes() as u64);
            span.set_bytes(out.size_bytes() as u64);
            Ok(out)
        })
    }

    /// Zero-duration instant span for an `account_*` ledger entry: the
    /// data movement happened inside the caller (which wraps its own
    /// timed span, e.g. a `Relayout`), but the byte must still appear on
    /// the Collective lane once for ledger parity.
    fn account_span(&self, name: &'static str, bytes: u64) {
        if self.tracer.enabled() {
            let mut span = self.tracer.span(Category::Collective, name);
            span.set_bytes(bytes);
            span.set_dur(Duration::ZERO);
        }
    }

    /// One fault-gated ledger entry on behalf of a data-structure owner.
    /// The payload lives in the caller, so every fault kind gates the
    /// attempt up front (`CorruptPayload` models the receiver-side verify
    /// failing); on success the increment and its instant span land once.
    fn account_collective(
        &self,
        name: &'static str,
        bytes: u64,
        ledger: fn(&Group, u64),
    ) -> Result<()> {
        self.with_faults(|fault| {
            // No frames of their own, but a dead peer still invalidates
            // the op the caller is accounting for.
            self.transport.check_peers()?;
            if let Some(kind) = fault {
                return Err(
                    AlstError::from_kind(kind, FaultSite::Collective, self.fault_rank()).into()
                );
            }
            self.account_span(name, bytes);
            ledger(self, bytes);
            Ok(())
        })
    }

    /// Record an all-to-all's traffic (the relayout itself is done by
    /// `coordinator::ulysses`, which owns the head/seq math).
    pub fn account_all_to_all(&self, bytes: u64) -> Result<()> {
        self.account_collective("all_to_all", bytes, Group::ledger_all_to_all)
    }

    /// Ledger an all-gather performed by a data-structure owner (e.g. the
    /// ZeRO store's just-in-time parameter gather).
    pub fn account_gather(&self, bytes: u64) -> Result<()> {
        self.account_collective("all_gather", bytes, Group::ledger_gather)
    }

    /// Ledger a reduce-scatter performed by a data-structure owner.
    pub fn account_reduce_scatter(&self, bytes: u64) -> Result<()> {
        self.account_collective("reduce_scatter", bytes, Group::ledger_reduce_scatter)
    }

    /// Ledger a point-to-point exchange performed by a data-structure
    /// owner (e.g. the ring plan homing completed dKV block partials to
    /// their owner rank without a full rotation).
    pub fn account_send_recv(&self, bytes: u64) -> Result<()> {
        self.account_collective("send_recv", bytes, Group::ledger_send_recv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn faulted(world: usize, kind: FaultKind, at_op: u64) -> (Group, Arc<FaultInjector>) {
        let mut g = Group::new(world);
        let inj = FaultInjector::new(FaultPlan {
            site: FaultSite::Collective,
            kind,
            rank: 1 % world,
            at_op,
            seed: 11,
        });
        g.set_injector(inj.clone());
        g.set_retry_policy(RetryPolicy {
            base: std::time::Duration::from_micros(10),
            ..Default::default()
        });
        (g, inj)
    }

    #[test]
    fn all_gather_concatenates_in_rank_order() {
        let g = Group::new(3);
        let out = g.all_gather(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(g.stats().all_gather_bytes, 24);
    }

    #[test]
    fn all_gather_into_reuses_pooled_buffers() {
        let g = Group::new(2);
        let arena = ScratchArena::new();
        let out = g.all_gather_into(&[&[1.0, 2.0], &[3.0, 4.0]], &arena).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
        arena.recycle_f32(out);
        let out2 = g.all_gather_into(&[&[5.0, 6.0], &[7.0, 8.0]], &arena).unwrap();
        assert_eq!(out2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!((arena.hits(), arena.misses()), (1, 1));
    }

    #[test]
    fn reduce_scatter_sums_and_shards() {
        let g = Group::new(2);
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let b = vec![10.0f32, 20.0, 30.0, 40.0];
        let out = g.reduce_scatter(&[&a, &b]).unwrap();
        assert_eq!(out[0], vec![11.0, 22.0]);
        assert_eq!(out[1], vec![33.0, 44.0]);
        assert_eq!(g.stats().reduce_scatter_bytes, 16);
    }

    #[test]
    fn gather_then_scatter_identity() {
        // reduce_scatter(all_gather(x) replicated) == world * x shards
        let g = Group::new(2);
        let full = g.all_gather(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        let out = g.reduce_scatter(&[&full, &full]).unwrap();
        assert_eq!(out[0], vec![2.0, 4.0]);
        assert_eq!(out[1], vec![6.0, 8.0]);
    }

    #[test]
    fn all_to_all_transposes_blocks() {
        let g = Group::new(2);
        let arena = ScratchArena::new();
        // rank 0 sends [1,2 | 3,4]; rank 1 sends [5,6 | 7,8]
        let out = g
            .all_to_all(&[&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]], &arena)
            .unwrap();
        assert_eq!(out[0], vec![1.0, 2.0, 5.0, 6.0]);
        assert_eq!(out[1], vec![3.0, 4.0, 7.0, 8.0]);
        assert_eq!(g.stats().all_to_all_bytes, 32);
        // steady state: second call hits the pool after recycling
        for v in out {
            arena.recycle_f32(v);
        }
        let _ = g.all_to_all(&[&[0.0; 4], &[0.0; 4]], &arena).unwrap();
        assert_eq!(arena.misses(), 2);
        assert_eq!(arena.hits(), 2);
    }

    #[test]
    fn scalar_all_reduce() {
        let g = Group::new(4);
        assert_eq!(g.all_reduce_scalars(&[1.0, 2.0, 3.0, 4.0]).unwrap(), 10.0);
    }

    #[test]
    fn tensor_all_reduce_sums_in_place() {
        let g = Group::new(3);
        let a = HostTensor::f32(vec![2], vec![1.0, 2.0]);
        let b = HostTensor::f32(vec![2], vec![10.0, 20.0]);
        let c = HostTensor::f32(vec![2], vec![100.0, 200.0]);
        let out = g.all_reduce_sum(&[&a, &b, &c]).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[111.0, 222.0]);
        assert_eq!(g.stats().all_reduce_bytes, 8);
        // shape mismatch is an error
        let bad = HostTensor::zeros(&[3]);
        assert!(g.all_reduce_sum(&[&a, &b, &bad]).is_err());
    }

    #[test]
    fn every_ledger_increment_pairs_one_collective_span() {
        use crate::obs::{Category, Tracer};
        let mut g = Group::new(2);
        let tracer = Arc::new(Tracer::new(true));
        g.set_tracer(tracer.clone());
        let arena = ScratchArena::new();
        let _ = g.all_gather(&[&[1.0], &[2.0]]).unwrap();
        let _ = g.all_to_all(&[&[1.0, 2.0], &[3.0, 4.0]], &arena).unwrap();
        let _ = g.reduce_scatter(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        let _ = g.all_reduce_scalars(&[1.0, 2.0]).unwrap();
        let a = HostTensor::f32(vec![2], vec![1.0, 2.0]);
        let _ = g.all_reduce_sum(&[&a, &a]).unwrap();
        let _ = g.send_recv(&[&[1.0, 2.0], &[3.0]], 1).unwrap();
        g.account_gather(100).unwrap();
        g.account_all_to_all(200).unwrap();
        g.account_reduce_scatter(300).unwrap();
        g.account_send_recv(400).unwrap();
        let st = g.stats();
        let spans = tracer.drain();
        assert!(spans.iter().all(|s| s.cat == Category::Collective));
        assert_eq!(spans.len() as u64, st.ops, "one span per ledger op");
        let span_bytes: u64 = spans.iter().map(|s| s.bytes).sum();
        assert_eq!(span_bytes, st.total_bytes(), "span bytes == ledger bytes");
        // The account_* instant spans are zero-duration.
        assert!(spans
            .iter()
            .filter(|s| s.bytes >= 100)
            .all(|s| s.dur_ns == 0));
    }

    #[test]
    fn send_recv_rotates_by_shift() {
        let g = Group::new(4);
        let bufs: [&[f32]; 4] = [&[0.0], &[1.0], &[2.0], &[3.0]];
        let out = g.send_recv(&bufs, 1).unwrap();
        // rank r receives rank (r-1)'s payload
        assert_eq!(out, vec![vec![3.0], vec![0.0], vec![1.0], vec![2.0]]);
        assert_eq!(g.stats().send_recv_bytes, 16);
        assert_eq!(g.stats().ops, 1);
        let out2 = g.send_recv(&bufs, 3).unwrap();
        assert_eq!(out2, vec![vec![1.0], vec![2.0], vec![3.0], vec![0.0]]);
    }

    #[test]
    fn send_recv_allows_ragged_and_empty_payloads() {
        let g = Group::new(3);
        let bufs: [&[f32]; 3] = [&[1.0, 2.0, 3.0], &[], &[4.0]];
        let out = g.send_recv(&bufs, 1).unwrap();
        assert_eq!(out[0], vec![4.0]);
        assert_eq!(out[1], vec![1.0, 2.0, 3.0]);
        assert!(out[2].is_empty());
        // only real payloads hit the wire: (3 + 1) * 4 bytes
        assert_eq!(g.stats().send_recv_bytes, 16);
        assert_eq!(g.stats().total_bytes(), 16);
    }

    #[test]
    fn send_recv_into_reuses_pooled_buffers() {
        let g = Group::new(2);
        let arena = ScratchArena::new();
        let out = g.send_recv_into(&[&[1.0, 2.0], &[3.0, 4.0]], 1, &arena).unwrap();
        assert_eq!(out[0], vec![3.0, 4.0]);
        assert_eq!(out[1], vec![1.0, 2.0]);
        for v in out {
            arena.recycle_f32(v);
        }
        let _ = g.send_recv_into(&[&[5.0, 6.0], &[7.0, 8.0]], 1, &arena).unwrap();
        assert_eq!((arena.hits(), arena.misses()), (2, 2));
    }

    #[test]
    #[should_panic(expected = "moves nothing")]
    fn send_recv_zero_shift_rejected() {
        let g = Group::new(2);
        let _ = g.send_recv(&[&[1.0], &[2.0]], 2);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_reduce_scatter_rejected() {
        let g = Group::new(2);
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 2];
        let _ = g.reduce_scatter(&[&a, &b]);
    }

    // -- fault injection --------------------------------------------------

    #[test]
    fn transient_fault_is_absorbed_and_ledger_matches_unfaulted() {
        use crate::obs::Tracer;
        let (mut g, inj) = faulted(2, FaultKind::Transient, 1);
        let tracer = Arc::new(Tracer::new(true));
        g.set_tracer(tracer.clone());
        let clean = Group::new(2);
        for _ in 0..3 {
            let a = g.all_gather(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
            let b = clean.all_gather(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
            assert_eq!(a, b, "retry reproduces the unfaulted payload");
        }
        assert_eq!(g.stats(), clean.stats(), "failed attempts ledger nothing");
        let stats = inj.stats();
        assert_eq!((stats.injected, stats.retries), (1, 1));
        let spans = tracer.drain();
        let collectives = spans.iter().filter(|s| s.cat == Category::Collective).count();
        let faults: Vec<_> = spans.iter().filter(|s| s.cat == Category::Fault).collect();
        assert_eq!(collectives as u64, g.stats().ops, "span==ledger pairing holds");
        assert_eq!(faults.len(), 1);
        assert_eq!(faults[0].name, "retry_backoff");
        assert!(faults[0].dur_ns > 0, "backoff time is real critical-path time");
    }

    #[test]
    fn corrupt_payload_is_caught_by_checksum_and_retried() {
        let (g, inj) = faulted(2, FaultKind::CorruptPayload, 0);
        let arena = ScratchArena::new();
        let clean = Group::new(2);
        let ca = ScratchArena::new();
        let out = g.all_gather_into(&[&[1.0, 2.0], &[3.0, 4.0]], &arena).unwrap();
        let want = clean.all_gather_into(&[&[1.0, 2.0], &[3.0, 4.0]], &ca).unwrap();
        assert_eq!(out, want, "corrupted attempt never escapes");
        assert_eq!(inj.stats().retries, 1);
        // the failed attempt's buffer went back to the pool: 1 miss, 1 hit
        assert_eq!((arena.hits(), arena.misses()), (1, 1));
        assert_eq!(g.stats().ops, 1, "only the clean attempt ledgers");
    }

    #[test]
    fn corrupt_multi_buffer_outputs_are_verified_and_recycled() {
        let (g, inj) = faulted(2, FaultKind::CorruptPayload, 0);
        let arena = ScratchArena::new();
        let out = g
            .all_to_all(&[&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]], &arena)
            .unwrap();
        assert_eq!(out[0], vec![1.0, 2.0, 5.0, 6.0]);
        assert_eq!(out[1], vec![3.0, 4.0, 7.0, 8.0]);
        assert_eq!(inj.stats().retries, 1);
        // first attempt took 2 buffers (misses) and recycled both; the
        // retry took them back as hits
        assert_eq!((arena.hits(), arena.misses()), (2, 2));
    }

    #[test]
    fn lost_rank_escapes_typed_with_clean_ledger() {
        let (g, inj) = faulted(4, FaultKind::LostRank, 0);
        let err = g.all_reduce_scalars(&[1.0, 2.0, 3.0, 4.0]).unwrap_err();
        match err.downcast_ref::<AlstError>() {
            Some(AlstError::LostRank { site: FaultSite::Collective, rank: 1 }) => {}
            other => panic!("expected typed LostRank, got {other:?}"),
        }
        assert_eq!(g.stats().ops, 0, "failed op ledgers nothing");
        assert_eq!(inj.stats().retries, 0, "lost rank is not retried");
        // the injector is one-shot: the group keeps working after recovery
        assert_eq!(g.all_reduce_scalars(&[1.0, 2.0, 3.0, 4.0]).unwrap(), 10.0);
        assert_eq!(g.stats().ops, 1);
    }

    // -- transport plumbing -----------------------------------------------

    #[test]
    fn group_defaults_to_local_transport() {
        let g = Group::new(2);
        assert_eq!(g.transport_kind(), TransportKind::Local);
        assert_eq!(g.transport().world(), 2);
    }

    #[test]
    fn real_wire_corruption_is_retried_without_an_injector() {
        use crate::obs::Tracer;
        let lt = LocalTransport::new(2);
        let mut g = Group::with_transport(2, lt.clone());
        g.set_retry_policy(RetryPolicy {
            base: std::time::Duration::from_micros(10),
            ..Default::default()
        });
        let tracer = Arc::new(Tracer::new(true));
        g.set_tracer(tracer.clone());
        lt.corrupt_next_frames(1);
        let out = g.all_gather(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0], "retry re-sends the clean payload");
        let spans = tracer.drain();
        let faults: Vec<_> = spans.iter().filter(|s| s.cat == Category::Fault).collect();
        assert_eq!(faults.len(), 1, "one backoff for the corrupted frame");
        assert_eq!(faults[0].name, "retry_backoff");
        let collectives = spans.iter().filter(|s| s.cat == Category::Collective).count();
        assert_eq!(collectives as u64, g.stats().ops, "failed attempt emits no span");
        assert_eq!(g.stats().ops, 1, "failed attempt ledgers nothing");
    }

    #[test]
    fn dead_peer_fails_collectives_and_accounting_with_typed_lost_rank() {
        let lt = LocalTransport::new(2);
        let g = Group::with_transport(2, lt.clone());
        lt.fail_peer(1);
        let err = g.all_gather(&[&[1.0], &[2.0]]).unwrap_err();
        match err.downcast_ref::<AlstError>() {
            Some(AlstError::LostRank { site: FaultSite::Wire, rank: 1 }) => {}
            other => panic!("expected LostRank over the wire, got {other:?}"),
        }
        // account_* entries gate on peer liveness too, frames or not
        let err = g.account_gather(64).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<AlstError>(),
            Some(AlstError::LostRank { site: FaultSite::Wire, rank: 1 })
        ));
        assert_eq!(g.stats().ops, 0, "nothing ledgers against a dead peer");
        lt.revive_peer(1);
        assert!(g.all_gather(&[&[1.0], &[2.0]]).is_ok(), "revived peer restores service");
        assert_eq!(g.stats().ops, 1);
    }

    #[test]
    fn socket_group_matches_local_group_bit_for_bit() {
        let st = SocketTransport::spawn(
            2,
            SocketOptions { in_thread: true, ..Default::default() },
            Tracer::off(),
        )
        .unwrap();
        let sock = Group::with_transport(2, st);
        let local = Group::new(2);
        let arena_s = ScratchArena::new();
        let arena_l = ScratchArena::new();
        let shards: [&[f32]; 2] = [&[1.5, -0.0, f32::MIN_POSITIVE], &[2.5e-30, 7.0, -3.25]];
        assert_eq!(
            sock.all_gather(&shards).unwrap().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            local.all_gather(&shards).unwrap().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
        let a = sock.all_to_all(&[&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]], &arena_s).unwrap();
        let b = local.all_to_all(&[&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]], &arena_l).unwrap();
        assert_eq!(a, b);
        assert_eq!(
            sock.reduce_scatter(&[&[1.0, 2.0, 3.0, 4.0], &[0.1, 0.2, 0.3, 0.4]]).unwrap(),
            local.reduce_scatter(&[&[1.0, 2.0, 3.0, 4.0], &[0.1, 0.2, 0.3, 0.4]]).unwrap(),
        );
        assert_eq!(
            sock.all_reduce_scalars(&[0.1, 0.2]).unwrap().to_bits(),
            local.all_reduce_scalars(&[0.1, 0.2]).unwrap().to_bits(),
        );
        assert_eq!(
            sock.send_recv(&[&[9.0, 8.0], &[]], 1).unwrap(),
            local.send_recv(&[&[9.0, 8.0], &[]], 1).unwrap(),
        );
        assert_eq!(sock.stats(), local.stats(), "ledger is transport-agnostic");
    }

    #[test]
    fn account_entries_are_fault_gated_too() {
        let (g, inj) = faulted(2, FaultKind::Transient, 0);
        g.account_gather(64).unwrap();
        assert_eq!(inj.stats().retries, 1, "gate fault absorbed by retry");
        assert_eq!(g.stats().all_gather_bytes, 64);
        assert_eq!(g.stats().ops, 1);

        let (g, _) = faulted(2, FaultKind::LostRank, 0);
        let err = g.account_send_recv(128).unwrap_err();
        assert!(err.downcast_ref::<AlstError>().is_some());
        assert_eq!(g.stats().ops, 0);
    }
}
