//! ALST-RS: Arctic Long Sequence Training reproduced as a three-layer
//! Rust + JAX + Pallas stack. See DESIGN.md for the system inventory.
// Style lints the codebase deliberately trades away: rank/sequence loops
// are written as indexed `for r in 0..sp` to mirror the SPMD math in the
// paper, and the strided copy helpers take (offset, stride) tuples per
// side. CI enforces `clippy -D warnings` over everything else.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::identity_op,
    clippy::erasing_op,
    clippy::type_complexity,
    clippy::new_without_default
)]
pub mod util;
pub mod config;
pub mod runtime;
pub mod collectives;
pub mod coordinator;
pub mod packing;
pub mod tiling;
pub mod memory;
pub mod obs;
pub mod perf;
pub mod metrics;
pub mod paper;
