//! Quickstart: train the tiny GQA transformer with Ulysses SP=2 through
//! the full three-layer stack (rust coordinator -> PJRT -> AOT'd jax/Pallas
//! stages). Mirrors README's first example.
//!
//!     make artifacts
//!     cargo run --release --example quickstart

use alst::coordinator::dataloader::{MarkovSource, UlyssesDataLoader};
use alst::coordinator::pipeline::{Trainer, TrainerOptions};
use alst::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    let dir = Manifest::artifact_dir(std::path::Path::new("artifacts"), "tiny", 2, 256);
    if !dir.exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let mut trainer = Trainer::new(&dir, TrainerOptions::default())?;
    println!(
        "tiny llama ({} params), sp={}, seq={}, kernels={}",
        trainer.manifest.config.params_count,
        trainer.sp(),
        trainer.manifest.seq,
        trainer.manifest.config.kernels
    );

    let vocab = trainer.manifest.config.vocab;
    let mut loader =
        UlyssesDataLoader::new(MarkovSource::new(vocab, 256, 0.05, 7), trainer.sp());

    let mut first = None;
    let mut last = 0.0;
    for step in 1..=30 {
        let (ids, _) = loader.next();
        let m = trainer.train_step(&ids)?;
        first.get_or_insert(m.loss);
        last = m.loss;
        if step % 5 == 0 {
            println!(
                "step {step:>3}  loss {:.4}  ({:.0}ms)",
                m.loss,
                m.step_time.as_secs_f64() * 1e3
            );
        }
    }
    let first = first.unwrap();
    println!(
        "\nloss {first:.3} -> {last:.3} over 30 steps (chance = ln({vocab}) = {:.3})",
        (vocab as f32).ln()
    );
    assert!(last < first, "loss should decrease");
    println!("quickstart OK");
    Ok(())
}
