//! Bench: tiled vs untiled loss-head EXECUTION (paper §3.1).
//!
//! Uses the `HostLossHead` reference executor, so it runs without PJRT
//! artifacts: the comparison is the `tiling::exec` driver overhead
//! (arena tile slicing, padding, pinned reductions) against the same
//! arithmetic in one monolithic pass, plus the paper-scale byte ledger
//! (GiB held untiled vs per tile, and the measured tracker peaks that
//! the acceptance tests pin to `TilePlan::savings()`).
//!
//! Emits `BENCH_tiling.json` (schema in DESIGN.md §Bench trajectory).

use alst::config::GIB;
use alst::memory::MemoryTracker;
use alst::runtime::{HostTensor, ScratchArena};
use alst::tiling::exec::{
    untiled_loss_bwd_bytes, HostLossHead, TiledLossExec, LOSS_HEAD_TAG,
};
use alst::tiling::plan_logits;
use alst::util::bench::{fmt_seqlen, quick, BenchReport, Table};
use alst::util::rng::Rng;

const IGNORE: i32 = -100;

fn main() {
    println!("bench_tiling\n");
    let mut report = BenchReport::new("tiling");

    // ---- timed rows: real host compute, tiled vs untiled ----------------
    let (s, vocab, hidden) = (256usize, 2048usize, 64usize);
    let mut rng = Rng::new(42);
    let lnf: Vec<f32> = (0..hidden).map(|_| 1.0 + 0.02 * rng.normal() as f32).collect();
    let head =
        HostLossHead::new(hidden, vocab, IGNORE, lnf, rng.normal_vec(hidden * vocab, 0.05))
            .unwrap();
    let h = HostTensor::f32(vec![s, hidden], rng.normal_vec(s * hidden, 1.0));
    let labels: Vec<i32> = (0..s).map(|_| (rng.below(vocab)) as i32).collect();
    let arena = ScratchArena::new();
    // logical fp32 logits volume the loss head streams per pass
    let logits_bytes = (s * vocab) as u64 * 4;

    for rows in [s, 32] {
        let tag = if rows == s {
            format!("loss fwd untiled ({s} rows)")
        } else {
            format!("loss fwd tiled rows={rows} ({} tiles)", s.div_ceil(rows))
        };
        let drv = TiledLossExec::new(s, hidden, vocab, rows, IGNORE, &arena).unwrap();
        let mut tracker = MemoryTracker::new(1 << 44);
        let r = quick(&tag, || {
            let sweep = drv
                .forward(&mut tracker, &h, &labels, |ht, lt| {
                    let per = head.per_row_losses(ht.as_f32()?, lt.as_i32()?)?;
                    Ok(HostTensor::f32(vec![per.len()], per))
                })
                .unwrap();
            arena.recycle_f32(sweep.per_row_loss);
        })
        .with_bytes(logits_bytes);
        report.push(&r);
    }
    for rows in [s, 32] {
        let tag = if rows == s {
            format!("loss bwd untiled ({s} rows)")
        } else {
            format!("loss bwd tiled rows={rows} ({} tiles)", s.div_ceil(rows))
        };
        let drv = TiledLossExec::new(s, hidden, vocab, rows, IGNORE, &arena).unwrap();
        let mut tracker = MemoryTracker::new(1 << 44);
        let mut d_lnf = vec![0f32; hidden];
        let mut d_unembed = vec![0f32; hidden * vocab];
        let r = quick(&tag, || {
            let d_h = drv
                .backward(
                    &mut tracker,
                    &h,
                    &labels,
                    &mut d_lnf,
                    &mut d_unembed,
                    |ht, lt| {
                        let lab = lt.as_i32()?;
                        let rows_t = lab.len();
                        let mut dl = vec![0f32; hidden];
                        let mut dw = vec![0f32; hidden * vocab];
                        let mut dh = vec![0f32; rows_t * hidden];
                        head.backward(ht.as_f32()?, lab, 0.25, &mut dl, &mut dw, &mut dh)?;
                        Ok((
                            HostTensor::f32(vec![hidden], dl),
                            HostTensor::f32(vec![hidden, vocab], dw),
                            HostTensor::f32(vec![rows_t, hidden], dh),
                        ))
                    },
                )
                .unwrap();
            arena.recycle(d_h);
        })
        .with_bytes(2 * logits_bytes);
        report.push(&r);
    }

    // ---- paper-scale byte ledger (no compute; tracker-measured) ----------
    let mut table = Table::new(
        "Loss-head bytes, untiled vs tiled (fp32, fwd+bwd copies; §3.1)",
        &["seqlen", "vocab", "untiled GiB", "tile GiB", "tiles", "saving", "measured"],
    );
    for (seq, vocab) in [(16_000usize, 128_256usize), (32_768, 128_256), (131_072, 152_064)]
    {
        let plan = plan_logits(seq, vocab, GIB);
        // measured: drive the no-op executor and read the tracker peaks
        let arena = ScratchArena::new();
        let mut untiled = MemoryTracker::new(1 << 46);
        untiled
            .alloc(untiled_loss_bwd_bytes(seq, vocab), LOSS_HEAD_TAG)
            .unwrap();
        untiled.free(untiled_loss_bwd_bytes(seq, vocab), LOSS_HEAD_TAG);
        let mut tiled = MemoryTracker::new(1 << 46);
        let drv = TiledLossExec::new(seq, 8, vocab, plan.rows_per_tile, IGNORE, &arena)
            .unwrap();
        let h0 = HostTensor::f32(vec![seq, 8], vec![0.0; seq * 8]);
        let lab0 = vec![0i32; seq];
        let mut dl = vec![0f32; 8];
        let mut dw = vec![0f32; 8 * vocab];
        let d_h = drv
            .backward(&mut tiled, &h0, &lab0, &mut dl, &mut dw, |_, lt| {
                let n = lt.numel();
                Ok((
                    HostTensor::f32(vec![8], vec![0.0; 8]),
                    HostTensor::f32(vec![8, vocab], vec![0.0; 8 * vocab]),
                    HostTensor::f32(vec![n, 8], vec![0.0; n * 8]),
                ))
            })
            .unwrap();
        arena.recycle(d_h);
        let measured_drop =
            untiled.tag_peak(LOSS_HEAD_TAG) - tiled.tag_peak(LOSS_HEAD_TAG);
        table.row(&[
            fmt_seqlen(seq),
            vocab.to_string(),
            format!("{:.2}", plan.untiled_bytes as f64 / GIB as f64),
            format!("{:.2}", plan.tile_bytes as f64 / GIB as f64),
            plan.n_tiles.to_string(),
            format!("{:.1}x", plan.saving_factor()),
            format!("{:.2} GiB", measured_drop as f64 / GIB as f64),
        ]);
    }
    table.print();

    match report.write_repo_root() {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_tiling.json: {e}"),
    }
}
