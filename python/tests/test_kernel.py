"""Kernel vs pure-jnp oracle — the core correctness signal (L1).

hypothesis sweeps shapes (and the GQA/MQA head ratios) for each kernel and
asserts allclose against ref.py, forward and backward.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attn, ref, tiled_ce, tiled_mlp

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=12, deadline=None)


def rnd(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# tiled_ce
# ---------------------------------------------------------------------------
class TestTiledCE:
    @settings(**SETTINGS)
    @given(
        s_tiles=st.integers(1, 4),
        v_tiles=st.integers(1, 4),
        tile_s=st.sampled_from([16, 32, 64]),
        tile_v=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_forward_matches_naive(self, s_tiles, v_tiles, tile_s, tile_v, seed):
        s, v, h = s_tiles * tile_s, v_tiles * tile_v, 48
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        hid = jax.random.normal(k1, (s, h))
        w = jax.random.normal(k2, (h, v)) * 0.05
        lab = jax.random.randint(k3, (s,), 0, v).astype(jnp.int32)
        want = ref.ce_naive(hid, w, lab)
        got = tiled_ce.ce_tiled(hid, w, lab, tile_s, tile_v)
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5)
        np.testing.assert_allclose(got[1], want[1])

    def test_ignore_index_tokens_contribute_zero(self):
        s, h, v = 64, 32, 128
        hid, w = rnd(0, (s, h)), rnd(1, (h, v), 0.05)
        lab = jnp.full((s,), ref.IGNORE_INDEX, jnp.int32)
        loss, count = tiled_ce.ce_tiled(hid, w, lab, 32, 64)
        assert float(loss) == 0.0 and float(count) == 0.0

    def test_partial_ignore_matches_naive(self):
        s, h, v = 64, 32, 128
        hid, w = rnd(0, (s, h)), rnd(1, (h, v), 0.05)
        lab = jax.random.randint(jax.random.PRNGKey(2), (s,), 0, v)
        lab = lab.at[::3].set(ref.IGNORE_INDEX).astype(jnp.int32)
        want = ref.ce_naive(hid, w, lab)
        got = tiled_ce.ce_tiled(hid, w, lab, 32, 64)
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5)
        np.testing.assert_allclose(got[1], want[1])

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**16), tile_s=st.sampled_from([16, 32]))
    def test_backward_matches_naive(self, seed, tile_s):
        s, h, v = 64, 32, 128
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        hid = jax.random.normal(k1, (s, h))
        w = jax.random.normal(k2, (h, v)) * 0.05
        lab = jax.random.randint(k3, (s,), 0, v).astype(jnp.int32)
        lab = lab.at[7].set(ref.IGNORE_INDEX)
        g_ref = jax.grad(lambda a, b: ref.ce_naive(a, b, lab)[0], (0, 1))(hid, w)
        g_k = jax.grad(lambda a, b: tiled_ce.ce_tiled(a, b, lab, tile_s, 64)[0],
                       (0, 1))(hid, w)
        np.testing.assert_allclose(g_k[0], g_ref[0], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(g_k[1], g_ref[1], rtol=1e-4, atol=1e-5)

    def test_large_logit_stability(self):
        """Online LSE must survive logits far outside exp() range."""
        s, h, v = 32, 16, 64
        hid = rnd(0, (s, h), 30.0)            # logits ~ O(1000)
        w = rnd(1, (h, v), 3.0)
        lab = jax.random.randint(jax.random.PRNGKey(2), (s,), 0, v).astype(jnp.int32)
        want = ref.ce_naive(hid, w, lab)
        got = tiled_ce.ce_tiled(hid, w, lab, 16, 32)
        assert np.isfinite(float(got[0]))
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5)


# ---------------------------------------------------------------------------
# tiled_mlp
# ---------------------------------------------------------------------------
class TestTiledMLP:
    @settings(**SETTINGS)
    @given(
        n_tiles=st.integers(1, 6),
        tile_s=st.sampled_from([16, 32, 64]),
        h=st.sampled_from([16, 48]),
        f=st.sampled_from([32, 96]),
        seed=st.integers(0, 2**16),
    )
    def test_forward_matches_naive(self, n_tiles, tile_s, h, f, seed):
        s = n_tiles * tile_s
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (s, h))
        wg = jax.random.normal(ks[1], (h, f)) * 0.1
        wu = jax.random.normal(ks[2], (h, f)) * 0.1
        wd = jax.random.normal(ks[3], (f, h)) * 0.1
        np.testing.assert_allclose(
            tiled_mlp.mlp_tiled(x, wg, wu, wd, tile_s),
            ref.mlp_naive(x, wg, wu, wd),
            rtol=1e-4, atol=1e-6,
        )

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**16))
    def test_backward_matches_naive(self, seed):
        s, h, f = 64, 24, 48
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        x = jax.random.normal(ks[0], (s, h))
        wg, wu = (jax.random.normal(k, (h, f)) * 0.1 for k in ks[1:3])
        wd = jax.random.normal(ks[3], (f, h)) * 0.1
        loss_r = lambda *a: (ref.mlp_naive(*a) ** 2).sum()
        loss_k = lambda *a: (tiled_mlp.mlp_tiled(*a, 16) ** 2).sum()
        g_r = jax.grad(loss_r, (0, 1, 2, 3))(x, wg, wu, wd)
        g_k = jax.grad(loss_k, (0, 1, 2, 3))(x, wg, wu, wd)
        for a, b in zip(g_k, g_r):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)

    def test_auto_shards_matches_paper_example(self):
        """Paper §3.1.1: ceil(256_000 / 4096) = 63 shards."""
        assert tiled_mlp.auto_shards(256_000, 4096) == 63
        assert tiled_mlp.auto_shards(1, 4096) == 1
        assert tiled_mlp.auto_shards(4096, 4096) == 1
        assert tiled_mlp.auto_shards(4097, 4096) == 2

    def test_tiled_jnp_variant_matches(self):
        s, h, f = 128, 16, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (s, h))
        wg, wu = (jax.random.normal(k, (h, f)) * 0.1 for k in ks[1:3])
        wd = jax.random.normal(ks[3], (f, h)) * 0.1
        np.testing.assert_allclose(
            ref.mlp_tiled_jnp(x, wg, wu, wd, tile_s=32),
            ref.mlp_naive(x, wg, wu, wd), rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# flash_attn
# ---------------------------------------------------------------------------
class TestFlashAttention:
    @settings(**SETTINGS)
    @given(
        s=st.sampled_from([64, 128, 256]),
        heads=st.sampled_from([(4, 4), (4, 2), (4, 1), (2, 1), (6, 3)]),
        d=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_forward_matches_naive_mha_gqa_mqa(self, s, heads, d, seed):
        hq, hkv = heads
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (s, hq, d))
        k = jax.random.normal(ks[1], (s, hkv, d))
        v = jax.random.normal(ks[2], (s, hkv, d))
        np.testing.assert_allclose(
            flash_attn.attention(q, k, v),
            ref.attention_naive(q, k, v),
            rtol=1e-4, atol=1e-5,
        )

    @settings(**SETTINGS)
    @given(tiles=st.sampled_from([(32, 32), (64, 32), (32, 64), (128, 128)]))
    def test_tile_shape_invariance(self, tiles):
        tq, tk = tiles
        s, hq, hkv, d = 128, 2, 1, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (s, hq, d))
        k = jax.random.normal(ks[1], (s, hkv, d))
        v = jax.random.normal(ks[2], (s, hkv, d))
        np.testing.assert_allclose(
            flash_attn.flash_attention(q, k, v, tile_q=tq, tile_k=tk),
            ref.attention_naive(q, k, v),
            rtol=1e-4, atol=1e-5,
        )

    def test_causality(self):
        """Perturbing future keys must not change earlier outputs."""
        s, hq, hkv, d = 64, 2, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (s, hq, d))
        k = jax.random.normal(ks[1], (s, hkv, d))
        v = jax.random.normal(ks[2], (s, hkv, d))
        o1 = flash_attn.attention(q, k, v)
        k2 = k.at[40:].add(100.0)
        v2 = v.at[40:].add(-50.0)
        o2 = flash_attn.attention(q, k2, v2)
        np.testing.assert_allclose(o1[:40], o2[:40], rtol=1e-5, atol=1e-6)
        assert not np.allclose(o1[41:], o2[41:], atol=1e-3)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**16))
    def test_backward_matches_naive(self, seed):
        s, hq, hkv, d = 64, 4, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (s, hq, d))
        k = jax.random.normal(ks[1], (s, hkv, d))
        v = jax.random.normal(ks[2], (s, hkv, d))
        loss_r = lambda *a: (ref.attention_naive(*a) ** 2).sum()
        loss_k = lambda *a: (flash_attn.attention(*a) ** 2).sum()
        g_r = jax.grad(loss_r, (0, 1, 2))(q, k, v)
        g_k = jax.grad(loss_k, (0, 1, 2))(q, k, v)
        for a, b in zip(g_k, g_r):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)
