//! Packed training end to end: a mixed-length synthetic corpus is
//! FFD-packed into fixed-capacity sequences, sharded segment-aware for
//! Ulysses SP, and (when `make artifacts` has run) trained through the
//! PJRT pipeline with per-document loss reporting. Without artifacts the
//! example still demonstrates the packing stats and the packed perf
//! model — the paper's point that packing N short documents is far
//! cheaper than one long document at equal token count.
//!
//!     cargo run --release --example packed_train

use alst::config::{preset, ClusterConfig, FeatureFlags, PlanKind, GIB};
use alst::coordinator::pipeline::{Trainer, TrainerOptions};
use alst::memory::MemoryTracker;
use alst::metrics::RunLog;
use alst::packing::{MixedLengthSource, PackedDataLoader};
use alst::perf::{iteration_time, iteration_time_packed, IterationModel};
use alst::runtime::{HostTensor, Manifest, ScratchArena};
use alst::tiling::exec::{
    untiled_loss_bwd_bytes, TiledLossExec, LOSS_HEAD_TAG,
};
use alst::tiling::plan_logits;
use alst::util::bench::fmt_seqlen;

fn main() -> anyhow::Result<()> {
    // ---- packing a mixed-length corpus (no artifacts needed) -----------
    let capacity = 256usize;
    let src = MixedLengthSource::new(512, 8, 200, 42);
    let mut loader = PackedDataLoader::new(src, capacity, 2, 32)?;
    let (first, shards) = loader.next()?;
    println!(
        "pack 0: {} docs in {} tokens ({} padding), cu_seqlens {:?}",
        first.n_docs(),
        first.len(),
        first.len() - first.doc_lengths().iter().sum::<usize>(),
        first.cu_seqlens
    );
    println!(
        "rank 0 shard: {} ids, positions reset at {:?} (local boundaries)",
        shards[0].batch.ids.len(),
        shards[0].cu_seqlens_local
    );

    // ---- the packed perf model (paper-scale arithmetic) ----------------
    let model = preset("llama3-8b").unwrap();
    let im = IterationModel {
        model: model.clone(),
        cluster: ClusterConfig::h100(1),
        flags: FeatureFlags::alst(),
        plan: PlanKind::Ulysses,
    };
    let total = 2_000_000usize;
    let one = iteration_time(&im, total, 8);
    println!("\nmodeled iteration at {} total tokens on 8 GPUs:", fmt_seqlen(total));
    println!("  one {}-token document : {:>8.0}s", fmt_seqlen(total), one.iteration_s);
    for k in [8usize, 64, 512] {
        let packed = iteration_time_packed(&im, &vec![total / k; k], 8);
        println!(
            "  {k:>3} packed docs of {:>5} : {:>8.0}s  ({:.1}x faster)",
            fmt_seqlen(total / k),
            packed.iteration_s,
            one.iteration_s / packed.iteration_s
        );
    }

    // ---- the headline win: tiled loss-head execution (§3.1) ------------
    // Tracker-MEASURED peak of the loss-head tag, untiled vs the tiled
    // sweep, at Llama-8B scale (vocab 128256, 32K-token shard). No
    // artifacts needed: the driver streams shape-correct no-op tiles —
    // the measurement is the instrumentation the trainer shares.
    {
        let (s, vocab, hidden) = (32_768usize, 128_256usize, 8usize);
        let plan = plan_logits(s, vocab, GIB);
        let mut untiled = MemoryTracker::new(1 << 46);
        let b = untiled_loss_bwd_bytes(s, vocab);
        untiled.alloc(b, LOSS_HEAD_TAG)?;
        untiled.free(b, LOSS_HEAD_TAG);
        let arena = ScratchArena::new();
        let mut tiled = MemoryTracker::new(1 << 46);
        let drv =
            TiledLossExec::new(s, hidden, vocab, plan.rows_per_tile, -100, &arena)?;
        let h0 = HostTensor::f32(vec![s, hidden], vec![0.0; s * hidden]);
        let labels0 = vec![0i32; s];
        let mut d_lnf = vec![0f32; hidden];
        let mut d_unembed = vec![0f32; hidden * vocab];
        let d_h = drv.backward(
            &mut tiled,
            &h0,
            &labels0,
            &mut d_lnf,
            &mut d_unembed,
            |_, lt| {
                let n = lt.numel();
                Ok((
                    HostTensor::f32(vec![hidden], vec![0.0; hidden]),
                    HostTensor::f32(vec![hidden, vocab], vec![0.0; hidden * vocab]),
                    HostTensor::f32(vec![n, hidden], vec![0.0; n * hidden]),
                ))
            },
        )?;
        arena.recycle(d_h);
        let (up, tp) = (
            untiled.tag_peak(LOSS_HEAD_TAG),
            tiled.tag_peak(LOSS_HEAD_TAG),
        );
        println!(
            "\ntiled loss head at {} x vocab {vocab} ({} tiles of {} rows):",
            fmt_seqlen(s),
            plan.n_tiles,
            plan.rows_per_tile
        );
        println!(
            "  measured loss-head peak: {:.2} GiB untiled -> {:.3} GiB tiled \
             (drop {:.2} GiB, plan savings {:.2} GiB)",
            up as f64 / GIB as f64,
            tp as f64 / GIB as f64,
            (up - tp) as f64 / GIB as f64,
            plan.savings() as f64 / GIB as f64,
        );
    }

    // ---- PJRT training with per-document loss (needs artifacts) --------
    let dir = Manifest::artifact_dir(std::path::Path::new("artifacts"), "tiny", 2, capacity);
    if !dir.join("manifest.json").exists() {
        println!("\n(artifacts missing — run `make artifacts` for the training half)");
        return Ok(());
    }
    // Enable the tiled loss-head sweep when the artifact carries the
    // tile stages: per-document losses then cost ZERO extra loss-head
    // executions, and the tracker shows the §3.1 peak cut for real.
    let tiled_loss = Manifest::load(&dir)?.has_tiled_loss();
    if !tiled_loss {
        println!("(old artifact without tile stages — training untiled)");
    }
    // Trace the run: serial ranks so the attribution table below reads
    // as a fraction of each step (see DESIGN.md §Observability).
    let mut trainer = Trainer::new(
        &dir,
        TrainerOptions {
            tiled_loss,
            trace: true,
            parallel_ranks: false,
            ..Default::default()
        },
    )?;
    let mut log = RunLog::default();
    for step in 1..=10 {
        // loader sp == trainer sp here, so feed the loader's shard set
        // straight in (train_step_packed_shards) — nothing is sharded twice
        let (p, shards) = loader.next()?;
        let m = trainer
            .train_step_packed_shards(&p, shards.into_iter().map(|s| s.batch).collect())?;
        if step % 2 == 0 {
            println!(
                "step {step:>2}  loss {:.4}  docs {}  worst-doc {:.4}",
                m.metrics.loss,
                m.doc_losses.len(),
                m.doc_losses
                    .iter()
                    .map(|d| d.loss)
                    .fold(f32::MIN, f32::max)
            );
        }
        log.push_packed(m);
    }
    println!(
        "\npacking efficiency {:.1}%  mean per-doc loss {:.4}",
        100.0 * log.packing_efficiency().unwrap_or(1.0),
        log.mean_doc_loss().unwrap_or(f32::NAN)
    );
    if tiled_loss {
        println!(
            "tiled loss head: measured per-step loss-head peak {} B \
             (tile-sized; per-doc losses cost no extra loss-head runs)",
            trainer.device.tag_peak(LOSS_HEAD_TAG)
        );
    }
    // Where each step's wall-clock went, from the same spans a
    // `trace.json` export would carry.
    let spans = trainer.tracer().drain();
    let mem = trainer.device.take_events();
    let report = alst::obs::AttributionReport::build(&spans, &mem);
    println!();
    report.to_table().print();
    for line in report.summary_lines() {
        println!("{line}");
    }
    println!("packed_train OK");
    Ok(())
}
