//! `artifacts/<cfg>-sp<k>-seq<n>/manifest.json` — the contract between
//! `python/compile/aot.py` and the coordinator. It fixes the stage input
//! order, every tensor shape, and the flat-parameter layout ZeRO shards.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::{numel, Dtype};
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorMeta {
    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    fn parse(j: &Json) -> Result<TensorMeta> {
        Ok(TensorMeta {
            name: j.str_field("name")?.to_string(),
            shape: j.shape_field("shape")?,
            dtype: Dtype::parse(j.str_field("dtype")?)?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct StageIo {
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

/// One named tensor inside the flat parameter buffer.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// "normal" | "ones" | "zeros" — init recipe (mirrors model.init_params).
    pub init: String,
    /// Offset in f32 elements into the flat parameter vector.
    pub offset: usize,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }
}

/// The flat layout: [embed group][layer 0]..[layer L-1][final group].
#[derive(Debug, Clone)]
pub struct ParamLayout {
    pub embed: Vec<ParamEntry>,
    /// Template for ONE layer; layer `i` lives at
    /// `embed_numel + i * layer_numel + entry.offset`.
    pub layer: Vec<ParamEntry>,
    pub final_: Vec<ParamEntry>,
    pub embed_numel: usize,
    pub layer_numel: usize,
    pub final_numel: usize,
    pub n_layers: usize,
}

impl ParamLayout {
    pub fn total_numel(&self) -> usize {
        self.embed_numel + self.n_layers * self.layer_numel + self.final_numel
    }

    /// Absolute offset of `name` within layer `layer_idx`'s group.
    pub fn layer_tensor(&self, layer_idx: usize, name: &str) -> Option<(usize, &ParamEntry)> {
        let e = self.layer.iter().find(|e| e.name == name)?;
        Some((self.embed_numel + layer_idx * self.layer_numel + e.offset, e))
    }

    pub fn embed_tensor(&self, name: &str) -> Option<(usize, &ParamEntry)> {
        let e = self.embed.iter().find(|e| e.name == name)?;
        Some((e.offset, e))
    }

    pub fn final_tensor(&self, name: &str) -> Option<(usize, &ParamEntry)> {
        let e = self.final_.iter().find(|e| e.name == name)?;
        Some((
            self.embed_numel + self.n_layers * self.layer_numel + e.offset,
            e,
        ))
    }

    /// Flat-range of one whole layer group (for just-in-time all-gather).
    pub fn layer_range(&self, layer_idx: usize) -> std::ops::Range<usize> {
        let start = self.embed_numel + layer_idx * self.layer_numel;
        start..start + self.layer_numel
    }
}

/// Architecture echo of the python ModelConfig (subset the runtime needs).
#[derive(Debug, Clone)]
pub struct ManifestConfig {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub ffn: usize,
    pub head_dim: usize,
    pub params_count: usize,
    pub kernels: String,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ManifestConfig,
    pub seq: usize,
    pub sp: usize,
    pub seq_shard: usize,
    pub q_heads_shard: usize,
    pub kv_heads_shard: usize,
    pub ignore_index: i32,
    pub stages: BTreeMap<String, StageIo>,
    pub params: ParamLayout,
}

pub const STAGE_NAMES: &[&str] = &[
    "embed_fwd", "embed_bwd", "pre_attn_fwd", "pre_attn_bwd", "attn_fwd",
    "attn_bwd", "post_attn_fwd", "post_attn_bwd", "loss_fwd", "loss_bwd",
];

/// OPTIONAL tiled-execution stages (paper §3.1 executed). Newer AOT
/// exports always carry them; manifests without them still load and the
/// coordinator falls back to the monolithic loss/post_attn stages, so
/// old artifact directories remain valid.
pub const OPTIONAL_STAGE_NAMES: &[&str] =
    &["loss_fwd_tile", "loss_bwd_tile", "mlp_fwd_tile", "mlp_bwd_tile"];

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let cj = j.field("config")?;
        let config = ManifestConfig {
            name: cj.str_field("name")?.to_string(),
            vocab: cj.usize_field("vocab")?,
            hidden: cj.usize_field("hidden")?,
            n_layers: cj.usize_field("n_layers")?,
            n_q_heads: cj.usize_field("n_q_heads")?,
            n_kv_heads: cj.usize_field("n_kv_heads")?,
            ffn: cj.usize_field("ffn")?,
            head_dim: cj.usize_field("head_dim")?,
            params_count: cj.usize_field("params_count")?,
            kernels: cj.str_field("kernels")?.to_string(),
        };

        let mut stages = BTreeMap::new();
        let sj = j
            .field("stages")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("stages is not an object"))?;
        for (name, st) in sj {
            let parse_list = |key: &str| -> Result<Vec<TensorMeta>> {
                st.field(key)?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("{key} not an array"))?
                    .iter()
                    .map(TensorMeta::parse)
                    .collect()
            };
            stages.insert(
                name.clone(),
                StageIo {
                    file: st.str_field("file")?.to_string(),
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                },
            );
        }
        for required in STAGE_NAMES {
            if !stages.contains_key(*required) {
                bail!("manifest missing stage `{required}`");
            }
        }

        let lj = j.field("param_layout")?;
        let parse_group = |key: &str| -> Result<(Vec<ParamEntry>, usize)> {
            let arr = lj
                .field(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("param group {key} not array"))?;
            let mut out = Vec::new();
            let mut off = 0usize;
            for e in arr {
                let shape = e.shape_field("shape")?;
                let n = numel(&shape);
                out.push(ParamEntry {
                    name: e.str_field("name")?.to_string(),
                    shape,
                    init: e.str_field("init")?.to_string(),
                    offset: off,
                });
                off += n;
            }
            Ok((out, off))
        };
        let (embed, embed_numel) = parse_group("embed")?;
        let (layer, layer_numel) = parse_group("layer")?;
        let (final_, final_numel) = parse_group("final")?;
        let params = ParamLayout {
            embed,
            layer,
            final_,
            embed_numel,
            layer_numel,
            final_numel,
            n_layers: config.n_layers,
        };
        if params.total_numel() != config.params_count {
            bail!(
                "param layout total {} != params_count {}",
                params.total_numel(),
                config.params_count
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            config,
            seq: j.usize_field("seq")?,
            sp: j.usize_field("sp")?,
            seq_shard: j.usize_field("seq_shard")?,
            q_heads_shard: j.usize_field("q_heads_shard")?,
            kv_heads_shard: j.usize_field("kv_heads_shard")?,
            ignore_index: j.f64_field("ignore_index")? as i32,
            stages,
            params,
        })
    }

    pub fn stage(&self, name: &str) -> &StageIo {
        &self.stages[name]
    }

    /// Whether this artifact carries `name` (use for the
    /// [`OPTIONAL_STAGE_NAMES`] tiled-execution stages).
    pub fn has_stage(&self, name: &str) -> bool {
        self.stages.contains_key(name)
    }

    /// All four tiled-execution stages for the loss head present?
    pub fn has_tiled_loss(&self) -> bool {
        self.has_stage("loss_fwd_tile") && self.has_stage("loss_bwd_tile")
    }

    /// Both tiled post-attention/MLP stages present?
    pub fn has_tiled_mlp(&self) -> bool {
        self.has_stage("mlp_fwd_tile") && self.has_stage("mlp_bwd_tile")
    }

    fn tile_rows(&self, stage: &str, input: &str) -> Option<usize> {
        self.stages
            .get(stage)?
            .inputs
            .iter()
            .find(|t| t.name == input)
            .and_then(|t| t.shape.first().copied())
    }

    /// Rows per loss-head tile, read back from the `loss_fwd_tile`
    /// stage's `h` input shape — the exporter's baked-in shapes are the
    /// single source of truth, so the driver cannot drift from the
    /// compiled artifact.
    pub fn loss_tile_rows(&self) -> Option<usize> {
        self.tile_rows("loss_fwd_tile", "h")
    }

    /// Rows per post-attention/MLP tile (`mlp_fwd_tile`'s `h_in` shape).
    pub fn mlp_tile_rows(&self) -> Option<usize> {
        self.tile_rows("mlp_fwd_tile", "h_in")
    }

    pub fn stage_path(&self, name: &str) -> PathBuf {
        self.dir.join(&self.stages[name].file)
    }

    /// Locate an artifact dir under `root` for (config, sp, seq).
    pub fn artifact_dir(root: &Path, config: &str, sp: usize, seq: usize) -> PathBuf {
        root.join(format!("{config}-sp{sp}-seq{seq}"))
    }
}
