//! Integration tests over the real PJRT pipeline (require `make artifacts`).
//!
//! These prove the three layers compose: rust coordinator -> PJRT CPU ->
//! AOT'd jax/Pallas stage programs — including the paper's central claims:
//! SP-degree invariance of the training trajectory (Figure 13) and
//! attention-implementation agnosticism (§3.2).

use std::path::{Path, PathBuf};

use alst::config::FeatureFlags;
use alst::coordinator::dataloader::{MarkovSource, UlyssesDataLoader};
use alst::coordinator::pipeline::{Trainer, TrainerOptions};
use alst::runtime::Manifest;

fn artifacts(config: &str, sp: usize, seq: usize) -> Option<PathBuf> {
    // tests run from the crate root
    let dir = Manifest::artifact_dir(Path::new("artifacts"), config, sp, seq);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "SKIP: {} missing — run `make artifacts`",
            dir.display()
        );
        None
    }
}

fn train_losses(dir: &Path, sp: usize, steps: usize, seed: u64) -> Vec<f32> {
    let mut trainer = Trainer::new(
        dir,
        TrainerOptions { seed, checked: true, ..Default::default() },
    )
    .expect("trainer");
    let vocab = trainer.manifest.config.vocab;
    let seq = trainer.manifest.seq;
    let mut loader =
        UlyssesDataLoader::new(MarkovSource::new(vocab, seq, 0.05, seed ^ 1), sp);
    (0..steps)
        .map(|_| {
            let (ids, _) = loader.next();
            trainer.train_step(&ids).expect("step").loss
        })
        .collect()
}

#[test]
fn tiny_sp2_trains_and_loss_decreases() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let losses = train_losses(&dir, 2, 25, 3);
    let first = losses[0];
    let last = *losses.last().unwrap();
    assert!(first.is_finite() && last.is_finite());
    // starts near chance ln(512)=6.24 on the Markov corpus
    assert!((first - 6.24).abs() < 0.5, "first loss {first}");
    assert!(last < first - 0.05, "no learning: {first} -> {last}");
}

#[test]
fn figure13_sp_invariance_through_pjrt() {
    // Identical init + data: SP=1, 2, 4 must produce the same trajectory.
    // SP=4 > kv_heads=2 also exercises kv replication end to end.
    let (Some(d1), Some(d2), Some(d4)) = (
        artifacts("tiny", 1, 256),
        artifacts("tiny", 2, 256),
        artifacts("tiny", 4, 256),
    ) else {
        return;
    };
    let l1 = train_losses(&d1, 1, 5, 42);
    let l2 = train_losses(&d2, 2, 5, 42);
    let l4 = train_losses(&d4, 4, 5, 42);
    for i in 0..5 {
        assert!(
            (l1[i] - l2[i]).abs() < 1e-4,
            "sp1 vs sp2 step {i}: {} vs {}",
            l1[i],
            l2[i]
        );
        assert!(
            (l1[i] - l4[i]).abs() < 1e-4,
            "sp1 vs sp4 step {i}: {} vs {}",
            l1[i],
            l4[i]
        );
    }
}

#[test]
fn attention_agnostic_kernel_swap() {
    // §3.2: the coordinator is agnostic to the attention implementation.
    // `tiny` uses the Pallas flash kernel, `tiny-ref` the naive jnp path;
    // same coordinator, same seed -> same losses.
    let (Some(d_pallas), Some(d_ref)) =
        (artifacts("tiny", 2, 256), artifacts("tiny-ref", 2, 256))
    else {
        return;
    };
    let lp = train_losses(&d_pallas, 2, 4, 11);
    let lr = train_losses(&d_ref, 2, 4, 11);
    for i in 0..4 {
        assert!(
            (lp[i] - lr[i]).abs() < 2e-3,
            "kernel swap changed training: step {i}: {} vs {}",
            lp[i],
            lr[i]
        );
    }
}

#[test]
fn tiled_execution_matches_monolithic_training() {
    // Tiled loss-head + tiled MLP EXECUTION must reproduce the
    // monolithic training trajectory (fp tolerance through XLA — the
    // tile stages re-round reductions; the bit-level contract is pinned
    // PJRT-free in tests/tiled_exec.rs).
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let man = Manifest::load(&dir).unwrap();
    if !man.has_tiled_loss() || !man.has_tiled_mlp() {
        eprintln!("SKIP: artifact predates tile stages — re-run `make artifacts`");
        return;
    }
    let run = |tiled: bool| -> Vec<f32> {
        let mut t = Trainer::new(
            &dir,
            TrainerOptions {
                seed: 42,
                checked: true,
                tiled_loss: tiled,
                tiled_mlp: tiled,
                ..Default::default()
            },
        )
        .unwrap();
        let mut dl = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 41), 2);
        (0..4)
            .map(|_| {
                let (ids, _) = dl.next();
                t.train_step(&ids).expect("step").loss
            })
            .collect()
    };
    let mono = run(false);
    let tiled = run(true);
    for i in 0..4 {
        assert!(
            (mono[i] - tiled[i]).abs() < 1e-3,
            "step {i}: tiled {} vs monolithic {}",
            tiled[i],
            mono[i]
        );
    }
}

#[test]
fn old_manifests_without_tile_stages_still_load() {
    // Optional-stage compatibility: a manifest stripped of the four
    // `*_tile` stages (i.e. an old artifact) must still load and train
    // untiled, and the tiled TrainerOptions must refuse it with a clear
    // error rather than silently falling back.
    use alst::util::json::Json;
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let tmp = std::env::temp_dir().join("alst-no-tile-stages");
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let e = entry.unwrap();
        std::fs::copy(e.path(), tmp.join(e.file_name())).unwrap();
    }
    let mpath = tmp.join("manifest.json");
    let mut doc = Json::parse(&std::fs::read_to_string(&mpath).unwrap()).unwrap();
    let Json::Obj(root) = &mut doc else { panic!("manifest root") };
    let Some(Json::Obj(stages)) = root.get_mut("stages") else {
        panic!("manifest stages")
    };
    if stages.remove("loss_fwd_tile").is_none() {
        eprintln!("SKIP: artifact predates tile stages — re-run `make artifacts`");
        return;
    }
    stages.remove("loss_bwd_tile");
    stages.remove("mlp_fwd_tile");
    stages.remove("mlp_bwd_tile");
    std::fs::write(&mpath, doc.to_string_pretty()).unwrap();

    // untiled load + step works (backward compat)...
    let man = Manifest::load(&tmp).unwrap();
    assert!(!man.has_tiled_loss() && !man.has_tiled_mlp());
    assert_eq!(man.loss_tile_rows(), None);
    let mut t = Trainer::new(&tmp, TrainerOptions { seed: 3, ..Default::default() })
        .unwrap();
    let mut dl = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 3), 2);
    let (ids, _) = dl.next();
    assert!(t.train_step(&ids).unwrap().loss.is_finite());

    // ...and the tiled options refuse with a pointer at the fix
    let err = Trainer::new(
        &tmp,
        TrainerOptions { tiled_loss: true, ..Default::default() },
    )
    .err()
    .expect("tiled_loss must be refused without tile stages");
    assert!(format!("{err:#}").contains("loss_fwd_tile"), "{err:#}");
    let err = Trainer::new(
        &tmp,
        TrainerOptions { tiled_mlp: true, ..Default::default() },
    )
    .err()
    .expect("tiled_mlp must be refused without tile stages");
    assert!(format!("{err:#}").contains("mlp_fwd_tile"), "{err:#}");
}

#[test]
fn ckpt_offload_does_not_change_numerics() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let mut flags_off = FeatureFlags::alst();
    flags_off.ckpt_offload = false;
    let base = {
        let mut t = Trainer::new(
            &dir,
            TrainerOptions { flags: flags_off, seed: 9, ..Default::default() },
        )
        .unwrap();
        let mut dl = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 8), 2);
        let (ids, _) = dl.next();
        t.train_step(&ids).unwrap()
    };
    let offl = {
        let mut t = Trainer::new(
            &dir,
            TrainerOptions { flags: FeatureFlags::alst(), seed: 9, ..Default::default() },
        )
        .unwrap();
        let mut dl = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 8), 2);
        let (ids, _) = dl.next();
        t.train_step(&ids).unwrap()
    };
    assert_eq!(base.loss, offl.loss, "offload is accounting-only");
    assert!(offl.ckpt_transfer_bytes > 0);
    assert_eq!(base.ckpt_transfer_bytes, 0);
    assert!(offl.device_peak_bytes < base.device_peak_bytes,
        "offload must reduce device peak: {} vs {}",
        offl.device_peak_bytes, base.device_peak_bytes);
}

#[test]
fn eval_loss_matches_train_loss_before_update() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let mut trainer =
        Trainer::new(&dir, TrainerOptions { seed: 5, ..Default::default() }).unwrap();
    let mut dl = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 4), 2);
    let (ids, _) = dl.next();
    let ev = trainer.eval_loss(&ids).unwrap();
    let tr = trainer.train_step(&ids).unwrap().loss;
    assert!((ev - tr).abs() < 1e-5, "{ev} vs {tr}");
    // after the update, the SAME sequence must score better
    let ev2 = trainer.eval_loss(&ids).unwrap();
    assert!(ev2 < ev, "{ev} -> {ev2}");
}

#[test]
fn a2a_traffic_matches_closed_form() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let mut trainer =
        Trainer::new(&dir, TrainerOptions { seed: 1, ..Default::default() }).unwrap();
    let mut dl = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 2), 2);
    let (ids, _) = dl.next();
    let m = trainer.train_step(&ids).unwrap();
    // per layer: fwd (q+k+v seq->head, o head->seq) + recompute (same) +
    // bwd (d_attn seq->head, d_q/d_k/d_v head->seq).
    let (seq, sp, d) = (256u64, 2u64, 16u64);
    let (nq, nkv, q_sh, kv_sh) = (4u64, 2u64, 2u64, 1u64);
    let fwd_once = 4 * (seq * q_sh * d           // q out
        + 2 * seq * kv_sh * d                    // k, v out
        + seq * q_sh * d);                       // o back
    let _ = fwd_once; // closed form spelled out below per direction:
    let s2h = |heads_out: u64| sp * seq / sp * heads_out * d * sp / sp; // logical
    let _ = s2h;
    let q = seq * q_sh * d * sp / sp; // per-rank out, summed over ranks = seq*q_sh*d*sp
    let _ = q;
    // Simplest exact check: recompute expectation from the ulysses helper.
    let per_block_fwd = alst::coordinator::ulysses::a2a_bytes_per_block(
        seq as usize, nq as usize, nkv as usize, d as usize, sp as usize, 4,
    );
    // fwd + recompute + bwd(d_o in + d_q/d_k/d_v out ~ same volume as fwd)
    let expect = per_block_fwd * 3 * trainer.n_layers() as u64;
    assert_eq!(m.a2a_bytes, expect, "a2a ledger mismatch");
}

#[test]
fn manifest_rejects_missing_dir() {
    let err = Trainer::new(Path::new("artifacts/nonexistent"), TrainerOptions::default());
    assert!(err.is_err());
}

#[test]
fn wrong_sequence_length_is_rejected() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let mut trainer =
        Trainer::new(&dir, TrainerOptions::default()).unwrap();
    let ids = vec![1i32; 128]; // artifact expects 256
    assert!(trainer.train_step(&ids).is_err());
}

#[test]
fn gradient_accumulation_equals_paper_gas_protocol() {
    // §5.6: the baseline uses grad accumulation to see the same data as
    // the SP run. Accumulating two micro-batches must differ from two
    // separate optimizer steps, and the accumulated loss must be the mean.
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let mut t = Trainer::new(&dir, TrainerOptions { seed: 21, ..Default::default() }).unwrap();
    let mut dl = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 20), 2);
    let (a, _) = dl.next();
    let (b, _) = dl.next();
    let m = t.train_step_accum(&[a.clone(), b.clone()]).unwrap();
    assert!(m.loss.is_finite());
    assert_eq!(m.tokens, 512);
    assert_eq!(t.step_count(), 1); // ONE optimizer step for two batches

    // the accumulated loss is the mean of the two individual losses
    let mut t2 =
        Trainer::new(&dir, TrainerOptions { seed: 21, ..Default::default() }).unwrap();
    let la = t2.eval_loss(&a).unwrap();
    let lb = t2.eval_loss(&b).unwrap();
    assert!((m.loss - (la + lb) / 2.0).abs() < 1e-4, "{} vs {}", m.loss, (la + lb) / 2.0);
}

#[test]
fn snapshot_resume_continues_identically() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let snap_path = std::env::temp_dir().join("alst-resume-test.alst");

    // run 4 steps, snapshot after 2
    let mut t1 = Trainer::new(&dir, TrainerOptions { seed: 33, ..Default::default() }).unwrap();
    let mut dl1 = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 30), 2);
    let mut losses_full = Vec::new();
    for i in 0..4 {
        let (ids, _) = dl1.next();
        losses_full.push(t1.train_step(&ids).unwrap().loss);
        if i == 1 {
            t1.save_snapshot(&snap_path).unwrap();
        }
    }

    // fresh trainer resumes from the snapshot; replay the same data stream
    let mut t2 = Trainer::new(&dir, TrainerOptions { seed: 99, ..Default::default() }).unwrap();
    t2.load_snapshot(&snap_path).unwrap();
    assert_eq!(t2.step_count(), 2);
    let mut dl2 = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 30), 2);
    let (_s1, _) = dl2.next();
    let (_s2, _) = dl2.next();
    for i in 2..4 {
        let (ids, _) = dl2.next();
        let loss = t2.train_step(&ids).unwrap().loss;
        assert!(
            (loss - losses_full[i]).abs() < 1e-5,
            "resume diverged at step {i}: {loss} vs {}",
            losses_full[i]
        );
    }
}

#[test]
fn lr_schedule_is_applied() {
    use alst::coordinator::pipeline::LrSchedule;
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let sched = LrSchedule { peak_lr: 1e-3, warmup_steps: 2, total_steps: 10, min_lr: 1e-5 };
    // schedule math itself:
    assert!((sched.lr_at(0) - 5e-4).abs() < 1e-9);
    assert!((sched.lr_at(1) - 1e-3).abs() < 1e-9);
    assert!(sched.lr_at(9) < sched.lr_at(2));
    assert!(sched.lr_at(100) >= 1e-5);

    let mut t = Trainer::new(
        &dir,
        TrainerOptions { seed: 1, lr_schedule: Some(sched), ..Default::default() },
    )
    .unwrap();
    let mut dl = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 2), 2);
    let (ids, _) = dl.next();
    t.train_step(&ids).unwrap();
    assert!((t.opt.cfg.lr - 5e-4).abs() < 1e-9, "warmup lr applied: {}", t.opt.cfg.lr);
}

#[test]
fn host_pool_exhaustion_surfaces_through_trainer() {
    // §5.3.2's failure mode: ckpt offload needs host RAM; when the node
    // budget is too small the step must fail with a clear error (not OOM
    // the device silently).
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let mut t = Trainer::new(
        &dir,
        TrainerOptions { host_bytes: 1024, ..Default::default() }, // 1 KiB host
    )
    .unwrap();
    let mut dl = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 2), 2);
    let (ids, _) = dl.next();
    let err = t.train_step(&ids).unwrap_err();
    assert!(format!("{err:#}").contains("host memory"), "{err:#}");
}

#[test]
fn device_budget_exhaustion_without_offload() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let mut flags = FeatureFlags::alst();
    flags.ckpt_offload = false; // checkpoints land on the tiny device
    let mut t = Trainer::new(
        &dir,
        TrainerOptions { flags, device_bytes: 4096, ..Default::default() },
    )
    .unwrap();
    let mut dl = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 2), 2);
    let (ids, _) = dl.next();
    let err = t.train_step(&ids).unwrap_err();
    assert!(format!("{err:#}").contains("OOM"), "{err:#}");
}

#[test]
fn corrupt_manifest_is_rejected_with_context() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    // copy the artifact dir, then break the manifest param layout
    let tmp = std::env::temp_dir().join("alst-corrupt-manifest");
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let e = entry.unwrap();
        std::fs::copy(e.path(), tmp.join(e.file_name())).unwrap();
    }
    let mpath = tmp.join("manifest.json");
    let text = std::fs::read_to_string(&mpath).unwrap();
    // params_count inconsistent with the layout -> loader must refuse
    let broken = text.replace("\"params_count\": 139584", "\"params_count\": 1");
    assert_ne!(text, broken, "fixture assumption: tiny params_count");
    std::fs::write(&mpath, broken).unwrap();
    let err = Trainer::new(&tmp, TrainerOptions::default());
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("param layout"), "{msg}");
}

#[test]
fn truncated_hlo_artifact_fails_compile_not_crash() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let tmp = std::env::temp_dir().join("alst-truncated-hlo");
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let e = entry.unwrap();
        std::fs::copy(e.path(), tmp.join(e.file_name())).unwrap();
    }
    let hlo = tmp.join("attn_fwd.hlo.txt");
    let text = std::fs::read_to_string(&hlo).unwrap();
    std::fs::write(&hlo, &text[..text.len() / 3]).unwrap();
    let err = Trainer::new(&tmp, TrainerOptions::default());
    assert!(err.is_err(), "truncated HLO must be a load error");
}

#[test]
fn corpus_source_trains_through_pipeline() {
    // the tiny-corpus (byte-tokenized real file) data path end to end
    use alst::coordinator::dataloader::{BatchSource, CorpusSource};
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let mut t =
        Trainer::new(&dir, TrainerOptions { seed: 2, ..Default::default() }).unwrap();
    let mut src = CorpusSource::from_file(Path::new("README.md"), 256, 3).unwrap();
    for _ in 0..2 {
        let ids = src.next_sequence();
        let m = t.train_step(&ids).unwrap();
        assert!(m.loss.is_finite() && m.loss > 0.0);
        // byte corpus: every token id < 256 < vocab 512
        assert!(ids.iter().all(|&i| i < 256));
    }
}
