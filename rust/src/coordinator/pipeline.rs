//! The distributed train step: Ulysses SP forward/backward over AOT PJRT
//! stages, with ZeRO-3 just-in-time parameter gathering, activation
//! checkpointing (+ optional CPU offload), recompute-based backward, and
//! sharded AdamW.
//!
//! Rank execution is SPMD simulated in-process: every rank's buffers are
//! isolated; collectives are the explicit relayouts in
//! `coordinator::ulysses` / `collectives::Group`. The stage programs are
//! exactly the jax functions `python/compile/aot.py` lowered — python
//! never runs here.
//!
//! §Perf note: parameters are uploaded to device buffers ONCE per step
//! (`StepParams`) and reused across ranks / forward / recompute / backward.
//! On real hardware ZeRO-3 would re-gather per layer in backward — the
//! collective LEDGER still records those gathers (the perf model consumes
//! protocol-accurate volumes); only the redundant single-device memcpys
//! are elided. Before this change a 100M-param step re-marshaled every
//! layer's weights 12x (4 ranks x 3 passes); see EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::collectives::Group;
use crate::config::FeatureFlags;
use crate::coordinator::dataloader::{shard_sequence, ShardedBatch, IGNORE_INDEX};
use crate::packing::{shard_packed, PackedSequence};
use crate::coordinator::optimizer::{AdamW, AdamWConfig};
use crate::coordinator::tape::CheckpointTape;
use crate::coordinator::ulysses::{a2a_head_to_seq_into, a2a_seq_to_head_into};
use crate::coordinator::zero::{init_flat_params, slice_group, GroupGrads, ShardedStore};
use crate::memory::{HostPool, MemoryTracker};
use crate::runtime::{Engine, HostTensor, Manifest, ScratchArena};

/// Execute `f` once per rank, returning the per-rank results in rank
/// order. With `parallel` (and at least two ranks) the ranks run
/// concurrently on `std::thread::scope` threads — legal because the
/// simulated ranks share no mutable state by design (DESIGN.md
/// substitutions: rank-parallelism is data isolation in the coordinator),
/// and the `Group`/`Engine` ledgers sit behind locks whose per-op updates
/// are commutative sums, so the accounted totals are byte-identical to a
/// serial run regardless of thread interleaving (pinned by
/// `rust/tests/relayout_equiv.rs`).
pub fn run_ranks<T, F>(sp: usize, parallel: bool, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    if !parallel || sp < 2 {
        return (0..sp).map(f).collect();
    }
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = (0..sp).map(|r| scope.spawn(move || f(r))).collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow::anyhow!("rank thread panicked"))?)
            .collect()
    })
}

/// Linear-warmup + cosine-decay learning-rate schedule.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub peak_lr: f32,
    pub warmup_steps: u64,
    pub total_steps: u64,
    pub min_lr: f32,
}

impl LrSchedule {
    pub fn lr_at(&self, step: u64) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.peak_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        let decay_steps = self.total_steps.saturating_sub(self.warmup_steps).max(1);
        let t = (step.saturating_sub(self.warmup_steps)).min(decay_steps) as f32
            / decay_steps as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
        self.min_lr + (self.peak_lr - self.min_lr) * cos
    }
}

#[derive(Debug, Clone)]
pub struct TrainerOptions {
    pub flags: FeatureFlags,
    pub adamw: AdamWConfig,
    /// Optional LR schedule; overrides `adamw.lr` per step when set.
    pub lr_schedule: Option<LrSchedule>,
    pub seed: u64,
    /// Simulated per-rank device budget for checkpoint accounting. Large
    /// default: the real constraint analysis lives in `memory::search`.
    pub device_bytes: u64,
    /// Host pool for checkpoint offload.
    pub host_bytes: u64,
    /// Validate every stage's shapes against the manifest (tests; ~free).
    pub checked: bool,
    /// Extract per-document losses on packed steps. Costs n_docs extra
    /// loss-head passes (the logits matmul — the most expensive single
    /// stage at large vocab) per step; turn off for steady-state
    /// training where only the aggregate loss matters.
    pub per_doc_loss: bool,
    /// Run the data-isolated per-rank stage executions on scoped threads
    /// (`run_ranks`). Accounting stays deterministic (see `run_ranks`);
    /// turn off to debug with strictly serial rank order. Note: assumes
    /// the linked `xla` crate's buffers are `Sync` (true of the vendored
    /// stub's host-side buffers). Cost model: each stage call spawns and
    /// joins `sp` scoped threads (scoped spawning is what lets the
    /// closures borrow per-call rank state safely), so the win
    /// materializes when per-rank stage work dominates the ~tens-of-µs
    /// spawn cost — the multi-K-token regime; for toy configs where a
    /// stage is microseconds, serial can be faster.
    pub parallel_ranks: bool,
    /// Pooled-byte budget per dtype for the relayout scratch arena.
    /// Raise it when the per-step relayout working set exceeds the
    /// default (see `runtime::tensor::DEFAULT_POOL_BYTE_BUDGET`) or the
    /// pool sheds buffers and every checkout allocates.
    pub arena_byte_budget: usize,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            flags: FeatureFlags::alst(),
            adamw: AdamWConfig::default(),
            lr_schedule: None,
            seed: 0,
            device_bytes: 1 << 40,
            host_bytes: 1 << 40,
            checked: false,
            per_doc_loss: true,
            parallel_ranks: true,
            arena_byte_budget: crate::runtime::tensor::DEFAULT_POOL_BYTE_BUDGET,
        }
    }
}

/// Per-step record (metrics.rs aggregates these).
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: u64,
    pub loss: f32,
    pub grad_norm: f64,
    pub tokens: usize,
    pub step_time: Duration,
    pub a2a_bytes: u64,
    pub gather_bytes: u64,
    pub reduce_scatter_bytes: u64,
    pub ckpt_transfer_bytes: u64,
    pub device_peak_bytes: u64,
}

/// Loss attributed to one document of a packed batch (`metrics` logs
/// these; `tokens` is the document length, so `tokens - 1` targets).
#[derive(Debug, Clone, PartialEq)]
pub struct DocumentLoss {
    pub doc_id: u64,
    pub tokens: usize,
    pub loss: f32,
}

/// Per-step record for a packed batch: the aggregate step metrics plus
/// the per-document loss breakdown and packing accounting.
#[derive(Debug, Clone)]
pub struct PackedStepMetrics {
    pub metrics: StepMetrics,
    pub doc_losses: Vec<DocumentLoss>,
    /// Document tokens in the pack (excludes padding).
    pub real_tokens: usize,
    /// Trailing padding tokens (loss-masked).
    pub padding_tokens: usize,
}

/// Device-resident parameter buffers for one step (perf fast path).
struct StepParams {
    embed: Vec<xla::PjRtBuffer>,
    layers: Vec<Vec<xla::PjRtBuffer>>,
    final_: Vec<xla::PjRtBuffer>,
}

pub struct Trainer {
    pub manifest: Manifest,
    pub engine: Engine,
    pub flags: FeatureFlags,
    pub group: Group,
    pub params: ShardedStore,
    pub grads: ShardedStore,
    pub opt: AdamW,
    pub device: MemoryTracker,
    pub host: HostPool,
    lr_schedule: Option<LrSchedule>,
    step: u64,
    checked: bool,
    per_doc_loss: bool,
    parallel_ranks: bool,
    /// Scratch-buffer pool the step loop's relayouts ping-pong through:
    /// after the first forward/backward cycle populates it, the 2×n_layers
    /// relayouts of every later step are allocation-free.
    arena: ScratchArena,
}

impl Trainer {
    /// Build a trainer from an artifact directory (manifest + HLO stages).
    pub fn new(artifact_dir: &std::path::Path, opts: TrainerOptions) -> Result<Trainer> {
        let manifest = Manifest::load(artifact_dir)
            .with_context(|| format!("loading manifest from {}", artifact_dir.display()))?;
        let mut engine = Engine::cpu()?;
        engine.load_manifest(&manifest)?;

        let sp = manifest.sp;
        // ZeRO-3 shards over the SP group; without zero3 every rank holds
        // a full replica (world=1 sharding on a shared store).
        let shard_world = if opts.flags.zero3 { sp } else { 1 };
        let flat = init_flat_params(&manifest.params, opts.seed, 0.02);
        let total = flat.len();
        let params = ShardedStore::from_flat(&flat, shard_world);
        let grads = ShardedStore::zeros(total, shard_world);
        let opt = AdamW::new(opts.adamw, total, shard_world);

        Ok(Trainer {
            manifest,
            engine,
            flags: opts.flags,
            group: Group::new(sp),
            params,
            grads,
            opt,
            device: MemoryTracker::new(opts.device_bytes),
            host: HostPool::new(opts.host_bytes),
            lr_schedule: opts.lr_schedule,
            step: 0,
            checked: opts.checked,
            per_doc_loss: opts.per_doc_loss,
            parallel_ranks: opts.parallel_ranks,
            arena: ScratchArena::with_byte_budget(opts.arena_byte_budget),
        })
    }

    pub fn sp(&self) -> usize {
        self.manifest.sp
    }

    /// The trainer's relayout scratch pool (hit/miss counters readable by
    /// tests and benches; steady-state hit rate should be 1.0).
    pub fn arena(&self) -> &ScratchArena {
        &self.arena
    }

    pub fn n_layers(&self) -> usize {
        self.manifest.config.n_layers
    }

    fn exec(&self, stage: &str, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        let out = self
            .engine
            .execute_buffers(&Engine::stage_key(&self.manifest, stage), inputs)
            .with_context(|| format!("executing stage {stage}"))?;
        if self.checked {
            let io = self.manifest.stage(stage);
            for (t, meta) in out.iter().zip(&io.outputs) {
                anyhow::ensure!(
                    t.shape() == meta.shape.as_slice(),
                    "stage {stage} output shape {:?} != manifest {:?}",
                    t.shape(),
                    meta.shape
                );
            }
        }
        Ok(out)
    }

    fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        self.engine.to_buffer(t)
    }

    fn upload_all(&self, ts: &[HostTensor]) -> Result<Vec<xla::PjRtBuffer>> {
        ts.iter().map(|t| self.upload(t)).collect()
    }

    /// Gather + upload every parameter group for this step. Each group's
    /// all-gather is ledgered once here; backward ledgers its re-gathers
    /// explicitly (see `account_bwd_regather`).
    fn build_step_params(&self) -> Result<StepParams> {
        let p = &self.manifest.params;
        let embed_flat = self.params.gather_range(&self.group, 0..p.embed_numel);
        let embed = self.upload_all(&slice_group(&embed_flat, &p.embed))?;
        let mut layers = Vec::with_capacity(p.n_layers);
        for li in 0..p.n_layers {
            let flat = self.params.gather_range(&self.group, p.layer_range(li));
            layers.push(self.upload_all(&slice_group(&flat, &p.layer))?);
        }
        let fstart = p.embed_numel + p.n_layers * p.layer_numel;
        let final_flat = self
            .params
            .gather_range(&self.group, fstart..fstart + p.final_numel);
        let final_ = self.upload_all(&slice_group(&final_flat, &p.final_))?;
        Ok(StepParams { embed, layers, final_ })
    }

    /// Ledger the ZeRO-3 backward re-gather of one layer (the data itself
    /// is served from the step cache on this single-device runtime).
    fn account_bwd_regather(&self, li: usize) {
        let range = self.manifest.params.layer_range(li);
        self.group.account_gather(range.len() as u64 * 4);
    }

    /// Forward through one layer for all ranks; returns (new_h, saved)
    /// where `saved` holds what backward reuses after recompute (qkv +
    /// attention-output buffers, device-side).
    fn layer_forward(
        &self,
        lp: &[xla::PjRtBuffer],
        h: &[xla::PjRtBuffer],
        pos: &[xla::PjRtBuffer],
    ) -> Result<(Vec<xla::PjRtBuffer>, LayerAct)> {
        let sp = self.sp();
        let (ln1, wq, wk, wv) = (&lp[0], &lp[1], &lp[2], &lp[3]);
        let (wo, ln2, wg, wu, wd) = (&lp[4], &lp[5], &lp[6], &lp[7], &lp[8]);

        // Per-rank stage executions run concurrently (scoped threads) —
        // ranks are data-isolated; see `run_ranks`.
        let qkv = run_ranks(sp, self.parallel_ranks, |r| {
            let out = self.exec("pre_attn_fwd", &[ln1, wq, wk, wv, &h[r], &pos[r]])?;
            let mut it = out.into_iter();
            Ok((it.next().unwrap(), it.next().unwrap(), it.next().unwrap()))
        })?;
        let mut qs = Vec::with_capacity(sp);
        let mut ks = Vec::with_capacity(sp);
        let mut vs = Vec::with_capacity(sp);
        for (q, k, v) in qkv {
            qs.push(q);
            ks.push(k);
            vs.push(v);
        }
        // Ulysses boundary 1: sequence -> head layout, through the arena:
        // outputs land in recycled buffers, and both the pre-relayout
        // shards and the uploaded host copies go straight back to the
        // pool — the ping-pong that makes steady-state relayout
        // allocation-free.
        let q_full = a2a_seq_to_head_into(&self.group, &qs, &self.arena);
        let k_full = a2a_seq_to_head_into(&self.group, &ks, &self.arena);
        let v_full = a2a_seq_to_head_into(&self.group, &vs, &self.arena);
        self.arena.recycle_all(qs);
        self.arena.recycle_all(ks);
        self.arena.recycle_all(vs);
        let q_full_b = self.upload_all(&q_full)?;
        let k_full_b = self.upload_all(&k_full)?;
        let v_full_b = self.upload_all(&v_full)?;
        self.arena.recycle_all(q_full);
        self.arena.recycle_all(k_full);
        self.arena.recycle_all(v_full);

        let o_full = run_ranks(sp, self.parallel_ranks, |r| {
            let out = self.exec("attn_fwd", &[&q_full_b[r], &k_full_b[r], &v_full_b[r]])?;
            Ok(out.into_iter().next().unwrap())
        })?;
        // Ulysses boundary 2: head -> sequence layout.
        let o_sh = a2a_head_to_seq_into(
            &self.group,
            &o_full,
            self.manifest.config.n_q_heads,
            false,
            &self.arena,
        );
        self.arena.recycle_all(o_full);
        let o_sh_b = self.upload_all(&o_sh)?;
        self.arena.recycle_all(o_sh);

        let post = run_ranks(sp, self.parallel_ranks, |r| {
            let out = self.exec("post_attn_fwd", &[wo, ln2, wg, wu, wd, &h[r], &o_sh_b[r]])?;
            let t = out.into_iter().next().unwrap();
            let b = self.upload(&t)?;
            Ok((b, t))
        })?;
        let mut h_out = Vec::with_capacity(sp);
        let mut h_out_host = Vec::with_capacity(sp);
        for (b, t) in post {
            h_out.push(b);
            h_out_host.push(t);
        }
        Ok((
            h_out,
            LayerAct {
                q_full: q_full_b,
                k_full: k_full_b,
                v_full: v_full_b,
                o_sh: o_sh_b,
                h_out_host,
            },
        ))
    }

    /// One full training step on one global sequence (effective batch 1,
    /// matching the paper's evaluation protocol): forward/backward + a
    /// single optimizer step.
    pub fn train_step(&mut self, ids: &[i32]) -> Result<StepMetrics> {
        self.train_step_accum(std::slice::from_ref(&ids.to_vec()))
    }

    /// Training step with gradient accumulation (paper §5.6 uses GAS=8 to
    /// equalize data between the DP baseline and the SP run). Each micro
    /// batch runs forward/backward; gradients accumulate in the ZeRO
    /// shards; ONE optimizer step follows. With synchronized replicas this
    /// is mathematically identical to data parallelism over
    /// `micro_batches.len()` ranks-groups.
    pub fn train_step_accum(&mut self, micro_batches: &[Vec<i32>]) -> Result<StepMetrics> {
        anyhow::ensure!(!micro_batches.is_empty(), "need at least one micro batch");
        let t0 = Instant::now();
        self.group.reset_stats();
        self.device.reset_peak();

        let mut loss_acc = 0f32;
        let mut tokens = 0usize;
        let mut ckpt_transfer = 0u64;
        let n = micro_batches.len() as f32;
        for ids in micro_batches {
            let (loss, transfer) = self.forward_backward(ids, 1.0 / n)?;
            loss_acc += loss / n;
            tokens += ids.len();
            ckpt_transfer += transfer;
        }

        let grad_norm = self.optimizer_step();
        let comm = self.group.stats();
        Ok(StepMetrics {
            step: self.step,
            loss: loss_acc,
            grad_norm,
            tokens,
            step_time: t0.elapsed(),
            a2a_bytes: comm.all_to_all_bytes,
            gather_bytes: comm.all_gather_bytes,
            reduce_scatter_bytes: comm.reduce_scatter_bytes,
            ckpt_transfer_bytes: ckpt_transfer,
            device_peak_bytes: self.device.peak(),
        })
    }

    /// Apply the accumulated gradients (AdamW on the owned shards) and
    /// clear them. Returns the pre-clip global gradient norm. Uses the
    /// scheduled learning rate if a schedule is configured.
    pub fn optimizer_step(&mut self) -> f64 {
        if let Some(sched) = &self.lr_schedule {
            self.opt.cfg.lr = sched.lr_at(self.step);
        }
        let norm = self.opt.step(&mut self.params, &self.grads);
        self.grads.zero_fill();
        self.step += 1;
        norm
    }

    /// One forward+backward pass over one sequence, scaling the loss
    /// cotangent by `loss_scale` (1/GAS for accumulation). Gradients are
    /// ADDED to the ZeRO shards; no optimizer step. Returns
    /// (mean loss, checkpoint transfer bytes).
    fn forward_backward(&mut self, ids: &[i32], loss_scale: f32) -> Result<(f32, u64)> {
        anyhow::ensure!(
            ids.len() == self.manifest.seq,
            "sequence length {} != artifact seq {}",
            ids.len(),
            self.manifest.seq
        );
        let shards: Vec<ShardedBatch> = shard_sequence(ids, self.manifest.sp);
        let (loss, transfer, _) = self.forward_backward_shards(&shards, loss_scale, None)?;
        Ok((loss, transfer))
    }

    /// Shard-level forward+backward shared by the whole-sequence and
    /// packed paths. With `packed` (and `per_doc_loss` on), per-document
    /// losses are extracted at the loss head: each document's labels
    /// isolated in turn (everything else `IGNORE_INDEX`), run only on
    /// ranks whose shard overlaps the document. No extra layer-stack
    /// compute, but each pass repeats the loss-head logits matmul —
    /// n_docs of them per step; disable `TrainerOptions::per_doc_loss`
    /// for steady-state training.
    fn forward_backward_shards(
        &mut self,
        shards: &[ShardedBatch],
        loss_scale: f32,
        packed: Option<&PackedSequence>,
    ) -> Result<(f32, u64, Vec<DocumentLoss>)> {
        let sp = self.manifest.sp;
        anyhow::ensure!(
            shards.len() == sp,
            "expected {sp} shards, got {}",
            shards.len()
        );
        let total: usize = shards.iter().map(|s| s.ids.len()).sum();
        anyhow::ensure!(
            total == self.manifest.seq,
            "sharded sequence length {} != artifact seq {}",
            total,
            self.manifest.seq
        );
        let mut ids_b = Vec::with_capacity(sp);
        let mut pos_b = Vec::with_capacity(sp);
        let mut lab_b = Vec::with_capacity(sp);
        for s in shards {
            ids_b.push(self.upload(&HostTensor::i32(vec![s.ids.len()], s.ids.clone()))?);
            pos_b.push(self.upload(&HostTensor::i32(
                vec![s.positions.len()],
                s.positions.clone(),
            ))?);
            lab_b.push(self.upload(&HostTensor::i32(vec![s.labels.len()], s.labels.clone()))?);
        }

        // ---- forward -------------------------------------------------------
        let dev_params = self.build_step_params()?;
        let n_layers = self.n_layers();
        let embed_out = run_ranks(sp, self.parallel_ranks, |r| {
            let out = self.exec("embed_fwd", &[&dev_params.embed[0], &ids_b[r]])?;
            let t = out.into_iter().next().unwrap();
            let b = self.upload(&t)?;
            Ok((b, t))
        })?;
        let mut h: Vec<xla::PjRtBuffer> = Vec::with_capacity(sp);
        let mut h_host: Vec<HostTensor> = Vec::with_capacity(sp);
        for (b, t) in embed_out {
            h.push(b);
            h_host.push(t);
        }

        let mut tape = CheckpointTape::new(n_layers, sp, self.flags.ckpt_offload);
        for li in 0..n_layers {
            // checkpoint the layer INPUT (host side, offloadable — §3.3)
            for (r, hr) in h_host.drain(..).enumerate() {
                tape.store(li, r, hr, &mut self.device, &mut self.host)?;
            }
            let (h_new, act) = self.layer_forward(&dev_params.layers[li], &h, &pos_b)?;
            h_host = act.h_out_host;
            h = h_new;
        }

        let (lnf, unembed) = (&dev_params.final_[0], &dev_params.final_[1]);
        let loss_out = run_ranks(sp, self.parallel_ranks, |r| {
            let out = self.exec("loss_fwd", &[lnf, unembed, &h[r], &lab_b[r]])?;
            Ok((out[0].scalar_f32()?, out[1].scalar_f32()?))
        })?;
        let (loss_sums, counts): (Vec<f32>, Vec<f32>) = loss_out.into_iter().unzip();
        let loss_sum = self.group.all_reduce_scalars(&loss_sums);
        let count = self.group.all_reduce_scalars(&counts);
        // Reachable on packed batches (e.g. every document length 1 =>
        // all labels IGNORE_INDEX): without this check loss is NaN and
        // the backward cotangent 1/count is inf, silently poisoning the
        // weights.
        anyhow::ensure!(
            count > 0.0,
            "batch has no trainable targets (all labels are IGNORE_INDEX)"
        );
        let loss = loss_sum / count;

        // Per-document loss (packed batches, opt-out via
        // `TrainerOptions::per_doc_loss`): re-run the loss head with
        // labels masked to one document at a time. A document with a
        // single token has no target; it reports loss 0 over 0 targets.
        let mut doc_losses = Vec::new();
        if let Some(p) = packed.filter(|_| self.per_doc_loss) {
            let ssh = self.manifest.seq / sp;
            for d in 0..p.n_docs() {
                let range = p.segment_range(d);
                let (mut sum_d, mut count_d) = (0f32, 0f32);
                for r in 0..sp {
                    let (a, b) = (r * ssh, (r + 1) * ssh);
                    if range.end <= a || range.start >= b {
                        continue; // no overlap: all-IGNORE shard adds 0/0
                    }
                    let (lo, hi) = (range.start.max(a), range.end.min(b));
                    let mut masked = self.arena.take_i32(ssh);
                    masked.fill(IGNORE_INDEX);
                    masked[lo - a..hi - a]
                        .copy_from_slice(&shards[r].labels[lo - a..hi - a]);
                    let masked_t = HostTensor::i32(vec![ssh], masked);
                    let lab = self.upload(&masked_t)?;
                    self.arena.recycle(masked_t);
                    let out = self.exec("loss_fwd", &[lnf, unembed, &h[r], &lab])?;
                    sum_d += out[0].scalar_f32()?;
                    count_d += out[1].scalar_f32()?;
                }
                doc_losses.push(DocumentLoss {
                    doc_id: p.doc_ids[d],
                    tokens: range.len(),
                    loss: if count_d > 0.0 { sum_d / count_d } else { 0.0 },
                });
            }
        }

        // ---- backward ------------------------------------------------------
        let m = &self.manifest;
        let ct = self.upload(&HostTensor::scalar(loss_scale / count))?;
        let mut final_grads: Vec<GroupGrads> =
            (0..sp).map(|_| GroupGrads::zeros(&m.params.final_)).collect();
        let loss_bwd_out = run_ranks(sp, self.parallel_ranks, |r| {
            let out = self.exec("loss_bwd", &[lnf, unembed, &h[r], &lab_b[r], &ct])?;
            let mut it = out.into_iter();
            let d_lnf = it.next().unwrap();
            let d_unembed = it.next().unwrap();
            let d_h_b = self.upload(&it.next().unwrap())?;
            Ok((d_lnf, d_unembed, d_h_b))
        })?;
        let mut d_h: Vec<xla::PjRtBuffer> = Vec::with_capacity(sp);
        for (r, (d_lnf, d_unembed, d_h_b)) in loss_bwd_out.into_iter().enumerate() {
            final_grads[r].accumulate("lnf", &d_lnf)?;
            final_grads[r].accumulate("unembed", &d_unembed)?;
            d_h.push(d_h_b);
        }
        {
            let p = &self.manifest.params;
            let start = p.embed_numel + p.n_layers * p.layer_numel;
            let range = start..start + p.final_numel;
            let contribs: Vec<&[f32]> =
                final_grads.iter().map(|g| g.flat.as_slice()).collect();
            self.grads.reduce_into_range(&self.group, range, &contribs);
        }
        drop(h);

        for li in (0..n_layers).rev() {
            // Restore the layer-input checkpoint (host->device if offloaded)
            let mut h_in_host = Vec::with_capacity(sp);
            for r in 0..sp {
                h_in_host.push(tape.fetch(li, r, &mut self.device, &mut self.host)?);
            }
            let h_in = self.upload_all(&h_in_host)?;
            // ZeRO-3 re-gathers the layer's params for backward (ledger).
            self.account_bwd_regather(li);
            let lp = &dev_params.layers[li];
            // Recompute forward through the layer (activation checkpointing
            // replays the all-to-alls too — the paper's flos model counts
            // this extra forward).
            let (_h_out, act) = self.layer_forward(lp, &h_in, &pos_b)?;

            let (ln1, wq, wk, wv) = (&lp[0], &lp[1], &lp[2], &lp[3]);
            let (wo, ln2, wg, wu, wd) = (&lp[4], &lp[5], &lp[6], &lp[7], &lp[8]);
            let mut layer_grads: Vec<GroupGrads> =
                (0..sp).map(|_| GroupGrads::zeros(&m.params.layer)).collect();

            // post_attn backward (per-rank exec in parallel; the grad
            // ledger merges serially in rank order — deterministic)
            let post_out = run_ranks(sp, self.parallel_ranks, |r| {
                self.exec(
                    "post_attn_bwd",
                    &[wo, ln2, wg, wu, wd, &h_in[r], &act.o_sh[r], &d_h[r]],
                )
            })?;
            let mut d_h_resid = Vec::with_capacity(sp);
            let mut d_attn = Vec::with_capacity(sp);
            for (r, out) in post_out.into_iter().enumerate() {
                let mut it = out.into_iter();
                for name in ["wo", "ln2", "wg", "wu", "wd"] {
                    layer_grads[r].accumulate(name, &it.next().unwrap())?;
                }
                d_h_resid.push(it.next().unwrap());
                d_attn.push(it.next().unwrap());
            }

            // transposed all-to-all: d_attn (seq layout) -> head layout
            let d_o_full = a2a_seq_to_head_into(&self.group, &d_attn, &self.arena);
            self.arena.recycle_all(d_attn);
            let d_o_full_b = self.upload_all(&d_o_full)?;
            self.arena.recycle_all(d_o_full);
            let attn_out = run_ranks(sp, self.parallel_ranks, |r| {
                let out = self.exec(
                    "attn_bwd",
                    &[&act.q_full[r], &act.k_full[r], &act.v_full[r], &d_o_full_b[r]],
                )?;
                let mut it = out.into_iter();
                Ok((it.next().unwrap(), it.next().unwrap(), it.next().unwrap()))
            })?;
            let mut d_q_full = Vec::with_capacity(sp);
            let mut d_k_full = Vec::with_capacity(sp);
            let mut d_v_full = Vec::with_capacity(sp);
            for (q, k, v) in attn_out {
                d_q_full.push(q);
                d_k_full.push(k);
                d_v_full.push(v);
            }
            // inverse a2a; kv grads SUM over replica consumers (fused
            // copy-first/accumulate-rest pass inside the relayout).
            let nq = m.config.n_q_heads;
            let nkv = m.config.n_kv_heads;
            let d_q = a2a_head_to_seq_into(&self.group, &d_q_full, nq, true, &self.arena);
            let d_k = a2a_head_to_seq_into(&self.group, &d_k_full, nkv, true, &self.arena);
            let d_v = a2a_head_to_seq_into(&self.group, &d_v_full, nkv, true, &self.arena);
            self.arena.recycle_all(d_q_full);
            self.arena.recycle_all(d_k_full);
            self.arena.recycle_all(d_v_full);

            // pre_attn backward; d_h = qkv path + residual path
            let pre_out = run_ranks(sp, self.parallel_ranks, |r| {
                let d_q_b = self.upload(&d_q[r])?;
                let d_k_b = self.upload(&d_k[r])?;
                let d_v_b = self.upload(&d_v[r])?;
                self.exec(
                    "pre_attn_bwd",
                    &[ln1, wq, wk, wv, &h_in[r], &pos_b[r], &d_q_b, &d_k_b, &d_v_b],
                )
            })?;
            self.arena.recycle_all(d_q);
            self.arena.recycle_all(d_k);
            self.arena.recycle_all(d_v);
            let mut new_d_h = Vec::with_capacity(sp);
            for (r, (out, resid)) in pre_out.into_iter().zip(d_h_resid).enumerate() {
                let mut it = out.into_iter();
                for name in ["ln1", "wq", "wk", "wv"] {
                    layer_grads[r].accumulate(name, &it.next().unwrap())?;
                }
                let mut d_hr = it.next().unwrap();
                d_hr.add_assign(&resid)?;
                new_d_h.push(self.upload(&d_hr)?);
                self.arena.recycle(d_hr);
                self.arena.recycle(resid);
            }
            d_h = new_d_h;

            let contribs: Vec<&[f32]> =
                layer_grads.iter().map(|g| g.flat.as_slice()).collect();
            let range = m.params.layer_range(li);
            self.grads.reduce_into_range(&self.group, range, &contribs);
        }

        // embed backward
        let mut embed_grads: Vec<GroupGrads> =
            (0..sp).map(|_| GroupGrads::zeros(&m.params.embed)).collect();
        let embed_bwd_out = run_ranks(sp, self.parallel_ranks, |r| {
            self.exec("embed_bwd", &[&dev_params.embed[0], &ids_b[r], &d_h[r]])
        })?;
        for (r, out) in embed_bwd_out.into_iter().enumerate() {
            embed_grads[r].accumulate("embed", &out[0])?;
        }
        let contribs: Vec<&[f32]> =
            embed_grads.iter().map(|g| g.flat.as_slice()).collect();
        self.grads
            .reduce_into_range(&self.group, 0..m.params.embed_numel, &contribs);

        Ok((loss, tape.transfer_bytes, doc_losses))
    }

    /// One training step on a PACKED batch of variable-length documents
    /// (paper §3.4/§7.2): segment-aware labels (no cross-document
    /// targets), per-document position ids (RoPE resets at boundaries),
    /// and a per-document loss breakdown in the returned metrics
    /// (empty when `TrainerOptions::per_doc_loss` is off — it costs one
    /// loss-head pass per document).
    ///
    /// §7.2 caveat, stated loudly: the compiled `attn_fwd` stage is dense
    /// causal over the full sequence and does not consume segment ids —
    /// exactly the SDPA behaviour the paper warns about, so attention can
    /// still read across boundaries inside this CPU artifact. The Pallas
    /// layer's `packed_attn.py` kernel is the masked implementation; the
    /// coordinator threads `cu_seqlens`/segment ids through every shard
    /// (see `packing::PackedShard`) so a packed-attention artifact drops
    /// in without coordinator changes. Labels and loss accounting are
    /// already fully segment-correct.
    pub fn train_step_packed(&mut self, p: &PackedSequence) -> Result<PackedStepMetrics> {
        let t0 = Instant::now(); // sharding counts toward step_time
        anyhow::ensure!(
            p.len() == self.manifest.seq,
            "packed length {} != artifact seq {}",
            p.len(),
            self.manifest.seq
        );
        let batches: Vec<ShardedBatch> = shard_packed(p, self.manifest.sp)
            .into_iter()
            .map(|s| s.batch)
            .collect();
        // shard_packed output is correct by construction — skip the
        // caller-input validation the pre-sharded entry point performs
        self.packed_step_core(p, batches, t0)
    }

    /// `train_step_packed` on PRE-SHARDED batches. When the caller already
    /// holds a shard set at the trainer's SP degree (e.g. from
    /// `PackedDataLoader::next`), this consumes it directly instead of
    /// re-running the per-rank slicing — the double materialization
    /// `PackedDataLoader::next_sequence` used to warn about.
    pub fn train_step_packed_shards(
        &mut self,
        p: &PackedSequence,
        batches: Vec<ShardedBatch>,
    ) -> Result<PackedStepMetrics> {
        let t0 = Instant::now(); // validation counts toward step_time
        anyhow::ensure!(
            p.len() == self.manifest.seq,
            "packed length {} != artifact seq {}",
            p.len(),
            self.manifest.seq
        );
        // A stale or foreign shard set satisfies the count/length checks
        // downstream while silently mis-attributing per-document losses —
        // or, worse, training on cross-document targets if the caller
        // sharded with the whole-sequence helper (the §4.3 bug class).
        // Allocation-free O(S) guards, always on: shards must be
        // equal-length (the per-doc loss slicing assumes seq/sp each) and
        // ids/positions must reassemble the pack (whole-sequence sharding
        // fails the positions check — no per-document resets).
        let ssh = p.len() / self.manifest.sp;
        anyhow::ensure!(
            batches.iter().all(|b| b.ids.len() == ssh
                && b.positions.len() == ssh
                && b.labels.len() == ssh)
                && batches.len() * ssh == p.len(),
            "packed shards must be {} equal-length rank batches (seq/sp = {ssh})",
            self.manifest.sp
        );
        anyhow::ensure!(
            batches.iter().flat_map(|b| b.ids.iter()).eq(p.ids.iter())
                && batches
                    .iter()
                    .flat_map(|b| b.positions.iter())
                    .eq(p.positions.iter()),
            "shard set does not reassemble the packed sequence (mismatched \
             sequence/shards pair, or sharded without segment awareness?)"
        );
        // Labels must be the pack's segment-aware shift, checked
        // element-wise against ids/seg_ids — allocation-free, so it stays
        // on unconditionally (the rule mirrors `shift_labels_packed` +
        // the padding mask of `PackedSequence::labels`). Whole-sequence
        // shifting fails at the first boundary: one leaked cross-document
        // target per boundary is the §4.3 bug.
        let pad_seg = if p.has_padding() { Some(p.n_docs() as i32) } else { None };
        let labels_ok =
            batches
                .iter()
                .flat_map(|b| b.labels.iter())
                .enumerate()
                .all(|(i, &l)| {
                    let expect = if Some(p.seg_ids[i]) == pad_seg {
                        IGNORE_INDEX
                    } else if i + 1 < p.len() && p.seg_ids[i + 1] == p.seg_ids[i] {
                        p.ids[i + 1]
                    } else {
                        IGNORE_INDEX
                    };
                    l == expect
                });
        anyhow::ensure!(
            labels_ok,
            "shard labels are not the segment-aware shift of the packed \
             sequence (sharded with the whole-sequence helper? see \
             packing::shift_labels_packed)"
        );
        self.packed_step_core(p, batches, t0)
    }

    /// The metered packed step both entry points share (inputs already
    /// validated or correct by construction). `t0` is the entry-point
    /// start time, so sharding/validation stay inside `step_time` as they
    /// were before the entry points split.
    fn packed_step_core(
        &mut self,
        p: &PackedSequence,
        batches: Vec<ShardedBatch>,
        t0: Instant,
    ) -> Result<PackedStepMetrics> {
        self.group.reset_stats();
        self.device.reset_peak();

        let (loss, ckpt_transfer, doc_losses) =
            self.forward_backward_shards(&batches, 1.0, Some(p))?;
        let grad_norm = self.optimizer_step();
        let comm = self.group.stats();
        let real_tokens: usize = p.doc_lengths().iter().sum();
        Ok(PackedStepMetrics {
            metrics: StepMetrics {
                step: self.step,
                loss,
                grad_norm,
                tokens: p.len(),
                step_time: t0.elapsed(),
                a2a_bytes: comm.all_to_all_bytes,
                gather_bytes: comm.all_gather_bytes,
                reduce_scatter_bytes: comm.reduce_scatter_bytes,
                ckpt_transfer_bytes: ckpt_transfer,
                device_peak_bytes: self.device.peak(),
            },
            doc_losses,
            real_tokens,
            padding_tokens: p.len() - real_tokens,
        })
    }

    /// Save training state (params + optimizer + step) to `path`.
    pub fn save_snapshot(&self, path: &std::path::Path) -> Result<()> {
        crate::coordinator::snapshot::save(path, self.step, &self.params, &self.opt)
    }

    /// Resume training state from `path` (re-sharded to this SP degree —
    /// snapshots are world-agnostic).
    pub fn load_snapshot(&mut self, path: &std::path::Path) -> Result<()> {
        let snap = crate::coordinator::snapshot::load(path)?;
        crate::coordinator::snapshot::restore(&snap, &mut self.params, &mut self.opt)?;
        self.step = snap.step;
        Ok(())
    }

    /// Current optimizer step count.
    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Forward-only evaluation loss on one sequence.
    pub fn eval_loss(&mut self, ids: &[i32]) -> Result<f32> {
        let sp = self.manifest.sp;
        anyhow::ensure!(ids.len() == self.manifest.seq, "bad sequence length");
        let shards = shard_sequence(ids, sp);
        let dev_params = self.build_step_params()?;
        let mut h = Vec::with_capacity(sp);
        let mut pos_b = Vec::with_capacity(sp);
        for s in &shards {
            let ids_t = self.upload(&HostTensor::i32(vec![s.ids.len()], s.ids.clone()))?;
            pos_b.push(self.upload(&HostTensor::i32(
                vec![s.positions.len()],
                s.positions.clone(),
            ))?);
            let out = self.exec("embed_fwd", &[&dev_params.embed[0], &ids_t])?;
            h.push(self.upload(&out.into_iter().next().unwrap())?);
        }
        for li in 0..self.n_layers() {
            let (h_new, _) = self.layer_forward(&dev_params.layers[li], &h, &pos_b)?;
            h = h_new;
        }
        let mut sums = Vec::new();
        let mut counts = Vec::new();
        for (r, s) in shards.iter().enumerate() {
            let lab = self.upload(&HostTensor::i32(vec![s.labels.len()], s.labels.clone()))?;
            let out = self.exec(
                "loss_fwd",
                &[&dev_params.final_[0], &dev_params.final_[1], &h[r], &lab],
            )?;
            sums.push(out[0].scalar_f32()?);
            counts.push(out[1].scalar_f32()?);
        }
        Ok(sums.iter().sum::<f32>() / counts.iter().sum::<f32>())
    }
}

/// Per-layer activations the backward pass reuses after recompute, plus
/// host copies of the layer output (checkpointed as the next layer input).
struct LayerAct {
    q_full: Vec<xla::PjRtBuffer>,
    k_full: Vec<xla::PjRtBuffer>,
    v_full: Vec<xla::PjRtBuffer>,
    o_sh: Vec<xla::PjRtBuffer>,
    h_out_host: Vec<HostTensor>,
}
