//! End-to-end step latency through the real PJRT pipeline (tiny config),
//! plus the coordinator-side hot path that runs with NO artifacts: the
//! per-step relayout cycle through the scratch arena, and the scoped-
//! thread rank executor versus the serial loop.
//!
//! Always emits repo-root `BENCH_pipeline.json` (schema in DESIGN.md);
//! the PJRT sections additionally require `make artifacts` and are
//! skipped gracefully without it.

use std::path::Path;

use alst::collectives::Group;
use alst::coordinator::dataloader::{MarkovSource, UlyssesDataLoader};
use alst::coordinator::pipeline::{run_ranks, Trainer, TrainerOptions};
use alst::coordinator::ulysses::relayout_step_cycle;
use alst::obs::{Category, Tracer};
use alst::runtime::{HostTensor, Manifest, ScratchArena};
use alst::util::bench::{bench, BenchReport};
use alst::util::rng::Rng;

fn main() {
    let mut report = BenchReport::new("pipeline");
    println!("bench_pipeline: coordinator hot path + PJRT step (if artifacts)\n");

    // ---- coordinator-only: relayout step cycle (no artifacts needed) ----
    let (sp, seq, n_q, n_kv, d, n_layers) = (8usize, 16384usize, 32usize, 4usize, 128usize, 4usize);
    let ssh = seq / sp;
    let mut rng = Rng::new(1);
    let q: Vec<HostTensor> = (0..sp)
        .map(|_| HostTensor::f32(vec![ssh, n_q, d], rng.normal_vec(ssh * n_q * d, 1.0)))
        .collect();
    let kv: Vec<HostTensor> = (0..sp)
        .map(|_| HostTensor::f32(vec![ssh, n_kv, d], rng.normal_vec(ssh * n_kv * d, 1.0)))
        .collect();
    let g = Group::new(sp);
    // this shape's per-layer relayout working set (~1.3 GB pooled at
    // steady state) exceeds the default budget; size the pool to fit so
    // the bench measures the allocation-free path
    let arena = ScratchArena::with_byte_budget(4 << 30);
    // warm one cycle: populates the pool AND measures the exact ledgered
    // wire volume of a cycle (the GiB/s denominator)
    relayout_step_cycle(&g, &arena, &q, &kv, n_layers, n_q, n_kv);
    let cycle_bytes = g.stats().all_to_all_bytes;
    g.reset_stats();
    let r = bench(
        &format!("relayout step-cycle sp={sp} seq={seq} L={n_layers} pooled"),
        1,
        10,
        std::time::Duration::from_secs(2),
        || relayout_step_cycle(&g, &arena, &q, &kv, n_layers, n_q, n_kv),
    )
    .with_bytes(cycle_bytes);
    println!(
        "    -> {:.2} GiB/s, arena hit rate {:.4} ({} buffers pooled)",
        r.gib_per_s().unwrap_or(0.0),
        arena.hit_rate(),
        arena.pooled()
    );
    report.push(&r);

    // ---- same cycle with the step tracer recording -----------------------
    // Relayout spans + instant collective spans per a2a; the delta vs the
    // pooled row above is the enabled-tracing overhead on a real hot path.
    let tracer = std::sync::Arc::new(Tracer::new(true));
    let mut gt = Group::new(sp);
    gt.set_tracer(tracer.clone());
    relayout_step_cycle(&gt, &arena, &q, &kv, n_layers, n_q, n_kv); // warm
    let r = bench(
        &format!("relayout step-cycle sp={sp} seq={seq} L={n_layers} traced"),
        1,
        10,
        std::time::Duration::from_secs(2),
        || relayout_step_cycle(&gt, &arena, &q, &kv, n_layers, n_q, n_kv),
    )
    .with_bytes(cycle_bytes);
    println!(
        "    -> {:.2} GiB/s with tracing on ({} spans recorded)",
        r.gib_per_s().unwrap_or(0.0),
        tracer.drain().len()
    );
    report.push(&r);

    // ---- disabled-overhead contract: one branch per span site ------------
    // The row obs/mod.rs pins: a disabled span site must cost a branch and
    // nothing else (no clock read, no lock, no allocation). Measured as
    // 1M guard create/drops per iteration.
    let off = Tracer::off();
    const SITES: u64 = 1_000_000;
    let r = bench(
        "span site (tracer disabled)",
        1,
        10,
        std::time::Duration::from_millis(500),
        || {
            for _ in 0..SITES {
                let s = off.span(Category::Exec, "noop");
                std::hint::black_box(&s);
            }
        },
    );
    println!(
        "    -> {:.3} ns per disabled span site",
        r.mean.as_secs_f64() * 1e9 / SITES as f64
    );
    report.push(&r);

    // ---- coordinator-only: scoped-thread rank executor ------------------
    // A cpu-bound per-rank workload (the shape of per-rank stage calls);
    // serial vs parallel run_ranks on the same closure.
    let work: Vec<Vec<f32>> = (0..sp).map(|_| rng.normal_vec(1 << 18, 1.0)).collect();
    let rank_work = |r: usize| -> anyhow::Result<f64> {
        let mut acc = 0f64;
        for &x in &work[r] {
            acc += (x as f64) * (x as f64);
        }
        Ok(acc)
    };
    for (parallel, label) in [(false, "serial"), (true, "threaded")] {
        let r = bench(
            &format!("run_ranks sp={sp} {label}"),
            1,
            20,
            std::time::Duration::from_millis(500),
            || {
                let out = run_ranks(sp, parallel, rank_work).unwrap();
                std::hint::black_box(out);
            },
        );
        report.push(&r);
    }

    // ---- PJRT sections (need `make artifacts`) ---------------------------
    let dir = Manifest::artifact_dir(Path::new("artifacts"), "tiny", 2, 256);
    if dir.join("manifest.json").exists() {
        println!("\nPJRT step (tiny config, sp=2, seq=256):\n");
        // serial ranks here: the exec/marshal percentage split below sums
        // per-rank stage durations, which only reads as a fraction of the
        // step when ranks don't overlap in wall time
        let opts = TrainerOptions { parallel_ranks: false, ..Default::default() };
        let mut trainer = Trainer::new(&dir, opts).unwrap();
        let mut loader = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 1), 2);
        let (ids, _) = loader.next();

        // eval (forward only)
        let ids_c = ids.clone();
        trainer.eval_loss(&ids_c).unwrap(); // warm the executable cache
        trainer.engine.reset_stats();
        let r = bench(
            "eval_loss (fwd only)",
            1,
            10,
            std::time::Duration::from_secs(2),
            || {
                trainer.eval_loss(&ids_c).unwrap();
            },
        );
        let st = trainer.engine.stats();
        let exec_frac = st.exec_time.as_secs_f64() / (r.mean.as_secs_f64() * r.iters as f64);
        println!(
            "    -> {} PJRT executions; exec {:.0}% / marshal {:.0}% of step",
            st.executions as usize / r.iters,
            100.0 * exec_frac,
            100.0 * st.marshal_time.as_secs_f64() / (r.mean.as_secs_f64() * r.iters as f64),
        );
        report.push(&r);

        // full train step (fwd + recompute + bwd + optimizer)
        trainer.engine.reset_stats();
        let r = bench(
            "train_step (fwd+bwd+adamw)",
            1,
            10,
            std::time::Duration::from_secs(3),
            || {
                trainer.train_step(&ids).unwrap();
            },
        );
        let st = trainer.engine.stats();
        println!(
            "    -> {} PJRT executions/step; exec {:.1}ms marshal {:.1}ms per step; \
             relayout arena hit rate {:.4}",
            st.executions as usize / r.iters,
            st.exec_time.as_secs_f64() * 1e3 / r.iters as f64,
            st.marshal_time.as_secs_f64() * 1e3 / r.iters as f64,
            trainer.arena().hit_rate(),
        );
        report.push(&r);
    } else {
        eprintln!("\nSKIP PJRT sections: run `make artifacts` first");
    }

    match report.write_repo_root() {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nFAILED to write BENCH_pipeline.json: {e}"),
    }
}
