//! In-process collectives over per-rank buffers, with exact byte
//! accounting fed to the perf model.
//!
//! Substitution note (DESIGN.md): the paper runs NCCL over NVLink/EFA;
//! here an SP/DP group is a set of rank-indexed `HostTensor` slots and a
//! collective is a deterministic data relayout. The *logic* (who sends
//! what where, replication, reduction) is identical — transport differs.
//! Byte counts are asserted against the closed-form volumes, and the
//! roofline model turns them into modeled wire time.

use std::cell::RefCell;

use anyhow::Result;

use crate::runtime::tensor::HostTensor;

/// Traffic ledger for one process group.
#[derive(Debug, Default, Clone)]
pub struct CommStats {
    pub all_gather_bytes: u64,
    pub reduce_scatter_bytes: u64,
    pub all_to_all_bytes: u64,
    pub all_reduce_bytes: u64,
    pub ops: u64,
}

impl CommStats {
    pub fn total_bytes(&self) -> u64 {
        self.all_gather_bytes
            + self.reduce_scatter_bytes
            + self.all_to_all_bytes
            + self.all_reduce_bytes
    }
}

/// A communicator over `world` in-process ranks.
#[derive(Debug)]
pub struct Group {
    pub world: usize,
    stats: RefCell<CommStats>,
}

impl Group {
    pub fn new(world: usize) -> Group {
        assert!(world >= 1);
        Group { world, stats: RefCell::default() }
    }

    pub fn stats(&self) -> CommStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = CommStats::default();
    }

    /// All-gather of equal-length f32 shards: each rank contributes its
    /// shard; result is the concatenation (same for all ranks). Wire
    /// volume per rank: (world-1)/world * total (ring), accounted as the
    /// full gathered size for simplicity on the ledger, matching NCCL's
    /// algbw convention.
    pub fn all_gather(&self, shards: &[&[f32]]) -> Vec<f32> {
        assert_eq!(shards.len(), self.world);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        let mut out = Vec::with_capacity(total);
        for s in shards {
            out.extend_from_slice(s);
        }
        let mut st = self.stats.borrow_mut();
        st.all_gather_bytes += (total * 4) as u64;
        st.ops += 1;
        out
    }

    /// Reduce-scatter (sum): input is one full-length gradient per rank;
    /// output is rank r's reduced shard. Shard boundaries are equal
    /// `total/world` splits (caller pads to divisibility).
    pub fn reduce_scatter(&self, fulls: &[&[f32]]) -> Vec<Vec<f32>> {
        assert_eq!(fulls.len(), self.world);
        let total = fulls[0].len();
        assert!(fulls.iter().all(|f| f.len() == total), "ragged reduce-scatter");
        assert_eq!(total % self.world, 0, "reduce-scatter needs padded input");
        let shard = total / self.world;
        let mut out = vec![vec![0f32; shard]; self.world];
        for (r, dst) in out.iter_mut().enumerate() {
            let base = r * shard;
            for f in fulls {
                let src = &f[base..base + shard];
                for (d, s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            }
        }
        let mut st = self.stats.borrow_mut();
        st.reduce_scatter_bytes += (total * 4) as u64;
        st.ops += 1;
        out
    }

    /// All-reduce (sum) of scalars — loss_sum/token-count reduction. The
    /// paper specifically replaced `all_reduce_object` with plain
    /// all_reduce to save >3 GiB/GPU (§3.3); we only ever move the scalars.
    pub fn all_reduce_scalars(&self, vals: &[f32]) -> f32 {
        assert_eq!(vals.len(), self.world);
        let mut st = self.stats.borrow_mut();
        st.all_reduce_bytes += (vals.len() * 4) as u64;
        st.ops += 1;
        vals.iter().sum()
    }

    /// All-reduce (sum) of one tensor per rank, in place semantics:
    /// returns the summed tensor each rank would hold.
    pub fn all_reduce_sum(&self, tensors: &[&HostTensor]) -> Result<HostTensor> {
        assert_eq!(tensors.len(), self.world);
        let mut acc = tensors[0].clone();
        for t in &tensors[1..] {
            acc.add_assign(t)?;
        }
        let mut st = self.stats.borrow_mut();
        // ring all-reduce moves 2*(w-1)/w * bytes; ledger the logical size
        st.all_reduce_bytes += acc.size_bytes() as u64;
        st.ops += 1;
        Ok(acc)
    }

    /// Record an all-to-all's traffic (the relayout itself is done by
    /// `coordinator::ulysses`, which owns the head/seq math).
    pub fn account_all_to_all(&self, bytes: u64) {
        let mut st = self.stats.borrow_mut();
        st.all_to_all_bytes += bytes;
        st.ops += 1;
    }

    /// Ledger an all-gather performed by a data-structure owner (e.g. the
    /// ZeRO store's just-in-time parameter gather).
    pub fn account_gather(&self, bytes: u64) {
        let mut st = self.stats.borrow_mut();
        st.all_gather_bytes += bytes;
        st.ops += 1;
    }

    /// Ledger a reduce-scatter performed by a data-structure owner.
    pub fn account_reduce_scatter(&self, bytes: u64) {
        let mut st = self.stats.borrow_mut();
        st.reduce_scatter_bytes += bytes;
        st.ops += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_gather_concatenates_in_rank_order() {
        let g = Group::new(3);
        let out = g.all_gather(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(g.stats().all_gather_bytes, 24);
    }

    #[test]
    fn reduce_scatter_sums_and_shards() {
        let g = Group::new(2);
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let b = vec![10.0f32, 20.0, 30.0, 40.0];
        let out = g.reduce_scatter(&[&a, &b]);
        assert_eq!(out[0], vec![11.0, 22.0]);
        assert_eq!(out[1], vec![33.0, 44.0]);
        assert_eq!(g.stats().reduce_scatter_bytes, 16);
    }

    #[test]
    fn gather_then_scatter_identity() {
        // reduce_scatter(all_gather(x) replicated) == world * x shards
        let g = Group::new(2);
        let full = g.all_gather(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let out = g.reduce_scatter(&[&full, &full]);
        assert_eq!(out[0], vec![2.0, 4.0]);
        assert_eq!(out[1], vec![6.0, 8.0]);
    }

    #[test]
    fn scalar_all_reduce() {
        let g = Group::new(4);
        assert_eq!(g.all_reduce_scalars(&[1.0, 2.0, 3.0, 4.0]), 10.0);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_reduce_scatter_rejected() {
        let g = Group::new(2);
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 2];
        g.reduce_scatter(&[&a, &b]);
    }
}
