//! Sequence-tiling plans AND execution (paper §3.1): shard-count
//! deduction, chunk sizing, the per-plan peak-memory arithmetic the
//! estimator and Figure-3/4 benches consume, and — in [`exec`] — the
//! row-tile driver that streams a sequence shard through the AOT'd
//! `*_tile` stages without ever materializing the full-shard
//! intermediates.

pub mod exec;

/// TiledMLP shard count (§3.1.1): `ceil(seqlen / hidden_size)`.
/// The paper's example: ceil(256_000 / 4096) = 63.
pub fn mlp_auto_shards(seqlen: usize, hidden: usize) -> usize {
    seqlen.div_ceil(hidden).max(1)
}

/// Rows per MLP tile under the auto-shard rule.
pub fn mlp_tile_rows(seqlen: usize, hidden: usize) -> usize {
    seqlen.div_ceil(mlp_auto_shards(seqlen, hidden))
}

/// Tiled-logits chunk rows: the paper shards logits into ~`chunk_bytes`
/// fp32 pieces (§3.1 uses 1 GiB -> ~8 chunks for 16K x 128256).
pub fn logits_chunk_rows(vocab: usize, chunk_bytes: u64) -> usize {
    ((chunk_bytes / 4) as usize / vocab).max(1)
}

pub fn logits_chunk_count(seqlen: usize, vocab: usize, chunk_bytes: u64) -> usize {
    seqlen.div_ceil(logits_chunk_rows(vocab, chunk_bytes))
}

/// One tiled-compute plan: what runs per tile and what memory it needs.
#[derive(Debug, Clone)]
pub struct TilePlan {
    pub n_tiles: usize,
    pub rows_per_tile: usize,
    /// Peak live bytes for the tile's intermediates.
    pub tile_bytes: u64,
    /// What the untiled computation would have needed.
    pub untiled_bytes: u64,
}

impl TilePlan {
    pub fn saving_factor(&self) -> f64 {
        self.untiled_bytes as f64 / self.tile_bytes.max(1) as f64
    }

    /// Bytes the tiled schedule keeps off the device versus untiled —
    /// the acceptance quantity the tracker-measured peak delta is
    /// asserted against (`exec` tests).
    pub fn savings(&self) -> u64 {
        self.untiled_bytes.saturating_sub(self.tile_bytes)
    }

    /// An empty plan: what a zero-length shard tiles into (0 tiles, 0
    /// bytes). Keeps the unchecked planners total instead of panicking
    /// on `seqlen == 0` (`0usize.div_ceil(0)` used to).
    fn empty() -> TilePlan {
        TilePlan { n_tiles: 0, rows_per_tile: 0, tile_bytes: 0, untiled_bytes: 0 }
    }
}

/// Plan a TiledMLP pass over `[seqlen, hidden]` with SwiGLU width `ffn`.
/// Intermediates per tile: gate + up `[rows, ffn]` + silu product, at
/// `elem_bytes` per element. `seqlen == 0` yields the empty plan; use
/// [`plan_mlp_checked`] to surface degenerate configs as errors.
pub fn plan_mlp(seqlen: usize, hidden: usize, ffn: usize, elem_bytes: u64) -> TilePlan {
    if seqlen == 0 {
        return TilePlan::empty();
    }
    let n_tiles = mlp_auto_shards(seqlen, hidden);
    let rows = seqlen.div_ceil(n_tiles);
    let per_row = (2 * ffn + hidden) as u64 * elem_bytes;
    TilePlan {
        n_tiles,
        rows_per_tile: rows,
        tile_bytes: rows as u64 * per_row,
        untiled_bytes: seqlen as u64 * per_row,
    }
}

/// Plan a tiled logits+loss pass (fp32, 2 copies fwd+bwd as §3.1
/// measures). `seqlen == 0` yields the empty plan; a `chunk_bytes` too
/// small for one vocab row silently degrades to 1-row tiles whose
/// `tile_bytes` EXCEED the chunk budget — [`plan_logits_checked`] turns
/// both edges into errors.
pub fn plan_logits(seqlen: usize, vocab: usize, chunk_bytes: u64) -> TilePlan {
    if seqlen == 0 {
        return TilePlan::empty();
    }
    let rows = logits_chunk_rows(vocab, chunk_bytes).min(seqlen);
    plan_logits_rows(seqlen, vocab, rows)
}

/// Logits plan from an explicit `rows_per_tile` (how the coordinator
/// rebuilds the plan the AOT exporter baked into a manifest's
/// `loss_fwd_tile` stage shapes).
pub fn plan_logits_rows(seqlen: usize, vocab: usize, rows_per_tile: usize) -> TilePlan {
    if seqlen == 0 || rows_per_tile == 0 {
        return TilePlan::empty();
    }
    let rows = rows_per_tile.min(seqlen);
    TilePlan {
        n_tiles: seqlen.div_ceil(rows),
        rows_per_tile: rows,
        tile_bytes: 2 * (rows * vocab) as u64 * 4,
        untiled_bytes: 2 * (seqlen * vocab) as u64 * 4,
    }
}

/// MLP plan from an explicit `rows_per_tile` (rebuilding the plan an AOT
/// manifest baked into its `mlp_fwd_tile` stage shapes).
pub fn plan_mlp_rows(
    seqlen: usize,
    hidden: usize,
    ffn: usize,
    rows_per_tile: usize,
    elem_bytes: u64,
) -> TilePlan {
    if seqlen == 0 || rows_per_tile == 0 {
        return TilePlan::empty();
    }
    let rows = rows_per_tile.min(seqlen);
    let per_row = (2 * ffn + hidden) as u64 * elem_bytes;
    TilePlan {
        n_tiles: seqlen.div_ceil(rows),
        rows_per_tile: rows,
        tile_bytes: rows as u64 * per_row,
        untiled_bytes: seqlen as u64 * per_row,
    }
}

/// [`plan_logits`] with the degenerate configs rejected: a plan is only
/// returned when every tile actually fits the chunk budget and there is
/// at least one row to tile. The AOT exporter enforces the same
/// chunk-vs-vocab-row invariant at export time
/// (`compile.aot.loss_tile_rows` raises), so artifacts carrying tile
/// stages never embed an over-budget 1-row tiling.
pub fn plan_logits_checked(
    seqlen: usize,
    vocab: usize,
    chunk_bytes: u64,
) -> anyhow::Result<TilePlan> {
    anyhow::ensure!(seqlen > 0, "cannot plan a logits tiling over 0 rows");
    anyhow::ensure!(vocab > 0, "cannot plan a logits tiling over vocab 0");
    anyhow::ensure!(
        chunk_bytes / 4 >= vocab as u64,
        "logits chunk budget {chunk_bytes} B holds no fp32 vocab row \
         ({} B): 1-row tiles would exceed the budget",
        vocab * 4
    );
    Ok(plan_logits(seqlen, vocab, chunk_bytes))
}

/// [`plan_mlp`] with degenerate configs rejected.
pub fn plan_mlp_checked(
    seqlen: usize,
    hidden: usize,
    ffn: usize,
    elem_bytes: u64,
) -> anyhow::Result<TilePlan> {
    anyhow::ensure!(seqlen > 0, "cannot plan an MLP tiling over 0 rows");
    anyhow::ensure!(hidden > 0 && ffn > 0, "MLP tiling needs hidden > 0 and ffn > 0");
    anyhow::ensure!(elem_bytes > 0, "MLP tiling needs elem_bytes > 0");
    Ok(plan_mlp(seqlen, hidden, ffn, elem_bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GIB;

    #[test]
    fn paper_3_1_1_auto_shards_63() {
        assert_eq!(mlp_auto_shards(256_000, 4096), 63);
        assert_eq!(mlp_auto_shards(4096, 4096), 1);
        assert_eq!(mlp_auto_shards(1, 4096), 1);
    }

    #[test]
    fn paper_3_1_logits_chunks_about_8_at_16k() {
        // "using a 1GiB shard size divides the computation into about 8
        // chunks" for 16K x 128256 fp32.
        let n = logits_chunk_count(16_000, 128_256, GIB);
        assert!((7..=9).contains(&n), "{n}");
    }

    #[test]
    fn mlp_plan_saves_order_of_magnitude_at_256k() {
        // Figure 4: ~10x memory saved on the 256K x 4096 LlamaMLP example.
        let plan = plan_mlp(256_000, 4096, 14336, 2);
        assert!(plan.saving_factor() > 8.0, "{}", plan.saving_factor());
        assert_eq!(plan.n_tiles, 63);
    }

    #[test]
    fn logits_plan_saving_grows_with_seq() {
        let a = plan_logits(16_000, 128_256, GIB);
        let b = plan_logits(128_000, 128_256, GIB);
        assert!(b.saving_factor() > a.saving_factor());
        // chunk memory itself is seq-independent (the O(1) claim)
        assert_eq!(a.tile_bytes, b.tile_bytes);
    }

    #[test]
    fn tile_plans_cover_all_rows() {
        for seq in [100, 4096, 250_000, 1_000_000] {
            let p = plan_mlp(seq, 4096, 14336, 2);
            assert!(p.n_tiles * p.rows_per_tile >= seq);
        }
    }

    #[test]
    fn zero_seqlen_plans_are_empty_not_panicking() {
        // plan_logits(0, ..) used to hit 0.div_ceil(0); plan_mlp(0, ..)
        // produced a 1-tile/0-row nonsense plan.
        for p in [plan_mlp(0, 4096, 14336, 2), plan_logits(0, 128_256, GIB)] {
            assert_eq!((p.n_tiles, p.rows_per_tile), (0, 0));
            assert_eq!((p.tile_bytes, p.untiled_bytes), (0, 0));
            assert_eq!(p.savings(), 0);
        }
        assert!(plan_mlp_checked(0, 4096, 14336, 2).is_err());
        assert!(plan_logits_checked(0, 128_256, GIB).is_err());
    }

    #[test]
    fn undersized_chunk_budget_is_rejected_not_silently_exceeded() {
        // One fp32 vocab row of Llama-8B is ~513 KB; a 4 KiB chunk budget
        // used to yield 1-row tiles whose tile_bytes exceed the budget.
        let v = 128_256;
        let silent = plan_logits(16_000, v, 4096);
        assert_eq!(silent.rows_per_tile, 1);
        assert!(silent.tile_bytes > 4096, "{}", silent.tile_bytes);
        let err = plan_logits_checked(16_000, v, 4096).unwrap_err();
        assert!(err.to_string().contains("vocab row"), "{err}");
        // the boundary case (budget == exactly one row) is accepted
        let one = plan_logits_checked(16_000, v, 4 * v as u64).unwrap();
        assert_eq!(one.rows_per_tile, 1);
        assert!(one.tile_bytes <= 2 * 4 * v as u64);
    }

    #[test]
    fn savings_and_explicit_rows_match_chunk_plan() {
        let by_chunk = plan_logits(32_768, 128_256, GIB);
        let by_rows = plan_logits_rows(32_768, 128_256, by_chunk.rows_per_tile);
        assert_eq!(by_rows.n_tiles, by_chunk.n_tiles);
        assert_eq!(by_rows.tile_bytes, by_chunk.tile_bytes);
        assert_eq!(
            by_chunk.savings(),
            by_chunk.untiled_bytes - by_chunk.tile_bytes
        );
        // rows beyond the shard clamp (the 1-tile degenerate sweep)
        let clamped = plan_logits_rows(100, 512, 4096);
        assert_eq!((clamped.n_tiles, clamped.rows_per_tile), (1, 100));
    }
}
