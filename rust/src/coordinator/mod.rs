//! The paper's L3 contribution: the Ulysses SP training coordinator.
//!
//! * `plan` — the `ParallelPlan` trait: how an SP group moves attention
//!   data (relayout/attention step API, per-layer comm-byte pricing,
//!   validity predicate) plus the shared online-softmax block kernels.
//! * `ulysses` — head-shard math + the seq<->head all-to-all relayouts
//!   (paper §3.2, §3.2.1), including GQA/MQA kv replication; implements
//!   the Ulysses `ParallelPlan`.
//! * `ring` — Blockwise RingAttention plan: KV blocks rotate rank-to-rank
//!   over `Group::send_recv` with measured transfer/compute overlap; no
//!   heads >= sp bound.
//! * `zero` — ZeRO-3 flat parameter/gradient sharding (§5.2 baseline).
//! * `optimizer` — AdamW on the owned shard (optionally host-offloaded).
//! * `tape` — activation-checkpoint store with CPU offload (§3.3).
//! * `offload` — async double-buffered D2H/H2D copy streams over the tape
//!   (FPDT-style prefetch; the stall-free offload path).
//! * `dataloader` — the UlyssesSPDataLoaderAdapter equivalent (§4.2) with
//!   pre-shifted labels (§4.3).
//! * `pipeline` — the distributed fwd/bwd orchestration over PJRT stages.
//! * `recover` — the resilient-training supervisor: snapshot cadence,
//!   typed fault recovery (restore + replay, optional world degrade), and
//!   the chaos harness that pins the bit-identity recovery contract.

pub mod dataloader;
pub mod offload;
pub mod optimizer;
pub mod pipeline;
pub mod plan;
pub mod recover;
pub mod ring;
pub mod snapshot;
pub mod tape;
pub mod ulysses;
pub mod zero;
