//! Host-side tensors: the coordinator's working representation.
//!
//! Everything the coordinator moves between ranks, checkpoints, offloads,
//! shards for ZeRO, or feeds to PJRT is a `HostTensor`. f32 end-to-end on
//! the CPU client (see DESIGN.md substitutions).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::collectives::faults::lock_clean;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn size_bytes(&self) -> usize {
        4
    }

    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype `{other}`"),
        }
    }
}

/// Dense row-major tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn numel(&self) -> usize {
        numel(self.shape())
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    /// Scalar extraction (loss values, token counts).
    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got shape {:?}", self.shape());
        }
        Ok(d[0])
    }

    /// Convert to an XLA literal (copy).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Convert from an XLA literal (copy), recovering shape + dtype.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported element type {other:?}"),
        }
    }

    /// Elementwise accumulate (gradient reduction).
    pub fn add_assign(&mut self, other: &HostTensor) -> Result<()> {
        anyhow::ensure!(self.shape() == other.shape(), "shape mismatch in add");
        let dst = self.as_f32_mut()?;
        let src = other.as_f32()?;
        for (d, s) in dst.iter_mut().zip(src) {
            *d += s;
        }
        Ok(())
    }

    pub fn scale(&mut self, a: f32) -> Result<()> {
        for d in self.as_f32_mut()? {
            *d *= a;
        }
        Ok(())
    }

    /// L2 norm (gradient clipping / debugging).
    pub fn l2_norm(&self) -> Result<f64> {
        Ok(self
            .as_f32()?
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt())
    }

    /// Move the underlying storage out of the tensor (shape, data). The
    /// arena uses this to recycle a consumed tensor's allocation instead
    /// of dropping it — the "move-out reuse" half of the zero-copy
    /// relayout discipline.
    pub fn take_data(self) -> (Vec<usize>, TensorData) {
        match self {
            HostTensor::F32 { shape, data } => (shape, TensorData::F32(data)),
            HostTensor::I32 { shape, data } => (shape, TensorData::I32(data)),
        }
    }
}

/// Raw storage moved out of a `HostTensor` (see `HostTensor::take_data`).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

// ---------------------------------------------------------------------------
// Borrowed strided-view copy helpers
// ---------------------------------------------------------------------------

/// Copy `rows` blocks of `block` contiguous elements from `src` into
/// `dst`: row `r` moves `src[src_off + r*src_stride ..][..block]` to
/// `dst[dst_off + r*dst_stride ..][..block]`. Each row lowers to one
/// `copy_from_slice` (memcpy); when both sides are contiguous
/// (`stride == block`) the whole span collapses to a single memcpy. This
/// is the primitive the Ulysses relayout is built from: one call per
/// (dst-rank, src-rank) pair instead of a per-row scalar loop.
pub fn copy_rows(
    dst: &mut [f32],
    dst_off: usize,
    dst_stride: usize,
    src: &[f32],
    src_off: usize,
    src_stride: usize,
    rows: usize,
    block: usize,
) {
    if rows == 0 || block == 0 {
        return;
    }
    debug_assert!(dst_off + (rows - 1) * dst_stride + block <= dst.len());
    debug_assert!(src_off + (rows - 1) * src_stride + block <= src.len());
    if dst_stride == block && src_stride == block {
        dst[dst_off..dst_off + rows * block]
            .copy_from_slice(&src[src_off..src_off + rows * block]);
        return;
    }
    for r in 0..rows {
        let (a, b) = (dst_off + r * dst_stride, src_off + r * src_stride);
        dst[a..a + block].copy_from_slice(&src[b..b + block]);
    }
}

/// `copy_rows` with `+=` instead of overwrite (the replica-sum backward).
/// The inner zipped add over a contiguous block is the shape LLVM
/// auto-vectorizes; the contiguous case fuses to one pass.
pub fn accumulate_rows(
    dst: &mut [f32],
    dst_off: usize,
    dst_stride: usize,
    src: &[f32],
    src_off: usize,
    src_stride: usize,
    rows: usize,
    block: usize,
) {
    if rows == 0 || block == 0 {
        return;
    }
    debug_assert!(dst_off + (rows - 1) * dst_stride + block <= dst.len());
    debug_assert!(src_off + (rows - 1) * src_stride + block <= src.len());
    if dst_stride == block && src_stride == block {
        let (d, s) = (
            &mut dst[dst_off..dst_off + rows * block],
            &src[src_off..src_off + rows * block],
        );
        for (a, b) in d.iter_mut().zip(s) {
            *a += b;
        }
        return;
    }
    for r in 0..rows {
        let (a, b) = (dst_off + r * dst_stride, src_off + r * src_stride);
        for (x, y) in dst[a..a + block].iter_mut().zip(&src[b..b + block]) {
            *x += y;
        }
    }
}

// ---------------------------------------------------------------------------
// ScratchArena: size-class buffer pool for the relayout hot path
// ---------------------------------------------------------------------------

/// Bound on pooled buffers per dtype — a leak backstop, far above what a
/// step's ping-pong working set (a few tensors per rank per boundary)
/// ever holds.
const MAX_POOLED: usize = 256;

/// Default bound on pooled BYTES per dtype. The count cap alone is not a
/// memory bound: the pipeline also recycles exec-output tensors the pool
/// never sourced, and at multi-million-token shapes a single relayout
/// buffer is tens of MB — 256 of those would pin multiple GiB for the
/// trainer's lifetime. Incoming recycles beyond the budget are dropped
/// (freed) instead of parked. Long-sequence configs whose relayout
/// working set legitimately exceeds this should raise the budget
/// (`ScratchArena::with_byte_budget` / `TrainerOptions::arena_byte_budget`)
/// or the pool will shed buffers and miss on every checkout.
pub const DEFAULT_POOL_BYTE_BUDGET: usize = 1 << 30;

/// One dtype's free list plus its pooled-byte total (tracked
/// incrementally — no O(pool) scan per recycle).
#[derive(Debug, Default)]
struct Pool<T> {
    bufs: Vec<Vec<T>>,
    bytes: usize,
}

/// Size-class scratch-buffer pool: `take_*` checks out a recycled
/// `Vec` (best-fit by capacity), `recycle*` returns it. At steady state
/// — after the first train-step cycle has populated the pool — every
/// relayout checkout is a hit and the hot path performs zero heap
/// allocation (see DESIGN.md §Buffer lifecycle).
///
/// Counters: `hits` = checkouts served from the pool, `misses` =
/// checkouts that had to allocate. `Sync` (mutex + atomics) so a
/// `Trainer` holding one can be borrowed across the scoped rank threads.
#[derive(Debug)]
pub struct ScratchArena {
    f32_free: Mutex<Pool<f32>>,
    i32_free: Mutex<Pool<i32>>,
    byte_budget: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for ScratchArena {
    fn default() -> ScratchArena {
        ScratchArena::with_byte_budget(DEFAULT_POOL_BYTE_BUDGET)
    }
}

/// Best-fit checkout shared by both dtype pools: take the smallest
/// pooled buffer whose capacity holds `len` (hit), else allocate
/// (miss). Reused buffers keep their old contents where possible — the
/// checkout contract is "contents unspecified".
fn take_from<T: Copy + Default>(
    pool: &Mutex<Pool<T>>,
    hits: &AtomicU64,
    misses: &AtomicU64,
    len: usize,
) -> Vec<T> {
    let mut pool = lock_clean(pool);
    let best = pool
        .bufs
        .iter()
        .enumerate()
        .filter(|(_, v)| v.capacity() >= len)
        .min_by_key(|(_, v)| v.capacity())
        .map(|(i, _)| i);
    match best {
        Some(i) => {
            let mut v = pool.bufs.swap_remove(i);
            pool.bytes -= v.capacity() * std::mem::size_of::<T>();
            drop(pool);
            hits.fetch_add(1, Ordering::Relaxed);
            if v.len() >= len {
                v.truncate(len); // no zero-fill: full-overwrite contract
            } else {
                v.resize(len, T::default());
            }
            v
        }
        None => {
            drop(pool);
            misses.fetch_add(1, Ordering::Relaxed);
            vec![T::default(); len]
        }
    }
}

fn recycle_into<T>(pool: &Mutex<Pool<T>>, byte_budget: usize, v: Vec<T>) {
    if v.capacity() == 0 {
        return;
    }
    let incoming = v.capacity() * std::mem::size_of::<T>();
    let mut pool = lock_clean(pool);
    if pool.bufs.len() < MAX_POOLED && pool.bytes + incoming <= byte_budget {
        pool.bytes += incoming;
        pool.bufs.push(v);
    }
}

impl ScratchArena {
    pub fn new() -> ScratchArena {
        ScratchArena::default()
    }

    /// Arena with a custom per-dtype pooled-byte budget (see
    /// `DEFAULT_POOL_BYTE_BUDGET` for why the default exists and when to
    /// raise it).
    pub fn with_byte_budget(bytes: usize) -> ScratchArena {
        ScratchArena {
            f32_free: Mutex::default(),
            i32_free: Mutex::default(),
            byte_budget: bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Check out an f32 buffer of exactly `len` elements. CONTENTS ARE
    /// UNSPECIFIED (recycled data) — for paths that overwrite every
    /// element, which is every relayout copy path. Use `take_f32_zeroed`
    /// when accumulating.
    pub fn take_f32(&self, len: usize) -> Vec<f32> {
        take_from(&self.f32_free, &self.hits, &self.misses, len)
    }

    /// Check out an f32 buffer of `len` zeros (accumulation paths).
    pub fn take_f32_zeroed(&self, len: usize) -> Vec<f32> {
        let mut v = self.take_f32(len);
        v.fill(0.0);
        v
    }

    /// Check out an i32 buffer of exactly `len` elements, contents
    /// unspecified (token-id / label staging).
    pub fn take_i32(&self, len: usize) -> Vec<i32> {
        take_from(&self.i32_free, &self.hits, &self.misses, len)
    }

    pub fn recycle_f32(&self, v: Vec<f32>) {
        recycle_into(&self.f32_free, self.byte_budget, v);
    }

    pub fn recycle_i32(&self, v: Vec<i32>) {
        recycle_into(&self.i32_free, self.byte_budget, v);
    }

    /// Recycle a consumed tensor's storage (shape is dropped).
    pub fn recycle(&self, t: HostTensor) {
        match t.take_data().1 {
            TensorData::F32(v) => self.recycle_f32(v),
            TensorData::I32(v) => self.recycle_i32(v),
        }
    }

    /// Recycle a batch of consumed tensors (e.g. relayout outputs after
    /// device upload — the ping-pong half of the cycle).
    pub fn recycle_all<I: IntoIterator<Item = HostTensor>>(&self, ts: I) {
        for t in ts {
            self.recycle(t);
        }
    }

    /// Arena-backed deep copy: check out a buffer of the source's length,
    /// memcpy the contents, wrap in a tensor of the same shape. This is
    /// the offload engine's copy-stream primitive — one call per simulated
    /// D2H/H2D transfer — so at steady state a copy costs one memcpy and
    /// zero heap allocation. Bit-preserving by construction, which is what
    /// makes the async offload path's losses bit-identical to the sync
    /// tape's.
    pub fn copy_tensor(&self, src: &HostTensor) -> HostTensor {
        match src {
            HostTensor::F32 { shape, data } => {
                let mut buf = self.take_f32(data.len());
                buf.copy_from_slice(data);
                HostTensor::F32 { shape: shape.clone(), data: buf }
            }
            HostTensor::I32 { shape, data } => {
                let mut buf = self.take_i32(data.len());
                buf.copy_from_slice(data);
                HostTensor::I32 { shape: shape.clone(), data: buf }
            }
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Fraction of checkouts served without allocating (1.0 = steady
    /// state, fully allocation-free).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = (self.hits() as f64, self.misses() as f64);
        if h + m == 0.0 {
            return 1.0;
        }
        h / (h + m)
    }

    /// Buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        lock_clean(&self.f32_free).bufs.len() + lock_clean(&self.i32_free).bufs.len()
    }

    /// Bytes currently parked in the pool (both dtypes).
    pub fn pooled_bytes(&self) -> usize {
        lock_clean(&self.f32_free).bytes + lock_clean(&self.i32_free).bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.size_bytes(), 24);
        assert_eq!(t.dtype(), Dtype::F32);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn rejects_bad_shape() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn add_assign_accumulates() {
        let mut a = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = HostTensor::f32(vec![3], vec![0.5, 0.5, 0.5]);
        a.add_assign(&b).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[1.5, 2.5, 3.5]);
        assert!(a.add_assign(&HostTensor::zeros(&[4])).is_err());
    }

    #[test]
    fn scalar_round_trip() {
        let s = HostTensor::scalar(2.5);
        assert_eq!(s.scalar_f32().unwrap(), 2.5);
        assert!(HostTensor::zeros(&[2]).scalar_f32().is_err());
    }

    #[test]
    fn take_data_moves_storage_out() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let (shape, data) = t.take_data();
        assert_eq!(shape, vec![2, 2]);
        assert_eq!(data, TensorData::F32(vec![1.0, 2.0, 3.0, 4.0]));
        let (_, di) = HostTensor::i32(vec![1], vec![7]).take_data();
        assert_eq!(di, TensorData::I32(vec![7]));
    }

    #[test]
    fn copy_rows_strided_and_contiguous() {
        // strided src (stride 4, block 2) -> contiguous dst
        let src = vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let mut dst = vec![-1.0; 4];
        copy_rows(&mut dst, 0, 2, &src, 1, 4, 2, 2);
        assert_eq!(dst, vec![1.0, 2.0, 5.0, 6.0]);
        // contiguous both sides: single memcpy fast path
        let mut d2 = vec![0.0; 6];
        copy_rows(&mut d2, 0, 3, &src, 2, 3, 2, 3);
        assert_eq!(d2, vec![2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        // zero rows is a no-op
        copy_rows(&mut d2, 0, 3, &src, 0, 3, 0, 3);
        assert_eq!(d2, vec![2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn accumulate_rows_adds_in_place() {
        let src = vec![1.0, 2.0, 3.0, 4.0];
        let mut dst = vec![10.0, 10.0, 10.0, 10.0];
        accumulate_rows(&mut dst, 0, 2, &src, 0, 2, 2, 2);
        assert_eq!(dst, vec![11.0, 12.0, 13.0, 14.0]);
        // strided dst (stride 3, block 1)
        let mut d2 = vec![0.0; 6];
        accumulate_rows(&mut d2, 1, 3, &src, 0, 1, 2, 1);
        assert_eq!(d2, vec![0.0, 1.0, 0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn arena_recycles_and_counts_hits() {
        let arena = ScratchArena::new();
        let a = arena.take_f32(128);
        assert_eq!(a.len(), 128);
        assert_eq!((arena.hits(), arena.misses()), (0, 1));
        arena.recycle_f32(a);
        assert_eq!(arena.pooled(), 1);
        // same-size checkout is a hit; larger is a miss
        let b = arena.take_f32(100);
        assert_eq!(b.len(), 100);
        assert_eq!((arena.hits(), arena.misses()), (1, 1));
        let c = arena.take_f32(4096);
        assert_eq!((arena.hits(), arena.misses()), (1, 2));
        arena.recycle_f32(b);
        arena.recycle_f32(c);
        // best-fit: a 128-elem ask reuses the 128-cap buffer, not 4096
        let d = arena.take_f32(128);
        assert!(d.capacity() < 4096);
        assert!(arena.hit_rate() > 0.0);
    }

    #[test]
    fn arena_zeroed_checkout_is_zero_after_reuse() {
        let arena = ScratchArena::new();
        arena.recycle_f32(vec![5.0; 64]);
        let v = arena.take_f32_zeroed(64);
        assert!(v.iter().all(|&x| x == 0.0));
        // non-zeroed reuse keeps the old contents (full-overwrite contract)
        arena.recycle_f32(vec![5.0; 64]);
        let w = arena.take_f32(64);
        assert_eq!(w, vec![5.0; 64]);
    }

    #[test]
    fn arena_byte_budget_sheds_excess_buffers() {
        // budget of 100 f32-bytes = 25 elements per dtype pool
        let arena = ScratchArena::with_byte_budget(100);
        arena.recycle_f32(vec![0.0; 20]); // 80 bytes: kept
        assert_eq!(arena.pooled(), 1);
        arena.recycle_f32(vec![0.0; 10]); // would make 120 bytes: dropped
        assert_eq!(arena.pooled(), 1);
        assert_eq!(arena.pooled_bytes(), 80);
        // checking out releases budget; the next recycle fits again
        let v = arena.take_f32(20);
        assert_eq!(arena.pooled_bytes(), 0);
        arena.recycle_f32(v);
        assert_eq!(arena.pooled_bytes(), 80);
    }

    #[test]
    fn arena_recycles_tensors_of_both_dtypes() {
        let arena = ScratchArena::new();
        arena.recycle(HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]));
        arena.recycle(HostTensor::i32(vec![2], vec![4, 5]));
        assert_eq!(arena.pooled(), 2);
        assert_eq!(arena.take_i32(2).len(), 2);
        assert_eq!((arena.hits(), arena.misses()), (1, 0));
    }

    #[test]
    fn copy_tensor_is_bit_identical_and_pooled() {
        let arena = ScratchArena::new();
        let src = HostTensor::f32(vec![2, 3], vec![1.0, -0.0, f32::MIN_POSITIVE, 3.5, -2.0, 9.0]);
        let cp = arena.copy_tensor(&src);
        assert_eq!(cp.shape(), src.shape());
        for (a, b) in cp.as_f32().unwrap().iter().zip(src.as_f32().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Round-tripping through the pool makes the second copy a hit.
        arena.recycle(cp);
        let _cp2 = arena.copy_tensor(&src);
        assert_eq!((arena.hits(), arena.misses()), (1, 1));
        // i32 path too (token-id checkpoints).
        let si = HostTensor::i32(vec![2], vec![7, -3]);
        assert_eq!(arena.copy_tensor(&si), si);
    }

    #[test]
    fn arena_is_shareable_across_threads() {
        // The offload engine checks buffers out from its stream workers;
        // this pins the Send + Sync bound the workers rely on.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ScratchArena>();
        assert_send_sync::<HostTensor>();
        let arena = std::sync::Arc::new(ScratchArena::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let arena = &arena;
                s.spawn(move || {
                    for _ in 0..8 {
                        let v = arena.take_f32(64);
                        arena.recycle_f32(v);
                    }
                });
            }
        });
        assert_eq!(arena.hits() + arena.misses(), 32);
    }

    #[test]
    fn literal_round_trip_f32_and_i32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), t);
        let ti = HostTensor::i32(vec![3], vec![7, -100, 2]);
        let lit = ti.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), ti);
    }
}
