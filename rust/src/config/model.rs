//! Paper-scale model presets (the three evaluation models of §5.3).

/// Architecture description sufficient for the memory estimator, the flos
/// formula, and Ulysses shard math. Matches the published configs.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelPreset {
    pub name: &'static str,
    pub params: u64,
    pub hidden: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub vocab: usize,
}

impl ModelPreset {
    /// Per-rank (q_heads, kv_heads) under Ulysses SP (paper §3.2.1).
    /// kv heads replicate when `n_kv_heads < sp`.
    pub fn head_shard(&self, sp: usize) -> Option<(usize, usize)> {
        if sp == 0 || self.n_q_heads % sp != 0 {
            return None; // §7.1: q_heads must be divisible by SP degree
        }
        let q = self.n_q_heads / sp;
        let kv = if self.n_kv_heads >= sp {
            // contiguous split requires divisibility too
            if self.n_kv_heads % sp != 0 {
                return None;
            }
            self.n_kv_heads / sp
        } else {
            1
        };
        Some((q, kv))
    }

    /// Max usable SP degree (paper §7.1: bounded by q-head count).
    pub fn max_sp(&self) -> usize {
        self.n_q_heads
    }

    /// All SP degrees valid for this model up to `limit`.
    pub fn valid_sp_degrees(&self, limit: usize) -> Vec<usize> {
        (1..=limit.min(self.max_sp()))
            .filter(|sp| self.head_shard(*sp).is_some())
            .collect()
    }
}

/// The paper's evaluation models (§5.3.1–§5.3.3) plus the runnable configs'
/// architectural mirrors (so the simulator can also be asked about them).
pub const PRESETS: &[ModelPreset] = &[
    // meta-llama/Llama-3.1-8B-Instruct: 32 q, 8 kv (§5.3.1)
    ModelPreset {
        name: "llama3-8b",
        params: 8_030_000_000,
        hidden: 4096,
        n_layers: 32,
        n_q_heads: 32,
        n_kv_heads: 8,
        head_dim: 128,
        ffn: 14336,
        vocab: 128_256,
    },
    // meta-llama/Llama-3.1-70B-Instruct: 64 q, 8 kv (§5.3.2)
    ModelPreset {
        name: "llama3-70b",
        params: 70_550_000_000,
        hidden: 8192,
        n_layers: 80,
        n_q_heads: 64,
        n_kv_heads: 8,
        head_dim: 128,
        ffn: 28672,
        vocab: 128_256,
    },
    // Qwen/Qwen3-32B: 64 q, 8 kv (§5.3.3)
    ModelPreset {
        name: "qwen3-32b",
        params: 32_760_000_000,
        hidden: 5120,
        n_layers: 64,
        n_q_heads: 64,
        n_kv_heads: 8,
        head_dim: 128,
        ffn: 25600,
        vocab: 151_936,
    },
];

pub fn preset(name: &str) -> Option<&'static ModelPreset> {
    PRESETS.iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_head_shard_examples() {
        let m8 = preset("llama3-8b").unwrap();
        // "32 q_heads, 8 kv_heads, sp=8 => 4 q, 1 kv"
        assert_eq!(m8.head_shard(8), Some((4, 1)));
        // "32 q_heads, 8 kv_heads, sp=32 => 1 q, 1 kv (replicated)"
        assert_eq!(m8.head_shard(32), Some((1, 1)));
        // "32 q_heads, 4 kv_heads, sp=8 => 4 q, 1 kv (replicated)"
        let hypothetical = ModelPreset { n_kv_heads: 4, ..m8.clone() };
        assert_eq!(hypothetical.head_shard(8), Some((4, 1)));
    }

    #[test]
    fn sp_divisibility_limit() {
        let m8 = preset("llama3-8b").unwrap();
        assert!(m8.head_shard(3).is_none());   // 32 % 3 != 0 (§7.1)
        assert!(m8.head_shard(64).is_none());  // beyond q-head count
        assert_eq!(m8.max_sp(), 32);
        // Llama-70B trains on 16..64 GPUs (§5.3.2): sp=64 valid (64 q heads)
        let m70 = preset("llama3-70b").unwrap();
        assert_eq!(m70.head_shard(64), Some((1, 1)));
        assert_eq!(m70.valid_sp_degrees(64), vec![1, 2, 4, 8, 16, 32, 64]);
    }

    #[test]
    fn kv_replication_boundary() {
        let m = preset("qwen3-32b").unwrap(); // 64 q, 8 kv
        assert_eq!(m.head_shard(8), Some((8, 1)));
        assert_eq!(m.head_shard(16), Some((4, 1))); // kv replicated 16/8=2x
        assert_eq!(m.head_shard(4), Some((16, 2)));
    }
}
