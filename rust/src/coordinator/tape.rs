//! Activation-checkpoint tape with CPU offload (paper §3.3).
//!
//! Forward stores ONE tensor per (layer, rank): the layer-input hidden
//! shard `[S/sp, hidden]`. Backward pops them in reverse and replays the
//! layer (the stage VJPs recompute internals — §3.3's activation
//! checkpointing). With `offload` enabled the checkpoint is accounted
//! against the *host* pool instead of the device tracker, which is what
//! flattens the paper's Figure-7 memory "hill": peak device usage stops
//! depending on layer count.

use std::sync::Arc;

use anyhow::Result;

use crate::memory::{HostPool, MemoryTracker};
use crate::obs::{Category, Tracer};
use crate::runtime::tensor::{HostTensor, ScratchArena};

/// Where a checkpoint currently resides.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residence {
    Device,
    Host,
}

struct Slot {
    tensor: HostTensor,
    residence: Residence,
    bytes: u64,
}

/// Per-rank checkpoint tape for one step.
pub struct CheckpointTape {
    pub offload: bool,
    slots: Vec<Vec<Option<Slot>>>, // [layer][rank]
    /// Cumulative device<->host transfer volume this step (both ways).
    pub transfer_bytes: u64,
    tracer: Arc<Tracer>,
}

impl CheckpointTape {
    pub fn new(n_layers: usize, world: usize, offload: bool) -> CheckpointTape {
        CheckpointTape {
            offload,
            slots: (0..n_layers)
                .map(|_| (0..world).map(|_| None).collect())
                .collect(),
            transfer_bytes: 0,
            tracer: Tracer::off(),
        }
    }

    /// Builder: record `Offload` spans for store/fetch on `tracer`.
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> CheckpointTape {
        self.tracer = tracer;
        self
    }

    /// Store layer `li`'s input for `rank`. Device tracker sees the
    /// checkpoint only while it's device-resident.
    pub fn store(
        &mut self,
        li: usize,
        rank: usize,
        tensor: HostTensor,
        device: &mut MemoryTracker,
        host: &mut HostPool,
    ) -> Result<()> {
        let bytes = tensor.size_bytes() as u64;
        let mut span = self.tracer.span(
            Category::Offload,
            if self.offload { "ckpt_store_host" } else { "ckpt_store_device" },
        );
        span.set_rank(rank);
        span.set_bytes(bytes);
        let residence = if self.offload {
            host.alloc(bytes)?;            // may fail: host RAM is finite
            self.transfer_bytes += bytes;  // device -> host copy
            Residence::Host
        } else {
            device.alloc(bytes, "ckpt")?;
            Residence::Device
        };
        self.slots[li][rank] = Some(Slot { tensor, residence, bytes });
        Ok(())
    }

    /// Fetch layer `li`'s input back for recompute; restores to device
    /// (backward needs it on-GPU — the paper notes this copy cannot
    /// overlap much in backward).
    ///
    /// Accounting contract: the restored checkpoint is DEVICE-resident
    /// until the recompute is done with it, so fetch leaves `bytes`
    /// charged to the device tracker's `ckpt` tag in both residence modes
    /// (host-resident slots move their charge host→device here). The
    /// caller must `device.free(bytes, "ckpt")` when it recycles the
    /// returned tensor — the pipeline does this at the end of each
    /// backward layer. (Before this rule, a host-resident checkpoint was
    /// never charged on fetch and the backward device peak understated
    /// resident checkpoint bytes.)
    pub fn fetch(
        &mut self,
        li: usize,
        rank: usize,
        device: &mut MemoryTracker,
        host: &mut HostPool,
    ) -> Result<HostTensor> {
        let slot = self.slots[li][rank]
            .take()
            .ok_or_else(|| anyhow::anyhow!("checkpoint ({li},{rank}) missing"))?;
        let mut span = self.tracer.span(
            Category::Offload,
            match slot.residence {
                Residence::Host => "ckpt_fetch_host",
                Residence::Device => "ckpt_fetch_device",
            },
        );
        span.set_rank(rank);
        span.set_bytes(slot.bytes);
        match slot.residence {
            Residence::Host => {
                // Charge the device side first: if it OOMs, put the slot
                // back so nothing is double-freed or leaked.
                if let Err(e) = device.alloc(slot.bytes, "ckpt") {
                    drop(span);
                    self.slots[li][rank] = Some(slot);
                    return Err(e);
                }
                host.free(slot.bytes);
                self.transfer_bytes += slot.bytes; // host -> device copy
            }
            Residence::Device => {} // already charged since store
        }
        Ok(slot.tensor)
    }

    /// Drop every remaining slot, releasing its host/device charge and
    /// recycling its tensor into `arena`. The mid-step error path: after
    /// a backward stage fails, the un-fetched checkpoints must not leave
    /// phantom bytes in the pools or leak their buffers.
    pub fn clear(
        &mut self,
        device: &mut MemoryTracker,
        host: &mut HostPool,
        arena: &ScratchArena,
    ) {
        for layer in &mut self.slots {
            for slot in layer.iter_mut() {
                if let Some(s) = slot.take() {
                    match s.residence {
                        Residence::Host => host.free(s.bytes),
                        Residence::Device => device.free(s.bytes, "ckpt"),
                    }
                    arena.recycle(s.tensor);
                }
            }
        }
    }

    /// Device-resident checkpoint bytes right now (Figure 7's "hill").
    pub fn device_bytes(&self) -> u64 {
        self.slots
            .iter()
            .flatten()
            .flatten()
            .filter(|s| s.residence == Residence::Device)
            .map(|s| s.bytes)
            .sum()
    }

    pub fn host_bytes(&self) -> u64 {
        self.slots
            .iter()
            .flatten()
            .flatten()
            .filter(|s| s.residence == Residence::Host)
            .map(|s| s.bytes)
            .sum()
    }

    pub fn stored(&self) -> usize {
        self.slots.iter().flatten().flatten().count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::{HostPool, MemoryTracker};

    fn t(n: usize) -> HostTensor {
        HostTensor::zeros(&[n])
    }

    #[test]
    fn device_tape_grows_then_shrinks() {
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut tape = CheckpointTape::new(3, 1, false);
        for li in 0..3 {
            tape.store(li, 0, t(256), &mut dev, &mut host).unwrap();
        }
        assert_eq!(tape.device_bytes(), 3 * 1024);
        assert_eq!(dev.current(), 3 * 1024);
        for li in (0..3).rev() {
            let ck = tape.fetch(li, 0, &mut dev, &mut host).unwrap();
            // The restored checkpoint stays device-charged through the
            // recompute; the caller releases it when done with the tensor.
            dev.free(ck.size_bytes() as u64, "ckpt");
        }
        assert_eq!(dev.current(), 0);
        assert_eq!(tape.stored(), 0);
        assert_eq!(dev.underflow_events(), 0);
    }

    #[test]
    fn fetch_charges_restored_checkpoint_to_device() {
        // Regression: a host-resident checkpoint restored for recompute
        // IS device-resident — fetch must move the charge host→device so
        // the backward device peak sees it, and the caller frees it when
        // the recompute recycles the tensor.
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut tape = CheckpointTape::new(1, 1, true);
        tape.store(0, 0, t(256), &mut dev, &mut host).unwrap();
        assert_eq!((dev.current(), host.current()), (0, 1024));
        let ck = tape.fetch(0, 0, &mut dev, &mut host).unwrap();
        assert_eq!(host.current(), 0, "host slot released on fetch");
        assert_eq!(dev.current(), 1024, "restored checkpoint charged to device");
        assert_eq!(dev.tag_bytes("ckpt"), 1024);
        dev.free(ck.size_bytes() as u64, "ckpt");
        assert_eq!(dev.current(), 0);
        assert_eq!(tape.transfer_bytes, 2 * 1024, "both copy directions counted");
    }

    #[test]
    fn fetch_oom_restores_the_slot() {
        // Device too small to take the restored checkpoint back: fetch
        // must fail WITHOUT dropping the checkpoint or corrupting the
        // host/device ledgers.
        let mut dev = MemoryTracker::new(100);
        let mut host = HostPool::new(1 << 30);
        let mut tape = CheckpointTape::new(1, 1, true);
        tape.store(0, 0, t(256), &mut dev, &mut host).unwrap();
        assert!(tape.fetch(0, 0, &mut dev, &mut host).is_err());
        assert_eq!(tape.stored(), 1, "slot survives the failed fetch");
        assert_eq!(host.current(), 1024, "host charge untouched");
        assert_eq!(dev.current(), 0);
    }

    #[test]
    fn clear_releases_remaining_slots() {
        use crate::runtime::tensor::ScratchArena;
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let arena = ScratchArena::new();
        // One host-resident and one device-resident tape, both mid-step.
        let mut tape = CheckpointTape::new(2, 1, true);
        tape.store(0, 0, t(64), &mut dev, &mut host).unwrap();
        tape.store(1, 0, t(64), &mut dev, &mut host).unwrap();
        let mut dtape = CheckpointTape::new(1, 1, false);
        dtape.store(0, 0, t(64), &mut dev, &mut host).unwrap();
        tape.clear(&mut dev, &mut host, &arena);
        dtape.clear(&mut dev, &mut host, &arena);
        assert_eq!((tape.stored(), dtape.stored()), (0, 0));
        assert_eq!(host.current(), 0, "no phantom host bytes");
        assert_eq!(dev.current(), 0, "no phantom device bytes");
        assert_eq!(arena.pooled(), 3, "buffers recycled, not leaked");
        assert_eq!(host.underflow_events() + dev.underflow_events(), 0);
    }

    #[test]
    fn offload_keeps_device_flat() {
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut tape = CheckpointTape::new(4, 2, true);
        for li in 0..4 {
            for r in 0..2 {
                tape.store(li, r, t(100), &mut dev, &mut host).unwrap();
            }
        }
        assert_eq!(tape.device_bytes(), 0);        // Figure 7: hill is gone
        assert_eq!(dev.current(), 0);
        assert_eq!(host.current(), 8 * 400);
        assert_eq!(tape.transfer_bytes, 8 * 400);  // device->host copies
    }

    #[test]
    fn host_pool_exhaustion_surfaces() {
        // The paper §5.3.2: 1.9TiB host RAM capped Llama-70B seqlen.
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(500);
        let mut tape = CheckpointTape::new(2, 1, true);
        tape.store(0, 0, t(100), &mut dev, &mut host).unwrap();
        let err = tape.store(1, 0, t(100), &mut dev, &mut host);
        assert!(err.is_err());
    }

    #[test]
    fn traced_tape_emits_offload_spans() {
        use crate::obs::{Category, Tracer};
        let tracer = Arc::new(Tracer::new(true));
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut tape = CheckpointTape::new(1, 1, true).with_tracer(tracer.clone());
        tape.store(0, 0, t(64), &mut dev, &mut host).unwrap();
        tape.fetch(0, 0, &mut dev, &mut host).unwrap();
        let spans = tracer.drain();
        assert_eq!(spans.len(), 2);
        assert!(spans
            .iter()
            .all(|s| s.cat == Category::Offload && s.rank == Some(0) && s.bytes == 256));
        assert_eq!(spans[0].name, "ckpt_store_host");
        assert_eq!(spans[1].name, "ckpt_fetch_host");
    }

    #[test]
    fn double_fetch_is_an_error() {
        let mut dev = MemoryTracker::new(1 << 30);
        let mut host = HostPool::new(1 << 30);
        let mut tape = CheckpointTape::new(1, 1, false);
        tape.store(0, 0, t(4), &mut dev, &mut host).unwrap();
        tape.fetch(0, 0, &mut dev, &mut host).unwrap();
        assert!(tape.fetch(0, 0, &mut dev, &mut host).is_err());
    }
}
